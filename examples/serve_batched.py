"""End-to-end serving driver (the paper's kind is inference): train a small
model briefly, then serve BATCHED requests through prefill + decode with an
int8-quantized KV cache (the paper's Q^a applied to the cache, Eq. 2),
reporting tokens/s and cache-memory savings.

  PYTHONPATH=src python examples/serve_batched.py [--batch 8] [--new 32]
"""

import argparse
import dataclasses
import time

import numpy as np

from repro.configs import get_config
from repro.core.opsc import kv_cache_bytes
from repro.data.pipeline import ZipfMarkov, lm_loader
from repro.models.transformer import RuntimeOpts
from repro.serving.engine import Engine
from repro.training.optimizer import AdamWConfig
from repro.training.train_loop import TrainConfig, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--new", type=int, default=32)
    ap.add_argument("--steps", type=int, default=80)
    args = ap.parse_args()

    cfg = dataclasses.replace(get_config("gemma2-2b").tiny(), vocab_size=128)
    opts = RuntimeOpts(q_chunk=64, kv_chunk=64, remat=False)
    corpus = ZipfMarkov(vocab_size=cfg.vocab_size, branching=4, seed=0)
    loader = lm_loader(corpus, batch=16, seq=64, num_batches=args.steps)
    tc = TrainConfig(AdamWConfig(lr=3e-3, warmup_steps=10, total_steps=args.steps))
    params, _, _ = train(cfg, loader, tc, opts, log_every=40)

    rng = np.random.default_rng(1)
    prompts = corpus.sample(rng, args.batch, 32).astype(np.int32)

    for quant in (False, True):
        o = dataclasses.replace(opts, quantized_kv=quant)
        eng = Engine(cfg, params, o, cache_len=32 + args.new)
        eng.generate(prompts, 2)  # warm the jit caches
        t0 = time.time()
        res = eng.generate(prompts, args.new)
        dt = time.time() - t0
        tps = args.batch * args.new / dt
        label = "int8-KV " if quant else "bf16-KV"
        print(f"[serve] {label} batch={args.batch} new={args.new}: "
              f"{tps:7.1f} tok/s ({dt*1e3:.0f} ms)")

    # Eq. (2) accounting at serving scale for the FULL architecture
    full = get_config("gemma2-2b")
    m = full.pattern[0].mixer
    hd = m.num_kv_heads * m.head_dim
    for qa in (16, 8, 4):
        b = kv_cache_bytes(4096, full.num_layers // 2, full.num_layers, hd, qa, qa)
        print(f"[serve] Eq.2 KV cache @4096 tokens, Qa={qa:2d}: {b/2**20:8.1f} MiB")


if __name__ == "__main__":
    main()
