"""End-to-end split-computing inference — the paper's full system (§2).

1. Train a small LM on the induction-copy task (attention-dependent, so
   compression damage is measurable).
2. Solve the unified optimization (Eq. 8) for the split point + quantization
   under an edge memory budget.
3. Deploy with SplitEngine: OPSC front quantization, TS+TAB-Q payload
   compression, ε-outage channel model, Algorithm-2 early exit.
4. Report accuracy / uplink / latency vs. the monolithic engine.

  PYTHONPATH=src python examples/split_inference.py [--steps 200]
"""

import argparse
import dataclasses

import jax
import numpy as np

from repro.configs import get_config
from repro.core.channel import ChannelConfig, optimal_rate
from repro.core.opsc import OPSCConfig
from repro.core.split_optimizer import SplitSearchSpace, optimize_split
from repro.data.pipeline import induction_batch, induction_loader
from repro.models.transformer import RuntimeOpts
from repro.serving.engine import Engine
from repro.serving.split_engine import SplitEngine
from repro.training.optimizer import AdamWConfig
from repro.training.train_loop import TrainConfig, train

OPTS = RuntimeOpts(q_chunk=64, kv_chunk=64, remat=False, moe_capacity_factor=0.0)


def copy_accuracy(engine_generate, prompts, half: int) -> float:
    """Feed [prefix][SEP], generate half tokens, score against the prefix."""
    out = engine_generate(prompts[:, : half + 1], half)
    pred = out[:, half + 1 : 2 * half + 1]
    return float(np.mean(pred == prompts[:, :half]))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    args = ap.parse_args()

    # -- 1. train the vehicle -------------------------------------------------
    cfg = dataclasses.replace(get_config("llama2-7b").tiny(), vocab_size=64,
                              num_blocks=4)  # 4 layers → 4 split candidates
    loader = induction_loader(cfg.vocab_size, batch=32, seq=33,
                              num_batches=args.steps)
    tc = TrainConfig(AdamWConfig(lr=3e-3, warmup_steps=20, total_steps=args.steps))
    params, _, hist = train(cfg, loader, tc, OPTS, log_every=50)
    print(f"[split] trained: ce {hist[0]['ce']:.3f} → {hist[-1]['ce']:.3f}")

    rng = np.random.default_rng(0)
    prompts, _ = induction_batch(rng, 32, 33, cfg.vocab_size)
    prompts = prompts.astype(np.int32)
    half = 16

    mono = Engine(cfg, params, OPTS, cache_len=128)
    base_acc = copy_accuracy(lambda p, n: mono.generate(p, n).tokens, prompts, half)
    print(f"[split] monolithic copy-accuracy: {base_acc:.3f}")

    # -- 2. unified optimization (Eq. 8) --------------------------------------
    def acc_fn(opsc: OPSCConfig) -> float:
        eng = SplitEngine(cfg, params, opsc, opts=OPTS, cache_len=128)
        return copy_accuracy(lambda p, n: eng.generate(p, n)[0], prompts[:8], half)

    budget = int(cfg.total_params() * 0.9)  # bytes ≈ force some quantization
    sol = optimize_split(
        num_layers=cfg.num_layers,
        layer_param_counts=cfg.layer_param_counts(),
        embed_params=cfg.embed_params(),
        kv_heads_dim=cfg.pattern[0].mixer.num_kv_heads * cfg.pattern[0].mixer.head_dim,
        max_tokens=64, memory_budget_bytes=budget,
        accuracy_fn=acc_fn, base_accuracy=base_acc, accuracy_drop=0.05,
        space=SplitSearchSpace(split_layers=[1, 2, 3], qw_bits=(4, 8),
                               qa_bits=(4, 8)))
    assert sol is not None, "no feasible split configuration"
    print(f"[split] Eq.8 solution: ℓ={sol.config.split_layer} "
          f"Qw=({sol.config.qw_front},{sol.config.qw_back}) "
          f"Qa=({sol.config.qa_front},{sol.config.qa_back}) "
          f"Ψ={sol.psi} mem={sol.memory_bytes/1e6:.1f}MB acc={sol.accuracy:.3f}")

    # -- 3./4. deploy + compare ----------------------------------------------
    chan = ChannelConfig()
    eng = SplitEngine(cfg, params, sol.config, channel=chan, deadline_s=0.5,
                      opts=OPTS, cache_len=128)
    t0 = __import__("time").time()
    out, stats = eng.generate(prompts[:, : half + 1], half)
    split_acc = float(np.mean(out[:, half + 1 : 2 * half + 1]
                              == prompts[:, :half]))
    print(f"[split] split copy-accuracy: {split_acc:.3f} "
          f"(Δ {split_acc - base_acc:+.3f})")
    print(f"[split] uplink: measured {stats.uplink_bits_measured/8e3:.1f} KB, "
          f"Eq.3 accounting {stats.uplink_bits_eq3/8e3:.1f} KB, "
          f"R*={optimal_rate(chan)/1e6:.2f} Mbit/s, "
          f"modeled latency {stats.latency_s*1e3:.1f} ms, "
          f"early_exits={stats.early_exits}")


if __name__ == "__main__":
    main()
