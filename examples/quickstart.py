"""Quickstart: train a tiny LM on the synthetic Zipf–Markov corpus, then
serve it through the request-level API (``LLMServer`` over the paged
continuous-batching backend, with a non-greedy sampled request mixed in).

  PYTHONPATH=src python examples/quickstart.py [--steps 150] [--smoke]

``--smoke`` shrinks the run (25 steps, short generations) — the CI
docs-check job executes it to prove the README's quickstart command works.
"""

import argparse
import dataclasses
import sys

import jax
import numpy as np

from repro.configs import get_config
from repro.data.pipeline import ZipfMarkov, lm_loader
from repro.models.transformer import RuntimeOpts
from repro.serving import LLMServer, SamplingParams
from repro.training.optimizer import AdamWConfig
from repro.training.train_loop import TrainConfig, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--arch", default="llama2-7b")  # tiny variant is used
    ap.add_argument("--smoke", action="store_true",
                    help="shrunken CI run (docs-check job)")
    args = ap.parse_args()
    if args.smoke:
        args.steps = min(args.steps, 25)

    cfg = dataclasses.replace(get_config(args.arch).tiny(), vocab_size=128)
    opts = RuntimeOpts(q_chunk=64, kv_chunk=64, remat=False,
                       moe_capacity_factor=0.0)
    corpus = ZipfMarkov(vocab_size=cfg.vocab_size, branching=4, seed=0)
    print(f"[quickstart] arch={cfg.name} params={cfg.total_params():,} "
          f"corpus entropy≈{corpus.entropy_rate_bits():.2f} bits/token")

    loader = lm_loader(corpus, batch=16, seq=64, num_batches=args.steps)
    tc = TrainConfig(AdamWConfig(lr=3e-3, warmup_steps=20, total_steps=args.steps))
    params, _, hist = train(cfg, loader, tc, opts, log_every=25)
    print(f"[quickstart] ce {hist[0]['ce']:.3f} → {hist[-1]['ce']:.3f}")

    # one request-level API over every backend; here: the paged
    # continuous-batching scheduler, mixing greedy and sampled requests
    # in one ragged batch (per-request knobs are traced operands — one
    # compiled decode shape serves the whole mix)
    server = LLMServer(cfg, params, opts, backend="paged",
                       num_pages=64, page_size=8, max_slots=4)
    rng = np.random.default_rng(0)
    prompts = corpus.sample(rng, batch=4, seq=16).astype(np.int32)
    max_tokens = 8 if args.smoke else 24
    rids = [server.submit(p, SamplingParams(
        max_tokens=max_tokens,
        temperature=0.0 if i < 3 else 0.8,  # last request samples
        seed=i)) for i, p in enumerate(prompts)]
    outputs = server.run()
    print("[quickstart] generated continuations (last row sampled at "
          "temperature 0.8):")
    for rid in rids:
        out = outputs[rid]
        print("  ", out.prompt.tolist(), "→", out.tokens.tolist(),
              f"[{out.finish_reason}, {out.tokens.size} tokens, "
              f"ttft {out.metrics.ttft_ticks} ticks]")


if __name__ == "__main__":
    main()
