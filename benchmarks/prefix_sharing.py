"""prefix_sharing benchmark: refcounted CoW prefix sharing + preemptive
lazy-growth scheduling vs the PR 2 baseline (per-request pages, worst-case
reservation).

The workload is the paper's multi-tenant shape: N requests that all carry
the same SYSTEM PROMPT (a long shared prefix) plus a short per-user suffix,
served from one Eq. 2-bounded pool. Three schedulers run the same mix:

  * baseline — PR 2 semantics: no sharing, worst-case page reservation at
    admission (each request's prompt + max_new pages held up front);
  * shared   — prefix sharing on (``submit(prefix_key=...)``): the system
    prompt is prefilled once, later requests fork onto its refcounted
    pages (CoW boundary copy when unaligned) and only suffix pages are
    allocated;
  * shared+lazy — sharing plus ``lazy_growth=True``: admission reserves
    only current-need pages, decode grows page by page and pool exhaustion
    preempts (evict-to-queue with bit-exact page-swap resume) — the
    highest admitted concurrency from the same pool.

Reported per variant: wall/tokens-per-sec (CPU, kernels in interpret mode —
CALL-PATH comparison, not TPU performance; the memory columns are exact on
any backend), peak physical pool bytes (shared pages once), peak logical
per-request Eq. 2 bytes, the analytical sharing-aware Eq. 2
(``core.opsc.kv_cache_bytes_shared``), mean decode concurrency
(slot_ticks/steps), prefix forks, preemptions, and the outputs-match check
against the baseline (prefix-shared runs must emit IDENTICAL greedy
tokens). JSON artifact under experiments/prefix_sharing/.

  PYTHONPATH=src python -m benchmarks.prefix_sharing [--smoke]

``--smoke`` runs one shrunken mix — the CI job's guard that the sharing +
preemption paths stay wired.
"""

from __future__ import annotations

import argparse
import json
import os
import time

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                       "prefix_sharing")

# name: (rng_seed, prefix_len, [(suffix_len, max_new), ...], num_pages) —
# a shared system prompt and ragged per-user turns. num_pages=None sizes the
# pool generously (sharing-only story); a small explicit pool forces the
# lazy variant into preemption. Seeds are pinned where quantized-prefix
# attention's fp drift would otherwise flip a greedy tie (the equivalence
# TESTS assert bit-parity on their own pinned workloads).
MIXES = {
    "sys_prompt_8way": (2, 24, [(3, 4), (5, 6), (2, 5), (4, 4), (6, 3),
                                (3, 6), (2, 4), (5, 5)], None),
    "sys_prompt_tight_pool": (0, 18, [(3, 6), (4, 5), (2, 6), (3, 5),
                                      (4, 6), (2, 5)], 11),
}
SMOKE_MIXES = {"sys_prompt_smoke": (0, 12, [(3, 3), (2, 4), (4, 3)], None)}

PAGE_SIZE = 4
MAX_SLOTS = 3  # fewer slots than requests → mid-stream admission exercised


def _build():
    import jax

    from repro.configs import get_config
    from repro.models.transformer import RuntimeOpts, init_params

    cfg = get_config("llama2-7b").tiny()
    params = init_params(cfg, jax.random.PRNGKey(0))
    opts = RuntimeOpts(q_chunk=16, kv_chunk=32, remat=False,
                       quantized_kv=True, moe_capacity_factor=0.0)
    return cfg, params, opts


def _pool_pages(prefix_len, jobs):
    """Default pool size: the BASELINE saturates (its worst-case
    reservations queue requests) while every variant completes without
    preemption — the two largest worst cases plus slack."""
    worst = sorted((-(-(prefix_len + sl + mn) // PAGE_SIZE))
                   for sl, mn in jobs)
    return max(sum(worst[-2:]) + 2, 8) + 1


def _serve(cfg, params, opts, prefix, jobs, suffixes, *, shared, lazy,
           num_pages):
    from repro.serving.scheduler import Scheduler

    import numpy as np

    sched = Scheduler(cfg, params, opts, num_pages=num_pages,
                      page_size=PAGE_SIZE, max_slots=MAX_SLOTS,
                      lazy_growth=lazy)
    rids = []
    for suf, (_, mn) in zip(suffixes, jobs):
        prompt = np.concatenate([prefix, suf])
        rids.append(sched.submit(
            prompt, mn,
            prefix_key="sys" if shared else None, prefix_len=prefix.size))
    total_tokens = sum(mn for _, mn in jobs)
    t0 = time.time()
    results = sched.run()
    wall = time.time() - t0
    st = sched.stats
    return results, rids, {
        "wall_s": round(wall, 3),
        "tokens_per_s": round(total_tokens / wall, 2),
        "decode_steps": st.steps,
        "prefill_waves": st.prefills,
        "admissions": st.admitted,
        "prefix_forks": st.prefix_forks,
        "preemptions": st.preemptions,
        "mean_decode_concurrency": round(st.slot_ticks / max(st.steps, 1), 2),
        "peak_occupancy": round(st.peak_occupancy, 3),
        "peak_pool_bytes": st.peak_pool_bytes,
        "peak_eq2_bytes": st.peak_eq2_bytes,
        "peak_shared_pages": st.peak_shared_pages,
        "peak_swap_bytes": st.peak_swap_bytes,
    }


def bench_prefix_sharing(smoke: bool = False):
    import numpy as np

    from repro.core.opsc import kv_cache_bytes_shared

    cfg, params, opts = _build()
    mixes = SMOKE_MIXES if smoke else MIXES
    rows, rec = [], {"config": {"arch": cfg.name, "page_size": PAGE_SIZE,
                                "max_slots": MAX_SLOTS, "smoke": smoke}}
    for name, (seed, prefix_len, jobs, num_pages) in mixes.items():
        rng = np.random.default_rng(seed)
        prefix = rng.integers(0, cfg.vocab_size, (prefix_len,)).astype(np.int32)
        suffixes = [rng.integers(0, cfg.vocab_size, (sl,)).astype(np.int32)
                    for sl, _ in jobs]
        if num_pages is None:
            num_pages = _pool_pages(prefix_len, jobs)
        variants = {}
        base_results = None
        for key, shared, lazy in (("baseline", False, False),
                                  ("shared", True, False),
                                  ("shared_lazy", True, True)):
            results, rids, m = _serve(cfg, params, opts, prefix, jobs,
                                      suffixes, shared=shared, lazy=lazy,
                                      num_pages=num_pages)
            if base_results is None:
                base_results = {r: results[r] for r in rids}
                m["outputs_match_baseline"] = True
            else:
                m["outputs_match_baseline"] = all(
                    np.array_equal(results[r], base_results[r])
                    for r in rids)
            variants[key] = m
        spec = cfg.pattern[0].mixer
        eq2_shared = kv_cache_bytes_shared(
            prefix_len,
            [prefix_len + sl + mn for sl, mn in jobs],
            cfg.num_layers, cfg.num_layers,
            spec.num_kv_heads * spec.head_dim, 8, 8)
        red = variants["baseline"]["peak_pool_bytes"] / max(
            variants["shared"]["peak_pool_bytes"], 1)
        red_lazy = variants["baseline"]["peak_pool_bytes"] / max(
            variants["shared_lazy"]["peak_pool_bytes"], 1)
        rec[name] = {
            "requests": len(jobs), "prefix_len": prefix_len,
            "pool_pages": num_pages, **variants,
            "eq2_shared_bytes_analytical": eq2_shared,
            "pool_bytes_reduction_shared": round(red, 2),
            "pool_bytes_reduction_shared_lazy": round(red_lazy, 2),
        }
        for key in variants:
            m = variants[key]
            rows.append((f"prefix_sharing/{name}_{key}", m["wall_s"] * 1e6,
                         f"tok/s={m['tokens_per_s']} "
                         f"pool={m['peak_pool_bytes']}B "
                         f"forks={m['prefix_forks']} "
                         f"preempt={m['preemptions']} "
                         f"match={m['outputs_match_baseline']}"))
        rows.append((f"prefix_sharing/{name}_mem_reduction", 0.0,
                     f"shared={round(red, 2)}x lazy={round(red_lazy, 2)}x"))
    from benchmarks.common import env_section
    rec.update(env_section())
    os.makedirs(OUT_DIR, exist_ok=True)
    out = os.path.join(OUT_DIR, "prefix_sharing_smoke.json" if smoke
                       else "prefix_sharing.json")
    with open(out, "w") as f:
        json.dump(rec, f, indent=1)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="one shrunken mix (CI prefix-sharing smoke job)")
    args = ap.parse_args()
    for name, us, derived in bench_prefix_sharing(smoke=args.smoke):
        print(f"{name},{us:.1f},{derived}", flush=True)


if __name__ == "__main__":
    main()
