"""decode_path benchmark: fp-cache vs int8-cache fused decode (§Roofline).

Compares `Engine`-style decode over (a) fp32 cache, (b) fp16 cache and
(c) the int8 quantized cache streamed by the Pallas decode-attention kernel,
at the same (batch, cache_len) config. Reports per-step latency (CPU with
kernels in interpret mode — call-path validation, NOT TPU performance) and
the cache bytes each path carries/streams, and writes a JSON record under
experiments/decode_path/ for the BENCH_* trajectory.

Byte accounting (per decode step, attention KV only):
  * resident_bytes — the KV cache arrays held in HBM (Eq. 2's memory term);
  * stream_bytes   — what the decode attention actually moves: the fp paths
    upcast the cache to an f32 compute copy (4 B/elem — the XLA chunked path
    materializes it; on CPU this is measured behavior, see
    kernels/decode_attention.py), while the kernel path streams the int8
    codes + per-(token, head) scales with in-register dequant.
"""

from __future__ import annotations

import dataclasses
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                       "decode_path")

BATCH = 4
PROMPT = 16
CACHE_LEN = 192  # < BLOCK_S: a single short kernel block, no padding


def _kv_bytes(caches) -> int:
    """Bytes of the attention-cache k/v/scale leaves (pos excluded)."""
    total = 0
    for c in caches:
        if not hasattr(c, "k"):
            continue
        for leaf in (c.k, c.v, c.k_scale, c.v_scale):
            if leaf is not None:
                total += leaf.size * leaf.dtype.itemsize
    return total


def _stream_bytes(caches) -> int:
    """Bytes the decode attention moves per step: f32 compute copies of fp
    caches vs. the int8 codes + f32 scales the kernel streams directly."""
    total = 0
    for c in caches:
        if not hasattr(c, "k"):
            continue
        if c.k_scale is None:  # fp path: k/v upcast to f32 for the contraction
            total += 2 * c.k.size * 4
        else:  # kernel path: int8 codes + per-(token, head) f32 scales
            total += 2 * (c.k.size * 1 + c.k_scale.size * 4)
    return total


def bench_decode_path():
    from repro.configs import get_config
    from repro.models.transformer import (RuntimeOpts, decode_step,
                                          init_params, prefill)

    cfg = get_config("llama2-7b").tiny()
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (BATCH, PROMPT)),
                         jnp.int32)
    nxt = jnp.asarray(rng.integers(0, cfg.vocab_size, (BATCH, 1)), jnp.int32)

    base = dict(q_chunk=16, kv_chunk=CACHE_LEN, remat=False,
                moe_capacity_factor=0.0)
    variants = {
        "fp32": RuntimeOpts(cache_dtype="float32", **base),
        "fp16": RuntimeOpts(cache_dtype="float16", **base),
        "int8": RuntimeOpts(quantized_kv=True, **base),
    }

    rows, rec = [], {"config": {"arch": cfg.name, "batch": BATCH,
                                "prompt": PROMPT, "cache_len": CACHE_LEN}}
    for name, opts in variants.items():
        _, caches = prefill(params, cfg, tokens, None, CACHE_LEN, opts)
        step = jax.jit(lambda p, t, c, pos, o=opts: decode_step(
            p, cfg, t, c, pos, o))
        jax.block_until_ready(step(params, nxt, caches, jnp.int32(PROMPT)))
        t0 = time.time()
        n = 5
        for i in range(n):
            logits, caches = step(params, nxt, caches, jnp.int32(PROMPT + i))
        jax.block_until_ready(logits)
        us = (time.time() - t0) / n * 1e6
        resident = _kv_bytes(caches)
        stream = _stream_bytes(caches)
        rec[name] = {"step_us": round(us, 1), "resident_bytes": resident,
                     "stream_bytes": stream}
        rows.append((f"decode_path/{name}_step", us,
                     f"resident={resident}B stream={stream}B"))

    rec["cache_bytes_reduction_vs_fp32"] = round(
        rec["fp32"]["resident_bytes"] / rec["int8"]["resident_bytes"], 2)
    rec["cache_bytes_reduction_vs_fp16"] = round(
        rec["fp16"]["resident_bytes"] / rec["int8"]["resident_bytes"], 2)
    rec["stream_bytes_reduction_vs_fp16"] = round(
        rec["fp16"]["stream_bytes"] / rec["int8"]["stream_bytes"], 2)
    from benchmarks.common import env_section
    rec.update(env_section())
    os.makedirs(OUT_DIR, exist_ok=True)
    with open(os.path.join(OUT_DIR, "decode_path.json"), "w") as f:
        json.dump(rec, f, indent=1)
    rows.append(("decode_path/stream_reduction_vs_fp16", 0.0,
                 rec["stream_bytes_reduction_vs_fp16"]))
    rows.append(("decode_path/resident_reduction_vs_fp32", 0.0,
                 rec["cache_bytes_reduction_vs_fp32"]))
    return rows
