"""packed_tick benchmark: the token-packed varlen tick vs the two-phase
chunked tick and the wave tick it subsumes.

The same three prompt mixes as ``benchmarks.chunked_prefill`` are served
through the SAME scheduler and pool (worst-case admission, kernels in
interpret mode off-TPU):

  * packed  — ``tick_mode="packed"``: each tick gathers every decoding
    slot's token PLUS up-to-budget prefill-chunk tokens into one flat
    ``(1, token_budget)`` buffer and dispatches ONE jitted ``packed_step``
    through the ``kernels.varlen_attention`` flat-batch page walk — one
    compiled shape for the whole run, pad only in the buffer's tail;
  * chunked — the default two-phase tick: one ``(1, chunk)`` prefill call
    per admitting slot, then one ``(max_slots, 1)`` decode call — every
    co-resident decode pays two dispatches per tick and both rectangles
    carry their own padding;
  * wave    — whole-prompt ragged wave prefill (one compile per
    (R_adm, S_pad) bucket), the pre-chunking baseline.

Reported per mix/variant: tokens/s, the TAIL tick latency (the longest
single tick — what a co-resident decode request experiences while a
prompt admits), the distinct-jit-shape count, the PAD FRACTION of all
dispatched token rows (packed: ``stats.packed_pad_tokens`` over the
buffer rows; chunked: the prefill rectangles' trailing pad plus the
decode call's empty slot rows — both exact from scheduler stats; wave
prefill padding is bucket-dependent and reported as null), and greedy
parity vs per-request ``Engine.generate``. CPU wall numbers are
call-path + dispatch-count comparisons, not TPU performance; the
tick/shape/pad columns are exact on any backend. JSON artifact under
experiments/packed_tick/.

  PYTHONPATH=src python -m benchmarks.packed_tick [--smoke]

``--smoke`` runs one shrunken mix — the CI packed-tick smoke step.
"""

from __future__ import annotations

import argparse
import json
import os
import time

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                       "packed_tick")

# (prompt_len, max_new_tokens) per request; pool pages per mix — the same
# workloads the chunked-prefill benchmark serves, so the two artifacts
# compose into one story
MIXES = {
    # the headline case: one long prompt admitted while short ones decode
    "one_long": {"jobs": [(48, 4), (4, 10), (6, 10), (5, 10)], "pages": 28},
    "bimodal": {"jobs": [(24, 4), (6, 8), (24, 4), (6, 8)], "pages": 28},
    # high decode occupancy: every slot decodes almost the whole run —
    # the mix where the per-tick dispatch count dominates
    "short": {"jobs": [(6, 6)] * 4, "pages": 20},
}
SMOKE_MIXES = {"one_long": {"jobs": [(16, 3), (4, 6)], "pages": 16}}

PAGE_SIZE = 4
CHUNK = 8
MAX_SLOTS = 3  # fewer slots than requests → mid-stream admission exercised


def _build():
    import jax

    from repro.configs import get_config
    from repro.models.transformer import RuntimeOpts, init_params

    cfg = get_config("llama2-7b").tiny()
    params = init_params(cfg, jax.random.PRNGKey(0))
    opts = RuntimeOpts(q_chunk=16, kv_chunk=32, remat=False,
                       quantized_kv=True, moe_capacity_factor=0.0)
    return cfg, params, opts


def _pad_fraction(sched, variant, prompt_tokens):
    """Exact pad share of all dispatched token rows, from scheduler stats.

    packed: the flat buffer's tail rows. chunked: each prefill call is a
    fixed ``(max_slots, chunk)`` rectangle (``stats.prefills`` counts the
    calls; only the admitting rows' chunk tokens are useful) and each
    decode call a ``(max_slots, 1)`` column (``steps * max_slots`` rows,
    ``slot_ticks`` useful). wave: prefill rows depend on the
    (R_adm, S_pad) buckets, which the stats don't record — None."""
    s = sched.stats
    if variant == "packed":
        total = s.packed_tokens + s.packed_pad_tokens
        return round(s.packed_pad_tokens / max(total, 1), 3)
    if variant == "chunked":
        rows = s.prefills * MAX_SLOTS * CHUNK + s.steps * MAX_SLOTS
        useful = prompt_tokens + s.slot_ticks
        return round((rows - useful) / max(rows, 1), 3)
    return None


def _serve(cfg, params, opts, jobs, prompts, variant, pages, telemetry=None):
    import numpy as np

    from repro.serving.scheduler import Scheduler

    max_seq = max(n + mn for n, mn in jobs)
    sched = Scheduler(cfg, params, opts, num_pages=pages,
                      page_size=PAGE_SIZE, max_slots=MAX_SLOTS,
                      max_seq_len=max_seq, tick_mode=variant,
                      prefill_chunk=CHUNK, telemetry=telemetry)
    rids = [sched.submit(p, mn) for p, (_, mn) in zip(prompts, jobs)]
    tick_walls = []
    t0 = time.time()
    while True:
        t_tick = time.time()
        more = sched.step()
        tick_walls.append(time.time() - t_tick)
        if not more:
            break
    wall = time.time() - t0
    total_tokens = sum(mn for _, mn in jobs)
    prompt_tokens = sum(n for n, _ in jobs)
    return sched.results, rids, {
        "wall_s": round(wall, 3),
        "tokens_per_s": round(total_tokens / wall, 2),
        "tail_tick_s": round(float(np.max(tick_walls)), 3),
        "median_tick_s": round(float(np.median(tick_walls)), 4),
        "ticks": len(tick_walls),
        "compiled_shapes": sched.stats.compiled_shapes,
        "pad_fraction": _pad_fraction(sched, variant, prompt_tokens),
        "packed_ticks": sched.stats.packed_ticks,
        "mean_ttft_ticks": round(float(np.mean(
            [sched.stats.ttft_ticks[r] for r in rids])), 2),
    }


def bench_packed_tick(smoke: bool = False, trace: str | None = None):
    import numpy as np

    from repro.serving.engine import Engine

    cfg, params, opts = _build()
    tracer = None
    if trace is not None:
        from repro.serving.telemetry import Tracer
        tracer = Tracer()
    mixes = SMOKE_MIXES if smoke else MIXES
    rng = np.random.default_rng(0)
    rows, rec = [], {"config": {"arch": cfg.name, "page_size": PAGE_SIZE,
                                "chunk": CHUNK, "max_slots": MAX_SLOTS,
                                "token_budget": CHUNK + MAX_SLOTS,
                                "smoke": smoke}}
    eng = Engine(cfg, params, opts, cache_len=64)
    for name, mix in mixes.items():
        jobs = mix["jobs"]
        prompts = [rng.integers(0, cfg.vocab_size, (n,)) for n, _ in jobs]
        want = [eng.generate(p[None], mn).tokens[0]
                for p, (_, mn) in zip(prompts, jobs)]
        entry = {"requests": len(jobs)}
        for variant in ("packed", "chunked", "wave"):
            # the tracer follows the packed variant only — one scheduler's
            # slot tracks per trace, not three runs interleaved
            results, rids, m = _serve(cfg, params, opts, jobs, prompts,
                                      variant, mix["pages"],
                                      telemetry=tracer
                                      if variant == "packed" else None)
            m["outputs_match_baseline"] = all(
                np.array_equal(results[r], w) for r, w in zip(rids, want))
            entry[variant] = m
            rows.append((f"packed_tick/{name}_{variant}",
                         m["wall_s"] * 1e6,
                         f"tok/s={m['tokens_per_s']} "
                         f"tail_tick={m['tail_tick_s']}s "
                         f"pad={m['pad_fraction']} "
                         f"shapes={m['compiled_shapes']}"))
        entry["tail_tick_reduction_vs_chunked"] = round(
            entry["chunked"]["tail_tick_s"]
            / max(entry["packed"]["tail_tick_s"], 1e-9), 2)
        entry["throughput_gain_vs_chunked"] = round(
            entry["packed"]["tokens_per_s"]
            / max(entry["chunked"]["tokens_per_s"], 1e-9), 2)
        entry["pad_fraction_reduction_vs_chunked"] = round(
            entry["chunked"]["pad_fraction"]
            - entry["packed"]["pad_fraction"], 3)
        rec[name] = entry
        rows.append((f"packed_tick/{name}_gain", 0.0,
                     f"tput_x{entry['throughput_gain_vs_chunked']} "
                     f"tail_x{entry['tail_tick_reduction_vs_chunked']}"))
    if tracer is not None:
        from benchmarks.common import telemetry_section
        rec.update(telemetry_section(tracer))
        os.makedirs(os.path.dirname(os.path.abspath(trace)), exist_ok=True)
        tracer.export_chrome_trace(trace)
        rows.append((f"packed_tick/trace", 0.0,
                     f"spans={len(tracer.spans)} ticks={len(tracer.ticks)} "
                     f"-> {trace}"))
    from benchmarks.common import env_section
    rec.update(env_section())
    os.makedirs(OUT_DIR, exist_ok=True)
    out = os.path.join(OUT_DIR, "packed_tick_smoke.json" if smoke
                       else "packed_tick.json")
    with open(out, "w") as f:
        json.dump(rec, f, indent=1)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="one shrunken mix (CI packed-tick smoke step)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="serve the packed variant with a telemetry.Tracer "
                         "and export a Chrome trace-event JSON here "
                         "(inspect with tools/trace_report.py or Perfetto)")
    args = ap.parse_args()
    for name, us, derived in bench_packed_tick(smoke=args.smoke,
                                               trace=args.trace):
        print(f"{name},{us:.1f},{derived}", flush=True)


if __name__ == "__main__":
    main()
