"""Benchmark harness — one function per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--only fig5,tab2] [--list]

Prints ``name,us_per_call,derived`` CSV (plus a roofline section aggregated
from experiments/dryrun). Vehicle models are trained once and checkpointed
under experiments/vehicles/.
"""

from __future__ import annotations

import argparse
import sys
import time


def _registry():
    from benchmarks import paper_benchmarks as pb
    from benchmarks.chunked_prefill import bench_chunked_prefill
    from benchmarks.decode_path import bench_decode_path
    from benchmarks.load_serving import bench_load_serving
    from benchmarks.packed_tick import bench_packed_tick
    from benchmarks.prefix_sharing import bench_prefix_sharing
    from benchmarks.ragged_batch import bench_ragged_batch
    from benchmarks.roofline_report import bench_roofline
    from benchmarks.sampling_api import bench_sampling_api
    from benchmarks.speculative_split import bench_speculative_split

    return {
        "chunked_prefill": bench_chunked_prefill,
        "decode_path": bench_decode_path,
        "load_serving": bench_load_serving,
        "packed_tick": bench_packed_tick,
        "prefix_sharing": bench_prefix_sharing,
        "ragged_batch": bench_ragged_batch,
        "sampling_api": bench_sampling_api,
        "speculative_split": bench_speculative_split,
        "fig5": pb.bench_fig5_server_scaling,
        "fig6": pb.bench_fig6_payload_size,
        "fig7": pb.bench_fig7_ts_ratio,
        "tab2": pb.bench_table2_split_accuracy,
        "tab3": pb.bench_table3_method_comparison,
        "tab4": pb.bench_table4_front_vs_back_ppl,
        "tab5": pb.bench_table5_ablation,
        "kernels": pb.bench_kernels,
        "roofline": bench_roofline,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated benchmark keys")
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args()
    registry = _registry()
    if args.list:
        print("\n".join(registry))
        return
    keys = args.only.split(",") if args.only else list(registry)
    print("name,us_per_call,derived")
    failures = []
    for key in keys:
        t0 = time.time()
        try:
            rows = registry[key]()
        except Exception as e:  # keep the harness running; report at the end
            failures.append((key, repr(e)))
            print(f"{key}/ERROR,0,{type(e).__name__}", flush=True)
            continue
        for name, us, derived in rows:
            print(f"{name},{us:.1f},{derived}", flush=True)
        print(f"# {key} done in {time.time() - t0:.1f}s", file=sys.stderr)
    if failures:
        for k, e in failures:
            print(f"# FAILED {k}: {e}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
