"""sharded_serving benchmark: decode/packed ticks over 1/2/4-device meshes
plus the disaggregated prefill→decode deployment's page-transport costs.

Forces ``--xla_force_host_platform_device_count=4`` BEFORE jax imports, so
one process hosts every topology: sub-meshes over ``jax.devices()[:n]``
give the 1-, 2- and 4-device columns. On CPU the mesh columns measure
DISPATCH overhead (shard_map partitioning, the page-axis all_gather, the
cross-device sampling hop) — wall-clock scaling is a TPU quantity; what IS
exact on any backend: bit-identical greedy outputs across every topology,
the compiled-shape count, and the per-request page-transfer bytes/latency
of the disaggregated column (read back from the PR 7 telemetry spans the
page-stream transport emits). JSON artifact under
experiments/sharded_serving/.

  PYTHONPATH=src python -m benchmarks.sharded_serving [--smoke]

``--smoke`` shrinks the workload — the CI sharded-smoke step.
"""

from __future__ import annotations

import argparse
import json
import os
import time

# must land in the environment before ANY jax import in this process
if "--xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=4")

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                       "sharded_serving")

JOBS = [(24, 8), (6, 12), (12, 10), (8, 12), (16, 8), (5, 12)]
SMOKE_JOBS = [(12, 4), (5, 6), (8, 4)]
PAGE_SIZE = 4
MAX_SLOTS = 3
DEVICE_COUNTS = (1, 2, 4)


def _build():
    import jax

    from repro.configs import get_config
    from repro.models.transformer import RuntimeOpts, init_params

    cfg = get_config("llama2-7b").tiny()
    params = init_params(cfg, jax.random.PRNGKey(0))
    opts = RuntimeOpts(q_chunk=16, kv_chunk=32, remat=False,
                       quantized_kv=True, moe_capacity_factor=0.0)
    return cfg, params, opts


def _drain(sched, jobs, prompts):
    rids = [sched.submit(p, mn) for p, (_, mn) in zip(prompts, jobs)]
    t0 = time.time()
    while sched.step():
        pass
    wall = time.time() - t0
    total = sum(mn for _, mn in jobs)
    return rids, wall, total


def _mesh_column(cfg, params, opts, jobs, prompts, pages, n_dev):
    import jax

    from repro.launch.mesh import make_serving_mesh
    from repro.serving.scheduler import Scheduler

    mesh = make_serving_mesh(cfg.pattern[0].mixer.num_kv_heads,
                             devices=jax.devices()[:n_dev])
    max_seq = max(n + mn for n, mn in jobs)
    sched = Scheduler(cfg, params, opts, num_pages=pages,
                      page_size=PAGE_SIZE, max_slots=MAX_SLOTS,
                      max_seq_len=max_seq, tick_mode="packed", mesh=mesh)
    rids, wall, total = _drain(sched, jobs, prompts)
    return sched, rids, mesh, {
        "devices": n_dev,
        "mesh": {a: int(mesh.shape[a]) for a in mesh.axis_names},
        "wall_s": round(wall, 3),
        "tokens_per_s": round(total / wall, 2),
        "compiled_shapes": sched.stats.compiled_shapes,
        "packed_ticks": sched.stats.packed_ticks,
    }


def _disaggregated_column(cfg, params, opts, jobs, prompts, pages):
    from repro.serving.page_transport import DisaggregatedScheduler
    from repro.serving.telemetry import Tracer

    tracer = Tracer()
    max_seq = max(n + mn for n, mn in jobs)
    ds = DisaggregatedScheduler(cfg, params, opts, telemetry=tracer,
                                num_pages=pages, page_size=PAGE_SIZE,
                                max_slots=MAX_SLOTS, max_seq_len=max_seq,
                                tick_mode="packed")
    rids, wall, total = _drain(ds, jobs, prompts)
    spans = [sp for sp in tracer.spans if sp.name == "page_stream"]
    by_rid: dict = {}
    for sp in spans:
        e = by_rid.setdefault(sp.rid, {"bytes": 0, "latency_s": 0.0,
                                       "layers": 0})
        e["bytes"] += sp.attrs["bytes"]
        e["latency_s"] += sp.duration
        e["layers"] += 1
    m = tracer.metrics_dict()
    return ds, rids, {
        "wall_s": round(wall, 3),
        "tokens_per_s": round(total / wall, 2),
        "transfers": ds.transport.transfers,
        "transferred_bytes": ds.transport.bytes_moved,
        "transfer_bytes_p50": m.get("transport.page_stream.bytes.p50"),
        "transfer_bytes_p99": m.get("transport.page_stream.bytes.p99"),
        "per_request": {
            int(r): {"bytes": e["bytes"],
                     "latency_us": round(e["latency_s"] * 1e6, 1),
                     "layers": e["layers"]}
            for r, e in sorted(by_rid.items())},
    }


def bench_sharded_serving(smoke: bool = False):
    import numpy as np

    from repro.serving.engine import Engine

    cfg, params, opts = _build()
    jobs = SMOKE_JOBS if smoke else JOBS
    pages = 24 if smoke else 48
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, (n,)).astype(np.int32)
               for n, _ in jobs]
    eng = Engine(cfg, params, opts, cache_len=64)
    want = [eng.generate(p[None], mn).tokens[0]
            for p, (_, mn) in zip(prompts, jobs)]

    rows, rec = [], {"config": {"arch": cfg.name, "page_size": PAGE_SIZE,
                                "max_slots": MAX_SLOTS,
                                "jobs": [list(j) for j in jobs],
                                "smoke": smoke}}
    last_mesh = None
    for n_dev in DEVICE_COUNTS:
        sched, rids, mesh, m = _mesh_column(cfg, params, opts, jobs, prompts,
                                            pages, n_dev)
        last_mesh = mesh
        m["outputs_match_engine"] = all(
            np.array_equal(sched.results[r], w) for r, w in zip(rids, want))
        assert m["outputs_match_engine"], \
            f"{n_dev}-device mesh diverged from the Engine oracle"
        rec[f"mesh_{n_dev}dev"] = m
        rows.append((f"sharded_serving/mesh_{n_dev}dev", m["wall_s"] * 1e6,
                     f"tok/s={m['tokens_per_s']} mesh={m['mesh']} "
                     f"shapes={m['compiled_shapes']}"))

    ds, rids, m = _disaggregated_column(cfg, params, opts, jobs, prompts,
                                        pages)
    m["outputs_match_engine"] = all(
        np.array_equal(ds.results[r], w) for r, w in zip(rids, want))
    assert m["outputs_match_engine"], \
        "disaggregated deployment diverged from the Engine oracle"
    rec["disaggregated"] = m
    rows.append(("sharded_serving/disaggregated", m["wall_s"] * 1e6,
                 f"tok/s={m['tokens_per_s']} transfers={m['transfers']} "
                 f"bytes={m['transferred_bytes']}"))

    from benchmarks.common import env_section
    rec.update(env_section(mesh=last_mesh, deployment="sharded+disaggregated"))
    os.makedirs(OUT_DIR, exist_ok=True)
    out = os.path.join(OUT_DIR, "sharded_serving_smoke.json" if smoke
                       else "sharded_serving.json")
    with open(out, "w") as f:
        json.dump(rec, f, indent=1)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="shrunken workload (CI sharded-smoke step)")
    args = ap.parse_args()
    for name, us, derived in bench_sharded_serving(smoke=args.smoke):
        print(f"{name},{us:.1f},{derived}", flush=True)


if __name__ == "__main__":
    main()
