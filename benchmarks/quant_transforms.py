"""Whole-model weight-quantization transforms for the Table 2/3 baselines
(SmoothQuant/OmniQuant/Atom lite re-implementations from repro.core.quant),
applied to stacked block parameters (fake-quant semantics)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.quant import (atom_lite, dequant_atom, omniquant_lite,
                              quantize_sym, smoothquant_lite)


def _map_matrices(blocks, fn):
    """Apply ``fn(w2d) -> w2d`` to every stacked weight matrix (nb, din, dout)."""

    def apply(x):
        if x.ndim < 3:
            return x
        flat = x.reshape(-1, x.shape[-2], x.shape[-1])
        out = jnp.stack([fn(flat[i]) for i in range(flat.shape[0])])
        return out.reshape(x.shape)

    return jax.tree_util.tree_map(apply, blocks)


def quantize_blocks(params: dict, method: str, bits: int = 4) -> dict:
    """Return params with ALL block weights fake-quantized by ``method``
    (uniform whole-model quantization — what the baselines do)."""

    def smooth(w):
        act_absmax = jnp.ones((w.shape[0],))  # calibration-free proxy
        qt, s = smoothquant_lite(w, act_absmax, bits)
        return qt.dequantize(w.dtype) / s[:, None]

    def omni(w):
        return omniquant_lite(w, bits).dequantize(w.dtype)

    def atom(w):
        q_low, q_out, mask = atom_lite(w, bits_low=bits)
        return dequant_atom(q_low, q_out, mask).astype(w.dtype)

    def plain(w):
        return quantize_sym(w, bits, axis=-1).dequantize(w.dtype)

    fn = {"smoothquant": smooth, "omniquant": omni, "atom": atom,
          "plain": plain}[method]
    out = dict(params)
    out["blocks"] = _map_matrices(params["blocks"], fn)
    return out
