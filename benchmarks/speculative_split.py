"""speculative_split benchmark: split-boundary speculative decoding — k
round trips folded into one.

The paper's split loop pays ONE edge→cloud uplink per generated token: the
edge runs its OPSC front segment, ships one TAB-Q activation payload, and
waits for the cloud's token. ``SplitEngine.generate(speculate_k=k)``
amortizes that: the edge drafts k tokens from its own front segment (the
early-exit head over the split-layer hidden state — zero extra weights),
ships ONE k-token TAB-Q payload, and the cloud verifies every position in
a single packed call; rejected positions roll back. Greedy output is
BIT-IDENTICAL to the per-token loop (asserted here) — speculation changes
only the round-trip count, never the tokens.

Measured on the trained induction vehicle (the copy task — a workload a
draft head can actually predict) per (cloud, k) variant: acceptance rate,
tokens/s, decode-phase uplink round trips, mean accepted tokens per round,
and uplink bits per generated token (measured TS+TAB-Q payload bits). The
same amortization is measured on the serving side: the continuous-batching
``Scheduler(speculate_k=)`` with model-free prompt-lookup drafting, where
the win is fewer decode ticks for the same bit-exact stream. CPU wall
numbers are call-path comparisons (kernels in interpret mode), not TPU
performance; the trips/acceptance/identity columns are exact on any
backend. JSON artifact under ``experiments/speculative_split/``.

  PYTHONPATH=src python -m benchmarks.speculative_split [--smoke]

``--smoke`` runs one shrunken variant per section — the CI guard that the
speculative path stays wired and bit-exact.
"""

from __future__ import annotations

import argparse
import json
import os
import time

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                       "speculative_split")

PAGE_SIZE = 4
MAX_SLOTS = 3


def _split_engine(cfg, params, paged: bool):
    from repro.core.opsc import OPSCConfig
    from repro.models.transformer import RuntimeOpts
    from repro.serving.split_engine import SplitEngine

    opsc = OPSCConfig(split_layer=2, qw_front=16, i_kv=1)
    opts = RuntimeOpts(q_chunk=32, kv_chunk=32, remat=False,
                       moe_capacity_factor=0.0)
    kw = dict(paged_cloud_kv=True, cloud_pool_pages=128,
              cloud_page_size=8) if paged else {}
    return SplitEngine(cfg, params, opsc, opts=opts, cache_len=128, **kw)


def _bench_split(cfg, params, prompts, max_new, ks, paged):
    import numpy as np

    name = "paged_cloud" if paged else "dense_cloud"
    eng = _split_engine(cfg, params, paged)
    ref, base = eng.generate(prompts, max_new, compress=True)
    rows, rec = [], {}
    for k in ks:
        t0 = time.time()
        out, st = eng.generate(prompts, max_new, compress=True,
                               speculate_k=k)
        wall = time.time() - t0
        identical = bool(np.array_equal(out, ref))
        assert identical, f"speculate_k={k} changed the greedy stream"
        assert st.uplink_round_trips < base.uplink_round_trips, \
            "speculation did not reduce decode round trips"
        gen = st.tokens_generated
        m = {
            "speculate_k": k,
            "identical_to_per_token": identical,
            "acceptance_rate": round(st.acceptance_rate, 4),
            "spec_rounds": st.spec_rounds,
            "uplink_round_trips": st.uplink_round_trips,
            "round_trips_per_token": round(
                st.uplink_round_trips / max(gen, 1), 3),
            "baseline_round_trips": base.uplink_round_trips,
            "tokens_generated": gen,
            "tokens_per_s": round(gen / wall, 2),
            "uplink_bits_per_token": round(
                st.uplink_bits_measured / max(gen, 1), 1),
        }
        rec[f"k{k}"] = m
        rows.append((
            f"speculative_split/{name}_k{k}", wall * 1e6,
            f"acc={m['acceptance_rate']} trips={st.uplink_round_trips} "
            f"vs {base.uplink_round_trips} bits/tok="
            f"{m['uplink_bits_per_token']} identical={identical}"))
    rec["baseline"] = {
        "uplink_round_trips": base.uplink_round_trips,
        "tokens_generated": base.tokens_generated,
        "uplink_bits_per_token": round(
            base.uplink_bits_measured / max(base.tokens_generated, 1), 1),
    }
    return rows, {name: rec}


def _bench_scheduler(cfg, params, prompts, max_new, k, tick_mode):
    import numpy as np

    from repro.models.transformer import RuntimeOpts
    from repro.serving.engine import Engine
    from repro.serving.scheduler import Scheduler

    opts = RuntimeOpts(q_chunk=16, kv_chunk=32, remat=False,
                       quantized_kv=True, moe_capacity_factor=0.0)
    eng = Engine(cfg, params, opts, cache_len=128)
    want = [eng.generate(p[None], max_new).tokens[0] for p in prompts]

    def serve(kk):
        sched = Scheduler(cfg, params, opts, num_pages=96,
                          page_size=PAGE_SIZE, max_slots=MAX_SLOTS,
                          tick_mode=tick_mode, speculate_k=kk)
        rids = [sched.submit(p, max_new) for p in prompts]
        t0 = time.time()
        res = sched.run()
        return [res[r] for r in rids], sched.stats, time.time() - t0

    _, st0, _ = serve(0)
    outs, st, wall = serve(k)
    identical = all(np.array_equal(o, w) for o, w in zip(outs, want))
    assert identical, "scheduler speculation diverged from Engine greedy"
    assert st.steps < st0.steps, "speculation did not reduce decode ticks"
    gen = len(prompts) * max_new
    m = {
        "tick_mode": tick_mode, "speculate_k": k,
        "identical_to_engine": identical,
        "acceptance_rate": round(st.acceptance_rate, 4),
        "spec_rounds": st.spec_rounds,
        "decode_steps": st.steps, "baseline_decode_steps": st0.steps,
        "tokens_per_s": round(gen / wall, 2),
    }
    row = (f"speculative_split/scheduler_{tick_mode}_k{k}", wall * 1e6,
           f"acc={m['acceptance_rate']} steps={st.steps} vs {st0.steps} "
           f"identical={identical}")
    return [row], {f"scheduler_{tick_mode}": m}


def bench_speculative_split(smoke: bool = False):
    from benchmarks.common import HALF, copy_prompts, induction_vehicle

    cfg, params = induction_vehicle()
    n = 2 if smoke else 8
    prompts = copy_prompts(n)[:, : HALF + 1]
    max_new = 6 if smoke else HALF
    ks = (2,) if smoke else (2, 4)

    rows, rec = [], {"config": {"arch": cfg.name, "prompts": n,
                                "max_new": max_new, "smoke": smoke}}
    for paged in ((True,) if smoke else (False, True)):
        r, m = _bench_split(cfg, params, prompts, max_new, ks, paged)
        rows += r
        rec.update(m)
    for mode in (("chunked",) if smoke else ("packed", "chunked")):
        r, m = _bench_scheduler(cfg, params, list(prompts), max_new,
                                ks[-1], mode)
        rows += r
        rec.update(m)

    from benchmarks.common import env_section
    rec.update(env_section())
    os.makedirs(OUT_DIR, exist_ok=True)
    out = os.path.join(OUT_DIR, "speculative_split_smoke.json" if smoke
                       else "speculative_split.json")
    with open(out, "w") as f:
        json.dump(rec, f, indent=1)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="one shrunken variant per section (CI guard for "
                         "the speculative split/scheduler paths)")
    args = ap.parse_args()
    for name, us, derived in bench_speculative_split(smoke=args.smoke):
        print(f"{name},{us:.1f},{derived}", flush=True)


if __name__ == "__main__":
    main()
