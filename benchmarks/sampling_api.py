"""sampling_api benchmark: per-request sampling on the paged backend —
greedy vs temperature vs top-p throughput through ONE compiled shape —
plus the three-backend smoke drive of the request-level API.

The point being measured: the scheduler's decode tick jits
``paged_decode_step`` + the shared ``core.sampling.sample_tokens`` as one
function with per-slot traced operands, so switching a request mix from
greedy to temperature to nucleus sampling changes ZERO compiled shapes —
the ``compiled_shapes`` column must be constant across variants (asserted
here), and the throughput delta is the sampler's arithmetic only.

Per variant: wall time, tokens/s, scheduler ticks, distinct jitted
shapes, and (greedy) parity vs per-request ``Engine.generate``. CPU wall
numbers are call-path comparisons, not TPU performance; the shape/parity
columns are exact on any backend. JSON under ``experiments/sampling_api/``.

  PYTHONPATH=src python -m benchmarks.sampling_api [--smoke]

``--smoke`` (the CI serving-api smoke step) also drives one request
through EACH backend — fused, paged, split — via ``LLMServer`` and
checks the greedy outputs agree bit-for-bit where the backends share a
numeric path.
"""

from __future__ import annotations

import argparse
import json
import os
import time

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                       "sampling_api")

JOBS = [(6, 12), (10, 8), (4, 14), (8, 10), (5, 12), (7, 8)]
SMOKE_JOBS = [(5, 6), (7, 4)]
PAGE_SIZE = 4
MAX_SLOTS = 3

VARIANTS = {
    "greedy": lambda mt, i: dict(max_tokens=mt),
    "temperature": lambda mt, i: dict(max_tokens=mt, temperature=0.8,
                                      seed=100 + i),
    "top_p": lambda mt, i: dict(max_tokens=mt, temperature=0.9, top_p=0.9,
                                seed=200 + i),
    "top_k": lambda mt, i: dict(max_tokens=mt, temperature=1.1, top_k=8,
                                seed=300 + i),
}


def _build():
    import jax

    from repro.configs import get_config
    from repro.models.transformer import RuntimeOpts, init_params

    cfg = get_config("llama2-7b").tiny()
    params = init_params(cfg, jax.random.PRNGKey(0))
    opts = RuntimeOpts(q_chunk=16, kv_chunk=32, remat=False,
                       quantized_kv=True, moe_capacity_factor=0.0)
    return cfg, params, opts


def _serve_paged(cfg, params, opts, jobs, prompts, variant):
    from repro.core.sampling import SamplingParams
    from repro.serving import LLMServer

    srv = LLMServer(cfg, params, opts, backend="paged",
                    num_pages=48, page_size=PAGE_SIZE, max_slots=MAX_SLOTS)
    sps = [SamplingParams(**VARIANTS[variant](mn, i))
           for i, (_, mn) in enumerate(jobs)]
    t0 = time.time()
    rids = [srv.submit(p, sp) for p, sp in zip(prompts, sps)]
    outs = srv.run()
    wall = time.time() - t0
    sched = srv.backend.scheduler
    total = sum(outs[r].tokens.shape[0] for r in rids)
    return outs, rids, {
        "wall_s": round(wall, 3),
        "tokens": total,
        "tokens_per_s": round(total / wall, 2),
        "ticks": sched.stats.steps,
        "compiled_shapes": sched.stats.compiled_shapes,
    }


def _smoke_three_backends(cfg, params, opts):
    """One greedy request through each backend via the SAME GenerationRequest
    surface — the CI drive for the API facade."""
    import dataclasses

    import numpy as np

    from repro.core.opsc import OPSCConfig
    from repro.core.sampling import SamplingParams
    from repro.serving import Engine, LLMServer

    rng = np.random.default_rng(0)
    p = rng.integers(0, cfg.vocab_size, (6,))
    sp = SamplingParams(max_tokens=5)
    want = Engine(cfg, params, opts, cache_len=32).generate(p[None],
                                                            5).tokens[0]
    rows = []
    for name, srv in (
            ("paged", LLMServer(cfg, params, opts, backend="paged",
                                num_pages=16, page_size=4, max_slots=2)),
            ("fused", LLMServer(cfg, params, opts, backend="fused",
                                cache_len=32)),
            ("split", LLMServer(
                cfg, params,
                dataclasses.replace(opts, quantized_kv=False),
                backend="split", compress=False, cache_len=32,
                opsc=OPSCConfig(split_layer=1, qw_front=16, i_kv=1)))):
        t0 = time.time()
        rid = srv.submit(p, sp)
        out = srv.run()[rid]
        ok = bool(np.array_equal(out.full_tokens, want)) \
            if name in ("paged", "fused") else out.finished
        assert out.finish_reason == "length", (name, out.finish_reason)
        if name in ("paged", "fused"):
            assert ok, f"{name} default params diverged from greedy Engine"
        rows.append((f"sampling_api/smoke_{name}",
                     (time.time() - t0) * 1e6,
                     f"tokens={out.tokens.shape[0]} "
                     f"reason={out.finish_reason} greedy_match={ok}"))
    return rows


def bench_sampling_api(smoke: bool = False):
    import numpy as np

    from repro.serving import Engine

    cfg, params, opts = _build()
    jobs = SMOKE_JOBS if smoke else JOBS
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, (n,)) for n, _ in jobs]
    rows, rec = [], {"config": {"arch": cfg.name, "jobs": jobs,
                                "page_size": PAGE_SIZE,
                                "max_slots": MAX_SLOTS, "smoke": smoke}}
    eng = Engine(cfg, params, opts, cache_len=64)
    want = [eng.generate(p[None], mn).tokens[0]
            for p, (_, mn) in zip(prompts, jobs)]
    shapes = {}
    for variant in VARIANTS:
        outs, rids, m = _serve_paged(cfg, params, opts, jobs, prompts,
                                     variant)
        if variant == "greedy":
            m["outputs_match_baseline"] = all(
                np.array_equal(outs[r].full_tokens, w)
                for r, w in zip(rids, want))
        shapes[variant] = m["compiled_shapes"]
        rec[variant] = m
        rows.append((f"sampling_api/{variant}", m["wall_s"] * 1e6,
                     f"tok/s={m['tokens_per_s']} "
                     f"shapes={m['compiled_shapes']}"))
    assert len(set(shapes.values())) == 1, \
        f"sampling params changed the compiled shapes: {shapes}"
    rec["one_compiled_shape_across_variants"] = True
    if smoke:
        rows += _smoke_three_backends(cfg, params, opts)
        rec["three_backend_smoke"] = "passed"
    from benchmarks.common import env_section
    rec.update(env_section())
    os.makedirs(OUT_DIR, exist_ok=True)
    out = os.path.join(OUT_DIR, "sampling_api_smoke.json" if smoke
                       else "sampling_api.json")
    with open(out, "w") as f:
        json.dump(rec, f, indent=1)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small mix + one request through each backend "
                         "(CI serving-api smoke step)")
    args = ap.parse_args()
    for name, us, derived in bench_sampling_api(smoke=args.smoke):
        print(f"{name},{us:.1f},{derived}", flush=True)


if __name__ == "__main__":
    main()
