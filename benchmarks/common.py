"""Shared benchmark infrastructure: the trained 'vehicle' models.

Two small models (checkpoint-cached under experiments/vehicles/):
  * induction vehicle — 4-layer llama-family tiny on the copy task
    (accuracy vehicle for Tables 2/3/5 analogs),
  * lm vehicle — same family on the Zipf–Markov corpus (perplexity vehicle
    for the Table 4 analog).
"""

from __future__ import annotations

import dataclasses
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data.pipeline import ZipfMarkov, induction_batch, induction_loader, lm_loader
from repro.models.transformer import RuntimeOpts, forward_train, init_params
from repro.serving.engine import Engine
from repro.serving.split_engine import SplitEngine
from repro.training.checkpoint import restore_checkpoint, save_checkpoint
from repro.training.optimizer import AdamWConfig
from repro.training.train_loop import TrainConfig, train

OPTS = RuntimeOpts(q_chunk=64, kv_chunk=64, remat=False, moe_capacity_factor=0.0)
VEHICLE_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments", "vehicles")
VOCAB = 64
SEQ = 33
HALF = 16
NUM_BLOCKS = 4


def vehicle_config():
    return dataclasses.replace(get_config("llama2-7b").tiny(), vocab_size=VOCAB,
                               num_blocks=NUM_BLOCKS)


def _get_vehicle(kind: str, steps: int = 250):
    cfg = vehicle_config()
    path = os.path.join(VEHICLE_DIR, kind)
    template = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
    template = jax.tree_util.tree_map(
        lambda s: jnp.zeros(s.shape, s.dtype), template)
    if os.path.exists(os.path.join(path, "meta.msgpack")):
        params, _ = restore_checkpoint(path, template)
        return cfg, params
    if kind == "induction":
        loader = induction_loader(VOCAB, batch=32, seq=SEQ, num_batches=steps)
    else:
        loader = lm_loader(ZipfMarkov(VOCAB, branching=4, seed=0), batch=32,
                           seq=SEQ, num_batches=steps)
    tc = TrainConfig(AdamWConfig(lr=3e-3, warmup_steps=20, total_steps=steps))
    params, _, _ = train(cfg, loader, tc, OPTS, log_every=10 ** 9)
    save_checkpoint(path, params)
    return cfg, params


def induction_vehicle():
    return _get_vehicle("induction")


def lm_vehicle():
    return _get_vehicle("lm")


def copy_prompts(n: int = 16, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    prompts, _ = induction_batch(rng, n, SEQ, VOCAB)
    return prompts.astype(np.int32)


def copy_accuracy_engine(engine: Engine, prompts: np.ndarray) -> float:
    out = engine.generate(prompts[:, : HALF + 1], HALF).tokens
    return float(np.mean(out[:, HALF + 1 :] == prompts[:, :HALF]))


def copy_accuracy_split(engine: SplitEngine, prompts: np.ndarray) -> float:
    out, _ = engine.generate(prompts[:, : HALF + 1], HALF)
    return float(np.mean(out[:, HALF + 1 : 2 * HALF + 1] == prompts[:, :HALF]))


def perplexity(cfg, params, opts: RuntimeOpts, n_batches: int = 4,
               seed: int = 123) -> float:
    corpus = ZipfMarkov(VOCAB, branching=4, seed=0)
    rng = np.random.default_rng(seed)
    nll, count = 0.0, 0
    fwd = jax.jit(lambda p, t: forward_train(p, cfg, t, None, opts)[0])
    for _ in range(n_batches):
        tokens = jnp.asarray(corpus.sample(rng, 16, SEQ), jnp.int32)
        logits = fwd(params, tokens)
        lp = jax.nn.log_softmax(logits[:, :-1].astype(jnp.float32))
        tgt = tokens[:, 1:]
        nll += float(-jnp.sum(jnp.take_along_axis(lp, tgt[..., None], -1)))
        count += tgt.size
    return float(np.exp(nll / count))


def timeit_us(fn, n: int = 5) -> float:
    fn()  # warm
    t0 = time.time()
    for _ in range(n):
        fn()
    return (time.time() - t0) / n * 1e6


def env_section(mesh=None, deployment: str | None = None) -> dict:
    """The benchmark-artifact environment block: device topology plus the
    serving deployment the numbers were measured under — without it a
    JSON artifact from a forced-4-device run is indistinguishable from a
    single-device one. Spliced into every benchmark's JSON."""
    env = {
        "device_count": jax.device_count(),
        "backend": jax.default_backend(),
        "xla_flags": os.environ.get("XLA_FLAGS", ""),
    }
    if mesh is not None:
        env["mesh"] = {a: int(mesh.shape[a]) for a in mesh.axis_names}
    if deployment is not None:
        env["deployment"] = deployment
    return {"env": env}


def telemetry_section(tracer) -> dict:
    """The benchmark-artifact telemetry block: the tracer's flat metrics
    plus the SLO percentiles benchmarks quote (TTFT/TPOT/tick latency).
    Returns {} for ``tracer=None`` so callers can splice it in
    unconditionally."""
    if tracer is None:
        return {}
    m = tracer.metrics_dict()
    slo = {}
    for row in ("ttft_s", "tpot_s", "e2e_s", "tick.wall_s"):
        if f"{row}.count" in m:
            slo[row] = {q: m[f"{row}.{q}"] for q in ("p50", "p95", "p99")}
    return {"telemetry": {
        "spans": len(tracer.spans),
        "ticks": len(tracer.ticks),
        "slo": slo,
        "metrics": m,
    }}
