"""load_serving benchmark: Poisson-arrival HTTP load against the async
service layer (``AsyncLLMServer`` + ``serving/http.py``).

Boots the real HTTP/SSE server in-process on an ephemeral port (tiny
randomly initialized model — wall-clock concurrency, not model quality,
is the thing under test), then drives it OPEN-LOOP: request arrival
times are drawn from a seeded exponential distribution (a Poisson
process at ``--rate`` req/s), so a slow server makes arrivals pile up
instead of politely waiting — the regime the paper's SLO machinery is
for. Each client is a raw asyncio socket speaking
``POST /v1/completions`` with ``stream=true`` and decoding SSE frames; a
configurable fraction disconnects mid-stream (socket close, no abort
RPC), exercising the disconnect→abort→pages-freed path under load.

Reported per run: client-side achieved tokens/s and TTFT/e2e
p50/p95/p99, the server-side ``/v1/metrics`` SLO dict (TTFT/TPOT/e2e
percentiles stamped on the tick thread), and the post-drain KV-pool
gauges — ``pages_in_use`` must return to 0, the no-leak gate
``tools/load_report.py`` enforces. JSON artifact under
experiments/load_serving/.

  PYTHONPATH=src python -m benchmarks.load_serving [--smoke] [--url URL]

``--smoke`` shrinks the burst (CI load-smoke step); ``--url`` targets an
already-running server instead of booting one (skips the in-process
pool-gauge section).
"""

from __future__ import annotations

import argparse
import asyncio
import json
import os
import time

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                       "load_serving")

# (num_requests, rate_req_per_s, abort_fraction)
FULL = (48, 24.0, 0.2)
SMOKE = (12, 16.0, 0.25)
PAGE_SIZE = 4
MAX_SLOTS = 3
NUM_PAGES = 48
PROMPT_LENS = (4, 6, 8, 12)
MAX_TOKENS = (4, 6, 8)
SHARED_PREFIX_LEN = 8          # half the prompts share this prefix head
SHARED_FRACTION = 0.5          # ... so auto_prefix has something to find


def _percentiles(xs) -> dict:
    import numpy as np

    if not xs:
        return {}
    return {q: round(float(np.percentile(xs, int(q[1:]))), 6)
            for q in ("p50", "p95", "p99")}


def _make_workload(vocab: int, n: int, rate: float, abort_frac: float,
                   seed: int):
    """Seeded Poisson arrivals + prompt mix. Returns a list of dicts:
    arrival_s (cumulative), prompt, max_tokens, abort_after (token count
    at which the client hangs up, or None)."""
    import numpy as np

    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / rate, size=n))
    shared = rng.integers(0, vocab, (SHARED_PREFIX_LEN,)).tolist()
    jobs = []
    for i in range(n):
        plen = int(rng.choice(PROMPT_LENS))
        prompt = rng.integers(0, vocab, (plen,)).tolist()
        if rng.random() < SHARED_FRACTION:
            prompt = shared + prompt[: max(1, plen - SHARED_PREFIX_LEN)]
        mt = int(rng.choice(MAX_TOKENS))
        abort_after = None
        if rng.random() < abort_frac and mt >= 3:
            abort_after = int(rng.integers(1, mt - 1))
        jobs.append({"arrival_s": float(arrivals[i]), "prompt": prompt,
                     "max_tokens": mt, "abort_after": abort_after})
    return jobs


async def _read_headers(reader):
    status = await reader.readline()
    code = int(status.split()[1])
    while await reader.readline() not in (b"\r\n", b"\n", b""):
        pass
    return code


async def _http_get_json(host: str, port: int, path: str) -> dict:
    reader, writer = await asyncio.open_connection(host, port)
    writer.write(f"GET {path} HTTP/1.1\r\nHost: {host}\r\n\r\n".encode())
    await writer.drain()
    await _read_headers(reader)
    body = await reader.read()  # Connection: close — EOF-terminated
    writer.close()
    return json.loads(body)


async def _client(host: str, port: int, job: dict, t0: float, res: dict):
    """One open-loop client: waits for its Poisson arrival slot, streams
    its completion over SSE, optionally hangs up mid-stream."""
    from repro.serving.http import SSEParser

    loop = asyncio.get_running_loop()
    await asyncio.sleep(max(0.0, t0 + job["arrival_s"] - loop.time()))
    t_submit = time.perf_counter()
    reader, writer = await asyncio.open_connection(host, port)
    body = json.dumps({"prompt": job["prompt"],
                       "max_tokens": job["max_tokens"],
                       "stream": True}).encode()
    writer.write((f"POST /v1/completions HTTP/1.1\r\nHost: {host}\r\n"
                  f"Content-Type: application/json\r\n"
                  f"Content-Length: {len(body)}\r\n\r\n").encode() + body)
    await writer.drain()
    try:
        code = await _read_headers(reader)
        if code != 200:
            res["rejected"].append(code)
            return
        parser, tokens, ttft = SSEParser(), [], None
        while True:
            chunk = await reader.read(4096)
            if not chunk:
                return  # server closed without [DONE]: counted nowhere
            for msg in parser.feed(chunk):
                if msg == "[DONE]":
                    res["e2e_s"].append(time.perf_counter() - t_submit)
                    res["ttft_s"].append(ttft)
                    res["tokens"] += len(tokens)
                    res["completed"] += 1
                    return
                if msg.get("finished"):
                    continue  # finish marker precedes [DONE]
                if ttft is None:
                    ttft = time.perf_counter() - t_submit
                tokens.append(msg["token"])
                if job["abort_after"] and len(tokens) >= job["abort_after"]:
                    res["tokens"] += len(tokens)
                    res["aborted"] += 1
                    return  # finally-close = mid-stream disconnect
    finally:
        writer.close()


async def _drive(host: str, port: int, jobs: list) -> tuple:
    res = {"completed": 0, "aborted": 0, "tokens": 0, "rejected": [],
           "ttft_s": [], "e2e_s": []}
    loop = asyncio.get_running_loop()
    t0 = loop.time() + 0.05
    t_wall = time.perf_counter()
    await asyncio.gather(*[_client(host, port, j, t0, res) for j in jobs])
    wall = time.perf_counter() - t_wall
    return res, wall


async def _run(jobs: list, url: str | None, smoke: bool) -> dict:
    rec: dict = {"config": {
        "requests": len(jobs), "smoke": smoke,
        "page_size": PAGE_SIZE, "max_slots": MAX_SLOTS,
        "num_pages": NUM_PAGES, "auto_prefix": True,
        "prompt_lens": list(PROMPT_LENS), "max_tokens": list(MAX_TOKENS),
    }}
    http = llm = None
    if url is None:
        import jax

        from repro.configs import get_config
        from repro.models.transformer import RuntimeOpts, init_params
        from repro.serving.api import LLMServer
        from repro.serving.async_engine import AsyncLLMServer
        from repro.serving.http import ServingHTTPServer

        cfg = get_config("llama2-7b").tiny()
        params = init_params(cfg, jax.random.PRNGKey(0))
        opts = RuntimeOpts(q_chunk=16, kv_chunk=16, remat=False,
                           quantized_kv=True, moe_capacity_factor=0.0)
        llm = LLMServer(cfg, params, opts, backend="paged",
                        num_pages=NUM_PAGES, page_size=PAGE_SIZE,
                        max_slots=MAX_SLOTS, auto_prefix=True)
        http = ServingHTTPServer(AsyncLLMServer(llm))
        await http.start()
        host, port = http.host, http.port
    else:
        hostport = url.split("//")[-1].rstrip("/")
        host, port = hostport.split(":")[0], int(hostport.split(":")[1])

    try:
        res, wall = await _drive(host, port, jobs)
        if llm is not None:  # let disconnect-aborts flush before scraping
            deadline = time.perf_counter() + 30.0
            while llm.pending and time.perf_counter() < deadline:
                await asyncio.sleep(0.02)
        metrics = await _http_get_json(host, port, "/v1/metrics")
        health = await _http_get_json(host, port, "/healthz")
    finally:
        if http is not None:
            await http.stop()

    rec["client"] = {
        "completed": res["completed"], "client_aborts": res["aborted"],
        "rejected": len(res["rejected"]), "tokens_streamed": res["tokens"],
        "wall_s": round(wall, 3),
        "tokens_per_s": round(res["tokens"] / wall, 2),
        "ttft_s": _percentiles(res["ttft_s"]),
        "e2e_s": _percentiles(res["e2e_s"]),
    }
    rec["server_metrics"] = {k: v for k, v in sorted(metrics.items())
                             if k.startswith("requests.")}
    rec["health"] = health
    if llm is not None:
        rec["pool"] = llm.backend.scheduler.pool.gauges()
        rec["scheduler"] = {
            "auto_prefix_hits": llm.backend.scheduler.stats.auto_prefix_hits,
            "prefix_forks": llm.backend.scheduler.stats.prefix_forks,
        }
    return rec


def bench_load_serving(smoke: bool = False, url: str | None = None,
                       seed: int = 0):
    n, rate, abort_frac = SMOKE if smoke else FULL
    # vocab matches the in-process tiny config; a --url server must accept
    # the same token-id range (serving/http.py's demo CLI defaults do)
    from repro.configs import get_config

    vocab = get_config("llama2-7b").tiny().vocab_size
    jobs = _make_workload(vocab, n, rate, abort_frac, seed)
    rec = asyncio.run(_run(jobs, url, smoke))
    rec["config"]["rate_req_per_s"] = rate
    rec["config"]["abort_fraction"] = abort_frac
    rec["config"]["seed"] = seed

    from benchmarks.common import env_section
    rec.update(env_section(deployment="async-http"))
    os.makedirs(OUT_DIR, exist_ok=True)
    out = os.path.join(OUT_DIR, "load_serving_smoke.json" if smoke
                       else "load_serving.json")
    with open(out, "w") as f:
        json.dump(rec, f, indent=1)

    c, sm = rec["client"], rec["server_metrics"]
    derived = (f"tok/s={c['tokens_per_s']} done={c['completed']} "
               f"aborts={c['client_aborts']} "
               f"ttft_p99={c['ttft_s'].get('p99')} "
               f"srv_tpot_p50={sm.get('requests.tpot_s.p50')}")
    return [("load_serving/poisson", c["wall_s"] * 1e6, derived)]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="small Poisson burst (CI load-smoke step)")
    ap.add_argument("--url", default=None,
                    help="target an already-running server "
                         "(http://host:port) instead of booting one")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    for name, us, derived in bench_load_serving(smoke=args.smoke,
                                                url=args.url,
                                                seed=args.seed):
        print(f"{name},{us:.1f},{derived}", flush=True)


if __name__ == "__main__":
    main()
