"""Roofline report: aggregate the dry-run JSONs into benchmark rows and the
EXPERIMENTS.md §Roofline table. ``us_per_call`` = modeled step time (the max
of the three roofline terms, in µs); ``derived`` = dominant term + terms."""

from __future__ import annotations

import glob
import json
import os

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")


def load_records(mesh: str | None = None) -> list:
    recs = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        if mesh and r.get("mesh") != mesh:
            continue
        recs.append(r)
    return recs


def bench_roofline():
    rows = []
    for r in load_records():
        if r["status"] == "skipped":
            rows.append((f"roofline/{r['tag']}", 0.0, "skipped"))
            continue
        if r["status"] != "ok":
            rows.append((f"roofline/{r['tag']}", 0.0, f"ERROR"))
            continue
        rl = r["roofline"]
        step_s = max(rl["compute_s"], rl["memory_s"], rl["collective_s"])
        rows.append((
            f"roofline/{r['tag']}",
            step_s * 1e6,
            f"dom={rl['dominant']}_c={rl['compute_s']:.2e}_m={rl['memory_s']:.2e}"
            f"_x={rl['collective_s']:.2e}_useful={rl['useful_flops_ratio']:.2f}",
        ))
    if not rows:
        rows.append(("roofline/none", 0.0,
                     "run repro.launch.dryrun first"))
    return rows


def markdown_table(mesh: str = "pod256") -> str:
    """EXPERIMENTS.md §Roofline source table."""
    lines = [
        "| arch | shape | compute s | memory s | collective s | dominant | "
        "MODEL/HLO flops | HBM GiB/dev (args+tmp) |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in load_records(mesh):
        if r["status"] == "skipped":
            lines.append(f"| {r['tag'].split('__')[0]} | {r['tag'].split('__')[1]}"
                         f" | — | — | — | skipped (full attention @500k) | — | — |")
            continue
        if r["status"] != "ok":
            continue
        rl = r["roofline"]
        mem = r.get("memory_analysis", {})
        gib = (mem.get("argument_size_in_bytes", 0)
               + mem.get("temp_size_in_bytes", 0)) / 2 ** 30
        lines.append(
            f"| {r['arch']} | {r['shape']} | {rl['compute_s']:.3e} | "
            f"{rl['memory_s']:.3e} | {rl['collective_s']:.3e} | "
            f"**{rl['dominant']}** | {rl['useful_flops_ratio']:.2f} | {gib:.1f} |")
    return "\n".join(lines)


if __name__ == "__main__":
    print(markdown_table())
