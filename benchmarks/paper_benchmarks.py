"""One benchmark per paper table/figure. Each function returns a list of
(name, us_per_call, derived) rows; run.py prints them as CSV.

Paper mapping:
  fig5  — server inference time vs #edge devices (SC vs cloud-only)
  fig6  — intermediate-output size vs token length W across (τ, Q̄a)
  fig7  — T_above/T_below byte split vs τ
  tab2  — accuracy vs split layer: whole-model Atom vs split-aware ours
  tab3  — accuracy vs activation bits: SmoothQuant/OmniQuant/Atom vs ours
  tab4  — perplexity: front-end vs back-end OPSC quantization vs ℓ_w
  tab5  — ablation: baseline / +TAB-Q / +TS+TAB-Q
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common as C
from benchmarks.quant_transforms import quantize_blocks
from repro.configs import get_config
from repro.core.opsc import OPSCConfig
from repro.core.payload import encode
from repro.core.tabq import tabq
from repro.core.ts import ts_encode
from repro.models.transformer import RuntimeOpts
from repro.serving.engine import Engine
from repro.serving.split_engine import SplitEngine


# ---------------------------------------------------------------- helpers


def _split_hidden(cfg, params, tokens, split_block):
    """Real split-layer activations of the vehicle (for Fig. 6/7)."""
    size = max(64, tokens.shape[1])
    eng = SplitEngine(cfg, params, OPSC_ID, opts=C.OPTS, cache_len=size)
    nfront = split_block
    from repro.models.transformer import init_caches

    caches = jax.tree_util.tree_map(
        lambda a: a[:nfront], init_caches(cfg, tokens.shape[0], size, C.OPTS))
    h, _ = eng._edge_front(eng.edge_params["blocks"], eng.edge_params,
                           jnp.asarray(tokens), caches, jnp.int32(0),
                           decode=False)
    return np.asarray(h, np.float32)


OPSC_ID = OPSCConfig(split_layer=2, qw_front=16)


# ------------------------------------------------------------------- fig5


def bench_fig5_server_scaling():
    """Grounded simulation: measured per-layer decode cost of the vehicle ×
    the paper's Fig. 5 scenario (requests of 400 tokens; edge covers W̄)."""
    cfg, params = C.induction_vehicle()
    eng = Engine(cfg, params, C.OPTS, cache_len=64)
    prompts = C.copy_prompts(4)[:, :8]
    us = C.timeit_us(lambda: eng.generate(prompts, 2), n=3)
    per_layer_us = us / 2 / cfg.num_layers  # one decode step, per layer

    l_total, ell = cfg.num_layers, 2
    req_tokens, results = 400, []
    for wbar in (0, 250, 350):  # 0 = cloud-only
        for n_dev in (1, 4, 8, 16):
            edge_tok = min(req_tokens, wbar)
            srv = (edge_tok * (l_total - ell) + (req_tokens - edge_tok) * l_total)
            srv_us = srv * per_layer_us * n_dev
            srv_us *= 1.0 + 0.04 * n_dev  # queueing/batching nonlinearity (§3.2)
            name = f"fig5/server_time/wbar={wbar or 'cloud-only'}/devices={n_dev}"
            results.append((name, srv_us, f"server_tokens={req_tokens - edge_tok}"))
    cloud = next(r[1] for r in results if "cloud-only/devices=8" in r[0])
    sc350 = next(r[1] for r in results if "wbar=350/devices=8" in r[0])
    results.append(("fig5/speedup@8dev", sc350, f"{cloud / sc350:.2f}x_vs_cloud_only"))
    return results


# ------------------------------------------------------------------- fig6


def bench_fig6_payload_size():
    cfg, params = C.induction_vehicle()
    rows = []
    from repro.data.pipeline import ZipfMarkov

    corpus = ZipfMarkov(C.VOCAB, branching=4, seed=0)
    rng = np.random.default_rng(0)
    for w in (64, 128, 256):
        tokens = corpus.sample(rng, 1, w).astype(np.int32)
        h = _split_hidden(cfg, params, tokens, OPSC_ID.split_layer)[0]  # (w, D)
        base_bits = h.size * 16
        rows.append((f"fig6/W={w}/baseline", 0.0, f"{base_bits}bits"))
        for tau in (1.0, 5.0, 10.0):
            for qa in (2, 4, 8):
                p = encode(jnp.asarray(h), tau=tau, max_bits=qa, delta=0.2)
                bits = int(p.payload_bits())
                us = C.timeit_us(
                    lambda: jax.block_until_ready(
                        encode(jnp.asarray(h), tau=tau, max_bits=qa, delta=0.2)),
                    n=3)
                rows.append((f"fig6/W={w}/tau={tau}/Qa={qa}", us,
                             f"{bits}bits_ratio={base_bits / max(bits, 1):.1f}x"))
    return rows


# ------------------------------------------------------------------- fig7


def bench_fig7_ts_ratio():
    cfg, params = C.induction_vehicle()
    from repro.data.pipeline import ZipfMarkov

    corpus = ZipfMarkov(C.VOCAB, branching=4, seed=0)
    tokens = corpus.sample(np.random.default_rng(1), 1, 128).astype(np.int32)
    h = jnp.asarray(_split_hidden(cfg, params, tokens, OPSC_ID.split_layer)[0])
    rows = []
    for tau_pct in (50.0, 90.0, 99.0, 99.9):
        tau = float(np.percentile(np.abs(np.asarray(h)), tau_pct))
        below, above = ts_encode(h, tau, capacity=h.size)
        above_bytes = int(above.csr_bytes())
        q = tabq(below, max_bits=8, delta=0.2)
        below_bytes = int(q.payload_bits()) // 8
        rows.append((f"fig7/tau_pct={tau_pct}", 0.0,
                     f"above={above_bytes}B_below={below_bytes}B_"
                     f"frac_above={above_bytes / (above_bytes + below_bytes):.3f}"))
    return rows


# ------------------------------------------------------------------- tab2


def _front_quant_params(cfg, params, ell: int, bits: int):
    from repro.serving.split_engine import _fake_quant_blocks, slice_blocks

    q = _fake_quant_blocks(slice_blocks(params["blocks"], 0, ell), bits)
    full = dict(params)
    full["blocks"] = jax.tree_util.tree_map(
        lambda orig, qq: jnp.concatenate([qq, orig[ell:]], axis=0),
        params["blocks"], q)
    return full


def bench_table2_split_accuracy():
    """Split-aware (front Qw=4 + boundary codec) vs whole-model Atom at the
    same weight budget, across split layers — on LM perplexity (the copy
    task saturates at these bit-widths; accuracy view lives in tab5)."""
    cfg, params = C.lm_vehicle()
    base = C.perplexity(cfg, params, C.OPTS)
    rows = [("tab2/baseline", 0.0, f"ppl={base:.4f}")]
    # whole-model Atom-lite (uniform Qw=4 + Qa=4 at every layer)
    atom_params = quantize_blocks(params, "atom", bits=4)
    opts_a = dataclasses.replace(C.OPTS, act_bits=4)
    ppl_atom = C.perplexity(cfg, atom_params, opts_a)
    tokens = np.asarray(C.copy_prompts(2))[:, :32]
    for ell in (1, 2, 3):
        qp = _front_quant_params(cfg, params, ell, 4)
        ppl_ours = _ppl_with_boundary_codec(cfg, qp, ell, tau=2.0, fixed_bits=4)
        rows.append((f"tab2/l={ell}/ours", 0.0, f"ppl={ppl_ours:.4f}"))
        rows.append((f"tab2/l={ell}/atom_whole", 0.0, f"ppl={ppl_atom:.4f}"))
    return rows


# ------------------------------------------------------------------- tab3


def bench_table3_method_comparison():
    """SmoothQuant/OmniQuant/Atom (uniform Qw=4 + Qa at EVERY layer) vs ours
    (front-only Qw=4, Qa only at the split boundary) — LM perplexity."""
    cfg, params = C.lm_vehicle()
    base = C.perplexity(cfg, params, C.OPTS)
    rows = [("tab3/baseline", 0.0, f"ppl={base:.4f}")]
    for qa in (3, 4):
        for method in ("smoothquant", "omniquant", "atom"):
            qp = quantize_blocks(params, method, bits=4)
            opts = dataclasses.replace(C.OPTS, act_bits=qa)
            ppl = C.perplexity(cfg, qp, opts)
            rows.append((f"tab3/Qa={qa}/{method}", 0.0, f"ppl={ppl:.4f}"))
        qp = _front_quant_params(cfg, params, 2, 4)
        ppl = _ppl_with_boundary_codec(cfg, qp, 2, tau=2.0, fixed_bits=qa)
        rows.append((f"tab3/Qa={qa}/ours", 0.0, f"ppl={ppl:.4f}"))
    return rows


# ------------------------------------------------------------------- tab4


def bench_table4_front_vs_back_ppl():
    """Front- vs back-segment OPSC quantization perplexity ladder. The bit
    ladder {4, 3, 2} exposes graded degradation on the small vehicle (int4
    alone is invisible on a saturated 4-layer model — see EXPERIMENTS.md)."""
    cfg, params = C.lm_vehicle()
    base_ppl = C.perplexity(cfg, params, C.OPTS)
    rows = [("tab4/baseline", 0.0, f"ppl={base_ppl:.4f}")]
    nb = cfg.num_blocks
    from repro.serving.split_engine import _fake_quant_blocks, slice_blocks

    def quant_range(lo, hi, bits):
        q = _fake_quant_blocks(slice_blocks(params["blocks"], lo, hi), bits)
        full = dict(params)
        full["blocks"] = jax.tree_util.tree_map(
            lambda orig, qq: jnp.concatenate([orig[:lo], qq, orig[hi:]], axis=0),
            params["blocks"], q)
        return full

    for bits in (4, 3, 2):
        for ell in (1, 2, 3, 4):
            ppl_f = C.perplexity(cfg, quant_range(0, ell, bits), C.OPTS)
            ppl_b = C.perplexity(cfg, quant_range(nb - ell, nb, bits), C.OPTS)
            rows.append((f"tab4/Qw={bits}/l={ell}/front", 0.0, f"ppl={ppl_f:.4f}"))
            rows.append((f"tab4/Qw={bits}/l={ell}/back", 0.0, f"ppl={ppl_b:.4f}"))
    return rows


# ------------------------------------------------------------------- tab5


def _ppl_with_boundary_codec(cfg, params, split_block, tau, fixed_bits,
                             n_batches: int = 4, outlier_scale: float = 0.0,
                             codec: bool = True):
    """LM perplexity with the split-layer hidden state passed through the
    TS+TAB-Q codec at a FIXED bit-width (τ=∞ → TS disabled = TAB-Q alone).

    ``outlier_scale`` > 0 plants sparse large-magnitude activations at the
    boundary (≈0.1 % of entries at ±scale·std) — a synthetic stressor
    mimicking the massive-activation phenomenon of large LLMs (paper Fig. 4),
    which the 4-layer vehicle does not develop on its own. All ablation arms
    share the same injection, so the comparison isolates the codec."""
    from repro.core.payload import decode as pdecode
    from repro.core.payload import encode as pencode
    from repro.data.pipeline import ZipfMarkov
    from repro.models.transformer import (_apply_blocks_train, apply_head,
                                          embed_inputs, make_positions,
                                          rope_tables)
    from repro.serving.split_engine import slice_blocks

    corpus = ZipfMarkov(C.VOCAB, branching=4, seed=0)
    rng = np.random.default_rng(77)

    @jax.jit
    def fwd(p, tokens):
        b, s = tokens.shape
        positions = make_positions(cfg, b, s)
        x = embed_inputs(cfg, p, tokens, None, positions)
        rope_cs = rope_tables(cfg, positions)
        front = slice_blocks(p["blocks"], 0, split_block)
        back = slice_blocks(p["blocks"], split_block, cfg.num_blocks)
        x, _ = _apply_blocks_train(cfg, front, x, rope_cs=rope_cs,
                                   q_positions=positions, opts=C.OPTS)
        d = x.shape[-1]
        flat = x.reshape(b * s, d).astype(jnp.float32)
        if outlier_scale > 0:
            key = jax.random.PRNGKey(99)
            mask = jax.random.bernoulli(key, 1e-3, flat.shape)
            signs = jnp.sign(jax.random.normal(key, flat.shape)) + 0.5
            flat = flat + mask * jnp.sign(signs) * outlier_scale * jnp.std(flat)
        if codec:
            pl = pencode(flat, tau=tau, fixed_bits=fixed_bits,
                         capacity=max(64, flat.size // 256))  # ample for 99.9pct τ
            flat = pdecode(pl)
        x = flat.reshape(b, s, d).astype(x.dtype)
        x, _ = _apply_blocks_train(cfg, back, x, rope_cs=rope_cs,
                                   q_positions=positions, opts=C.OPTS)
        return apply_head(cfg, p, x)

    nll, count = 0.0, 0
    for _ in range(n_batches):
        tokens = jnp.asarray(corpus.sample(rng, 16, C.SEQ), jnp.int32)
        logits = fwd(params, tokens)
        lp = jax.nn.log_softmax(logits[:, :-1].astype(jnp.float32))
        tgt = tokens[:, 1:]
        nll += float(-jnp.sum(jnp.take_along_axis(lp, tgt[..., None], -1)))
        count += tgt.size
    return float(np.exp(nll / count))


def bench_table5_ablation():
    """Baseline / +TAB-Q alone (τ=∞, 3-bit) / +TS+TAB-Q (same bits, outliers
    preserved) — both on LM perplexity (graded) and copy accuracy."""
    cfg_lm, params_lm = C.lm_vehicle()
    base = C.perplexity(cfg_lm, params_lm, C.OPTS)
    # calibrate τ at the 99.5th percentile of |h| (the paper's tiny-above-set)
    from repro.data.pipeline import ZipfMarkov

    tokens = ZipfMarkov(C.VOCAB, branching=4, seed=0).sample(
        np.random.default_rng(5), 2, 64).astype(np.int32)
    h = _split_hidden(cfg_lm, params_lm, tokens, 2)
    tau = float(np.percentile(np.abs(h), 99.9))
    rows = [("tab5/ppl/baseline", 0.0, f"ppl={base:.4f}")]
    for bits in (6, 4, 3):
        p_tabq = _ppl_with_boundary_codec(cfg_lm, params_lm, 2, 1e9, bits)
        p_full = _ppl_with_boundary_codec(cfg_lm, params_lm, 2, tau, bits)
        rows.append((f"tab5/ppl/Qa={bits}/tabq_only", 0.0, f"ppl={p_tabq:.4f}"))
        rows.append((f"tab5/ppl/Qa={bits}/ts_tabq", 0.0, f"ppl={p_full:.4f}"))

    # synthetic outlier stress (paper Fig. 4 regime — see docstring): the
    # same planted outliers flow through all three arms
    scale = 30.0
    p_none = _ppl_with_boundary_codec(cfg_lm, params_lm, 2, 1e9, 6,
                                      outlier_scale=scale, codec=False)
    rows.append(("tab5/stress/baseline", 0.0, f"ppl={p_none:.4f}"))
    for bits in (6, 4):
        p_tq = _ppl_with_boundary_codec(cfg_lm, params_lm, 2, 1e9, bits,
                                        outlier_scale=scale)
        stress_tau = tau * 3.0  # above normal activations, below the plants
        p_ts = _ppl_with_boundary_codec(cfg_lm, params_lm, 2, stress_tau, bits,
                                        outlier_scale=scale)
        rows.append((f"tab5/stress/Qa={bits}/tabq_only", 0.0, f"ppl={p_tq:.4f}"))
        rows.append((f"tab5/stress/Qa={bits}/ts_tabq", 0.0, f"ppl={p_ts:.4f}"))

    # accuracy view on the induction vehicle
    cfg, params = C.induction_vehicle()
    prompts = C.copy_prompts(16)
    mono = Engine(cfg, params, C.OPTS, cache_len=64)
    rows.append(("tab5/acc/baseline", 0.0,
                 f"acc={C.copy_accuracy_engine(mono, prompts):.3f}"))
    for name, t in (("tabq_only", 1e9), ("ts_tabq", 2.0)):
        o = OPSCConfig(split_layer=2, qw_front=16, tau=t, delta=10.0,
                       max_act_bits=3)
        s = SplitEngine(cfg, params, o, opts=C.OPTS, cache_len=64)
        rows.append((f"tab5/acc/{name}", 0.0,
                     f"acc={C.copy_accuracy_split(s, prompts):.3f}"))
    return rows


# ----------------------------------------------------------------- kernels


def bench_kernels():
    """Microbenchmarks of the Pallas kernels (interpret mode on CPU — these
    validate call paths, NOT TPU performance; see EXPERIMENTS.md)."""
    from repro.kernels.ops import dequant_matmul, tabq_quantize, ts_mask

    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(64, 256)).astype(np.float32))
    w = jnp.asarray(rng.integers(-127, 128, (256, 128)), jnp.int8)
    s = jnp.asarray(rng.uniform(0.001, 0.1, (128,)), jnp.float32)
    rows = []
    rows.append(("kernels/tabq_quantize_64x256", C.timeit_us(
        lambda: jax.block_until_ready(tabq_quantize(x, bits=8)), 3), "interpret"))
    rows.append(("kernels/dequant_matmul_64x256x128", C.timeit_us(
        lambda: jax.block_until_ready(dequant_matmul(x, w, s, block_k=256)), 3),
        "interpret"))
    rows.append(("kernels/ts_mask_64x256", C.timeit_us(
        lambda: jax.block_until_ready(ts_mask(x, 5.0)), 3), "interpret"))
    from repro.kernels.ops import decode_attention

    q = jnp.asarray(rng.normal(size=(2, 2, 4, 64)), jnp.float32)
    kc = jnp.asarray(rng.integers(-127, 128, (2, 2, 256, 64)), jnp.int8)
    sc = jnp.asarray(rng.uniform(0.005, 0.02, (2, 2, 256)), jnp.float32)
    kv_pos = jnp.asarray(np.arange(256)[None].repeat(2, 0), jnp.int32)
    rows.append(("kernels/decode_attention_int8kv_s256", C.timeit_us(
        lambda: jax.block_until_ready(
            decode_attention(q, kc, sc, kc, sc, kv_pos, jnp.int32(256),
                             block_s=64)), 3), "interpret"))
    return rows
