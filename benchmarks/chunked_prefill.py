"""chunked_prefill benchmark: TTFT and tail tick latency under Sarathi-style
chunked prefill vs the wave prefill it replaces.

Three prompt mixes are served three ways through the SAME scheduler and
pool (worst-case admission, kernels in interpret mode off-TPU):

  * wave         — ``prefill_mode="wave"``: an admission prefills its whole
    (bucketed) prompt in one ragged call. A long prompt stalls every
    decoding request for its full length AND each new (R_adm, S_pad)
    bucket is a fresh XLA compile;
  * chunked      — ``prefill_mode="chunked"`` (the default): prompts advance
    one fixed-size chunk per tick through ONE compiled shape; continuation
    chunks attend their earlier chunks in place via the Pallas
    ``kernels.paged_prefill_attention`` page walk;
  * dense_gather — chunked scheduling but
    ``RuntimeOpts(paged_prefill_kernel=False)``: continuation chunks gather
    the WHOLE pool dense and dequantize it per layer (the pre-kernel path)
    — isolating the kernel's contribution from the scheduler's;
  * auto         — ``prefill_chunk=(CHUNK//4, CHUNK//2, CHUNK)``: the
    ADAPTIVE ladder picks the chunk per tick — large while the batch is
    prefill-heavy, small once decode slots dominate (or an
    ``interactive`` latency hint objects) — trading a bounded extra
    compile count (one per rung) for a shorter TAIL TICK while decodes
    co-reside with a long admitting prompt. ``auto_chunks`` in the JSON
    records the per-rung tick counts.

Reported per mix/variant: wall TTFT (mean/max over requests) and TTFT in
scheduler ticks, the TAIL tick latency (the longest single tick — what a
co-resident decode request experiences while a prompt admits), tokens/s,
the distinct-jit-shape count, and greedy parity vs per-request
``Engine.generate`` (``outputs_match_baseline`` plus per-token
``token_agreement``: multi-chunk prefill is documented as bit-TOLERANT —
page-walk fp reassociation — and smaller chunk rungs re-associate more,
so a near-tie greedy argmax can flip on some prompt mixes; the agreement
column records how close a non-exact run stays). CPU wall numbers are
call-path + compile-churn comparisons, not TPU performance; the
tick/shape columns are exact on any backend. JSON artifact under
experiments/chunked_prefill/.

  PYTHONPATH=src python -m benchmarks.chunked_prefill [--smoke]

``--smoke`` runs one shrunken mix — the CI chunked-prefill smoke step.
"""

from __future__ import annotations

import argparse
import json
import os
import time

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                       "chunked_prefill")

# (prompt_len, max_new_tokens) per request; pool pages per mix
MIXES = {
    # the headline case: one long prompt admitted while short ones decode
    "one_long": {"jobs": [(48, 4), (4, 10), (6, 10), (5, 10)], "pages": 28},
    "bimodal": {"jobs": [(24, 4), (6, 8), (24, 4), (6, 8)], "pages": 28},
    # control: all prompts fit one chunk — chunking must not cost anything
    "short": {"jobs": [(6, 6)] * 4, "pages": 20},
}
SMOKE_MIXES = {"one_long": {"jobs": [(16, 3), (4, 6)], "pages": 16}}

PAGE_SIZE = 4
CHUNK = 8
MAX_SLOTS = 3  # fewer slots than requests → mid-stream admission exercised


def _build():
    import jax

    from repro.configs import get_config
    from repro.models.transformer import RuntimeOpts, init_params

    cfg = get_config("llama2-7b").tiny()
    params = init_params(cfg, jax.random.PRNGKey(0))
    opts = RuntimeOpts(q_chunk=16, kv_chunk=32, remat=False,
                       quantized_kv=True, moe_capacity_factor=0.0)
    return cfg, params, opts


def _serve(cfg, params, opts, jobs, prompts, variant, pages):
    import dataclasses

    import numpy as np

    from repro.serving.scheduler import Scheduler

    mode = "wave" if variant == "wave" else "chunked"
    if variant == "dense_gather":
        opts = dataclasses.replace(opts, paged_prefill_kernel=False)
    chunk = (max(1, CHUNK // 4), max(1, CHUNK // 2), CHUNK) \
        if variant == "auto" else CHUNK
    max_seq = max(n + mn for n, mn in jobs)
    sched = Scheduler(cfg, params, opts, num_pages=pages,
                      page_size=PAGE_SIZE, max_slots=MAX_SLOTS,
                      max_seq_len=max_seq, prefill_mode=mode,
                      prefill_chunk=chunk)
    rids = [sched.submit(p, mn) for p, (_, mn) in zip(prompts, jobs)]
    first_wall: dict = {}
    tick_walls = []
    t0 = time.time()
    while True:
        t_tick = time.time()
        more = sched.step()
        now = time.time()
        tick_walls.append(now - t_tick)
        for s in sched.slots:  # a request's first token appears in-slot...
            if s is not None and s.generated:
                first_wall.setdefault(s.req.rid, now - t0)
        for rid in sched.results:  # ...or it already finished this tick
            first_wall.setdefault(rid, now - t0)
        if not more:
            break
    wall = time.time() - t0
    results = sched.results
    total_tokens = sum(mn for _, mn in jobs)
    ttft_ticks = [sched.stats.ttft_ticks[r] for r in rids]
    ttft_wall = [first_wall[r] for r in rids]
    return results, rids, {
        "wall_s": round(wall, 3),
        "tokens_per_s": round(total_tokens / wall, 2),
        "mean_ttft_s": round(float(np.mean(ttft_wall)), 3),
        "max_ttft_s": round(float(np.max(ttft_wall)), 3),
        "mean_ttft_ticks": round(float(np.mean(ttft_ticks)), 2),
        "max_ttft_ticks": int(np.max(ttft_ticks)),
        "tail_tick_s": round(float(np.max(tick_walls)), 3),
        "median_tick_s": round(float(np.median(tick_walls)), 4),
        "ticks": len(tick_walls),
        "decode_steps": sched.stats.steps,
        "prefill_calls": sched.stats.prefills,
        "prefill_chunks": sched.stats.prefill_chunks,
        "compiled_shapes": sched.stats.compiled_shapes,
        "auto_chunks": {int(k): v
                        for k, v in sorted(sched.stats.auto_chunks.items())},
    }


def bench_chunked_prefill(smoke: bool = False):
    import numpy as np

    from repro.serving.engine import Engine

    cfg, params, opts = _build()
    mixes = SMOKE_MIXES if smoke else MIXES
    rng = np.random.default_rng(0)
    rows, rec = [], {"config": {"arch": cfg.name, "page_size": PAGE_SIZE,
                                "chunk": CHUNK, "max_slots": MAX_SLOTS,
                                "smoke": smoke}}
    eng = Engine(cfg, params, opts, cache_len=64)
    for name, mix in mixes.items():
        jobs = mix["jobs"]
        prompts = [rng.integers(0, cfg.vocab_size, (n,)) for n, _ in jobs]
        want = [eng.generate(p[None], mn).tokens[0]
                for p, (_, mn) in zip(prompts, jobs)]
        entry = {"requests": len(jobs)}
        for variant in ("wave", "chunked", "dense_gather", "auto"):
            results, rids, m = _serve(cfg, params, opts, jobs, prompts,
                                      variant, mix["pages"])
            m["outputs_match_baseline"] = all(
                np.array_equal(results[r], w) for r, w in zip(rids, want))
            gen = [(results[r][n:], w[n:])
                   for r, w, (n, _) in zip(rids, want, jobs)]
            m["token_agreement"] = round(float(np.mean(
                [np.mean(g == w) for g, w in gen])), 3)
            entry[variant] = m
            rows.append((f"chunked_prefill/{name}_{variant}",
                         m["wall_s"] * 1e6,
                         f"ttft={m['mean_ttft_s']}s "
                         f"tail_tick={m['tail_tick_s']}s "
                         f"shapes={m['compiled_shapes']}"))
        entry["ttft_reduction_vs_wave"] = round(
            entry["wave"]["mean_ttft_s"]
            / max(entry["chunked"]["mean_ttft_s"], 1e-9), 2)
        entry["tail_tick_reduction_vs_wave"] = round(
            entry["wave"]["tail_tick_s"]
            / max(entry["chunked"]["tail_tick_s"], 1e-9), 2)
        entry["tail_tick_reduction_auto_vs_chunked"] = round(
            entry["chunked"]["tail_tick_s"]
            / max(entry["auto"]["tail_tick_s"], 1e-9), 2)
        rec[name] = entry
        rows.append((f"chunked_prefill/{name}_ttft_reduction", 0.0,
                     entry["ttft_reduction_vs_wave"]))
    from benchmarks.common import env_section
    rec.update(env_section())
    os.makedirs(OUT_DIR, exist_ok=True)
    out = os.path.join(OUT_DIR, "chunked_prefill_smoke.json" if smoke
                       else "chunked_prefill.json")
    with open(out, "w") as f:
        json.dump(rec, f, indent=1)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="one shrunken mix (CI chunked-prefill smoke step)")
    args = ap.parse_args()
    for name, us, derived in bench_chunked_prefill(smoke=args.smoke):
        print(f"{name},{us:.1f},{derived}", flush=True)


if __name__ == "__main__":
    main()
