"""ragged_batch benchmark: paged continuous batching vs equal-length
bucketing (the Eq. 2 memory term under a multi-tenant mix).

Three batch mixes (uniform / bimodal / longtail) are served two ways:

  * ragged  — ``serving.scheduler.Scheduler`` over one shared
    ``PagedKVPool``: admission reserves each request's worst case
    (prompt + max_new) and eviction reclaims it immediately, so peak
    memory is bounded by the requests CONCURRENTLY resident — not by
    sizing every slot for the batch-wide longest request. (On mixes small
    enough that everything fits at once — e.g. the --smoke mix — the
    reservation + page rounding can exceed tight per-group bucketing;
    the win appears when the mix is ragged and deeper than the slots.);
  * bucketed — the seed ``serving.engine.Engine`` strategy: group requests
    by exact prompt length, one dense batch per group sized for the
    group's LONGEST generation (shorter requests over-generate and their
    surplus is discarded — the cost of equal-length batches).

Reported per mix: tokens/sec (CPU with kernels in interpret mode — CALL-PATH
comparison, not TPU performance; the memory columns are exact on any
backend), the scheduler's peak pool occupancy/bytes, the analytical Eq. 2
bytes of the resident requests, and the bucketed path's dense-cache
residency. JSON artifact under experiments/ragged_batch/ for the BENCH_*
trajectory.

  PYTHONPATH=src python -m benchmarks.ragged_batch [--smoke]

``--smoke`` runs one shrunken mix — the CI scheduler-smoke job's 2-minute
guard that the paged path stays wired.
"""

from __future__ import annotations

import argparse
import json
import os
import time

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                       "ragged_batch")

# (prompt_len, max_new_tokens) per request
MIXES = {
    "uniform": [(8, 6)] * 4,
    "bimodal": [(4, 3), (4, 3), (12, 10), (12, 10), (4, 3), (12, 10)],
    "longtail": [(3, 2), (5, 3), (6, 4), (8, 5), (10, 6), (16, 12)],
}
SMOKE_MIXES = {"bimodal": [(4, 3), (8, 5), (4, 3)]}

PAGE_SIZE = 4
MAX_SLOTS = 3  # fewer slots than requests → mid-stream admission exercised


def _build():
    import jax

    from repro.configs import get_config
    from repro.models.transformer import RuntimeOpts, init_params

    cfg = get_config("llama2-7b").tiny()
    params = init_params(cfg, jax.random.PRNGKey(0))
    opts = RuntimeOpts(q_chunk=16, kv_chunk=32, remat=False,
                       quantized_kv=True, moe_capacity_factor=0.0)
    return cfg, params, opts


def _run_ragged(cfg, params, opts, jobs, prompts):
    from repro.serving.scheduler import Scheduler

    total_tokens = sum(mn for _, mn in jobs)  # generated tokens only
    need = sum(-(-(n + mn) // PAGE_SIZE) for n, mn in jobs)
    sched = Scheduler(cfg, params, opts, num_pages=max(need // 2, 8) + 1,
                      page_size=PAGE_SIZE, max_slots=MAX_SLOTS)
    rids = [sched.submit(p, mn) for p, (_, mn) in zip(prompts, jobs)]
    t0 = time.time()
    results = sched.run()
    wall = time.time() - t0
    assert len(results) == len(rids)
    return {
        "wall_s": round(wall, 3),
        "tokens_per_s": round(total_tokens / wall, 2),
        "decode_steps": sched.stats.steps,
        "prefill_waves": sched.stats.prefills,
        "peak_occupancy": round(sched.stats.peak_occupancy, 3),
        "peak_pool_bytes": sched.stats.peak_pool_bytes,
        "peak_eq2_bytes": sched.stats.peak_eq2_bytes,
        "pool_pages": sched.pool.num_pages,
    }


def _run_bucketed(cfg, params, opts, jobs, prompts):
    """Seed strategy: equal-prompt-length groups, each generating to the
    group max (surplus tokens discarded), dense caches sized per group."""
    import numpy as np

    from repro.core.opsc import kv_cache_bytes
    from repro.serving.engine import Engine

    groups: dict = {}
    for p, (n, mn) in zip(prompts, jobs):
        groups.setdefault(n, []).append((p, mn))
    total_tokens = sum(mn for _, mn in jobs)
    resident = 0
    t0 = time.time()
    for n, members in groups.items():
        mx = max(mn for _, mn in members)
        cache_len = n + mx
        eng = Engine(cfg, params, opts, cache_len=cache_len)
        batch = np.stack([p for p, _ in members])
        eng.generate(batch, mx)  # shorter members over-generate to mx
        # dense residency: every member holds cache_len slots at int8
        resident += sum(
            kv_cache_bytes(cache_len, cfg.num_layers, cfg.num_layers,
                           cfg.pattern[0].mixer.num_kv_heads
                           * cfg.pattern[0].mixer.head_dim, 8, 8)
            for _ in members)
    wall = time.time() - t0
    return {
        "wall_s": round(wall, 3),
        "tokens_per_s": round(total_tokens / wall, 2),
        "groups": len(groups),
        "resident_bytes": resident,
        "overgenerated_tokens": sum(
            max(mn2 for _, mn2 in members) - mn
            for members in groups.values() for _, mn in members),
    }


def bench_ragged_batch(smoke: bool = False):
    import numpy as np

    cfg, params, opts = _build()
    mixes = SMOKE_MIXES if smoke else MIXES
    rng = np.random.default_rng(0)
    rows, rec = [], {"config": {"arch": cfg.name, "page_size": PAGE_SIZE,
                                "max_slots": MAX_SLOTS, "smoke": smoke}}
    for name, jobs in mixes.items():
        prompts = [rng.integers(0, cfg.vocab_size, (n,)) for n, _ in jobs]
        ragged = _run_ragged(cfg, params, opts, jobs, prompts)
        bucketed = _run_bucketed(cfg, params, opts, jobs, prompts)
        mem_red = bucketed["resident_bytes"] / max(ragged["peak_pool_bytes"], 1)
        rec[name] = {"requests": len(jobs), "ragged": ragged,
                     "bucketed": bucketed,
                     "mem_reduction_vs_bucketed": round(mem_red, 2)}
        rows.append((f"ragged_batch/{name}_ragged", ragged["wall_s"] * 1e6,
                     f"tok/s={ragged['tokens_per_s']} "
                     f"occ={ragged['peak_occupancy']} "
                     f"pool={ragged['peak_pool_bytes']}B"))
        rows.append((f"ragged_batch/{name}_bucketed", bucketed["wall_s"] * 1e6,
                     f"tok/s={bucketed['tokens_per_s']} "
                     f"resident={bucketed['resident_bytes']}B"))
        rows.append((f"ragged_batch/{name}_mem_reduction", 0.0,
                     round(mem_red, 2)))
    from benchmarks.common import env_section
    rec.update(env_section())
    os.makedirs(OUT_DIR, exist_ok=True)
    out = os.path.join(OUT_DIR, "ragged_batch_smoke.json" if smoke
                       else "ragged_batch.json")
    with open(out, "w") as f:
        json.dump(rec, f, indent=1)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="one shrunken mix (CI scheduler-smoke job)")
    args = ap.parse_args()
    for name, us, derived in bench_ragged_batch(smoke=args.smoke):
        print(f"{name},{us:.1f},{derived}", flush=True)


if __name__ == "__main__":
    main()
