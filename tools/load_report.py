"""load-report: render + validate a load_serving artifact (the CI
load-smoke gate — ``tools/trace_report.py``'s sibling for the HTTP
service layer).

  python tools/load_report.py experiments/load_serving/load_serving_smoke.json \
      [--min-completed N] [--min-tokens-per-s X] [--max-ttft-p99-s X]

Reads the JSON ``benchmarks/load_serving.py`` writes and prints the
client-vs-server SLO table; then validates (exit code 1 on failure):

  * structure: ``client`` / ``server_metrics`` / ``config`` sections
    present, percentile dicts well-formed (p50 <= p95 <= p99);
  * progress: at least ``--min-completed`` streams ran to ``[DONE]`` and
    achieved tokens/s clears ``--min-tokens-per-s``;
  * server-side accounting: the ``/v1/metrics`` histograms saw every
    finished request (``requests.e2e_s.count`` >= client completions)
    and every client hang-up shows up as an abort
    (``requests.reason.abort`` >= client aborts);
  * **no leak**: when the artifact carries a ``pool`` section (in-process
    run), ``pages_in_use`` and ``pages_shared`` are both 0 after the
    drain — a mid-stream disconnect that pins pages fails CI here;
  * latency sanity: client TTFT p99 under ``--max-ttft-p99-s`` when set.
"""

from __future__ import annotations

import argparse
import json
import sys


def _check_pcts(d: dict, name: str, problems: list) -> None:
    have = [d[q] for q in ("p50", "p95", "p99") if q in d]
    if len(have) != 3:
        problems.append(f"{name}: incomplete percentile dict {sorted(d)}")
    elif not have[0] <= have[1] <= have[2]:
        problems.append(f"{name}: percentiles not monotone: {have}")


def validate(rec: dict, min_completed: int = 1,
             min_tokens_per_s: float = 0.0,
             max_ttft_p99_s: float | None = None) -> list:
    problems = []
    for section in ("config", "client", "server_metrics"):
        if section not in rec:
            problems.append(f"missing {section!r} section")
    if problems:
        return problems
    c, sm = rec["client"], rec["server_metrics"]
    if c.get("completed", 0) < min_completed:
        problems.append(f"expected >= {min_completed} completed streams, "
                        f"got {c.get('completed')}")
    if c.get("tokens_per_s", 0.0) < min_tokens_per_s:
        problems.append(f"achieved {c.get('tokens_per_s')} tok/s < floor "
                        f"{min_tokens_per_s}")
    for key in ("ttft_s", "e2e_s"):
        if c.get("completed", 0) > 0:
            _check_pcts(c.get(key, {}), f"client.{key}", problems)
    if max_ttft_p99_s is not None and \
            c.get("ttft_s", {}).get("p99", 0.0) > max_ttft_p99_s:
        problems.append(f"client TTFT p99 {c['ttft_s']['p99']}s over the "
                        f"{max_ttft_p99_s}s gate")
    n_srv = sm.get("requests.e2e_s.count", 0)
    if n_srv < c.get("completed", 0):
        problems.append(f"server e2e histogram saw {n_srv} requests but "
                        f"{c['completed']} clients completed — tick-thread "
                        f"metric stamping is dropping requests")
    if sm.get("requests.reason.abort", 0) < c.get("client_aborts", 0):
        problems.append(f"{c['client_aborts']} clients hung up but server "
                        f"recorded {sm.get('requests.reason.abort', 0)} "
                        f"aborts — disconnect→abort path is broken")
    pool = rec.get("pool")
    if pool is not None:
        for g in ("pages_in_use", "pages_shared"):
            if pool.get(g, 0) != 0:
                problems.append(f"LEAK: pool gauge {g} = {pool[g]} after "
                                f"drain (expected 0)")
    return problems


def report(rec: dict, out=sys.stdout) -> None:
    w = out.write
    cfg, c = rec.get("config", {}), rec.get("client", {})
    w(f"== load ==\n  requests={cfg.get('requests')} "
      f"rate={cfg.get('rate_req_per_s')}/s "
      f"abort_fraction={cfg.get('abort_fraction')} "
      f"smoke={cfg.get('smoke')}\n")
    w(f"== client ==\n  completed={c.get('completed')} "
      f"aborts={c.get('client_aborts')} rejected={c.get('rejected')} "
      f"tokens={c.get('tokens_streamed')} tok/s={c.get('tokens_per_s')}\n")
    for key in ("ttft_s", "e2e_s"):
        p = c.get(key, {})
        if p:
            w(f"  {key:<8} p50={p.get('p50')} p95={p.get('p95')} "
              f"p99={p.get('p99')}\n")
    sm = rec.get("server_metrics", {})
    w("== server (/v1/metrics) ==\n")
    for row in ("requests.ttft_s", "requests.tpot_s", "requests.e2e_s"):
        if f"{row}.count" in sm:
            w(f"  {row:<18} n={sm[f'{row}.count']:<5} "
              f"p50={sm.get(f'{row}.p50', 0):.6f} "
              f"p99={sm.get(f'{row}.p99', 0):.6f}\n")
    for k in sorted(sm):
        if k.startswith("requests.reason.") or k == "requests.retained":
            w(f"  {k} = {sm[k]}\n")
    if "pool" in rec:
        g = rec["pool"]
        w(f"== pool (post-drain) ==\n  pages_in_use={g.get('pages_in_use')}"
          f" pages_shared={g.get('pages_shared')} "
          f"pages_free={g.get('pages_free')}\n")
    if "scheduler" in rec:
        s = rec["scheduler"]
        w(f"== prefix sharing ==\n  auto_prefix_hits="
          f"{s.get('auto_prefix_hits')} prefix_forks="
          f"{s.get('prefix_forks')}\n")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("artifact", help="JSON from benchmarks/load_serving.py")
    ap.add_argument("--min-completed", type=int, default=1)
    ap.add_argument("--min-tokens-per-s", type=float, default=0.0)
    ap.add_argument("--max-ttft-p99-s", type=float, default=None)
    args = ap.parse_args(argv)
    try:
        with open(args.artifact) as f:
            rec = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"load-report: cannot load {args.artifact}: {e}",
              file=sys.stderr)
        return 1
    report(rec)
    problems = validate(rec, min_completed=args.min_completed,
                        min_tokens_per_s=args.min_tokens_per_s,
                        max_ttft_p99_s=args.max_ttft_p99_s)
    if problems:
        print("load-report: VALIDATION FAILED", file=sys.stderr)
        for p in problems:
            print(f"  - {p}", file=sys.stderr)
        return 1
    print(f"load-report: OK ({rec['client']['completed']} completed, "
          f"{rec['client']['tokens_per_s']} tok/s)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
