"""docs-check: verify that intra-repo links and back-ticked file paths in
the repo's markdown docs resolve to real files.

  python tools/check_links.py [README.md src/repro/serving/README.md ...]

With no arguments, checks the default doc set (root README + serving
README). External links (http/https/mailto) and pure anchors are skipped;
relative links are resolved against each file's own directory AND the repo
root (both styles appear in the docs). Exits non-zero listing every broken
link — the CI docs-check job fails on rot.
"""

from __future__ import annotations

import os
import re
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_DOCS = ["README.md", "src/repro/serving/README.md", "MIGRATION.md"]

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
# back-ticked tokens that look like repo paths: `src/...`, `tests/...`, etc.
PATH_RE = re.compile(
    r"`((?:src|tests|benchmarks|examples|experiments|tools|\.github)"
    r"/[^`\s]+?)`")


def _exists(base_dir: str, target: str) -> bool:
    target = target.split("#", 1)[0]
    if not target:
        return True  # pure anchor
    for root in (base_dir, REPO):
        if os.path.exists(os.path.join(root, target)):
            return True
    return False


def check(path: str) -> list:
    broken = []
    base = os.path.dirname(os.path.join(REPO, path))
    text = open(os.path.join(REPO, path)).read()
    for num, line in enumerate(text.splitlines(), 1):
        for m in LINK_RE.finditer(line):
            t = m.group(1)
            if t.startswith(("http://", "https://", "mailto:", "#")):
                continue
            if not _exists(base, t):
                broken.append(f"{path}:{num}: broken link -> {t}")
        for m in PATH_RE.finditer(line):
            t = m.group(1).rstrip("/").split("#")[0].split("::")[0]
            # module globs / command lines aren't file references
            if any(ch in t for ch in "*<>{}"):
                continue
            if not _exists(base, t):
                broken.append(f"{path}:{num}: missing path -> {t}")
    return broken


def main() -> None:
    docs = sys.argv[1:] or DEFAULT_DOCS
    broken = []
    for d in docs:
        if not os.path.exists(os.path.join(REPO, d)):
            broken.append(f"{d}: doc file itself is missing")
            continue
        broken.extend(check(d))
    if broken:
        print("\n".join(broken))
        raise SystemExit(1)
    print(f"docs-check: {len(docs)} file(s), all links resolve")


if __name__ == "__main__":
    main()
