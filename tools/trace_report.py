"""trace-report: render a text summary of an exported serving trace and
validate its structure (the CI smoke gate for telemetry).

  python tools/trace_report.py experiments/telemetry/trace.json \
      [--require-spans N] [--require-ticks N] \
      [--require-phases queued,prefill,decode,...]

Reads a Chrome trace-event JSON produced by
``repro.serving.telemetry.Tracer.export_chrome_trace`` and prints:

  * per-phase span breakdown (count, total/mean duration) — the request
    lifecycle time budget;
  * instant-event counts (first_token / preempt / finish / compile /
    uplink);
  * tick timeline stats (count, modes, live vs. pad tokens, compile
    events, peak pool occupancy, peak queue depth);
  * the embedded ``repro_metrics`` SLO table (TTFT / TPOT / tick-latency
    p50/p95/p99 and the preemption/swap counters).

Validation (exit code 1 on failure): the trace must parse, carry at
least ``--require-spans`` spans and ``--require-ticks`` tick events,
contain every phase named in ``--require-phases`` (span names and
instant-event names both count), and every span must have monotonically
consistent timestamps (``ts >= 0``, ``dur >= 0``, and each request's
lifecycle events in submit → first-token → finish order).
"""

from __future__ import annotations

import argparse
import json
import sys
from collections import defaultdict


def load(path: str) -> dict:
    with open(path) as f:
        return json.load(f)


def _spans(trace: dict) -> list:
    return [e for e in trace.get("traceEvents", [])
            if e.get("ph") == "X" and e.get("cat") == "span"]


def _instants(trace: dict) -> list:
    return [e for e in trace.get("traceEvents", [])
            if e.get("ph") == "i"]


def _ticks(trace: dict) -> list:
    return [e for e in trace.get("traceEvents", [])
            if e.get("ph") == "X" and e.get("cat") == "tick"]


def validate(trace: dict, require_phases=(), min_spans: int = 1,
             min_ticks: int = 0) -> list:
    """Structural checks; returns a list of human-readable problems
    (empty = valid)."""
    problems = []
    spans, instants, ticks = _spans(trace), _instants(trace), _ticks(trace)
    if len(spans) < min_spans:
        problems.append(f"expected >= {min_spans} spans, found {len(spans)}")
    if len(ticks) < min_ticks:
        problems.append(f"expected >= {min_ticks} tick events, "
                        f"found {len(ticks)}")
    for e in spans + ticks:
        if e.get("ts", -1) < 0:
            problems.append(f"negative timestamp on {e.get('name')!r}")
        if e.get("dur", -1) < 0:
            problems.append(f"negative duration on {e.get('name')!r}")
    seen = {e["name"] for e in spans} | {e["name"] for e in instants}
    for phase in require_phases:
        if phase not in seen:
            problems.append(f"required phase {phase!r} missing "
                            f"(have: {sorted(seen)})")
    # per-request lifecycle ordering: queued begins before first_token,
    # first_token at or before finish (all in the same exported timebase)
    starts: dict = {}
    firsts: dict = {}
    for e in spans:
        rid = e.get("args", {}).get("rid")
        if rid is not None and e["name"] == "queued":
            starts[rid] = min(starts.get(rid, e["ts"]), e["ts"])
    for e in instants:
        rid = e.get("args", {}).get("rid")
        if rid is None:
            continue
        if e["name"] == "first_token":
            firsts[rid] = e["ts"]
            if rid in starts and e["ts"] < starts[rid]:
                problems.append(f"rid {rid}: first_token at {e['ts']} "
                                f"before queued at {starts[rid]}")
        if e["name"] == "finish" and rid in firsts \
                and e["ts"] < firsts[rid]:
            problems.append(f"rid {rid}: finish at {e['ts']} before "
                            f"first_token at {firsts[rid]}")
    if "repro_metrics" not in trace:
        problems.append("missing embedded repro_metrics dict")
    return problems


def _fmt_us(us: float) -> str:
    if us >= 1e6:
        return f"{us / 1e6:.3f}s"
    if us >= 1e3:
        return f"{us / 1e3:.2f}ms"
    return f"{us:.0f}us"


def report(trace: dict, out=sys.stdout) -> None:
    spans, instants, ticks = _spans(trace), _instants(trace), _ticks(trace)
    w = out.write

    w("== span phases ==\n")
    by_phase: dict = defaultdict(list)
    for e in spans:
        by_phase[e["name"]].append(e["dur"])
    for name in sorted(by_phase):
        durs = by_phase[name]
        w(f"  {name:<16} n={len(durs):<5} total={_fmt_us(sum(durs)):<10} "
          f"mean={_fmt_us(sum(durs) / len(durs))}\n")
    if not by_phase:
        w("  (none)\n")

    w("== instant events ==\n")
    counts: dict = defaultdict(int)
    for e in instants:
        counts[e["name"]] += 1
    for name in sorted(counts):
        w(f"  {name:<16} n={counts[name]}\n")
    if not counts:
        w("  (none)\n")

    w("== ticks ==\n")
    if ticks:
        modes: dict = defaultdict(int)
        tokens = pad = compiles = 0
        peak_pages = peak_queue = 0
        for e in ticks:
            a = e.get("args", {})
            modes[a.get("mode", "?")] += 1
            tokens += a.get("tokens", 0) or 0
            pad += a.get("pad_tokens", 0) or 0
            compiles += a.get("new_compiles", 0) or 0
            peak_pages = max(peak_pages, a.get("pages_in_use", 0) or 0)
            peak_queue = max(peak_queue, a.get("queue_depth", 0) or 0)
        durs = [e["dur"] for e in ticks]
        w(f"  count={len(ticks)} modes={dict(modes)}\n")
        w(f"  tokens={tokens} pad_tokens={pad} new_compiles={compiles}\n")
        w(f"  peak_pages_in_use={peak_pages} peak_queue_depth="
          f"{peak_queue}\n")
        w(f"  wall total={_fmt_us(sum(durs))} mean="
          f"{_fmt_us(sum(durs) / len(durs))}\n")
    else:
        w("  (none)\n")

    m = trace.get("repro_metrics", {})
    w("== SLO table ==\n")
    slo_rows = ("ttft_s", "tpot_s", "e2e_s", "tick.wall_s",
                "fused.batch_s", "split.edge_s", "split.cloud_s")
    any_row = False
    for row in slo_rows:
        if f"{row}.count" not in m:
            continue
        any_row = True
        w(f"  {row:<14} n={m[f'{row}.count']:<6} "
          f"p50={m.get(f'{row}.p50', 0):.6f} "
          f"p95={m.get(f'{row}.p95', 0):.6f} "
          f"p99={m.get(f'{row}.p99', 0):.6f}\n")
    if not any_row:
        w("  (no latency histograms recorded)\n")
    w("== counters ==\n")
    for key in sorted(m):
        if isinstance(m[key], (int, float)) and "." not in key.rsplit(
                ".", 1)[-1] and not any(
                key.endswith(s) for s in
                (".p50", ".p95", ".p99", ".mean", ".min", ".max", ".sum",
                 ".count")):
            w(f"  {key} = {m[key]}\n")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("trace", help="Chrome trace-event JSON from "
                                  "Tracer.export_chrome_trace")
    ap.add_argument("--require-spans", type=int, default=1,
                    help="minimum span count (default 1)")
    ap.add_argument("--require-ticks", type=int, default=0,
                    help="minimum tick-event count (default 0)")
    ap.add_argument("--require-phases", default="",
                    help="comma-separated span/event names that must be "
                         "present (e.g. queued,prefill,first_token,decode)")
    args = ap.parse_args(argv)
    try:
        trace = load(args.trace)
    except (OSError, json.JSONDecodeError) as e:
        print(f"trace-report: cannot load {args.trace}: {e}",
              file=sys.stderr)
        return 1
    report(trace)
    phases = [p for p in args.require_phases.split(",") if p]
    problems = validate(trace, require_phases=phases,
                        min_spans=args.require_spans,
                        min_ticks=args.require_ticks)
    if problems:
        print("trace-report: VALIDATION FAILED", file=sys.stderr)
        for p in problems:
            print(f"  - {p}", file=sys.stderr)
        return 1
    print(f"trace-report: OK ({len(_spans(trace))} spans, "
          f"{len(_ticks(trace))} ticks, {len(_instants(trace))} instants)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
