"""Unit tests for the shared on-device sampler (``core.sampling``): exact
greedy lanes, top-k / top-p support filtering, per-row PRNG-lane
independence (the property that buys fused/paged sampling parity), and
distribution sanity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.sampling import (SamplingParams, bias_rows, sample_tokens,
                                 sample_tokens_with_logprobs,
                                 sampling_operands, speculative_verify,
                                 token_logprobs)


def _logits(r=4, v=32, seed=0):
    return jnp.asarray(np.random.default_rng(seed).normal(size=(r, v)) * 2.0,
                       jnp.float32)


def _ops(params):
    o = sampling_operands(params)
    return o["keys"], o["temperature"], o["top_k"], o["top_p"]


def _draws(logits, params, n=200):
    keys, temp, tk, tp = _ops(params)
    fn = jax.jit(sample_tokens)
    r = logits.shape[0]
    return np.stack([np.asarray(fn(logits, keys, np.full((r,), t, np.int32),
                                   temp, tk, tp)) for t in range(n)])


def test_greedy_lanes_are_exact_argmax():
    """temperature <= 0 and top_k == 1 both select the argmax exactly,
    row-wise, in a batch whose other rows sample."""
    logits = _logits()
    params = [SamplingParams(),  # default: temperature 0
              SamplingParams(temperature=2.0, top_k=1),  # top-k 1
              SamplingParams(temperature=1.0, seed=3),
              SamplingParams(temperature=-1.0, seed=4)]  # negative temp
    draws = _draws(logits, params, n=20)
    am = np.argmax(np.asarray(logits), axis=-1)
    assert np.all(draws[:, 0] == am[0])
    assert np.all(draws[:, 1] == am[1])
    assert np.all(draws[:, 3] == am[3])


def test_top_k_restricts_support():
    logits = _logits(r=2, v=16, seed=1)
    k = 3
    params = [SamplingParams(temperature=1.5, top_k=k, seed=s)
              for s in (0, 1)]
    draws = _draws(logits, params)
    for row in range(2):
        allowed = set(np.argsort(-np.asarray(logits)[row])[:k].tolist())
        assert set(draws[:, row].tolist()) <= allowed
        # with 200 draws at temperature 1.5 the support should be exercised
        assert len(set(draws[:, row].tolist())) > 1


def test_top_p_restricts_support_to_nucleus():
    logits = _logits(r=1, v=16, seed=2)
    top_p = 0.6
    params = [SamplingParams(temperature=1.0, top_p=top_p, seed=0)]
    draws = _draws(logits, params)[:, 0]
    z = np.asarray(logits)[0]
    order = np.argsort(-z)
    probs = np.exp(z[order]) / np.exp(z[order]).sum()
    # the nucleus: smallest prefix whose exclusive cumsum is < top_p
    nucleus = set()
    cum = 0.0
    for tok, pr in zip(order, probs):
        if cum >= top_p and nucleus:
            break
        nucleus.add(int(tok))
        cum += pr
    assert set(draws.tolist()) <= nucleus


def test_top_p_one_and_top_k_zero_disable_filters():
    """Disabled filters leave the full support reachable (all tokens of a
    near-uniform distribution appear across many draws)."""
    logits = jnp.zeros((1, 8), jnp.float32)  # uniform
    params = [SamplingParams(temperature=1.0, seed=0)]
    draws = _draws(logits, params, n=400)[:, 0]
    assert set(draws.tolist()) == set(range(8))


def test_rows_are_independent_of_batch_composition():
    """A row's draw depends only on (its logits, its key, its index) — the
    property that makes the paged scheduler reproduce the fused engine."""
    logits = _logits(r=3, v=16, seed=3)
    params = [SamplingParams(temperature=1.1, seed=s) for s in (5, 6, 7)]
    batch = _draws(logits, params, n=25)
    solo = _draws(logits[1:2], params[1:2], n=25)
    np.testing.assert_array_equal(batch[:, 1], solo[:, 0])


def test_same_seed_same_index_is_deterministic():
    logits = _logits(r=2, v=16, seed=4)
    params = [SamplingParams(temperature=1.0, seed=9),
              SamplingParams(temperature=1.0, seed=9)]
    keys, temp, tk, tp = _ops(params)
    t = np.zeros((2,), np.int32)
    a = sample_tokens(logits, keys, t, temp, tk, tp)
    b = sample_tokens(logits, keys, t, temp, tk, tp)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # identical params + identical logits rows ⇒ identical draws
    same = _logits(r=1, v=16, seed=5)
    both = jnp.concatenate([same, same], axis=0)
    out = np.asarray(sample_tokens(both, keys, t, temp, tk, tp))
    assert out[0] == out[1]


def test_low_temperature_concentrates_on_argmax():
    logits = _logits(r=2, v=16, seed=6)
    cold = _draws(logits, [SamplingParams(temperature=0.05, seed=0),
                           SamplingParams(temperature=3.0, seed=0)], n=300)
    am = np.argmax(np.asarray(logits), axis=-1)
    cold_hit = np.mean(cold[:, 0] == am[0])
    hot_hit = np.mean(cold[:, 1] == am[1])
    assert cold_hit > 0.95  # near-greedy
    assert hot_hit < cold_hit  # hot row genuinely spreads


def test_sampling_params_validation():
    with pytest.raises(ValueError, match="max_tokens"):
        SamplingParams(max_tokens=0)
    with pytest.raises(ValueError, match="top_k"):
        SamplingParams(top_k=-1)
    with pytest.raises(ValueError, match="top_p"):
        SamplingParams(top_p=0.0)
    with pytest.raises(ValueError, match="top_p"):
        SamplingParams(top_p=1.5)
    with pytest.raises(ValueError, match="latency_hint"):
        SamplingParams(latency_hint="asap")
    sp = SamplingParams(stop_token_ids=(3, 5), eos_id=7)
    assert sp.stop_set == {3, 5, 7}
    assert SamplingParams().greedy
    assert SamplingParams(temperature=1.0, top_k=1).greedy
    assert not SamplingParams(temperature=1.0).greedy


def test_logit_bias_normalization_and_rows():
    """``logit_bias`` normalizes to a sorted (token, bias) tuple from a
    dict or pair iterable; ``bias_rows`` densifies per-request rows and
    range-checks token ids against the vocab."""
    sp = SamplingParams(logit_bias={7: -2.0, 3: 1.5})
    assert sp.logit_bias == ((3, 1.5), (7, -2.0))
    assert SamplingParams(logit_bias=[(2, 0.5)]).logit_bias == ((2, 0.5),)
    assert SamplingParams().logit_bias == ()
    with pytest.raises(ValueError, match="logit_bias"):
        SamplingParams(logit_bias={-1: 1.0})
    rows = bias_rows([sp, SamplingParams()], vocab_size=10)
    assert rows.shape == (2, 10)
    assert rows[0, 3] == 1.5 and rows[0, 7] == -2.0
    assert not rows[1].any()
    with pytest.raises(ValueError, match="out of range"):
        bias_rows([SamplingParams(logit_bias={10: 1.0})], vocab_size=10)


def test_logit_bias_reshapes_greedy_argmax():
    """A large positive bias redirects the greedy argmax to the biased
    token; an all-zero bias row is a bitwise no-op on every lane."""
    logits = _logits(r=3, v=16, seed=7)
    am = np.argmax(np.asarray(logits), axis=-1)
    target = int((am[0] + 1) % 16)  # provably not the raw argmax
    params = [SamplingParams(logit_bias={target: 100.0}),
              SamplingParams(),
              SamplingParams(temperature=1.3, seed=11)]
    keys, temp, tk, tp = _ops(params)
    t = np.zeros((3,), np.int32)
    bias = jnp.asarray(bias_rows(params, 16))
    toks = np.asarray(sample_tokens(logits, keys, t, temp, tk, tp, bias))
    assert toks[0] == target  # bias flipped the greedy row
    assert toks[1] == am[1]  # unbiased greedy row untouched
    # zero bias operand == no bias operand, bit for bit, sampled rows too
    none = np.asarray(sample_tokens(logits, keys, t, temp, tk, tp, None))
    zero = np.asarray(sample_tokens(logits, keys, t, temp, tk, tp,
                                    jnp.zeros_like(bias)))
    np.testing.assert_array_equal(none, zero)


def test_logit_bias_logprobs_stay_raw():
    """The emitted token follows the BIASED argmax but its reported
    logprob is the raw distribution's value for that token."""
    logits = _logits(r=1, v=16, seed=8)
    target = int((np.argmax(np.asarray(logits)[0]) + 3) % 16)
    params = [SamplingParams(logit_bias={target: 50.0})]
    keys, temp, tk, tp = _ops(params)
    bias = jnp.asarray(bias_rows(params, 16))
    toks, lps = sample_tokens_with_logprobs(
        logits, keys, np.zeros((1,), np.int32), temp, tk, tp, bias)
    assert int(toks[0]) == target
    want = np.asarray(token_logprobs(logits, toks))
    np.testing.assert_array_equal(np.asarray(lps), want)


def test_logit_bias_speculative_matches_prebias():
    """Biased ``speculative_verify`` emits the same tokens as an unbiased
    verify over pre-biased logits (so speculative and sequential biased
    greedy decoding agree), while its logprobs come from the RAW logits."""
    rng = np.random.default_rng(9)
    r, kd, v = 2, 3, 16
    logits = jnp.asarray(rng.normal(size=(r, kd + 1, v)) * 2.0, jnp.float32)
    params = [SamplingParams(logit_bias={5: 30.0}), SamplingParams()]
    keys, temp, tk, tp = _ops(params)
    bias = jnp.asarray(bias_rows(params, v))
    draft = jnp.asarray(rng.integers(0, v, (r, kd)), jnp.int32)
    dlen = jnp.asarray([kd, 2], jnp.int32)
    t0 = np.zeros((r,), np.int32)
    out_b, n_b, lp_b = speculative_verify(draft, dlen, logits, keys, t0,
                                          temp, tk, tp, bias)
    out_p, n_p, lp_p = speculative_verify(draft, dlen,
                                          logits + bias[:, None, :], keys,
                                          t0, temp, tk, tp)
    np.testing.assert_array_equal(np.asarray(out_b), np.asarray(out_p))
    np.testing.assert_array_equal(np.asarray(n_b), np.asarray(n_p))
    # row 0's every emitted position is the biased token (bias dominates)
    assert np.all(np.asarray(out_b)[0, : int(n_b[0])] == 5)
    # logprobs from the raw logits, not the biased ones
    flat = np.asarray(token_logprobs(
        logits.reshape(r * (kd + 1), v),
        jnp.asarray(out_b).reshape(r * (kd + 1)))).reshape(r, kd + 1)
    np.testing.assert_array_equal(np.asarray(lp_b), flat)
