"""Tests for the analytical models: OPSC memory (Eq. 1-3), channel (Eq. 9-13),
unified split optimization (Eq. 8) and the early-exit controller (Alg. 2)."""

import math

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.channel import (ChannelConfig, LatencyModel, optimal_rate,
                                outage_probability, worst_case_latency)
from repro.core.early_exit import EarlyExitController, default_payload_bits_fn
from repro.core.opsc import (OPSCConfig, edge_weight_memory_bytes,
                             kv_cache_bytes, payload_bytes,
                             weight_memory_bytes)
from repro.core.split_optimizer import SplitSearchSpace, optimize_split, psi

L, HD, DMODEL = 32, 4096, 4096
COUNTS = [202 * 10 ** 6] * L  # llama2-7b-ish per-layer params


def test_eq1_weight_memory_endpoints():
    total = sum(COUNTS)
    # split at 0 → everything at back precision; at L → everything at front
    assert weight_memory_bytes(COUNTS, 0, 4, 16) == total * 16 // 8
    assert weight_memory_bytes(COUNTS, L, 4, 16) == total * 4 // 8
    # monotone decreasing in ℓ when front bits < back bits
    vals = [weight_memory_bytes(COUNTS, e, 4, 16) for e in range(L + 1)]
    assert all(a > b for a, b in zip(vals, vals[1:]))


def test_eq2_kv_cache_grows_linearly_in_w():
    b1 = kv_cache_bytes(10, 16, L, HD, 4, 16)
    b2 = kv_cache_bytes(20, 16, L, HD, 4, 16)
    b3 = kv_cache_bytes(30, 16, L, HD, 4, 16)
    assert (b3 - b2) == (b2 - b1)  # linear growth
    assert b1 > 0


def test_eq2_front_bits_reduce_memory():
    hi = kv_cache_bytes(100, 16, L, HD, 16, 16)
    lo = kv_cache_bytes(100, 16, L, HD, 4, 16)
    assert lo < hi


def test_eq3_ikv_switch():
    w = 64
    with_kv = payload_bytes(w, 16, L, HD, DMODEL, 4, 16, i_kv=1)
    without = payload_bytes(w, 16, L, HD, DMODEL, 4, 16, i_kv=0)
    # paper's Eq. (2) case rule: Q_{a,k} = Q_a2 for k ≥ ℓ_w, and the payload
    # is indexed at the split layer itself → back bits
    assert without == w * DMODEL * 16 // 8
    assert with_kv > without  # KV cache across layers dwarfs one hidden state


def test_channel_outage_monotone_in_rate():
    cfg = ChannelConfig()
    rates = [1e5, 1e6, 1e7, 5e7]
    po = [outage_probability(r, cfg) for r in rates]
    assert all(a < b for a, b in zip(po, po[1:]))
    assert 0.0 <= po[0] <= po[-1] <= 1.0


def test_channel_latency_tradeoff_and_rstar():
    cfg = ChannelConfig()
    r_star = optimal_rate(cfg)
    l_star = worst_case_latency(8e6, r_star, cfg)
    for r in (cfg.r_min * 2, r_star / 3, r_star * 3, cfg.r_max / 2):
        assert l_star <= worst_case_latency(8e6, r, cfg) * 1.0001
    assert cfg.r_min <= r_star <= cfg.r_max


@settings(max_examples=20, deadline=None)
@given(snr=st.floats(1.0, 100.0), bw=st.floats(1e6, 50e6))
def test_channel_latency_positive_property(snr, bw):
    cfg = ChannelConfig(bandwidth_hz=bw, snr=snr)
    r = optimal_rate(cfg, n_grid=512)
    assert worst_case_latency(1e6, r, cfg) > 0


def test_eq8_optimizer_respects_constraints():
    budget = 3 * 2 ** 30  # 3 GiB edge
    # accuracy model: quantizing more layers at low bits hurts; back-quant hurts more
    def acc(cfg: OPSCConfig) -> float:
        frac_front = cfg.split_layer / L
        drop = 0.02 * frac_front * (16 - cfg.qw_front) / 12
        drop += 0.001 * (16 - cfg.qa_front) / 14
        return 0.70 - drop

    sol = optimize_split(
        num_layers=L, layer_param_counts=COUNTS, embed_params=131 * 10 ** 6,
        kv_heads_dim=HD, max_tokens=256, memory_budget_bytes=budget,
        accuracy_fn=acc, base_accuracy=0.70, accuracy_drop=0.02,
        space=SplitSearchSpace(split_layers=range(4, L, 4)),
    )
    assert sol is not None
    assert sol.memory_bytes <= budget
    assert sol.accuracy >= 0.70 - 0.02
    # Ψ is the objective: no feasible config with the same search space beats it
    assert sol.psi == psi(L, sol.config.split_layer, sol.config.qa_front, sol.config.qa_back)


def test_eq8_infeasible_returns_none():
    sol = optimize_split(
        num_layers=L, layer_param_counts=COUNTS, embed_params=0, kv_heads_dim=HD,
        max_tokens=256, memory_budget_bytes=1024,  # 1 KiB — impossible
        accuracy_fn=lambda c: 1.0, base_accuracy=0.5, accuracy_drop=0.5,
        space=SplitSearchSpace(split_layers=[8, 16]),
    )
    assert sol is None


def test_early_exit_ladder():
    opsc = OPSCConfig(split_layer=16)
    cfg = ChannelConfig()
    lat = LatencyModel(cfg, optimal_rate(cfg), compute_per_token_s=1e-5)
    payload_fn = default_payload_bits_fn(opsc, L, HD, DMODEL, compression_ratio=6.0)

    def run(deadline):
        return EarlyExitController(opsc, lat, deadline, L, payload_fn).decide(w_max=64)

    generous = run(deadline=1e6)
    assert not generous.exited_early and not generous.compressed and generous.i_kv == 1
    medium = run(deadline=generous.latency_s / 3)
    assert medium.compressed
    tight = run(deadline=1e-4)
    assert tight.exited_early and tight.w < 64 and tight.i_kv == 0
    # escalation never violates the deadline unless fully exhausted (w == 1)
    assert tight.latency_s <= 1e-4 or tight.w == 1
