"""Per-kernel validation: sweep shapes/dtypes, assert_allclose against the
pure-jnp oracles in repro.kernels.ref (kernels run interpret=True on CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.ops import dequant_matmul, tabq_dequantize, tabq_quantize, ts_mask

SHAPES_TD = [(8, 128), (16, 256), (32, 384), (64, 128)]
DTYPES = [jnp.float32, jnp.bfloat16]


def _rand(shape, dtype, seed=0, scale=3.0, outliers=0):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=shape).astype(np.float32) * scale
    if outliers:
        flat = x.reshape(-1)
        idx = rng.choice(flat.size, outliers, replace=False)
        flat[idx] = 80.0 * np.sign(flat[idx])
    return jnp.asarray(x, dtype)


# ------------------------------------------------------------ tabq kernel


@pytest.mark.parametrize("shape", SHAPES_TD)
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("bits", [4, 8])
def test_tabq_kernel_matches_ref(shape, dtype, bits):
    x = _rand(shape, dtype, seed=shape[0] + bits)
    codes, s, z, sign = tabq_quantize(x, bits=bits)
    rc, rs, rz, rsign = ref.tabq_quantize_ref(x, bits)
    np.testing.assert_allclose(np.asarray(s), np.asarray(rs), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(z), np.asarray(rz), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(sign), np.asarray(rsign))
    np.testing.assert_allclose(np.asarray(codes), np.asarray(rc), atol=1)
    # end-to-end dequant error bounded by one step
    out = tabq_dequantize(codes, s, z, sign)
    rout = ref.tabq_dequantize_ref(rc, rs, rz, rsign)
    np.testing.assert_allclose(np.asarray(out), np.asarray(rout),
                               atol=float(jnp.max(s)) * 1.5)


def test_tabq_kernel_block_sweep():
    x = _rand((64, 128), jnp.float32, seed=9)
    outs = [tabq_quantize(x, bits=6, block_t=bt)[0] for bt in (8, 16, 32, 64)]
    for o in outs[1:]:
        np.testing.assert_array_equal(np.asarray(outs[0]), np.asarray(o))


# --------------------------------------------------- dequant matmul kernel


@pytest.mark.parametrize("mnk", [(128, 128, 512), (256, 128, 1024),
                                 (128, 256, 512), (8, 128, 128)])
@pytest.mark.parametrize("dtype", DTYPES)
def test_dequant_matmul_matches_ref(mnk, dtype):
    m, n, k = mnk
    rng = np.random.default_rng(m + n)
    x = _rand((m, k), dtype, seed=m)
    codes = jnp.asarray(rng.integers(-127, 128, (k, n)), jnp.int8)
    scale = jnp.asarray(rng.uniform(0.001, 0.1, (n,)), jnp.float32)
    bm = min(128, m)
    got = dequant_matmul(x, codes, scale, block_m=bm)
    want = ref.dequant_matmul_ref(x, codes, scale)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-2 if dtype == jnp.bfloat16 else 1e-5,
                               atol=1e-3 * float(jnp.max(jnp.abs(want))))


def test_dequant_matmul_block_shapes_agree():
    m, n, k = 256, 256, 1024
    rng = np.random.default_rng(3)
    x = _rand((m, k), jnp.float32, seed=5)
    codes = jnp.asarray(rng.integers(-127, 128, (k, n)), jnp.int8)
    scale = jnp.asarray(rng.uniform(0.001, 0.1, (n,)), jnp.float32)
    base = dequant_matmul(x, codes, scale, 128, 128, 512)
    for bm, bn, bk in [(64, 128, 256), (128, 64, 1024), (256, 256, 512)]:
        out = dequant_matmul(x, codes, scale, bm, bn, bk)
        # different block_k → different f32 summation order
        np.testing.assert_allclose(np.asarray(base), np.asarray(out),
                                   rtol=1e-4, atol=1e-2)


def test_dequant_matmul_equals_quantize_then_matmul():
    """End-to-end: quantize_sym(axis=0) + kernel ≈ full-precision matmul."""
    from repro.core.quant import quantize_sym

    rng = np.random.default_rng(11)
    x = _rand((64, 256), jnp.float32, seed=13, scale=1.0)
    w = jnp.asarray(rng.normal(size=(256, 128)).astype(np.float32))
    qt = quantize_sym(w, 8, axis=0)  # per-out-channel scale (1, N)
    got = dequant_matmul(x, qt.codes, qt.scale[0], block_k=256)
    want = x @ w
    rel = float(jnp.linalg.norm(got - want) / jnp.linalg.norm(want))
    assert rel < 0.01


# ----------------------------------------------------------- ts_mask kernel


@pytest.mark.parametrize("shape", SHAPES_TD)
@pytest.mark.parametrize("tau", [1.0, 5.0, 50.0])
def test_ts_mask_matches_ref(shape, tau):
    x = _rand(shape, jnp.float32, seed=int(tau) + shape[1], outliers=6)
    below, mask, counts = ts_mask(x, tau)
    rbelow, rmask, rcount = ref.ts_mask_ref(x, tau)
    np.testing.assert_allclose(np.asarray(below), np.asarray(rbelow), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(mask), np.asarray(rmask))
    assert int(jnp.sum(counts)) == int(rcount)


def test_ts_mask_counts_per_tile():
    x = jnp.zeros((16, 128))
    x = x.at[0, 0].set(100.0).at[9, 5].set(-100.0)
    below, mask, counts = ts_mask(x, tau=50.0, block_t=8)
    assert counts.shape == (2, 1)
    assert int(counts[0, 0]) == 1 and int(counts[1, 0]) == 1


# ----------------------------------------------- decode attention kernel


@pytest.mark.parametrize("s,bs", [(64, 64), (128, 32), (256, 64),
                                  (80, 32), (200, 64)])  # s % bs != 0 → the
# trailing block is padded in-kernel and masked via kv_pos = -1
@pytest.mark.parametrize("g,kh", [(4, 2), (6, 1), (1, 4)])
def test_decode_attention_matches_ref(s, bs, g, kh):
    from repro.kernels.ops import decode_attention

    rng = np.random.default_rng(s + g)
    b, hd = 2, 64
    q = jnp.asarray(rng.normal(size=(b, kh, g, hd)), jnp.float32)
    kc = jnp.asarray(rng.integers(-127, 128, (b, kh, s, hd)), jnp.int8)
    vc = jnp.asarray(rng.integers(-127, 128, (b, kh, s, hd)), jnp.int8)
    ks = jnp.asarray(rng.uniform(0.005, 0.02, (b, kh, s)), jnp.float32)
    vs = jnp.asarray(rng.uniform(0.005, 0.02, (b, kh, s)), jnp.float32)
    # half-filled cache with a ring-style hole
    pos = np.arange(s)[None].repeat(b, 0)
    pos[:, s // 2:] = -1
    kv_pos = jnp.asarray(pos, jnp.int32)
    q_pos = jnp.int32(s)

    got = decode_attention(q, kc, ks, vc, vs, kv_pos, q_pos, block_s=bs)
    want = ref.decode_attention_ref(q, kc, ks, vc, vs, kv_pos, q_pos)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.kernel_grid
@pytest.mark.parametrize("s,bs", [(320, 64), (512, 128), (96, 32),
                                  (130, 64), (33, 32)])
@pytest.mark.parametrize("g,kh", [(8, 2), (2, 6), (1, 1)])
@pytest.mark.parametrize("hd", [32, 128])
def test_decode_attention_extended_grid(s, bs, g, kh, hd):
    """Deep-CI sweep (``-m kernel_grid``): cache lengths, GQA ratios and
    head dims beyond the tier-1 grid, including bs-misaligned and
    single-block caches. Tier-1 keeps its own smaller grid — this is
    additive coverage, not a relocation."""
    from repro.kernels.ops import decode_attention

    rng = np.random.default_rng(s * 7 + g + hd)
    b = 2
    q = jnp.asarray(rng.normal(size=(b, kh, g, hd)), jnp.float32)
    kc = jnp.asarray(rng.integers(-127, 128, (b, kh, s, hd)), jnp.int8)
    vc = jnp.asarray(rng.integers(-127, 128, (b, kh, s, hd)), jnp.int8)
    ks = jnp.asarray(rng.uniform(0.005, 0.02, (b, kh, s)), jnp.float32)
    vs = jnp.asarray(rng.uniform(0.005, 0.02, (b, kh, s)), jnp.float32)
    pos = np.arange(s)[None].repeat(b, 0)
    pos[:, (3 * s) // 4:] = -1  # ring-style hole in the tail
    kv_pos = jnp.asarray(pos, jnp.int32)
    got = decode_attention(q, kc, ks, vc, vs, kv_pos, jnp.int32(s),
                           block_s=bs)
    want = ref.decode_attention_ref(q, kc, ks, vc, vs, kv_pos, jnp.int32(s))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_decode_attention_causal_bound():
    from repro.kernels.ops import decode_attention

    rng = np.random.default_rng(0)
    b, kh, g, hd, s = 1, 1, 2, 32, 64
    q = jnp.asarray(rng.normal(size=(b, kh, g, hd)), jnp.float32)
    kc = jnp.asarray(rng.integers(-127, 128, (b, kh, s, hd)), jnp.int8)
    vc = jnp.asarray(rng.integers(-127, 128, (b, kh, s, hd)), jnp.int8)
    ks = vs = jnp.full((b, kh, s), 0.01, jnp.float32)
    kv_pos = jnp.asarray(np.arange(s)[None], jnp.int32)
    # attending at q_pos=10 must ignore slots with pos > 10: perturbing them
    # cannot change the output
    out1 = decode_attention(q, kc, ks, vc, vs, kv_pos, jnp.int32(10), block_s=32)
    vc2 = vc.at[:, :, 20:].set(100)
    out2 = decode_attention(q, kc, ks, vc2, vs, kv_pos, jnp.int32(10), block_s=32)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), rtol=1e-6)
