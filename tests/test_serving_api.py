"""The request-level serving API (``serving.api``): greedy-equivalence
regression across all three backends, seeded fused/paged sampling parity,
stop-token and abort() mid-stream behavior, the streaming-order
invariant, one-compiled-shape sampling on the paged backend, and the
adaptive prefill chunk ladder."""

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.opsc import OPSCConfig
from repro.core.sampling import SamplingParams
from repro.models.transformer import RuntimeOpts, init_params
from repro.serving import Engine, LLMServer, Scheduler, SplitEngine

OPTS_Q = RuntimeOpts(q_chunk=16, kv_chunk=16, remat=False, quantized_kv=True,
                     moe_capacity_factor=0.0)
OPTS = RuntimeOpts(q_chunk=16, kv_chunk=16, remat=False,
                   moe_capacity_factor=0.0)


@pytest.fixture(scope="module")
def tiny_model():
    cfg = get_config("llama2-7b").tiny()
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _paged(cfg, params, **kw):
    kw.setdefault("num_pages", 24)
    kw.setdefault("page_size", 4)
    kw.setdefault("max_slots", 3)
    return LLMServer(cfg, params, OPTS_Q, backend="paged", **kw)


# --------------------------------------------------- greedy equivalence


def test_default_params_reproduce_greedy_on_all_backends(tiny_model):
    """Satellite regression: ``SamplingParams()`` defaults must reproduce
    the pre-API greedy outputs BIT FOR BIT on fused, paged and split."""
    cfg, params = tiny_model
    rng = np.random.default_rng(0)
    p = rng.integers(0, cfg.vocab_size, (6,))
    want_q = Engine(cfg, params, OPTS_Q, cache_len=32).generate(
        p[None], 5).tokens[0]
    sp = SamplingParams(max_tokens=5)

    rid = (srv := _paged(cfg, params)).submit(p, sp)
    np.testing.assert_array_equal(srv.run()[rid].full_tokens, want_q)

    srv = LLMServer(cfg, params, OPTS_Q, backend="fused", cache_len=32)
    rid = srv.submit(p, sp)
    np.testing.assert_array_equal(srv.run()[rid].full_tokens, want_q)

    # split: reference is the legacy SplitEngine greedy run itself
    opsc = OPSCConfig(split_layer=1, qw_front=16, i_kv=1)
    want_split, _ = SplitEngine(cfg, params, opsc, opts=OPTS,
                                cache_len=32).generate(p[None], 5,
                                                       compress=False)
    srv = LLMServer(cfg, params, OPTS, backend="split", opsc=opsc,
                    compress=False, cache_len=32)
    rid = srv.submit(p, sp)
    out = srv.run()[rid]
    np.testing.assert_array_equal(out.full_tokens, want_split[0])
    assert out.split_stats is not None
    assert out.split_stats.uplink_bits_eq3 > 0
    # and the unchanged legacy surfaces still agree with themselves
    np.testing.assert_array_equal(
        Engine(cfg, params, OPTS_Q, cache_len=32).generate(p[None], 5).tokens[0],
        want_q)


# ----------------------------------------------------- sampling parity


def test_seeded_sampling_parity_paged_vs_fused(tiny_model):
    """Same per-request seeds ⇒ same tokens: a ragged non-greedy batch
    through the paged scheduler equals per-request fused generation."""
    cfg, params = tiny_model
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab_size, (n,)) for n in (5, 8, 3)]
    sps = [SamplingParams(max_tokens=6, temperature=0.9, seed=7),
           SamplingParams(max_tokens=5, temperature=1.2, top_k=4, seed=11),
           SamplingParams(max_tokens=7, temperature=0.8, top_p=0.85, seed=13)]
    eng = Engine(cfg, params, OPTS_Q, cache_len=32)
    want = [eng.generate_requests(p[None], sp).tokens[0]
            for p, sp in zip(prompts, sps)]

    srv = _paged(cfg, params)
    rids = [srv.submit(p, sp) for p, sp in zip(prompts, sps)]
    outs = srv.run()
    for rid, w in zip(rids, want):
        np.testing.assert_array_equal(outs[rid].full_tokens, w)


def test_paged_sampling_is_one_compiled_shape(tiny_model):
    """Acceptance: the paged backend serves any mix of SamplingParams
    through the SAME compiled shapes as an all-greedy run — the knobs are
    traced operands, never compile keys."""
    cfg, params = tiny_model
    rng = np.random.default_rng(2)
    prompts = [rng.integers(0, cfg.vocab_size, (n,)) for n in (5, 7)]

    def serve(sps):
        srv = _paged(cfg, params, max_slots=2)
        for p, sp in zip(prompts, sps):
            srv.submit(p, sp)
        srv.run()
        return srv.backend.scheduler.stats.compiled_shapes

    greedy = serve([SamplingParams(max_tokens=4)] * 2)
    mixed = serve([SamplingParams(max_tokens=4, temperature=1.0, seed=3),
                   SamplingParams(max_tokens=4, top_k=5, temperature=0.7,
                                  top_p=0.9, seed=4)])
    assert mixed == greedy


# ------------------------------------------------- stop tokens & abort


def test_stop_token_finishes_midstream_paged(tiny_model):
    """A stop-set token ends the request the tick it is sampled: truncated
    output, reason "stop", fewer decode events than max_tokens."""
    cfg, params = tiny_model
    rng = np.random.default_rng(3)
    p = rng.integers(0, cfg.vocab_size, (5,))
    free = Engine(cfg, params, OPTS_Q, cache_len=32).generate(
        p[None], 8).tokens[0]
    stop = int(free[5 + 3])  # the 4th generated token

    srv = _paged(cfg, params)
    rid = srv.submit(p, SamplingParams(max_tokens=8, stop_token_ids=(stop,)))
    events = list(srv.stream())
    out = srv.outputs()[rid]
    assert out.finish_reason == "stop"
    assert out.tokens[-1] == stop and out.tokens.shape[0] == 4
    np.testing.assert_array_equal(out.full_tokens, free[: 5 + 4])
    token_events = [e for e in events if not e.finished]
    assert len(token_events) == 4  # nothing streamed past the stop


def test_abort_midstream_paged(tiny_model):
    """abort() mid-stream cancels one request in place: its partial output
    carries reason "abort", its co-tenant finishes and still matches the
    per-request engine bit-for-bit, and the pool fully reclaims."""
    cfg, params = tiny_model
    rng = np.random.default_rng(4)
    a = rng.integers(0, cfg.vocab_size, (5,))
    b = rng.integers(0, cfg.vocab_size, (5,))
    srv = _paged(cfg, params, max_slots=2)
    ra = srv.submit(a, SamplingParams(max_tokens=10))
    rb = srv.submit(b, SamplingParams(max_tokens=6))
    aborted = False
    for ev in srv.stream():
        if not aborted and ev.rid == ra and not ev.finished and ev.index >= 1:
            assert srv.abort(ra)
            aborted = True
    outs = srv.outputs()
    assert outs[ra].finish_reason == "abort"
    assert 1 <= outs[ra].tokens.shape[0] < 10  # cut mid-generation
    eng = Engine(cfg, params, OPTS_Q, cache_len=32)
    np.testing.assert_array_equal(outs[rb].full_tokens,
                                  eng.generate(b[None], 6).tokens[0])
    sched = srv.backend.scheduler
    assert sched.stats.aborted == 1
    assert sched.pool.pages_in_use == 0
    assert not srv.abort(ra)  # already finished — not retractable


def test_abort_on_fused_backend_cuts_stream(tiny_model):
    """Replay backends too: abort mid-replay keeps the streamed prefix and
    emits a finish marker with reason "abort"."""
    cfg, params = tiny_model
    rng = np.random.default_rng(10)
    p = rng.integers(0, cfg.vocab_size, (4,))
    srv = LLMServer(cfg, params, OPTS_Q, backend="fused", cache_len=32)
    rid = srv.submit(p, SamplingParams(max_tokens=6))
    events = list(srv.backend.step())  # computes + streams token 0
    assert [e.index for e in events if e.rid == rid] == [0]
    assert srv.abort(rid)
    tail = list(srv.stream())
    assert [(e.finished, e.finish_reason) for e in tail if e.rid == rid] \
        == [(True, "abort")]
    out = srv.outputs()[rid]
    assert out.finish_reason == "abort" and out.tokens.shape[0] == 1
    assert not srv.pending


def test_abort_queued_request_never_runs(tiny_model):
    cfg, params = tiny_model
    rng = np.random.default_rng(5)
    srv = _paged(cfg, params, max_slots=1, num_pages=8)
    ra = srv.submit(rng.integers(0, cfg.vocab_size, (4,)),
                    SamplingParams(max_tokens=3))
    rb = srv.submit(rng.integers(0, cfg.vocab_size, (4,)),
                    SamplingParams(max_tokens=3))
    assert srv.abort(rb)  # still queued behind ra
    outs = srv.run()
    assert outs[rb].finish_reason == "abort"
    assert outs[rb].tokens.shape[0] == 0
    assert outs[ra].finish_reason == "length"


# --------------------------------------------------- streaming invariant


@pytest.mark.parametrize("backend", ["paged", "fused"])
def test_streaming_order_invariant(tiny_model, backend):
    """Per request, token events arrive in strict position order 0,1,2,…;
    concurrent requests interleave (both backends run them together)."""
    cfg, params = tiny_model
    rng = np.random.default_rng(6)
    prompts = [rng.integers(0, cfg.vocab_size, (4,)) for _ in range(3)]
    if backend == "paged":
        srv = _paged(cfg, params)
    else:
        srv = LLMServer(cfg, params, OPTS_Q, backend="fused", cache_len=32)
    rids = [srv.submit(p, SamplingParams(max_tokens=5, seed=i))
            for i, p in enumerate(prompts)]
    events = list(srv.stream())
    seen = {r: [] for r in rids}
    for ev in events:
        if not ev.finished:
            seen[ev.rid].append(ev.index)
    for r in rids:
        assert seen[r] == list(range(5))  # strict position order
    # interleaving: some other request's token lands between one request's
    # consecutive tokens
    order = [ev.rid for ev in events if not ev.finished]
    assert any(order[i] != order[i + 1] for i in range(len(order) - 1))
    # every request ends with exactly one finish marker
    fins = [ev for ev in events if ev.finished]
    assert sorted(ev.rid for ev in fins) == sorted(rids)
    assert all(ev.token == -1 and ev.finish_reason == "length"
               for ev in fins)


# ------------------------------------------------- adaptive chunk ladder


def test_adaptive_chunk_matches_engine_and_adapts(tiny_model):
    """``prefill_chunk`` ladder: outputs stay bit-identical to the engine
    while the per-tick chunk genuinely moves — large while the batch is
    prefill-heavy, small once decode slots dominate."""
    cfg, params = tiny_model
    rng = np.random.default_rng(7)
    long_p = rng.integers(0, cfg.vocab_size, (24,))
    shorts = [rng.integers(0, cfg.vocab_size, (4,)) for _ in range(2)]
    sched = Scheduler(cfg, params, OPTS_Q, num_pages=24, page_size=4,
                      max_slots=3, prefill_chunk=(2, 4, 8))
    rids = [sched.submit(long_p, 4)] + [sched.submit(p, 8) for p in shorts]
    results = sched.run()
    eng = Engine(cfg, params, OPTS_Q, cache_len=64)
    for rid, (p, mn) in zip(rids, [(long_p, 4)] + [(p, 8) for p in shorts]):
        np.testing.assert_array_equal(results[rid],
                                      eng.generate(p[None], mn).tokens[0])
    picks = sched.stats.auto_chunks
    assert len(picks) >= 2, picks  # the ladder was actually walked
    assert 8 in picks  # prefill-heavy start took the big rung
    assert 2 in picks  # decode-dominated tail shrank the chunk
    # compile count stays bounded by the ladder, not the prompt mix
    assert sched.stats.compiled_shapes <= 2 + 2 * 3  # decode+prefill rungs


def test_latency_hint_interactive_forces_smallest_chunk(tiny_model):
    """A decoding request with latency_hint="interactive" pins the chunk
    to the smallest rung even when the batch is otherwise balanced."""
    cfg, params = tiny_model
    rng = np.random.default_rng(8)
    short = rng.integers(0, cfg.vocab_size, (3,))
    long_p = rng.integers(0, cfg.vocab_size, (16,))

    def serve(hint):
        sched = Scheduler(cfg, params, OPTS_Q, num_pages=24, page_size=4,
                          max_slots=2, prefill_chunk=(2, 4, 8))
        sched.submit(short, sampling=SamplingParams(
            max_tokens=10, latency_hint=hint))
        sched.submit(long_p, 3)
        sched.run()
        return sched.stats.auto_chunks

    with_hint = serve("interactive")
    without = serve("balanced")
    assert 2 in with_hint  # interactive decode pulled the smallest rung
    assert 2 not in without  # balanced mix never needed it


# ----------------------------------------------------------- facade misc


def test_llm_server_rejects_unknown_backend(tiny_model):
    cfg, params = tiny_model
    with pytest.raises(ValueError, match="backend"):
        LLMServer(cfg, params, OPTS_Q, backend="warp")
    with pytest.raises(ValueError, match="opsc"):
        LLMServer(cfg, params, OPTS_Q, backend="split")


def test_llm_server_rejects_batched_prompt(tiny_model):
    """A (B, S) matrix must NOT silently flatten into one long prompt —
    the Engine.generate migration accident."""
    cfg, params = tiny_model
    srv = _paged(cfg, params)
    with pytest.raises(ValueError, match="one request per row"):
        srv.submit(np.ones((4, 16), np.int32))


def test_scheduler_submit_rejects_mixed_forms(tiny_model):
    cfg, params = tiny_model
    sched = Scheduler(cfg, params, OPTS_Q, num_pages=8, page_size=4,
                      max_slots=1)
    with pytest.raises(ValueError, match="not both"):
        sched.submit(np.ones(3, np.int32), 4,
                     sampling=SamplingParams(max_tokens=4))
    with pytest.raises(ValueError, match="max_new_tokens or sampling"):
        sched.submit(np.ones(3, np.int32))


@pytest.mark.parametrize("backend", ["paged", "fused"])
def test_release_drops_finished_outputs(tiny_model, backend):
    """release(rid) frees a consumed result (long-lived-server memory
    valve); unknown or live rids are refused."""
    cfg, params = tiny_model
    rng = np.random.default_rng(11)
    p = rng.integers(0, cfg.vocab_size, (4,))
    srv = _paged(cfg, params) if backend == "paged" else \
        LLMServer(cfg, params, OPTS_Q, backend="fused", cache_len=32)
    rid = srv.submit(p, SamplingParams(max_tokens=3))
    assert not srv.release(rid)  # not finished yet
    srv.run()
    assert rid in srv.outputs()
    assert srv.release(rid)
    assert rid not in srv.outputs()
    assert not srv.release(rid)  # already gone


def test_fused_backend_mixed_lengths_and_stop(tiny_model):
    """The fused backend groups ragged prompts by length, honors per-row
    max_tokens, and truncates at per-request stop tokens."""
    cfg, params = tiny_model
    rng = np.random.default_rng(9)
    p1 = rng.integers(0, cfg.vocab_size, (5,))
    p2 = rng.integers(0, cfg.vocab_size, (8,))
    eng = Engine(cfg, params, OPTS_Q, cache_len=32)
    free1 = eng.generate(p1[None], 6).tokens[0]
    stop = int(free1[5 + 1])  # second generated token
    srv = LLMServer(cfg, params, OPTS_Q, backend="fused", cache_len=32)
    r1 = srv.submit(p1, SamplingParams(max_tokens=6, stop_token_ids=(stop,)))
    r2 = srv.submit(p2, SamplingParams(max_tokens=3))
    outs = srv.run()
    assert outs[r1].finish_reason == "stop"
    np.testing.assert_array_equal(outs[r1].full_tokens, free1[: 5 + 2])
    np.testing.assert_array_equal(outs[r2].full_tokens,
                                  eng.generate(p2[None], 3).tokens[0])


# ------------------------------------- speculative multi-token emission


def test_speculative_multi_token_events_ordered_across_backends(tiny_model):
    """A verify round emits SEVERAL tokens at once — the API must still
    stream ``TokenEvent``s in strict index order, with each token's
    logprob taken from the VERIFY logits: on the paged backend those match
    the non-speculative run's decode logprobs to float32 round-off (the
    verify reads the same quantized cache a sequential decode would; the
    batched (1+k)-row head matmul may differ in the last ULP)."""
    cfg, params = tiny_model
    rng = np.random.default_rng(13)
    p = np.tile(rng.integers(0, cfg.vocab_size, (3,)), 3)  # repetitive:
    #            prompt-lookup drafts land, so multi-token bursts occur
    sp = SamplingParams(max_tokens=6, speculate_k=3)

    def stream_tokens(srv, sp_):
        rid = srv.submit(p, sp_)
        evs = [e for e in srv.stream() if e.rid == rid and not e.finished]
        return rid, evs

    # paged: speculation on vs off — same tokens, same RAW-model logprobs
    _, evs0 = stream_tokens(_paged(cfg, params), SamplingParams(max_tokens=6))
    srv = _paged(cfg, params, speculate_k=3)
    _, evs = stream_tokens(srv, sp)
    assert srv.backend.scheduler.stats.spec_accepted > 0  # bursts happened
    assert [e.index for e in evs] == list(range(6))
    assert [e.token for e in evs] == [e.token for e in evs0]
    np.testing.assert_allclose(
        np.asarray([e.logprob for e in evs], np.float32),
        np.asarray([e.logprob for e in evs0], np.float32),
        rtol=0, atol=1e-6)

    # fused: no incremental tick to amortize — speculate_k is documented
    # as ignored, never an error; ordering and tokens unchanged
    srv = LLMServer(cfg, params, OPTS_Q, backend="fused", cache_len=32)
    _, evs_f = stream_tokens(srv, sp)
    assert [e.index for e in evs_f] == list(range(6))
    assert [e.token for e in evs_f] == [e.token for e in evs0]

    # split: one k-token uplink per round — events stay index-ordered with
    # per-token logprobs, and the carried SplitStats show the amortization
    opsc = OPSCConfig(split_layer=1, qw_front=16, i_kv=1)

    def split_srv():
        return LLMServer(cfg, params, OPTS, backend="split", opsc=opsc,
                         compress=False, cache_len=32)

    _, evs_ref = stream_tokens(split_srv(), SamplingParams(max_tokens=6))
    srv = split_srv()
    rid, evs_s = stream_tokens(srv, sp)
    assert [e.index for e in evs_s] == list(range(len(evs_s)))
    assert [e.token for e in evs_s] == [e.token for e in evs_ref]
    assert all(e.logprob is not None and np.isfinite(e.logprob)
               for e in evs_s)
    st = srv.outputs()[rid].split_stats
    assert st.spec_rounds > 0 and st.spec_drafted > 0
    # never MORE trips than tokens; the strict amortization (with real
    # acceptance) is pinned in test_serving.py and the benchmark
    assert st.uplink_round_trips <= len(evs_s)
