"""Continuous-batching scheduler: greedy parity with per-request
``Engine.generate`` while ragged requests are admitted and evicted
mid-stream from ONE shared pool; queueing/backpressure; EOS eviction; full
pool reclamation after drain."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.sampling import SamplingParams
from repro.models.transformer import RuntimeOpts, init_params
from repro.serving.engine import Engine
from repro.serving.scheduler import Scheduler

OPTS_Q = RuntimeOpts(q_chunk=16, kv_chunk=16, remat=False, quantized_kv=True,
                     moe_capacity_factor=0.0)


@pytest.fixture(scope="module")
def tiny_model():
    cfg = get_config("llama2-7b").tiny()
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def test_scheduler_matches_engine_with_midstream_admission(tiny_model):
    """Acceptance: 5 ragged requests through 3 slots — mid-stream admission
    and eviction, a single shared pool — must produce IDENTICAL greedy
    tokens to the per-request Engine over the same quantized-cache setup."""
    cfg, params = tiny_model
    rng = np.random.default_rng(0)
    jobs = [(5, 6), (8, 3), (3, 9), (6, 4), (2, 7)]  # (prompt_len, max_new)
    prompts = [rng.integers(0, cfg.vocab_size, (n,)) for n, _ in jobs]

    sched = Scheduler(cfg, params, OPTS_Q, num_pages=24, page_size=4,
                      max_slots=3)
    rids = [sched.submit(p, mn) for p, (_, mn) in zip(prompts, jobs)]
    results = sched.run()

    assert sched.stats.admitted == 5 and sched.stats.evicted == 5
    assert sched.stats.prefills >= 2  # queue drained in waves, not one batch
    eng = Engine(cfg, params, OPTS_Q, cache_len=32)
    for rid, p, (_, mn) in zip(rids, prompts, jobs):
        want = eng.generate(p[None], mn).tokens[0]
        np.testing.assert_array_equal(results[rid], want)


def test_scheduler_pool_fully_reclaimed(tiny_model):
    cfg, params = tiny_model
    sched = Scheduler(cfg, params, OPTS_Q, num_pages=16, page_size=4,
                      max_slots=2)
    rng = np.random.default_rng(1)
    for n, mn in [(4, 3), (7, 2), (2, 5)]:
        sched.submit(rng.integers(0, cfg.vocab_size, (n,)), mn)
    sched.run()
    assert sched.pool.pages_in_use == 0
    assert not sched.pool.active.any()
    assert sched.pool.occupancy() == 0.0
    assert sched.stats.peak_occupancy > 0.0
    assert sched.stats.peak_eq2_bytes > 0


def test_scheduler_backpressure_queues_oversized_wave(tiny_model):
    """A pool that only fits one request at a time still serves all of them
    — later submissions wait in the queue instead of failing."""
    cfg, params = tiny_model
    # 6 usable pages of 4 slots; each request needs 2-3 pages incl. headroom
    sched = Scheduler(cfg, params, OPTS_Q, num_pages=7, page_size=4,
                      max_slots=2)
    rng = np.random.default_rng(2)
    prompts = [rng.integers(0, cfg.vocab_size, (8,)) for _ in range(3)]
    rids = [sched.submit(p, 3) for p in prompts]
    results = sched.run()
    assert len(results) == 3
    assert sched.stats.prefills >= 2  # memory forced at least two waves
    assert sched.stats.peak_occupancy == 1.0  # the pool really saturated
    eng = Engine(cfg, params, OPTS_Q, cache_len=32)
    for rid, p in zip(rids, prompts):
        np.testing.assert_array_equal(results[rid],
                                      eng.generate(p[None], 3).tokens[0])


def test_scheduler_eos_evicts_early(tiny_model):
    """An EOS-terminated request frees its slot for the queue: pick the
    token the model actually emits first as the EOS id, and require the
    result to be truncated at it."""
    cfg, params = tiny_model
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, cfg.vocab_size, (5,))
    eng = Engine(cfg, params, OPTS_Q, cache_len=32)
    free_run = eng.generate(prompt[None], 6).tokens[0]
    eos = int(free_run[5 + 2])  # the 3rd generated token

    sched = Scheduler(cfg, params, OPTS_Q, num_pages=16, page_size=4,
                      max_slots=2)
    rid = sched.submit(prompt, 6, eos_id=eos)
    results = sched.run()
    got = results[rid]
    assert got[-1] == eos and got.size == 5 + 3  # truncated at EOS
    np.testing.assert_array_equal(got, free_run[: 5 + 3])


def test_scheduler_impossible_request_fails_loudly(tiny_model):
    """A request whose worst case exceeds the whole pool raises instead of
    spinning the run loop forever."""
    from repro.serving.kv_pool import PoolExhaustedError

    cfg, params = tiny_model
    sched = Scheduler(cfg, params, OPTS_Q, num_pages=4, page_size=4,
                      max_slots=2)  # 3 usable pages = 12 tokens
    rng = np.random.default_rng(5)
    sched.submit(rng.integers(0, cfg.vocab_size, (10,)), 8)  # needs 18
    with pytest.raises(PoolExhaustedError, match="never be admitted"):
        sched.run()


def test_scheduler_single_token_requests(tiny_model):
    """max_new_tokens=1 finishes on its prefill logits — no decode step."""
    cfg, params = tiny_model
    sched = Scheduler(cfg, params, OPTS_Q, num_pages=16, page_size=4,
                      max_slots=2)
    rng = np.random.default_rng(4)
    p = rng.integers(0, cfg.vocab_size, (6,))
    rid = sched.submit(p, 1)
    results = sched.run()
    eng = Engine(cfg, params, OPTS_Q, cache_len=32)
    np.testing.assert_array_equal(results[rid], eng.generate(p[None], 1).tokens[0])
    assert sched.stats.steps == 0  # finished at prefill, never decoded


def test_prefix_sharing_matches_engine_and_saves_pool_bytes(tiny_model):
    """Acceptance: requests attached to a shared 10-token prefix (page 4 →
    partial boundary page, so the CoW path runs) produce greedy tokens
    IDENTICAL to the per-request Engine, while the pool's physical peak is
    LOWER than the same workload served without sharing."""
    cfg, params = tiny_model
    rng = np.random.default_rng(7)
    prefix = rng.integers(0, cfg.vocab_size, (10,))
    jobs = [(3, 3), (2, 4), (4, 2), (3, 3)]  # (suffix_len, max_new)
    prompts = [np.concatenate([prefix,
                               rng.integers(0, cfg.vocab_size, (n,))])
               for n, _ in jobs]

    def serve(shared: bool):
        sched = Scheduler(cfg, params, OPTS_Q, num_pages=32, page_size=4,
                          max_slots=2)
        # only the key's FIRST submit declares prefix_len; later submits
        # (ragged prompt lengths) inherit the registered length
        rids = [sched.submit(p, mn,
                             prefix_key="sys" if shared else None,
                             prefix_len=10 if i == 0 else None)
                for i, (p, (_, mn)) in enumerate(zip(prompts, jobs))]
        return sched, rids, sched.run()

    sched, rids, results = serve(shared=True)
    base, _, base_results = serve(shared=False)
    eng = Engine(cfg, params, OPTS_Q, cache_len=32)
    for rid, p, (_, mn) in zip(rids, prompts, jobs):
        want = eng.generate(p[None], mn).tokens[0]
        np.testing.assert_array_equal(results[rid], want)
        np.testing.assert_array_equal(base_results[rid], want)
    assert sched.stats.prefix_forks >= 2  # later requests really attached
    assert sched.stats.peak_shared_pages > 0
    assert sched.stats.peak_pool_bytes < base.stats.peak_pool_bytes
    # drained: pinned prefix released, every page home again
    assert sched.pool.pages_in_use == 0 and not sched.pool.active.any()


@pytest.mark.parametrize("resume", ["swap", "refill"])
def test_preemption_lazy_growth_matches_engine(tiny_model, resume):
    """Acceptance: lazy admission over a pool too small for every request's
    worst case — growth exhausts the pool mid-decode, the lowest-priority
    request is evicted to the queue and later RESUMED (bit-identical page
    restore by default; re-prefill also matches on this workload) — and
    every result is identical to the isolated Engine run."""
    cfg, params = tiny_model
    rng = np.random.default_rng(11)
    jobs = [(6, 8, 1), (5, 9, 0), (4, 8, 0)]  # (prompt, max_new, priority)
    prompts = [rng.integers(0, cfg.vocab_size, (n,)) for n, _, _ in jobs]
    # 8 usable pages: prompts alone need 2+2+1, worst cases need 4+4+3
    sched = Scheduler(cfg, params, OPTS_Q, num_pages=9, page_size=4,
                      max_slots=3, lazy_growth=True, resume=resume)
    rids = [sched.submit(p, mn, priority=pr)
            for p, (_, mn, pr) in zip(prompts, jobs)]
    results = sched.run()
    assert sched.stats.preemptions >= 1  # the pool really forced eviction
    eng = Engine(cfg, params, OPTS_Q, cache_len=32)
    for rid, p, (_, mn, _) in zip(rids, prompts, jobs):
        np.testing.assert_array_equal(results[rid],
                                      eng.generate(p[None], mn).tokens[0])
    assert sched.pool.pages_in_use == 0  # preempt/resume leaked nothing


def test_preemption_victim_is_lowest_priority(tiny_model):
    """Victim selection: the priority-0 request is evicted (and resumed),
    the priority-1 request admitted at the same time never is."""
    cfg, params = tiny_model
    rng = np.random.default_rng(13)
    hi = rng.integers(0, cfg.vocab_size, (5,))
    lo = rng.integers(0, cfg.vocab_size, (5,))
    sched = Scheduler(cfg, params, OPTS_Q, num_pages=6, page_size=4,
                      max_slots=2, lazy_growth=True)
    rid_hi = sched.submit(hi, 8, priority=1)
    rid_lo = sched.submit(lo, 8, priority=0)
    results = sched.run()
    assert sched.stats.preemptions >= 1
    eng = Engine(cfg, params, OPTS_Q, cache_len=32)
    np.testing.assert_array_equal(results[rid_hi],
                                  eng.generate(hi[None], 8).tokens[0])
    np.testing.assert_array_equal(results[rid_lo],
                                  eng.generate(lo[None], 8).tokens[0])


def test_shared_prefix_with_preemption_roundtrip(tiny_model):
    """The full tentpole combination: forked requests under lazy growth get
    preempted, re-fork on resume (their prefix stays pinned), and still
    match the Engine bit-for-bit."""
    cfg, params = tiny_model
    rng = np.random.default_rng(17)
    prefix = rng.integers(0, cfg.vocab_size, (8,))  # 2 full pages
    jobs = [(2, 6), (3, 6), (2, 6)]
    prompts = [np.concatenate([prefix,
                               rng.integers(0, cfg.vocab_size, (n,))])
               for n, _ in jobs]
    sched = Scheduler(cfg, params, OPTS_Q, num_pages=8, page_size=4,
                      max_slots=3, lazy_growth=True)
    rids = [sched.submit(p, mn, prefix_key="sys", prefix_len=8)
            for p, (_, mn) in zip(prompts, jobs)]
    results = sched.run()
    assert sched.stats.prefix_forks >= 2
    assert sched.stats.preemptions >= 1
    eng = Engine(cfg, params, OPTS_Q, cache_len=32)
    for rid, p, (_, mn) in zip(rids, prompts, jobs):
        np.testing.assert_array_equal(results[rid],
                                      eng.generate(p[None], mn).tokens[0])
    assert sched.pool.pages_in_use == 0


def test_prefix_mismatch_rejected(tiny_model):
    cfg, params = tiny_model
    sched = Scheduler(cfg, params, OPTS_Q, num_pages=16, page_size=4,
                      max_slots=2)
    rng = np.random.default_rng(19)
    a = rng.integers(0, cfg.vocab_size, (8,))
    b = a.copy()
    b[2] = (b[2] + 1) % cfg.vocab_size
    sched.submit(a, 2, prefix_key="k", prefix_len=6)
    with pytest.raises(ValueError, match="does not match"):
        sched.submit(b, 2, prefix_key="k", prefix_len=6)


def test_swap_snapshot_excludes_speculative_append(tiny_model):
    """Regression: slot 0 runs its speculative append for the tick, then
    slot 1's append exhausts the pool and preempts slot 0 — the snapshot
    must cover only WRITTEN positions (the pending token's position holds
    no KV yet), or the restore carries a permanent pos=-1 hole and the
    resumed decode diverges. Both results must match the Engine exactly."""
    cfg, params = tiny_model
    rng = np.random.default_rng(0)
    a = rng.integers(0, cfg.vocab_size, (5,))  # slot 0, preemption victim
    b = rng.integers(0, cfg.vocab_size, (5,))
    # 5 usable pages: both admit at 2 pages (prompt 5 + 1 headroom), slot 0
    # grabs the 5th page at length 9, slot 1's matching append exhausts →
    # victim is slot 0 (priority), AFTER its own append already landed
    sched = Scheduler(cfg, params, OPTS_Q, num_pages=6, page_size=4,
                      max_slots=2, lazy_growth=True, resume="swap")
    ra = sched.submit(a, 8, priority=0)
    rb = sched.submit(b, 8, priority=1)
    results = sched.run()
    assert sched.stats.preemptions >= 1
    eng = Engine(cfg, params, OPTS_Q, cache_len=32)
    np.testing.assert_array_equal(results[ra],
                                  eng.generate(a[None], 8).tokens[0])
    np.testing.assert_array_equal(results[rb],
                                  eng.generate(b[None], 8).tokens[0])


# ----------------------------------------------- split-boundary speculation


def _repetitive_prompts(cfg, n=4, seed=7):
    """Prompts with a repeating 3-gram: prompt-lookup drafting has signal,
    so accepted bursts actually occur (the random-init model still rejects
    plenty — both accept and rollback paths run)."""
    rng = np.random.default_rng(seed)
    return [np.tile(rng.integers(0, cfg.vocab_size, (3,)), 4)[:9]
            .astype(np.int32) for _ in range(n)]


def _serve_spec(cfg, params, mode, prompts, max_new, k, **kw):
    sched = Scheduler(cfg, params, OPTS_Q, num_pages=32, page_size=4,
                      max_slots=3, tick_mode=mode, speculate_k=k, **kw)
    rids = [sched.submit(p, max_new) for p in prompts]
    res = sched.run()
    return [res[r] for r in rids], sched


@pytest.mark.parametrize("mode", ["packed", "chunked", "wave"])
def test_speculative_scheduler_matches_engine(tiny_model, mode):
    """Tentpole acceptance: ``speculate_k`` NEVER changes the greedy
    stream — bit-identical to the per-request Engine in every tick mode —
    while the verify rounds fold multiple tokens into single decode
    ticks (fewer steps than the k=0 run of the same workload)."""
    cfg, params = tiny_model
    prompts = _repetitive_prompts(cfg)
    max_new = 6
    _, s0 = _serve_spec(cfg, params, mode, prompts, max_new, 0)
    outs, s = _serve_spec(cfg, params, mode, prompts, max_new, 3)
    eng = Engine(cfg, params, OPTS_Q, cache_len=32)
    for p, got in zip(prompts, outs):
        np.testing.assert_array_equal(
            got, eng.generate(p[None], max_new).tokens[0])
    st, st0 = s.stats, s0.stats
    assert st.steps < st0.steps, "speculation must reduce decode ticks"
    assert st.spec_rounds > 0 and st.spec_drafted >= st.spec_accepted > 0
    assert 0.0 < st.acceptance_rate <= 1.0
    # multi-token emission: indices strictly ordered with finite logprobs
    seen = {}
    for rid, idx, tok, lp in s.drain_events():
        assert idx == seen.get(rid, -1) + 1 and np.isfinite(lp)
        seen[rid] = idx


def test_speculative_per_request_cap(tiny_model):
    """``SamplingParams(speculate_k=1)`` lowers a request's draft burst
    below the scheduler-level k: no verify round may carry more than one
    draft token, and the stream still equals the Engine's."""
    cfg, params = tiny_model
    p = _repetitive_prompts(cfg, n=1)[0]
    sched = Scheduler(cfg, params, OPTS_Q, num_pages=32, page_size=4,
                      max_slots=3, tick_mode="chunked", speculate_k=3)
    rid = sched.submit(p, sampling=SamplingParams(max_tokens=6, speculate_k=1))
    res = sched.run()
    eng = Engine(cfg, params, OPTS_Q, cache_len=32)
    np.testing.assert_array_equal(res[rid], eng.generate(p[None], 6).tokens[0])
    st = sched.stats
    assert st.spec_rounds > 0
    assert st.spec_drafted <= st.spec_rounds  # capped at 1 draft per round


def test_speculative_rejection_rolls_back_exactly(tiny_model):
    """Prompt-lookup drafts continue the prompt's repetition, but the
    random-init model mostly doesn't — rejected tails are truncated out of
    the pool every round, and the stream must still be bit-identical to
    the Engine with the pool draining clean."""
    cfg, params = tiny_model
    prompts = _repetitive_prompts(cfg, n=3, seed=11)
    outs, s = _serve_spec(cfg, params, "chunked", prompts, 7, 3)
    eng = Engine(cfg, params, OPTS_Q, cache_len=32)
    for p, got in zip(prompts, outs):
        np.testing.assert_array_equal(got, eng.generate(p[None], 7).tokens[0])
    assert s.stats.spec_accepted < s.stats.spec_drafted  # rollbacks happened
    assert s.pool.pages_in_use == 0
