"""Continuous-batching scheduler: greedy parity with per-request
``Engine.generate`` while ragged requests are admitted and evicted
mid-stream from ONE shared pool; queueing/backpressure; EOS eviction; full
pool reclamation after drain."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.transformer import RuntimeOpts, init_params
from repro.serving.engine import Engine
from repro.serving.scheduler import Scheduler

OPTS_Q = RuntimeOpts(q_chunk=16, kv_chunk=16, remat=False, quantized_kv=True,
                     moe_capacity_factor=0.0)


@pytest.fixture(scope="module")
def tiny_model():
    cfg = get_config("llama2-7b").tiny()
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def test_scheduler_matches_engine_with_midstream_admission(tiny_model):
    """Acceptance: 5 ragged requests through 3 slots — mid-stream admission
    and eviction, a single shared pool — must produce IDENTICAL greedy
    tokens to the per-request Engine over the same quantized-cache setup."""
    cfg, params = tiny_model
    rng = np.random.default_rng(0)
    jobs = [(5, 6), (8, 3), (3, 9), (6, 4), (2, 7)]  # (prompt_len, max_new)
    prompts = [rng.integers(0, cfg.vocab_size, (n,)) for n, _ in jobs]

    sched = Scheduler(cfg, params, OPTS_Q, num_pages=24, page_size=4,
                      max_slots=3)
    rids = [sched.submit(p, mn) for p, (_, mn) in zip(prompts, jobs)]
    results = sched.run()

    assert sched.stats.admitted == 5 and sched.stats.evicted == 5
    assert sched.stats.prefills >= 2  # queue drained in waves, not one batch
    eng = Engine(cfg, params, OPTS_Q, cache_len=32)
    for rid, p, (_, mn) in zip(rids, prompts, jobs):
        want = eng.generate(p[None], mn).tokens[0]
        np.testing.assert_array_equal(results[rid], want)


def test_scheduler_pool_fully_reclaimed(tiny_model):
    cfg, params = tiny_model
    sched = Scheduler(cfg, params, OPTS_Q, num_pages=16, page_size=4,
                      max_slots=2)
    rng = np.random.default_rng(1)
    for n, mn in [(4, 3), (7, 2), (2, 5)]:
        sched.submit(rng.integers(0, cfg.vocab_size, (n,)), mn)
    sched.run()
    assert sched.pool.pages_in_use == 0
    assert not sched.pool.active.any()
    assert sched.pool.occupancy() == 0.0
    assert sched.stats.peak_occupancy > 0.0
    assert sched.stats.peak_eq2_bytes > 0


def test_scheduler_backpressure_queues_oversized_wave(tiny_model):
    """A pool that only fits one request at a time still serves all of them
    — later submissions wait in the queue instead of failing."""
    cfg, params = tiny_model
    # 6 usable pages of 4 slots; each request needs 2-3 pages incl. headroom
    sched = Scheduler(cfg, params, OPTS_Q, num_pages=7, page_size=4,
                      max_slots=2)
    rng = np.random.default_rng(2)
    prompts = [rng.integers(0, cfg.vocab_size, (8,)) for _ in range(3)]
    rids = [sched.submit(p, 3) for p in prompts]
    results = sched.run()
    assert len(results) == 3
    assert sched.stats.prefills >= 2  # memory forced at least two waves
    assert sched.stats.peak_occupancy == 1.0  # the pool really saturated
    eng = Engine(cfg, params, OPTS_Q, cache_len=32)
    for rid, p in zip(rids, prompts):
        np.testing.assert_array_equal(results[rid],
                                      eng.generate(p[None], 3).tokens[0])


def test_scheduler_eos_evicts_early(tiny_model):
    """An EOS-terminated request frees its slot for the queue: pick the
    token the model actually emits first as the EOS id, and require the
    result to be truncated at it."""
    cfg, params = tiny_model
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, cfg.vocab_size, (5,))
    eng = Engine(cfg, params, OPTS_Q, cache_len=32)
    free_run = eng.generate(prompt[None], 6).tokens[0]
    eos = int(free_run[5 + 2])  # the 3rd generated token

    sched = Scheduler(cfg, params, OPTS_Q, num_pages=16, page_size=4,
                      max_slots=2)
    rid = sched.submit(prompt, 6, eos_id=eos)
    results = sched.run()
    got = results[rid]
    assert got[-1] == eos and got.size == 5 + 3  # truncated at EOS
    np.testing.assert_array_equal(got, free_run[: 5 + 3])


def test_scheduler_impossible_request_fails_loudly(tiny_model):
    """A request whose worst case exceeds the whole pool raises instead of
    spinning the run loop forever."""
    from repro.serving.kv_pool import PoolExhaustedError

    cfg, params = tiny_model
    sched = Scheduler(cfg, params, OPTS_Q, num_pages=4, page_size=4,
                      max_slots=2)  # 3 usable pages = 12 tokens
    rng = np.random.default_rng(5)
    sched.submit(rng.integers(0, cfg.vocab_size, (10,)), 8)  # needs 18
    with pytest.raises(PoolExhaustedError, match="never be admitted"):
        sched.run()


def test_scheduler_single_token_requests(tiny_model):
    """max_new_tokens=1 finishes on its prefill logits — no decode step."""
    cfg, params = tiny_model
    sched = Scheduler(cfg, params, OPTS_Q, num_pages=16, page_size=4,
                      max_slots=2)
    rng = np.random.default_rng(4)
    p = rng.integers(0, cfg.vocab_size, (6,))
    rid = sched.submit(p, 1)
    results = sched.run()
    eng = Engine(cfg, params, OPTS_Q, cache_len=32)
    np.testing.assert_array_equal(results[rid], eng.generate(p[None], 1).tokens[0])
    assert sched.stats.steps == 0  # finished at prefill, never decoded
