"""Dry-run machinery on a small forced-device-count mesh.

XLA locks the host device count at first backend init, so these tests spawn
subprocesses with ``--xla_force_host_platform_device_count=8`` and exercise
the REAL sharding policies + lowering path on a (2, 4)/(2, 2, 2) mesh with
tiny architectures — the same code the 256/512-chip dry-run runs.
"""

import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, sys
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.launch import sharding as shd
from repro.launch.roofline import model_flops
from repro.launch.hlo_cost import analyze
from repro.launch.shapes import (ShapeSpec, default_opts, train_target,
                                 decode_target, prefill_target,
                                 paged_decode_target)

arch, kind, multi = sys.argv[1], sys.argv[2], sys.argv[3] == "multi"
cfg = get_config(arch).tiny()
if multi:
    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
else:
    mesh = jax.make_mesh((2, 4), ("data", "model"))

if kind == "train":
    shape = ShapeSpec("t", 32, 8, "train")
    fn, args = train_target(cfg, shape, mesh, default_opts(cfg, shape, q_chunk=16, kv_chunk=16))
elif kind == "prefill":
    shape = ShapeSpec("p", 64, 8, "prefill")
    fn, args = prefill_target(cfg, shape, mesh, default_opts(cfg, shape, q_chunk=16, kv_chunk=16))
elif kind == "paged":
    shape = ShapeSpec("pd", 64, 8, "paged_decode")
    fn, args = paged_decode_target(cfg, shape, mesh, default_opts(cfg, shape))
else:
    shape = ShapeSpec("d", 64, 8, "decode")
    fn, args = decode_target(cfg, shape, mesh, default_opts(cfg, shape))

with mesh:
    compiled = jax.jit(fn).lower(*args).compile()
hc = analyze(compiled.as_text())
print(json.dumps({"flops": hc.flops, "coll": hc.collective_bytes,
                  "mem": hc.memory_bytes, "ok": True}))
"""


def _run(arch: str, kind: str, multi: bool = False) -> dict:
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", _SCRIPT, arch, kind, "multi" if multi else "single"],
        capture_output=True, text=True, env=env, timeout=420)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


@pytest.mark.parametrize("arch,kind", [
    ("llama2-7b", "train"),
    ("gemma2-2b", "decode"),
    ("qwen3-moe-235b-a22b", "train"),
    ("jamba-v0.1-52b", "decode"),
    ("qwen2-vl-2b", "prefill"),
    ("mamba2-780m", "decode"),
])
def test_small_mesh_lowering(arch, kind):
    res = _run(arch, kind)
    assert res["ok"]
    assert res["flops"] > 0
    assert res["mem"] > 0


def test_small_mesh_lowering_paged_decode():
    """Ragged paged_decode_step lowers + compiles with the pool's page axis
    sharded over the data axes (flops hide inside the Pallas call — the
    dense int8-kernel decode reports 0 the same way; memory and the
    block-table gather's collectives are the observable signal)."""
    res = _run("llama2-7b", "paged")
    assert res["ok"]
    assert res["mem"] > 0


def test_multi_pod_small_mesh():
    res = _run("llama2-7b", "train", multi=True)
    assert res["ok"] and res["flops"] > 0
    # FSDP over (pod, data) + TP must produce collectives
    assert res["coll"] > 0
