"""Serving tests: batched engine greedy decode, and the split-computing
engine (OPSC + TS/TAB-Q payload + channel/early-exit) against the monolithic
engine."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.channel import ChannelConfig
from repro.core.opsc import OPSCConfig
from repro.models.transformer import RuntimeOpts, init_params
from repro.serving.engine import Engine
from repro.serving.split_engine import SplitEngine

OPTS = RuntimeOpts(q_chunk=16, kv_chunk=16, remat=False, moe_capacity_factor=0.0)


@pytest.fixture(scope="module")
def tiny_model():
    cfg = get_config("llama2-7b").tiny()  # 2 layers, pattern len 1
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def test_engine_greedy_deterministic(tiny_model):
    cfg, params = tiny_model
    eng = Engine(cfg, params, OPTS, cache_len=64)
    prompts = np.random.default_rng(0).integers(0, cfg.vocab_size, (3, 8))
    r1 = eng.generate(prompts, max_new_tokens=6)
    r2 = eng.generate(prompts, max_new_tokens=6)
    np.testing.assert_array_equal(r1.tokens, r2.tokens)
    assert r1.tokens.shape == (3, 14)
    np.testing.assert_array_equal(r1.tokens[:, :8], prompts)


def test_engine_temperature_sampling_varies(tiny_model):
    cfg, params = tiny_model
    eng = Engine(cfg, params, OPTS, cache_len=64)
    prompts = np.random.default_rng(1).integers(0, cfg.vocab_size, (2, 8))
    a = eng.generate(prompts, 8, temperature=1.5, seed=0).tokens
    b = eng.generate(prompts, 8, temperature=1.5, seed=1).tokens
    assert not np.array_equal(a, b)


def test_split_engine_matches_monolithic_uncompressed(tiny_model):
    """No compression + fp16-equivalent front → split must equal monolithic."""
    cfg, params = tiny_model
    eng = Engine(cfg, params, OPTS, cache_len=64)
    opsc = OPSCConfig(split_layer=1, qw_front=16, i_kv=1)
    split = SplitEngine(cfg, params, opsc, opts=OPTS, cache_len=64)
    prompts = np.random.default_rng(2).integers(0, cfg.vocab_size, (2, 8))
    ref = eng.generate(prompts, 5).tokens
    got, stats = split.generate(prompts, 5, compress=False)
    np.testing.assert_array_equal(got, ref)
    assert stats.uplink_bits_eq3 > 0


def test_split_engine_compressed_mostly_matches(tiny_model):
    """TS+TAB-Q payload + int4 front weights: tokens should mostly agree with
    the monolithic engine (paper's 'negligible accuracy loss')."""
    cfg, params = tiny_model
    eng = Engine(cfg, params, OPTS, cache_len=64)
    opsc = OPSCConfig(split_layer=1, qw_front=8, qa_front=8, tau=2.0,
                      delta=0.05, max_act_bits=8)
    split = SplitEngine(cfg, params, opsc, opts=OPTS, cache_len=64)
    prompts = np.random.default_rng(3).integers(0, cfg.vocab_size, (2, 8))
    ref = eng.generate(prompts, 8).tokens
    got, stats = split.generate(prompts, 8, compress=True)
    agree = np.mean(got[:, 8:] == ref[:, 8:])
    assert agree >= 0.75, f"agreement {agree}"
    assert stats.uplink_bits_measured > 0


def test_split_engine_ikv0_stateless_cloud(tiny_model):
    """I_kv = 0: stateless cloud recompute must still produce the same greedy
    tokens as the cached path when nothing is compressed."""
    cfg, params = tiny_model
    o1 = OPSCConfig(split_layer=1, qw_front=16, i_kv=1)
    o0 = OPSCConfig(split_layer=1, qw_front=16, i_kv=0)
    s1 = SplitEngine(cfg, params, o1, opts=OPTS, cache_len=64)
    s0 = SplitEngine(cfg, params, o0, opts=OPTS, cache_len=64)
    prompts = np.random.default_rng(4).integers(0, cfg.vocab_size, (1, 6))
    t1, st1 = s1.generate(prompts, 5, compress=False)
    t0, st0 = s0.generate(prompts, 5, compress=False)
    np.testing.assert_array_equal(t0, t1)
    # Eq. 3: hidden-only uplink accounting is far smaller than KV-cache uplink
    assert st0.uplink_bits_eq3 < st1.uplink_bits_eq3


def test_split_engine_early_exit_on_tight_deadline(tiny_model):
    cfg, params = tiny_model
    opsc = OPSCConfig(split_layer=1, qw_front=16)
    split = SplitEngine(cfg, params, opsc, opts=OPTS, cache_len=64,
                        deadline_s=1e-7, compute_per_layer_s=1e-3)
    prompts = np.random.default_rng(5).integers(0, cfg.vocab_size, (1, 6))
    got, stats = split.generate(prompts, 10, compress=True)
    assert stats.early_exits >= 1
    assert got.shape[1] < 16  # truncated generation


def test_split_engine_compression_shrinks_uplink(tiny_model):
    cfg, params = tiny_model
    opsc = OPSCConfig(split_layer=1, qw_front=16, tau=5.0, max_act_bits=6)
    split = SplitEngine(cfg, params, opsc, opts=OPTS, cache_len=64)
    prompts = np.random.default_rng(6).integers(0, cfg.vocab_size, (1, 8))
    _, raw = split.generate(prompts, 5, compress=False)
    _, comp = split.generate(prompts, 5, compress=True)
    assert comp.uplink_bits_measured < raw.uplink_bits_measured / 2


def test_split_engine_paged_cloud_matches_dense(tiny_model):
    """I_kv=1 with a paged cloud pool: the cloud decodes from shipped PAGES
    (kernels.paged_decode_attention over a kv_pool) — same greedy tokens as
    the dense cloud cache, with page-granular uplink/memory accounting."""
    cfg, params = tiny_model
    opsc = OPSCConfig(split_layer=1, qw_front=16, i_kv=1)
    dense = SplitEngine(cfg, params, opsc, opts=OPTS, cache_len=64)
    paged = SplitEngine(cfg, params, opsc, opts=OPTS, cache_len=64,
                        paged_cloud_kv=True, cloud_pool_pages=32,
                        cloud_page_size=8)
    prompts = np.random.default_rng(7).integers(0, cfg.vocab_size, (2, 8))
    t_dense, _ = dense.generate(prompts, 5, compress=False)
    t_paged, st = paged.generate(prompts, 5, compress=False)
    np.testing.assert_array_equal(t_paged, t_dense)
    assert st.uplink_bits_paged > 0
    assert st.cloud_pool_bytes_peak > 0
    # page-granular shipment ≤ the dense Eq. 3 accounting at fp16 widths —
    # the pool ships int8 codes + scales in whole pages
    assert st.cloud_pool_bytes_peak * 8 <= st.uplink_bits_eq3


def test_split_engine_speculative_matches_per_token(tiny_model):
    """Split-boundary speculation: the edge drafts k tokens on its OPSC
    front segment, ships ONE k-token TAB-Q payload, and the cloud verifies
    every position in a single packed call — the greedy stream is
    BIT-IDENTICAL to the per-token loop on both cloud variants, with
    strictly fewer decode uplink round trips."""
    cfg, params = tiny_model
    opsc = OPSCConfig(split_layer=1, qw_front=16, i_kv=1)
    # two repetitive rows the random-init model actually drafts on: row 0
    # accepts its whole bursts, row 1 mixes accepts and rejections — the
    # round-trip count is the max over rows, so BOTH must amortize for the
    # strict reduction below
    prompts = np.concatenate([
        np.tile(np.random.default_rng(s).integers(0, cfg.vocab_size, (1, 3)),
                (1, 3)) for s in (6, 14)])
    for kw in ({}, dict(paged_cloud_kv=True, cloud_pool_pages=32,
                        cloud_page_size=8)):
        eng = SplitEngine(cfg, params, opsc, opts=OPTS, cache_len=64, **kw)
        ref, base = eng.generate(prompts, 6, compress=True)
        out, st = eng.generate(prompts, 6, compress=True, speculate_k=3)
        np.testing.assert_array_equal(out, ref)
        assert st.uplink_round_trips < base.uplink_round_trips
        assert st.spec_rounds > 0
        assert 0 < st.spec_accepted < st.spec_drafted  # accepts AND rejects
        assert 0.0 <= st.acceptance_rate <= 1.0
        # the k-token payload still pays TAB-Q bits per shipped activation:
        # uplink bits stay comparable while round trips shrink
        assert st.uplink_bits_measured > 0


def test_split_engine_shared_cloud_prefix_dedupes_pages_and_uplink(tiny_model):
    """Edge devices sharing a system prompt: with ``shared_prefix_len`` the
    cloud pool holds the prefix pages ONCE (rows 1+ fork from row 0), the
    prefix crosses the uplink once, and the generated tokens still match
    the unshared paged run."""
    cfg, params = tiny_model
    opsc = OPSCConfig(split_layer=1, qw_front=16, i_kv=1)
    rng = np.random.default_rng(9)
    prefix = rng.integers(0, cfg.vocab_size, (1, 8))
    sufs = rng.integers(0, cfg.vocab_size, (3, 4))
    prompts = np.concatenate([np.repeat(prefix, 3, axis=0), sufs], axis=1)

    def build():
        return SplitEngine(cfg, params, opsc, opts=OPTS, cache_len=64,
                           paged_cloud_kv=True, cloud_pool_pages=16,
                           cloud_page_size=8)

    t_plain, st_plain = build().generate(prompts, 5, compress=False)
    t_shared, st = build().generate(prompts, 5, compress=False,
                                    shared_prefix_len=8)
    np.testing.assert_array_equal(t_shared, t_plain)
    assert st.shared_prefix_pages == 1  # one 8-token page pinned
    # physical cloud residency and page-granular uplink dedupe the prefix
    assert st.cloud_pool_bytes_peak < st_plain.cloud_pool_bytes_peak
    assert st.uplink_bits_paged < st_plain.uplink_bits_paged
    # rows 1+ never ship their prefix hidden states
    assert st.uplink_bits_measured < st_plain.uplink_bits_measured
    # mismatched rows are rejected loudly, not silently deduped
    bad = prompts.copy()
    bad[1, 2] = (bad[1, 2] + 1) % cfg.vocab_size
    with pytest.raises(ValueError, match="do not share"):
        build().generate(bad, 5, compress=False, shared_prefix_len=8)
    # a declared prefix below one page disables the dedup (rounds to 0
    # shared pages) but MUST still validate the declared tokens
    with pytest.raises(ValueError, match="do not share"):
        build().generate(bad, 5, compress=False, shared_prefix_len=3)
    t_sub, st_sub = build().generate(prompts, 5, compress=False,
                                     shared_prefix_len=3)
    assert st_sub.shared_prefix_pages == 0
    np.testing.assert_array_equal(t_sub, t_plain)

# ------------------------------------------------ engine compile-cache key


def test_engine_generate_fn_keys_on_cache_len(tiny_model):
    """Regression: the fused-loop compile cache must key on cache_len (the
    closure bakes it in) — reconfiguring a live engine previously reused the
    stale closure and silently kept the old cache size."""
    cfg, params = tiny_model
    eng = Engine(cfg, params, OPTS, cache_len=64)
    fn64 = eng.generate_fn(4, greedy=True)
    assert eng.generate_fn(4, greedy=True) is fn64  # same config → cached
    eng.cache_len = 32
    fn32 = eng.generate_fn(4, greedy=True)
    assert fn32 is not fn64  # new cache size → new closure, not stale reuse
    prompts = np.random.default_rng(8).integers(0, cfg.vocab_size, (2, 8))
    out = eng.generate(prompts, 4).tokens  # and it actually serves
    assert out.shape == (2, 12)
    # opts changes key too (they alter the traced computation)
    eng.opts = dataclasses.replace(OPTS, quantized_kv=True)
    assert eng.generate_fn(4, greedy=True) is not fn32
