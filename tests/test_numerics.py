"""Numerical-equivalence property tests for the compute layers:

* chunked (flash-style) attention ≡ dense softmax reference, across chunk
  sizes, GQA ratios, windows and softcaps;
* chunked SSD ≡ naive sequential state-space recurrence;
* grouped MoE dispatch ≡ global dispatch in the dropless regime.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.models.layers import NEG_INF, chunked_attention
from repro.models.ssm import ssd_chunked, ssd_decode_step


def dense_attention_ref(q, k, v, q_pos, kv_pos, causal=True, window=None,
                        softcap=None):
    b, sq, h, hd = q.shape
    kh = k.shape[2]
    g = h // kh
    qf = q.astype(jnp.float32).reshape(b, sq, kh, g, hd) / np.sqrt(hd)
    s = jnp.einsum("bqkgd,bckd->bkgqc", qf, k.astype(jnp.float32))
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    mask = kv_pos[:, None, None, None, :] >= 0
    if causal:
        mask &= kv_pos[:, None, None, None, :] <= q_pos[:, None, None, :, None]
    if window is not None:
        mask &= kv_pos[:, None, None, None, :] > (q_pos[:, None, None, :, None] - window)
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqc,bckd->bkgqd", p, v.astype(jnp.float32))
    return out.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, hd)


def _attn_inputs(b, s, h, kh, hd, seed):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(b, s, h, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, kh, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, kh, hd)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    return q, k, v, pos


@pytest.mark.parametrize("qc,kc", [(4, 4), (8, 16), (16, 8), (64, 64)])
@pytest.mark.parametrize("window,softcap", [(None, None), (7, None),
                                            (None, 30.0), (5, 20.0)])
def test_chunked_attention_matches_dense(qc, kc, window, softcap):
    q, k, v, pos = _attn_inputs(2, 24, 4, 2, 16, seed=qc * 100 + kc)
    got = chunked_attention(q, k, v, pos, pos, causal=True, window=window,
                            softcap=softcap, q_chunk=qc, kv_chunk=kc)
    want = dense_attention_ref(q, k, v, pos, pos, window=window, softcap=softcap)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


@settings(max_examples=15, deadline=None)
@given(s=st.integers(3, 40), h=st.sampled_from([2, 4, 8]),
       kh=st.sampled_from([1, 2]), seed=st.integers(0, 50))
def test_chunked_attention_property(s, h, kh, seed):
    if h % kh:
        kh = 1
    q, k, v, pos = _attn_inputs(1, s, h, kh, 8, seed)
    got = chunked_attention(q, k, v, pos, pos, q_chunk=8, kv_chunk=8)
    want = dense_attention_ref(q, k, v, pos, pos)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=5e-4, atol=5e-4)


def test_decode_fast_path_matches_scan_path():
    """nq=nk=1 fast path ≡ generic scan path."""
    q, k, v, pos = _attn_inputs(2, 32, 4, 2, 16, seed=7)
    q1 = q[:, -1:]
    qpos = pos[:, -1:]
    fast = chunked_attention(q1, k, v, qpos, pos, q_chunk=1, kv_chunk=64)
    slow = chunked_attention(q1, k, v, qpos, pos, q_chunk=1, kv_chunk=8)
    np.testing.assert_allclose(np.asarray(fast), np.asarray(slow),
                               rtol=2e-4, atol=2e-4)


# ------------------------------------------------------------------- SSD


def ssd_sequential_ref(x, dt, a, b_mat, c_mat):
    """Naive token-by-token recurrence (the ground truth SSD computes)."""
    bsz, s, h, p = x.shape
    n = b_mat.shape[-1]
    state = jnp.zeros((bsz, h, p, n), jnp.float32)
    ys = []
    for t in range(s):
        y, state = ssd_decode_step(
            x[:, t].astype(jnp.float32), dt[:, t].astype(jnp.float32), a,
            b_mat[:, t].astype(jnp.float32), c_mat[:, t].astype(jnp.float32),
            state)
        ys.append(y)
    return jnp.stack(ys, axis=1), state


@pytest.mark.parametrize("chunk", [2, 4, 8, 32])
@pytest.mark.parametrize("s", [6, 16, 23])
def test_ssd_chunked_matches_sequential(chunk, s):
    rng = np.random.default_rng(chunk * 10 + s)
    bsz, h, p, n = 2, 3, 4, 5
    x = jnp.asarray(rng.normal(size=(bsz, s, h, p)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.5, size=(bsz, s, h)), jnp.float32)
    a = -jnp.asarray(rng.uniform(0.5, 2.0, size=(h,)), jnp.float32)
    bm = jnp.asarray(rng.normal(size=(bsz, s, n)), jnp.float32)
    cm = jnp.asarray(rng.normal(size=(bsz, s, n)), jnp.float32)
    y, st = ssd_chunked(x, dt, a, bm, cm, chunk)
    y_ref, st_ref = ssd_sequential_ref(x, dt, a, bm, cm)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(st), np.asarray(st_ref),
                               rtol=1e-4, atol=1e-4)


def test_ssd_initial_state_carries():
    """Chunked prefill in two halves ≡ one shot (state threading)."""
    rng = np.random.default_rng(0)
    bsz, s, h, p, n = 1, 12, 2, 4, 3
    x = jnp.asarray(rng.normal(size=(bsz, s, h, p)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.05, 0.3, size=(bsz, s, h)), jnp.float32)
    a = -jnp.ones((h,), jnp.float32)
    bm = jnp.asarray(rng.normal(size=(bsz, s, n)), jnp.float32)
    cm = jnp.asarray(rng.normal(size=(bsz, s, n)), jnp.float32)
    y_full, st_full = ssd_chunked(x, dt, a, bm, cm, chunk=4)
    y1, st1 = ssd_chunked(x[:, :6], dt[:, :6], a, bm[:, :6], cm[:, :6], 4)
    y2, st2 = ssd_chunked(x[:, 6:], dt[:, 6:], a, bm[:, 6:], cm[:, 6:], 4,
                          initial_state=st1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(st2), np.asarray(st_full),
                               rtol=1e-4, atol=1e-4)


# ------------------------------------------------------------------- MoE


def test_moe_grouped_equals_global_dropless():
    from repro.configs.base import MoESpec
    from repro.models.moe import init_moe_params, moe_layer

    spec = MoESpec(num_experts=4, top_k=2, d_ff=16, renormalize=True)
    params = init_moe_params(jax.random.PRNGKey(0), 32, spec)
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(4, 8, 32)), jnp.float32)
    y1, aux1 = moe_layer(params, x, spec, capacity_factor=0.0, groups=1)
    y4, aux4 = moe_layer(params, x, spec, capacity_factor=0.0, groups=4)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y4), rtol=1e-4,
                               atol=1e-5)
    assert float(aux1) == pytest.approx(float(aux4), rel=1e-4)


def test_moe_dropping_converges_to_dropless():
    from repro.configs.base import MoESpec
    from repro.models.moe import init_moe_params, moe_layer

    spec = MoESpec(num_experts=4, top_k=2, d_ff=16, renormalize=True)
    params = init_moe_params(jax.random.PRNGKey(2), 32, spec)
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(2, 16, 32)), jnp.float32)
    y_full, _ = moe_layer(params, x, spec, capacity_factor=0.0)
    errs = []
    for cf in (0.5, 1.0, 2.0):
        y, _ = moe_layer(params, x, spec, capacity_factor=cf)
        errs.append(float(jnp.mean((y - y_full) ** 2)))
    assert errs[0] >= errs[1] >= errs[2]
    assert errs[2] < 1e-8  # cf=2.0 ≈ dropless at uniform-ish routing