"""Tests for threshold splitting (Eq. 4/7), TAB-Q (Alg. 1) and the payload codec."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.payload import (Payload, decode, encode, encode_decode_ste,
                                entropy_bound_bits)
from repro.core.tabq import tabq, tabq_fixed
from repro.core.ts import reconstruct, split_dense, ts_decode, ts_encode


def _mk(rows=32, d=64, seed=0, outliers=8, outlier_mag=50.0):
    rng = np.random.default_rng(seed)
    t = rng.normal(size=(rows, d)).astype(np.float32)
    flat = t.reshape(-1)
    idx = rng.choice(flat.size, size=outliers, replace=False)
    flat[idx] = outlier_mag * np.sign(flat[idx])
    return jnp.asarray(flat.reshape(rows, d))


# ---------------------------------------------------------------- TS ------


def test_split_dense_partition_is_exact():
    t = _mk()
    above, below, m = split_dense(t, tau=5.0)
    np.testing.assert_allclose(np.asarray(above + below), np.asarray(t), rtol=1e-6)
    assert float(jnp.max(jnp.abs(below))) < 5.0


def test_ts_encode_decode_exact_roundtrip():
    t = _mk(outliers=10)
    below, above = ts_encode(t, tau=5.0, capacity=32)
    assert int(above.count) == 10
    dense_above = ts_decode(above)
    np.testing.assert_allclose(np.asarray(below + dense_above), np.asarray(t), rtol=1e-6)
    # below really has the big values removed
    assert float(jnp.max(jnp.abs(below))) < 5.0


def test_ts_capacity_overflow_keeps_largest():
    t = _mk(outliers=20, outlier_mag=50.0)
    # add a few even larger entries
    t = t.at[0, :4].set(jnp.asarray([500.0, -400.0, 300.0, 200.0]))
    below, above = ts_encode(t, tau=5.0, capacity=4)
    kept = np.sort(np.abs(np.asarray(above.values)))
    np.testing.assert_allclose(kept, [200.0, 300.0, 400.0, 500.0])
    assert int(above.count) == 24  # true nnz still reported


def test_reconstruct_matches_eq7():
    t = _mk(outliers=6)
    below, above = ts_encode(t, tau=5.0, capacity=16)
    rec = reconstruct(below, above)
    np.testing.assert_allclose(np.asarray(rec), np.asarray(t), rtol=1e-6)


@settings(max_examples=25, deadline=None)
@given(tau=st.floats(min_value=0.5, max_value=20.0), seed=st.integers(0, 100))
def test_ts_property_roundtrip(tau, seed):
    t = _mk(seed=seed, outliers=5, outlier_mag=30.0)
    below, above = ts_encode(t, tau=tau, capacity=t.size)  # ample capacity
    rec = reconstruct(below, above)
    np.testing.assert_allclose(np.asarray(rec), np.asarray(t), rtol=1e-5, atol=1e-5)


# -------------------------------------------------------------- TAB-Q -----


def test_tabq_respects_distortion_tolerance_direction():
    t = jnp.abs(_mk(outliers=0)) + 0.1
    loose = tabq(t, max_bits=8, delta=10.0)
    tight = tabq(t, max_bits=8, delta=0.0)
    # looser tolerance → fewer (or equal) bits everywhere
    assert int(jnp.max(loose.bits)) <= int(jnp.min(tight.bits))
    assert int(jnp.max(tight.bits)) == 8  # δ>0 for any reduction → stays at Q̄


def test_tabq_dequant_error_small_at_high_bits():
    t = _mk(outliers=0)
    q = tabq_fixed(t, bits=8)
    rec = q.dequantize()
    err = float(jnp.max(jnp.abs(rec - t)))
    assert err < float(jnp.max(jnp.abs(t))) / 40


def test_tabq_per_token_bits_vary_with_token_stats():
    rng = np.random.default_rng(9)
    smooth = np.full((1, 64), 1.0, np.float32) + rng.normal(size=(1, 64)).astype(np.float32) * 1e-4
    spiky = rng.normal(size=(1, 64)).astype(np.float32) * 10
    t = jnp.asarray(np.concatenate([smooth, spiky]))
    q = tabq(t, max_bits=8, delta=0.05)
    assert int(q.bits[0]) <= int(q.bits[1])


def test_tabq_payload_bits_accounting():
    t = _mk(outliers=0, rows=4, d=32)
    q = tabq_fixed(t, bits=6)
    expect = 4 * 32 * 6 + 4 * (64 + 8)
    assert int(q.payload_bits()) == expect


# ------------------------------------------------------------- payload ----


def test_payload_roundtrip_close_and_outliers_exact():
    t = _mk(outliers=8, outlier_mag=80.0)
    p = encode(t, tau=5.0, delta=0.05, max_bits=8, capacity=32)
    rec = decode(p)
    # outliers reinstated exactly
    mask = np.abs(np.asarray(t)) >= 5.0
    np.testing.assert_allclose(np.asarray(rec)[mask], np.asarray(t)[mask], rtol=1e-6)
    # body error bounded by the TAB-Q step
    body_err = np.max(np.abs((np.asarray(rec) - np.asarray(t))[~mask]))
    assert body_err < 0.6


def test_payload_compression_ratio_beats_fp16():
    t = _mk(rows=128, d=256, outliers=16, outlier_mag=60.0)
    p = encode(t, tau=5.0, delta=0.2, max_bits=6)
    raw_bits = t.size * 16
    assert int(p.payload_bits()) < raw_bits / 2  # ≥2× vs fp16


def test_ste_gradient_is_identity():
    import jax

    t = _mk(rows=8, d=16, outliers=2)

    def f(x):
        return jnp.sum(encode_decode_ste(x, tau=5.0, max_bits=8) ** 2)

    g = jax.grad(f)(t)
    np.testing.assert_allclose(np.asarray(g), np.asarray(2 * decode(encode(t, tau=5.0, max_bits=8))), rtol=1e-4)


def test_entropy_bound_below_raw_bits():
    t = _mk(rows=64, d=64, outliers=0)
    q = tabq_fixed(t, bits=8)
    h = float(entropy_bound_bits(q))
    assert h <= float(q.payload_bits()) * 1.01
