"""Correctness of the pod-axis split pipeline: on a 2-pod mesh (subprocess,
forced device count) the pipelined decode must produce the same greedy
tokens as the monolithic decode_step, and int8 payloads must stay close."""

import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import json, sys
import jax, jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.launch.split_pipeline import init_pipeline_caches, pipeline_decode_sharded
from repro.models.transformer import RuntimeOpts, decode_step, init_caches, init_params

payload_bits = int(sys.argv[1])
cfg = get_config("llama2-7b").tiny()  # 2 blocks → 1 per pod
opts = RuntimeOpts(q_chunk=8, kv_chunk=64, remat=False, moe_capacity_factor=0.0)
params = init_params(cfg, jax.random.PRNGKey(0))
mesh = jax.make_mesh((2, 1, 1), ("pod", "data", "model"))

b, n_micro, steps = 8, 2, 3
rng = np.random.default_rng(0)
tok0 = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, 1)), jnp.int32)

# ---- monolithic reference
caches = init_caches(cfg, b, 64, opts)
ref_tokens = []
tok = tok0
for pos in range(steps):
    logits, caches = decode_step(params, cfg, tok, caches, jnp.int32(pos), opts)
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    ref_tokens.append(np.asarray(tok))

# ---- pipelined
other = {k: v for k, v in params.items() if k != "blocks"}
bs = b // n_micro
with mesh:
    step = jax.jit(pipeline_decode_sharded(cfg, opts, mesh, n_micro, payload_bits))
    pcaches = init_pipeline_caches(cfg, bs, n_micro, 64, opts)
    tok = tok0
    got_tokens = []
    for pos in range(steps):
        tok, pcaches = step(params["blocks"], other, tok, pcaches, jnp.int32(pos))
        tok = tok.astype(jnp.int32)
        got_tokens.append(np.asarray(tok))

match = float(np.mean([np.mean(a == b_) for a, b_ in zip(ref_tokens, got_tokens)]))
print(json.dumps({"match": match}))
"""


@pytest.mark.parametrize("bits,min_match", [(16, 1.0), (8, 0.8), (4, 0.5)])
def test_pipeline_matches_monolithic(bits, min_match):
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", _SCRIPT, str(bits)],
                         capture_output=True, text=True, env=env, timeout=420)
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["match"] >= min_match, res
