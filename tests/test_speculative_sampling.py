"""``core.sampling.speculative_verify``: the sampler half of split-boundary
speculative decoding. Greedy lanes must be EXACT — emission is the argmax of
the verify logits whatever the drafter proposed — and non-greedy lanes must
preserve the sampling distribution (rejection sampling against the point-mass
draft proposal), pinned here statistically against ``sample_tokens`` draws
from the very same logits."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.sampling import (SamplingParams, sample_tokens,
                                 sampling_operands, speculative_verify,
                                 token_logprobs)


def _ops(params):
    o = sampling_operands(params)
    return o["keys"], o["temperature"], o["top_k"], o["top_p"]


def _verify(draft, draft_len, logits, params, t0):
    keys, temp, tk, tp = _ops(params)
    r = len(params)
    out, n, lps = jax.jit(speculative_verify)(
        jnp.asarray(draft, jnp.int32).reshape(r, -1),
        jnp.asarray(draft_len, jnp.int32).reshape(r),
        jnp.asarray(logits, jnp.float32),
        keys, jnp.asarray(t0, jnp.int32).reshape(r), temp, tk, tp)
    return np.asarray(out), np.asarray(n), np.asarray(lps)


def _rand_logits(r, k1, v, seed=0, scale=2.0):
    rng = np.random.default_rng(seed)
    return rng.normal(size=(r, k1, v)).astype(np.float32) * scale


# ------------------------------------------------------------ greedy lane


def test_greedy_accepts_matching_prefix_and_emits_argmax():
    """n_out = matched prefix + 1; every emitted token IS the argmax."""
    logits = _rand_logits(3, 4, 16, seed=1)
    am = logits.argmax(-1)
    draft = np.zeros((3, 3), np.int32)
    draft[0] = am[0, :3]  # full match -> all 3 + bonus
    draft[1] = [am[1, 0], (am[1, 1] + 1) % 16, am[1, 2]]  # break at 1
    draft[2] = [(am[2, 0] + 1) % 16, am[2, 1], am[2, 2]]  # break at 0
    out, n, _ = _verify(draft, [3, 3, 3], logits,
                        [SamplingParams()] * 3, [0, 0, 0])
    np.testing.assert_array_equal(n, [4, 2, 1])
    for r in range(3):
        np.testing.assert_array_equal(out[r, : n[r]], am[r, : n[r]])


def test_greedy_emission_is_draft_independent():
    """Two verifies of the same logits with DIFFERENT drafts emit the same
    accepted stream (prefixes of the argmax chain) — a bad drafter can only
    shorten acceptance, never corrupt output."""
    logits = _rand_logits(2, 5, 32, seed=2)
    am = logits.argmax(-1)
    rng = np.random.default_rng(3)
    params = [SamplingParams(), SamplingParams(top_k=1, temperature=1.5,
                                               seed=9)]  # both greedy lanes
    for trial in range(4):
        draft = rng.integers(0, 32, (2, 4)).astype(np.int32)
        out, n, _ = _verify(draft, [4, 4], logits, params, [0, 0])
        for r in range(2):
            np.testing.assert_array_equal(out[r, : n[r]], am[r, : n[r]])


def test_draft_len_zero_degenerates_to_sample_tokens():
    """A round with no drafts must emit EXACTLY the token sample_tokens
    would draw at the same generation index — greedy and seeded sampling
    rows alike (the scheduler's no-draft-available slots ride this)."""
    params = [SamplingParams(), SamplingParams(temperature=0.9, seed=5),
              SamplingParams(temperature=1.3, top_k=7, seed=6),
              SamplingParams(temperature=0.7, top_p=0.8, seed=7)]
    logits = _rand_logits(4, 1, 64, seed=4)
    for t in (0, 3, 17):
        out, n, _ = _verify(np.zeros((4, 0)), [0] * 4, logits, params,
                            [t] * 4)
        keys, temp, tk, tp = _ops(params)
        want = np.asarray(jax.jit(sample_tokens)(
            jnp.asarray(logits[:, 0]), keys,
            jnp.full((4,), t, jnp.int32), temp, tk, tp))
        np.testing.assert_array_equal(n, [1] * 4)
        np.testing.assert_array_equal(out[:, 0], want)


def test_logprobs_are_verify_model_logprobs():
    logits = _rand_logits(2, 3, 16, seed=8)
    draft = logits.argmax(-1)[:, :2].astype(np.int32)
    out, n, lps = _verify(draft, [2, 2], logits,
                          [SamplingParams()] * 2, [0, 0])
    want = np.asarray(token_logprobs(jnp.asarray(logits.reshape(-1, 16)),
                                     jnp.asarray(out.reshape(-1))))
    np.testing.assert_allclose(lps.reshape(-1), want, rtol=1e-6)


# ------------------------------------------- rejection-sampling statistics


def _freqs(tokens, v):
    return np.bincount(np.asarray(tokens).reshape(-1), minlength=v) \
        / tokens.size


def test_rejected_first_position_preserves_distribution():
    """Marginal distribution of the FIRST emitted token under speculation
    (accept draft w.p. p(draft), else residual) must match plain
    sample_tokens draws from the same logits. R identical rows with
    distinct seeds give the empirical law in one compiled call."""
    v, r = 12, 4000
    rng = np.random.default_rng(11)
    row = (rng.normal(size=(v,)) * 1.5).astype(np.float32)
    logits = np.broadcast_to(row, (r, 1, v)).copy()[:, None, :][:, 0]
    logits = logits.reshape(r, 1, v)
    params = [SamplingParams(temperature=1.0, seed=s) for s in range(r)]
    draft = np.full((r, 1), int(row.argmax()), np.int32)  # high-prob draft
    out, n, _ = _verify(draft, [1] * r, logits, params, [0] * r)
    assert np.all(n >= 1)
    spec = _freqs(out[:, 0], v)

    keys, temp, tk, tp = _ops(params)
    base = np.asarray(jax.jit(sample_tokens)(
        jnp.asarray(logits[:, 0]), keys, jnp.zeros((r,), jnp.int32),
        temp, tk, tp))
    ref = _freqs(base, v)
    target = np.exp(row - row.max())
    target /= target.sum()
    # both empirical laws near the analytic target, and near each other
    assert np.abs(spec - target).sum() < 0.08
    assert np.abs(spec - ref).sum() < 0.10


def test_acceptance_probability_is_target_mass_of_draft():
    """The draft token is accepted with probability p(draft) under the
    filtered+tempered target — the rejection-sampling identity's other
    half. Estimated over R seeds, against the analytic softmax mass."""
    v, r = 10, 4000
    rng = np.random.default_rng(13)
    row = (rng.normal(size=(v,)) * 1.2).astype(np.float32)
    logits = np.broadcast_to(row, (r, v)).reshape(r, 1, v).copy()
    d = int(np.argsort(row)[-2])  # a mid-mass token
    params = [SamplingParams(temperature=1.0, seed=s) for s in range(r)]
    draft = np.full((r, 1), d, np.int32)
    out, n, _ = _verify(draft, [1] * r, logits, params, [0] * r)
    accepted = (out[:, 0] == d) & (n >= 1)
    p = np.exp(row - row.max())
    p /= p.sum()
    # accepted rows include residual draws that landed on d by chance:
    # P(emit d) = p(d) + (1 - p(d)) * 0 (residual excludes d) -> exactly p(d)
    assert abs(accepted.mean() - p[d]) < 0.04


def test_top_k_top_p_speculation_stays_in_support():
    """Accepted/corrected tokens under top-k / top-p rows never leave the
    filtered support, exactly like sample_tokens."""
    v, r, kd = 16, 512, 2
    logits = _rand_logits(r, kd + 1, v, seed=17, scale=1.0)
    params = [SamplingParams(temperature=1.1, top_k=4, seed=s)
              for s in range(r)]
    rng = np.random.default_rng(19)
    draft = rng.integers(0, v, (r, kd)).astype(np.int32)
    out, n, _ = _verify(draft, [kd] * r, logits, params, [0] * r)
    topk = np.argsort(logits, axis=-1)[..., -4:]
    for row in range(r):
        for j in range(n[row]):
            assert out[row, j] in topk[row, j]
