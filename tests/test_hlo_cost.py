"""Tests for the trip-count-aware HLO cost analyzer."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.hlo_cost import analyze, parse_computations


def _compiled_text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_single_matmul_flops_exact():
    x = jnp.zeros((128, 128))
    c = analyze(_compiled_text(lambda a: a @ a, x))
    assert c.flops == 2 * 128 ** 3


def test_scan_multiplies_by_trip_count():
    def ten(a):
        def body(c, _):
            return c @ c, None

        y, _ = jax.lax.scan(body, a, None, length=10)
        return y

    x = jnp.zeros((64, 64))
    c1 = analyze(_compiled_text(lambda a: a @ a, x))
    c10 = analyze(_compiled_text(ten, x))
    assert c10.flops == 10 * c1.flops
    assert c10.unknown_trip_loops == 0


def test_nested_scan_multiplies():
    def nested(a):
        def outer(c, _):
            def inner(ci, _):
                return ci @ ci, None

            ci, _ = jax.lax.scan(inner, c, None, length=3)
            return ci, None

        y, _ = jax.lax.scan(outer, a, None, length=5)
        return y

    x = jnp.zeros((32, 32))
    c = analyze(_compiled_text(nested, x))
    assert c.flops == 15 * 2 * 32 ** 3


def test_memory_estimate_positive_and_scales():
    x = jnp.zeros((256, 256))
    small = analyze(_compiled_text(lambda a: a + 1.0, x))
    big = analyze(_compiled_text(lambda a: (a @ a) + (a.T @ a), x))
    assert 0 < small.memory_bytes < big.memory_bytes


def test_train_step_flops_within_remat_band():
    """End-to-end: analyzer flops vs analytic 6·N·D on a real train step
    must land in the [1, 3]× band (remat + attention overhead), not the
    ~100× error of raw cost_analysis."""
    import dataclasses

    from repro.configs import get_config
    from repro.models.transformer import RuntimeOpts
    from repro.training.optimizer import AdamWConfig
    from repro.training.train_loop import TrainConfig, init_train_state, make_train_step

    cfg = dataclasses.replace(get_config("llama2-7b").tiny(), num_blocks=6)
    opts = RuntimeOpts(q_chunk=32, kv_chunk=32, remat=True)
    tc = TrainConfig(AdamWConfig(), accum_steps=2, batch_pre_split=False)
    params, opt = jax.eval_shape(
        lambda: init_train_state(cfg, jax.random.PRNGKey(0)))
    b, s = 8, 64
    batch = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
             "labels": jax.ShapeDtypeStruct((b, s), jnp.int32),
             "loss_mask": jax.ShapeDtypeStruct((b, s), jnp.float32)}
    step = make_train_step(cfg, tc, opts)
    comp = jax.jit(step).lower(params, opt, batch).compile()
    c = analyze(comp.as_text())
    analytic = 6.0 * cfg.total_params() * b * s
    ratio = c.flops / analytic
    assert 0.8 < ratio < 4.0, f"flops ratio {ratio}"


def test_parse_computations_finds_entry():
    x = jnp.zeros((16, 16))
    comps = parse_computations(_compiled_text(lambda a: a @ a + 1, x))
    assert "__entry__" in comps
    assert any(op.kind == "dot" for c in comps.values() for op in c.ops)
