"""Per-architecture smoke tests: instantiate the REDUCED (tiny) variant of
each assigned family (≤2 layers, d_model ≤ 512, ≤4 experts) and run one
forward/train step + one prefill/decode step on CPU, asserting output shapes
and absence of NaNs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, get_config
from repro.models.transformer import (RuntimeOpts, decode_step, forward_train,
                                      init_params, prefill)

OPTS = RuntimeOpts(q_chunk=16, kv_chunk=16, remat=False, moe_capacity_factor=0.0)
BATCH, SEQ = 2, 24


def _make_inputs(cfg, b=BATCH, s=SEQ, seed=0):
    rng = np.random.default_rng(seed)
    if cfg.embed == "musicgen":
        tokens = rng.integers(0, cfg.vocab_size, (b, s, cfg.num_codebooks))
        return jnp.asarray(tokens, jnp.int32), None
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)
    patches = None
    if cfg.embed == "vlm":
        patches = jnp.asarray(rng.normal(size=(b, cfg.num_patches, cfg.d_vision)),
                              jnp.float32)
    return tokens, patches


@pytest.fixture(scope="module")
def arch_state():
    cache = {}

    def get(name):
        if name not in cache:
            cfg = get_config(name).tiny()
            params = init_params(cfg, jax.random.PRNGKey(0))
            cache[name] = (cfg, params)
        return cache[name]

    return get


@pytest.mark.parametrize("name", ASSIGNED + ["llama2-7b"])
def test_forward_shapes_and_no_nans(arch_state, name):
    cfg, params = arch_state(name)
    tokens, patches = _make_inputs(cfg)
    logits, aux = forward_train(params, cfg, tokens, patches, OPTS)
    if cfg.num_codebooks > 1:
        assert logits.shape == (BATCH, SEQ, cfg.num_codebooks, cfg.vocab_size)
    else:
        assert logits.shape == (BATCH, SEQ, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("name", ASSIGNED + ["llama2-7b"])
def test_train_step_no_nans(arch_state, name):
    cfg, params = arch_state(name)
    tokens, patches = _make_inputs(cfg)

    def loss_fn(p):
        logits, aux = forward_train(p, cfg, tokens, patches, OPTS)
        if cfg.num_codebooks > 1:
            labels = tokens[:, 1:]
            lp = jax.nn.log_softmax(logits[:, :-1])
            ce = -jnp.mean(jnp.take_along_axis(lp, labels[..., None], -1))
        else:
            labels = tokens[:, 1:]
            lp = jax.nn.log_softmax(logits[:, :-1])
            ce = -jnp.mean(jnp.take_along_axis(lp, labels[..., None], -1))
        return ce + 0.01 * aux

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert bool(jnp.isfinite(loss))
    flat = jax.tree_util.tree_leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in flat)
    # at least the embedding gradient must be non-zero
    assert float(jnp.max(jnp.abs(grads["embed"]))) > 0


@pytest.mark.parametrize("name", ASSIGNED)
def test_prefill_decode_consistency(arch_state, name):
    """prefill(S tokens) + decode(token S) must match forward on S+1 tokens."""
    cfg, params = arch_state(name)
    tokens, patches = _make_inputs(cfg, s=SEQ + 1)
    full_logits, _ = forward_train(params, cfg, tokens, patches, OPTS)

    last, caches = prefill(params, cfg, tokens[:, :SEQ], patches,
                           cache_len=SEQ + 8, opts=OPTS)
    np.testing.assert_allclose(np.asarray(last), np.asarray(full_logits[:, SEQ - 1]),
                               rtol=2e-2, atol=5e-3)
    step_logits, caches = decode_step(params, cfg, tokens[:, SEQ:SEQ + 1], caches,
                                      jnp.int32(SEQ), OPTS)
    np.testing.assert_allclose(np.asarray(step_logits), np.asarray(full_logits[:, SEQ]),
                               rtol=2e-2, atol=5e-3)


@pytest.mark.parametrize("name", ["gemma2-2b", "internlm2-20b", "jamba-v0.1-52b"])
def test_quantized_kv_decode_close(arch_state, name):
    """int8 KV cache (the paper's Q^a on the cache) ≈ bf16 cache decode."""
    cfg, params = arch_state(name)
    tokens, patches = _make_inputs(cfg, s=SEQ + 1)
    opts_q = RuntimeOpts(q_chunk=16, kv_chunk=16, remat=False, quantized_kv=True,
                         moe_capacity_factor=0.0)

    _, caches = prefill(params, cfg, tokens[:, :SEQ], patches, cache_len=SEQ + 8,
                        opts=OPTS)
    ref, _ = decode_step(params, cfg, tokens[:, SEQ:SEQ + 1], caches, jnp.int32(SEQ), OPTS)

    _, caches_q = prefill(params, cfg, tokens[:, :SEQ], patches, cache_len=SEQ + 8,
                          opts=opts_q)
    out, _ = decode_step(params, cfg, tokens[:, SEQ:SEQ + 1], caches_q, jnp.int32(SEQ),
                         opts_q)
    # int8 cache error is small relative to the logit scale
    scale = float(jnp.maximum(jnp.max(jnp.abs(ref)), 1e-3))
    assert float(jnp.max(jnp.abs(out - ref))) / scale < 0.08


def test_sliding_window_masks_distant_tokens():
    """A distant token outside the window must not influence the output."""
    cfg = get_config("h2o-danube-3-4b").tiny()  # window 16 in tiny
    params = init_params(cfg, jax.random.PRNGKey(1))
    rng = np.random.default_rng(3)
    s = 40  # > window 16
    base = rng.integers(0, cfg.vocab_size, (1, s))
    pert = base.copy()
    pert[0, 0] = (pert[0, 0] + 7) % cfg.vocab_size  # token 0 is > window away
    la, _ = forward_train(params, cfg, jnp.asarray(base, jnp.int32), None, OPTS)
    lb, _ = forward_train(params, cfg, jnp.asarray(pert, jnp.int32), None, OPTS)
    np.testing.assert_allclose(np.asarray(la[0, -1]), np.asarray(lb[0, -1]),
                               rtol=1e-4, atol=1e-4)
    assert float(jnp.max(jnp.abs(la[0, 1] - lb[0, 1]))) > 1e-4  # nearby differs


def test_param_counts_match_assignment():
    """Full-size configs roughly match the assigned parameter scales."""
    import math

    expect = {
        "gemma2-2b": (2.0e9, 3.5e9),
        "qwen2-vl-2b": (1.2e9, 2.3e9),
        "qwen3-moe-235b-a22b": (2.0e11, 2.6e11),
        "qwen2-moe-a2.7b": (1.1e10, 1.6e10),
        "h2o-danube-3-4b": (3.5e9, 4.5e9),
        "granite-34b": (3.0e10, 4.0e10),
        "mamba2-780m": (6.5e8, 9.5e8),
        "musicgen-medium": (1.3e9, 2.1e9),
        "jamba-v0.1-52b": (4.5e10, 6.0e10),
        "internlm2-20b": (1.7e10, 2.3e10),
    }
    for name, (lo, hi) in expect.items():
        n = get_config(name).total_params()
        assert lo <= n <= hi, f"{name}: {n / 1e9:.2f}B outside [{lo / 1e9}, {hi / 1e9}]"
    # MoE active params
    active = get_config("qwen3-moe-235b-a22b").total_params(active=True)
    assert 1.5e10 <= active <= 3.0e10  # ≈22B active
