"""Paged KV pool: block allocator semantics (alloc/free/LIFO reuse,
exhaustion, page-boundary appends), uniform-page validation, occupancy
accounting against Eq. 2, and the property that block-table gather of pool
pages reconstructs the dense quantized cache bit-exactly."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.kernels.decode_attention import padded_cache_len
from repro.models import layers as L
from repro.serving.kv_pool import PagedKVPool, PoolExhaustedError

CFG = get_config("llama2-7b").tiny()


def make_pool(num_pages=16, page_size=4, max_requests=3, **kw):
    return PagedKVPool(CFG, num_pages=num_pages, page_size=page_size,
                       max_requests=max_requests, **kw)


# ------------------------------------------------------------- allocator


def test_alloc_free_reuse_ordering():
    pool = make_pool()
    a = pool.admit(6)  # 2 pages
    b = pool.admit(4)  # 1 page
    pages_a = [p for p in pool.block_tables[a] if p != 0]
    pages_b = [p for p in pool.block_tables[b] if p != 0]
    assert len(pages_a) == 2 and len(pages_b) == 1
    assert not set(pages_a) & set(pages_b)  # disjoint
    assert 0 not in pages_a + pages_b  # trash page never handed out
    used = pool.pages_in_use
    pool.free(a)
    assert pool.pages_in_use == used - 2
    # LIFO reuse: the next admit gets a's just-freed pages back, most
    # recently freed first
    c = pool.admit(8)  # 2 pages
    pages_c = [p for p in pool.block_tables[c] if p != 0]
    assert set(pages_c) == set(pages_a)


def test_pool_exhaustion_raises():
    pool = make_pool(num_pages=4, page_size=4, max_requests=4)  # 3 usable
    pool.admit(12)  # takes all 3 pages
    with pytest.raises(PoolExhaustedError, match="exhausted"):
        pool.admit(4)
    assert not pool.can_admit(4)


def test_slot_exhaustion_raises():
    pool = make_pool(num_pages=16, page_size=4, max_requests=1)
    pool.admit(4)
    with pytest.raises(PoolExhaustedError, match="slots"):
        pool.admit(4)


def test_append_across_page_boundary():
    pool = make_pool(page_size=4)
    slot = pool.admit(4)  # exactly one page
    pool.commit_prefill(slot, 4)
    before = pool.pages_in_use
    pool.append(slot, 1)  # crosses into a second page
    assert pool.pages_in_use == before + 1
    assert int(pool.lengths[slot]) == 5
    pool.append(slot, 1)  # stays inside the second page
    assert pool.pages_in_use == before + 1
    # growing past max_blocks is a clean error, not silent corruption
    small = make_pool(num_pages=16, page_size=4, max_seq_len=8)
    s = small.admit(8)
    small.commit_prefill(s, 8)
    with pytest.raises(PoolExhaustedError, match="max_blocks"):
        small.append(s, 1)


def test_free_scrubs_positions_on_device():
    pool = make_pool(page_size=4)
    slot = pool.admit(4)
    page = int(pool.block_tables[slot][0])
    # simulate a written page: stored positions >= 0
    pool._caches = tuple(
        type(c)(c.k, c.v, c.k_scale, c.v_scale,
                c.pos.at[:, page].set(jnp.arange(4, dtype=jnp.int32)),
                c.block_table)
        for c in pool._caches)
    pool.free(slot)
    for c in pool._caches:
        assert int(jnp.max(c.pos[:, page])) == -1  # stale tokens unreachable


# ---------------------------------------------------- uniform-page contract


def test_padded_cache_len_uniform_flag():
    # dense contract: short lengths stay unpadded (single clamped block)
    assert padded_cache_len(40, 512) == 40
    assert padded_cache_len(600, 512) == 1024
    # pool contract: every length rounds to whole uniform pages
    assert padded_cache_len(40, 512, uniform=True) == 512
    assert padded_cache_len(512, 512, uniform=True) == 512
    assert padded_cache_len(600, 512, uniform=True) == 1024


def test_pool_rejects_bad_page_sizes():
    with pytest.raises(ValueError, match="positive"):
        make_pool(page_size=0)
    with pytest.raises(ValueError, match="reserved"):
        make_pool(num_pages=1)
    pool = make_pool(page_size=4)
    bad = tuple(
        type(c)(c.k[..., :3, :], c.v[..., :3, :], c.k_scale[..., :3],
                c.v_scale[..., :3], c.pos[..., :3], c.block_table)
        for c in pool._caches)
    with pytest.raises(ValueError, match="non-uniform page"):
        pool.update_from(bad)
    # a page dim that IS a multiple of page_size but not equal is still wrong
    doubled = tuple(
        type(c)(jnp.concatenate([c.k, c.k], axis=-2),
                jnp.concatenate([c.v, c.v], axis=-2),
                jnp.concatenate([c.k_scale, c.k_scale], axis=-1),
                jnp.concatenate([c.v_scale, c.v_scale], axis=-1),
                jnp.concatenate([c.pos, c.pos], axis=-1), c.block_table)
        for c in pool._caches)
    with pytest.raises(ValueError, match="non-uniform page size"):
        pool.update_from(doubled)


def test_pool_rejects_sliding_window_patterns():
    gemma = get_config("gemma2-2b").tiny()  # local/global alternation
    with pytest.raises(NotImplementedError, match="sliding-window"):
        PagedKVPool(gemma, num_pages=8, page_size=4, max_requests=1)


# ------------------------------------------------------------- accounting


def test_occupancy_and_eq2_accounting():
    pool = make_pool(num_pages=9, page_size=4)  # 8 usable pages
    assert pool.occupancy() == 0.0 and pool.eq2_bytes() == 0
    slot = pool.admit(6)  # 2 pages
    pool.commit_prefill(slot, 6)
    assert pool.occupancy() == pytest.approx(2 / 8)
    eq2 = pool.eq2_bytes()
    paged = pool.page_bytes_in_use()
    assert eq2 > 0 and paged > 0
    # page granularity over-allocates vs the analytical Eq. 2 bytes
    # (internal fragmentation: 8 slots held for 6 tokens)
    assert paged > eq2 * 0.5  # same order of magnitude
    pool.free(slot)
    assert pool.occupancy() == 0.0 and pool.eq2_bytes() == 0


def test_paged_update_routes_out_of_table_positions_to_trash():
    """A position past the block table's reach, or one whose table entry is
    still unallocated (caller skipped the host-side append), must behave
    like a pad — never overwrite a live page slot, and never store a real
    position on the shared trash page (cross-request leak)."""
    pool = make_pool(num_pages=8, page_size=4, max_requests=1,
                     max_seq_len=8)  # max_blocks = 2
    slot = pool.admit(4)  # one page allocated; table entry 1 stays 0
    cache = jax.tree_util.tree_map(lambda a: a[0],
                                   pool.device_caches(rows=[slot])[0])
    kv = jnp.ones((1, 4, CFG.pattern[0].mixer.num_kv_heads,
                   CFG.pattern[0].mixer.head_dim), jnp.float32)
    cache = L.paged_cache_update(cache, kv, kv,
                                 jnp.asarray([[0, 1, 2, 3]], jnp.int32))
    live = np.asarray(cache.pos[int(pool.block_tables[slot][0])]).copy()
    one = jnp.ones((1, 1) + kv.shape[2:], jnp.float32)
    # position 9 exceeds max_blocks * page = 8; position 5 is in reach but
    # its table entry is unallocated (0) — both must leave live pages and
    # the trash page's -1 positions untouched
    for bad_pos in (9, 5):
        cache = L.paged_cache_update(cache, one, one,
                                     jnp.asarray([[bad_pos]], jnp.int32))
        np.testing.assert_array_equal(
            np.asarray(cache.pos[int(pool.block_tables[slot][0])]), live)
        assert int(jnp.max(cache.pos[0])) == -1  # trash page stays masked


# ---------------------------------------- gather reconstructs dense cache


@pytest.mark.parametrize("lens", [(5, 8, 3), (4, 4, 4), (1, 9, 2)])
def test_gather_reconstructs_dense_cache_bit_exact(lens):
    """Property: writing a ragged batch through paged_cache_update and
    gathering each request's pages by its block table must reproduce the
    dense quantized cache of the same tokens BIT-exactly (same per-token
    quantization, different addressing only)."""
    spec = CFG.pattern[0].mixer
    kh, hd = spec.num_kv_heads, spec.head_dim
    page = 4
    pool = make_pool(num_pages=16, page_size=page)
    rng = np.random.default_rng(sum(lens))
    r, s_pad = len(lens), max(lens)
    kv = rng.normal(size=(r, s_pad, kh, hd)).astype(np.float32)

    slots = [pool.admit(n) for n in lens]
    posn = np.full((r, s_pad), -1, np.int32)
    for i, n in enumerate(lens):  # right-aligned ragged positions
        posn[i, s_pad - n:] = np.arange(n)
    caches = pool.device_caches(rows=slots)
    updated = tuple(
        L.paged_cache_update(
            jax.tree_util.tree_map(lambda a: a[0], c),
            jnp.asarray(kv), jnp.asarray(kv), jnp.asarray(posn))
        for c in caches)
    # write back with the nb axis restored (nb=2 identical layer slices)
    pool.update_from(tuple(
        jax.tree_util.tree_map(lambda a: jnp.stack([a] * pool.nb), u)
        for u in updated))
    for i, (slot, n) in enumerate(zip(slots, lens)):
        pool.commit_prefill(slot, n)

    for i, (slot, n) in enumerate(zip(slots, lens)):
        # dense reference: same tokens through the dense quantized cache
        dense = L.init_cache(1, n, kh, hd, quantized=True)
        valid = kv[i, s_pad - n:][None]  # (1, n, K, hd)
        dense = L.cache_update(dense, jnp.asarray(valid), jnp.asarray(valid),
                               jnp.int32(0))
        got = pool.gather_dense(slot)[0]  # pattern position 0
        gk, gv, gks, gvs, gpos = (np.asarray(x[0]) for x in got)
        order = np.argsort(np.asarray(gpos))  # gather is block-table order
        keep = np.asarray(gpos) >= 0
        assert keep.sum() == n
        sl = order[-n:]  # the n valid slots, position-sorted
        np.testing.assert_array_equal(gk[:, sl], np.asarray(dense.k[0]))
        np.testing.assert_array_equal(gv[:, sl], np.asarray(dense.v[0]))
        np.testing.assert_array_equal(gks[:, sl], np.asarray(dense.k_scale[0]))
        np.testing.assert_array_equal(gvs[:, sl], np.asarray(dense.v_scale[0]))
        np.testing.assert_array_equal(np.asarray(gpos)[sl], np.arange(n))
