"""Paged KV pool: block allocator semantics (alloc/free/LIFO reuse,
exhaustion, page-boundary appends), uniform-page validation, occupancy
accounting against Eq. 2, the property that block-table gather of pool
pages reconstructs the dense quantized cache bit-exactly, and the
refcounted copy-on-write ownership model (share_prefix/fork, CoW on append
into a shared page, double-free protection, randomized invariant walk)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.kernels.decode_attention import padded_cache_len
from repro.models import layers as L
from repro.serving.kv_pool import PagedKVPool, PoolExhaustedError

CFG = get_config("llama2-7b").tiny()


def make_pool(num_pages=16, page_size=4, max_requests=3, **kw):
    return PagedKVPool(CFG, num_pages=num_pages, page_size=page_size,
                       max_requests=max_requests, **kw)


# ------------------------------------------------------------- allocator


def test_alloc_free_reuse_ordering():
    pool = make_pool()
    a = pool.admit(6)  # 2 pages
    b = pool.admit(4)  # 1 page
    pages_a = [p for p in pool.block_tables[a] if p != 0]
    pages_b = [p for p in pool.block_tables[b] if p != 0]
    assert len(pages_a) == 2 and len(pages_b) == 1
    assert not set(pages_a) & set(pages_b)  # disjoint
    assert 0 not in pages_a + pages_b  # trash page never handed out
    used = pool.pages_in_use
    pool.free(a)
    assert pool.pages_in_use == used - 2
    # LIFO reuse: the next admit gets a's just-freed pages back, most
    # recently freed first
    c = pool.admit(8)  # 2 pages
    pages_c = [p for p in pool.block_tables[c] if p != 0]
    assert set(pages_c) == set(pages_a)


def test_pool_exhaustion_raises():
    pool = make_pool(num_pages=4, page_size=4, max_requests=4)  # 3 usable
    pool.admit(12)  # takes all 3 pages
    with pytest.raises(PoolExhaustedError, match="exhausted"):
        pool.admit(4)
    assert not pool.can_admit(4)


def test_slot_exhaustion_raises():
    pool = make_pool(num_pages=16, page_size=4, max_requests=1)
    pool.admit(4)
    with pytest.raises(PoolExhaustedError, match="slots"):
        pool.admit(4)


def test_append_across_page_boundary():
    pool = make_pool(page_size=4)
    slot = pool.admit(4)  # exactly one page
    pool.commit_prefill(slot, 4)
    before = pool.pages_in_use
    pool.append(slot, 1)  # crosses into a second page
    assert pool.pages_in_use == before + 1
    assert int(pool.lengths[slot]) == 5
    pool.append(slot, 1)  # stays inside the second page
    assert pool.pages_in_use == before + 1
    # growing past max_blocks is a clean error, not silent corruption
    small = make_pool(num_pages=16, page_size=4, max_seq_len=8)
    s = small.admit(8)
    small.commit_prefill(s, 8)
    with pytest.raises(PoolExhaustedError, match="max_blocks"):
        small.append(s, 1)


def test_free_scrubs_positions_on_device():
    pool = make_pool(page_size=4)
    slot = pool.admit(4)
    page = int(pool.block_tables[slot][0])
    # simulate a written page: stored positions >= 0
    pool._caches = tuple(
        type(c)(c.k, c.v, c.k_scale, c.v_scale,
                c.pos.at[:, page].set(jnp.arange(4, dtype=jnp.int32)),
                c.block_table)
        for c in pool._caches)
    pool.free(slot)
    for c in pool._caches:
        assert int(jnp.max(c.pos[:, page])) == -1  # stale tokens unreachable


# ---------------------------------------------------- uniform-page contract


def test_padded_cache_len_uniform_flag():
    # dense contract: short lengths stay unpadded (single clamped block)
    assert padded_cache_len(40, 512) == 40
    assert padded_cache_len(600, 512) == 1024
    # pool contract: every length rounds to whole uniform pages
    assert padded_cache_len(40, 512, uniform=True) == 512
    assert padded_cache_len(512, 512, uniform=True) == 512
    assert padded_cache_len(600, 512, uniform=True) == 1024


def test_pool_rejects_bad_page_sizes():
    with pytest.raises(ValueError, match="positive"):
        make_pool(page_size=0)
    with pytest.raises(ValueError, match="reserved"):
        make_pool(num_pages=1)
    pool = make_pool(page_size=4)
    bad = tuple(
        type(c)(c.k[..., :3, :], c.v[..., :3, :], c.k_scale[..., :3],
                c.v_scale[..., :3], c.pos[..., :3], c.block_table)
        for c in pool._caches)
    with pytest.raises(ValueError, match="non-uniform page"):
        pool.update_from(bad)
    # a page dim that IS a multiple of page_size but not equal is still wrong
    doubled = tuple(
        type(c)(jnp.concatenate([c.k, c.k], axis=-2),
                jnp.concatenate([c.v, c.v], axis=-2),
                jnp.concatenate([c.k_scale, c.k_scale], axis=-1),
                jnp.concatenate([c.v_scale, c.v_scale], axis=-1),
                jnp.concatenate([c.pos, c.pos], axis=-1), c.block_table)
        for c in pool._caches)
    with pytest.raises(ValueError, match="non-uniform page size"):
        pool.update_from(doubled)


def test_pool_rejects_sliding_window_patterns():
    gemma = get_config("gemma2-2b").tiny()  # local/global alternation
    with pytest.raises(NotImplementedError, match="sliding-window"):
        PagedKVPool(gemma, num_pages=8, page_size=4, max_requests=1)


# ------------------------------------------------------------- accounting


def test_occupancy_and_eq2_accounting():
    pool = make_pool(num_pages=9, page_size=4)  # 8 usable pages
    assert pool.occupancy() == 0.0 and pool.eq2_bytes() == 0
    slot = pool.admit(6)  # 2 pages
    pool.commit_prefill(slot, 6)
    assert pool.occupancy() == pytest.approx(2 / 8)
    eq2 = pool.eq2_bytes()
    paged = pool.page_bytes_in_use()
    assert eq2 > 0 and paged > 0
    # page granularity over-allocates vs the analytical Eq. 2 bytes
    # (internal fragmentation: 8 slots held for 6 tokens)
    assert paged > eq2 * 0.5  # same order of magnitude
    pool.free(slot)
    assert pool.occupancy() == 0.0 and pool.eq2_bytes() == 0


def test_paged_update_routes_out_of_table_positions_to_trash():
    """A position past the block table's reach, or one whose table entry is
    still unallocated (caller skipped the host-side append), must behave
    like a pad — never overwrite a live page slot, and never store a real
    position on the shared trash page (cross-request leak)."""
    pool = make_pool(num_pages=8, page_size=4, max_requests=1,
                     max_seq_len=8)  # max_blocks = 2
    slot = pool.admit(4)  # one page allocated; table entry 1 stays 0
    cache = jax.tree_util.tree_map(lambda a: a[0],
                                   pool.device_caches(rows=[slot])[0])
    kv = jnp.ones((1, 4, CFG.pattern[0].mixer.num_kv_heads,
                   CFG.pattern[0].mixer.head_dim), jnp.float32)
    cache = L.paged_cache_update(cache, kv, kv,
                                 jnp.asarray([[0, 1, 2, 3]], jnp.int32))
    live = np.asarray(cache.pos[int(pool.block_tables[slot][0])]).copy()
    one = jnp.ones((1, 1) + kv.shape[2:], jnp.float32)
    # position 9 exceeds max_blocks * page = 8; position 5 is in reach but
    # its table entry is unallocated (0) — both must leave live pages and
    # the trash page's -1 positions untouched
    for bad_pos in (9, 5):
        cache = L.paged_cache_update(cache, one, one,
                                     jnp.asarray([[bad_pos]], jnp.int32))
        np.testing.assert_array_equal(
            np.asarray(cache.pos[int(pool.block_tables[slot][0])]), live)
        assert int(jnp.max(cache.pos[0])) == -1  # trash page stays masked


# ---------------------------------------- gather reconstructs dense cache


@pytest.mark.parametrize("lens", [(5, 8, 3), (4, 4, 4), (1, 9, 2)])
def test_gather_reconstructs_dense_cache_bit_exact(lens):
    """Property: writing a ragged batch through paged_cache_update and
    gathering each request's pages by its block table must reproduce the
    dense quantized cache of the same tokens BIT-exactly (same per-token
    quantization, different addressing only)."""
    spec = CFG.pattern[0].mixer
    kh, hd = spec.num_kv_heads, spec.head_dim
    page = 4
    pool = make_pool(num_pages=16, page_size=page)
    rng = np.random.default_rng(sum(lens))
    r, s_pad = len(lens), max(lens)
    kv = rng.normal(size=(r, s_pad, kh, hd)).astype(np.float32)

    slots = [pool.admit(n) for n in lens]
    posn = np.full((r, s_pad), -1, np.int32)
    for i, n in enumerate(lens):  # right-aligned ragged positions
        posn[i, s_pad - n:] = np.arange(n)
    caches = pool.device_caches(rows=slots)
    updated = tuple(
        L.paged_cache_update(
            jax.tree_util.tree_map(lambda a: a[0], c),
            jnp.asarray(kv), jnp.asarray(kv), jnp.asarray(posn))
        for c in caches)
    # write back with the nb axis restored (nb=2 identical layer slices)
    pool.update_from(tuple(
        jax.tree_util.tree_map(lambda a: jnp.stack([a] * pool.nb), u)
        for u in updated))
    for i, (slot, n) in enumerate(zip(slots, lens)):
        pool.commit_prefill(slot, n)

    for i, (slot, n) in enumerate(zip(slots, lens)):
        # dense reference: same tokens through the dense quantized cache
        dense = L.init_cache(1, n, kh, hd, quantized=True)
        valid = kv[i, s_pad - n:][None]  # (1, n, K, hd)
        dense = L.cache_update(dense, jnp.asarray(valid), jnp.asarray(valid),
                               jnp.int32(0))
        got = pool.gather_dense(slot)[0]  # pattern position 0
        gk, gv, gks, gvs, gpos = (np.asarray(x[0]) for x in got)
        order = np.argsort(np.asarray(gpos))  # gather is block-table order
        keep = np.asarray(gpos) >= 0
        assert keep.sum() == n
        sl = order[-n:]  # the n valid slots, position-sorted
        np.testing.assert_array_equal(gk[:, sl], np.asarray(dense.k[0]))
        np.testing.assert_array_equal(gv[:, sl], np.asarray(dense.v[0]))
        np.testing.assert_array_equal(gks[:, sl], np.asarray(dense.k_scale[0]))
        np.testing.assert_array_equal(gvs[:, sl], np.asarray(dense.v_scale[0]))
        np.testing.assert_array_equal(np.asarray(gpos)[sl], np.arange(n))


# --------------------------------------------- refcounts / CoW / prefixes


def test_share_prefix_fork_refcounts_and_cow():
    """Fork onto a 6-token prefix (page 4 → partial boundary page): shared
    full page aliased, boundary page CoW-copied, refcounts track every
    owner, and pages only return to the free list at refcount zero."""
    pool = make_pool(num_pages=16, page_size=4)
    a = pool.admit(6)
    pool.commit_prefill(a, 6)
    h = pool.share_prefix(a, 6)
    p0, p1 = h.pages
    assert pool.refcount[p0] == 2 and pool.refcount[p1] == 2  # slot + handle
    assert pool.pages_shared == 2

    b = pool.admit(8, prefix=h)
    assert int(pool.lengths[b]) == 6  # prefix tokens already resident
    tb = pool.block_tables[b]
    assert tb[0] == p0  # full prefix page aliased, not copied
    cow = int(tb[1])
    assert cow not in (p1, 0)  # boundary page copy-on-write
    assert pool.refcount[p0] == 3  # a + handle + b
    assert pool.refcount[p1] == 2  # a + handle (b dropped it for the copy)
    assert pool.refcount[cow] == 1
    assert pool.pages_in_use == 3  # p0, p1, cow — shared counted once

    pool.free(a)
    assert pool.refcount[p0] == 2 and pool.refcount[p1] == 1  # handle holds
    pool.free(b)
    assert pool.refcount[p0] == 1 and pool.refcount[cow] == 0
    pool.release_prefix(h)
    assert pool.pages_in_use == 0
    assert int(pool.refcount.sum()) == 0
    pool.release_prefix(h)  # idempotent


def test_cow_copy_scrubs_foreign_positions():
    """The CoW copy keeps only positions < the forker's length: the
    creator's tokens past the shared prefix are scrubbed to -1 in the copy
    so they can never leak into the fork's attention."""
    pool = make_pool(num_pages=16, page_size=4)
    a = pool.admit(8)
    pool.commit_prefill(a, 8)  # creator wrote positions 0..7 (2 pages)
    p1 = int(pool.block_tables[a][1])
    # simulate device contents of the boundary page: positions 4..7
    pool._caches = tuple(
        type(c)(c.k, c.v, c.k_scale, c.v_scale,
                c.pos.at[:, p1].set(jnp.arange(4, 8, dtype=jnp.int32)),
                c.block_table)
        for c in pool._caches)
    h = pool.share_prefix(a, 6)  # prefix covers positions 0..5 only
    b = pool.admit(7, prefix=h)
    cow = int(pool.block_tables[b][1])
    for c in pool._caches:
        got = np.asarray(c.pos[:, cow])
        np.testing.assert_array_equal(got, np.tile([4, 5, -1, -1],
                                                   (pool.nb, 1)))
        # the original page is untouched
        np.testing.assert_array_equal(np.asarray(c.pos[:, p1]),
                                      np.tile([4, 5, 6, 7], (pool.nb, 1)))


def test_cow_on_append_into_shared_page():
    """The CREATOR side of CoW: once its boundary page is pinned by a
    shared prefix, the creator's own append must copy before writing."""
    pool = make_pool(num_pages=16, page_size=4)
    a = pool.admit(6)
    pool.commit_prefill(a, 6)
    h = pool.share_prefix(a, 6)
    p1 = int(pool.block_tables[a][1])
    pool.append(a, 1)  # next write lands in the shared boundary page
    new = int(pool.block_tables[a][1])
    assert new != p1
    assert pool.refcount[p1] == 1  # handle only
    assert pool.refcount[new] == 1
    assert int(pool.lengths[a]) == 7


def test_aligned_prefix_forks_without_cow():
    """A page-aligned prefix needs no boundary copy: the fork's first write
    lands in a fresh page."""
    pool = make_pool(num_pages=16, page_size=4)
    a = pool.admit(8)
    pool.commit_prefill(a, 8)
    h = pool.share_prefix(a, 8)  # exactly 2 full pages
    before = pool.pages_in_use
    b = pool.admit(9, prefix=h)
    assert pool.pages_in_use == before + 1  # one suffix page, zero copies
    assert tuple(pool.block_tables[b][:2]) == h.pages


def test_fork_admission_is_atomic_on_exhaustion():
    """A fork that cannot afford its CoW + suffix pages raises BEFORE any
    state changes — refcounts, tables and the free list stay intact."""
    pool = make_pool(num_pages=4, page_size=4, max_requests=3)  # 3 usable
    a = pool.admit(6)  # 2 pages
    pool.commit_prefill(a, 6)
    h = pool.share_prefix(a, 6)
    rc = pool.refcount.copy()
    free = list(pool._free)
    with pytest.raises(PoolExhaustedError, match="fork needs"):
        pool.admit(10, prefix=h)  # wants CoW + 1 suffix page, only 1 free
    np.testing.assert_array_equal(rc, pool.refcount)
    assert pool._free == free
    assert not pool.active[1:].any()


def test_double_free_is_an_assert_never_silent_reuse():
    pool = make_pool()
    a = pool.admit(4)
    pool.free(a)
    with pytest.raises(AssertionError, match="not active"):
        pool.free(a)
    with pytest.raises(AssertionError, match="double free"):
        pool._decref([int(pool._free[-1])])


def test_page_bytes_written_counts_shared_pages_once():
    pool = make_pool(num_pages=16, page_size=4)
    a = pool.admit(8)
    pool.commit_prefill(a, 8)
    solo = pool.page_bytes_written()
    assert solo == 2 * pool.page_bytes()
    h = pool.share_prefix(a, 8)
    b = pool.admit(9, prefix=h)
    pool.commit_prefill(b, 9)
    # a holds pages {p0,p1}; b holds {p0,p1,s} — shipment moves 3 pages,
    # not 5 (the shared prefix crosses the uplink once)
    assert pool.page_bytes_written() == 3 * pool.page_bytes()
    # the logical per-request Eq. 2 total keeps double-counting (8 + 9
    # tokens): the gap vs page bytes IS the sharing win
    assert pool.eq2_bytes() > pool.page_bytes_written() * 0  # sanity
    pool.release_prefix(h)


# ------------------------------------------------- speculative KV rollback


def test_truncate_rolls_back_rejected_tail():
    """The speculative-rollback primitive: truncate scrubs stored positions
    >= new_len on device (a rejected draft token can never be attended or
    swapped out), keeps the pages allocated (they sit inside the slot's
    reservation; the next append rewrites the same page slots), and leaves
    everything below the cut untouched."""
    pool = make_pool(num_pages=16, page_size=4)
    s = pool.admit(6)            # pages p0 (pos 0..3), p1 (pos 4..5)
    pool.commit_prefill(s, 6)
    pool.append(s, 3)            # draft burst: pos 6..8 — p1 fills, p2 opens
    p0, p1, p2 = (int(p) for p in pool.block_tables[s][:3])
    pool._caches = tuple(
        type(c)(c.k, c.v, c.k_scale, c.v_scale,
                c.pos.at[:, p0].set(jnp.arange(4, dtype=jnp.int32))
                     .at[:, p1].set(jnp.arange(4, 8, dtype=jnp.int32))
                     .at[:, p2].set(jnp.asarray([8, -1, -1, -1], jnp.int32)),
                c.block_table)
        for c in pool._caches)
    used = pool.pages_in_use
    pool.truncate(s, 6)          # verify rejected the whole 3-token draft
    assert int(pool.lengths[s]) == 6
    assert pool.pages_in_use == used  # rollback never frees pages
    for c in pool._caches:
        np.testing.assert_array_equal(
            np.asarray(c.pos[:, p0]), np.tile(np.arange(4), (pool.nb, 1)))
        np.testing.assert_array_equal(
            np.asarray(c.pos[:, p1]), np.tile([4, 5, -1, -1], (pool.nb, 1)))
        assert int(jnp.max(c.pos[:, p2])) == -1
    # bounds: rolling back to zero or past the length is a caller bug
    with pytest.raises(ValueError, match="outside"):
        pool.truncate(s, 0)
    with pytest.raises(ValueError, match="outside"):
        pool.truncate(s, 7)


def test_truncate_refuses_to_scrub_shared_pages():
    """A rollback that would reach into a refcount > 1 page is a caller
    bug — shared prefix tokens are immutable. The refusal must leave pool
    state AND the shared page's device positions untouched; once the other
    reference drops, the same rollback proceeds."""
    pool = make_pool(num_pages=16, page_size=4)
    a = pool.admit(8)
    pool.commit_prefill(a, 8)
    p1 = int(pool.block_tables[a][1])
    pool._caches = tuple(
        type(c)(c.k, c.v, c.k_scale, c.v_scale,
                c.pos.at[:, p1].set(jnp.arange(4, 8, dtype=jnp.int32)),
                c.block_table)
        for c in pool._caches)
    h = pool.share_prefix(a, 8)  # p1 now refcount 2
    before_ref = pool.refcount.copy()
    before_len = pool.lengths.copy()
    with pytest.raises(ValueError, match="shared page"):
        pool.truncate(a, 6)      # the cut lands inside the shared page
    np.testing.assert_array_equal(pool.refcount, before_ref)
    np.testing.assert_array_equal(pool.lengths, before_len)
    for c in pool._caches:
        np.testing.assert_array_equal(np.asarray(c.pos[:, p1]),
                                      np.tile([4, 5, 6, 7], (pool.nb, 1)))
    pool.release_prefix(h)
    pool.truncate(a, 6)          # exclusive again → rollback proceeds
    for c in pool._caches:
        np.testing.assert_array_equal(np.asarray(c.pos[:, p1]),
                                      np.tile([4, 5, -1, -1], (pool.nb, 1)))


# --------------------------------------------------- randomized invariants


def _check_pool_invariants(pool, handles):
    """The ownership-model invariants the docstring promises: refcounts
    equal the live references (block-table entries of active slots + unreleased
    handles), the free list is duplicate-free and disjoint from live pages,
    every page is accounted for, and physical residency never exceeds the
    pool."""
    refs = np.zeros((pool.num_pages,), np.int64)
    for slot in np.flatnonzero(pool.active):
        for p in pool.block_tables[slot]:
            if p != 0:
                refs[p] += 1
    for h in handles:
        if not h.released:
            for p in h.pages:
                refs[p] += 1
    np.testing.assert_array_equal(refs, pool.refcount)
    free = pool._free
    assert len(set(free)) == len(free), "free list holds duplicates"
    assert all(pool.refcount[p] == 0 for p in free), "free list holds live pages"
    live = {p for p in range(1, pool.num_pages) if pool.refcount[p] > 0}
    assert live | set(free) == set(range(1, pool.num_pages)), "page leaked"
    assert pool.pages_in_use <= pool.num_pages - 1
    assert pool.page_bytes_in_use() <= (pool.num_pages - 1) * pool.page_bytes()
    for slot in np.flatnonzero(pool.active):
        npages = int(np.count_nonzero(pool.block_tables[slot]))
        assert npages >= pool.pages_for(max(1, int(pool.lengths[slot])))
    # the per-tick telemetry gauges are views of the same counters — they
    # must agree with the allocator state at every step of the walk
    g = pool.gauges()
    assert g["pages_in_use"] == pool.pages_in_use
    assert g["pages_shared"] == int(np.count_nonzero(pool.refcount > 1))
    assert g["pages_free"] == pool.free_pages
    assert g["pages_in_use"] + g["pages_free"] == pool.num_pages - 1
    assert g["swap_bytes"] >= 0
    assert g["page_bytes_in_use"] == pool.page_bytes_in_use()
    assert 0.0 <= g["occupancy"] <= 1.0


def test_property_random_admit_fork_append_preempt_free_never_corrupts():
    """Random walk over the full allocator API — admit / share / fork /
    append / preempt-style free / release / speculative truncate-rollback —
    holding every refcount invariant at each step. This is the double-free /
    leak / over-capacity property test for the CoW ownership model; the
    truncate op additionally pins that a rollback never mutates a
    refcount > 1 page (the refusal is atomic)."""
    rng = np.random.default_rng(12345)
    pool = make_pool(num_pages=20, page_size=4, max_requests=5)
    handles: list = []
    for step in range(250):
        op = rng.integers(0, 6)
        active = list(np.flatnonzero(pool.active))
        try:
            if op == 0:  # admit, sometimes onto a random live prefix
                live_handles = [h for h in handles if not h.released]
                if live_handles and rng.random() < 0.5:
                    h = live_handles[rng.integers(len(live_handles))]
                    n = h.n_tokens + int(rng.integers(1, 9))
                    s = pool.admit(n, prefix=h)
                else:
                    n = int(rng.integers(1, 17))
                    s = pool.admit(n)
                pool.commit_prefill(s, n)
            elif op == 1 and active:  # share a prefix of a live request
                s = active[rng.integers(len(active))]
                length = int(pool.lengths[s])
                if length >= 2:
                    n = int(rng.integers(1, length))
                    handles.append(pool.share_prefix(s, n))
            elif op == 2 and active:  # decode growth
                s = active[rng.integers(len(active))]
                pool.append(s, int(rng.integers(1, 4)))
            elif op == 3 and active:  # finish / preempt: both are free()
                s = active[rng.integers(len(active))]
                pool.free(s)
            elif op == 4 and handles:  # registry drops a prefix
                h = handles[rng.integers(len(handles))]
                pool.release_prefix(h)
            elif op == 5 and active:  # speculative rollback: truncate a tail
                s = active[rng.integers(len(active))]
                length = int(pool.lengths[s])
                new_len = int(rng.integers(1, length + 1))
                before = (pool.refcount.copy(), pool.lengths.copy(),
                          np.asarray(pool.block_tables).copy())
                try:
                    pool.truncate(s, new_len)
                    assert int(pool.lengths[s]) == new_len
                except ValueError:
                    # the cut reached a CoW-shared page: refused atomically —
                    # refcounts, lengths and block tables must be untouched
                    np.testing.assert_array_equal(pool.refcount, before[0])
                    np.testing.assert_array_equal(pool.lengths, before[1])
                    np.testing.assert_array_equal(
                        np.asarray(pool.block_tables), before[2])
        except PoolExhaustedError:
            pass  # backpressure is a legal outcome; state must be unchanged
        _check_pool_invariants(pool, handles)
    # drain: everything returns, nothing double-frees, nothing leaks
    for s in list(np.flatnonzero(pool.active)):
        pool.free(s)
    for h in handles:
        pool.release_prefix(h)
    _check_pool_invariants(pool, handles)
    assert pool.pages_in_use == 0 and pool.free_pages == pool.num_pages - 1
