"""Chunked prefill: the Pallas ragged prefill page-walk kernel against its
oracle and the dense-gather/chunked_attention path (ragged lengths, GQA
ratios, shared-prefix forks, non-aligned trailing pages), the kernel on the
default model route (no dense pool gather), and the chunked-prefill
scheduler's greedy parity with per-request ``Engine.generate`` — including
mid-chunk admission, mid-prefill preemption, and the anti-thrash admission
cooldown."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.kernels import ref
from repro.kernels.ops import paged_prefill_attention
from repro.models.transformer import RuntimeOpts, init_params
from repro.serving.engine import Engine
from repro.serving.scheduler import Scheduler

OPTS_Q = RuntimeOpts(q_chunk=16, kv_chunk=16, remat=False, quantized_kv=True,
                     moe_capacity_factor=0.0)


@pytest.fixture(scope="module")
def tiny_model():
    cfg = get_config("llama2-7b").tiny()
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _prefill_fixture(rng, hist_lens, suf_lens, kh=2, g=2, page=4, hd=32,
                     p=16):
    """A hand-built pool + in-call batch: request r holds ``hist_lens[r]``
    HISTORY tokens in its pages (its earlier chunks / shared prefix) and
    prefills ``suf_lens[r]`` fresh tokens right-aligned from position
    ``hist_lens[r]``. The call's fresh tokens are ALSO scattered into the
    pool (post-update convention) so the kernel's ``pos < start`` history
    mask is really exercised against double counting."""
    kc = np.asarray(rng.integers(-127, 128, (p, kh, page, hd)), np.int8)
    vc = np.asarray(rng.integers(-127, 128, (p, kh, page, hd)), np.int8)
    ks = np.asarray(rng.uniform(0.005, 0.02, (p, kh, page)), np.float32)
    vs = np.asarray(rng.uniform(0.005, 0.02, (p, kh, page)), np.float32)
    r = len(hist_lens)
    totals = [h + s for h, s in zip(hist_lens, suf_lens)]
    maxb = max(-(-t // page) for t in totals)
    bt = np.zeros((r, maxb), np.int32)
    pool_pos = np.full((p, page), -1, np.int32)
    nxt = 1
    for i, t in enumerate(totals):
        for b in range(-(-t // page)):
            bt[i, b] = nxt
            nxt += 1
        for tok in range(t):  # history AND this call's tokens stored
            pool_pos[bt[i, tok // page], tok % page] = tok
    assert nxt <= p
    s = max(suf_lens)
    q_pos = np.full((r, s), -1, np.int32)
    for i, (h, ns) in enumerate(zip(hist_lens, suf_lens)):
        q_pos[i, s - ns:] = np.arange(h, h + ns)
    q = rng.normal(size=(r, kh, s, g, hd)).astype(np.float32)
    kf = rng.normal(size=(r, kh, s, hd)).astype(np.float32)
    vf = rng.normal(size=(r, kh, s, hd)).astype(np.float32)
    return tuple(jnp.asarray(a) for a in
                 (q, kc, ks, vc, vs, pool_pos, bt, q_pos, kf, vf))


@pytest.mark.parametrize("g,kh", [(2, 2), (4, 1), (1, 2)])
@pytest.mark.parametrize("hist,suf", [
    ((9, 5, 0), (4, 6, 3)),    # ragged, non-aligned trailing pages
    ((8, 8, 8), (4, 4, 4)),    # page-aligned shared-prefix forks
    ((13, 0, 1), (2, 7, 5)),   # long fork / plain / 1-token history
])
def test_prefill_kernel_matches_oracle(g, kh, hist, suf):
    rng = np.random.default_rng(g * 100 + sum(hist) + sum(suf))
    q, kc, ks, vc, vs, pp, bt, qp, kf, vf = _prefill_fixture(
        rng, hist, suf, kh=kh, g=g)
    start = jnp.min(jnp.where(qp >= 0, qp, jnp.int32(2 ** 30)), axis=1)
    got = paged_prefill_attention(q, kc, ks, vc, vs, pp, bt, qp, kf, vf)
    want = ref.paged_prefill_attention_ref(q, kc, ks, vc, vs, pp, bt, qp,
                                           start, kf, vf)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
    # pad query columns emit exact zeros (fixed-shape scheduler ticks rely
    # on finite outputs for inactive rows)
    s = qp.shape[1]
    for i, ns in enumerate(suf):
        np.testing.assert_array_equal(np.asarray(got[i, :, : s - ns]), 0.0)


def test_prefill_kernel_multiple_q_blocks():
    """q_block smaller than S: the online state must survive across query
    blocks AND the (nb + fresh) minor axis."""
    rng = np.random.default_rng(3)
    q, kc, ks, vc, vs, pp, bt, qp, kf, vf = _prefill_fixture(
        rng, (9, 5, 0), (7, 6, 3))
    start = jnp.min(jnp.where(qp >= 0, qp, jnp.int32(2 ** 30)), axis=1)
    want = ref.paged_prefill_attention_ref(q, kc, ks, vc, vs, pp, bt, qp,
                                           start, kf, vf)
    got = paged_prefill_attention(q, kc, ks, vc, vs, pp, bt, qp, kf, vf,
                                  q_block=2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_model_route_matches_dense_gather_and_skips_it(tiny_model,
                                                       monkeypatch):
    """Acceptance: the model-level ``paged_prefill_attention`` kernel route
    agrees with the dense-gather/chunked_attention fallback on a forked
    shared-prefix prefill, and the default (non-softcap) path never calls
    ``_gather_dense_kv``."""
    from repro.models import layers as L
    from repro.models.transformer import paged_prefill, paged_prefill_shared
    from repro.serving.kv_pool import PagedKVPool

    cfg, params = tiny_model
    rng = np.random.default_rng(11)
    prefix_len, suf = 6, 4
    prompt = rng.integers(0, cfg.vocab_size, (prefix_len + suf,))

    def run(opts):
        pool = PagedKVPool(cfg, num_pages=16, page_size=4, max_requests=2)
        s0 = pool.admit(prefix_len + suf)
        # creator prefills the full prompt (plain path)
        tokens = prompt[None].astype(np.int32)
        logits, caches = paged_prefill(
            params, cfg, jnp.asarray(tokens), pool.device_caches(rows=[s0]),
            jnp.asarray(np.arange(prefix_len + suf)[None].astype(np.int32)),
            opts)
        pool.update_from(caches)
        pool.commit_prefill(s0, prefix_len + suf)
        handle = pool.share_prefix(s0, prefix_len)
        s1 = pool.admit(prefix_len + suf, prefix=handle)
        # fork prefills only its suffix THROUGH the pool
        stoks = np.zeros((1, suf), np.int32)
        stoks[0] = prompt[prefix_len:]
        spos = np.arange(prefix_len, prefix_len + suf)[None].astype(np.int32)
        logits2, caches2 = paged_prefill_shared(
            params, cfg, jnp.asarray(stoks), pool.device_caches(rows=[s1]),
            jnp.asarray(spos), opts)
        return np.asarray(logits2[0])

    calls = []
    orig = L._gather_dense_kv
    monkeypatch.setattr(L, "_gather_dense_kv",
                        lambda c: calls.append(1) or orig(c))
    kernel_logits = run(OPTS_Q)
    assert not calls, "default path must not gather the pool dense"
    dense_logits = run(
        __import__("dataclasses").replace(OPTS_Q, paged_prefill_kernel=False))
    assert calls, "fallback path exercises the dense gather"
    np.testing.assert_allclose(kernel_logits, dense_logits,
                               rtol=2e-4, atol=2e-4)
    assert int(np.argmax(kernel_logits)) == int(np.argmax(dense_logits))


# ------------------------------------------------- scheduler equivalence


def test_chunked_scheduler_matches_engine_multi_chunk(tiny_model):
    """Acceptance: prompts LONGER than the chunk (here 3-5 chunks each) are
    admitted piecewise — later chunks attend earlier ones through the
    page-walk kernel — while other requests keep decoding, and every
    greedy output is IDENTICAL to the per-request Engine. Mid-chunk
    admission is forced by queueing more requests than slots."""
    cfg, params = tiny_model
    rng = np.random.default_rng(21)
    jobs = [(18, 5), (9, 4), (4, 6), (14, 3)]  # (prompt_len, max_new)
    prompts = [rng.integers(0, cfg.vocab_size, (n,)) for n, _ in jobs]
    sched = Scheduler(cfg, params, OPTS_Q, num_pages=32, page_size=4,
                      max_slots=2, prefill_chunk=4)
    rids = [sched.submit(p, mn) for p, (_, mn) in zip(prompts, jobs)]
    results = sched.run()
    # 18 tokens / chunk 4 → ≥ 5 chunks for request 0 alone
    assert sched.stats.prefill_chunks >= 5 + 3 + 1 + 4
    assert sched.stats.ttft_ticks[rids[0]] >= 5  # ticks, one chunk each
    eng = Engine(cfg, params, OPTS_Q, cache_len=32)
    for rid, p, (_, mn) in zip(rids, prompts, jobs):
        np.testing.assert_array_equal(results[rid],
                                      eng.generate(p[None], mn).tokens[0])
    # ONE compiled shape per step kind, whatever the admission pattern
    assert sched.stats.compiled_shapes <= 3


def test_chunked_scheduler_decodes_while_long_prompt_admits(tiny_model):
    """The Sarathi property: a decoding request keeps emitting one token
    per tick WHILE a long prompt is being admitted chunk by chunk (wave
    mode would stall it for the whole prompt)."""
    cfg, params = tiny_model
    rng = np.random.default_rng(23)
    short = rng.integers(0, cfg.vocab_size, (3,))
    long = rng.integers(0, cfg.vocab_size, (16,))
    sched = Scheduler(cfg, params, OPTS_Q, num_pages=32, page_size=4,
                      max_slots=2, prefill_chunk=4)
    r_short = sched.submit(short, 10)
    r_long = sched.submit(long, 2)
    ticks_with_progress = 0
    last = 0
    while sched.step():
        st = next((s for s in sched.slots
                   if s is not None and s.req.rid == r_short), None)
        if st is not None and len(st.generated) > last:
            last = len(st.generated)
            ticks_with_progress += 1
    results = sched.results
    # the long prompt needed 4 chunk ticks; the short request decoded
    # through every one of them
    assert ticks_with_progress >= 4
    eng = Engine(cfg, params, OPTS_Q, cache_len=32)
    np.testing.assert_array_equal(results[r_short],
                                  eng.generate(short[None], 10).tokens[0])
    np.testing.assert_array_equal(results[r_long],
                                  eng.generate(long[None], 2).tokens[0])


@pytest.mark.parametrize("resume", ["swap", "refill"])
def test_chunked_prefill_preemption_roundtrip(tiny_model, resume):
    """A mid-prefill slot evicted by a decoding neighbour's growth resumes
    CHUNKING where it left off (swap) or re-prefills (refill) — and both
    requests still match the Engine exactly."""
    cfg, params = tiny_model
    rng = np.random.default_rng(29)
    a = rng.integers(0, cfg.vocab_size, (5,))   # decodes and grows
    b = rng.integers(0, cfg.vocab_size, (24,))  # chunked mid-prefill victim
    # 9 usable pages: a admits at 2 (5+1 tokens), b's lazy target takes the
    # other 7; a's growth to a 3rd page exhausts the pool on tick 5 while b
    # (6 chunks of 4) has only written 16 of 24 prompt tokens — b is
    # evicted MID-PREFILL with just its chunks and must resume them
    sched = Scheduler(cfg, params, OPTS_Q, num_pages=10, page_size=4,
                      max_slots=2, prefill_chunk=4, lazy_growth=True,
                      resume=resume, preempt_cooldown=1)
    ra = sched.submit(a, 10, priority=1)
    rb = sched.submit(b, 3, priority=0)
    results = sched.run()
    assert sched.stats.preemptions >= 1
    # an uninterrupted 24-token prompt takes exactly 6 chunk ticks; the
    # preempted one must have waited out its eviction
    assert sched.stats.ttft_ticks[rb] > 6
    eng = Engine(cfg, params, OPTS_Q, cache_len=32)
    np.testing.assert_array_equal(results[ra],
                                  eng.generate(a[None], 10).tokens[0])
    np.testing.assert_array_equal(results[rb],
                                  eng.generate(b[None], 3).tokens[0])
    assert sched.pool.pages_in_use == 0


def test_chunked_prefix_sharing_matches_engine(tiny_model):
    """Prefix forks under chunked prefill: the creator's prefix is pinned
    as soon as its chunks cover it, forks chunk only their suffix, and
    every output matches the Engine."""
    cfg, params = tiny_model
    rng = np.random.default_rng(31)
    prefix = rng.integers(0, cfg.vocab_size, (10,))
    jobs = [(6, 3), (2, 4), (5, 3)]
    prompts = [np.concatenate([prefix,
                               rng.integers(0, cfg.vocab_size, (n,))])
               for n, _ in jobs]
    sched = Scheduler(cfg, params, OPTS_Q, num_pages=32, page_size=4,
                      max_slots=2, prefill_chunk=4)
    rids = [sched.submit(p, mn, prefix_key="sys",
                         prefix_len=10 if i == 0 else None)
            for i, (p, (_, mn)) in enumerate(zip(prompts, jobs))]
    results = sched.run()
    assert sched.stats.prefix_forks >= 2
    eng = Engine(cfg, params, OPTS_Q, cache_len=32)
    for rid, p, (_, mn) in zip(rids, prompts, jobs):
        np.testing.assert_array_equal(results[rid],
                                      eng.generate(p[None], mn).tokens[0])
    assert sched.pool.pages_in_use == 0


# --------------------------------------------------- anti-thrash cooldown


def _swap_storm(cfg, params, cooldown):
    """One high-priority long-runner crossing a page boundary every other
    tick, a low-priority victim, and a stream of short requests whose
    evictions keep opening just enough slack for the victim to re-admit —
    the evict → re-admit → evict oscillation the cooldown exists to damp."""
    rng = np.random.default_rng(37)
    eng = Engine(cfg, params, OPTS_Q, cache_len=64)
    sched = Scheduler(cfg, params, OPTS_Q, num_pages=12, page_size=2,
                      max_slots=3, lazy_growth=True,
                      preempt_cooldown=cooldown)
    jobs = [(rng.integers(0, cfg.vocab_size, (4,)), 14, 2),  # grower
            (rng.integers(0, cfg.vocab_size, (4,)), 14, 0)]  # victim
    jobs += [(rng.integers(0, cfg.vocab_size, (3,)), 2, 1) for _ in range(6)]
    rids = [sched.submit(p, mn, priority=pr) for p, mn, pr in jobs]
    results = sched.run()
    for rid, (p, mn, _) in zip(rids, jobs):
        np.testing.assert_array_equal(results[rid],
                                      eng.generate(p[None], mn).tokens[0])
    return sched.stats.preemptions


def test_anti_thrash_cooldown_damps_swap_storm(tiny_model):
    """Regression for the ROADMAP follow-on: without a cooldown the victim
    is re-admitted as soon as slack reopens — right after its preemptor
    grew — and re-evicted at the preemptor's next page boundary, a swap
    storm that re-plays the same pages over and over. A cooldown spanning
    a few growth boundaries lets the preemptor drain first and must cut
    the preemption count (with identical outputs, which both runs
    assert)."""
    cfg, params = tiny_model
    storm = _swap_storm(cfg, params, cooldown=0)
    calm = _swap_storm(cfg, params, cooldown=4)
    assert storm >= 2, "workload must provoke repeated preemption today"
    assert calm < storm


def test_wave_mode_still_available_and_compiles_per_bucket(tiny_model):
    """``prefill_mode="wave"`` keeps the old behavior: same outputs, but a
    distinct prefill shape per (R_adm, S_pad) bucket — the compile-count
    counter shows exactly what chunked mode eliminates."""
    cfg, params = tiny_model
    rng = np.random.default_rng(41)
    jobs = [(3, 3), (9, 3), (17, 3)]  # three different buckets
    prompts = [rng.integers(0, cfg.vocab_size, (n,)) for n, _ in jobs]

    def serve(mode):
        sched = Scheduler(cfg, params, OPTS_Q, num_pages=32, page_size=4,
                          max_slots=1, prefill_mode=mode, prefill_chunk=8)
        rids = [sched.submit(p, mn) for p, (_, mn) in zip(prompts, jobs)]
        return sched, rids, sched.run()

    wave, wrids, wres = serve("wave")
    chunk, crids, cres = serve("chunked")
    eng = Engine(cfg, params, OPTS_Q, cache_len=32)
    for (wr, cr, p, (_, mn)) in zip(wrids, crids, prompts, jobs):
        want = eng.generate(p[None], mn).tokens[0]
        np.testing.assert_array_equal(wres[wr], want)
        np.testing.assert_array_equal(cres[cr], want)
    # wave: one prefill shape per bucket (4, 16, 32) + decode ≥ 4 shapes;
    # chunked: first-chunk + continuation + decode ≤ 3, bucket-independent
    assert wave.stats.compiled_shapes >= 4
    assert chunk.stats.compiled_shapes <= 3
    assert chunk.stats.prefill_chunks == 1 + 2 + 3  # ceil(n / 8) each
