"""Tests for the Eq. (12) depth objective solver (max w·ℓ s.t. L_t ≤ D)."""

import pytest

from repro.core.channel import ChannelConfig, LatencyModel, optimal_rate
from repro.core.early_exit import solve_depth_objective


def _model(compute_s=1e-4):
    cfg = ChannelConfig()
    return LatencyModel(cfg, optimal_rate(cfg), compute_s)


def _bits_fn(w, ell, i_kv, compressed):
    base = w * 4096 * 8.0  # hidden-state payload grows with w
    return base / (4.0 if compressed else 1.0)


def test_depth_objective_monotone_in_deadline():
    lat = _model()
    prods = []
    for d in (0.01, 0.1, 1.0, 10.0):
        sol = solve_depth_objective(lat, _bits_fn, d, w_max=256, num_layers=32)
        prods.append(0 if sol is None else sol[0] * sol[1])
    assert prods == sorted(prods)
    assert prods[-1] == 256 * 32  # generous deadline → full depth


def test_depth_objective_respects_deadline():
    lat = _model(compute_s=1e-3)
    d = 0.15
    sol = solve_depth_objective(lat, _bits_fn, d, w_max=128, num_layers=16)
    assert sol is not None
    w, ell, t = sol
    assert t <= d
    # optimality vs brute force
    best = 0
    from repro.core.channel import worst_case_latency

    for e in range(1, 17):
        for ww in range(1, 129):
            lt = lat.compute_per_token_s * e + worst_case_latency(
                _bits_fn(ww, e, 1, True), lat.rate, lat.channel)
            if lt <= d:
                best = max(best, ww * e)
    assert w * ell == best


def test_depth_objective_infeasible():
    lat = _model(compute_s=10.0)  # one layer already busts the deadline
    sol = solve_depth_objective(lat, _bits_fn, 1.0, w_max=8, num_layers=4)
    assert sol is None


def test_compression_increases_depth():
    lat = _model()
    d = 0.2
    s_raw = solve_depth_objective(lat, _bits_fn, d, 512, 32, compressed=False)
    s_cmp = solve_depth_objective(lat, _bits_fn, d, 512, 32, compressed=True)
    assert (0 if s_raw is None else s_raw[0] * s_raw[1]) <= s_cmp[0] * s_cmp[1]
