"""Token-packed varlen ticks: the flat-batch Pallas varlen attention
kernel against its dense oracle (pure-decode / pure-prefill / mixed
segment packs, GQA ratios, non-page-aligned boundaries, all-pad tails,
and a cross-check against the decode oracle), the packed scheduler's
greedy parity with per-request ``Engine.generate`` and with the chunked
tick under admission pressure and preemption, the one-compiled-shape
guarantee, the cached sampling-operand upload, and per-token logprobs
threaded through the sampler, the scheduler events, and the serving
API backends."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.sampling import SamplingParams, token_logprobs
from repro.kernels import ops, ref
from repro.kernels.varlen_attention import segment_start
from repro.models.transformer import RuntimeOpts, init_params
from repro.serving import Engine, LLMServer, Scheduler

OPTS_Q = RuntimeOpts(q_chunk=16, kv_chunk=16, remat=False, quantized_kv=True,
                     moe_capacity_factor=0.0)


@pytest.fixture(scope="module")
def tiny_model():
    cfg = get_config("llama2-7b").tiny()
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _varlen_fixture(rng, segs, kh=2, g=2, page=4, hd=32, p=16, pad=0):
    """A hand-built pool + flat token batch: slot ``i`` holds
    ``segs[i][0]`` HISTORY tokens in its pages and contributes
    ``segs[i][1]`` fresh in-call tokens from that position — a decode
    token is the ``n = 1`` case. The call's own tokens are ALSO stored in
    the pool (post-update convention) so the ``pos < start`` history mask
    is really exercised against double counting; ``pad`` inactive rows
    (slot -1, position -1) close the fixed-budget buffer's tail."""
    kc = np.asarray(rng.integers(-127, 128, (p, kh, page, hd)), np.int8)
    vc = np.asarray(rng.integers(-127, 128, (p, kh, page, hd)), np.int8)
    ks = np.asarray(rng.uniform(0.005, 0.02, (p, kh, page)), np.float32)
    vs = np.asarray(rng.uniform(0.005, 0.02, (p, kh, page)), np.float32)
    r = len(segs)
    totals = [h + n for h, n in segs]
    maxb = max(-(-t // page) for t in totals)
    bt = np.zeros((r, maxb), np.int32)
    pool_pos = np.full((p, page), -1, np.int32)
    nxt = 1  # page 0 is the trash page
    for i, t in enumerate(totals):
        for b in range(-(-t // page)):
            bt[i, b] = nxt
            nxt += 1
        for tok in range(t):  # history AND this call's tokens stored
            pool_pos[bt[i, tok // page], tok % page] = tok
    assert nxt <= p
    t_flat = sum(n for _, n in segs) + pad
    q_pos = np.full((t_flat,), -1, np.int32)
    tok_slot = np.full((t_flat,), -1, np.int32)
    cur = 0
    for i, (h, n) in enumerate(segs):
        q_pos[cur:cur + n] = np.arange(h, h + n)
        tok_slot[cur:cur + n] = i
        cur += n
    q = rng.normal(size=(kh, t_flat, g, hd)).astype(np.float32)
    kf = rng.normal(size=(kh, t_flat, hd)).astype(np.float32)
    vf = rng.normal(size=(kh, t_flat, hd)).astype(np.float32)
    return tuple(jnp.asarray(a) for a in
                 (q, kc, ks, vc, vs, pool_pos, bt, q_pos, tok_slot, kf, vf))


MIXES = {
    # decode-only pack: three length-1 segments + pad tail
    "pure_decode": dict(segs=[(5, 1), (9, 1), (3, 1)], pad=3),
    # prefill-only pack, non-page-aligned segment totals (4, 9, 5 on
    # page 4) including a fresh request and a mid-prompt continuation
    "pure_prefill": dict(segs=[(0, 4), (6, 3), (0, 5)], pad=0),
    # the packed tick's real shape: decode tokens and ragged prefill
    # chunks interleaved in one buffer
    "mixed": dict(segs=[(9, 1), (5, 4), (0, 6), (7, 1)], pad=2),
}


@pytest.mark.parametrize("g,kh", [(2, 2), (4, 1), (1, 2)])
@pytest.mark.parametrize("mix", sorted(MIXES))
def test_varlen_kernel_matches_oracle(g, kh, mix):
    spec = MIXES[mix]
    rng = np.random.default_rng(abs(hash((g, kh, mix))) % 2 ** 31)
    q, kc, ks, vc, vs, pp, bt, qp, sl, kf, vf = _varlen_fixture(
        rng, spec["segs"], kh=kh, g=g, pad=spec["pad"])
    got = ops.varlen_attention(q, kc, ks, vc, vs, pp, bt, qp, sl, kf, vf)
    start = segment_start(qp, sl, bt.shape[0])
    want = ref.varlen_attention_ref(q, kc, ks, vc, vs, pp, bt, qp, sl,
                                    start, kf, vf)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
    # pad tail rows emit exact zeros — the fixed-budget scheduler tick
    # relies on inactive rows being finite and inert
    if spec["pad"]:
        np.testing.assert_array_equal(
            np.asarray(got[:, -spec["pad"]:]), 0.0)


def test_varlen_all_pad_rows_emit_exact_zeros():
    """A buffer with NO active tokens (every row slot -1 / position -1)
    must come back all-zero — never NaN from an empty softmax."""
    rng = np.random.default_rng(17)
    q, kc, ks, vc, vs, pp, bt, qp, sl, kf, vf = _varlen_fixture(
        rng, [(4, 2), (7, 1)], pad=1)
    qp = jnp.full_like(qp, -1)
    sl = jnp.full_like(sl, -1)
    got = ops.varlen_attention(q, kc, ks, vc, vs, pp, bt, qp, sl, kf, vf)
    np.testing.assert_array_equal(np.asarray(got), 0.0)


def test_varlen_pure_decode_matches_decode_oracle():
    """A pure-decode pack whose fresh k/v equal the pool's dequantized
    self entries is EXACTLY the decode kernel's problem — row r of the
    flat batch must reproduce ``paged_decode_attention_ref`` for request
    r (same pages, same causal bound)."""
    kh, g, page, hd = 2, 2, 4, 32
    segs = [(5, 1), (9, 1), (3, 1)]
    rng = np.random.default_rng(23)
    q, kc, ks, vc, vs, pp, bt, qp, sl, kf, vf = _varlen_fixture(
        rng, segs, kh=kh, g=g, page=page, hd=hd)
    # overwrite the fresh keys with the pool's own (dequantized) entry at
    # each token's position, so both conventions see identical self keys
    kf, vf = np.asarray(kf).copy(), np.asarray(vf).copy()
    for t, (h, _) in enumerate(segs):
        pg, off = bt[t, h // page], h % page
        kf[:, t] = np.asarray(kc)[pg, :, off] * np.asarray(ks)[pg, :, off,
                                                              None]
        vf[:, t] = np.asarray(vc)[pg, :, off] * np.asarray(vs)[pg, :, off,
                                                               None]
    got = ops.varlen_attention(q, kc, ks, vc, vs, pp, bt, qp, sl,
                               jnp.asarray(kf), jnp.asarray(vf))
    want = ref.paged_decode_attention_ref(
        jnp.swapaxes(q, 0, 1), kc, ks, vc, vs, pp, bt,
        jnp.asarray([h for h, _ in segs], jnp.int32))
    np.testing.assert_allclose(np.asarray(jnp.swapaxes(got, 0, 1)),
                               np.asarray(want), rtol=1e-4, atol=1e-4)


# ------------------------------------------------- scheduler equivalence


def test_packed_scheduler_matches_engine_and_chunked(tiny_model):
    """Acceptance: ``tick_mode="packed"`` serves the multi-chunk workload
    (prompts 3-5 chunks long, more requests than slots, mid-tick
    admission) through ONE compiled shape, greedy outputs IDENTICAL to
    the per-request Engine — and therefore to the chunked tick."""
    cfg, params = tiny_model
    rng = np.random.default_rng(21)
    jobs = [(18, 5), (9, 4), (4, 6), (14, 3)]  # (prompt_len, max_new)
    prompts = [rng.integers(0, cfg.vocab_size, (n,)) for n, _ in jobs]

    def serve(**kw):
        sched = Scheduler(cfg, params, OPTS_Q, num_pages=32, page_size=4,
                          max_slots=2, prefill_chunk=4, **kw)
        rids = [sched.submit(p, mn) for p, (_, mn) in zip(prompts, jobs)]
        return sched, rids, sched.run()

    packed, prids, pres = serve(tick_mode="packed")
    chunked, crids, cres = serve(tick_mode="chunked")
    eng = Engine(cfg, params, OPTS_Q, cache_len=32)
    for pr, cr, p, (_, mn) in zip(prids, crids, prompts, jobs):
        want = eng.generate(p[None], mn).tokens[0]
        np.testing.assert_array_equal(pres[pr], want)
        np.testing.assert_array_equal(cres[cr], want)
    # the whole run is ONE jitted shape: (1, token_budget) packed steps —
    # vs the chunked tick's first-chunk + continuation + decode trio
    assert packed.stats.compiled_shapes == 1
    assert packed.stats.compiled_shapes <= chunked.stats.compiled_shapes
    assert packed.stats.packed_ticks > 0
    # exact token accounting: every prompt token is processed once, plus
    # one decode row per generated token except the first (it rides the
    # final prefill row) and the last (sampled, never fed back)
    assert packed.stats.packed_tokens == (sum(n for n, _ in jobs)
                                          + sum(m - 1 for _, m in jobs))
    assert packed.pool.pages_in_use == 0


def test_packed_decodes_while_long_prompt_admits(tiny_model):
    """The Sarathi property survives packing: a decoding request keeps
    emitting one token per PACKED tick while a long prompt's chunks share
    the same buffer."""
    cfg, params = tiny_model
    rng = np.random.default_rng(23)
    short = rng.integers(0, cfg.vocab_size, (3,))
    long = rng.integers(0, cfg.vocab_size, (16,))
    sched = Scheduler(cfg, params, OPTS_Q, num_pages=32, page_size=4,
                      max_slots=2, prefill_chunk=4, tick_mode="packed")
    r_short = sched.submit(short, 10)
    r_long = sched.submit(long, 2)
    ticks_with_progress = 0
    last = 0
    while sched.step():
        st = next((s for s in sched.slots
                   if s is not None and s.req.rid == r_short), None)
        if st is not None and len(st.generated) > last:
            last = len(st.generated)
            ticks_with_progress += 1
    assert ticks_with_progress >= 4
    eng = Engine(cfg, params, OPTS_Q, cache_len=32)
    np.testing.assert_array_equal(sched.results[r_short],
                                  eng.generate(short[None], 10).tokens[0])
    np.testing.assert_array_equal(sched.results[r_long],
                                  eng.generate(long[None], 2).tokens[0])


@pytest.mark.parametrize("resume", ["swap", "refill"])
def test_packed_preemption_roundtrip(tiny_model, resume):
    """A mid-prefill slot evicted by a decoding neighbour's growth
    resumes its packed pieces where it left off (swap) or re-prefills
    (refill) — and both requests still match the Engine exactly."""
    cfg, params = tiny_model
    rng = np.random.default_rng(29)
    a = rng.integers(0, cfg.vocab_size, (5,))   # decodes and grows
    b = rng.integers(0, cfg.vocab_size, (24,))  # mid-prefill victim
    sched = Scheduler(cfg, params, OPTS_Q, num_pages=10, page_size=4,
                      max_slots=2, prefill_chunk=4, lazy_growth=True,
                      resume=resume, preempt_cooldown=1,
                      tick_mode="packed")
    ra = sched.submit(a, 10, priority=1)
    rb = sched.submit(b, 3, priority=0)
    results = sched.run()
    assert sched.stats.preemptions >= 1
    eng = Engine(cfg, params, OPTS_Q, cache_len=32)
    np.testing.assert_array_equal(results[ra],
                                  eng.generate(a[None], 10).tokens[0])
    np.testing.assert_array_equal(results[rb],
                                  eng.generate(b[None], 3).tokens[0])
    assert sched.pool.pages_in_use == 0


# ------------------------------------------- cached sampling operands


def test_device_ops_upload_cached_across_ticks(tiny_model):
    """Satellite regression: steady-state ticks must ship the SAME device
    operand arrays — greedy admissions into greedy-reset rows and
    membership-stable decode ticks never trigger a re-upload."""
    cfg, params = tiny_model
    rng = np.random.default_rng(31)
    sched = Scheduler(cfg, params, OPTS_Q, num_pages=32, page_size=4,
                      max_slots=2, tick_mode="packed")
    for _ in range(3):  # all greedy (seed 0) — the reset-row no-op case
        sched.submit(rng.integers(0, cfg.vocab_size, (5,)), 4)
    assert sched.step()
    first = sched._device_ops()
    while sched.step():
        assert sched._device_ops() is first  # never rebuilt, never re-sent
    # a NON-default row must invalidate the cache exactly once
    sched.submit(rng.integers(0, cfg.vocab_size, (4,)),
                 sampling=SamplingParams(max_tokens=3, temperature=0.7,
                                         seed=5))
    sched.step()
    second = sched._device_ops()
    assert second is not first
    while sched.step():
        assert sched._device_ops() is not first


def test_seeded_draws_unchanged_by_operand_cache(tiny_model):
    """Same seeds ⇒ same draws through the cached-operand path: seeded
    non-greedy requests through the packed scheduler equal the fused
    per-request engine row for row."""
    cfg, params = tiny_model
    rng = np.random.default_rng(37)
    prompts = [rng.integers(0, cfg.vocab_size, (6,)) for _ in range(3)]
    sps = [SamplingParams(max_tokens=5, temperature=0.8, top_k=7, seed=s)
           for s in (3, 11, 3)]
    sched = Scheduler(cfg, params, OPTS_Q, num_pages=32, page_size=4,
                      max_slots=2, tick_mode="packed")
    rids = [sched.submit(p, sampling=sp) for p, sp in zip(prompts, sps)]
    results = sched.run()
    eng = Engine(cfg, params, OPTS_Q, cache_len=32)
    for rid, p, sp in zip(rids, prompts, sps):
        want = eng.generate_requests(p[None], [sp]).tokens[0]
        np.testing.assert_array_equal(results[rid], want)


# ------------------------------------------------------------- logprobs


def test_token_logprobs_matches_numpy():
    """The sampler helper is log-softmax of the RAW logits at the emitted
    token — checked against numpy, including the (B, K, V) codebook
    shape."""
    rng = np.random.default_rng(41)
    logits = rng.normal(size=(3, 11)).astype(np.float32) * 3
    toks = rng.integers(0, 11, (3,))
    got = np.asarray(token_logprobs(jnp.asarray(logits), jnp.asarray(toks)))
    z = logits - logits.max(-1, keepdims=True)
    want = (z - np.log(np.exp(z).sum(-1, keepdims=True)))[
        np.arange(3), toks]
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)
    lk = rng.normal(size=(2, 4, 9)).astype(np.float32)
    tk = rng.integers(0, 9, (2, 4))
    got = np.asarray(token_logprobs(jnp.asarray(lk), jnp.asarray(tk)))
    assert got.shape == (2, 4)
    np.testing.assert_allclose(
        got[1, 2],
        jax.nn.log_softmax(lk[1, 2])[tk[1, 2]], rtol=1e-5)


def test_logprob_events_across_backends(tiny_model):
    """Every streamed token carries its raw-distribution logprob on both
    the fused (replayed) and paged (true-streaming) backends — same
    greedy tokens, logprobs agreeing to kernel-numerics tolerance, finish
    markers logprob-free."""
    cfg, params = tiny_model
    rng = np.random.default_rng(43)
    p = rng.integers(0, cfg.vocab_size, (6,))
    sp = SamplingParams(max_tokens=5)

    def collect(srv):
        rid = srv.submit(p, sp)
        toks, lps = [], []
        for ev in srv.stream():
            if ev.finished:
                assert ev.logprob is None
            else:
                toks.append(ev.token)
                lps.append(ev.logprob)
        return rid, np.asarray(toks), np.asarray(lps)

    srv_f = LLMServer(cfg, params, OPTS_Q, backend="fused", cache_len=32)
    _, toks_f, lps_f = collect(srv_f)
    srv_p = LLMServer(cfg, params, OPTS_Q, backend="paged", num_pages=24,
                      page_size=4, max_slots=2, tick_mode="packed")
    _, toks_p, lps_p = collect(srv_p)
    np.testing.assert_array_equal(toks_f, toks_p)
    assert np.all(np.isfinite(lps_f)) and np.all(lps_f <= 0.0)
    # fused reads fp logits, paged reads the packed int8-pool path — the
    # distributions agree to quantization/kernel tolerance
    np.testing.assert_allclose(lps_f, lps_p, atol=5e-2, rtol=5e-2)


def test_engine_generate_returns_logprobs(tiny_model):
    """``Engine.generate`` logprobs: one per generated token, finite,
    <= 0, and for greedy equal to the max of the step's log-softmax (the
    argmax token's own probability)."""
    cfg, params = tiny_model
    rng = np.random.default_rng(47)
    prompts = rng.integers(0, cfg.vocab_size, (2, 6))
    eng = Engine(cfg, params, OPTS_Q, cache_len=32)
    res = eng.generate(prompts, 4)
    assert res.logprobs.shape == (2, 4)
    assert np.all(np.isfinite(res.logprobs)) and np.all(res.logprobs <= 0)
    # deterministic across calls (pure function of the logits)
    np.testing.assert_array_equal(res.logprobs,
                                  eng.generate(prompts, 4).logprobs)
