"""The wired Pallas fast path: quantized-cache decode parity against the
pure-jnp oracle, head-major cache writes, the fused on-device generation
loop, and the no-host-transfer guarantee (the whole loop jit-traces
abstractly)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import AttnSpec
from repro.kernels import ref
from repro.models import layers as L
from repro.models.transformer import (RuntimeOpts, decode_step, init_caches,
                                      init_params, prefill)
from repro.serving.engine import Engine

OPTS = RuntimeOpts(q_chunk=16, kv_chunk=16, remat=False, moe_capacity_factor=0.0)
OPTS_Q = RuntimeOpts(q_chunk=16, kv_chunk=16, remat=False, quantized_kv=True,
                     moe_capacity_factor=0.0)


# ----------------------------------------------- quantized decode parity


@pytest.mark.parametrize("h,kh", [(4, 2), (6, 1), (4, 4)])  # K<H and K=H
@pytest.mark.parametrize("s,fill", [(96, 96), (80, 50)])  # full and
# partially-filled caches (empty slots masked via pos = -1); trailing-block
# padding itself (s % block_s != 0) is covered by test_kernels.py
def test_quantized_decode_matches_oracle(h, kh, s, fill):
    """The dispatch layer (cache_update + Pallas kernel, interpret=True on
    CPU) must match kernels.ref.decode_attention_ref on the same cache."""
    hd = 32
    b = 2
    rng = np.random.default_rng(h * 100 + s)
    spec = AttnSpec(num_heads=h, num_kv_heads=kh, head_dim=hd)
    cache = L.init_cache(b, s, kh, hd, quantized=True)
    k_new = jnp.asarray(rng.normal(size=(b, fill, kh, hd)), jnp.float32)
    v_new = jnp.asarray(rng.normal(size=(b, fill, kh, hd)), jnp.float32)
    cache = L.cache_update(cache, k_new, v_new, jnp.int32(0))
    assert cache.k.shape == (b, kh, s, hd) and cache.k.dtype == jnp.int8
    assert cache.k_scale.shape == (b, kh, s)

    q = jnp.asarray(rng.normal(size=(b, 1, h, hd)), jnp.float32)
    q_pos = jnp.int32(fill - 1)
    out = L.quantized_decode_attention(q, cache, spec, None, q_pos)
    qh = q[:, 0].reshape(b, kh, h // kh, hd)
    want = ref.decode_attention_ref(qh, cache.k, cache.k_scale, cache.v,
                                    cache.v_scale, cache.pos, q_pos)
    np.testing.assert_allclose(np.asarray(out[:, 0].reshape(b, kh, h // kh, hd)),
                               np.asarray(want), rtol=2e-4, atol=2e-4)


def test_quantized_decode_step_close_to_fp_reference():
    """End-to-end through decode_step: the kernel-backed quantized cache must
    track the fp-cache decode within int8 quantization error, with a cache_len
    that spans multiple kernel blocks and is not block-aligned."""
    cfg = get_config("internlm2-20b").tiny()  # GQA, no softcap → kernel path
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(7)
    s = 24
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, s + 1)), jnp.int32)

    _, caches = prefill(params, cfg, tokens[:, :s], None, cache_len=40, opts=OPTS)
    want, _ = decode_step(params, cfg, tokens[:, s:], caches, jnp.int32(s), OPTS)
    _, caches_q = prefill(params, cfg, tokens[:, :s], None, cache_len=40,
                          opts=OPTS_Q)
    got, _ = decode_step(params, cfg, tokens[:, s:], caches_q, jnp.int32(s),
                         OPTS_Q)
    scale = float(jnp.maximum(jnp.max(jnp.abs(want)), 1e-3))
    assert float(jnp.max(jnp.abs(got - want))) / scale < 0.08


def test_quantized_cache_layout_and_bytes():
    """init_caches emits the kv-head-major int8 layout the kernel streams."""
    cfg = get_config("llama2-7b").tiny()
    caches = jax.eval_shape(lambda: init_caches(cfg, 2, 32, OPTS_Q))
    c = caches[0]
    m = cfg.pattern[0].mixer
    assert c.k.shape == (cfg.num_blocks, 2, m.num_kv_heads, 32, m.head_dim)
    assert c.k.dtype == jnp.int8 and c.v.dtype == jnp.int8
    assert c.k_scale.shape == (cfg.num_blocks, 2, m.num_kv_heads, 32)
    fp = jax.eval_shape(lambda: init_caches(cfg, 2, 32, OPTS))[0]
    int8_bytes = c.k.size + c.k_scale.size * 4
    fp_bytes = fp.k.size * fp.k.dtype.itemsize
    assert int8_bytes < fp_bytes  # Eq. 2: the quantized cache is smaller


# ------------------------------------------------- fused generation loop


def test_engine_fused_loop_matches_stepwise_greedy():
    """Regression: the on-device scan must reproduce the per-step host loop
    exactly for greedy sampling."""
    cfg = get_config("llama2-7b").tiny()
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, OPTS, cache_len=64)
    prompts = np.random.default_rng(0).integers(0, cfg.vocab_size, (3, 8))
    got = eng.generate(prompts, max_new_tokens=6).tokens

    # reference: the old host-stepped loop
    tokens = jnp.asarray(prompts, jnp.int32)
    logits, caches = prefill(params, cfg, tokens, None, 64, OPTS)
    out = [tokens]
    pos = 8
    for i in range(6):
        nxt = jnp.argmax(logits, axis=-1)[:, None].astype(tokens.dtype)
        out.append(nxt)
        if i + 1 < 6:
            logits, caches = decode_step(params, cfg, nxt, caches,
                                         jnp.int32(pos), OPTS)
            pos += 1
    want = np.asarray(jnp.concatenate(out, axis=1))
    np.testing.assert_array_equal(got, want)


def test_engine_fused_loop_quantized_kv():
    """The fused loop composes with the int8-cache kernel path (scan over
    Pallas interpret calls) and still decodes deterministically."""
    cfg = get_config("llama2-7b").tiny()
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, OPTS_Q, cache_len=48)
    prompts = np.random.default_rng(1).integers(0, cfg.vocab_size, (2, 8))
    a = eng.generate(prompts, max_new_tokens=5).tokens
    b = eng.generate(prompts, max_new_tokens=5).tokens
    np.testing.assert_array_equal(a, b)
    np.testing.assert_array_equal(a[:, :8], prompts)
    assert a.shape == (2, 13)


def test_engine_length_bucketing_shares_compiles():
    """Varying max_new_tokens bucket to a power of two: one compiled loop
    serves both, and greedy outputs are prefix-consistent."""
    cfg = get_config("llama2-7b").tiny()
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, OPTS, cache_len=64)
    prompts = np.random.default_rng(5).integers(0, cfg.vocab_size, (2, 8))
    a = eng.generate(prompts, 5).tokens
    b = eng.generate(prompts, 6).tokens
    assert len(eng._gen_fns) == 1  # 5 and 6 both bucket to 8
    assert a.shape == (2, 13) and b.shape == (2, 14)
    np.testing.assert_array_equal(a, b[:, :13])


def test_engine_generate_zero_new_tokens():
    cfg = get_config("llama2-7b").tiny()
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, OPTS, cache_len=64)
    prompts = np.random.default_rng(2).integers(0, cfg.vocab_size, (2, 8))
    res = eng.generate(prompts, max_new_tokens=0)
    np.testing.assert_array_equal(res.tokens, prompts)


def test_quantized_cache_block_aligned_and_decodes():
    """Slot axes of big quantized caches are rounded up to whole kernel
    blocks (no per-step jnp.pad of the cache), pad slots masked via pos=-1."""
    from repro.kernels.decode_attention import padded_cache_len

    assert padded_cache_len(600, 512) == 1024
    assert padded_cache_len(40, 512) == 40  # single block: no padding
    cfg = get_config("llama2-7b").tiny()
    caches = jax.eval_shape(lambda: init_caches(cfg, 1, 600, OPTS_Q))
    assert caches[0].k.shape[3] == 1024 and caches[0].pos.shape[2] == 1024
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(3)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 8)), jnp.int32)
    logits, caches = prefill(params, cfg, tokens, None, cache_len=600,
                             opts=OPTS_Q)
    logits, _ = decode_step(params, cfg, tokens[:, :1], caches, jnp.int32(8),
                            OPTS_Q)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_ring_write_stays_within_window_on_padded_cache():
    """A block-padded sliding-window cache must wrap modulo the WINDOW, so no
    stored position can be older than the window and pad slots stay empty."""
    b, kh, hd, window, alloc = 1, 1, 8, 16, 24
    cache = L.KVCache(jnp.zeros((b, kh, alloc, hd), jnp.int8),
                      jnp.zeros((b, kh, alloc, hd), jnp.int8),
                      jnp.zeros((b, kh, alloc), jnp.float32),
                      jnp.zeros((b, kh, alloc), jnp.float32),
                      jnp.full((b, alloc), -1, jnp.int32))
    rng = np.random.default_rng(4)
    for pos in range(40):
        kv = jnp.asarray(rng.normal(size=(b, 1, kh, hd)), jnp.float32)
        cache = L.cache_update(cache, kv, kv, jnp.int32(pos), window=window)
    stored = np.asarray(cache.pos[0])
    assert np.all(stored[window:] == -1)  # pad slots never written
    assert set(stored[:window]) == set(range(40 - window, 40))


def test_engine_generate_has_no_host_transfer_in_loop():
    """Acceptance: the whole generation — prefill, decode scan, sampling —
    jit-traces with abstract inputs. Any host round-trip inside the loop
    (np.asarray, float(), .item()) would raise a TracerError here."""
    cfg = get_config("llama2-7b").tiny()
    eng = Engine(cfg, params=None, opts=OPTS, cache_len=64)
    fn = eng.generate_fn(max_new_tokens=6, greedy=True)
    params = jax.eval_shape(lambda k: init_params(cfg, k),
                            jax.ShapeDtypeStruct((2,), jnp.uint32))
    tokens = jax.ShapeDtypeStruct((3, 8), jnp.int32)
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    temp = jax.ShapeDtypeStruct((), jnp.float32)
    out, lps = jax.eval_shape(fn, params, tokens, None, key, temp)
    assert out.shape == (3, 14)
    assert lps.shape == (3, 6)  # per-token logprobs ride the same scan
    # the temperature-sampling branch traces too — and temperature is a
    # traced operand, so per-request temperatures share one compile
    fn_t = eng.generate_fn(max_new_tokens=4, greedy=False)
    out, _ = jax.eval_shape(fn_t, params, tokens, None, key, temp)
    assert out.shape == (3, 12)
    assert fn_t is eng.generate_fn(max_new_tokens=4, greedy=False)
