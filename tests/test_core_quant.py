"""Unit + property tests for the quantization primitives (Eq. 5-6)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.quant import (aiq, aiq_dequant, atom_lite, dequant_atom,
                              omniquant_lite, pack_int4, quantize_groupwise,
                              quantize_sym, smoothquant_lite, unpack_int4)

jax.config.update("jax_enable_x64", False)


def test_aiq_roundtrip_error_bounded():
    rng = np.random.default_rng(0)
    t = jnp.asarray(rng.normal(size=(64, 128)).astype(np.float32))
    for bits in (4, 6, 8):
        codes, s, z = aiq(t, bits, axis=-1)
        rec = aiq_dequant(codes, s, z)
        # max error ≤ half a quantization step per token
        step = jnp.max(s)
        assert float(jnp.max(jnp.abs(rec - t))) <= float(step) * 0.75 + 1e-6


def test_aiq_codes_in_range():
    rng = np.random.default_rng(1)
    t = jnp.asarray(rng.normal(size=(16, 32)).astype(np.float32) * 10)
    bits = 5
    codes, s, z = aiq(t, bits, axis=-1)
    qmax = 2 ** (bits - 1) - 1
    # per-token code span must fit in the 2^(Q-1) level budget
    span = jnp.max(codes, axis=-1) - jnp.min(codes, axis=-1)
    assert float(jnp.max(span)) <= qmax + 1e-5


def test_aiq_more_bits_less_error():
    rng = np.random.default_rng(2)
    t = jnp.asarray(rng.normal(size=(32, 64)).astype(np.float32))
    errs = []
    for bits in (3, 5, 8):
        codes, s, z = aiq(t, bits, axis=-1)
        errs.append(float(jnp.mean((aiq_dequant(codes, s, z) - t) ** 2)))
    assert errs[0] > errs[1] > errs[2]


@settings(max_examples=30, deadline=None)
@given(
    bits=st.integers(min_value=3, max_value=8),
    scale=st.floats(min_value=0.01, max_value=100.0),
    rows=st.integers(min_value=1, max_value=8),
)
def test_aiq_roundtrip_property(bits, scale, rows):
    rng = np.random.default_rng(bits * 1000 + rows)
    t = jnp.asarray(rng.normal(size=(rows, 16)).astype(np.float32) * scale)
    codes, s, z = aiq(t, bits, axis=-1)
    rec = aiq_dequant(codes, s, z)
    tol = float(jnp.max(s)) * 0.75 + 1e-5
    assert float(jnp.max(jnp.abs(rec - t))) <= tol


def test_quantize_sym_roundtrip():
    rng = np.random.default_rng(3)
    w = jnp.asarray(rng.normal(size=(128, 64)).astype(np.float32))
    for bits in (4, 8):
        qt = quantize_sym(w, bits, axis=-1)
        rec = qt.dequantize()
        step = float(jnp.max(qt.scale))
        assert float(jnp.max(jnp.abs(rec - w))) <= step * 0.51 + 1e-6
        assert qt.codes.dtype == jnp.int8


def test_groupwise_better_than_per_tensor():
    rng = np.random.default_rng(4)
    # heterogeneous channel scales — groupwise should win
    w = rng.normal(size=(256, 32)).astype(np.float32)
    w[:128] *= 50.0
    w = jnp.asarray(w)
    g = quantize_groupwise(w, 4, group=128)
    p = quantize_sym(w, 4, axis=None)
    eg = float(jnp.mean((g.dequantize() - w) ** 2))
    ep = float(jnp.mean((p.dequantize() - w) ** 2))
    assert eg < ep


def test_int4_pack_roundtrip():
    rng = np.random.default_rng(5)
    codes = jnp.asarray(rng.integers(-7, 8, size=257).astype(np.int8))
    packed = pack_int4(codes)
    assert packed.size == 129
    rec = unpack_int4(packed, 257)
    np.testing.assert_array_equal(np.asarray(rec), np.asarray(codes))


@settings(max_examples=20, deadline=None)
@given(n=st.integers(min_value=1, max_value=300))
def test_int4_pack_roundtrip_property(n):
    rng = np.random.default_rng(n)
    codes = jnp.asarray(rng.integers(-7, 8, size=n).astype(np.int8))
    rec = unpack_int4(pack_int4(codes), n)
    np.testing.assert_array_equal(np.asarray(rec), np.asarray(codes))


def test_atom_lite_outliers_exact_in_int8():
    rng = np.random.default_rng(6)
    w = rng.normal(size=(256, 64)).astype(np.float32)
    w[7] *= 100.0  # one screaming outlier channel
    w = jnp.asarray(w)
    q_low, q_out, mask = atom_lite(w, bits_low=4, outlier_frac=4 / 256)
    assert bool(mask[7])
    rec = dequant_atom(q_low, q_out, mask)
    # outlier channel error stays at int8 precision despite int4 body
    err_out = float(jnp.max(jnp.abs(rec[7] - w[7])))
    assert err_out <= float(jnp.max(jnp.abs(w[7]))) / 127 * 1.02
    # atom beats naive int4 per-tensor on this tensor
    naive = quantize_sym(w, 4, axis=None)
    assert float(jnp.mean((rec - w) ** 2)) < float(jnp.mean((naive.dequantize() - w) ** 2))


def test_smoothquant_omniquant_sanity():
    rng = np.random.default_rng(7)
    w = jnp.asarray(rng.normal(size=(64, 64)).astype(np.float32))
    act_absmax = jnp.asarray(rng.uniform(0.5, 20.0, size=(64,)).astype(np.float32))
    qt, s = smoothquant_lite(w, act_absmax, bits_w=8)
    assert qt.codes.shape == w.shape and s.shape == (64,)
    oq = omniquant_lite(w, 4)
    base = quantize_sym(w, 4, axis=-1)
    # learned clipping should never be (meaningfully) worse than no clipping
    e_oq = float(jnp.mean((oq.dequantize() - w) ** 2))
    e_base = float(jnp.mean((base.dequantize() - w) ** 2))
    assert e_oq <= e_base * 1.001
