"""Unified serving telemetry (``serving.telemetry``): streaming-histogram
percentile correctness, full request-lifecycle span coverage on a
preemption workload, the disabled-path no-op guarantee, Chrome-trace
schema validation (incl. ``tools/trace_report.py``), greedy bit-identity
with tracing on vs. off, ``LLMServer.metrics()``, and split-engine wire
accounting."""

import json

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.opsc import OPSCConfig
from repro.core.sampling import SamplingParams
from repro.models.transformer import RuntimeOpts, init_params
from repro.serving import (Engine, Histogram, LLMServer, Scheduler,
                           SplitEngine, Tracer)

OPTS_Q = RuntimeOpts(q_chunk=16, kv_chunk=16, remat=False, quantized_kv=True,
                     moe_capacity_factor=0.0)
OPTS = RuntimeOpts(q_chunk=16, kv_chunk=16, remat=False,
                   moe_capacity_factor=0.0)


@pytest.fixture(scope="module")
def tiny_model():
    cfg = get_config("llama2-7b").tiny()
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


# ------------------------------------------------------------- histogram


def test_histogram_percentiles_on_known_distribution():
    """1..10000 recorded once each: every quantile is within the sketch's
    relative error of the true value, count/sum/min/max are exact."""
    h = Histogram(rel_err=0.01)
    for v in range(1, 10001):
        h.record(float(v))
    assert h.count == 10000
    assert h.sum == pytest.approx(10000 * 10001 / 2)
    assert h.min == 1.0 and h.max == 10000.0
    for q in (0.10, 0.50, 0.95, 0.99):
        true = q * (h.count - 1) + 1
        assert h.percentile(q) == pytest.approx(true, rel=0.021)
    assert h.percentile(0.0) == 1.0
    assert h.percentile(1.0) == 10000.0
    s = h.summary()
    assert set(s) == {"count", "sum", "mean", "min", "max",
                      "p50", "p95", "p99"}


def test_histogram_zero_and_edge_cases():
    h = Histogram()
    assert h.percentile(0.5) is None and h.mean is None
    assert h.summary() == {"count": 0}
    h.record(0.0)
    h.record(0.0)
    h.record(5.0)
    assert h.percentile(0.0) == 0.0  # the exact zero bucket
    assert h.percentile(1.0) == 5.0
    with pytest.raises(ValueError):
        h.percentile(1.5)
    with pytest.raises(ValueError):
        Histogram(rel_err=0.0)


def test_metrics_registry_flat():
    from repro.serving import MetricsRegistry
    m = MetricsRegistry()
    m.count("a")
    m.count("a", 4)
    m.gauge("g", 7.5)
    m.observe("h", 2.0)
    m.observe("h", 4.0)
    flat = m.flat()
    assert flat["a"] == 5 and flat["g"] == 7.5
    assert flat["h.count"] == 2 and flat["h.min"] == 2.0
    assert flat["h.mean"] == pytest.approx(3.0)


# ------------------------------------------- lifecycle spans (scheduler)


def _preemption_run(cfg, params, tracer, resume="swap", abort_one=False):
    """The PR 3 preemption workload: lazy growth over a pool too small for
    every worst case forces at least one eviction + resume."""
    rng = np.random.default_rng(11)
    jobs = [(6, 8, 1), (5, 9, 0), (4, 8, 0)]  # (prompt, max_new, priority)
    prompts = [rng.integers(0, cfg.vocab_size, (n,)) for n, _, _ in jobs]
    sched = Scheduler(cfg, params, OPTS_Q, num_pages=9, page_size=4,
                      max_slots=3, lazy_growth=True, resume=resume,
                      telemetry=tracer)
    rids = [sched.submit(p, mn, priority=pr)
            for p, (_, mn, pr) in zip(prompts, jobs)]
    if abort_one:
        extra = sched.submit(rng.integers(0, cfg.vocab_size, (4,)), 6)
        sched.abort(extra)
    results = sched.run()
    assert sched.stats.preemptions >= 1
    return sched, rids, prompts, jobs, results


def test_span_lifecycle_covers_every_phase(tiny_model):
    """Acceptance: a mixed prefill/decode/preemption run lands >= 1 span
    or instant per lifecycle phase — queued, prefill, first_token, decode,
    preempt, swap_out/swap_resume, finish — with consistent timestamps."""
    cfg, params = tiny_model
    tracer = Tracer()
    sched, rids, _, _, _ = _preemption_run(cfg, params, tracer,
                                           abort_one=True)
    by_name = {}
    for sp in sched.telemetry.spans:
        by_name.setdefault(sp.name, []).append(sp)
    ev_names = {e[0] for e in tracer.events}
    for phase in ("queued", "prefill", "decode", "swap_out", "swap_resume"):
        assert phase in by_name, f"no {phase} span recorded"
    assert {"first_token", "finish", "preempt"} <= ev_names
    # every span closed (run drained), every duration non-negative
    for sp in tracer.spans:
        assert sp.end is not None, f"{sp.name} left open"
        assert sp.duration >= 0.0
    # preempted request: its queued span count exceeds one (requeued)
    requeued = [sp for sp in by_name["queued"]
                if sp.attrs.get("requeued")]
    assert requeued and requeued[0].attrs["reason"] == "preempt"
    # ttft bookkeeping: every finished request got a ttft_ticks entry,
    # and spans carry the tick ids they started under
    assert set(rids) <= set(tracer.ttft_ticks)
    assert all(t >= 1 for t in tracer.ttft_ticks.values())
    assert any("tick" in sp.attrs for sp in by_name["prefill"])
    m = tracer.metrics_dict()
    assert m["scheduler.preemptions"] >= 1
    assert m["requests.finish_reason.abort"] == 1
    assert m["ttft_s.count"] == len(rids)
    assert m["tick.count"] == len(tracer.ticks) > 0


def test_tick_timeline_records(tiny_model):
    """Per-tick records: every tick carries mode/token/pool/queue fields,
    compile counts sum to the scheduler's compiled-shape stat, and the
    final tick leaves the pool empty."""
    cfg, params = tiny_model
    tracer = Tracer()
    sched, _, _, jobs, _ = _preemption_run(cfg, params, tracer)
    ticks = tracer.ticks
    assert [r.tick for r in ticks] == sorted(r.tick for r in ticks)
    assert all(r.wall_s >= 0 and r.mode == sched.tick_mode for r in ticks)
    assert sum(r.new_compiles for r in ticks) == sched.stats.compiled_shapes
    assert sum(r.new_compiles + r.shape_hits for r in ticks) \
        == tracer.metrics.counters["compile.dispatches"]
    # generated tokens all appear in the timeline (prefill + decode)
    total = sum(r.tokens for r in ticks)
    assert total >= sum(mn for _, mn, _ in jobs)
    assert ticks[-1].pages_in_use == 0 and ticks[-1].queue_depth == 0
    assert max(r.pages_in_use for r in ticks) > 0
    assert max(r.swap_bytes for r in ticks) > 0  # swap really happened


# --------------------------------------------------- disabled path no-op


def test_disabled_path_never_touches_tracer(tiny_model, monkeypatch):
    """Overhead guard: with ``telemetry=None`` (the default) NO Tracer
    method may run — every public method is patched to raise, and a full
    preemption run plus fused + split generations must still succeed."""
    cfg, params = tiny_model

    def boom(self, *a, **k):  # pragma: no cover - must never fire
        raise AssertionError("Tracer touched on the disabled path")

    for name in dir(Tracer):
        if not name.startswith("_"):
            monkeypatch.setattr(Tracer, name, boom)
    sched, _, _, _, _ = _preemption_run(cfg, params, None)
    assert sched.telemetry is None
    eng = Engine(cfg, params, OPTS_Q, cache_len=32)
    assert eng.telemetry is None
    eng.generate(np.arange(4, dtype=np.int32)[None] % cfg.vocab_size, 3)
    opsc = OPSCConfig(split_layer=1, qw_front=16, i_kv=1)
    se = SplitEngine(cfg, params, opsc, opts=OPTS, cache_len=32)
    assert se.telemetry is None
    se.generate(np.arange(5, dtype=np.int32)[None] % cfg.vocab_size, 3,
                compress=False)


# ------------------------------------------------------- greedy identity


def test_greedy_bit_identical_telemetry_on_vs_off(tiny_model):
    """Acceptance: tracing must observe, never perturb — the preemption
    workload's greedy tokens are IDENTICAL with telemetry on and off."""
    cfg, params = tiny_model
    _, rids_off, _, _, res_off = _preemption_run(cfg, params, None)
    _, rids_on, _, _, res_on = _preemption_run(cfg, params, Tracer())
    for ra, rb in zip(rids_off, rids_on):
        np.testing.assert_array_equal(res_off[ra], res_on[rb])


# ----------------------------------------------------- chrome trace export


def test_chrome_trace_schema_and_report(tiny_model, tmp_path):
    """The exported trace is valid Chrome trace-event JSON: every event
    has ph/pid/tid/ts, spans have non-negative dur, tracks map to stable
    tids (ticks=0, queue=1, slot<i>=2+i), metadata names every track, and
    ``tools/trace_report.py`` validates it with all 7 phases required."""
    cfg, params = tiny_model
    tracer = Tracer()
    _preemption_run(cfg, params, tracer, abort_one=True)
    path = tmp_path / "trace.json"
    trace = tracer.export_chrome_trace(str(path))
    on_disk = json.loads(path.read_text())
    assert on_disk["displayTimeUnit"] == "ms"
    assert on_disk["repro_metrics"] == pytest.approx(trace["repro_metrics"])
    evs = trace["traceEvents"]
    assert all({"name", "ph", "pid"} <= set(e) for e in evs)
    for e in evs:
        if e["ph"] == "X":
            assert e["ts"] >= 0 and e["dur"] >= 0
        if e["ph"] == "i":
            assert e["s"] == "t"
    tids = {e["args"]["name"]: e["tid"] for e in evs
            if e["ph"] == "M" and e["name"] == "thread_name"}
    assert tids["ticks"] == 0 and tids["queue"] == 1
    assert tids["slot0"] == 2
    queued = [e for e in evs if e.get("cat") == "span"
              and e["name"] == "queued"]
    assert queued and all(e["tid"] == 1 for e in queued)
    tick_evs = [e for e in evs if e.get("cat") == "tick"]
    assert tick_evs and all(e["tid"] == 0 for e in tick_evs)

    from tools.trace_report import report, validate
    problems = validate(
        trace, require_phases=("queued", "prefill", "first_token", "decode",
                               "preempt", "swap_resume", "finish"),
        min_spans=5, min_ticks=5)
    assert problems == []
    import io
    buf = io.StringIO()
    report(trace, out=buf)
    text = buf.getvalue()
    assert "prefill" in text and "SLO table" in text
    from tools.trace_report import main as report_main
    assert report_main([str(path), "--require-spans", "5",
                        "--require-ticks", "5",
                        "--require-phases", "queued,preempt,finish"]) == 0
    assert report_main([str(path), "--require-phases", "warpdrive"]) == 1


def test_open_spans_export_closed_at_export_instant():
    t = [0.0]
    tracer = Tracer(clock=lambda: t[0])
    tracer.request_submitted(1)
    t[0] = 2.0
    trace = tracer.export_chrome_trace()
    sp = [e for e in trace["traceEvents"] if e.get("cat") == "span"]
    assert len(sp) == 1 and sp[0]["args"]["open"] is True
    assert sp[0]["dur"] == pytest.approx(2e6)


# ------------------------------------------------------ server integration


def test_llmserver_metrics_and_ttft_ticks_paged(tiny_model):
    cfg, params = tiny_model
    rng = np.random.default_rng(3)
    srv = LLMServer(cfg, params, OPTS_Q, backend="paged", num_pages=24,
                    page_size=4, max_slots=3, telemetry=True)
    assert srv.tracer is not None
    rids = [srv.submit(rng.integers(0, cfg.vocab_size, (n,)),
                       SamplingParams(max_tokens=4)) for n in (5, 7)]
    outs = srv.run()
    m = srv.metrics()
    assert m["requests.submitted"] == 2 and m["requests.finished"] == 2
    assert m["ttft_s.count"] == 2 and m["tick.count"] >= 1
    assert m["requests.retained"] == 2
    assert m["requests.reason.length"] == 2
    for rid in rids:
        assert outs[rid].metrics.ttft_ticks == srv.tracer.ttft_ticks[rid]


def test_llmserver_metrics_without_telemetry(tiny_model):
    """server.metrics() still reports request-level aggregates with the
    tracer off — from the retained RequestOutputs."""
    cfg, params = tiny_model
    rng = np.random.default_rng(4)
    srv = LLMServer(cfg, params, OPTS_Q, backend="paged", num_pages=24,
                    page_size=4, max_slots=2)
    assert srv.tracer is None
    srv.submit(rng.integers(0, cfg.vocab_size, (5,)),
               SamplingParams(max_tokens=3))
    srv.run()
    m = srv.metrics()
    assert m["requests.retained"] == 1
    assert m["requests.reason.length"] == 1
    assert m["requests.ttft_s.count"] == 1
    assert "requests.ttft_ticks.p50" in m


def test_fused_backend_ttft_ticks_and_span(tiny_model):
    """Satellite: the fused backend now populates RequestMetrics.ttft_ticks
    (one fused call = tick 1) and lands a fused_generate span."""
    cfg, params = tiny_model
    rng = np.random.default_rng(5)
    srv = LLMServer(cfg, params, OPTS_Q, backend="fused", cache_len=32,
                    telemetry=True)
    rid = srv.submit(rng.integers(0, cfg.vocab_size, (5,)),
                     SamplingParams(max_tokens=4))
    out = srv.run()[rid]
    assert out.metrics.ttft_ticks == 1
    names = {sp.name for sp in srv.tracer.spans}
    assert "fused_generate" in names
    m = srv.metrics()
    assert m["fused.calls"] >= 1 and m["fused.batch_s.count"] >= 1


def test_split_backend_telemetry_wire_accounting(tiny_model):
    """Split backend: edge/cloud segment spans, per-step uplink events
    whose bits sum to SplitStats.uplink_bits_measured, and the TAB-Q
    bit-width histogram with one entry per uplinked token."""
    cfg, params = tiny_model
    rng = np.random.default_rng(6)
    opsc = OPSCConfig(split_layer=1, qw_front=16, i_kv=1)
    srv = LLMServer(cfg, params, OPTS, backend="split", opsc=opsc,
                    cache_len=32, telemetry=True)
    rid = srv.submit(rng.integers(0, cfg.vocab_size, (6,)),
                     SamplingParams(max_tokens=4))
    out = srv.run()[rid]
    assert out.metrics.ttft_ticks == 1
    tr = srv.tracer
    tracks = {sp.track for sp in tr.spans}
    assert "split:edge" in tracks and "split:cloud" in tracks
    stages = {sp.attrs.get("stage") for sp in tr.spans
              if sp.track == "split:edge"}
    assert {"prefill", "decode"} <= stages
    uplinks = [e for e in tr.events if e[0] == "uplink"]
    assert sum(e[4]["bits"] for e in uplinks) \
        == out.split_stats.uplink_bits_measured
    m = tr.metrics_dict()
    assert m["split.uplink_bits_measured"] \
        == out.split_stats.uplink_bits_measured
    assert m["split.tabq_bits.count"] > 0
    assert 1 <= m["split.tabq_bits.min"] <= m["split.tabq_bits.max"] <= 16
    assert m["split.edge_s.count"] >= 1 and m["split.cloud_s.count"] >= 1


# ------------------------------------------------------- kv pool gauges


def test_pool_swap_bytes_accounting(tiny_model):
    """pool.swap_bytes tracks bytes parked on the host: export raises it,
    restore and discard both return it to zero."""
    cfg, params = tiny_model
    from repro.serving.kv_pool import PagedKVPool
    pool = PagedKVPool(cfg, num_pages=8, page_size=4, max_requests=2)
    assert pool.gauges()["swap_bytes"] == 0
    slot = pool.admit(6)
    pool.commit_prefill(slot, 6)
    snap = pool.export_slot(slot)
    nbytes = PagedKVPool.snapshot_bytes(snap)
    assert nbytes > 0 and pool.gauges()["swap_bytes"] == nbytes
    pool.free(slot)
    slot2 = pool.restore_slot(snap)
    assert pool.gauges()["swap_bytes"] == 0
    snap2 = pool.export_slot(slot2)
    assert pool.gauges()["swap_bytes"] == PagedKVPool.snapshot_bytes(snap2)
    pool.discard_snapshot(snap2)
    assert pool.gauges()["swap_bytes"] == 0
