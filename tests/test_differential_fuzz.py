"""Differential fuzzing of the continuous-batching scheduler: seeded random
admission / abort / preempt / swap schedules, driven through every tick mode
with speculation off and on, must emit greedy streams BIT-IDENTICAL to the
per-request ``Engine.generate`` oracle — whatever the schedule interleaving.

Each (tick_mode, speculate_k) config reuses ONE scheduler instance across
schedules so the jitted tick functions compile once; the pool must drain to
zero pages between schedules (leak check rides along for free). A failing
schedule is SHRUNK — jobs dropped one at a time while the failure
reproduces — so the assertion message carries a minimal repro, not a
20-request haystack.

Every config — packed included — is held to the per-request Engine oracle.
The packed tick historically could NOT be (PR6): the varlen flat-batch
kernel attended a decode token's OWN key as fresh f32 where the
Engine/chunked/verify paths read it int8-quantized from the cache, and a
near-tie in the top-2 logits flipped the argmax. The scheduler now marks
the packed buffer's decode rows in a ``quant_fresh`` mask and the packed
step routes those rows' fresh k/v through the int8 round trip
(``codes.astype(f32) * scale`` — the exact dequantized values a
sequential decode step reads back from the pool), which restores the
bit-identity and retired the solo-run invariance oracle this file used
to carry for packed configs.

Tier-1 runs a small schedule count; ``-m slow`` scales the same walk past
200 schedules (the CI slow job).
"""

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.transformer import RuntimeOpts, init_params
from repro.serving.engine import Engine
from repro.serving.scheduler import Scheduler

OPTS_Q = RuntimeOpts(q_chunk=16, kv_chunk=16, remat=False, quantized_kv=True,
                     moe_capacity_factor=0.0)
CONFIGS = [(mode, k) for mode in ("packed", "chunked", "wave")
           for k in (0, 2)]
MAX_TICKS = 400


@pytest.fixture(scope="module")
def tiny_model():
    cfg = get_config("llama2-7b").tiny()
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


@pytest.fixture(scope="module")
def oracle(tiny_model):
    """Per-request greedy Engine reference, memoized across schedules."""
    cfg, params = tiny_model
    eng = Engine(cfg, params, OPTS_Q, cache_len=64)
    cache = {}

    def get(prompt, max_new):
        key = (prompt.tobytes(), len(prompt), max_new)
        if key not in cache:
            cache[key] = eng.generate(prompt[None], max_new).tokens[0]
        return cache[key]

    return get


def _random_schedule(rng, vocab):
    """One schedule: jobs with staggered submit ticks, occasional aborts,
    and a mix of repetitive prompts (prompt-lookup drafting has signal →
    acceptance > 0) and random prompts (drafts mostly rejected →
    rollback exercised)."""
    jobs = []
    for _ in range(int(rng.integers(2, 6))):
        if rng.random() < 0.5:
            base = rng.integers(0, vocab, (int(rng.integers(2, 4)),))
            prompt = np.tile(base, 5)[: int(rng.integers(3, 11))]
        else:
            prompt = rng.integers(0, vocab, (int(rng.integers(2, 13)),))
        jobs.append({
            "prompt": prompt.astype(np.int32),
            "max_new": int(rng.integers(1, 9)),
            "submit_at": int(rng.integers(0, 4)),
            "abort_at": int(rng.integers(1, 12))
            if rng.random() < 0.25 else None,
        })
    return jobs


def _drive(sched, jobs):
    """Play one schedule: submit jobs at their ticks, abort on cue, step to
    drain. Returns {job_index: rid}."""
    rids = {}
    tick = 0
    while True:
        for j, job in enumerate(jobs):
            if j not in rids and job["submit_at"] <= tick:
                rids[j] = sched.submit(job["prompt"], job["max_new"])
            if (job["abort_at"] == tick and j in rids):
                sched.abort(rids[j])
        if sched.pending:
            sched.step()
        elif len(rids) == len(jobs):
            break
        tick += 1
        assert tick < MAX_TICKS, "schedule failed to drain"
    return rids


def _check_schedule(sched, oracle, jobs):
    """Drive one schedule and return a list of mismatch descriptions
    (empty = the schedule round-trips bit-exactly)."""
    rids = _drive(sched, jobs)
    events = sched.drain_events()
    problems = []
    seen = {}
    for rid, idx, tok, lp in events:
        if idx != seen.get(rid, -1) + 1:
            problems.append(f"rid {rid}: event index {idx} after "
                            f"{seen.get(rid, -1)}")
        seen[rid] = idx
        assert np.isfinite(lp)
    for j, job in enumerate(jobs):
        rid = rids[j]
        got = sched.results[rid]
        reason = sched.finish_reasons[rid]
        want = oracle(job["prompt"], job["max_new"])
        if reason == "abort":
            if not np.array_equal(got, want[: len(got)]):
                problems.append(f"job {j} (abort): partial stream is not "
                                f"a prefix of the oracle stream")
        elif not np.array_equal(got, want):
            d = next((i for i in range(min(len(got), len(want)))
                      if got[i] != want[i]), min(len(got), len(want)))
            problems.append(
                f"job {j}: diverged from the oracle at token {d} "
                f"(prompt_len={len(job['prompt'])}, "
                f"max_new={job['max_new']}): {got[d:d + 3]} vs "
                f"{want[d:d + 3]}")
    if sched.pool.pages_in_use != 0:
        problems.append(f"pool leaked {sched.pool.pages_in_use} pages")
    return problems


def _shrink(make_sched, oracle, jobs):
    """Greedy delta-debugging: drop jobs one at a time while the failure
    still reproduces on a FRESH scheduler."""
    cur = list(jobs)
    changed = True
    while changed and len(cur) > 1:
        changed = False
        for i in range(len(cur)):
            trial = cur[:i] + cur[i + 1:]
            if _check_schedule(make_sched(), oracle, trial):
                cur = trial
                changed = True
                break
    return cur


def _fuzz(tiny_model, oracle, mode, k, n_schedules, seed=0):
    cfg, params = tiny_model

    def make_sched():
        # lazy growth + a tight pool: concurrent load forces the
        # preempt → swap → resume path to fire inside the schedules
        return Scheduler(cfg, params, OPTS_Q, num_pages=24, page_size=4,
                         max_slots=3, tick_mode=mode, speculate_k=k,
                         lazy_growth=True)

    sched = make_sched()
    rng = np.random.default_rng(seed)
    for n in range(n_schedules):
        jobs = _random_schedule(rng, cfg.vocab_size)
        problems = _check_schedule(sched, oracle, jobs)
        if problems:
            minimal = _shrink(make_sched, oracle, jobs)
            spec = [(list(map(int, j["prompt"])), j["max_new"],
                     j["submit_at"], j["abort_at"]) for j in minimal]
            pytest.fail(
                f"{mode} speculate_k={k} schedule {n}: {problems}\n"
                f"minimal repro (prompt, max_new, submit_at, abort_at): "
                f"{spec}")
    assert sched.stats.aborted + sched.stats.preemptions > 0 or \
        sched.stats.evicted > 0
    if k:
        assert sched.stats.spec_rounds > 0


@pytest.mark.parametrize("mode,k", CONFIGS,
                         ids=[f"{m}-k{k}" for m, k in CONFIGS])
def test_fuzz_schedules_match_engine(tiny_model, oracle, mode, k):
    """Tier-1: a handful of randomized schedules per config — every
    non-aborted request's greedy stream equals the per-request Engine
    oracle's, aborted ones are exact prefixes, events arrive in index
    order, the pool drains clean."""
    _fuzz(tiny_model, oracle, mode, k, n_schedules=3)


@pytest.mark.slow
@pytest.mark.parametrize("mode,k", CONFIGS,
                         ids=[f"{m}-k{k}" for m, k in CONFIGS])
def test_fuzz_schedules_match_engine_deep(tiny_model, oracle, mode, k):
    """The CI slow job: the same walk, 35 schedules per config — 210
    schedules across the grid, all bit-exact."""
    _fuzz(tiny_model, oracle, mode, k, n_schedules=35, seed=1000)
