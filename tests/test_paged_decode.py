"""Paged decode path: the block-table Pallas kernel against the paged and
dense oracles (ragged causal bounds, trash-page masking, free-slot rows),
and the model-level ragged paged prefill/decode against the dense
quantized-cache path per request."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.kernels import ref
from repro.kernels.ops import decode_attention, paged_decode_attention
from repro.models.transformer import (RuntimeOpts, decode_step, init_params,
                                      paged_decode_step, paged_prefill,
                                      prefill)
from repro.serving.kv_pool import PagedKVPool

OPTS_Q = RuntimeOpts(q_chunk=16, kv_chunk=16, remat=False, quantized_kv=True,
                     moe_capacity_factor=0.0)


def _pool_fixture(rng, p=10, kh=2, page=16, hd=32, lens=(40, 20, 10)):
    """A hand-built pool: request r holds ``lens[r]`` tokens in pages
    [1 + sum(prior pages)...]; page 0 is trash (pos = -1)."""
    kc = jnp.asarray(rng.integers(-127, 128, (p, kh, page, hd)), jnp.int8)
    vc = jnp.asarray(rng.integers(-127, 128, (p, kh, page, hd)), jnp.int8)
    ks = jnp.asarray(rng.uniform(0.005, 0.02, (p, kh, page)), jnp.float32)
    vs = jnp.asarray(rng.uniform(0.005, 0.02, (p, kh, page)), jnp.float32)
    maxb = max(-(-n // page) for n in lens)
    bt = np.zeros((len(lens), maxb), np.int32)
    pool_pos = np.full((p, page), -1, np.int32)
    nxt = 1
    for r, n in enumerate(lens):
        for b in range(-(-n // page)):
            bt[r, b] = nxt
            nxt += 1
        for t in range(n):
            pool_pos[bt[r, t // page], t % page] = t
    assert nxt <= p
    return kc, ks, vc, vs, jnp.asarray(pool_pos), jnp.asarray(bt)


@pytest.mark.parametrize("g,kh", [(2, 2), (4, 1), (1, 2)])
@pytest.mark.parametrize("lens", [(40, 20, 10), (16, 16, 16), (31, 1, 7)])
def test_paged_kernel_matches_paged_oracle(g, kh, lens):
    rng = np.random.default_rng(g * 10 + sum(lens))
    kc, ks, vc, vs, pool_pos, bt = _pool_fixture(rng, kh=kh, lens=lens)
    q = jnp.asarray(rng.normal(size=(len(lens), kh, g, 32)), jnp.float32)
    q_pos = jnp.asarray([n - 1 for n in lens], jnp.int32)
    got = paged_decode_attention(q, kc, ks, vc, vs, pool_pos, bt, q_pos)
    want = ref.paged_decode_attention_ref(q, kc, ks, vc, vs, pool_pos, bt,
                                          q_pos)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_paged_kernel_matches_dense_kernel():
    """Gathering a request's pages dense and running the PR 1 dense kernel
    must agree with the paged kernel reading the pool in place."""
    rng = np.random.default_rng(3)
    lens = (40, 20, 10)
    kc, ks, vc, vs, pool_pos, bt = _pool_fixture(rng, lens=lens)
    q = jnp.asarray(rng.normal(size=(3, 2, 2, 32)), jnp.float32)
    q_pos = jnp.asarray([n - 1 for n in lens], jnp.int32)
    got = paged_decode_attention(q, kc, ks, vc, vs, pool_pos, bt, q_pos)
    for r, n in enumerate(lens):
        dense = decode_attention(
            q[r:r + 1],
            ref.gather_pages_ref(kc, bt[r:r + 1]),
            ref.gather_pages_ref(ks, bt[r:r + 1]),
            ref.gather_pages_ref(vc, bt[r:r + 1]),
            ref.gather_pages_ref(vs, bt[r:r + 1]),
            ref.gather_pages_ref(pool_pos, bt[r:r + 1]),
            jnp.int32(n - 1), block_s=16)
        np.testing.assert_allclose(np.asarray(got[r]), np.asarray(dense[0]),
                                   rtol=1e-4, atol=1e-4)


def test_paged_kernel_per_request_causal_bounds():
    """Ragged q_pos: lowering one request's bound must change only that
    request's output (per-request causal masking, not a shared scalar)."""
    rng = np.random.default_rng(5)
    kc, ks, vc, vs, pool_pos, bt = _pool_fixture(rng)
    q = jnp.asarray(rng.normal(size=(3, 2, 2, 32)), jnp.float32)
    a = paged_decode_attention(q, kc, ks, vc, vs, pool_pos, bt,
                               jnp.asarray([39, 19, 9], jnp.int32))
    b = paged_decode_attention(q, kc, ks, vc, vs, pool_pos, bt,
                               jnp.asarray([5, 19, 9], jnp.int32))
    assert float(jnp.max(jnp.abs(a[0] - b[0]))) > 1e-6
    np.testing.assert_allclose(np.asarray(a[1:]), np.asarray(b[1:]), rtol=1e-6)


def test_paged_kernel_inactive_row_is_finite_zero():
    """A free decode slot (block table all trash, q_pos = -1) must produce a
    finite all-zero row, never NaN — the scheduler decodes a fixed-shape
    batch with such rows every step."""
    rng = np.random.default_rng(7)
    kc, ks, vc, vs, pool_pos, bt_full = _pool_fixture(rng)
    bt = jnp.asarray(np.vstack([np.asarray(bt_full[:1]),
                                np.zeros((1, bt_full.shape[1]), np.int32)]))
    q = jnp.asarray(rng.normal(size=(2, 2, 2, 32)), jnp.float32)
    out = paged_decode_attention(q, kc, ks, vc, vs, pool_pos, bt,
                                 jnp.asarray([39, -1], jnp.int32))
    assert bool(jnp.all(jnp.isfinite(out)))
    np.testing.assert_array_equal(np.asarray(out[1]), 0.0)


# -------------------------------------------------- model-level parity


def test_ragged_paged_prefill_and_decode_match_dense_per_request():
    """Acceptance: a ragged batch of 3 requests with unequal prompt lengths
    through paged_prefill + paged_decode_step matches the dense quantized
    per-request path — prefill logits BIT-exactly (same math, the pool only
    re-addresses the writes), decode within fp-reassociation tolerance of
    the page walk, and greedy argmax identically."""
    cfg = get_config("llama2-7b").tiny()
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    lens = [5, 8, 3]
    r, s_pad = len(lens), max(lens)
    prompts = [rng.integers(0, cfg.vocab_size, (n,)) for n in lens]
    tokens = np.zeros((r, s_pad), np.int32)
    posn = np.full((r, s_pad), -1, np.int32)
    for i, p in enumerate(prompts):
        tokens[i, s_pad - p.size:] = p
        posn[i, s_pad - p.size:] = np.arange(p.size)

    pool = PagedKVPool(cfg, num_pages=16, page_size=4, max_requests=r)
    slots = [pool.admit(n) for n in lens]
    logits, caches = paged_prefill(params, cfg, jnp.asarray(tokens),
                                   pool.device_caches(rows=slots),
                                   jnp.asarray(posn), OPTS_Q)
    pool.update_from(caches)
    for slot, n in zip(slots, lens):
        pool.commit_prefill(slot, n)

    nxt = np.asarray(jnp.argmax(logits, axis=-1))[:, None].astype(np.int32)
    pos = np.asarray(lens, np.int32)
    for slot in slots:
        pool.append(slot, 1)
    logits2, caches2 = paged_decode_step(params, cfg, jnp.asarray(nxt),
                                         pool.device_caches(),
                                         jnp.asarray(pos), OPTS_Q)

    for i, p in enumerate(prompts):
        want, dense_caches = prefill(params, cfg, jnp.asarray(p[None]), None,
                                     16, OPTS_Q)
        np.testing.assert_array_equal(np.asarray(logits[i]),
                                      np.asarray(want[0]))  # bit-exact
        want2, _ = decode_step(params, cfg, jnp.asarray(nxt[i][None]),
                               dense_caches, jnp.int32(lens[i]), OPTS_Q)
        np.testing.assert_allclose(np.asarray(logits2[i]), np.asarray(want2[0]),
                                   rtol=2e-4, atol=2e-4)
        assert int(jnp.argmax(logits2[i])) == int(jnp.argmax(want2[0]))
