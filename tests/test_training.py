"""Training substrate tests: optimizer math, data pipeline, loss decreases on
real (synthetic-corpus) training, checkpoint roundtrip."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data.pipeline import (ZipfMarkov, induction_batch, induction_loader,
                                 lm_loader, make_batch)
from repro.models.transformer import RuntimeOpts, init_params
from repro.training.checkpoint import restore_checkpoint, save_checkpoint
from repro.training.optimizer import (AdamWConfig, adamw_init, adamw_update,
                                      global_norm, lr_schedule)
from repro.training.train_loop import (TrainConfig, cross_entropy,
                                       init_train_state, make_train_step, train)

OPTS = RuntimeOpts(q_chunk=32, kv_chunk=32, remat=False, moe_capacity_factor=0.0)


def test_adamw_reduces_quadratic():
    cfg = AdamWConfig(lr=0.1, warmup_steps=0, total_steps=200, weight_decay=0.0)
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = adamw_init(params)
    for _ in range(150):
        grads = {"w": 2 * params["w"]}
        params, state, m = adamw_update(cfg, grads, state, params)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.2


def test_lr_schedule_shape():
    cfg = AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=100, min_lr_ratio=0.1)
    lrs = [float(lr_schedule(cfg, jnp.int32(s))) for s in (0, 5, 10, 50, 100, 200)]
    assert lrs[1] == pytest.approx(5e-4)
    assert lrs[2] == pytest.approx(1e-3)
    assert lrs[2] > lrs[3] > lrs[4]
    assert lrs[4] == pytest.approx(1e-4, rel=1e-3)
    assert lrs[5] == pytest.approx(1e-4, rel=1e-3)  # floor


def test_grad_accumulation_matches_full_batch():
    cfg = get_config("llama2-7b").tiny()
    params, opt_state = init_train_state(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batch = make_batch(rng.integers(0, cfg.vocab_size, (8, 16)))
    batch = {k: jnp.asarray(v) for k, v in batch.items()}

    tc1 = TrainConfig(AdamWConfig(lr=1e-2, warmup_steps=0, total_steps=10), accum_steps=1)
    tc4 = TrainConfig(AdamWConfig(lr=1e-2, warmup_steps=0, total_steps=10), accum_steps=4)
    p1, _, m1 = jax.jit(make_train_step(cfg, tc1, OPTS))(params, opt_state, batch)
    p4, _, m4 = jax.jit(make_train_step(cfg, tc4, OPTS))(params, opt_state, batch)
    assert float(m1["loss"]) == pytest.approx(float(m4["loss"]), rel=1e-4)
    diffs = jax.tree_util.tree_map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))), p1, p4)
    assert max(jax.tree_util.tree_leaves(diffs)) < 5e-3


def test_zipf_markov_learnable():
    """A tiny model trained on the Markov corpus must beat the unigram bound
    and approach the chain's entropy rate."""
    corpus = ZipfMarkov(vocab_size=64, branching=4, seed=0)
    cfg = get_config("llama2-7b").tiny()
    import dataclasses

    cfg = dataclasses.replace(cfg, vocab_size=64)
    loader = lm_loader(corpus, batch=16, seq=32, num_batches=120)
    tc = TrainConfig(AdamWConfig(lr=3e-3, warmup_steps=20, total_steps=120))
    params, _, hist = train(cfg, loader, tc, OPTS, log_every=1000)
    first, last = hist[0]["ce"], hist[-1]["ce"]
    assert last < first * 0.7  # clear learning signal
    h_chain = corpus.entropy_rate_bits() * np.log(2.0)
    assert last < np.log(64) * 0.8  # well below uniform
    assert last > h_chain * 0.5  # sanity: not below the entropy bound /2


def test_induction_task_shapes_and_mask():
    rng = np.random.default_rng(0)
    tokens, mask = induction_batch(rng, 4, 21, 64)
    assert tokens.shape == (4, 21)
    # copied region repeats the prefix
    np.testing.assert_array_equal(tokens[:, :10], tokens[:, 11:21])
    b = make_batch(tokens, mask)
    assert b["labels"].shape == (4, 21)
    assert b["loss_mask"].sum() > 0


def test_checkpoint_roundtrip():
    cfg = get_config("gemma2-2b").tiny()
    params = init_params(cfg, jax.random.PRNGKey(7))
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, params, step=42)
        template = jax.tree_util.tree_map(
            lambda a: jnp.zeros(a.shape, a.dtype), params)
        restored, step = restore_checkpoint(d, template)
        assert step == 42
        same = jax.tree_util.tree_map(
            lambda a, b: bool(jnp.all(a == b)), params, restored)
        assert all(jax.tree_util.tree_leaves(same))


def test_cross_entropy_masking():
    logits = jnp.zeros((2, 4, 8))
    labels = jnp.zeros((2, 4), jnp.int32)
    full = cross_entropy(logits, labels, jnp.ones((2, 4)))
    assert float(full) == pytest.approx(np.log(8), rel=1e-5)
    half = cross_entropy(logits, labels,
                         jnp.asarray([[1, 1, 0, 0], [0, 0, 0, 0]], jnp.float32))
    assert float(half) == pytest.approx(np.log(8), rel=1e-5)
