"""The async serving front end (``serving.async_engine`` +
``serving.http``): concurrent HTTP/SSE streams bit-identical to the sync
server, disconnect→abort frees pool pages, bounded admission returns 429,
SSE framing round-trips, graceful shutdown drains, auto prefix detection
parity, and the scheduler's cross-thread contracts (single-driver step
guard, lossless concurrent event drains)."""

import asyncio
import json
import threading

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.sampling import SamplingParams
from repro.models.transformer import RuntimeOpts, init_params
from repro.serving import Engine, LLMServer, Scheduler
from repro.serving.async_engine import AdmissionError, AsyncLLMServer
from repro.serving.http import ServingHTTPServer, SSEParser, sse_frame

OPTS_Q = RuntimeOpts(q_chunk=16, kv_chunk=16, remat=False, quantized_kv=True,
                     moe_capacity_factor=0.0)


@pytest.fixture(scope="module")
def tiny_model():
    cfg = get_config("llama2-7b").tiny()
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _paged(cfg, params, **kw):
    kw.setdefault("num_pages", 32)
    kw.setdefault("page_size", 4)
    kw.setdefault("max_slots", 3)
    return LLMServer(cfg, params, OPTS_Q, backend="paged", **kw)


def _run(coro, timeout=120.0):
    return asyncio.run(asyncio.wait_for(coro, timeout))


# ------------------------------------------------- raw HTTP test client


async def _open(host, port, method, path, body=None):
    reader, writer = await asyncio.open_connection(host, port)
    payload = b"" if body is None else json.dumps(body).encode()
    writer.write((f"{method} {path} HTTP/1.1\r\nHost: {host}\r\n"
                  f"Content-Type: application/json\r\n"
                  f"Content-Length: {len(payload)}\r\n\r\n").encode()
                 + payload)
    await writer.drain()
    status = await reader.readline()
    code = int(status.split()[1])
    headers = {}
    while (h := await reader.readline()) not in (b"\r\n", b"\n", b""):
        k, _, v = h.decode().partition(":")
        headers[k.strip().lower()] = v.strip()
    return reader, writer, code, headers


async def _request_json(host, port, method, path, body=None):
    reader, writer, code, headers = await _open(host, port, method, path,
                                                body)
    raw = await reader.read()  # Connection: close — EOF-terminated
    writer.close()
    return code, headers, json.loads(raw) if raw else None


async def _stream_completion(host, port, body):
    """POST a streaming completion; returns (code, headers, messages) with
    messages = parsed SSE payloads up to and including "[DONE]"."""
    reader, writer, code, headers = await _open(
        host, port, "POST", "/v1/completions", dict(body, stream=True))
    msgs, parser = [], SSEParser()
    if code == 200:
        while True:
            chunk = await reader.read(4096)
            if not chunk:
                break
            msgs += parser.feed(chunk)
            if msgs and msgs[-1] == "[DONE]":
                break
    writer.close()
    return code, headers, msgs


def _tokens_of(msgs):
    return [m["token"] for m in msgs
            if m != "[DONE]" and not m.get("finished")]


async def _boot(cfg, params, *, max_queue_depth=64, **server_kw):
    engine = AsyncLLMServer(_paged(cfg, params, **server_kw),
                            max_queue_depth=max_queue_depth)
    http = ServingHTTPServer(engine)
    await http.start()
    return http, engine


# ------------------------------------------- concurrent HTTP bit-parity


def test_eight_concurrent_http_streams_bit_identical(tiny_model):
    """The acceptance bar: 8 concurrent clients over real HTTP (with
    auto_prefix sharing on) stream greedy tokens bit-identical to the
    per-request Engine oracle, and the finish metadata survives SSE."""
    cfg, params = tiny_model
    rng = np.random.default_rng(0)
    shared = rng.integers(0, cfg.vocab_size, (10,))
    prompts = []
    for i in range(8):  # half share a 10-token head: auto_prefix forks
        tail = rng.integers(0, cfg.vocab_size, (3 + i % 3,))
        prompts.append(np.concatenate([shared, tail]) if i % 2 == 0
                       else rng.integers(0, cfg.vocab_size, (5 + i % 4,)))
    max_tokens = [4 + i % 4 for i in range(8)]
    eng = Engine(cfg, params, OPTS_Q, cache_len=32)
    want = [eng.generate(p[None], mt).tokens[0, p.shape[0]:]
            for p, mt in zip(prompts, max_tokens)]

    async def go():
        http, engine = await _boot(cfg, params, auto_prefix=True)
        try:
            outs = await asyncio.gather(*[
                _stream_completion(http.host, http.port,
                                   {"prompt": p.tolist(), "max_tokens": mt})
                for p, mt in zip(prompts, max_tokens)])
        finally:
            await http.stop()
        return outs, engine

    outs, engine = _run(go())
    for (code, _, msgs), w in zip(outs, want):
        assert code == 200
        np.testing.assert_array_equal(_tokens_of(msgs), w)
        fin = [m for m in msgs if m != "[DONE]" and m.get("finished")]
        assert len(fin) == 1 and fin[0]["finish_reason"] == "length"
        assert msgs[-1] == "[DONE]"
        assert all(np.isfinite(m["logprob"]) for m in msgs
                   if m != "[DONE]" and not m.get("finished"))
    sched = engine.server.backend.scheduler
    assert sched.stats.auto_prefix_hits >= 1
    assert sched.pool.gauges()["pages_in_use"] == 0


def test_nonstream_completion_and_metrics_endpoint(tiny_model):
    cfg, params = tiny_model
    rng = np.random.default_rng(1)
    p = rng.integers(0, cfg.vocab_size, (6,))
    want = Engine(cfg, params, OPTS_Q, cache_len=32).generate(
        p[None], 5).tokens[0, 6:]

    async def go():
        http, _ = await _boot(cfg, params)
        try:
            code, _, body = await _request_json(
                http.host, http.port, "POST", "/v1/completions",
                {"prompt": p.tolist(), "max_tokens": 5})
            hcode, _, health = await _request_json(
                http.host, http.port, "GET", "/healthz")
            mcode, _, metrics = await _request_json(
                http.host, http.port, "GET", "/v1/metrics")
            ncode, _, _ = await _request_json(
                http.host, http.port, "GET", "/nope")
        finally:
            await http.stop()
        return code, body, hcode, health, mcode, metrics, ncode

    code, body, hcode, health, mcode, metrics, ncode = _run(go())
    assert code == 200 and hcode == 200 and mcode == 200 and ncode == 404
    np.testing.assert_array_equal(body["tokens"], want)
    assert body["finish_reason"] == "length"
    assert len(body["logprobs"]) == len(body["tokens"])
    assert body["metrics"]["ttft_s"] > 0 and body["metrics"]["e2e_s"] > 0
    assert health["status"] == "ok"
    # the tick-thread-stamped SLO surface, correct with telemetry=None
    assert metrics["requests.e2e_s.count"] == 1
    assert metrics["requests.tpot_s.count"] == 1
    assert metrics["requests.ttft_s.p50"] > 0
    assert metrics["requests.reason.length"] == 1


# ------------------------------------------------- disconnect → no leak


def test_midstream_disconnect_frees_pool_pages(tiny_model):
    """A client that vanishes after one token must abort its request and
    leave ZERO pages in use once the scheduler settles."""
    cfg, params = tiny_model
    rng = np.random.default_rng(2)
    p = rng.integers(0, cfg.vocab_size, (8,))

    async def go():
        http, engine = await _boot(cfg, params)
        try:
            reader, writer, code, _ = await _open(
                http.host, http.port, "POST", "/v1/completions",
                {"prompt": p.tolist(), "max_tokens": 32, "stream": True})
            assert code == 200
            parser, got = SSEParser(), []
            while not got:  # first token arrived ⇒ request holds pages
                got += parser.feed(await reader.read(4096))
            writer.close()  # hang up mid-stream, no abort RPC
            await writer.wait_closed()
            sched = engine.server.backend.scheduler
            for _ in range(500):
                if not engine.server.pending and \
                        sched.pool.gauges()["pages_in_use"] == 0:
                    break
                await asyncio.sleep(0.01)
            gauges = sched.pool.gauges()
            out = await engine.result(
                next(iter(engine.server.outputs())))
        finally:
            await http.stop()
        return gauges, out

    gauges, out = _run(go())
    assert gauges["pages_in_use"] == 0 and gauges["pages_shared"] == 0
    assert out.finish_reason == "abort"
    assert out.metrics.e2e_s is not None  # aborts are stamped too


# ---------------------------------------------------- 429 backpressure


def test_backpressure_returns_429(tiny_model):
    """max_slots=1 + max_queue_depth=1: A streams (holds the slot), B
    queues, C must bounce with 429 + Retry-After instead of queuing
    without bound."""
    cfg, params = tiny_model
    rng = np.random.default_rng(3)
    pa, pb, pc = (rng.integers(0, cfg.vocab_size, (5,)) for _ in range(3))

    async def go():
        http, engine = await _boot(cfg, params, max_slots=1,
                                   max_queue_depth=1)
        try:
            ra, wa, code_a, _ = await _open(
                http.host, http.port, "POST", "/v1/completions",
                {"prompt": pa.tolist(), "max_tokens": 24, "stream": True})
            assert code_a == 200
            parser, got = SSEParser(), []
            while not got:  # A is admitted and decoding
                got += parser.feed(await ra.read(4096))
            b_task = asyncio.ensure_future(_request_json(
                http.host, http.port, "POST", "/v1/completions",
                {"prompt": pb.tolist(), "max_tokens": 2}))
            for _ in range(500):  # B accepted → scheduler queue depth 1
                _, _, health = await _request_json(
                    http.host, http.port, "GET", "/healthz")
                if health["queue_depth"] >= 1:
                    break
                await asyncio.sleep(0.01)
            assert health["queue_depth"] == 1
            code_c, headers_c, body_c = await _request_json(
                http.host, http.port, "POST", "/v1/completions",
                {"prompt": pc.tolist(), "max_tokens": 2})
            while got[-1] != "[DONE]":  # drain A; slot frees for B
                got += parser.feed(await ra.read(4096))
            wa.close()
            code_b, _, body_b = await b_task
        finally:
            await http.stop()
        return code_c, headers_c, body_c, code_b, body_b

    code_c, headers_c, body_c, code_b, body_b = _run(go())
    assert code_c == 429
    assert headers_c.get("retry-after") == "1"
    assert "admission queue full" in body_c["error"]
    assert code_b == 200 and len(body_b["tokens"]) == 2


# --------------------------------------------------------- SSE framing


def test_sse_framing_round_trips():
    msgs = [{"rid": 7, "index": i, "token": i * 3, "logprob": -0.25 * i}
            for i in range(5)]
    msgs.append({"rid": 7, "index": 5, "token": -1, "finished": True,
                 "finish_reason": "stop"})
    wire = b"".join(sse_frame(m) for m in msgs) + b"data: [DONE]\n\n"
    # every chunking of the byte stream decodes to the same payloads
    for size in (1, 2, 3, 7, len(wire)):
        parser, got = SSEParser(), []
        for i in range(0, len(wire), size):
            got += parser.feed(wire[i: i + size])
        assert got == msgs + ["[DONE]"]


# ----------------------------------------------------------- shutdown


def test_graceful_shutdown_drains_inflight(tiny_model):
    cfg, params = tiny_model
    rng = np.random.default_rng(4)
    prompts = [rng.integers(0, cfg.vocab_size, (5 + i,)) for i in range(2)]
    eng = Engine(cfg, params, OPTS_Q, cache_len=32)
    want = [eng.generate(p[None], 6).tokens[0, p.shape[0]:]
            for p in prompts]

    async def go():
        engine = AsyncLLMServer(_paged(cfg, params))
        rids = [await engine.submit(p, SamplingParams(max_tokens=6))
                for p in prompts]
        streams = [asyncio.ensure_future(_collect(engine, r)) for r in rids]
        await engine.shutdown(drain=True)  # must NOT cut the streams
        events = await asyncio.gather(*streams)
        with pytest.raises(Exception) as ei:
            await engine.submit(prompts[0], SamplingParams(max_tokens=2))
        return events, ei.value

    async def _collect(engine, rid):
        return [ev async for ev in engine.stream(rid)]

    events, err = _run(go())
    for evs, w in zip(events, want):
        assert evs[-1].finished and evs[-1].finish_reason == "length"
        np.testing.assert_array_equal([e.token for e in evs[:-1]], w)
    assert "shut down" in str(err)


def test_shutdown_now_aborts_inflight(tiny_model):
    cfg, params = tiny_model
    p = np.random.default_rng(5).integers(0, cfg.vocab_size, (6,))

    async def go():
        engine = AsyncLLMServer(_paged(cfg, params))
        rid = await engine.submit(p, SamplingParams(max_tokens=64))
        agen = engine.stream(rid)
        first = await agen.__anext__()  # admitted and producing
        await engine.shutdown(drain=False)
        evs = [ev async for ev in agen]  # abort marker still flushes
        out = await engine.result(rid)
        return first, evs, out

    first, evs, out = _run(go())
    assert not first.finished
    assert evs[-1].finished and evs[-1].finish_reason == "abort"
    assert out.finish_reason == "abort"


def test_admission_error_direct(tiny_model):
    """Bounded admission at the engine API level (no HTTP): the check and
    the submit are atomic on the tick thread."""
    cfg, params = tiny_model
    rng = np.random.default_rng(6)

    async def go():
        engine = AsyncLLMServer(_paged(cfg, params, max_slots=1),
                                max_queue_depth=1)
        r1 = await engine.submit(rng.integers(0, 64, (5,)),
                                 SamplingParams(max_tokens=16))
        agen = engine.stream(r1)
        await agen.__anext__()  # r1 admitted: slot busy, queue empty
        await engine.submit(rng.integers(0, 64, (5,)),
                            SamplingParams(max_tokens=2))  # queues
        with pytest.raises(AdmissionError):
            await engine.submit(rng.integers(0, 64, (5,)),
                                SamplingParams(max_tokens=2))
        async for _ in agen:
            pass
        await engine.shutdown()

    _run(go())


# ------------------------------------------- scheduler thread contracts


def test_step_guard_rejects_second_driver(tiny_model):
    """Scheduler.step() is single-driver: a second thread calling step()
    mid-tick gets a hard RuntimeError, not a silent data race."""
    cfg, params = tiny_model
    sched = Scheduler(cfg, params, OPTS_Q, num_pages=8, page_size=4,
                      max_slots=2)
    sched.submit(np.arange(4, dtype=np.int32), 2)
    assert sched._step_guard.acquire(blocking=False)  # a tick in flight
    try:
        with pytest.raises(RuntimeError, match="single-driver"):
            sched.step()
    finally:
        sched._step_guard.release()
    sched.run()  # guard released: normal drive still works


def test_concurrent_event_drain_loses_nothing(tiny_model):
    """drain_events() swaps under the emit lock: a producer hammering
    _emit_event from another thread never loses an event to the
    load/store interleave."""
    cfg, params = tiny_model
    sched = Scheduler(cfg, params, OPTS_Q, num_pages=8, page_size=4,
                      max_slots=2)
    n = 20000
    done = threading.Event()

    def produce():
        for i in range(n):
            sched._emit_event(1, i, i % 64, -0.5)
        done.set()

    t = threading.Thread(target=produce)
    t.start()
    got = []
    while not (done.is_set() and not sched._events):
        got += sched.drain_events()
    t.join()
    got += sched.drain_events()
    assert [e[1] for e in got] == list(range(n))


# ------------------------------------------------- auto prefix detection


def test_auto_prefix_detection_parity_and_forks(tiny_model):
    """auto_prefix=True: prompts sharing a long head get CoW page sharing
    with NO explicit prefix_key — and stay bit-identical to the plain
    scheduler."""
    cfg, params = tiny_model
    rng = np.random.default_rng(7)
    shared = rng.integers(0, cfg.vocab_size, (12,))
    prompts = [np.concatenate([shared,
                               rng.integers(0, cfg.vocab_size, (2 + i,))])
               for i in range(3)]
    prompts.append(rng.integers(0, cfg.vocab_size, (6,)))  # no shared head

    def drain(**kw):
        sched = Scheduler(cfg, params, OPTS_Q, num_pages=32, page_size=4,
                          max_slots=4, **kw)
        rids = [sched.submit(p, 4) for p in prompts]
        results = sched.run()
        return [results[r] for r in rids], sched.stats

    plain, _ = drain()
    auto, stats = drain(auto_prefix=True)
    for a, b in zip(plain, auto):
        np.testing.assert_array_equal(a, b)
    assert stats.auto_prefix_hits >= 2  # prompts 1 and 2 match prompt 0
    assert stats.prefix_forks >= 1  # at least one CoW fork attached
