"""Sharded + disaggregated serving acceptance: the ``deployment`` grid's
greedy token streams are BIT-IDENTICAL to the single-device per-request
``Engine.generate`` oracle.

The sharded tests need multiple host devices; CI's sharded-smoke job sets
``XLA_FLAGS=--xla_force_host_platform_device_count=4`` (the flag must be
in the ENVIRONMENT before jax imports — tests never set it in-process, so
a plain tier-1 run simply skips the >1-device cells of the grid and still
exercises the shard_map lowering on the degenerate (1, 1) mesh). Sub-mesh
cells build their mesh over ``jax.devices()[:n]``, so 1-, 2- and 4-device
topologies all run inside one forced-4-device process.

What the grid pins, per (devices, tick_mode, speculate_k) cell:

* every request's greedy stream equals the Engine oracle's, under a
  schedule tight enough to force preemption + swap on the sharded pool;
* packed mode still dispatches ONE compiled shape (speculation off) —
  sharding must not fracture the single-(1, T)-buffer property;
* the pool drains to zero pages (leak check).

Plus: the kv-pool randomized invariant walk re-run over a mesh-sharded
pool (same host allocator, device leaves placed by NamedSharding), and
the disaggregated prefill→decode deployment held to the same oracle with
page-stream transport accounting checked end to end.
"""

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.launch.mesh import make_serving_mesh
from repro.models.transformer import RuntimeOpts, init_params
from repro.serving.engine import Engine
from repro.serving.page_transport import DisaggregatedScheduler
from repro.serving.scheduler import Scheduler
from repro.serving.telemetry import Tracer

OPTS_Q = RuntimeOpts(q_chunk=16, kv_chunk=16, remat=False, quantized_kv=True,
                     moe_capacity_factor=0.0)
GRID = [(n, mode, k) for n in (1, 2, 4)
        for mode in ("packed", "chunked", "wave") for k in (0, 2)]


@pytest.fixture(scope="module")
def tiny_model():
    cfg = get_config("llama2-7b").tiny()
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


@pytest.fixture(scope="module")
def oracle(tiny_model):
    """Per-request greedy Engine reference, memoized across the grid."""
    cfg, params = tiny_model
    eng = Engine(cfg, params, OPTS_Q, cache_len=64)
    cache = {}

    def get(prompt, max_new):
        key = (prompt.tobytes(), len(prompt), max_new)
        if key not in cache:
            cache[key] = eng.generate(prompt[None], max_new).tokens[0]
        return cache[key]

    return get


def _mesh_or_skip(cfg, n):
    if jax.device_count() < n:
        pytest.skip(f"needs {n} host devices — run under "
                    "XLA_FLAGS=--xla_force_host_platform_device_count=4")
    return make_serving_mesh(cfg.pattern[0].mixer.num_kv_heads,
                             devices=jax.devices()[:n])


def _workload(cfg, seed=0, n_jobs=4):
    """A fixed mixed workload: staggered submits, repetitive prompts (so
    prompt-lookup drafts get accepted) and random ones, sized against a
    24-page pool with 3 slots so preemption + swap fire mid-run."""
    rng = np.random.default_rng(seed)
    jobs = []
    for i in range(n_jobs):
        if i % 2:
            base = rng.integers(0, cfg.vocab_size, (3,))
            prompt = np.tile(base, 4)[: int(rng.integers(5, 11))]
        else:
            prompt = rng.integers(0, cfg.vocab_size, (int(rng.integers(3, 13)),))
        jobs.append((prompt.astype(np.int32), int(rng.integers(2, 7)),
                     int(rng.integers(0, 3))))  # (prompt, max_new, submit_at)
    return jobs


def _drive(sched, jobs):
    rids, tick = {}, 0
    while True:
        for j, (prompt, max_new, submit_at) in enumerate(jobs):
            if j not in rids and submit_at <= tick:
                rids[j] = sched.submit(prompt, max_new)
        if sched.pending:
            sched.step()
        elif len(rids) == len(jobs):
            break
        tick += 1
        assert tick < 400, "schedule failed to drain"
    return rids


def _assert_streams_match(sched, rids, jobs, oracle):
    events = sched.drain_events()
    seen = {}
    for rid, idx, tok, lp in events:
        assert idx == seen.get(rid, -1) + 1, f"rid {rid} events out of order"
        seen[rid] = idx
        assert np.isfinite(lp)
    for j, (prompt, max_new, _) in enumerate(jobs):
        got = sched.results[rids[j]]
        want = oracle(prompt, max_new)
        np.testing.assert_array_equal(
            got, want, err_msg=f"job {j} diverged from the Engine oracle")


# ------------------------------------------------------- sharded scheduler


@pytest.mark.parametrize("n,mode,k", GRID,
                         ids=[f"d{n}-{m}-k{k}" for n, m, k in GRID])
def test_sharded_streams_match_engine(tiny_model, oracle, n, mode, k):
    """Acceptance: the shard_map-lowered scheduler over an n-device mesh
    emits bit-identical greedy streams to the single-device Engine, in
    every tick mode, speculation off and on, under preemption pressure."""
    cfg, params = tiny_model
    mesh = _mesh_or_skip(cfg, n)
    sched = Scheduler(cfg, params, OPTS_Q, num_pages=24, page_size=4,
                      max_slots=3, tick_mode=mode, speculate_k=k,
                      lazy_growth=True, mesh=mesh)
    jobs = _workload(cfg, seed=7)
    rids = _drive(sched, jobs)
    _assert_streams_match(sched, rids, jobs, oracle)
    assert sched.pool.pages_in_use == 0, "sharded pool leaked pages"
    if mode == "packed":
        assert sched.stats.packed_ticks > 0
        if k == 0:
            # sharding must not fracture the one-(1, T)-buffer property
            assert sched.stats.compiled_shapes == 1
    if k:
        assert sched.stats.spec_rounds > 0


def test_sharded_pool_leaves_are_mesh_placed(tiny_model):
    """The mesh-mode pool's device leaves carry the page-axis
    NamedSharding (axis 1 split over 'kv', block tables replicated) while
    the host allocator stays byte-identical to the unsharded pool."""
    cfg, params = tiny_model
    mesh = _mesh_or_skip(cfg, 2)
    from repro.serving.kv_pool import PagedKVPool
    pool = PagedKVPool(cfg, num_pages=16, page_size=4, max_requests=3,
                       mesh=mesh)
    s = pool.admit(6)
    pool.commit_prefill(s, 6)
    caches = pool.device_caches()
    leaf = jax.tree_util.tree_leaves(caches)[0]
    spec = leaf.sharding.spec
    assert spec[1] == "kv", f"page axis not sharded over kv: {spec}"
    pool.free(s)
    assert pool.pages_in_use == 0


def test_sharded_pool_property_walk(tiny_model):
    """The kv-pool randomized ownership walk (tests/test_kv_pool.py),
    re-run with the pool's leaves sharded over a 2-device mesh — the host
    allocator invariants must be mesh-blind."""
    cfg, _ = tiny_model
    mesh = _mesh_or_skip(cfg, 2)
    from tests.test_kv_pool import _check_pool_invariants, make_pool
    from repro.serving.kv_pool import PoolExhaustedError
    rng = np.random.default_rng(99)
    pool = make_pool(num_pages=20, page_size=4, max_requests=4, mesh=mesh)
    handles: list = []
    for _ in range(80):
        op = rng.integers(0, 4)
        active = list(np.flatnonzero(pool.active))
        try:
            if op == 0:
                n = int(rng.integers(1, 13))
                s = pool.admit(n)
                pool.commit_prefill(s, n)
            elif op == 1 and active:
                pool.append(active[rng.integers(len(active))],
                            int(rng.integers(1, 4)))
            elif op == 2 and active:
                s = active[rng.integers(len(active))]
                if int(pool.lengths[s]) >= 2:
                    handles.append(pool.share_prefix(
                        s, int(rng.integers(1, int(pool.lengths[s])))))
            elif op == 3 and active:
                pool.free(active[rng.integers(len(active))])
        except PoolExhaustedError:
            pass
        _check_pool_invariants(pool, handles)
    for s in list(np.flatnonzero(pool.active)):
        pool.free(s)
    for h in handles:
        pool.release_prefix(h)
    _check_pool_invariants(pool, handles)
    assert pool.pages_in_use == 0


# ------------------------------------------------- disaggregated serving


@pytest.mark.parametrize("mode,k", [("packed", 0), ("packed", 2),
                                    ("chunked", 2), ("wave", 0)],
                         ids=["packed-k0", "packed-k2", "chunked-k2",
                              "wave-k0"])
def test_disaggregated_streams_match_engine(tiny_model, oracle, mode, k):
    """Acceptance: prefill→decode disaggregation (two pools + the page
    stream) emits bit-identical greedy streams, events stay in per-request
    index order across the replica handoff, both pools drain, and every
    transferred byte lands in the transport spans/metrics."""
    cfg, params = tiny_model
    tr = Tracer()
    ds = DisaggregatedScheduler(cfg, params, OPTS_Q, telemetry=tr,
                                num_pages=24, page_size=4, max_slots=3,
                                tick_mode=mode, lazy_growth=True,
                                decode_kwargs={"speculate_k": k})
    jobs = _workload(cfg, seed=11, n_jobs=5)
    rids = _drive(ds, jobs)
    _assert_streams_match(ds, rids, jobs, oracle)
    assert ds.prefill.pool.pages_in_use == 0
    assert ds.decode.pool.pages_in_use == 0
    # multi-token requests crossed the stream; their bytes are accounted
    multi = sum(1 for _, max_new, _ in jobs if max_new > 1)
    assert ds.transport.transfers == multi * len(cfg.pattern)
    assert ds.transport.bytes_moved > 0
    spans = [sp for sp in tr.spans if sp.name == "page_stream"]
    assert sum(sp.attrs["bytes"] for sp in spans) == ds.transport.bytes_moved
    m = tr.metrics_dict()
    assert m["transport.page_stream.total_bytes"] == ds.transport.bytes_moved
    # swap-byte ownership handed off cleanly: neither pool holds residue
    assert ds.prefill.pool.swap_bytes == 0
    assert ds.decode.pool.swap_bytes == 0
    # ttft is a prefill-replica quantity; the merged stats carry it
    assert set(rids.values()) <= set(ds.stats.ttft_ticks)


def test_disaggregated_single_token_requests_finish_on_prefill(tiny_model,
                                                               oracle):
    """max_new_tokens == 1 finishes on the prefill replica — nothing to
    decode, nothing crosses the stream."""
    cfg, params = tiny_model
    ds = DisaggregatedScheduler(cfg, params, OPTS_Q, num_pages=24,
                                page_size=4, max_slots=3, tick_mode="packed",
                                lazy_growth=True)
    prompt = np.arange(1, 7, dtype=np.int32)
    rid = ds.submit(prompt, 1)
    res = ds.run()
    np.testing.assert_array_equal(res[rid], oracle(prompt, 1))
    assert ds.transport.transfers == 0
    assert rid in ds.prefill.results and rid not in ds.decode.results


def test_disaggregated_mismatched_page_size_rejected(tiny_model):
    cfg, params = tiny_model
    with pytest.raises(ValueError, match="page_size"):
        DisaggregatedScheduler(cfg, params, OPTS_Q, num_pages=16,
                               page_size=4, max_slots=2,
                               decode_kwargs={"page_size": 8})


# ------------------------------------------------------- deployment knob


def test_server_deployment_knob(tiny_model):
    """The ``deployment=`` knob on the paged backend: 'disaggregated'
    serves through the facade, 'fused' rejects a mesh, unknown names
    raise. (The 'sharded' path is covered device-parametrized above —
    here just the 1-device degenerate mesh build.)"""
    from repro.serving.api import LLMServer, SamplingParams

    cfg, params = tiny_model
    srv = LLMServer(cfg, params, OPTS_Q, backend="paged",
                    deployment="disaggregated", num_pages=24, page_size=4,
                    max_slots=3, tick_mode="packed", lazy_growth=True)
    prompt = np.arange(2, 9, dtype=np.int32)
    rid = srv.submit(prompt, SamplingParams(max_tokens=4))
    out = srv.run()[rid]
    eng = Engine(cfg, params, OPTS_Q, cache_len=64)
    want = eng.generate(prompt[None], 4).tokens[0][len(prompt):]
    np.testing.assert_array_equal(out.tokens, want)

    srv2 = LLMServer(cfg, params, OPTS_Q, backend="paged",
                     deployment="sharded", num_pages=24, page_size=4,
                     max_slots=3, lazy_growth=True)
    assert srv2.backend.scheduler.mesh is not None
    rid2 = srv2.submit(prompt, SamplingParams(max_tokens=4))
    out2 = srv2.run()[rid2]
    np.testing.assert_array_equal(out2.tokens, want)

    with pytest.raises(ValueError, match="deployment='sharded'"):
        LLMServer(cfg, params, OPTS_Q, backend="paged",
                  mesh=make_serving_mesh(2, devices=jax.devices()[:1]))
    with pytest.raises(ValueError, match="unknown deployment"):
        LLMServer(cfg, params, OPTS_Q, backend="paged", deployment="tpu")
