"""Async serving front end: one background tick thread, many asyncio clients.

Everything below :class:`LLMServer` is synchronous and single-driver — the
scheduler's tick loop wants to be driven hard from ONE thread, while HTTP
clients arrive concurrently on an asyncio event loop. This module is the
bridge:

  * a daemon **tick thread** owns the backend outright: it drives
    ``backend.step()`` continuously while work is pending and executes
    every mutating call (``submit`` / ``abort`` / ``metrics`` / ...)
    marshaled to it through a command queue — the scheduler never sees a
    second thread, so its single-driver contract
    (:meth:`repro.serving.scheduler.Scheduler.step`) holds by
    construction;
  * each tick's :class:`~repro.serving.api.TokenEvent` batch fans out to
    per-request ``asyncio.Queue``s via ``loop.call_soon_threadsafe`` —
    clients ``async for`` over :meth:`AsyncLLMServer.stream` without ever
    touching the backend;
  * **bounded admission**: :meth:`submit` raises :class:`AdmissionError`
    (HTTP 429 upstream) once ``server.queue_depth`` — requests accepted
    but not yet scheduled — reaches ``max_queue_depth``, so a traffic
    burst queues in the CLIENTS, not in an unbounded server-side list;
  * **client disconnect → abort**: leaving :meth:`stream` early (the HTTP
    layer closes the generator when the socket drops) fires
    :meth:`abort_nowait`, so an abandoned request frees its pool pages on
    the very next tick;
  * **graceful shutdown**: :meth:`shutdown` stops admission, optionally
    drains in-flight requests to completion (``drain=True``) or aborts
    them (``drain=False`` — the abort finish markers still flush to every
    open stream), then joins the thread.

Because all request wall-clock stamps (``RequestMetrics.ttft_s`` /
``e2e_s``) are taken by whichever thread drives the backend, running under
this front end stamps them on the tick thread — ``metrics()`` (and the
HTTP ``/v1/metrics`` endpoint) report real concurrent-serving latencies
with or without a tracer attached.

Quickstart::

    server = AsyncLLMServer(LLMServer(cfg, params, opts, backend="paged",
                                      num_pages=64, max_slots=4))
    rid = await server.submit(prompt, SamplingParams(max_tokens=32))
    async for ev in server.stream(rid):
        ...                         # TokenEvents; last one has .finished
    out = await server.result(rid)  # RequestOutput
    await server.shutdown()
"""

from __future__ import annotations

import asyncio
import concurrent.futures
import dataclasses
import queue
import threading

from repro.core.sampling import SamplingParams
from repro.serving.api import LLMServer, RequestOutput, TokenEvent


class AdmissionError(RuntimeError):
    """Submit refused: the backend's unscheduled queue is at
    ``max_queue_depth`` (the HTTP layer maps this to 429 + Retry-After)."""


class EngineClosedError(RuntimeError):
    """Submit refused: the engine is shutting down or has shut down."""


@dataclasses.dataclass
class _Failure:
    """In-band sentinel pushed to every open stream when the tick thread
    dies on an unexpected exception — streams re-raise it."""

    exc: BaseException


class AsyncLLMServer:
    """Asyncio facade over one :class:`~repro.serving.api.LLMServer`.

    THREAD MODEL — two threads, one owner:

    * the **tick thread** (started in ``__init__``) is the backend's only
      driver. Its loop: drain the command queue, then if
      ``backend.pending`` run ONE ``backend.step()`` and fan the events
      out; otherwise block briefly waiting for a command. Every method
      here that touches the backend marshals a closure onto this thread
      and awaits its ``concurrent.futures.Future``.
    * the **event-loop thread** only ever reads per-request
      ``asyncio.Queue``s (filled via ``call_soon_threadsafe``) and awaits
      marshaled futures. The loop is captured on the first async call and
      must stay the same for the server's lifetime.

    ``max_queue_depth`` bounds admission (see :class:`AdmissionError`);
    ``idle_wait_s`` is how long the tick thread parks per wait when there
    is no work — it bounds submit→first-tick latency on an idle server.
    """

    def __init__(self, server: LLMServer, *, max_queue_depth: int = 64,
                 idle_wait_s: float = 0.005):
        self.server = server
        self.max_queue_depth = max_queue_depth
        self.idle_wait_s = idle_wait_s
        self._loop: asyncio.AbstractEventLoop | None = None
        self._cmds: queue.SimpleQueue = queue.SimpleQueue()
        # All three written ONLY on the tick thread (submit/abort/metrics
        # closures + _dispatch run there), read anywhere:
        self._subs: dict = {}     # rid -> asyncio.Queue of TokenEvent
        self._live: set = set()   # rids submitted, not yet finished
        self._waiters: dict = {}  # rid -> [Future[RequestOutput]]
        self._closing = False     # no new admissions
        self._stopping = False    # tick thread exits once drained + idle
        # guards the enqueue-vs-thread-exit race: once the tick thread
        # flips _accepting under this lock, new commands run inline on
        # the caller instead of landing in a queue nobody drains
        self._accept_lock = threading.Lock()
        self._accepting = True
        self._error: BaseException | None = None
        self._exit_fut: concurrent.futures.Future = concurrent.futures.Future()
        self._thread = threading.Thread(target=self._run,
                                        name="asyncllm-tick", daemon=True)
        self._thread.start()

    # ------------------------------------------------------------- public

    async def submit(self, prompt,
                     sampling: SamplingParams = SamplingParams()) -> int:
        """Admit one request; returns its rid. Raises
        :class:`AdmissionError` when the unscheduled queue is full and
        :class:`EngineClosedError` after :meth:`shutdown` began. The
        admission check and the submit run atomically on the tick thread,
        so concurrent submits can never jointly overshoot the bound."""
        q: asyncio.Queue = asyncio.Queue()

        def _do() -> int:
            if self._closing:
                raise EngineClosedError("engine is shut down")
            if self.server.queue_depth >= self.max_queue_depth:
                raise AdmissionError(
                    f"admission queue full ({self.max_queue_depth} "
                    f"unscheduled requests) — retry later")
            rid = self.server.submit(prompt, sampling)
            self._subs[rid] = q
            self._live.add(rid)
            return rid

        return await self._call(_do)

    async def stream(self, rid: int):
        """``async for ev in server.stream(rid)`` — the request's
        :class:`TokenEvent`s in position order; the last event has
        ``finished=True``. Single consumer per rid. Exiting early (client
        disconnect, ``break``, task cancellation) aborts the request so
        its pool pages free on the next tick."""
        q = self._subs.get(rid)
        if q is None:
            raise KeyError(f"rid {rid}: never submitted, already streamed, "
                           f"or released")
        finished = False
        try:
            while True:
                ev = await q.get()
                if isinstance(ev, _Failure):
                    raise ev.exc
                yield ev
                if ev.finished:
                    finished = True
                    return
        finally:
            self._subs.pop(rid, None)
            if not finished:
                self.abort_nowait(rid)

    async def result(self, rid: int) -> RequestOutput:
        """Await the request's :class:`RequestOutput` (finished OR
        aborted) without consuming its stream."""
        fut: concurrent.futures.Future = concurrent.futures.Future()

        def _register() -> None:
            out = self.server.outputs().get(rid)
            if out is not None:
                fut.set_result(out)
            elif rid in self._live:
                self._waiters.setdefault(rid, []).append(fut)
            else:
                fut.set_exception(
                    KeyError(f"rid {rid}: never submitted or released"))

        await self._call(_register)
        return await asyncio.wrap_future(fut)

    async def abort(self, rid: int) -> bool:
        """Cancel a request (confirmed): True if it was live. Its finish
        marker (reason ``"abort"``) still flushes to an open stream."""
        return await self._call(lambda: self.server.abort(rid))

    def abort_nowait(self, rid: int) -> None:
        """Fire-and-forget abort, safe from ANY context — including a
        generator ``finally`` running under ``GeneratorExit``, where no
        further ``await`` is allowed. This is the disconnect path."""
        with self._accept_lock:
            if self._accepting:
                self._cmds.put((lambda: self.server.abort(rid), None))
        # after shutdown the backend is drained — nothing left to free

    async def release(self, rid: int) -> bool:
        """Drop a finished request's retained output/metrics (the
        long-lived-server memory valve — see ``LLMServer.release``)."""
        def _do() -> bool:
            self._subs.pop(rid, None)
            self._waiters.pop(rid, None)
            return self.server.release(rid)
        return await self._call(_do)

    async def metrics(self) -> dict:
        """``LLMServer.metrics()`` computed on the tick thread (it reads
        the backend's retained outputs, which only that thread writes)."""
        return await self._call(self.server.metrics)

    async def shutdown(self, *, drain: bool = True) -> None:
        """Stop admission, then either let in-flight requests run to
        completion (``drain=True``) or abort them all (``drain=False`` —
        open streams still receive the abort finish markers), then stop
        and join the tick thread. Idempotent."""
        def _close() -> None:
            self._closing = True
            if not drain:
                for rid in sorted(self._live):
                    self.server.abort(rid)

        await self._call(_close)
        self._stopping = True
        await asyncio.wrap_future(self._exit_fut)
        self._thread.join(timeout=5.0)  # at set_result it is already exiting

    @property
    def queue_depth(self) -> int:
        """Unscheduled-request depth the admission bound is measured
        against (a cross-thread read of one int — advisory, exact only on
        the tick thread where :meth:`submit` re-checks it)."""
        return self.server.queue_depth

    @property
    def closed(self) -> bool:
        return self._closing

    @property
    def error(self) -> BaseException | None:
        """The exception that killed the tick thread, if any."""
        return self._error

    async def __aenter__(self) -> "AsyncLLMServer":
        return self

    async def __aexit__(self, *exc) -> None:
        await self.shutdown(drain=exc == (None, None, None))

    # -------------------------------------------------------- tick thread

    def _run(self) -> None:
        try:
            while True:
                while True:  # commands first: submits join the next tick
                    try:
                        self._exec(self._cmds.get_nowait())
                    except queue.Empty:
                        break
                if self.server.pending:
                    for ev in self.server.backend.step():
                        self._dispatch(ev)
                    continue
                if self._stopping:
                    break
                try:  # idle: park until a command (or the next poll)
                    self._exec(self._cmds.get(timeout=self.idle_wait_s))
                except queue.Empty:
                    pass
        except BaseException as e:  # noqa: BLE001 — fan failure to clients
            self._fail(e)
        finally:
            self._closing = True
            with self._accept_lock:
                self._accepting = False  # later commands run caller-inline
            while True:  # commands that raced the flip drain here
                try:
                    self._exec(self._cmds.get_nowait())
                except queue.Empty:
                    break
            self._exit_fut.set_result(None)

    def _exec(self, cmd) -> None:
        fn, fut = cmd
        try:
            res = fn()
        except BaseException as e:  # noqa: BLE001 — surfaces via future
            if fut is not None:
                fut.set_exception(e)
            elif self._error is None:
                raise  # fire-and-forget abort failed: that IS an engine bug
        else:
            if fut is not None:
                fut.set_result(res)

    def _dispatch(self, ev: TokenEvent) -> None:
        if ev.finished:
            self._live.discard(ev.rid)
            waiters = self._waiters.pop(ev.rid, ())
            if waiters:
                out = self.server.outputs().get(ev.rid)
                for fut in waiters:
                    fut.set_result(out)
        q = self._subs.get(ev.rid)
        if q is not None and self._loop is not None:
            try:
                self._loop.call_soon_threadsafe(q.put_nowait, ev)
            except RuntimeError:
                pass  # loop already closed: nobody is listening

    def _fail(self, exc: BaseException) -> None:
        self._error = exc
        self._closing = True
        for rid, waiters in self._waiters.items():
            for fut in waiters:
                if not fut.done():
                    fut.set_exception(exc)
        self._waiters.clear()
        if self._loop is not None:
            for q in list(self._subs.values()):
                try:
                    self._loop.call_soon_threadsafe(q.put_nowait,
                                                    _Failure(exc))
                except RuntimeError:
                    pass

    # ---------------------------------------------------------- marshaling

    def _call_future(self, fn) -> concurrent.futures.Future:
        fut: concurrent.futures.Future = concurrent.futures.Future()
        with self._accept_lock:
            if self._accepting:
                self._cmds.put((fn, fut))
                return fut
        # post-shutdown: the backend is drained and single-threaded again
        # — run read-only surfaces (metrics, outputs) inline; submit
        # still refuses via the _closing check
        try:
            fut.set_result(fn())
        except BaseException as e:  # noqa: BLE001
            fut.set_exception(e)
        return fut

    async def _call(self, fn):
        loop = asyncio.get_running_loop()
        if self._loop is None:
            self._loop = loop
        elif self._loop is not loop:
            raise RuntimeError(
                "AsyncLLMServer is bound to one event loop for its "
                "lifetime; build a new server per loop")
        return await asyncio.wrap_future(self._call_future(fn))
