"""Stdlib-only HTTP/SSE service over :class:`AsyncLLMServer`.

One asyncio-streams server (no frameworks — the repo's zero-dependency
telemetry precedent extends to networking), four endpoints:

================  ======  =====================================================
``/v1/completions``  POST  ``{"prompt": [ids], "max_tokens": 16, "stream":
                           true, ...}`` — any :class:`SamplingParams` field.
                           ``stream=true`` answers ``text/event-stream``: one
                           ``data: {json}`` frame per token (rid / index /
                           token / logprob), a final frame with
                           ``finish_reason``, then ``data: [DONE]``.
                           ``stream=false`` answers one JSON body with the
                           full token list, logprobs, finish reason, and the
                           request's measured ``ttft_s`` / ``e2e_s``.
``/v1/abort``        POST  ``{"rid": N}`` → ``{"aborted": bool}``.
``/v1/metrics``      GET   the flat ``LLMServer.metrics()`` SLO dict.
``/healthz``         GET   liveness + queue depth (503 once shut down).
================  ======  =====================================================

Error mapping: full admission queue → **429** with ``Retry-After``;
engine shut down → **503**; malformed request → **400**; unknown route →
**404**. Streaming responses send ``Connection: close`` and terminate by
EOF, so no chunked-encoding framing is needed; a client that disconnects
mid-stream is detected by EOF on its socket and the request is aborted —
its pool pages free on the next tick.

Run a demo server (tiny randomly initialized model — the serving plumbing
is real, the weights are not)::

    PYTHONPATH=src python -m repro.serving.http --port 8035 --max-slots 4
    curl -N localhost:8035/v1/completions -d \
        '{"prompt": [1,2,3], "max_tokens": 8, "stream": true}'

``--backend``/``--deployment`` thread straight through to
:class:`~repro.serving.api.LLMServer`, so the same front end serves
fused, paged, sharded, and disaggregated sessions.
"""

from __future__ import annotations

import argparse
import asyncio
import dataclasses
import json

from repro.core.sampling import SamplingParams
from repro.serving.async_engine import (AdmissionError, AsyncLLMServer,
                                        EngineClosedError)

# SamplingParams fields settable straight from request JSON (prefix_key
# must be hashable — a JSON string/int is; lists are rejected by coercion)
_SAMPLING_FIELDS = ("max_tokens", "temperature", "top_k", "top_p", "seed",
                    "stop_token_ids", "eos_id", "priority", "prefix_key",
                    "prefix_len", "latency_hint", "speculate_k")

SSE_DONE = b"data: [DONE]\n\n"


def sse_frame(obj: dict) -> bytes:
    """One Server-Sent-Events frame: ``data: {json}\\n\\n``."""
    return b"data: " + json.dumps(obj).encode() + b"\n\n"


class SSEParser:
    """Incremental SSE decoder — feed raw socket bytes, get back the
    ``data:`` payloads (parsed JSON dicts; the ``[DONE]`` terminator comes
    back as the string ``"[DONE]"``). The inverse of :func:`sse_frame`,
    used by the load generator and the round-trip tests."""

    def __init__(self):
        self._buf = b""

    def feed(self, chunk: bytes) -> list:
        self._buf += chunk
        out = []
        while b"\n\n" in self._buf:
            frame, self._buf = self._buf.split(b"\n\n", 1)
            for line in frame.splitlines():
                if not line.startswith(b"data:"):
                    continue  # comments / other SSE fields
                payload = line[5:].strip()
                out.append("[DONE]" if payload == b"[DONE]"
                           else json.loads(payload))
        return out


def _event_json(ev) -> dict:
    d = {"rid": ev.rid, "index": ev.index, "token": ev.token}
    if ev.logprob is not None:
        d["logprob"] = ev.logprob
    if ev.finished:
        d["finished"] = True
        d["finish_reason"] = ev.finish_reason
    return d


def _parse_sampling(body: dict) -> SamplingParams:
    kw = {}
    for f in _SAMPLING_FIELDS:
        if body.get(f) is not None:
            kw[f] = body[f]
    if "stop_token_ids" in kw:
        kw["stop_token_ids"] = tuple(kw["stop_token_ids"])
    return SamplingParams(**kw)


class ServingHTTPServer:
    """The service layer: routes HTTP requests onto one
    :class:`AsyncLLMServer`. ``port=0`` binds an ephemeral port (read
    ``self.port`` after :meth:`start` — how the tests and the load-smoke
    CI job avoid port collisions)."""

    def __init__(self, engine: AsyncLLMServer, host: str = "127.0.0.1",
                 port: int = 0):
        self.engine = engine
        self.host = host
        self.port = port
        self._server: asyncio.AbstractServer | None = None

    async def start(self) -> None:
        self._server = await asyncio.start_server(self._handle, self.host,
                                                  self.port)
        self.port = self._server.sockets[0].getsockname()[1]

    async def serve_forever(self) -> None:
        assert self._server is not None, "call start() first"
        await self._server.serve_forever()

    async def stop(self, *, shutdown_engine: bool = True,
                   drain: bool = True) -> None:
        """Stop accepting connections; optionally shut the engine down
        too (drain-then-stop by default)."""
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()
            self._server = None
        if shutdown_engine:
            await self.engine.shutdown(drain=drain)

    # ---------------------------------------------------------- plumbing

    async def _handle(self, reader: asyncio.StreamReader,
                      writer: asyncio.StreamWriter) -> None:
        try:
            req = await self._read_request(reader)
            if req is None:
                return
            method, path, body = req
            if path == "/healthz" and method == "GET":
                code = 503 if self.engine.closed else 200
                await self._json(writer, code, {
                    "status": "closed" if self.engine.closed else "ok",
                    "queue_depth": self.engine.queue_depth})
            elif path == "/v1/metrics" and method == "GET":
                await self._json(writer, 200, await self.engine.metrics())
            elif path == "/v1/abort" and method == "POST":
                ok = await self.engine.abort(int(body["rid"]))
                await self._json(writer, 200, {"aborted": ok})
            elif path == "/v1/completions" and method == "POST":
                await self._completions(reader, writer, body)
            else:
                await self._json(writer, 404,
                                 {"error": f"no route {method} {path}"})
        except (ValueError, KeyError, TypeError) as e:
            try:
                await self._json(writer, 400, {"error": str(e)})
            except (ConnectionError, RuntimeError):
                pass
        except (ConnectionError, asyncio.IncompleteReadError):
            pass  # client went away mid-exchange
        finally:
            try:
                writer.close()
                await writer.wait_closed()
            except (ConnectionError, RuntimeError):
                pass

    async def _read_request(self, reader):
        line = await reader.readline()
        if not line:
            return None
        parts = line.decode("latin-1").split()
        if len(parts) < 2:
            raise ValueError(f"malformed request line {line!r}")
        method, path = parts[0], parts[1]
        headers = {}
        while True:
            h = await reader.readline()
            if h in (b"\r\n", b"\n", b""):
                break
            k, _, v = h.decode("latin-1").partition(":")
            headers[k.strip().lower()] = v.strip()
        n = int(headers.get("content-length", 0))
        body = json.loads(await reader.readexactly(n)) if n else {}
        return method, path, body

    async def _completions(self, reader, writer, body: dict) -> None:
        prompt = body.get("prompt")
        if not isinstance(prompt, list) or not prompt:
            raise ValueError("'prompt' must be a non-empty token-id list")
        sp = _parse_sampling(body)
        try:
            rid = await self.engine.submit(prompt, sp)
        except AdmissionError as e:
            await self._json(writer, 429, {"error": str(e)},
                             extra_headers=(("Retry-After", "1"),))
            return
        except EngineClosedError as e:
            await self._json(writer, 503, {"error": str(e)})
            return
        if body.get("stream"):
            await self._stream_sse(reader, writer, rid)
        else:
            events = [ev async for ev in self.engine.stream(rid)]
            out = await self.engine.result(rid)
            await self._json(writer, 200, {
                "rid": rid,
                "tokens": [int(t) for t in out.tokens],
                "logprobs": [ev.logprob for ev in events
                             if not ev.finished],
                "finish_reason": out.finish_reason,
                "metrics": {"ttft_s": out.metrics.ttft_s,
                            "e2e_s": out.metrics.e2e_s},
            })

    async def _stream_sse(self, reader, writer, rid: int) -> None:
        writer.write(b"HTTP/1.1 200 OK\r\n"
                     b"Content-Type: text/event-stream\r\n"
                     b"Cache-Control: no-cache\r\n"
                     b"Connection: close\r\n\r\n")
        await writer.drain()
        agen = self.engine.stream(rid)
        # the client sends nothing after its request body, so a completed
        # read = EOF = disconnect; racing it against the token stream is
        # what turns a vanished client into abort(rid)
        eof = asyncio.ensure_future(reader.read(1))
        try:
            while True:
                nxt = asyncio.ensure_future(agen.__anext__())
                done, _ = await asyncio.wait(
                    {nxt, eof}, return_when=asyncio.FIRST_COMPLETED)
                if nxt not in done:  # EOF won: client disconnected
                    nxt.cancel()
                    await asyncio.gather(nxt, return_exceptions=True)
                    return
                try:
                    ev = nxt.result()
                except StopAsyncIteration:
                    return
                writer.write(sse_frame(_event_json(ev)))
                await writer.drain()
                if ev.finished:
                    writer.write(SSE_DONE)
                    await writer.drain()
                    return
        finally:
            eof.cancel()
            await asyncio.gather(eof, return_exceptions=True)
            # closing the generator aborts rid if it has not finished
            await agen.aclose()

    async def _json(self, writer, code: int, obj: dict,
                    extra_headers=()) -> None:
        reason = {200: "OK", 400: "Bad Request", 404: "Not Found",
                  429: "Too Many Requests",
                  503: "Service Unavailable"}.get(code, "")
        payload = json.dumps(obj).encode()
        head = [f"HTTP/1.1 {code} {reason}",
                "Content-Type: application/json",
                f"Content-Length: {len(payload)}", "Connection: close"]
        head += [f"{k}: {v}" for k, v in extra_headers]
        writer.write(("\r\n".join(head) + "\r\n\r\n").encode() + payload)
        await writer.drain()


# ------------------------------------------------------------------ CLI


def _build_server(args):
    """A demo LLMServer on a tiny randomly initialized model — boots in
    seconds on CPU; the serving layer under test is real."""
    import jax

    from repro.configs import get_config
    from repro.models.transformer import RuntimeOpts, init_params

    cfg = dataclasses.replace(get_config(args.config).tiny(),
                              vocab_size=args.vocab)
    params = init_params(cfg, jax.random.PRNGKey(args.seed))
    opts = RuntimeOpts(q_chunk=16, kv_chunk=16, remat=False,
                       quantized_kv=True, moe_capacity_factor=0.0)
    from repro.serving.api import LLMServer

    kwargs: dict = {}
    if args.backend == "paged":
        kwargs = dict(deployment=args.deployment, num_pages=args.num_pages,
                      page_size=4, max_slots=args.max_slots,
                      auto_prefix=args.auto_prefix)
    return LLMServer(cfg, params, opts, backend=args.backend, **kwargs)


async def _amain(args) -> None:
    engine = AsyncLLMServer(_build_server(args),
                            max_queue_depth=args.max_queue_depth)
    http = ServingHTTPServer(engine, args.host, args.port)
    await http.start()
    print(f"serving on http://{http.host}:{http.port}  "
          f"(backend={args.backend}, deployment={args.deployment})",
          flush=True)
    try:
        await http.serve_forever()
    except asyncio.CancelledError:
        pass
    finally:
        await http.stop()


def main(argv=None) -> None:
    p = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    p.add_argument("--host", default="127.0.0.1")
    p.add_argument("--port", type=int, default=8035)
    p.add_argument("--backend", default="paged",
                   choices=("paged", "fused"))
    p.add_argument("--deployment", default="fused",
                   choices=("fused", "sharded", "disaggregated"))
    p.add_argument("--config", default="llama2-7b")
    p.add_argument("--vocab", type=int, default=64)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--num-pages", type=int, default=64)
    p.add_argument("--max-slots", type=int, default=4)
    p.add_argument("--max-queue-depth", type=int, default=64)
    p.add_argument("--auto-prefix", action="store_true")
    args = p.parse_args(argv)
    try:
        asyncio.run(_amain(args))
    except KeyboardInterrupt:
        pass


if __name__ == "__main__":
    main()
