"""Serving: batched engine, split-computing engine, and the paged-KV
continuous-batching stack (``kv_pool`` + ``scheduler``) for ragged
multi-request decode from one shared memory pool — see README.md here."""

from repro.serving.engine import Engine, GenerationResult  # noqa: F401
from repro.serving.kv_pool import (PagedKVPool,  # noqa: F401
                                   PoolExhaustedError)
from repro.serving.scheduler import Request, Scheduler  # noqa: F401
from repro.serving.split_engine import SplitEngine, SplitStats  # noqa: F401
