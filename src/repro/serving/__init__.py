"""Serving: batched engine + split-computing engine."""
