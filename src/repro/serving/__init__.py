"""Serving: ONE request-level API (``api.LLMServer`` + ``SamplingParams``
+ streaming ``RequestOutput``) over three pluggable backends — the fused
static-batch engine, the paged continuous-batching scheduler, and the
split-computing engine — see README.md here.

The legacy entry points (``Engine.generate``, ``Scheduler.submit``/
``run``, ``SplitEngine.generate``) keep working unchanged and stay
exported below, but new call sites should go through ``LLMServer`` —
``MIGRATION.md`` at the repo root maps the old surfaces onto it.
"""

from repro.core.sampling import SamplingParams  # noqa: F401
from repro.serving.api import (FusedBackend, GenerationRequest,  # noqa: F401
                               LLMServer, PagedBackend, RequestMetrics,
                               RequestOutput, ServingBackend, SplitBackend,
                               TokenEvent)
from repro.serving.async_engine import (AdmissionError,  # noqa: F401
                                        AsyncLLMServer, EngineClosedError)
from repro.serving.http import ServingHTTPServer  # noqa: F401
from repro.serving.engine import Engine, GenerationResult  # noqa: F401
from repro.serving.kv_pool import (PagedKVPool,  # noqa: F401
                                   PoolExhaustedError)
from repro.serving.scheduler import Request, Scheduler  # noqa: F401
from repro.serving.split_engine import SplitEngine, SplitStats  # noqa: F401
from repro.serving.telemetry import (Histogram, MetricsRegistry,  # noqa: F401
                                     Span, TickRecord, Tracer)
