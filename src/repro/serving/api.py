"""One request-level serving API over pluggable backends.

The repo's three serving front ends — the fused static-batch ``Engine``,
the paged continuous-batching ``Scheduler``, and the split-computing
``SplitEngine`` — historically had three divergent call shapes. This
module gives them ONE request-level surface:

  * :class:`~repro.core.sampling.SamplingParams` — every per-request knob
    (max_tokens, temperature / top-k / top-p / seed, stop tokens,
    priority, prefix sharing, latency hint) in one frozen dataclass;
  * :class:`GenerationRequest` / :class:`RequestOutput` — a prompt going
    in; tokens, finish reason, and latency metrics coming out, with
    per-token :class:`TokenEvent` streaming in between;
  * :class:`ServingBackend` — the small protocol (``submit`` / ``step`` /
    ``abort`` / ``pending`` / ``outputs``) each front end adapts to:
    ``fused`` (wraps ``Engine``'s jitted scan), ``paged`` (wraps
    ``Scheduler`` — true per-tick streaming, on-device per-slot
    sampling), ``split`` (wraps ``SplitEngine`` — each
    :class:`RequestOutput` carries the call's ``SplitStats`` uplink /
    residency accounting);
  * :class:`LLMServer` — the facade: ``submit()`` requests, ``stream()``
    token events, ``run()`` to drain, ``abort()`` to cancel.

Every backend samples through the same ``core.sampling.sample_tokens``
(per-request PRNG lanes folded per generation index), so default
``SamplingParams()`` is greedy on all three bit-for-bit with the legacy
entry points, and a seeded non-greedy request draws the same tokens on
the fused and paged backends. Event streams observe one invariant
everywhere: per request, token indices arrive strictly in position order
(interleaving across requests is backend-dependent — the paged backend
interleaves per tick; fused and split replay after the batch computes).
Finish events carry ``token = -1``, ``index = len(generated)`` and the
finish reason (``"stop"`` | ``"length"`` | ``"abort"`` | ``"deadline"``).

Quickstart::

    from repro.serving import LLMServer, SamplingParams

    server = LLMServer(cfg, params, opts, backend="paged",
                       num_pages=64, max_slots=4)
    rid = server.submit(prompt, SamplingParams(max_tokens=32,
                                               temperature=0.8, seed=1))
    for ev in server.stream():          # or: outputs = server.run()
        print(ev.rid, ev.index, ev.token)
    out = server.outputs()[rid]         # RequestOutput
"""

from __future__ import annotations

import dataclasses
import time
from typing import Protocol, runtime_checkable

import numpy as np

from repro.core.sampling import SamplingParams, truncate_at_stop
from repro.models.transformer import RuntimeOpts
from repro.serving.engine import Engine
from repro.serving.page_transport import DisaggregatedScheduler
from repro.serving.scheduler import Scheduler
from repro.serving.split_engine import SplitEngine

FINISH_REASONS = ("stop", "length", "abort", "deadline")


@dataclasses.dataclass(frozen=True)
class TokenEvent:
    """One streamed token (or the finish marker, ``token = -1``)."""

    rid: int
    index: int  # 0-based generation index; strictly increasing per rid
    token: int  # -1 on the finish marker
    finished: bool = False
    finish_reason: str | None = None  # set only on the finish marker
    # the emitted token's log-probability under the RAW model distribution
    # (before temperature / top-k / top-p — core.sampling.token_logprobs);
    # None on finish markers and abort events
    logprob: float | None = None


@dataclasses.dataclass
class GenerationRequest:
    """A prompt plus its :class:`SamplingParams`; ``rid`` is assigned by
    the backend at submit."""

    prompt: np.ndarray
    sampling: SamplingParams = SamplingParams()
    rid: int = -1


@dataclasses.dataclass
class RequestMetrics:
    """Wall-clock latency accounting per request (CPU wall times are
    call-path numbers off-TPU; ``ttft_ticks`` is exact on any backend).
    All times are ``time.perf_counter()`` stamps — monotonic, so they
    never jump under wall-clock adjustments; ``submit_s`` is only
    meaningful relative to other stamps from the same process."""

    submit_s: float = 0.0  # perf_counter stamp at submit
    ttft_s: float | None = None  # submit → first streamed token
    latency_s: float | None = None  # submit → finish
    # submit → finish (same stamp pair as latency_s, kept as its own field
    # so SLO surfaces — LLMServer.metrics(), /v1/metrics — read one
    # canonical end-to-end name). Populated on the thread that drives the
    # backend (the async front end's tick thread), so it is correct with
    # telemetry=None
    e2e_s: float | None = None
    # scheduling quanta from submit to first token: scheduler ticks on the
    # paged backend, server steps on the fused/split replay backends
    ttft_ticks: int | None = None


@dataclasses.dataclass
class RequestOutput:
    """The per-request result: generated tokens (stop token included,
    truncated at it), finish reason, metrics, and — on the split backend —
    the ``SplitStats`` uplink/residency accounting of the serving call."""

    rid: int
    prompt: np.ndarray
    tokens: np.ndarray  # generated tokens only
    finished: bool = False
    finish_reason: str | None = None
    metrics: RequestMetrics = dataclasses.field(default_factory=RequestMetrics)
    split_stats: object | None = None  # serving.split_engine.SplitStats

    @property
    def full_tokens(self) -> np.ndarray:
        """Prompt + generation — the legacy engines' return shape."""
        return np.concatenate([np.asarray(self.prompt, np.int32),
                               np.asarray(self.tokens, np.int32)])


@runtime_checkable
class ServingBackend(Protocol):
    """What :class:`LLMServer` drives. ``step()`` advances the backend by
    one scheduling quantum and returns the token events it produced;
    ``pending`` is True while any submitted request has undelivered
    events; ``outputs()`` maps rid → :class:`RequestOutput` for every
    finished (or aborted) request."""

    def submit(self, req: GenerationRequest) -> int: ...

    def step(self) -> list: ...

    def abort(self, rid: int) -> bool: ...

    def release(self, rid: int) -> bool: ...

    @property
    def pending(self) -> bool: ...

    @property
    def queue_depth(self) -> int: ...

    def outputs(self) -> dict: ...


def _apply_stop(gen: np.ndarray, sp: SamplingParams) -> tuple:
    """The shared stop-set truncation (``core.sampling.truncate_at_stop``)
    with an ndarray result — the replay backends' output shaping."""
    toks, reason = truncate_at_stop(gen, sp)
    return np.asarray(toks, np.int32), reason


class _RequestBook:
    """Per-request bookkeeping every backend shares: tracked requests,
    wall-clock metrics, finished outputs, deferred finish events, and the
    ``release`` memory valve."""

    def __init__(self):
        self._reqs: dict = {}
        self._metrics: dict = {}
        self._outputs: dict = {}
        self._pending_events: list = []  # finish markers for the next step

    def _track(self, req: GenerationRequest, rid: int) -> int:
        req.rid = rid
        self._reqs[rid] = req
        # perf_counter: monotonic — ttft_s/latency_s can never go negative
        # or jump when the wall clock is adjusted mid-serve
        self._metrics[rid] = RequestMetrics(submit_s=time.perf_counter())
        return rid

    def outputs(self) -> dict:
        return dict(self._outputs)

    def _release_dicts(self) -> tuple:
        """Extra per-rid dicts a backend also retains (popped by release)."""
        return ()

    def release(self, rid: int) -> bool:
        """Drop a FINISHED request's retained state (output, metrics,
        prompt). A long-lived server that never releases grows linearly
        with total requests served. Returns False for unknown/unfinished
        rids (live requests must finish or be aborted first)."""
        if rid not in self._outputs:
            return False
        for d in (self._outputs, self._metrics,
                  self._reqs) + self._release_dicts():
            d.pop(rid, None)
        return True


class _ReplayBackend(_RequestBook):
    """Shared machinery for backends that compute whole requests and then
    REPLAY them as streams (fused, split): queueing, abort, and the
    round-robin one-token-per-request-per-step event emitter (which keeps
    the per-request position-order invariant and interleaves across
    requests)."""

    def __init__(self, telemetry=None):
        super().__init__()
        self.telemetry = telemetry
        self._next_rid = 0
        self._queued: list = []
        # rid → [tokens np, cursor, finish_reason, logprobs np | None] for
        # computed-but-not-fully-streamed requests
        self._streams: dict = {}
        self._split_stats: dict = {}
        # replay-backend "ticks" are server steps: rid → step at submit,
        # so ttft_ticks is populated on fused/split too (paged parity)
        self._steps = 0
        self._submit_step: dict = {}

    def submit(self, req: GenerationRequest) -> int:
        rid = self._track(req, self._next_rid)
        self._next_rid += 1
        self._queued.append(req)
        self._submit_step[rid] = self._steps
        if self.telemetry is not None:
            self.telemetry.request_submitted(rid)
        return rid

    @property
    def pending(self) -> bool:
        return bool(self._queued or self._streams or self._pending_events)

    @property
    def queue_depth(self) -> int:
        """Requests accepted but not yet computing — the replay backends'
        admission-backpressure signal (``AsyncLLMServer`` bounds it)."""
        return len(self._queued)

    def _release_dicts(self) -> tuple:
        return (self._split_stats, self._submit_step)

    def abort(self, rid: int) -> bool:
        """Cancel: a queued request never computes; a streaming one is cut
        at its cursor (tokens already streamed are kept). The finish
        marker (reason "abort") arrives on the next ``step()``."""
        for req in self._queued:
            if req.rid == rid:
                self._queued.remove(req)
                self._finalize(rid, np.zeros((0,), np.int32), "abort")
                self._pending_events.append(TokenEvent(
                    rid, 0, -1, finished=True, finish_reason="abort"))
                return True
        if rid in self._streams:
            toks, cur, _, _ = self._streams.pop(rid)
            self._finalize(rid, toks[:cur], "abort")
            self._pending_events.append(TokenEvent(
                rid, cur, -1, finished=True, finish_reason="abort"))
            return True
        return False

    def _finalize(self, rid: int, gen, reason: str) -> None:
        m = self._metrics[rid]
        m.latency_s = m.e2e_s = time.perf_counter() - m.submit_s
        self._outputs[rid] = RequestOutput(
            rid, self._reqs[rid].prompt, np.asarray(gen, np.int32),
            finished=True, finish_reason=reason, metrics=m,
            split_stats=self._split_stats.get(rid))
        if self.telemetry is not None:
            self.telemetry.request_finished(rid, "requests", reason,
                                            len(self._outputs[rid].tokens))

    def _emit_round(self) -> list:
        events, self._pending_events = self._pending_events, []
        self._steps += 1
        now = time.perf_counter()
        for rid in list(self._streams):
            toks, cur, reason, lps = self._streams[rid]
            if cur < len(toks):
                m = self._metrics[rid]
                if m.ttft_s is None:
                    m.ttft_s = now - m.submit_s
                    m.ttft_ticks = self._steps - self._submit_step[rid]
                    if self.telemetry is not None:
                        self.telemetry.first_token(
                            rid, "requests", ttft_ticks=m.ttft_ticks)
                lp = None if lps is None else float(lps[cur])
                events.append(TokenEvent(rid, cur, int(toks[cur]),
                                         logprob=lp))
                cur += 1
                self._streams[rid][1] = cur
            if cur >= len(toks):
                del self._streams[rid]
                self._finalize(rid, toks, reason)
                events.append(TokenEvent(rid, cur, -1, finished=True,
                                         finish_reason=reason))
        return events


class FusedBackend(_ReplayBackend):
    """``Engine``'s jitted prefill + ``lax.scan`` loop behind the request
    API. Submitted requests accumulate until the next ``step()``, which
    computes ALL of them — grouped by prompt length (the fused scan wants
    rectangular batches), each group one ``Engine.generate_requests``
    call with per-row sampling operands, scanned to the group's largest
    ``max_tokens`` — then replays the tokens as interleaved events.
    Per-request ``max_tokens`` and stop sets truncate the replay."""

    def __init__(self, cfg, params, opts: RuntimeOpts = RuntimeOpts(),
                 *, cache_len: int = 4096, telemetry=None):
        super().__init__(telemetry=telemetry)
        self.engine = Engine(cfg, params, opts, cache_len=cache_len,
                             telemetry=telemetry)

    def step(self) -> list:
        if self._queued:
            self._compute()
        return self._emit_round()

    def _compute(self) -> None:
        groups: dict = {}
        for req in self._queued:
            groups.setdefault(req.prompt.shape, []).append(req)
        self._queued = []
        for group in groups.values():
            prompts = np.stack([r.prompt for r in group])
            res = self.engine.generate_requests(
                prompts, [r.sampling for r in group])
            for i, (row, req) in enumerate(zip(res.tokens, group)):
                plen = req.prompt.shape[0]
                gen = row[plen: plen + req.sampling.max_tokens]
                gen, reason = _apply_stop(gen, req.sampling)
                lps = (None if res.logprobs is None
                       else res.logprobs[i, : gen.shape[0]])
                self._streams[req.rid] = [gen, 0, reason, lps]


class SplitBackend(_ReplayBackend):
    """The paper's split system behind the request API: each request runs
    ``SplitEngine.generate`` (edge front → TS+TAB-Q uplink → cloud back,
    Algorithm 2 deadline ladder) with its own sampling params, one request
    per ``step()``. The resulting :class:`RequestOutput` carries the
    call's ``SplitStats`` (measured/Eq. 3 uplink bits, paged-cloud
    residency, early exits). A generation the deadline ladder truncated
    finishes with reason ``"deadline"``.

    ``SamplingParams(speculate_k=)`` turns the request's serving call
    speculative: the edge drafts that many tokens per round and the cloud
    verifies them in ONE uplink round trip
    (``SplitEngine.generate(speculate_k=)``) — the carried ``SplitStats``
    then report ``uplink_round_trips`` < tokens generated and the round's
    ``acceptance_rate``."""

    def __init__(self, cfg, params, opts: RuntimeOpts = RuntimeOpts(),
                 *, opsc=None, compress: bool = True, telemetry=None,
                 **split_kwargs):
        if opsc is None:
            raise ValueError("the split backend needs opsc=OPSCConfig(...)")
        super().__init__(telemetry=telemetry)
        self.compress = compress
        self.engine = SplitEngine(cfg, params, opsc, opts=opts,
                                  telemetry=telemetry, **split_kwargs)

    def step(self) -> list:
        if self._queued and not self._streams:
            req = self._queued.pop(0)
            sp = req.sampling
            toks, stats, lps = self.engine.generate(
                req.prompt[None], sp.max_tokens, compress=self.compress,
                sampling=sp, with_logprobs=True,
                speculate_k=sp.speculate_k)
            gen = toks[0, req.prompt.shape[0]:]
            gen, reason = _apply_stop(gen, sp)
            if reason == "length" and gen.shape[0] < sp.max_tokens:
                reason = "deadline"  # Algorithm 2 cut the generation short
            self._split_stats[req.rid] = stats
            self._streams[req.rid] = [gen, 0, reason,
                                      lps[0, : gen.shape[0]]]
        return self._emit_round()


class PagedBackend(_RequestBook):
    """The continuous-batching ``Scheduler`` behind the request API — the
    one backend with TRUE streaming: each ``step()`` is one scheduler tick
    (admit → chunked prefill → one-shape ragged decode with on-device
    per-slot sampling → evict), and the tick's sampled tokens come back as
    events immediately. ``abort()`` cancels in place (pages reclaimed this
    call); the drained scheduler releases its pinned prefixes exactly like
    ``Scheduler.run``; ``release()`` also drops the scheduler's retained
    results/finish_reasons.

    Construct with ``speculate_k=`` (a ``Scheduler`` keyword) to make
    decode ticks speculative — each tick then verifies a prompt-lookup
    draft burst per slot in one call, and a request's own
    ``SamplingParams(speculate_k=)`` may lower its burst below the
    scheduler-wide width. The fused backend has no incremental tick to
    amortize, so it ignores ``speculate_k`` (documented on
    ``SamplingParams``).

    ``deployment`` picks the serving topology — greedy token streams are
    bit-identical across all three (the sharded/disaggregated acceptance
    bar, pinned by ``tests/test_sharded_serving.py``):

    * ``"fused"`` (default) — one scheduler, single-device step fns.
    * ``"sharded"`` — one scheduler whose ticks are ``shard_map``-lowered
      over a device mesh (pool pages sharded over the ``"kv"`` axis,
      attention heads over ``"model"``). Pass ``mesh=`` to pin a
      ``jax.sharding.Mesh``; omitted, ``launch.mesh.make_serving_mesh``
      builds one over every visible device.
    * ``"disaggregated"`` — a prefill replica and a decode replica with
      separate pools, joined by the page-stream transport
      (:class:`~repro.serving.page_transport.DisaggregatedScheduler`);
      ``prefill_kwargs=``/``decode_kwargs=`` tune the sides."""

    def __init__(self, cfg, params, opts: RuntimeOpts = RuntimeOpts(),
                 *, telemetry=None, deployment: str = "fused",
                 **scheduler_kwargs):
        super().__init__()
        self.telemetry = telemetry
        self.deployment = deployment
        if deployment == "fused":
            if "mesh" in scheduler_kwargs and \
                    scheduler_kwargs["mesh"] is not None:
                raise ValueError(
                    "mesh= requires deployment='sharded' (a fused "
                    "deployment never lowers through shard_map)")
            scheduler_kwargs.pop("mesh", None)
            self.scheduler = Scheduler(cfg, params, opts,
                                       telemetry=telemetry,
                                       **scheduler_kwargs)
        elif deployment == "sharded":
            mesh = scheduler_kwargs.pop("mesh", None)
            if mesh is None:
                from repro.launch.mesh import make_serving_mesh
                mesh = make_serving_mesh(cfg.pattern[0].mixer.num_kv_heads)
            self.scheduler = Scheduler(cfg, params, opts,
                                       telemetry=telemetry, mesh=mesh,
                                       **scheduler_kwargs)
        elif deployment == "disaggregated":
            self.scheduler = DisaggregatedScheduler(
                cfg, params, opts, telemetry=telemetry, **scheduler_kwargs)
        else:
            raise ValueError(
                f"unknown deployment {deployment!r}: expected 'fused', "
                f"'sharded' or 'disaggregated'")

    def submit(self, req: GenerationRequest) -> int:
        return self._track(req, self.scheduler.submit(
            req.prompt, sampling=req.sampling))

    @property
    def pending(self) -> bool:
        return self.scheduler.pending or bool(self._pending_events)

    @property
    def queue_depth(self) -> int:
        """Requests waiting UNADMITTED in the scheduler queue (slots and
        pages all busy) — the paged admission-backpressure signal. The
        disaggregated facade sums both replicas' queues."""
        sched = self.scheduler
        if hasattr(sched, "queue"):
            return len(sched.queue)
        return len(sched.prefill.queue) + len(sched.decode.queue)

    def _release_dicts(self) -> tuple:
        rd = getattr(self.scheduler, "_release_dicts", None)
        if rd is not None:  # disaggregated facade: merged-copy properties
            return rd()
        return (self.scheduler.results, self.scheduler.finish_reasons)

    def step(self) -> list:
        events, sched = self._pending_events, self.scheduler
        self._pending_events = []
        if sched.pending:
            sched.step()
        events += self._collect(time.perf_counter())
        if not sched.pending:  # drained — same reclamation as run()
            sched.release_prefixes()
        return events

    def abort(self, rid: int) -> bool:
        ok = self.scheduler.abort(rid)
        if ok:  # surface the partial result now, its events next step
            self._pending_events += self._collect(time.perf_counter())
        return ok

    def _collect(self, now: float) -> list:
        sched, events = self.scheduler, []
        for rid, idx, tok, lp in sched.drain_events():
            m = self._metrics[rid]
            if m.ttft_s is None:
                m.ttft_s = now - m.submit_s
            events.append(TokenEvent(rid, idx, tok, logprob=lp))
        for rid in sched.drain_finished():
            req = self._reqs[rid]
            reason = sched.finish_reasons.get(rid, "length")
            gen = np.asarray(sched.results[rid][req.prompt.shape[0]:],
                             np.int32)
            m = self._metrics[rid]
            m.latency_s = m.e2e_s = now - m.submit_s
            # tracer-sourced when tracing (the first-token span records the
            # tick), scheduler stats otherwise — identical values, but the
            # tracer copy survives a stats reset
            if sched.telemetry is not None:
                m.ttft_ticks = sched.telemetry.ttft_ticks.get(
                    rid, sched.stats.ttft_ticks.get(rid))
            else:
                m.ttft_ticks = sched.stats.ttft_ticks.get(rid)
            self._outputs[rid] = RequestOutput(
                rid, req.prompt, gen, finished=True, finish_reason=reason,
                metrics=m)
            events.append(TokenEvent(rid, gen.shape[0], -1, finished=True,
                                     finish_reason=reason))
        return events


_BACKENDS = {"fused": FusedBackend, "paged": PagedBackend,
             "split": SplitBackend}


class LLMServer:
    """The facade: one request-level API over the fused / paged / split
    backends. ``backend`` is a name from ``{"fused", "paged", "split"}``
    (extra keyword arguments reach that backend's constructor — e.g.
    ``num_pages=``/``max_slots=``/``lazy_growth=`` for paged, ``opsc=``
    and channel/deadline knobs for split, ``cache_len=`` for fused) or an
    already-built :class:`ServingBackend`. The paged backend additionally
    takes ``deployment="fused"|"sharded"|"disaggregated"`` — same API,
    same greedy streams, different topology (see :class:`PagedBackend`).

    ``telemetry`` threads one :class:`~repro.serving.telemetry.Tracer`
    through the chosen backend (``True`` builds a fresh one, exposed as
    ``server.tracer``): request-lifecycle spans, per-tick timelines, and
    the :meth:`metrics` SLO summaries all record into it; export a
    Perfetto-loadable trace with ``server.tracer.export_chrome_trace``.
    The default ``None`` keeps every instrumented path a strict no-op."""

    def __init__(self, cfg=None, params=None,
                 opts: RuntimeOpts = RuntimeOpts(), *,
                 backend="paged", telemetry=None, **backend_kwargs):
        if telemetry is True:
            from repro.serving.telemetry import Tracer

            telemetry = Tracer()
        self.tracer = telemetry
        if isinstance(backend, str):
            if backend not in _BACKENDS:
                raise ValueError(f"backend must be one of "
                                 f"{sorted(_BACKENDS)}, got {backend!r}")
            backend = _BACKENDS[backend](cfg, params, opts,
                                         telemetry=telemetry,
                                         **backend_kwargs)
        elif telemetry is not None and getattr(
                backend, "telemetry", None) is None:
            raise ValueError(
                "pass telemetry= to the backend's constructor when handing "
                "LLMServer an already-built backend")
        self.backend: ServingBackend = backend
        if self.tracer is None:  # adopt a prebuilt backend's tracer
            self.tracer = getattr(backend, "telemetry", None)

    def submit(self, prompt,
               sampling: SamplingParams = SamplingParams()) -> int:
        """Enqueue ONE request — ``prompt`` is a 1-D token sequence;
        returns its rid. A batch is a sequence of submits (silently
        flattening a (B, S) matrix into one long prompt is exactly the
        migration accident this guards against)."""
        prompt = np.asarray(prompt, np.int32)
        if prompt.ndim == 0:
            prompt = prompt.reshape(1)
        if prompt.ndim != 1:
            raise ValueError(
                f"submit takes ONE 1-D prompt, got shape {prompt.shape} — "
                f"submit a batch as one request per row")
        return self.backend.submit(GenerationRequest(prompt, sampling))

    @property
    def pending(self) -> bool:
        return self.backend.pending

    @property
    def queue_depth(self) -> int:
        """Requests accepted but not yet scheduled — what the async front
        end's bounded admission (429 backpressure) is measured against."""
        return getattr(self.backend, "queue_depth", 0)

    def stream(self):
        """Drive the backend, yielding :class:`TokenEvent`s as they are
        produced, until every submitted request has finished. Requests
        submitted (or aborted) mid-iteration join the stream."""
        while self.backend.pending:
            yield from self.backend.step()

    def run(self) -> dict:
        """Drain everything; returns {rid: :class:`RequestOutput`}."""
        for _ in self.stream():
            pass
        return self.backend.outputs()

    def outputs(self) -> dict:
        """{rid: RequestOutput} for every finished/aborted request so far."""
        return self.backend.outputs()

    def abort(self, rid: int) -> bool:
        """Cancel a request; its partial output (finish reason
        ``"abort"``) appears in :meth:`outputs`."""
        return self.backend.abort(rid)

    def release(self, rid: int) -> bool:
        """Drop a finished request's retained output/metrics — call after
        consuming a :class:`RequestOutput` so a long-lived server's memory
        tracks LIVE requests, not total requests ever served."""
        return self.backend.release(rid)

    def metrics(self) -> dict:
        """One flat ``{name: number}`` metrics dict — the serving layer's
        SLO surface, superseding ad-hoc :class:`RequestMetrics` plumbing.

        Always present (telemetry on or off): ``requests.*`` aggregates
        built from the finished outputs still retained — finished count,
        per-reason counts, and streaming-percentile summaries of
        ``requests.ttft_s`` / ``requests.latency_s`` (``.p50``/``.p95``/
        ``.p99``/``.mean``/...). With a tracer attached, the tracer's
        full registry (tick latencies, pool gauges, TTFT/TPOT/e2e
        histograms, compile counters, split uplink accounting) is merged
        in under its own names."""
        from repro.serving.telemetry import Histogram

        out: dict = {}
        if self.tracer is not None:
            out.update(self.tracer.metrics_dict())
        finished = self.backend.outputs()
        out["requests.retained"] = len(finished)
        ttft, lat = Histogram(), Histogram()
        ticks, e2e, tpot = Histogram(), Histogram(), Histogram()
        for o in finished.values():
            out[f"requests.reason.{o.finish_reason}"] = out.get(
                f"requests.reason.{o.finish_reason}", 0) + 1
            m = o.metrics
            if m.ttft_s is not None:
                ttft.record(m.ttft_s)
            if m.latency_s is not None:
                lat.record(m.latency_s)
            if m.ttft_ticks is not None:
                ticks.record(m.ttft_ticks)
            # e2e_s falls back to latency_s so outputs stamped by older
            # drivers still aggregate; TPOT is the post-first-token decode
            # cadence — (e2e - ttft) / (n - 1), requests with one token
            # have no decode phase to measure
            e2e_v = m.e2e_s if m.e2e_s is not None else m.latency_s
            if e2e_v is not None:
                e2e.record(e2e_v)
                if m.ttft_s is not None and len(o.tokens) > 1:
                    tpot.record((e2e_v - m.ttft_s) / (len(o.tokens) - 1))
        for name, h in (("requests.ttft_s", ttft),
                        ("requests.latency_s", lat),
                        ("requests.ttft_ticks", ticks),
                        ("requests.e2e_s", e2e),
                        ("requests.tpot_s", tpot)):
            for k, v in h.summary().items():
                out[f"{name}.{k}"] = v
        return out
