"""Split-computing serving engine — the paper's system (§2, Fig. 3).

The model is partitioned at OPSC's split point: *edge* runs blocks
[0, split) with weights fake-quantized at Q_w1 (OPSC front segment), *cloud*
runs blocks [split, L) at full precision. The split-layer hidden state is
TS+TAB-Q compressed (``repro.core.payload``), its **measured** bit count
drives the ε-outage channel latency model, and Algorithm 2's early-exit
controller escalates (compress → drop KV → truncate generation) when the
deadline would be violated.

``I_kv`` semantics (paper §2.2.1, Eq. 2/3): the cloud is stateless across
edge devices. With I_kv=1 the per-step uplink is accounted at the Eq. (2)
KV-cache size and the cloud decodes incrementally from its (shipped)
caches; with I_kv=0 only hidden states cross, and the cloud must re-run its
segment over the whole received history each step — reproducing the paper's
cache-vs-bandwidth tradeoff in both bytes *and* compute.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.channel import ChannelConfig, LatencyModel, optimal_rate
from repro.core.opsc import OPSCConfig, kv_cache_bytes
from repro.core.sampling import (broadcast_params, device_operands,
                                 sample_tokens, speculative_verify,
                                 token_logprobs)
from repro.core.payload import decode as payload_decode
from repro.core.payload import encode as payload_encode
from repro.models import layers as L
from repro.serving.page_transport import TabqUplinkTransport
from repro.models.transformer import (RuntimeOpts, _apply_blocks_cached,
                                      apply_head, embed_inputs, init_caches,
                                      make_positions, rope_tables)


def slice_blocks(tree, lo: int, hi: int):
    return jax.tree_util.tree_map(lambda a: a[lo:hi], tree)


def _fake_quant_blocks(blocks, bits: int):
    """OPSC front-segment weight quantization (symmetric per-channel,
    fake-quant semantics — see repro.core.opsc). ≥16 bits ≡ full precision
    (the paper's high-precision segment)."""
    from repro.core.quant import quantize_sym

    if bits >= 16:
        return blocks

    def fq(x):
        if x.ndim < 3:  # stacked (nb, ...) matrices only; norms/scalars stay
            return x
        flat = x.reshape(x.shape[0], -1, x.shape[-1])
        qt = quantize_sym(flat, bits, axis=-2)  # per-output-channel scale
        return qt.dequantize(x.dtype).reshape(x.shape)

    return jax.tree_util.tree_map(fq, blocks)


@dataclasses.dataclass
class SplitStats:
    tokens_generated: int = 0
    uplink_bits_measured: float = 0.0  # real TS+TAB-Q payload bits
    uplink_bits_eq3: float = 0.0  # paper's analytical accounting
    latency_s: float = 0.0
    early_exits: int = 0
    kv_dropped_steps: int = 0
    # paged-cloud accounting (paged_cloud_kv=True, I_kv=1): the per-step KV
    # shipment at PAGE granularity, following the SAME full-cache-per-step
    # convention as uplink_bits_eq3 (Eq. 3 ships B_kv(w) every step — this
    # is its page-granular int8 analogue, directly comparable), plus the
    # pool's peak residency (Eq. 2's cloud-side term, reservation included).
    # Both count a page SHARED between edge devices ONCE — the multi-tenant
    # dedup is exactly what `shared_prefix_len` buys
    uplink_bits_paged: float = 0.0
    cloud_pool_bytes_peak: int = 0
    shared_prefix_pages: int = 0  # pool pages pinned by the shared prefix
    # speculative decoding (generate(speculate_k=)): per-call draft/verify
    # accounting. uplink_round_trips counts DECODE-phase uplink payloads
    # (prefill excluded) in BOTH modes, so the round-trip amortization is
    # directly readable: the per-token loop pays one trip per generated
    # token; speculation pays one per verify round and emits
    # ~(1 + acceptance length) tokens with it.
    uplink_round_trips: int = 0
    spec_rounds: int = 0
    spec_drafted: int = 0  # draft tokens proposed (per row, summed)
    spec_accepted: int = 0  # draft tokens accepted by the verifier

    @property
    def acceptance_rate(self) -> float:
        """Fraction of proposed draft tokens the cloud verifier accepted."""
        return self.spec_accepted / self.spec_drafted if self.spec_drafted \
            else 0.0


class SplitEngine:
    def __init__(self, cfg: ArchConfig, params, opsc: OPSCConfig,
                 channel: ChannelConfig = ChannelConfig(),
                 deadline_s: float | None = None,
                 compute_per_layer_s: float = 1e-4,
                 opts: RuntimeOpts = RuntimeOpts(remat=False),
                 cache_len: int = 4096,
                 paged_cloud_kv: bool = False,
                 cloud_pool_pages: int = 256,
                 cloud_page_size: int | None = None,
                 telemetry=None):
        """The paper's split system (§2, Fig. 3): edge blocks [0, split)
        fake-quantized at ``opsc.qw_front``, cloud blocks [split, L) full
        precision, TS+TAB-Q payload across the split.

        Paged-cloud options (``I_kv=1`` only): ``paged_cloud_kv=True``
        swaps the cloud's dense per-request cache for a
        ``serving.kv_pool.PagedKVPool`` of ``cloud_pool_pages`` PAGES of
        ``cloud_page_size`` TOKENS each (None → the pool default). The
        ENGINE owns the pool and every page lifetime: requests are
        admitted with worst-case reservation (prompt + max_new TOKENS) for
        each ``generate`` call, and a ``generate(shared_prefix_len=...)``
        fleet prefix is pinned only within the call (rows hold the page
        references; the handle is released after admission).
        ``SplitStats.uplink_bits_paged`` (BITS) and
        ``cloud_pool_bytes_peak`` (BYTES) then account page-granular
        shipment/residency, counting a page shared between rows once.
        ``cache_len`` (TOKENS) bounds every per-request history buffer;
        prompts + generation beyond it are rejected."""
        assert opsc.split_layer % len(cfg.pattern) == 0, \
            "split point must fall on a pattern boundary"
        self.cfg, self.opts, self.opsc = cfg, opts, opsc
        self.cache_len = cache_len
        # telemetry.Tracer | None: per-segment edge/cloud spans, per-token
        # uplink-bit and TAB-Q bit-width histograms, SplitStats mirrored
        # into the shared registry. None skips every tracer touch and
        # every device sync (the disabled path adds no host work)
        self.telemetry = telemetry
        # the edge→cloud activation mover: every TS+TAB-Q payload's wire
        # accounting (legacy "uplink" events + the unified transport
        # span/histogram from serving.page_transport) flows through it
        self._uplink = TabqUplinkTransport(telemetry=telemetry)
        # I_kv=1 with a paged cloud: the per-step KV shipment and the cloud's
        # resident memory are accounted at PAGE granularity from a shared
        # pool (serving.kv_pool) instead of a dense per-request cache — the
        # multi-tenant cloud serves many edges from one Eq. 2 budget
        self.paged_cloud_kv = paged_cloud_kv
        self.cloud_pool_pages = cloud_pool_pages
        self.cloud_page_size = cloud_page_size
        self.split_block = opsc.split_layer // len(cfg.pattern)
        nb = cfg.num_blocks

        self.edge_params = dict(params)
        self.edge_params["blocks"] = _fake_quant_blocks(
            slice_blocks(params["blocks"], 0, self.split_block), opsc.qw_front)
        self.cloud_params = dict(params)
        self.cloud_params["blocks"] = slice_blocks(params["blocks"], self.split_block, nb)

        self.channel = channel
        self.rate = optimal_rate(channel)
        self.latency = LatencyModel(channel, self.rate, compute_per_layer_s)
        self.deadline_s = deadline_s

        self._edge_front = jax.jit(self._edge_front_fn, static_argnames=("decode",))
        self._cloud_back = jax.jit(self._cloud_back_fn, static_argnames=("decode",))
        self._cloud_back_shared = jax.jit(self._cloud_back_shared_fn)
        # speculative-verify stages: the edge's early-exit draft head (the
        # OPSC front segment IS the draft model — apply_head over the
        # split-layer hidden state, zero extra weights), the multi-token
        # cloud verify (dense and paged variants), and the accept/reject
        # sampler lanes
        self._draft_next = jax.jit(
            lambda head_params, h: jnp.argmax(
                apply_head(self.cfg, head_params, h), axis=-1))
        self._cloud_verify = jax.jit(self._cloud_verify_fn,
                                     static_argnames=("decode", "tail"))
        self._cloud_verify_paged = jax.jit(self._cloud_verify_paged_fn)
        self._spec_verify = jax.jit(speculative_verify)
        # device-side helpers for the generation loop: greedy head, the
        # per-request sampler (serving-API path; step index and every knob
        # traced — one trace total), and sequence-buffer writes
        self._next_token = jax.jit(lambda lg: jnp.argmax(lg, axis=-1)[:, None])
        self._sample_next = jax.jit(
            lambda lg, keys, t, temp, tk, tp: sample_tokens(
                lg, keys, jnp.full((lg.shape[0],), t, jnp.int32),
                temp, tk, tp)[:, None])
        self._tok_lp = jax.jit(
            lambda lg, tok: token_logprobs(lg, tok[:, 0])[:, None])
        self._seq_write = jax.jit(
            lambda buf, val, i: jax.lax.dynamic_update_slice(
                buf, val.astype(buf.dtype), (0, i) + (0,) * (buf.ndim - 2)))

    # ------------------------------------------------------------- stages

    def _edge_front_fn(self, params_blocks, embed_params, tokens, caches, pos,
                       patches=None, decode=False):
        cfg, opts = self.cfg, self.opts
        b, s = tokens.shape[:2]
        positions = make_positions(cfg, b, s, offset=pos)
        x = embed_inputs(cfg, embed_params, tokens, patches, positions)
        rope_cs = rope_tables(cfg, positions)
        x, caches = _apply_blocks_cached(cfg, params_blocks, x, caches,
                                         rope_cs=rope_cs, q_positions=positions,
                                         pos=jnp.asarray(pos, jnp.int32),
                                         opts=opts, decode=decode)
        return x, caches

    def _cloud_back_fn(self, params_blocks, head_params, h, caches, pos, decode=False):
        cfg, opts = self.cfg, self.opts
        b, s = h.shape[:2]
        positions = make_positions(cfg, b, s, offset=pos)
        rope_cs = rope_tables(cfg, positions)
        x, caches = _apply_blocks_cached(cfg, params_blocks, h, caches,
                                         rope_cs=rope_cs, q_positions=positions,
                                         pos=jnp.asarray(pos, jnp.int32),
                                         opts=opts, decode=decode)
        logits = apply_head(cfg, head_params, x[:, -1:])
        return logits[:, 0], caches

    def _cloud_verify_fn(self, params_blocks, head_params, h, caches, pos,
                         decode=False, tail=1):
        """Multi-token cloud verify over DENSE caches: identical to
        :meth:`_cloud_back_fn` but the head runs over the last ``tail``
        positions — one decode=True call consumes the whole k-token draft
        payload (the s>1 decode path attends the int8 cache positionally,
        the same key set k sequential steps would read) and returns the
        target distribution at EVERY draft position. ``tail`` also serves
        the stateless I_kv=0 re-run, which feeds the full history and heads
        only the verify columns. Returns (logits (B, tail, V), caches)."""
        cfg, opts = self.cfg, self.opts
        b, s = h.shape[:2]
        positions = make_positions(cfg, b, s, offset=pos)
        rope_cs = rope_tables(cfg, positions)
        x, caches = _apply_blocks_cached(cfg, params_blocks, h, caches,
                                         rope_cs=rope_cs, q_positions=positions,
                                         pos=jnp.asarray(pos, jnp.int32),
                                         opts=opts, decode=decode)
        return apply_head(cfg, head_params, x[:, -tail:]), caches

    def _cloud_verify_paged_fn(self, params_blocks, head_params, h, caches,
                               positions):
        """Multi-token cloud verify THROUGH the paged pool — the multi-token
        generalization of the paged decode step: the k in-call keys are
        written to the pool first and attention reads every key (history
        AND the burst itself) back through the pool's quantized codes, so
        the verify logits see bit-identical attention inputs to k
        sequential decode steps (prefill-style fresh-f32 in-call keys
        would diverge at quantization scale). Head over ALL columns.
        Returns (logits (B, k, V), caches)."""
        cfg, opts = self.cfg, self.opts
        positions = jnp.asarray(positions, jnp.int32)
        rope_cs = rope_tables(cfg, positions)
        x, caches = _apply_blocks_cached(cfg, params_blocks, h, caches,
                                         rope_cs=rope_cs,
                                         q_positions=positions,
                                         pos=jnp.int32(0), opts=opts,
                                         decode=True)
        return apply_head(cfg, head_params, x), caches

    def _cloud_back_shared_fn(self, params_blocks, head_params, h, caches,
                              positions):
        """Cloud prefill with a SHARED prompt prefix across the batch rows:
        ``positions`` (B, S) masks rows 1+'s prefix columns to -1 (their
        writes route to the pool's trash page and their hidden states are
        never read), and attention runs THROUGH the paged pool
        (``attend_cache=True``), so each masked row's suffix reads the
        prefix K/V that row 0 scatters into the shared pages in this very
        call — the cloud computes and stores the prefix once however many
        edge devices sent it."""
        cfg, opts = self.cfg, self.opts
        positions = jnp.asarray(positions, jnp.int32)
        rope_cs = rope_tables(cfg, positions)
        x, caches = _apply_blocks_cached(cfg, params_blocks, h, caches,
                                         rope_cs=rope_cs,
                                         q_positions=positions,
                                         pos=jnp.int32(0), opts=opts,
                                         decode=False, attend_cache=True)
        logits = apply_head(cfg, head_params, x[:, -1:])
        return logits[:, 0], caches

    # ------------------------------------------------------------ payload

    def _tspan(self, segment: str, stage: str, t0: float, out) -> None:
        """Close one edge/cloud segment span: sync so the span covers the
        real device work (values untouched — tracing stays bit-identical)."""
        tel = self.telemetry
        jax.block_until_ready(out)
        t1 = tel.now()
        tel.add_span(segment, t0, t1, track=f"split:{segment}", stage=stage)
        tel.metrics.observe(f"split.{segment}_s", t1 - t0)

    def _compress(self, h: jax.Array, fixed_bits=None):
        b, s, d = h.shape
        p = payload_encode(h.reshape(b * s, d).astype(jnp.float32),
                           tau=self.opsc.tau, delta=self.opsc.delta,
                           max_bits=self.opsc.max_act_bits, fixed_bits=fixed_bits)
        rec = payload_decode(p).reshape(b, s, d).astype(h.dtype)
        bits = float(p.payload_bits())
        tel = self.telemetry
        if tel is not None:
            # per-token TAB-Q chosen bit widths (sign bit included) and the
            # mean uplink bits each token of this payload cost — the wire
            # histograms the placement optimizer consumes
            for w in np.asarray(p.below.bits).reshape(-1).tolist():
                tel.metrics.observe("split.tabq_bits", float(w))
            tel.metrics.observe("split.uplink_bits_per_token",
                                bits / max(1, b * s))
        return rec, bits

    def _eq3_bits(self, w: int, i_kv: int) -> float:
        c = self.cfg
        attn = [ls.mixer for ls in c.pattern if ls.mixer.kind == "attn"]
        hd = (attn[0].num_kv_heads * attn[0].head_dim) if attn else c.d_model
        from repro.core.opsc import payload_bytes

        return payload_bytes(w, self.opsc.split_layer, c.num_layers, hd,
                             c.d_model, self.opsc.qa_front, self.opsc.qa_back,
                             i_kv) * 8.0

    # ----------------------------------------------------------- generate

    def generate(self, prompts: np.ndarray, max_new_tokens: int,
                 compress: bool = True, shared_prefix_len: int = 0,
                 sampling=None, with_logprobs: bool = False,
                 speculate_k: int = 0) -> tuple:
        """Split-computing generation. Returns (tokens, SplitStats) — or
        (tokens, SplitStats, logprobs (B, generated) f32) with
        ``with_logprobs=True``: each emitted token's log-probability under
        the raw cloud-head distribution (``core.sampling.token_logprobs``),
        accumulated in a device buffer alongside the token matrix (the
        existing two-tuple return is preserved for legacy callers).

        ``speculate_k`` > 0 turns on SPLIT-BOUNDARY SPECULATIVE DECODING:
        each round the edge decode-steps its own front segment k times,
        reading draft tokens off the split-layer hidden state with the
        model's OWN head (the OPSC front segment doubles as the draft
        model — zero extra weights), ships the k hidden states as ONE
        TS+TAB-Q payload, and the cloud verifies all k in a single
        multi-token call; ``core.sampling.speculative_verify`` accepts a
        prefix (exact-match for greedy rows — the emitted stream is
        bit-identical to ``speculate_k=0`` — rejection sampling for
        temperature/top-k/top-p rows), the rejected tail is rolled back
        (pool ``truncate``; the dense caches are overwritten in place by
        the next round before the causal mask could ever expose them), and
        the round emits 1..k tokens for one uplink round trip.
        ``SplitStats`` reports ``spec_rounds`` / ``spec_drafted`` /
        ``spec_accepted`` / ``acceptance_rate`` and ``uplink_round_trips``
        — the amortization the benchmark
        (``benchmarks/speculative_split.py``) measures.

        ``sampling`` — one ``core.sampling.SamplingParams`` (applied to
        every row) or a list of ``len(prompts)`` — threads the serving
        API's per-request temperature / top-k / top-p / seed through the
        cloud-side token head via the shared ``sample_tokens`` sampler
        (per-row PRNG lanes folded per step — the same stream the fused
        and paged backends draw). ``None`` or all-greedy params take the
        exact argmax path, bit-identical to the pre-API engine.

        The loop is host-orchestrated only where Algorithm 2 demands it (the
        measured payload bits feed the deadline ladder); tokens and the
        split-layer history live in preallocated device buffers and cross to
        the host once, after the loop. The cloud segment's caches follow
        ``opts.quantized_kv`` — with it set, cloud decode streams the int8
        cache through the Pallas decode-attention kernel like ``Engine``.

        ``shared_prefix_len`` (TOKENS; requires ``paged_cloud_kv=True`` and
        ``I_kv=1``) declares that every batch row — each row modelling one
        edge device — begins with the same prompt prefix (a fleet-wide
        system prompt). The cloud then holds that prefix ONCE: rows 1+ fork
        from row 0's pool pages (rounded down to whole pages; the remainder
        is treated as per-row suffix), their prefix uplink columns are
        neither compressed nor shipped (the cloud reuses row 0's
        transmission), and page-granular uplink/residency stats count the
        shared pages once."""
        cfg, opts = self.cfg, self.opts
        tokens = jnp.asarray(prompts)
        b, s = tokens.shape[:2]
        # h_buf and the KV caches are sized by cache_len; past it,
        # dynamic_update_slice would clamp and silently corrupt the history
        assert s + max_new_tokens <= self.cache_len, "cache_len too small"
        if speculate_k < 0:
            raise ValueError(f"speculate_k must be >= 0, got {speculate_k}")
        if speculate_k and tokens.ndim != 2:
            raise NotImplementedError(
                "speculate_k needs (B, S) token prompts")
        stats = SplitStats()
        samp_ops = None  # None → the exact greedy argmax path
        if sampling is not None:
            splist = broadcast_params(sampling, b)
            if not all(p.greedy for p in splist):
                if tokens.ndim != 2:
                    raise NotImplementedError(
                        "non-greedy sampling needs (B, S) token prompts")
                samp_ops = device_operands(splist)

        nfront, nback = self.split_block, cfg.num_blocks - self.split_block
        edge_caches = jax.tree_util.tree_map(
            lambda a: a[:nfront], init_caches(cfg, b, self.cache_len, opts))
        cloud_pool = None
        aligned = 0
        if shared_prefix_len and not (self.paged_cloud_kv and self.opsc.i_kv):
            raise ValueError("shared_prefix_len needs paged_cloud_kv=True "
                             "and I_kv=1 (the prefix lives in cloud pages)")
        if self.paged_cloud_kv and self.opsc.i_kv:
            from repro.serving.kv_pool import (DEFAULT_PAGE_SIZE, PagedKVPool)

            cloud_pool = PagedKVPool(
                cfg, num_pages=self.cloud_pool_pages,
                page_size=self.cloud_page_size or DEFAULT_PAGE_SIZE,
                max_requests=b, max_seq_len=self.cache_len, num_blocks=nback)
            if shared_prefix_len and b > 1:
                declared = min(int(shared_prefix_len), s - 1)
                # validate the DECLARED prefix even when page rounding
                # disables the dedup below — a caller with mismatched rows
                # must hear about it, not silently lose sharing
                if not np.all(np.asarray(prompts)[:, :declared]
                              == np.asarray(prompts)[:1, :declared]):
                    raise ValueError(
                        f"shared_prefix_len={shared_prefix_len}: rows do "
                        f"not share their first {declared} prompt tokens")
                # share whole pages only: no CoW needed, and rows created in
                # the same prefill call can read the pages row 0 writes
                # (a declared prefix shorter than one page shares nothing)
                aligned = (declared // cloud_pool.page_size
                           * cloud_pool.page_size)
            if aligned:
                slot0 = cloud_pool.admit(s, reserve_tokens=s + max_new_tokens)
                handle = cloud_pool.share_prefix(slot0, aligned)
                for _ in range(b - 1):
                    cloud_pool.admit(s, reserve_tokens=s + max_new_tokens,
                                     prefix=handle)
                cloud_pool.release_prefix(handle)  # rows hold their own refs
                stats.shared_prefix_pages = aligned // cloud_pool.page_size
            else:
                for _ in range(b):
                    # worst-case reservation (like the scheduler's admission
                    # control): a mid-decode append can then never exhaust
                    # the pool and lose the generated tokens
                    cloud_pool.admit(s, reserve_tokens=s + max_new_tokens)
            cloud_caches = cloud_pool.device_caches()
        else:
            cloud_caches = jax.tree_util.tree_map(
                lambda a: a[nfront:], init_caches(cfg, b, self.cache_len, opts))

        def account_pages():
            if cloud_pool is None:
                return
            # shipment moves the WRITTEN pages; residency counts the whole
            # worst-case reservation the cloud is holding
            stats.uplink_bits_paged += cloud_pool.page_bytes_written() * 8
            stats.cloud_pool_bytes_peak = max(stats.cloud_pool_bytes_peak,
                                              cloud_pool.page_bytes_in_use())

        # ---- prefill both segments (prompt flows through the same uplink)
        tel = self.telemetry
        t0 = tel.now() if tel is not None else 0.0
        h, edge_caches = self._edge_front(self.edge_params["blocks"],
                                          self.edge_params, tokens, edge_caches,
                                          jnp.int32(0), decode=False)
        if tel is not None:
            self._tspan("edge", "prefill", t0, h)
        if aligned:
            # the shared prefix crosses the uplink ONCE (with row 0); rows
            # 1+ ship only their suffix columns and the cloud reconstructs
            # their prefix from row 0's transmission — causality makes the
            # prefix hidden states row-independent, so this is lossless
            if compress:
                rec0, bits0 = self._compress(h[:1])
                recs, bits_s = self._compress(h[1:, aligned:])
            else:
                rec0, bits0 = h[:1], float(h[:1].size * 16)
                recs, bits_s = h[1:, aligned:], float(h[1:, aligned:].size * 16)
            pre = jnp.broadcast_to(rec0[:, :aligned],
                                   (b - 1, aligned) + h.shape[2:])
            h = jnp.concatenate(
                [rec0, jnp.concatenate([pre, recs], axis=1)],
                axis=0).astype(h.dtype)
            bits = float(bits0 + bits_s)
        elif compress:
            h, bits = self._compress(h)
        else:
            bits = float(h.size * 16)  # uncompressed fp16 uplink
        stats.uplink_bits_measured += bits
        self._uplink.uplink(bits, stage="prefill", tokens=b * s)
        t0 = tel.now() if tel is not None else 0.0
        if aligned:
            posn = np.tile(np.arange(s, dtype=np.int32), (b, 1))
            posn[1:, :aligned] = -1  # rows 1+ neither write nor re-read it
            logits, cloud_caches = self._cloud_back_shared(
                self.cloud_params["blocks"], self.cloud_params, h,
                cloud_caches, jnp.asarray(posn))
        else:
            logits, cloud_caches = self._cloud_back(
                self.cloud_params["blocks"], self.cloud_params, h,
                cloud_caches, jnp.int32(0), decode=False)
        if tel is not None:
            self._tspan("cloud", "prefill", t0, logits)
        stats.uplink_bits_eq3 += self._eq3_bits(s, self.opsc.i_kv)
        if cloud_pool is not None:
            cloud_pool.update_from(cloud_caches)
            for r in range(b):
                cloud_pool.commit_prefill(r, s)
            account_pages()

        # Preallocated device buffers (no unbounded Python-list concat, no
        # per-token host copy): split-layer history for the stateless-cloud
        # (I_kv=0) fallback, and the generated-token matrix — both read back
        # to the host exactly once, after the loop.
        h_buf = jnp.zeros((b, self.cache_len) + h.shape[2:], h.dtype)
        h_buf = self._seq_write(h_buf, h, jnp.int32(0))
        tok_buf = jnp.zeros((b, max_new_tokens) + tokens.shape[2:], tokens.dtype)
        lp_buf = jnp.zeros((b, max_new_tokens), jnp.float32)
        n_hist = s
        n_out = 0
        i_kv = self.opsc.i_kv
        pos = s
        if speculate_k:
            # ---- speculative rounds: draft on the edge head, verify all k
            # in ONE cloud call — k uplink round trips become one
            if samp_ops is None:
                v_keys = jnp.zeros((b, 2), jnp.uint32)
                v_temp = jnp.zeros((b,), jnp.float32)
                v_tk = jnp.zeros((b,), jnp.int32)
                v_tp = jnp.ones((b,), jnp.float32)
                nxt = self._next_token(logits).astype(tokens.dtype)
            else:
                v_keys, v_temp, v_tk, v_tp = samp_ops
                nxt = self._sample_next(logits, v_keys, jnp.int32(0), v_temp,
                                        v_tk, v_tp).astype(tokens.dtype)
            # the first token is sampled from the prefill logits exactly as
            # the per-token loop samples it (same draw, same fold)
            tok_buf = self._seq_write(tok_buf, nxt, jnp.int32(0))
            if with_logprobs:
                lp_buf = self._seq_write(lp_buf, self._tok_lp(logits, nxt),
                                         jnp.int32(0))
            n_out = 1
            cur = nxt  # last emitted, not yet consumed by the model
            while n_out < max_new_tokens:
                # kd drafts + the pending token = one k_eff-token payload;
                # a round emits 1..k_eff tokens, so never draft past the
                # generation budget
                kd = min(speculate_k, max_new_tokens - n_out - 1)
                k_eff = kd + 1
                t0 = tel.now() if tel is not None else 0.0
                hs, drafts = [], []
                for j in range(k_eff):
                    h, edge_caches = self._edge_front(
                        self.edge_params["blocks"], self.edge_params, cur,
                        edge_caches, jnp.int32(pos + j), decode=True)
                    hs.append(h)
                    if j + 1 < k_eff:
                        cur = self._draft_next(
                            self.edge_params, h).astype(tokens.dtype)
                        drafts.append(cur)
                h = jnp.concatenate(hs, axis=1) if k_eff > 1 else hs[0]
                draft_mat = (jnp.concatenate(drafts, axis=1).astype(jnp.int32)
                             if drafts else jnp.zeros((b, 0), jnp.int32))
                if tel is not None:
                    self._tspan("edge", "draft", t0, h)
                if compress:
                    # ONE payload for the whole burst (TAB-Q allocates bits
                    # per row, so the k-token encode matches k per-token
                    # encodes bit for bit — the greedy-identity tests
                    # exercise exactly this)
                    h_c, bits = self._compress(h)
                else:
                    h_c, bits = h, float(h.size * 16)
                # Algorithm 2 ladder on the *modeled* total latency
                w = pos + k_eff
                if self.deadline_s is not None:
                    lat = self.latency.total_latency(
                        w, self.opsc.split_layer, bits)
                    if lat > self.deadline_s and i_kv == 1:
                        i_kv = 0  # drop KV from the uplink accounting
                        stats.kv_dropped_steps += 1
                        lat = self.latency.total_latency(
                            w, self.opsc.split_layer, self._eq3_bits(w, 0))
                    if lat > self.deadline_s:
                        stats.early_exits += 1
                        stats.latency_s += lat
                        break
                    stats.latency_s += lat
                stats.uplink_bits_measured += bits
                stats.uplink_bits_eq3 += self._eq3_bits(w, i_kv)
                stats.uplink_round_trips += 1
                self._uplink.uplink(bits, stage="speculate",
                                    tokens=b * k_eff, i_kv=i_kv)
                h_buf = self._seq_write(h_buf, h_c, jnp.int32(n_hist))
                t0 = tel.now() if tel is not None else 0.0
                if i_kv:
                    if cloud_pool is not None:
                        for r in range(b):
                            cloud_pool.append(r, k_eff)
                        cloud_caches = cloud_pool.device_caches()
                        posn = pos + np.tile(
                            np.arange(k_eff, dtype=np.int32), (b, 1))
                        vlogits, cloud_caches = self._cloud_verify_paged(
                            self.cloud_params["blocks"], self.cloud_params,
                            h_c, cloud_caches, jnp.asarray(posn))
                        cloud_pool.update_from(cloud_caches)
                        account_pages()
                    else:
                        vlogits, cloud_caches = self._cloud_verify(
                            self.cloud_params["blocks"], self.cloud_params,
                            h_c, cloud_caches, jnp.int32(pos), decode=True,
                            tail=k_eff)
                else:
                    # stateless cloud re-run over the whole history; only
                    # the verify columns reach the head
                    hist = h_buf[:, :n_hist + k_eff]
                    fresh = jax.tree_util.tree_map(
                        lambda a: a[self.split_block:],
                        init_caches(cfg, b, self.cache_len, opts))
                    vlogits, _ = self._cloud_verify(
                        self.cloud_params["blocks"], self.cloud_params, hist,
                        fresh, jnp.int32(0), decode=False, tail=k_eff)
                if tel is not None:
                    self._tspan("cloud", "verify", t0, vlogits)
                out, n_acc, lps = self._spec_verify(
                    draft_mat, jnp.full((b,), kd, jnp.int32), vlogits,
                    v_keys, jnp.full((b,), n_out, jnp.int32), v_temp, v_tk,
                    v_tp)
                # batch rows march in lockstep: advance by the MINIMUM
                # accepted run (every row's accepted prefix is exact, so a
                # faster row's discarded tail is re-derived — never wrong —
                # by the next round)
                n_acc_h = np.asarray(n_acc)
                n = int(n_acc_h.min())
                stats.spec_rounds += 1
                stats.spec_drafted += b * kd
                stats.spec_accepted += int(n_acc_h.sum()) - b
                if tel is not None:
                    tel.metrics.observe("split.accepted_tokens", float(n))
                tok_buf = self._seq_write(
                    tok_buf, out[:, :n].astype(tok_buf.dtype),
                    jnp.int32(n_out))
                if with_logprobs:
                    lp_buf = self._seq_write(lp_buf, lps[:, :n],
                                             jnp.int32(n_out))
                if cloud_pool is not None and n < k_eff:
                    # scrub the rejected tail: stale positions must never
                    # survive into the next round's history mask or a swap
                    # export (the dense-cache paths need no scrub — the
                    # next round overwrites the same cache slots before the
                    # causal mask could expose them)
                    for r in range(b):
                        cloud_pool.truncate(r, pos + n)
                cur = out[:, n - 1:n].astype(tokens.dtype)
                pos += n
                n_hist += n
                n_out += n
                stats.tokens_generated += n
        else:
            for step in range(max_new_tokens):
                if samp_ops is None:
                    nxt = self._next_token(logits).astype(tokens.dtype)
                else:
                    keys, temp, tk, tp = samp_ops
                    nxt = self._sample_next(logits, keys, jnp.int32(step),
                                            temp, tk, tp).astype(tokens.dtype)
                tok_buf = self._seq_write(tok_buf, nxt, jnp.int32(step))
                if with_logprobs:
                    lp_buf = self._seq_write(lp_buf, self._tok_lp(logits, nxt),
                                             jnp.int32(step))
                n_out = step + 1
                if step + 1 == max_new_tokens:
                    break
                t0 = tel.now() if tel is not None else 0.0
                h, edge_caches = self._edge_front(
                    self.edge_params["blocks"], self.edge_params, nxt,
                    edge_caches, jnp.int32(pos), decode=True)
                if tel is not None:
                    self._tspan("edge", "decode", t0, h)
                fixed_bits = None
                if compress:
                    h_c, bits = self._compress(h, fixed_bits)
                else:
                    h_c, bits = h, float(h.size * 16)
                # Algorithm 2 ladder on the *modeled* total latency
                w = pos + 1
                if self.deadline_s is not None:
                    lat = self.latency.total_latency(
                        w, self.opsc.split_layer, bits)
                    if lat > self.deadline_s and i_kv == 1:
                        i_kv = 0  # drop KV from the uplink accounting
                        stats.kv_dropped_steps += 1
                        lat = self.latency.total_latency(
                            w, self.opsc.split_layer, self._eq3_bits(w, 0))
                    if lat > self.deadline_s:
                        stats.early_exits += 1
                        stats.latency_s += lat
                        break
                    stats.latency_s += lat
                stats.uplink_bits_measured += bits
                stats.uplink_bits_eq3 += self._eq3_bits(w, i_kv)
                stats.uplink_round_trips += 1
                self._uplink.uplink(bits, stage="decode", step=step,
                                    i_kv=i_kv)

                h_buf = self._seq_write(h_buf, h_c, jnp.int32(n_hist))
                n_hist += 1
                t0 = tel.now() if tel is not None else 0.0
                if i_kv:
                    if cloud_pool is not None:  # grow each request by one
                        for r in range(b):
                            cloud_pool.append(r, 1)
                        cloud_caches = cloud_pool.device_caches()
                    logits, cloud_caches = self._cloud_back(
                        self.cloud_params["blocks"], self.cloud_params, h_c,
                        cloud_caches, jnp.int32(pos), decode=True)
                    if cloud_pool is not None:
                        cloud_pool.update_from(cloud_caches)
                        account_pages()
                else:
                    # stateless cloud: re-run the back segment over the
                    # history (the paper's "losing the benefits of the
                    # cache")
                    hist = h_buf[:, :n_hist]
                    fresh = jax.tree_util.tree_map(
                        lambda a: a[self.split_block:],
                        init_caches(cfg, b, self.cache_len, opts))
                    logits, _ = self._cloud_back(self.cloud_params["blocks"],
                                                 self.cloud_params, hist,
                                                 fresh, jnp.int32(0),
                                                 decode=False)
                if tel is not None:
                    self._tspan("cloud", "decode", t0, logits)
                pos += 1
                stats.tokens_generated += 1

        if tel is not None:
            # mirror the call's SplitStats into the shared registry — ONE
            # uplink accounting surface across SplitStats, server.metrics()
            # and exported traces
            m = tel.metrics
            m.count("split.calls")
            m.count("split.requests", b)
            m.count("split.tokens_generated", stats.tokens_generated)
            m.count("split.uplink_bits_measured", stats.uplink_bits_measured)
            m.count("split.uplink_bits_eq3", stats.uplink_bits_eq3)
            m.count("split.uplink_bits_paged", stats.uplink_bits_paged)
            m.count("split.early_exits", stats.early_exits)
            m.count("split.kv_dropped_steps", stats.kv_dropped_steps)
            m.count("split.deadline_latency_s", stats.latency_s)
            m.count("split.uplink_round_trips", stats.uplink_round_trips)
            if stats.spec_rounds:
                m.count("split.spec_rounds", stats.spec_rounds)
                m.count("split.spec_drafted", stats.spec_drafted)
                m.count("split.spec_accepted", stats.spec_accepted)
                m.gauge("split.acceptance_rate", stats.acceptance_rate)
            if cloud_pool is not None:
                m.gauge("split.cloud_pool_bytes_peak",
                        stats.cloud_pool_bytes_peak)
                m.gauge("split.shared_prefix_pages",
                        stats.shared_prefix_pages)
        out = np.asarray(tok_buf[:, :n_out])
        toks = np.concatenate([np.asarray(tokens), out], axis=1)
        if with_logprobs:
            return toks, stats, np.asarray(lp_buf[:, :n_out])
        return toks, stats
