"""Continuous-batching scheduler over the paged KV pool.

Replaces ``Engine``'s equal-length bucketing: requests of RAGGED prompt and
generation lengths share one decode batch and one KV pool, and the batch
composition changes mid-stream — a finished request's slot and pages are
reclaimed and handed to the next queued request without draining the batch.

Lifecycle per request (see ``serving/README.md``):

  admit   — the queue head is admitted when a slot row AND its worst-case
            pages (prompt + max_new_tokens) are free — admission control
            against the Eq. 2 ceiling (``PagedKVPool.admit`` with
            ``reserve_tokens``; reserving up front is what makes mid-decode
            exhaustion impossible). Admission is batched, so several
            waiting requests prefill together
  prefill — the admitted group prefills RAGGEDLY: right-aligned padding,
            per-row position masks, one ``paged_prefill`` call whose last
            column yields every row's first sampled token
  decode  — ALL active slots step together through ONE jitted
            ``paged_decode_step`` (fixed slot-count shape → a single
            compile, whatever the batch mix); each row decodes at its own
            absolute position, inactive rows ride along masked
  evict   — on max-tokens or EOS the slot's pages return to the free list
            (positions scrubbed device-side) and the next admit reuses them

The decode loop is host-orchestrated (greedy argmax on host): what this
scheduler buys is MEMORY — residency is bounded by the worst case
(prompt + max_new) of the requests CURRENTLY resident, reclaimed the tick
each finishes, instead of slots × an engine-wide ``cache_len`` held for the
whole batch — and admission latency, not per-step dispatch. The fused
single-batch scan in ``serving.engine`` remains the static-batch fast
path.
"""

from __future__ import annotations

import dataclasses
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models.transformer import (RuntimeOpts, paged_decode_step,
                                      paged_prefill)
from repro.serving.kv_pool import DEFAULT_PAGE_SIZE, PagedKVPool


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (S,) int32
    max_new_tokens: int
    eos_id: int | None = None


@dataclasses.dataclass
class _SlotState:
    rid: int
    prompt: np.ndarray
    max_new_tokens: int
    eos_id: int | None
    generated: list = dataclasses.field(default_factory=list)

    @property
    def done(self) -> bool:
        if len(self.generated) >= self.max_new_tokens:
            return True
        return (self.eos_id is not None and self.generated
                and self.generated[-1] == self.eos_id)


@dataclasses.dataclass
class SchedulerStats:
    steps: int = 0  # ragged decode steps executed
    prefills: int = 0  # ragged prefill calls (≈ admission waves)
    admitted: int = 0
    evicted: int = 0
    peak_occupancy: float = 0.0
    peak_pool_bytes: int = 0
    peak_eq2_bytes: int = 0


def _bucket(n: int) -> int:
    """Next power of two — bounds the distinct (R_adm, S_pad) prefill
    compiles the same way Engine buckets its scan length."""
    return 1 << max(0, (n - 1).bit_length())


class Scheduler:
    """Continuous-batching front end over one shared ``PagedKVPool``.

    ``submit`` enqueues; ``run`` drains queue + batch; ``step`` advances one
    admit→prefill→decode→evict tick for incremental/streaming use."""

    def __init__(self, cfg: ArchConfig, params,
                 opts: RuntimeOpts = RuntimeOpts(),
                 *, num_pages: int = 128, page_size: int = DEFAULT_PAGE_SIZE,
                 max_slots: int = 4, max_seq_len: int | None = None):
        self.cfg, self.params, self.opts = cfg, params, opts
        self.pool = PagedKVPool(cfg, num_pages=num_pages, page_size=page_size,
                                max_requests=max_slots, max_seq_len=max_seq_len)
        self.max_slots = max_slots
        self.queue: deque = deque()
        self.slots: list = [None] * max_slots
        self.results: dict = {}
        self.stats = SchedulerStats()
        self._next_rid = 0
        self._prefill = jax.jit(
            lambda params, tokens, caches, positions: paged_prefill(
                params, cfg, tokens, caches, positions, opts))
        self._decode = jax.jit(
            lambda params, tokens, caches, pos: paged_decode_step(
                params, cfg, tokens, caches, pos, opts))

    # -------------------------------------------------------------- intake

    def submit(self, prompt, max_new_tokens: int, eos_id: int | None = None
               ) -> int:
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        assert prompt.size >= 1 and max_new_tokens >= 1
        rid = self._next_rid
        self._next_rid += 1
        self.queue.append(Request(rid, prompt, max_new_tokens, eos_id))
        return rid

    # ------------------------------------------------------------ lifecycle

    def _admit_wave(self) -> list:
        """Admit queue heads while a slot row and their WORST-CASE pages
        (prompt + max_new_tokens) fit — admission control against the Eq. 2
        ceiling. Reserving up front means a mid-decode append can never hit
        an exhausted pool (concurrent lazy growers can deadlock each other
        one page short); the queue, not an exception, is the backpressure.
        FIFO: a too-big head blocks the queue (no starvation-prone
        skipping)."""
        admitted = []
        while self.queue:
            req = self.queue[0]
            worst = len(req.prompt) + req.max_new_tokens
            if not self.pool.can_admit(worst):
                break
            slot = self.pool.admit(len(req.prompt), reserve_tokens=worst)
            self.queue.popleft()
            self.slots[slot] = _SlotState(req.rid, req.prompt,
                                          req.max_new_tokens, req.eos_id)
            admitted.append(slot)
        return admitted

    def _prefill_wave(self, admitted: list) -> None:
        """One ragged right-aligned prefill over the admitted rows; the last
        column is every row's final prompt token → first sampled token."""
        lens = [len(self.slots[s].prompt) for s in admitted]
        s_pad = _bucket(max(lens))
        r = len(admitted)
        tokens = np.zeros((r, s_pad), np.int32)
        posn = np.full((r, s_pad), -1, np.int32)
        for i, slot in enumerate(admitted):
            p = self.slots[slot].prompt
            tokens[i, s_pad - p.size:] = p
            posn[i, s_pad - p.size:] = np.arange(p.size)
        logits, new_caches = self._prefill(
            self.params, jnp.asarray(tokens),
            caches=self.pool.device_caches(rows=admitted),
            positions=jnp.asarray(posn))
        self.pool.update_from(new_caches)
        first = np.asarray(jnp.argmax(logits, axis=-1))
        for i, slot in enumerate(admitted):
            self.pool.commit_prefill(slot, lens[i])
            self.slots[slot].generated.append(int(first[i]))
        self.stats.prefills += 1
        self.stats.admitted += r

    def _decode_tick(self) -> None:
        """One ragged decode step over EVERY slot (single compiled shape);
        inactive rows carry position -1 and are masked end-to-end."""
        tokens = np.zeros((self.max_slots, 1), np.int32)
        pos = np.full((self.max_slots,), -1, np.int32)
        for i, st in enumerate(self.slots):
            if st is None:
                continue
            tokens[i, 0] = st.generated[-1]
            pos[i] = self.pool.lengths[i]  # absolute position being written
            self.pool.append(i, 1)
        logits, new_caches = self._decode(
            self.params, jnp.asarray(tokens),
            caches=self.pool.device_caches(), pos=jnp.asarray(pos))
        self.pool.update_from(new_caches)
        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        for i, st in enumerate(self.slots):
            if st is not None:
                st.generated.append(int(nxt[i]))
        self.stats.steps += 1

    def _evict_finished(self) -> None:
        for i, st in enumerate(self.slots):
            if st is None or not st.done:
                continue
            toks = st.generated[: st.max_new_tokens]
            if st.eos_id is not None and st.eos_id in toks:
                toks = toks[: toks.index(st.eos_id) + 1]
            self.results[st.rid] = np.concatenate(
                [st.prompt, np.asarray(toks, np.int32)])
            self.pool.free(i)
            self.slots[i] = None
            self.stats.evicted += 1

    def _track_occupancy(self) -> None:
        self.stats.peak_occupancy = max(self.stats.peak_occupancy,
                                        self.pool.occupancy())
        self.stats.peak_pool_bytes = max(self.stats.peak_pool_bytes,
                                         self.pool.page_bytes_in_use())
        self.stats.peak_eq2_bytes = max(self.stats.peak_eq2_bytes,
                                        self.pool.eq2_bytes())

    # ------------------------------------------------------------- driving

    @property
    def pending(self) -> bool:
        return bool(self.queue) or any(s is not None for s in self.slots)

    def step(self) -> bool:
        """One scheduler tick: admit+prefill a wave, evict anything that
        finished on its prefill token, decode the ragged batch, evict.
        Returns whether work remains."""
        admitted = self._admit_wave()
        if admitted:
            self._prefill_wave(admitted)
            self._track_occupancy()
            self._evict_finished()  # max_new_tokens == 1 finishes here
        if any(s is not None for s in self.slots):
            self._decode_tick()
            self._track_occupancy()
            self._evict_finished()
        elif not admitted and self.queue:
            # idle pool yet the head still doesn't fit: it never will —
            # fail loudly instead of spinning forever
            req = self.queue[0]
            from repro.serving.kv_pool import PoolExhaustedError

            raise PoolExhaustedError(
                f"request {req.rid} needs "
                f"{self.pool.pages_for(len(req.prompt) + req.max_new_tokens)}"
                f" pages worst-case but the whole pool has "
                f"{self.pool.num_pages - 1} (max_blocks "
                f"{self.pool.max_blocks}); it can never be admitted")
        return self.pending

    def run(self) -> dict:
        """Drain queue and batch; returns {rid: np.ndarray tokens} (prompt +
        generation, EOS-truncated)."""
        while self.step():
            pass
        return self.results
