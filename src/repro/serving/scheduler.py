"""Continuous-batching scheduler over the paged KV pool: prefix sharing,
preemption, lazy page growth.

Replaces ``Engine``'s equal-length bucketing: requests of RAGGED prompt and
generation lengths share one decode batch and one KV pool, and the batch
composition changes mid-stream — a finished request's slot and pages are
reclaimed and handed to the next queued request without draining the batch.

Lifecycle per request (see ``serving/README.md``):

  admit   — the queue head is admitted when a slot row AND its admission
            pages are free. Two admission policies:
              * reserve (default) — pages for the WORST case
                (prompt + max_new_tokens) are reserved up front, so a
                mid-decode append can never hit an exhausted pool; the
                queue, not an exception, is the backpressure
              * lazy (``lazy_growth=True``) — only the PROMPT's pages are
                reserved; decode grows page by page and pool exhaustion is
                resolved by PREEMPTION (below). Strictly higher admitted
                concurrency from the same pool, at the cost of preemption
                work under pressure
            A request submitted with ``prefix_key=`` attaches to the shared
            prefix instead of allocating its own copy: the first such
            request (the CREATOR) prefills the full prompt and its prefix
            pages are pinned as a ``kv_pool.SharedPrefix``; later requests
            FORK — their block tables alias the pinned pages (refcount +1
            each) and only suffix pages (plus one CoW boundary copy when
            the prefix is not page-aligned) are newly allocated
  prefill — three tick shapes (``tick_mode=``, defaulting to
            ``prefill_mode`` for the two legacy values):
              * "packed" — ONE token-packed call serves the whole tick:
                every decoding slot's next token AND up-to-budget
                prefill-chunk tokens ride in a single flat
                ``(1, token_budget)`` buffer (each slot one contiguous
                segment — a decode token is a length-1 segment), attended
                in one pass by the Pallas ``kernels.varlen_attention``
                page walk and sampled through the same per-slot operand
                lanes (``models.transformer.packed_step``). One compiled
                shape, one dispatch per tick, pad limited to the buffer's
                tail — see serving/README.md for the segment layout
            and two legacy prefill policies (``prefill_mode=``):
              * "chunked" (default, Sarathi-style) — every prompt is split
                into fixed ``prefill_chunk``-token pieces and each tick
                advances every mid-prefill slot by ONE chunk through a
                FIXED-shape ``(max_slots, prefill_chunk)`` call: one
                compile serves every admission, per-tick latency is
                bounded by the chunk (a long prompt can no longer stall
                the decoding batch for its full length), and continuation
                chunks attend their earlier chunks THROUGH the pool via
                the Pallas ``kernels.paged_prefill_attention`` page walk
                — exactly what their decode steps will read. The chunk's
                last column yields the first sampled token when it
                completes the prompt
              * "wave" — the pre-chunking behavior: the admitted group
                prefills RAGGEDLY in one right-aligned call of bucketed
                ``(R_adm, S_pad)`` shape (a distinct compile per bucket,
                and decode waits for the full prompt)
            Either way, forked rows prefill ONLY their suffix, attending
            the shared prefix through their block tables
            (``models.transformer.paged_prefill_shared``)
  decode  — ALL active slots step together through ONE jitted
            ``paged_decode_step`` (fixed slot-count shape → a single
            compile, whatever the batch mix); each row decodes at its own
            absolute position, inactive rows ride along masked
  preempt — (lazy mode) when an append exhausts the pool, idle pinned
            prefixes are released first; then the lowest-priority (ties:
            most recently admitted) running request is EVICTED back to the
            queue head carrying its generated-so-far tokens, its pages
            freed. Two resume mechanisms (``resume=``):
              * "swap" (default) — the victim's written pages are
                snapshotted to HOST memory at eviction and restored
                bit-identically on re-admission (``kv_pool.export_slot`` /
                ``restore_slot``): the resumed decode is exactly the
                un-preempted one, token for token
              * "refill" — nothing is saved; the resumed request RE-PREFILLS
                prompt + generated tokens (re-attaching to its shared
                prefix if it has one). Cheaper in host memory, but the
                re-prefilled K/V travel a different numeric path than the
                decode-written originals, so the continuation is only
                approximately (not bit-) identical
            Already-emitted tokens are never re-sampled either way. Lazy
            admission always reserves one token of decode headroom past the
            (re-)prefill, so every admitted request makes ≥ 1 token of
            progress before it can be preempted — no livelock
  evict   — on max-tokens or EOS the slot's page references return to the
            pool (exclusively-owned pages scrubbed device-side; shared
            prefix pages survive for the next fork) and the next admit
            reuses them

Sampling is ON DEVICE and PER REQUEST: the decode tick jits
``paged_decode_step`` + ``core.sampling.sample_tokens`` as one function —
per-slot temperature / top-k / top-p operands, a per-request PRNG lane
folded with the row's own generation index, inactive rows masked — so a
batch mixing greedy and non-greedy requests runs through ONE compiled
shape (no per-request recompiles, no per-step host argmax; only the
sampled token ids cross to the host for bookkeeping). Greedy rows
(``temperature <= 0`` or ``top_k == 1``) take the exact argmax lane.
Per-request STOP-TOKEN SETS (``SamplingParams.stop_set``) finish a
request mid-stream, and ``abort(rid)`` cancels one wherever it is —
queued, mid-prefill, or decoding. Per-token events — each carrying the
token's log-probability under the raw model distribution — stream out
through ``drain_events()`` (consumed by ``serving.api.LLMServer``).

The tick loop itself stays host-orchestrated: what this scheduler buys is
MEMORY — shared prefixes are resident once however many requests attach,
residency is bounded by what the CURRENTLY resident requests actually use
(lazy mode), reclaimed the tick each finishes — and admission latency,
not per-step dispatch. The fused single-batch scan in ``serving.engine``
remains the static-batch fast path.
"""

from __future__ import annotations

import dataclasses
import threading
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.sampling import (SamplingParams, bias_rows,
                                 sample_tokens_with_logprobs,
                                 speculative_verify, truncate_at_stop)
from repro.models.transformer import (RuntimeOpts, packed_step,
                                      paged_decode_step, paged_prefill,
                                      paged_prefill_shared, paged_verify_step,
                                      sharded_step_fns)
from repro.serving.kv_pool import (DEFAULT_PAGE_SIZE, PagedKVPool,
                                   PoolExhaustedError, SharedPrefix)
from repro.serving.page_transport import HostSwapTransport

# the adaptive-prefill ladder ``prefill_chunk="auto"`` expands to: three
# compiled chunk shapes, picked per tick by batch composition (see
# Scheduler._pick_chunk)
AUTO_CHUNK_LADDER = (64, 128, 256)


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (S,) int32 — the ORIGINAL prompt tokens
    max_new_tokens: int
    eos_id: int | None = None
    prefix_key: object = None  # hashable; same key ⇒ shared prompt prefix
    priority: int = 0  # higher = preempted later
    # per-request sampling knobs (temperature/top-k/top-p/seed/stop set) —
    # turned into per-slot device operands at admission
    sampling: SamplingParams = SamplingParams(max_tokens=1)
    # resume state: tokens generated before a preemption — re-seeded into
    # the slot on re-admission, never re-sampled — and (swap resume) the
    # host snapshot of the request's written pages
    generated: list = dataclasses.field(default_factory=list)
    snapshot: dict | None = dataclasses.field(default=None, repr=False)
    submit_tick: int = 0  # scheduler tick at submission (TTFT accounting)
    # anti-thrash backoff: a preempted request is not re-admitted before
    # this tick while its preemptor still runs (see _admit_wave)
    cooldown_until: int = 0

    def __post_init__(self):
        # the stop set lives in sampling; fold a directly-passed eos_id in
        # so a hand-built Request(…, eos_id=…) stops like a submitted one
        if self.eos_id is not None \
                and self.eos_id not in self.sampling.stop_set:
            self.sampling = dataclasses.replace(
                self.sampling, stop_token_ids=self.sampling.stop_token_ids
                + (int(self.eos_id),))

    @property
    def prefill_tokens(self) -> np.ndarray:
        """TOKENS a (re-)prefill must write: the prompt plus every generated
        token already FED to the model (all but the last generated token,
        which is the next decode input)."""
        if not self.generated:
            return self.prompt
        return np.concatenate(
            [self.prompt, np.asarray(self.generated[:-1], np.int32)])


@dataclasses.dataclass
class _PrefixEntry:
    """Registry row for one shared prompt prefix."""

    key: object
    tokens: np.ndarray  # (prefix_len,) int32 — validated on every submit
    handle: SharedPrefix | None = None  # pinned pages once materialized
    creator_rid: int | None = None  # request whose prefill materializes it
    forks: int = 0


@dataclasses.dataclass
class _SlotState:
    req: Request
    generated: list
    seq: int  # admission sequence number (preemption tie-break)
    prefilled: int = 0  # prompt/resume TOKENS already written to the pool

    @property
    def prefilling(self) -> bool:
        """Still mid-prefill: more chunks to write before the slot decodes."""
        return self.prefilled < len(self.req.prefill_tokens)

    @property
    def done(self) -> bool:
        if len(self.generated) >= self.req.max_new_tokens:
            return True
        return bool(self.generated
                    and self.generated[-1] in self.req.sampling.stop_set)


@dataclasses.dataclass
class SchedulerStats:
    steps: int = 0  # ragged decode steps executed
    prefills: int = 0  # prefill CALLS: admission waves in "wave" mode, per-
    #                    tick fixed-shape chunk calls in "chunked" mode
    prefill_chunks: int = 0  # per-slot chunks written (chunked mode; a
    #                          single-chunk prompt counts 1)
    admitted: int = 0  # admissions incl. resumptions
    evicted: int = 0  # completed requests
    aborted: int = 0  # abort() cancellations
    preemptions: int = 0  # evict-to-queue events (lazy mode)
    prefix_forks: int = 0  # admissions that attached to a shared prefix
    slot_ticks: int = 0  # Σ active slots over decode steps (mean concurrency
    #                       = slot_ticks / steps)
    peak_occupancy: float = 0.0
    peak_pool_bytes: int = 0  # physical page bytes (shared pages once)
    peak_eq2_bytes: int = 0  # logical per-request Eq. 2 bytes
    peak_shared_pages: int = 0  # pages with refcount > 1
    peak_swap_bytes: int = 0  # host bytes held by swapped-out snapshots
    compiled_shapes: int = 0  # distinct jitted step shapes seen (packed
    #                           mode is exactly 1; chunked stays O(1); wave
    #                           grows per bucket)
    spec_rounds: int = 0  # verify rounds that carried >= 1 draft token
    spec_drafted: int = 0  # draft tokens proposed across those rounds
    spec_accepted: int = 0  # draft tokens EMITTED (accepted and not cut by
    #                         a stop token) — acceptance rate is
    #                         spec_accepted / spec_drafted
    auto_prefix_hits: int = 0  # submits auto-attached to a detected shared
    #                            prefix (Scheduler(auto_prefix=True))
    packed_ticks: int = 0  # token-packed calls dispatched (packed mode)
    packed_tokens: int = 0  # live tokens those calls carried
    packed_pad_tokens: int = 0  # tail-pad rows they carried (pad fraction
    #                             = packed_pad_tokens / (packed_ticks * T))
    prefill_tokens: int = 0  # prompt/resume TOKENS written by prefill calls
    #                          (all tick modes — the prefill side of the
    #                          tick timeline's token accounting)
    # rid → ticks from submit to the first sampled token (TTFT in ticks)
    ttft_ticks: dict = dataclasses.field(default_factory=dict)
    # chunk size → ticks it was picked (adaptive prefill_chunk="auto")
    auto_chunks: dict = dataclasses.field(default_factory=dict)

    @property
    def acceptance_rate(self) -> float:
        """Fraction of proposed draft tokens that were emitted."""
        return (self.spec_accepted / self.spec_drafted
                if self.spec_drafted else 0.0)


def _bucket(n: int) -> int:
    """Next power of two — bounds the distinct (R_adm, S_pad) prefill
    compiles the same way Engine buckets its scan length."""
    return 1 << max(0, (n - 1).bit_length())


def _prompt_lookup_draft(context: np.ndarray, k: int,
                         max_ngram: int = 3) -> np.ndarray:
    """Model-free draft proposal by PROMPT LOOKUP: find the most recent
    earlier occurrence of the context's trailing n-gram (longest of
    ``max_ngram`` .. 1 that matches) and propose the up-to-``k`` tokens
    that followed it.

    This is the scheduler's draft source — no second model, no extra
    weights, pure host-side token matching — and it is SAFE BY
    CONSTRUCTION: the verify step accepts a draft position only when the
    target model (greedy: argmax match; sampled: rejection test) agrees,
    so a bad guess costs acceptance length, never correctness. Repetitive
    continuations (code, structured text, tiny-vocab test models) accept
    long runs; incompressible ones degenerate to one verified token per
    round, exactly the non-speculative tick. Returns (<= k,) int32,
    possibly empty."""
    context = np.asarray(context, np.int32).reshape(-1)
    length = context.size
    if k <= 0 or length < 2:
        return np.zeros((0,), np.int32)
    for n in range(min(max_ngram, length - 1), 0, -1):
        pat = context[length - n:]
        # windows over context[:-1]: every start whose match leaves >= 1
        # follower token; the trailing n-gram itself can never match
        windows = np.lib.stride_tricks.sliding_window_view(
            context[:length - 1], n)
        hits = np.flatnonzero((windows == pat).all(axis=1))
        if hits.size:
            start = int(hits[-1])  # most recent occurrence wins
            return context[start + n:start + n + k].copy()
    return np.zeros((0,), np.int32)


class Scheduler:
    """Continuous-batching front end over one shared ``PagedKVPool``.

    ``submit`` enqueues; ``run`` drains queue + batch; ``step`` advances one
    admit→prefill→decode→evict tick for incremental/streaming use.
    ``lazy_growth=True`` switches admission control from worst-case page
    reservation to current-need reservation with preemption on exhaustion
    (see module doc).

    ``prefill_mode="chunked"`` (default) admits prompts in fixed
    ``prefill_chunk``-token pieces through one compiled step shape (see
    module doc); ``"wave"`` restores the per-bucket ragged wave prefill.
    ``prefill_chunk`` also takes ``"auto"`` (the ``AUTO_CHUNK_LADDER``
    sizes) or an explicit tuple of sizes: the chunk is then picked PER
    TICK from the ladder — small when decode slots dominate (a decoding
    request pays the chunk's latency every tick, so tail latency wins) or
    when any decoding request carries
    ``SamplingParams(latency_hint="interactive")``, large when the batch
    is prefill-heavy (throughput wins) — bounding the compile count by
    the ladder length instead of 1 (``stats.auto_chunks`` records the
    choices; ``benchmarks/chunked_prefill.py`` measures the tail-tick
    effect). ``preempt_cooldown`` (ticks) is the anti-thrash backoff: a
    preempted request is held in the queue that many extra ticks before
    re-admission while other work runs, so an evict→re-admit→evict swap
    storm can't oscillate tick over tick (0 restores the immediate
    re-admit).

    ``tick_mode="packed"`` replaces the per-tick prefill call(s) + decode
    call pair with ONE token-packed ``packed_step`` over a flat
    ``(1, token_budget)`` buffer (see module doc); ``"chunked"`` and
    ``"wave"`` keep the legacy two-phase tick. The default (None) follows
    ``prefill_mode`` so existing callers are untouched. ``token_budget``
    (packed mode) is the buffer's fixed token count — it must cover every
    decoding slot plus at least one prefill token, so it is clamped to
    ``>= max_slots + 1``; the default ``prefill_chunk + max_slots`` gives
    prefill the same per-tick bandwidth as one chunked-mode chunk even at
    full decode occupancy.

    ``speculate_k=k`` (k > 0) turns every decode tick SPECULATIVE: each
    decoding slot proposes up to k draft tokens by prompt lookup
    (:func:`_prompt_lookup_draft` — model-free n-gram matching over its
    own prompt + generation), the pool optimistically appends the burst,
    ONE fixed ``(max_slots, 1 + k)`` ``paged_verify_step`` call scores
    every position through the pool's quantized codes (in packed mode
    the packed buffer then carries prefill only — in-segment fresh-f32
    draft keys would drift from the sequential path at quantization
    scale), and
    ``core.sampling.speculative_verify`` accepts per slot — rejected
    positions roll back via ``kv_pool.truncate``. Greedy requests emit a
    stream BIT-IDENTICAL to ``speculate_k=0`` (acceptance is argmax
    match, emission is the argmax itself); sampled requests emit the
    exact target distribution (rejection sampling). ``k`` is the
    compiled verify width and the per-request cap —
    ``SamplingParams(speculate_k=)`` may lower it per request, and 0
    (the default) disables speculation entirely, leaving every code path
    byte-identical to the non-speculative scheduler.

    ``auto_prefix=True`` turns on AUTOMATIC prefix detection: a submit
    with no explicit ``prefix_key`` is longest-common-prefix matched
    against the last ``auto_prefix_window`` prompts and against already
    auto-registered prefixes; a match of >= ``auto_prefix_min`` tokens
    attaches the request to a shared prefix through the exact same CoW
    fork machinery as an explicit key (:meth:`_detect_auto_prefix`) —
    repeated system prompts share pages with zero caller cooperation.
    Greedy streams are unchanged (prefix sharing is bit-exact);
    ``stats.auto_prefix_hits`` counts the attachments.

    ``mesh=`` (a ``("kv", "model")`` mesh from
    ``launch.mesh.make_serving_mesh``) turns every tick MULTI-DEVICE: the
    pool's page axis is sharded over the mesh's "kv" axis
    (``kv_pool.PagedKVPool(mesh=)`` — each device stores 1/kv of the
    pages) and the five step functions are swapped for their
    ``models.transformer.sharded_step_fns`` shard_map lowerings
    (kv-heads split over "model", exact all_gathers at the attention
    boundary — no psum). The host-side scheduling logic, the per-slot
    sampling lanes and the compiled-shape accounting are UNTOUCHED, and
    greedy token streams stay bit-identical to the single-device
    scheduler (``tests/test_sharded_serving.py``)."""

    def __init__(self, cfg: ArchConfig, params,
                 opts: RuntimeOpts = RuntimeOpts(),
                 *, num_pages: int = 128, page_size: int = DEFAULT_PAGE_SIZE,
                 max_slots: int = 4, max_seq_len: int | None = None,
                 lazy_growth: bool = False, resume: str = "swap",
                 prefill_mode: str = "chunked",
                 prefill_chunk: int | str | tuple = 256,
                 preempt_cooldown: int = 1, tick_mode: str | None = None,
                 token_budget: int | None = None, speculate_k: int = 0,
                 auto_prefix: bool = False, auto_prefix_min: int = 8,
                 auto_prefix_window: int = 16, telemetry=None, mesh=None):
        if resume not in ("swap", "refill"):
            raise ValueError(f"resume must be 'swap' or 'refill', got {resume}")
        if prefill_mode not in ("chunked", "wave"):
            raise ValueError(
                f"prefill_mode must be 'chunked' or 'wave', got {prefill_mode}")
        if tick_mode is None:
            tick_mode = prefill_mode
        if tick_mode not in ("packed", "chunked", "wave"):
            raise ValueError(f"tick_mode must be 'packed', 'chunked' or "
                             f"'wave', got {tick_mode}")
        if tick_mode != "packed":
            prefill_mode = tick_mode
        if prefill_chunk == "auto":
            ladder = AUTO_CHUNK_LADDER
        elif isinstance(prefill_chunk, (tuple, list)):
            ladder = tuple(sorted({int(c) for c in prefill_chunk}))
        else:
            ladder = (int(prefill_chunk),)
        if not ladder or min(ladder) < 1:
            raise ValueError(
                f"prefill_chunk sizes must be >= 1, got {prefill_chunk!r}")
        if speculate_k < 0:
            raise ValueError(f"speculate_k must be >= 0, got {speculate_k}")
        self.cfg, self.params, self.opts = cfg, params, opts
        self.mesh = mesh
        self.pool = PagedKVPool(cfg, num_pages=num_pages, page_size=page_size,
                                max_requests=max_slots, max_seq_len=max_seq_len,
                                mesh=mesh)
        self.max_slots = max_slots
        self.lazy_growth = lazy_growth
        self.resume = resume
        self.prefill_mode = prefill_mode
        self.tick_mode = tick_mode
        # no prompt can exceed the block table's reach, so neither need a chunk
        reach = self.pool.max_blocks * page_size
        self._chunk_ladder = tuple(sorted({min(c, reach) for c in ladder}))
        self.prefill_chunk = self._chunk_ladder[-1]
        self.speculate_k = int(speculate_k)
        if token_budget is None:
            token_budget = self.prefill_chunk + max_slots
        # every decoding slot needs a row, plus >= 1 for prefill progress
        self.token_budget = max(int(token_budget), max_slots + 1)
        self.preempt_cooldown = preempt_cooldown
        # telemetry.Tracer | None — every instrumentation site below is
        # guarded on it, so the disabled path never calls the tracer (and
        # never forces a device sync): telemetry=None is a strict no-op
        self.telemetry = telemetry
        # the preempt/resume page mover — all swap spans + byte accounting
        # flow through the unified transport layer (page_transport)
        self._swap = HostSwapTransport(telemetry=telemetry)
        self._tick = 0
        self._shapes: set = set()  # distinct jitted call shapes dispatched
        self.queue: deque = deque()
        self.slots: list = [None] * max_slots
        self.results: dict = {}
        self.finish_reasons: dict = {}  # rid → "stop" | "length" | "abort"
        self.stats = SchedulerStats()
        self._prefixes: dict = {}
        self._next_rid = 0
        self._admit_seq = 0
        # automatic prefix detection (auto_prefix=True): submits with no
        # explicit prefix_key are longest-common-prefix matched against the
        # last `auto_prefix_window` prompts and against already-registered
        # auto prefixes; a match of >= auto_prefix_min tokens mints/joins a
        # shared prefix through the ordinary CoW fork machinery
        self.auto_prefix = bool(auto_prefix)
        self.auto_prefix_min = max(1, int(auto_prefix_min))
        self._recent_reqs: deque = deque(maxlen=max(1, int(auto_prefix_window)))
        self._auto_keys: set = set()
        self._auto_seq = 0
        # per-token streaming events (rid, token_index, token) in emission
        # order, and rids finished since the last drain — both consumed by
        # serving.api.LLMServer; a long-lived driver reads the finished
        # QUEUE instead of rescanning the whole results dict per tick.
        # THREAD MODEL: the scheduler is single-driver — submit/abort/step
        # mutate pool and slot state and must all run on ONE thread (the
        # async front end's tick thread marshals everything there; the
        # step() re-entry guard below turns a violation into a loud
        # RuntimeError instead of corrupted block tables). The two drain
        # surfaces are the exception: _emit_lock makes event/finished
        # APPENDS atomic with the drain swap, so drain_events() /
        # drain_finished() may be called from any thread, each by a single
        # consumer (a drained event exists exactly once — two competing
        # consumers would each see a disjoint, useless half of the stream)
        self._events: list = []
        self._finished: list = []
        self._emit_lock = threading.Lock()
        self._step_guard = threading.Lock()
        # per-slot sampling operands, updated at admit/evict so every tick
        # ships the SAME (max_slots,)-shaped arrays — per-request sampling
        # without per-request compiles. Freed rows reset to greedy.
        self._op_keys = np.zeros((max_slots, 2), np.uint32)
        self._op_temp = np.zeros((max_slots,), np.float32)
        self._op_topk = np.zeros((max_slots,), np.int32)
        self._op_topp = np.ones((max_slots,), np.float32)
        # dense per-slot logit-bias rows (SamplingParams.logit_bias) — an
        # all-zero row is the bitwise identity, so bias-free slots ride the
        # same compiled shape untouched
        self._op_bias = np.zeros((max_slots, cfg.vocab_size), np.float32)
        # device-resident copy, rebuilt lazily after _set_ops/_reset_ops —
        # the hot decode tick must not re-upload unchanged operands
        self._dev_ops: tuple | None = None
        if mesh is not None:
            # shard_map lowerings of the five step fns — same signatures,
            # so the jitted tick wrappers below are shared verbatim
            sf = sharded_step_fns(cfg, opts, mesh)
            prefill_fn, prefill_shared_fn = sf["prefill"], sf["prefill_shared"]
            decode_fn, packed_fn, verify_fn = (sf["decode"], sf["packed"],
                                               sf["verify"])
        else:
            prefill_fn = lambda params, tokens, caches, positions: \
                paged_prefill(params, cfg, tokens, caches, positions, opts)
            prefill_shared_fn = lambda params, tokens, caches, positions: \
                paged_prefill_shared(params, cfg, tokens, caches, positions,
                                     opts)
            decode_fn = lambda params, tokens, caches, pos: \
                paged_decode_step(params, cfg, tokens, caches, pos, opts)
            packed_fn = lambda params, tokens, caches, positions, slots, \
                logit_rows, quant_fresh: \
                packed_step(params, cfg, tokens, caches, positions, slots,
                            logit_rows, opts, quant_fresh)
            verify_fn = lambda params, tokens, caches, positions: \
                paged_verify_step(params, cfg, tokens, caches, positions,
                                  opts)
        self._prefill = jax.jit(prefill_fn)
        self._prefill_shared = jax.jit(prefill_shared_fn)

        def decode_sample(params, tokens, caches, pos, keys, t, temp, tk, tp,
                          bias):
            # decode + sampling as ONE jitted function: logits never leave
            # the device — only the sampled token ids (and their logprobs)
            # cross to the host
            logits, new_caches = decode_fn(params, tokens, caches, pos)
            toks, lps = sample_tokens_with_logprobs(logits, keys, t,
                                                    temp, tk, tp, bias)
            return toks, lps, new_caches

        self._decode = jax.jit(decode_sample)

        def packed_sample(params, tokens, caches, positions, slots,
                          logit_rows, quant_fresh, keys, t, temp, tk, tp,
                          bias):
            # the whole packed tick as ONE jitted function: embed → varlen
            # attention over the int8 pages → per-slot sampling lanes.
            # quant_fresh marks the buffer's DECODE rows: their fresh
            # self-keys round-trip through the int8 quantizer so they
            # attend the same values a sequential decode step reads back
            # from the pool (bit-identity with the chunked/wave ticks)
            logits, new_caches = packed_fn(params, tokens, caches, positions,
                                           slots, logit_rows, quant_fresh)
            toks, lps = sample_tokens_with_logprobs(logits, keys, t,
                                                    temp, tk, tp, bias)
            return toks, lps, new_caches

        self._packed = jax.jit(packed_sample)
        self._sample = jax.jit(sample_tokens_with_logprobs)

        def sample_rows(logits, rows, keys, t, temp, tk, tp, bias):
            # wave-mode prefill samples a SUBSET of slot rows: gather the
            # rows' lanes from the cached full-slot operands on device
            # instead of rebuilding (R_adm,)-shaped host arrays per call
            return sample_tokens_with_logprobs(
                logits, keys[rows], t, temp[rows], tk[rows], tp[rows],
                bias[rows])

        self._sample_rows = jax.jit(sample_rows)

        def verify_sample(params, tokens, caches, positions, gather, draft,
                          draft_len, keys, t0, temp, tk, tp, bias):
            # speculative tick (every tick mode): one multi-token verify
            # through the pool, logits realigned from the right-aligned call layout
            # to generation-index order, then draft acceptance — all ONE
            # jitted function; only accepted tokens cross to the host
            logits, new_caches = verify_fn(params, tokens, caches, positions)
            logits = jnp.take_along_axis(logits, gather[:, :, None], axis=1)
            out, n, lps = speculative_verify(draft, draft_len, logits,
                                             keys, t0, temp, tk, tp, bias)
            return out, n, lps, new_caches

        self._verify = jax.jit(verify_sample)

    # -------------------------------------------------------------- intake

    def submit(self, prompt, max_new_tokens: int | None = None,
               eos_id: int | None = None,
               *, prefix_key=None, prefix_len: int | None = None,
               priority: int | None = None,
               sampling: SamplingParams | None = None) -> int:
        """Enqueue a request; returns its rid.

        ``sampling`` carries every per-request knob of the serving API
        (``core.sampling.SamplingParams``): max_tokens, temperature /
        top-k / top-p / seed (the on-device per-slot sampler operands),
        the stop-token set, priority, prefix declaration and latency
        hint. When given, it is the single source of truth and the legacy
        positional arguments must be omitted. The legacy form
        ``submit(prompt, max_new_tokens, eos_id, prefix_key=, ...)``
        keeps working — it builds greedy ``SamplingParams`` internally.

        ``prefix_key`` (any hashable) declares that this prompt's first
        ``prefix_len`` TOKENS are shared verbatim with every other request
        carrying the same key (a system prompt, a beam stem): the prefix is
        prefilled once and later requests attach to its pages. The key's
        FIRST submit fixes the shared length (pass ``prefix_len``
        explicitly there — it defaults to that whole prompt minus one
        token); later submits inherit the registered length, so they may
        omit ``prefix_len``. The shared length is capped at
        ``len(prompt) - 1`` (at least one suffix token must prefill to
        produce the request's first logits) and must match token-for-token
        across the key's requests. ``priority`` orders preemption victims
        in lazy mode (lower evicts first)."""
        if sampling is None:
            if max_new_tokens is None:
                raise ValueError("submit needs max_new_tokens or sampling=")
            sampling = SamplingParams(
                max_tokens=int(max_new_tokens), eos_id=eos_id,
                priority=priority or 0, prefix_key=prefix_key,
                prefix_len=prefix_len)
            priority = sampling.priority
        elif any(a is not None for a in (max_new_tokens, eos_id, prefix_key,
                                         prefix_len, priority)):
            raise ValueError(
                "pass either sampling= or the legacy arguments, not both — "
                "sampling is the single source of truth when given")
        else:
            prefix_key = sampling.prefix_key
            prefix_len = sampling.prefix_len
            priority = sampling.priority
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        assert prompt.size >= 1 and sampling.max_tokens >= 1
        if prefix_key is None and self.auto_prefix:
            prefix_key, prefix_len = self._detect_auto_prefix(prompt)
        rid = self._next_rid
        self._next_rid += 1
        req = Request(rid, prompt, sampling.max_tokens, sampling.eos_id,
                      priority=priority, sampling=sampling,
                      submit_tick=self._tick)
        if prefix_key is not None:
            entry = self._prefixes.get(prefix_key)
            if prefix_len is not None:
                plen = int(prefix_len)
            elif entry is not None:
                plen = int(entry.tokens.size)  # inherit the key's length
            else:
                plen = prompt.size - 1
            plen = min(plen, prompt.size - 1)
            if plen >= 1:
                if entry is None:
                    entry = _PrefixEntry(prefix_key, prompt[:plen].copy())
                    self._prefixes[prefix_key] = entry
                else:
                    if entry.tokens.size != plen or not np.array_equal(
                            entry.tokens, prompt[:plen]):
                        raise ValueError(
                            f"prefix_key {prefix_key!r}: request {rid}'s "
                            f"declared {plen}-token prefix does not match "
                            f"the registered {entry.tokens.size}-token one")
                req.prefix_key = prefix_key
        if self.auto_prefix:
            self._recent_reqs.append(req)
        self.queue.append(req)
        if self.telemetry is not None:
            self.telemetry.request_submitted(rid)
        return rid

    @staticmethod
    def _lcp(a: np.ndarray, b: np.ndarray) -> int:
        """Length of the longest common prefix of two token sequences."""
        n = min(a.size, b.size)
        neq = np.nonzero(a[:n] != b[:n])[0]
        return int(neq[0]) if neq.size else n

    def _detect_auto_prefix(self, prompt: np.ndarray) -> tuple:
        """Automatic prefix detection (``auto_prefix=True``): find the
        longest shared prefix of >= ``auto_prefix_min`` tokens between
        this prompt and (a) any already-registered auto prefix or (b) any
        of the last ``auto_prefix_window`` submitted prompts, and return
        the ``(prefix_key, prefix_len)`` to attach with — HTTP clients
        get CoW prefix-page sharing without ever naming a ``prefix_key``.

        Matching an existing auto prefix simply joins it (the ordinary
        fork path). A longer match against a RECENT raw prompt mints a
        new auto key covering the common prefix; when that earlier
        request is still QUEUED and keyless it is retroactively attached,
        so the FIFO-first of the pair materializes the prefix and the
        later one forks — first-pair sharing, not just third-request-on.
        Both lengths are capped at each prompt's size - 1 (at least one
        suffix token must prefill to produce first logits). Returns
        ``(None, None)`` when nothing clears the threshold."""
        best_key, best_len = None, 0
        for key in self._auto_keys:
            entry = self._prefixes.get(key)
            if entry is None:
                continue
            plen = int(entry.tokens.size)
            if (plen > best_len and plen <= prompt.size - 1
                    and np.array_equal(entry.tokens, prompt[:plen])):
                best_key, best_len = key, plen
        best_req, best_req_len = None, best_len
        for other in self._recent_reqs:
            lcp = min(self._lcp(prompt, other.prompt),
                      prompt.size - 1, other.prompt.size - 1)
            if lcp > best_req_len:
                best_req, best_req_len = other, lcp
        if best_req is not None and best_req_len >= self.auto_prefix_min:
            self._auto_seq += 1
            key = ("auto_prefix", self._auto_seq)
            self._auto_keys.add(key)
            self._prefixes[key] = _PrefixEntry(
                key, prompt[:best_req_len].copy())
            if best_req.prefix_key is None and best_req in self.queue:
                best_req.prefix_key = key  # FIFO-first becomes the creator
            self.stats.auto_prefix_hits += 1
            return key, best_req_len
        if best_key is not None and best_len >= self.auto_prefix_min:
            self.stats.auto_prefix_hits += 1
            return best_key, best_len
        return None, None

    def release_prefixes(self) -> None:
        """Release every pinned shared prefix (their pages return to the
        free list once the last attached request finishes) and prune
        registry entries no queued or running request still names — a
        long-running scheduler must not accumulate one entry per
        prefix_key ever submitted. ``run`` calls this after draining;
        streaming users call it when a prefix's tenancy ends."""
        for entry in self._prefixes.values():
            if entry.handle is not None:
                self.pool.release_prefix(entry.handle)
                entry.handle = None
                entry.creator_rid = None
        live = {r.prefix_key for r in self.queue} | {
            st.req.prefix_key for st in self.slots if st is not None}
        self._prefixes = {k: e for k, e in self._prefixes.items()
                          if k in live}
        self._auto_keys &= set(self._prefixes)

    def abort(self, rid: int) -> bool:
        """Cancel a request wherever it currently is — queued (including
        preempted-and-swapped), mid-prefill, or decoding. The partial
        result (prompt + tokens emitted so far) is recorded with finish
        reason ``"abort"``; a live slot's pages return to the pool this
        call. Returns False when the rid is unknown or already finished
        (finished results are never retracted)."""
        for req in self.queue:
            if req.rid != rid:
                continue
            if req.snapshot is not None:
                self.pool.discard_snapshot(req.snapshot)
                req.snapshot = None
            self.queue.remove(req)
            self._finish_abort(req, req.generated)
            return True
        for i, st in enumerate(self.slots):
            if st is None or st.req.rid != rid:
                continue
            self.pool.free(i)
            self.slots[i] = None
            self._reset_ops(i)
            self._finish_abort(st.req, st.generated, track=f"slot{i}")
            return True
        return False

    def _finish_abort(self, req: Request, generated: list,
                      track: str = "queue") -> None:
        # an aborted prefix CREATOR must not strand waiting forks: clear
        # the claim so the next same-key admission materializes the prefix
        entry = self._prefixes.get(req.prefix_key) \
            if req.prefix_key is not None else None
        if entry is not None and entry.creator_rid == req.rid:
            entry.creator_rid = None
        self.results[req.rid] = np.concatenate(
            [req.prompt, np.asarray(generated, np.int32)])
        self.finish_reasons[req.rid] = "abort"
        self._mark_finished(req.rid)
        self.stats.aborted += 1
        if self.telemetry is not None:
            self.telemetry.request_finished(req.rid, track, "abort",
                                            len(generated))

    def extract(self, rid: int) -> Request | None:
        """Detach a RUNNING request from its slot and return it carrying a
        host snapshot of every page position it has WRITTEN — the
        prefill→decode handoff of the disaggregated deployment
        (``serving.page_transport``). The snapshot machinery is exactly
        the swap-preemption export (``kv_pool.export_slot``), so a request
        re-injected into ANOTHER scheduler's queue (:meth:`inject`)
        resumes its decode bit-identically — same guarantee as a
        preempt-and-resume on one scheduler. The slot and its pages free
        immediately; already-emitted tokens ride along in
        ``req.generated`` and are never re-sampled. Returns None when the
        rid is not currently in a slot (queued/finished requests are not
        extractable)."""
        for i, st in enumerate(self.slots):
            if st is None or st.req.rid != rid:
                continue
            st.req.generated = list(st.generated)
            # snapshot only WRITTEN positions: the last generated token is
            # the next decode input, not yet in the pool (same accounting
            # as the swap-preemption export)
            if st.generated:
                written = len(st.req.prompt) + len(st.generated) - 1
            else:
                written = st.prefilled
            st.req.snapshot = self.pool.export_slot(i, n_tokens=written)
            self.pool.free(i)
            self.slots[i] = None
            self._reset_ops(i)
            if self.telemetry is not None:
                self.telemetry.event("extract", track=f"slot{i}", rid=rid,
                                     tokens=written)
            return st.req
        return None

    def inject(self, req: Request) -> None:
        """Enqueue a :class:`Request` EXTRACTED from another scheduler
        (snapshot and generated tokens intact) — the decode-replica side
        of the disaggregated handoff. The next admission wave restores the
        snapshot through the ordinary swap-resume path. The caller owns
        rid uniqueness: injected rids come from the extracting scheduler,
        so a scheduler that both ``submit``s and ``inject``s must keep the
        two rid spaces disjoint (``serving.page_transport`` does)."""
        self.queue.append(req)
        if self.telemetry is not None:
            self.telemetry.request_submitted(req.rid)

    def _emit_event(self, rid: int, idx: int, tok: int, lp: float) -> None:
        """Append one streamed-token event atomically w.r.t. the drain
        swap — the tick thread may be mid-step while another thread calls
        ``drain_events``; without the lock an append racing the swap can
        land in the already-drained list and vanish."""
        with self._emit_lock:
            self._events.append((rid, idx, tok, lp))

    def _mark_finished(self, rid: int) -> None:
        with self._emit_lock:
            self._finished.append(rid)

    def drain_events(self) -> list:
        """Return and clear the per-token events emitted since the last
        call: ``(rid, token_index, token, logprob)`` tuples in emission
        order — position order per request, interleaved across requests.
        ``logprob`` is the token's log-probability under the row's raw
        model distribution (``core.sampling.token_logprobs``).

        SINGLE-CONSUMER: safe to call from a thread other than the one
        driving ``step()`` (the swap is atomic with event appends), but
        only ONE consumer may drain — each event is returned exactly once,
        so two competing drainers would each see a useless interleaved
        half of every request's stream."""
        with self._emit_lock:
            ev, self._events = self._events, []
        return ev

    def drain_finished(self) -> list:
        """Return and clear the rids that finished (evicted or aborted)
        since the last call — O(newly finished), however many results a
        long-running scheduler retains. Same single-consumer contract as
        :meth:`drain_events`."""
        with self._emit_lock:
            f, self._finished = self._finished, []
        return f

    # ------------------------------------------------------------ lifecycle

    def _set_ops(self, slot: int, req: Request) -> None:
        """Install the request's sampling operands in its slot row. A
        WRITE happens only when the row's values actually change (slot
        membership or per-request params): re-admitting the same request
        after a swap, or a greedy request landing in a greedy-reset row,
        keeps the uploaded device copy valid — steady-state ticks ship the
        SAME device arrays with zero host work."""
        sp = req.sampling
        key = np.asarray(jax.random.PRNGKey(sp.seed), np.uint32)
        row = (key, np.float32(sp.temperature), np.int32(sp.top_k),
               np.float32(sp.top_p))
        brow = bias_rows([sp], self._op_bias.shape[1])[0] \
            if sp.logit_bias else None
        if (np.array_equal(self._op_keys[slot], key)
                and self._op_temp[slot] == row[1]
                and self._op_topk[slot] == row[2]
                and self._op_topp[slot] == row[3]
                and (not self._op_bias[slot].any() if brow is None
                     else np.array_equal(self._op_bias[slot], brow))):
            return
        (self._op_keys[slot], self._op_temp[slot], self._op_topk[slot],
         self._op_topp[slot]) = row
        self._op_bias[slot] = 0.0 if brow is None else brow
        self._dev_ops = None

    def _reset_ops(self, slot: int) -> None:
        if (self._op_temp[slot] == 0.0 and self._op_topk[slot] == 0
                and self._op_topp[slot] == 1.0
                and not self._op_keys[slot].any()
                and not self._op_bias[slot].any()):
            return  # already the greedy reset row — keep the device copy
        self._op_keys[slot] = 0
        self._op_temp[slot] = 0.0
        self._op_topk[slot] = 0
        self._op_topp[slot] = 1.0
        self._op_bias[slot] = 0.0
        self._dev_ops = None

    def _device_ops(self) -> tuple:
        """(keys, temperature, top_k, top_p, bias) for ALL slot rows,
        uploaded once per operand change rather than once per tick."""
        if self._dev_ops is None:
            self._dev_ops = (jnp.asarray(self._op_keys),
                             jnp.asarray(self._op_temp),
                             jnp.asarray(self._op_topk),
                             jnp.asarray(self._op_topp),
                             jnp.asarray(self._op_bias))
        return self._dev_ops

    def _register_shape(self, *shape) -> None:
        """Track every distinct jitted call shape the scheduler dispatches —
        ``stats.compiled_shapes`` is the compile-count the chunked mode
        exists to bound."""
        new = shape not in self._shapes
        self._shapes.add(shape)
        self.stats.compiled_shapes = len(self._shapes)
        if self.telemetry is not None:
            self.telemetry.shape_dispatch(new)

    def _admission_target(self, req: Request) -> int:
        """TOKENS the admission must cover. Reserve mode: the request's
        worst-case final length. Lazy mode: the (re-)prefill/restore length
        plus ONE decode token of headroom (capped at the final written
        length), so an admitted request always decodes at least one token
        before it can be preempted — the liveness guarantee. A swap
        snapshot never holds MORE than the (re-)prefill length (a
        mid-prefill victim holds less — its remaining chunks must still
        fit), so the prefill length covers both admission paths."""
        final = len(req.prompt) + req.max_new_tokens
        if not self.lazy_growth:
            return final
        held = len(req.prefill_tokens)
        # final - 1: the last sampled token is emitted, never written back
        return min(held + 1, final - 1)

    def _admit_wave(self) -> tuple:
        """Admit queue heads while a slot row and their admission pages fit.
        FIFO: a too-big head blocks the queue (no starvation-prone
        skipping), and a head whose shared prefix is still being prefilled
        by its creator waits one wave, then forks. A freshly PREEMPTED head
        additionally waits out its anti-thrash cooldown while its preemptor
        (or any other slot) runs — re-admitting it on the very next tick
        would only re-provoke the same exhaustion and evict it again, a
        swap storm that makes no progress; with every slot idle the
        cooldown is moot and is ignored. Returns (slots needing a prefill,
        slots restored from a swap snapshot)."""
        admitted, restored = [], []
        while self.queue:
            req = self.queue[0]
            if (req.cooldown_until > self._tick
                    and any(st is not None for st in self.slots)):
                break
            handle, entry = None, None
            if req.snapshot is None and req.prefix_key is not None:
                entry = self._prefixes.get(req.prefix_key)
                if entry is not None:
                    if entry.handle is not None:
                        handle = entry.handle
                    elif entry.creator_rid is not None:
                        break  # creator's prefill lands next wave; wait
            target = self._admission_target(req)
            if not self.pool.can_admit(target, prefix=handle):
                break
            tel = self.telemetry
            # swap resume carries a snapshot; refill resume carries only
            # its already-generated tokens — both re-admissions
            resumed = req.snapshot is not None or bool(req.generated)
            if req.snapshot is not None:
                slot = self._swap.swap_in(self.pool, req.snapshot,
                                          reserve_tokens=target, rid=req.rid)
                req.snapshot = None
                restored.append(slot)
            else:
                slot = self.pool.admit(len(req.prefill_tokens),
                                       reserve_tokens=target, prefix=handle)
                if handle is not None:
                    entry.forks += 1
                    self.stats.prefix_forks += 1
                elif entry is not None:
                    entry.creator_rid = req.rid
                admitted.append(slot)
            self.queue.popleft()
            # the pool length at admission = tokens already resident (0,
            # a shared prefix, or a restored snapshot — which for a victim
            # evicted mid-prefill is less than its prompt: it resumes
            # CHUNKING right where it left off)
            self.slots[slot] = _SlotState(req, list(req.generated),
                                          self._admit_seq,
                                          prefilled=int(self.pool.lengths[slot]))
            self._set_ops(slot, req)
            self._admit_seq += 1
            if tel is not None:
                tel.request_admitted(req.rid, slot, resumed=resumed)
        return admitted, restored

    def _record_first_token(self, st: _SlotState, slot: int, token: int,
                            logprob: float) -> None:
        """Seed the slot's first sampled token (resumed requests keep their
        already-emitted tokens — the last one is the next decode input, not
        a fresh sample) and record its TTFT."""
        if not st.generated:
            st.generated.append(token)
            self._emit_event(st.req.rid, 0, token, logprob)
            self.stats.ttft_ticks.setdefault(
                st.req.rid, self._tick - st.req.submit_tick)
            if self.telemetry is not None:
                self.telemetry.first_token(
                    st.req.rid, f"slot{slot}",
                    ttft_ticks=self._tick - st.req.submit_tick)

    def _maybe_pin_prefix(self, st: _SlotState, slot: int) -> None:
        """Pin the shared prefix once its creator has WRITTEN the covered
        tokens — under chunked prefill that can be mid-prompt, so waiting
        forks admit as soon as the prefix pages exist, not only after the
        creator's whole (possibly much longer) prompt lands."""
        entry = self._prefixes.get(st.req.prefix_key) \
            if st.req.prefix_key is not None else None
        if entry is not None and entry.handle is None \
                and entry.creator_rid == st.req.rid \
                and st.prefilled >= entry.tokens.size:
            entry.handle = self.pool.share_prefix(slot, entry.tokens.size)
            entry.creator_rid = None

    def _prefill_wave(self, admitted: list) -> None:
        """One ragged right-aligned prefill over the admitted rows; the last
        column is every row's final prompt token → first sampled token.
        Forked rows carry only their SUFFIX (positions from prefix_len) and
        attend the shared pages through ``paged_prefill_shared``."""
        toks = [self.slots[s].req.prefill_tokens for s in admitted]
        starts = [int(self.pool.lengths[s]) for s in admitted]  # 0 or prefix
        lens = [t.size - st for t, st in zip(toks, starts)]  # suffix lengths
        s_pad = _bucket(max(lens))
        r = len(admitted)
        tokens = np.zeros((r, s_pad), np.int32)
        posn = np.full((r, s_pad), -1, np.int32)
        for i, slot in enumerate(admitted):
            suffix = toks[i][starts[i]:]
            tokens[i, s_pad - suffix.size:] = suffix
            posn[i, s_pad - suffix.size:] = np.arange(starts[i], toks[i].size)
        shared = any(st > 0 for st in starts)
        fn = self._prefill_shared if shared else self._prefill
        self._register_shape("prefill_shared" if shared else "prefill",
                             r, s_pad)
        tel = self.telemetry
        t0 = tel.now() if tel is not None else 0.0
        logits, new_caches = fn(
            self.params, jnp.asarray(tokens),
            caches=self.pool.device_caches(rows=admitted),
            positions=jnp.asarray(posn))
        if tel is not None:
            jax.block_until_ready(logits)  # honest phase timing; values
            t1 = tel.now()                 # are untouched (bit-identity)
        self.pool.update_from(new_caches)
        first, first_lp = self._sample_first(logits, admitted)
        for i, slot in enumerate(admitted):
            st = self.slots[slot]
            self.pool.commit_prefill(slot, int(toks[i].size))
            st.prefilled = int(toks[i].size)
            if tel is not None:
                tel.add_span("prefill", t0, t1, track=f"slot{slot}",
                             rid=st.req.rid, tokens=lens[i], stage="wave")
            self._record_first_token(st, slot, int(first[i]),
                                     float(first_lp[i]))
            self._maybe_pin_prefix(st, slot)
        self.stats.prefills += 1
        self.stats.prefill_tokens += sum(lens)
        self.stats.admitted += r

    def _sample_first(self, logits, rows: list | None) -> tuple:
        """Sample each row's FIRST token (generation index 0) from prefill
        logits with its own sampling operands — same device sampler, same
        per-request PRNG lane as the decode tick, so a request's stream is
        seamless across the prefill→decode boundary. ``rows`` are the slot
        indices matching ``logits``'s rows (``None`` = all slots; a subset
        gathers its rows' lanes from the same cached device operands —
        the per-slot arrays are never rebuilt host-side per call); rows
        that didn't finish their prompt this call simply discard the
        sample. Returns (tokens, logprobs) as host arrays."""
        keys, temp, tk, tp, bias = self._device_ops()
        if rows is None:
            toks, lps = self._sample(logits, keys,
                                     jnp.zeros((self.max_slots,), jnp.int32),
                                     temp, tk, tp, bias)
        else:
            toks, lps = self._sample_rows(
                logits, jnp.asarray(np.asarray(rows, np.int32)), keys,
                jnp.zeros((len(rows),), jnp.int32), temp, tk, tp, bias)
        return np.asarray(toks), np.asarray(lps)

    def _pick_chunk(self) -> int:
        """The tick's prefill chunk size. Fixed ladder of one → that size.
        Adaptive (``prefill_chunk="auto"`` or an explicit ladder): shrink
        when decode slots dominate the batch — every decoding request pays
        the chunk call's latency this tick — or when any decoding request
        hints ``latency_hint="interactive"``; grow when the batch is
        prefill-heavy and nobody decoding objects (throughput); middle
        rung when balanced. Each rung is one compiled shape, so the
        compile count stays bounded by the ladder length."""
        ladder = self._chunk_ladder
        if len(ladder) == 1:
            return ladder[0]
        decoding = [st for st in self.slots
                    if st is not None and not st.prefilling and not st.done]
        n_pre = sum(1 for st in self.slots
                    if st is not None and st.prefilling)
        if decoding and any(st.req.sampling.latency_hint == "interactive"
                            for st in decoding):
            c = ladder[0]
        elif len(decoding) > n_pre:
            c = ladder[0]
        elif n_pre > len(decoding):
            c = ladder[-1]
        else:
            c = ladder[len(ladder) // 2]
        self.stats.auto_chunks[c] = self.stats.auto_chunks.get(c, 0) + 1
        return c

    def _prefill_chunk_tick(self) -> bool:
        """Advance every mid-prefill slot by ONE ``prefill_chunk``-token
        chunk through a FIXED-shape ``(max_slots, chunk)`` call — rows with
        nothing pending ride along fully padded (their writes trash-route,
        their attention masks out), so one compiled shape serves every
        admission state and the tick's latency is bounded by the chunk.

        First chunks (nothing of the request in the pool yet) keep the
        plain fresh-only attention path — the same math as ``Engine``'s
        prefill. Continuation chunks and prefix forks attend their pool
        history through the Pallas page-walk kernel
        (``models.layers.paged_prefill_attention``) — int8 in place,
        exactly what their decode steps will read. A chunk whose last
        token completes the prompt yields the row's first sampled token
        from the call's last column."""
        rows = [i for i, st in enumerate(self.slots)
                if st is not None and st.prefilling]
        if not rows:
            return False
        c = self._pick_chunk()
        fresh = [i for i in rows if int(self.pool.lengths[i]) == 0]
        cont = [i for i in rows if int(self.pool.lengths[i]) > 0]
        for group, fn, kind in ((fresh, self._prefill, "chunk"),
                                (cont, self._prefill_shared, "chunk_shared")):
            if not group:
                continue
            tokens = np.zeros((self.max_slots, c), np.int32)
            posn = np.full((self.max_slots, c), -1, np.int32)
            ends = {}
            for i in group:
                st = self.slots[i]
                toks = st.req.prefill_tokens
                lo = st.prefilled
                hi = min(lo + c, toks.size)
                chunk = toks[lo:hi]
                tokens[i, c - chunk.size:] = chunk
                posn[i, c - chunk.size:] = np.arange(lo, hi)
                ends[i] = (hi, toks.size)
            self._register_shape(kind, self.max_slots, c)
            tel = self.telemetry
            t0 = tel.now() if tel is not None else 0.0
            logits, new_caches = fn(
                self.params, jnp.asarray(tokens),
                caches=self.pool.device_caches(),
                positions=jnp.asarray(posn))
            if tel is not None:
                jax.block_until_ready(logits)
                t1 = tel.now()
            self.pool.update_from(new_caches)
            # only dispatch the sampler on ticks where some row actually
            # completes its prompt — mid-prompt chunks discard the sample
            first, first_lp = self._sample_first(logits, None) \
                if any(hi == total for hi, total in ends.values()) \
                else (None, None)
            for i in group:
                st = self.slots[i]
                hi, total = ends[i]
                self.pool.commit_prefill(i, hi)
                chunk_tokens = hi - st.prefilled
                st.prefilled = hi
                self.stats.prefill_chunks += 1
                self.stats.prefill_tokens += chunk_tokens
                if tel is not None:
                    tel.add_span("prefill", t0, t1, track=f"slot{i}",
                                 rid=st.req.rid, tokens=chunk_tokens,
                                 stage=kind, done=hi == total)
                self._maybe_pin_prefix(st, i)
                if hi == total:  # prompt complete → first token
                    self._record_first_token(st, i, int(first[i]),
                                             float(first_lp[i]))
            self.stats.prefills += 1
        return True

    def _release_idle_prefix(self) -> bool:
        """Unpin one materialized prefix whose pages nobody but the handle
        references (refcount 1 everywhere — e.g. every attached request
        finished, or was preempted and will resume from its own swap
        snapshot) — the cheapest way to make room before preempting live
        work. A later same-key request simply re-creates the prefix."""
        for entry in self._prefixes.values():
            if entry.handle is None:
                continue
            if any(self.pool.refcount[p] > 1 for p in entry.handle.pages):
                continue  # a live slot still reads these pages
            self.pool.release_prefix(entry.handle)
            entry.handle = None
            entry.creator_rid = None
            return True
        return False

    def _preempt_one(self, requester: int) -> bool:
        """Evict the lowest-priority (ties: most recently admitted) running
        request back to the queue head with its generated tokens; its pages
        are freed for ``requester``'s growth. Refuses (returns False) when
        the only candidate is the requester itself AND no idle prefix can
        be released — then the pool is simply too small for the request and
        the caller must fail loudly rather than thrash."""
        if self._release_idle_prefix():
            return True
        cands = [(st.req.priority, -st.seq, i)
                 for i, st in enumerate(self.slots) if st is not None]
        if not cands:
            return False
        victim = min(cands)[2]
        if victim == requester and len(cands) == 1:
            return False
        st = self.slots[victim]
        st.req.generated = list(st.generated)
        # anti-thrash: the victim re-queues but is not re-admitted before
        # its cooldown elapses while other slots run (see _admit_wave)
        st.req.cooldown_until = self._tick + 1 + self.preempt_cooldown
        tel = self.telemetry
        if tel is not None:
            tel.span_end(("decode", st.req.rid), outcome="preempt")
            tel.event("preempt", track=f"slot{victim}", rid=st.req.rid,
                      reason="pool_exhausted", resume=self.resume)
            tel.metrics.count("scheduler.preemptions")
        if self.resume == "swap":
            # snapshot only positions actually WRITTEN: the victim may have
            # run its speculative append this very tick (its pending token
            # was never decoded, so its position holds no KV yet) — the
            # accounted length would bake a permanent hole into the restore.
            # A victim still mid-prefill has written exactly its chunks so
            # far; its restore resumes chunking from there
            if st.generated:
                written = len(st.req.prompt) + len(st.generated) - 1
            else:
                written = st.prefilled
            st.req.snapshot = self._swap.swap_out(self.pool, victim,
                                                  n_tokens=written,
                                                  rid=st.req.rid)
            self.stats.peak_swap_bytes = max(self.stats.peak_swap_bytes,
                                             self.pool.swap_bytes)
        self.pool.free(victim)
        self.slots[victim] = None
        self._reset_ops(victim)
        self.queue.appendleft(st.req)
        self.stats.preemptions += 1
        if tel is not None:
            tel.request_requeued(st.req.rid, reason="preempt")
        return True

    def _draft_plan(self) -> dict:
        """Propose this tick's draft burst per decoding slot: ``{slot:
        drafts (kd,) int32}`` with ``kd <= speculate_k``, empty when
        speculation is off. Each slot's cap is the scheduler-wide
        ``speculate_k`` (the compiled verify width), optionally lowered by
        the request's own ``SamplingParams.speculate_k``, and always
        bounded by the tokens it may still emit (``kd + 1`` emit at most —
        the bound that keeps the reserve-mode admission reservation
        unbreachable). Drafts come from :func:`_prompt_lookup_draft` over
        prompt + generated."""
        k = self.speculate_k
        if k == 0:
            return {}
        plan = {}
        for i, st in enumerate(self.slots):
            if st is None or st.prefilling:
                continue
            sp = st.req.sampling
            cap = min(k, sp.speculate_k) if sp.speculate_k > 0 else k
            kd = min(cap, st.req.max_new_tokens - len(st.generated) - 1)
            plan[i] = _prompt_lookup_draft(
                np.concatenate([st.req.prompt,
                                np.asarray(st.generated, np.int32)]), kd)
        return plan

    def _grow_decode_slots(self, plan: dict | None = None) -> None:
        """Reserve pool tokens for every slot about to decode this tick —
        one per slot, plus its planned draft burst when speculating.
        In lazy mode, page-boundary growth that exhausts the pool sheds
        the slot's OWN drafts first (a draft burst is optional work; a
        request is not), then preempts before the step runs (the victim's
        un-decoded tick is simply not taken — its resume re-prefills from
        exactly the tokens it had emitted)."""
        for i in range(self.max_slots):
            if self.slots[i] is None or self.slots[i].prefilling:
                continue
            want = 1 + (plan[i].size if plan and i in plan else 0)
            while True:
                try:
                    self.pool.append(i, want)
                    break
                except PoolExhaustedError:
                    if want > 1:
                        plan[i] = plan[i][:0]
                        want = 1
                        continue
                    if not self._preempt_one(requester=i):
                        raise PoolExhaustedError(
                            f"request {self.slots[i].req.rid} cannot grow: "
                            f"the pool's {self.pool.num_pages - 1} page(s) "
                            f"cannot hold its worst case even alone")
                    if self.slots[i] is None:
                        break  # we were the victim; skip our own step

    def _emit_burst(self, slot: int, toks, n: int, lps, kd: int) -> None:
        """Land one verify round's accepted tokens on slot ``slot``:
        ``toks[:n]`` emit IN INDEX ORDER, each event carrying the token's
        logprob under the true verify distribution (never the drafter's).
        The burst is cut at its first stop token — the sequential decode
        would have finished there, so later accepted tokens must not leak
        out — and the slot's pool length rolls back to its fed-token count
        whenever part of the appended burst went unemitted
        (``kv_pool.truncate``: rejected/cut positions are scrubbed so no
        later step, export or history walk can see them)."""
        st = self.slots[slot]
        stop = st.req.sampling.stop_set
        emit = 0
        for j in range(n):
            tok = int(toks[j])
            st.generated.append(tok)
            self._emit_event(st.req.rid, len(st.generated) - 1, tok,
                             float(lps[j]))
            emit += 1
            if tok in stop:
                break
        if kd:
            self.stats.spec_rounds += 1
            self.stats.spec_drafted += kd
            self.stats.spec_accepted += emit - 1
            if self.telemetry is not None:
                self.telemetry.metrics.observe(
                    "scheduler.accepted_tokens", float(emit))
        if emit < 1 + kd:
            self.pool.truncate(slot, int(self.pool.lengths[slot])
                               - (1 + kd) + emit)

    def _verify_tick(self, active: list, plan: dict) -> None:
        """The speculative decode tick (every tick mode): each decoding
        slot's last token plus its draft burst ride one fixed
        ``(max_slots, 1 + speculate_k)`` right-aligned call through the
        pool (``models.transformer.paged_verify_step`` — all keys read
        back quantized, bit-identical attention inputs to the sequential
        decode steps), and the fused
        ``core.sampling.speculative_verify`` accepts per slot — k drafts
        verified for one dispatch instead of k ticks. Greedy slots emit
        the exact argmax stream (bit-identical to the non-speculative
        tick); sampled slots emit the exact target distribution by
        rejection sampling. Inactive rows ride fully padded as ever."""
        k = self.speculate_k
        s = 1 + k
        self._register_shape("verify", self.max_slots, s)
        tokens = np.zeros((self.max_slots, s), np.int32)
        posn = np.full((self.max_slots, s), -1, np.int32)
        gather = np.zeros((self.max_slots, s), np.int32)
        draft = np.zeros((self.max_slots, k), np.int32)
        dlen = np.zeros((self.max_slots,), np.int32)
        t0 = np.zeros((self.max_slots,), np.int32)
        for i in active:
            st = self.slots[i]
            d = plan[i]
            kd = d.size
            # first position being written: the grow appended 1 + kd
            base = int(self.pool.lengths[i]) - 1 - kd
            tokens[i, s - 1 - kd:] = np.concatenate(
                [[st.generated[-1]], d]).astype(np.int32)
            posn[i, s - 1 - kd:] = np.arange(base, base + 1 + kd)
            # verify column j (generation index t0 + j) lives at call
            # column s - 1 - kd + j; clamp past the draft count (those
            # gathers are garbage the sampler masks by draft_len)
            gather[i] = s - 1 - kd + np.minimum(np.arange(s), kd)
            draft[i, :kd] = d
            dlen[i] = kd
            t0[i] = len(st.generated)
        keys, temp, tk, tp, bias = self._device_ops()
        tel = self.telemetry
        if tel is not None:
            for i in active:
                tel.decode_begin(self.slots[i].req.rid, f"slot{i}")
        out, n_acc, lps, new_caches = self._verify(
            self.params, jnp.asarray(tokens),
            caches=self.pool.device_caches(), positions=jnp.asarray(posn),
            gather=jnp.asarray(gather), draft=jnp.asarray(draft),
            draft_len=jnp.asarray(dlen), keys=keys, t0=jnp.asarray(t0),
            temp=temp, tk=tk, tp=tp, bias=bias)
        self.pool.update_from(new_caches)
        out, n_acc, lps = np.asarray(out), np.asarray(n_acc), np.asarray(lps)
        for i in active:
            self._emit_burst(i, out[i], int(n_acc[i]), lps[i], plan[i].size)
        self.stats.steps += 1
        self.stats.slot_ticks += len(active)

    def _decode_tick(self) -> None:
        """One ragged decode step over EVERY slot (single compiled shape);
        inactive rows — free slots AND slots still mid-prefill — carry
        position -1 and are masked end-to-end, so prefill chunks and decode
        share the tick without sharing a shape. With ``speculate_k`` set
        the tick dispatches as one multi-token verify instead
        (:meth:`_verify_tick`)."""
        plan = self._draft_plan()
        self._grow_decode_slots(plan)
        active = [i for i, st in enumerate(self.slots)
                  if st is not None and not st.prefilling]
        if not active:
            return
        if self.speculate_k:
            self._verify_tick(active, plan)
            return
        self._register_shape("decode", self.max_slots, 1)
        tokens = np.zeros((self.max_slots, 1), np.int32)
        pos = np.full((self.max_slots,), -1, np.int32)
        # each row samples at its OWN generation index (folded into its
        # PRNG lane) — the stream a request draws is independent of which
        # slot it sits in and who else shares the batch
        t = np.zeros((self.max_slots,), np.int32)
        for i in active:
            tokens[i, 0] = self.slots[i].generated[-1]
            pos[i] = int(self.pool.lengths[i]) - 1  # position being written
            t[i] = len(self.slots[i].generated)
        keys, temp, tk, tp, bias = self._device_ops()
        tel = self.telemetry
        if tel is not None:
            for i in active:
                tel.decode_begin(self.slots[i].req.rid, f"slot{i}")
        nxt, lps, new_caches = self._decode(
            self.params, jnp.asarray(tokens),
            caches=self.pool.device_caches(), pos=jnp.asarray(pos),
            keys=keys, t=jnp.asarray(t), temp=temp, tk=tk, tp=tp, bias=bias)
        self.pool.update_from(new_caches)
        nxt, lps = np.asarray(nxt), np.asarray(lps)
        for i in active:
            st = self.slots[i]
            st.generated.append(int(nxt[i]))
            self._emit_event(st.req.rid, len(st.generated) - 1,
                             int(nxt[i]), float(lps[i]))
        self.stats.steps += 1
        self.stats.slot_ticks += len(active)

    def _packed_tick(self) -> bool:
        """ONE token-packed call for the whole tick: every decoding slot
        contributes its next-token row and every mid-prefill slot up to the
        remaining budget contributes its next chunk, laid out slot-major as
        contiguous segments in a fixed ``(1, token_budget)`` buffer (tail
        rows carry position/slot -1: their writes trash-route, their
        attention emits exact zeros). The call embeds, runs the varlen
        page-walk attention, gathers each slot's LAST row into ``(R, V)``
        logits and samples through the per-slot operand lanes — prefill
        chunks and decode tokens share one dispatch AND one compiled shape.
        With ``speculate_k`` set, decoding slots are EXCLUDED from the
        buffer: a draft burst must be verified through the pool's
        quantized codes (:meth:`_verify_tick`, dispatched right after by
        the packed step), not as fresh in-segment f32 keys, or the verify
        logits drift from the sequential decode path at quantization
        scale. Returns whether any work was dispatched."""
        k = self.speculate_k
        if not k:
            self._grow_decode_slots()
        decode_rows = [] if k else [
            i for i, st in enumerate(self.slots)
            if st is not None and not st.prefilling]
        t_budget = self.token_budget
        tokens = np.zeros((1, t_budget), np.int32)
        posn = np.full((1, t_budget), -1, np.int32)
        slot_ids = np.full((1, t_budget), -1, np.int32)
        # decode rows' fresh self-keys round-trip the int8 quantizer inside
        # the packed step, so they attend exactly what a sequential decode
        # step reads back from the pool; prefill rows keep f32 fresh keys
        # (the same math as Engine's prompt prefill)
        quant_fresh = np.zeros((1, t_budget), bool)
        logit_rows = np.zeros((self.max_slots,), np.int32)
        t_idx = np.zeros((self.max_slots,), np.int32)
        # decode rows are never cut
        budget = t_budget - len(decode_rows)
        cap = self._pick_chunk() if any(
            st is not None and st.prefilling for st in self.slots) else 0
        cur = 0
        pieces = {}  # slot → (lo, hi, total) prefill piece taken this tick
        for i in range(self.max_slots):
            st = self.slots[i]
            if st is None:
                continue
            if not st.prefilling:
                if k:
                    continue  # speculating: decodes ride _verify_tick
                tokens[0, cur] = st.generated[-1]
                posn[0, cur] = int(self.pool.lengths[i]) - 1
                slot_ids[0, cur] = i
                quant_fresh[0, cur] = True
                logit_rows[i] = cur
                t_idx[i] = len(st.generated)
                cur += 1
            elif budget > 0:
                toks = st.req.prefill_tokens
                lo = st.prefilled
                hi = min(lo + min(cap, budget), toks.size)
                n = hi - lo
                tokens[0, cur:cur + n] = toks[lo:hi]
                posn[0, cur:cur + n] = np.arange(lo, hi)
                slot_ids[0, cur:cur + n] = i
                logit_rows[i] = cur + n - 1
                pieces[i] = (lo, hi, toks.size)
                budget -= n
                cur += n
        if cur == 0:
            return False
        self._register_shape("packed", self.max_slots, t_budget)
        keys, temp, tk, tp, bias = self._device_ops()
        tel = self.telemetry
        if tel is not None:
            for i in decode_rows:
                tel.decode_begin(self.slots[i].req.rid, f"slot{i}")
            t0 = tel.now()
        nxt, lps, new_caches = self._packed(
            self.params, jnp.asarray(tokens),
            caches=self.pool.device_caches(), positions=jnp.asarray(posn),
            slots=jnp.asarray(slot_ids), logit_rows=jnp.asarray(logit_rows),
            quant_fresh=jnp.asarray(quant_fresh), keys=keys,
            t=jnp.asarray(t_idx), temp=temp, tk=tk, tp=tp, bias=bias)
        if tel is not None:
            jax.block_until_ready(nxt)
            t1 = tel.now()
        self.pool.update_from(new_caches)
        nxt, lps = np.asarray(nxt), np.asarray(lps)
        for i, (lo, hi, total) in pieces.items():
            st = self.slots[i]
            self.pool.commit_prefill(i, hi)
            st.prefilled = hi
            self.stats.prefill_chunks += 1
            self.stats.prefill_tokens += hi - lo
            if tel is not None:
                tel.add_span("prefill", t0, t1, track=f"slot{i}",
                             rid=st.req.rid, tokens=hi - lo, stage="packed",
                             done=hi == total)
            self._maybe_pin_prefix(st, i)
            if hi == total:  # prompt complete → first token
                self._record_first_token(st, i, int(nxt[i]), float(lps[i]))
        for i in decode_rows:
            st = self.slots[i]
            st.generated.append(int(nxt[i]))
            self._emit_event(st.req.rid, len(st.generated) - 1,
                             int(nxt[i]), float(lps[i]))
        self.stats.packed_ticks += 1
        self.stats.packed_tokens += cur
        self.stats.packed_pad_tokens += t_budget - cur
        if pieces:
            self.stats.prefills += 1
        if decode_rows:
            self.stats.steps += 1
            self.stats.slot_ticks += len(decode_rows)
        return True

    def _evict_finished(self) -> None:
        for i, st in enumerate(self.slots):
            if st is None or not st.done:
                continue
            toks, reason = truncate_at_stop(
                st.generated[: st.req.max_new_tokens], st.req.sampling)
            self.results[st.req.rid] = np.concatenate(
                [st.req.prompt, np.asarray(toks, np.int32)])
            self.finish_reasons[st.req.rid] = reason
            self._mark_finished(st.req.rid)
            self.pool.free(i)
            self.slots[i] = None
            self._reset_ops(i)
            self.stats.evicted += 1
            if self.telemetry is not None:
                self.telemetry.request_finished(st.req.rid, f"slot{i}",
                                                reason, len(toks))

    def _track_occupancy(self) -> None:
        self.stats.peak_occupancy = max(self.stats.peak_occupancy,
                                        self.pool.occupancy())
        self.stats.peak_pool_bytes = max(self.stats.peak_pool_bytes,
                                         self.pool.page_bytes_in_use())
        self.stats.peak_eq2_bytes = max(self.stats.peak_eq2_bytes,
                                        self.pool.eq2_bytes())
        self.stats.peak_shared_pages = max(self.stats.peak_shared_pages,
                                           self.pool.pages_shared)

    # ------------------------------------------------------------- driving

    @property
    def pending(self) -> bool:
        return bool(self.queue) or any(s is not None for s in self.slots)

    def _fail_stuck_queue(self) -> bool:
        """The batch is idle yet the queue head still doesn't fit: release
        an idle pinned prefix and retry (returns True); if nothing is
        releasable it never will fit — fail loudly instead of spinning
        forever."""
        if self._release_idle_prefix():
            return True
        req = self.queue[0]
        need = self.pool.pages_for(self._admission_target(req))
        kind = "for admission" if self.lazy_growth else "worst-case"
        raise PoolExhaustedError(
            f"request {req.rid} needs {need} pages {kind} but the "
            f"whole pool has {self.pool.num_pages - 1} (max_blocks "
            f"{self.pool.max_blocks}); it can never be admitted")

    def step(self) -> bool:
        """One scheduler tick. Packed mode: admit, then ONE token-packed
        call carrying every decode token and up-to-budget prefill tokens,
        then evict. Chunked/wave modes: admit, advance prefill (one
        fixed-size chunk per mid-prefill slot, or the full wave), evict
        anything that finished on its prefill token, decode the ragged
        batch, evict. Returns whether work remains.

        With ``telemetry=`` set, each tick additionally lands one
        :class:`~repro.serving.telemetry.TickRecord` (wall time, token/pad
        counts, compile events, pool occupancy, queue depth); the
        timeline is assembled from stat deltas, so the instrumented tick
        runs the exact same scheduling decisions as the bare one.

        SINGLE-DRIVER: ticks mutate pool pages, block tables and slot
        state with no internal locking — one thread must own them
        (``serving.async_engine.AsyncLLMServer`` marshals every call onto
        its tick thread). A second thread entering mid-tick raises
        RuntimeError instead of silently corrupting the pool."""
        if not self._step_guard.acquire(blocking=False):
            raise RuntimeError(
                "Scheduler.step() re-entered from another thread mid-tick: "
                "the scheduler is single-driver — submit/abort/step must "
                "all run on ONE thread (drain_events/drain_finished are "
                "the only cross-thread-safe surfaces)")
        try:
            return self._step_guarded()
        finally:
            self._step_guard.release()

    def _step_guarded(self) -> bool:
        tel = self.telemetry
        if tel is None:
            return self._step_inner()
        s = self.stats
        pre = (s.packed_tokens, s.packed_pad_tokens, s.prefill_tokens,
               s.slot_ticks)
        tel.tick_begin(self._tick + 1, self.tick_mode)
        try:
            pending = self._step_inner()
        finally:
            if self.tick_mode == "packed":
                tokens = s.packed_tokens - pre[0]
                pad = s.packed_pad_tokens - pre[1]
                if self.speculate_k:
                    # the multi-token verify dispatch rides OUTSIDE the
                    # packed buffer (decode slots are excluded from it);
                    # count its stepped slots like the two-phase ticks do
                    tokens += s.slot_ticks - pre[3]
            else:
                # legacy two-phase tick: prefill tokens + one decode token
                # per stepped slot (no fixed buffer → no pad accounting)
                tokens = (s.prefill_tokens - pre[2]) + (s.slot_ticks - pre[3])
                pad = None
            g = self.pool.gauges()
            tel.tick_end(
                tokens=tokens, pad_tokens=pad,
                pages_in_use=g["pages_in_use"],
                pages_shared=g["pages_shared"],
                swap_bytes=g["swap_bytes"], queue_depth=len(self.queue),
                active_slots=sum(st is not None for st in self.slots),
                prefilling_slots=sum(st is not None and st.prefilling
                                     for st in self.slots))
        return pending

    def _step_inner(self) -> bool:
        self._tick += 1
        admitted, restored = self._admit_wave()
        if restored:
            self.stats.admitted += len(restored)
        if self.tick_mode == "packed":
            self.stats.admitted += len(admitted)
            did = self._packed_tick()
            if did or restored:
                self._track_occupancy()
                self._evict_finished()
            if self.speculate_k:
                # speculating: the packed call carried prefill only; the
                # decode slots now ride the pool-only multi-token verify
                # (max_new == 1 slots already finished on their prefill
                # token and were evicted above)
                if any(st is not None and not st.prefilling
                       for st in self.slots):
                    self._decode_tick()
                    self._track_occupancy()
                    self._evict_finished()
                    return self.pending
            if not did and (not admitted and not restored and self.queue
                            and all(st is None for st in self.slots)):
                self._fail_stuck_queue()
            return self.pending
        did_prefill = False
        if self.prefill_mode == "wave":
            if admitted:
                # prefill fresh rows and forked rows separately: the shared
                # path's full-pool history walk is paid only by rows that
                # actually attend history
                fresh = [s for s in admitted
                         if int(self.pool.lengths[s]) == 0]
                forked = [s for s in admitted
                          if int(self.pool.lengths[s]) > 0]
                for group in (fresh, forked):
                    if group:
                        self._prefill_wave(group)
                did_prefill = True
        else:
            self.stats.admitted += len(admitted)
            did_prefill = self._prefill_chunk_tick()
        if did_prefill or restored:
            self._track_occupancy()
            self._evict_finished()  # max_new_tokens == 1 finishes here
        if any(st is not None and not st.prefilling for st in self.slots):
            self._decode_tick()
            self._track_occupancy()
            self._evict_finished()
        elif (not admitted and not restored and self.queue
              and all(st is None for st in self.slots)):
            self._fail_stuck_queue()
        return self.pending

    def run(self) -> dict:
        """Drain queue and batch; returns {rid: np.ndarray tokens} (prompt +
        generation, EOS-truncated). Pinned prefixes are released after the
        drain so the pool ends fully reclaimed."""
        while self.step():
            pass
        self.release_prefixes()
        return self.results
