"""Batched serving engine: prefill + autoregressive decode with KV caches.

Requests are batched by equal prompt length (length bucketing — the
production-standard strategy when no per-row attention masking is wired
through). Sampling: greedy or temperature. ``serve_step`` (one decode step
for the whole batch) is the function the dry-run lowers for the decode
shapes.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models.transformer import (RuntimeOpts, decode_step, init_caches,
                                      prefill)


@dataclasses.dataclass
class GenerationResult:
    tokens: np.ndarray  # (B, prompt + generated)
    steps: int


class Engine:
    def __init__(self, cfg: ArchConfig, params, opts: RuntimeOpts = RuntimeOpts(),
                 cache_len: int = 4096):
        self.cfg = cfg
        self.params = params
        self.opts = opts
        self.cache_len = cache_len
        self._prefill = jax.jit(
            lambda p, t, patches: prefill(p, cfg, t, patches, cache_len, opts))
        self._step = jax.jit(
            lambda p, t, caches, pos: decode_step(p, cfg, t, caches, pos, opts))

    def _sample(self, logits, key, temperature: float):
        if temperature <= 0:
            return jnp.argmax(logits, axis=-1)
        return jax.random.categorical(key, logits / temperature, axis=-1)

    def generate(self, prompts: np.ndarray, max_new_tokens: int,
                 temperature: float = 0.0, patches=None, seed: int = 0,
                 ) -> GenerationResult:
        """``prompts``: (B, S) int32 (or (B, S, K) musicgen), equal lengths."""
        tokens = jnp.asarray(prompts)
        b, s = tokens.shape[:2]
        assert s + max_new_tokens <= self.cache_len, "cache_len too small"
        logits, caches = self._prefill(self.params, tokens,
                                       None if patches is None else jnp.asarray(patches))
        key = jax.random.PRNGKey(seed)
        out = [tokens]
        pos = s
        for i in range(max_new_tokens):
            key, sub = jax.random.split(key)
            nxt = self._sample(logits, sub, temperature)  # (B,) or (B, K)
            nxt = nxt[:, None].astype(tokens.dtype)  # (B, 1, ...)
            out.append(nxt)
            if i + 1 == max_new_tokens:
                break
            logits, caches = self._step(self.params, nxt, caches, jnp.int32(pos))
            pos += 1
        return GenerationResult(np.asarray(jnp.concatenate(out, axis=1)),
                                max_new_tokens)


def serve_step_fn(cfg: ArchConfig, opts: RuntimeOpts):
    """The function lowered by the dry-run for decode shapes: one new token
    against a full cache of ``cache_len`` (greedy head included)."""

    def serve_step(params, tokens, caches, pos):
        logits, new_caches = decode_step(params, cfg, tokens, caches, pos, opts)
        return jnp.argmax(logits, axis=-1), new_caches

    return serve_step
