"""Batched serving engine: one jitted prefill + on-device decode loop.

``generate`` lowers the ENTIRE generation — prefill, a ``lax.scan`` over
decode steps, and on-device greedy/temperature sampling — as one jitted
function: no per-token host round-trip, a single device→host copy of the
finished token matrix at the end. With ``RuntimeOpts.quantized_kv`` the
decode steps inside the scan stream the int8 KV cache through the Pallas
``kernels.decode_attention`` kernel (the §Roofline fast path).

Requests are batched by equal prompt length (length bucketing — the
production-standard strategy when no per-row attention masking is wired
through). ``serve_step`` (one decode step for the whole batch) remains the
function the dry-run lowers for the decode shapes.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.sampling import (bias_rows, broadcast_params,
                                 device_operands, sample_tokens,
                                 token_logprobs)
from repro.models.transformer import RuntimeOpts, decode_step, prefill


@dataclasses.dataclass
class GenerationResult:
    tokens: np.ndarray  # (B, prompt + generated)
    steps: int
    # (B, generated) f32 — each emitted token's log-probability under the
    # raw model distribution (core.sampling.token_logprobs); None only for
    # zero-step generations
    logprobs: np.ndarray | None = None


def _fused_generate(params, cfg, opts, cache_len, max_new, tokens, patches,
                    sample):
    """The fused-loop scaffold both compile paths share: one prefill, a
    ``lax.scan`` of ``max_new - 1`` decode steps whose carry is (logits,
    caches, pos), and ``sample(logits, t)`` — t the 0-based index of the
    token being drawn — called inside the scan so nothing crosses to the
    host between steps. Each drawn token's raw-distribution logprob is
    computed in the scan too — the logits it needs are already in the
    carry, so the tokens themselves are untouched (greedy stays
    bit-identical to the logprob-less loop). Returns
    ((B, prompt + max_new) tokens, (B, max_new) logprobs)."""
    b, s = tokens.shape[:2]
    logits, caches = prefill(params, cfg, tokens, patches, cache_len, opts)

    def body(carry, t):
        logits, caches, pos = carry
        nxt = sample(logits, t)
        lp = token_logprobs(logits, nxt)
        tok = nxt[:, None].astype(tokens.dtype)
        logits, caches = decode_step(params, cfg, tok, caches, pos, opts)
        return (logits, caches, pos + 1), (nxt, lp)

    # max_new - 1 decode steps; the last sampled token needs no step
    (logits, caches, _), (toks, lps) = jax.lax.scan(
        body, (logits, caches, jnp.int32(s)),
        jnp.arange(max_new - 1, dtype=jnp.int32))
    last = sample(logits, jnp.int32(max_new - 1))
    last_lp = token_logprobs(logits, last)
    toks = jnp.concatenate([toks, last[None]], axis=0)
    lps = jnp.concatenate([lps, last_lp[None]], axis=0)
    toks = jnp.moveaxis(toks, 0, 1).astype(tokens.dtype)
    return jnp.concatenate([tokens, toks], axis=1), jnp.moveaxis(lps, 0, 1)


class Engine:
    def __init__(self, cfg: ArchConfig, params, opts: RuntimeOpts = RuntimeOpts(),
                 cache_len: int = 4096, telemetry=None):
        self.cfg = cfg
        self.params = params
        self.opts = opts
        self.cache_len = cache_len
        # telemetry.Tracer | None: with a tracer, each fused call lands one
        # "fused_generate" span (device-synced timing) plus batch/token
        # counters; None skips every tracer touch AND the sync
        self.telemetry = telemetry
        self._gen_fns: dict = {}

    def _span(self, t0: float, *, batch: int, prompt_len: int,
              max_new: int, out) -> None:
        """Close one fused-call span: sync so the span covers the real
        device work (the values themselves are untouched)."""
        tel = self.telemetry
        jax.block_until_ready(out)
        t1 = tel.now()
        tel.add_span("fused_generate", t0, t1, track="engine", batch=batch,
                     prompt_len=prompt_len, max_new=max_new)
        tel.metrics.count("fused.calls")
        tel.metrics.count("fused.requests", batch)
        tel.metrics.count("fused.tokens", batch * max_new)
        tel.metrics.observe("fused.batch_s", t1 - t0)

    def generate_fn(self, max_new_tokens: int, greedy: bool = True):
        """The fused loop: jitted ``fn(params, tokens, patches, rng,
        temperature) → ((B, prompt + max_new_tokens) tokens,
        (B, max_new_tokens) logprobs)``, everything on device. Temperature is a traced operand (ignored when ``greedy``), so
        per-request temperatures don't recompile the loop;
        (max_new_tokens, greedy) plus the engine's current ``cache_len`` and
        ``opts`` key the compile cache — the closure bakes both in, so keying
        on only (max_new_tokens, greedy) would silently serve a stale cache
        size to a reconfigured live engine. Batch/prompt shapes need no key:
        ``jax.jit`` retraces per input shape on its own.

        The token loop is a ``lax.scan`` whose carry is (logits, caches, pos);
        sampling happens inside the scan, so nothing crosses to the host
        between steps (verified by jit-tracing this function abstractly)."""
        assert max_new_tokens >= 1, "the fused loop samples at least one token"
        key = (int(max_new_tokens), bool(greedy), int(self.cache_len),
               self.opts)
        if key in self._gen_fns:
            return self._gen_fns[key]
        cfg, opts, cache_len = self.cfg, self.opts, self.cache_len
        max_new = int(max_new_tokens)

        def fn(params, tokens, patches, rng, temperature):
            keys = jax.random.split(rng, max_new)

            def sample(logits, t):  # (B,) or (B, K)
                if greedy:
                    return jnp.argmax(logits, axis=-1)
                return jax.random.categorical(
                    keys[t], logits / temperature, axis=-1)

            return _fused_generate(params, cfg, opts, cache_len, max_new,
                                   tokens, patches, sample)

        self._gen_fns[key] = jax.jit(fn)
        return self._gen_fns[key]

    def request_fn(self, max_new_tokens: int, greedy: bool = True):
        """The PER-REQUEST fused loop behind the serving API
        (``serving.api.LLMServer`` fused backend): same jitted
        prefill + ``lax.scan`` as :meth:`generate_fn`, but sampling runs
        through the shared ``core.sampling.sample_tokens`` with PER-ROW
        operands — ``fn(params, tokens, patches, keys (B, 2) uint32,
        temperature (B,), top_k (B,), top_p (B,))`` — so one compile
        serves any mix of per-request temperatures / top-k / top-p, and
        each row's PRNG lane is its own key folded with its generation
        index (the exact stream the paged scheduler draws for the same
        seed — fused/paged sampling parity). ``greedy=True`` compiles the
        pure-argmax scan (identical tokens to :meth:`generate_fn`
        greedy, bit for bit). The trailing ``bias`` operand is ``None``
        for bias-free batches (jit retraces on the pytree-structure
        change, so the default workload's compiled program has no extra
        operand at all); with a (B, V) bias row it shifts the logits
        before the argmax / sampler — logprobs stay raw."""
        assert max_new_tokens >= 1, "the fused loop samples at least one token"
        key = ("req", int(max_new_tokens), bool(greedy), int(self.cache_len),
               self.opts)
        if key in self._gen_fns:
            return self._gen_fns[key]
        cfg, opts, cache_len = self.cfg, self.opts, self.cache_len
        max_new = int(max_new_tokens)

        def fn(params, tokens, patches, keys, temperature, top_k, top_p,
               bias):
            b = tokens.shape[0]

            def sample(logits, t):
                if bias is not None:
                    logits = logits + bias
                if greedy:
                    return jnp.argmax(logits, axis=-1)
                return sample_tokens(logits, keys,
                                     jnp.full((b,), t, jnp.int32),
                                     temperature, top_k, top_p)

            return _fused_generate(params, cfg, opts, cache_len, max_new,
                                   tokens, patches, sample)

        self._gen_fns[key] = jax.jit(fn)
        return self._gen_fns[key]

    def generate_requests(self, prompts: np.ndarray,
                          sampling) -> GenerationResult:
        """Serve a batch of equal-length prompts with PER-REQUEST
        :class:`~repro.core.sampling.SamplingParams` through the fused
        scan. ``sampling`` is one ``SamplingParams`` (applied to every
        row) or a list of ``len(prompts)``. The scan runs to the batch's
        LARGEST ``max_tokens``; per-row ``max_tokens`` and stop-token
        truncation are the caller's concern (``serving.api`` does both).
        All-greedy batches compile the pure-argmax scan — bit-identical
        to :meth:`generate` at ``temperature=0``."""
        tokens = jnp.asarray(prompts)
        b, s = tokens.shape[:2]
        sampling = broadcast_params(sampling, b)
        max_new = max(p.max_tokens for p in sampling)
        assert s + max_new <= self.cache_len, "cache_len too small"
        if any(not p.greedy for p in sampling) and tokens.ndim != 2:
            raise NotImplementedError(
                "non-greedy sampling needs (B, S) token prompts")
        bucket = min(1 << (max_new - 1).bit_length(), self.cache_len - s)
        fn = self.request_fn(bucket, greedy=all(p.greedy for p in sampling))
        keys, temp, tk, tp = device_operands(sampling)
        bias = None
        if any(p.logit_bias for p in sampling):
            bias = jnp.asarray(bias_rows(sampling, self.cfg.vocab_size))
        t0 = self.telemetry.now() if self.telemetry is not None else 0.0
        out, lps = fn(self.params, tokens, None, keys, temp, tk, tp, bias)
        if self.telemetry is not None:
            self._span(t0, batch=b, prompt_len=s, max_new=max_new, out=out)
        return GenerationResult(np.asarray(out[:, : s + max_new]), max_new,
                                logprobs=np.asarray(lps[:, :max_new]))

    def generate(self, prompts: np.ndarray, max_new_tokens: int,
                 temperature: float = 0.0, patches=None, seed: int = 0,
                 ) -> GenerationResult:
        """``prompts``: (B, S) int32 (or (B, S, K) musicgen), equal lengths."""
        tokens = jnp.asarray(prompts)
        b, s = tokens.shape[:2]
        assert s + max_new_tokens <= self.cache_len, "cache_len too small"
        if max_new_tokens == 0:
            return GenerationResult(np.asarray(tokens), 0)
        # bucket the scan length to the next power of two (capped by the
        # cache) so varying request lengths share a handful of compiles
        # instead of one full prefill+scan XLA program per distinct length;
        # the surplus steps are sliced off below
        bucket = min(1 << (max_new_tokens - 1).bit_length(),
                     self.cache_len - s)
        fn = self.generate_fn(bucket, greedy=temperature <= 0)
        t0 = self.telemetry.now() if self.telemetry is not None else 0.0
        out, lps = fn(self.params, tokens,
                      None if patches is None else jnp.asarray(patches),
                      jax.random.PRNGKey(seed),
                      jnp.float32(max(temperature, 1e-6)))
        if self.telemetry is not None:
            self._span(t0, batch=b, prompt_len=s, max_new=max_new_tokens,
                       out=out)
        return GenerationResult(np.asarray(out[:, : s + max_new_tokens]),
                                max_new_tokens,
                                logprobs=np.asarray(lps[:, :max_new_tokens]))


def serve_step_fn(cfg: ArchConfig, opts: RuntimeOpts):
    """The function lowered by the dry-run for decode shapes: one new token
    against a full cache of ``cache_len`` (greedy head included)."""

    def serve_step(params, tokens, caches, pos):
        logits, new_caches = decode_step(params, cfg, tokens, caches, pos, opts)
        return jnp.argmax(logits, axis=-1), new_caches

    return serve_step
