"""Unified serving telemetry: request-lifecycle spans, per-tick timeline
records, and a counter/gauge/histogram registry with streaming percentiles.

The paper's unified optimizer (§2) picks split points, quantization
settings, and sequence lengths against *measured* memory and latency
constraints — this module is the measurement substrate. One
:class:`Tracer` instance is threaded (``telemetry=``, default ``None``)
through all three serving front ends:

  * ``serving.scheduler.Scheduler`` — every tick (any ``tick_mode``)
    emits a :class:`TickRecord` (wall time, mode, live/pad token counts,
    compiled-shape cache hits vs. new compiles, pool page occupancy,
    queue depth) and each request's lifecycle lands as spans:
    ``queued → prefill chunk(s) → first_token → decode →
    preempt/swap_out/swap_resume → finish``, each carrying its tick id
    and reason;
  * ``serving.engine.Engine`` — one ``fused_generate`` span per jitted
    prefill+scan call with batch/token counters;
  * ``serving.split_engine.SplitEngine`` — per-segment ``edge`` /
    ``cloud`` spans (prefill and every decode step), per-step uplink-bit
    events, and TAB-Q bit-width histograms, unifying the existing
    ``SplitStats`` uplink accounting.

Everything is zero-dependency (stdlib only) and strictly pay-for-what-
you-use: with ``telemetry=None`` no Tracer method is ever called (the
disabled path is guarded at every instrumentation site — enforced by
``tests/test_telemetry.py``'s no-op test), and an enabled Tracer never
touches device values, so greedy outputs are bit-identical with
telemetry on or off.

Exporters:

  * :meth:`Tracer.export_chrome_trace` — Chrome trace-event JSON
    (load in Perfetto / ``chrome://tracing``): one track per scheduler
    slot plus a ``ticks`` track, a ``queue`` track, and per-engine
    tracks, with the flat metrics dict embedded under ``repro_metrics``;
  * :meth:`Tracer.metrics_dict` — the flat ``{name: value}`` metrics
    dict consumed by ``LLMServer.metrics()`` and benchmark artifacts
    (histograms expand to ``name.p50`` / ``name.p95`` / ``name.p99`` /
    ``name.mean`` / ... keys);
  * ``tools/trace_report.py`` — text summary (per-phase time breakdown,
    preemption/swap counts, compile events, SLO table) of an exported
    trace, used by CI to validate smoke traces.

Clock: ``time.perf_counter`` (monotonic) by default — the same clock
``serving.api`` stamps ``RequestMetrics`` with — injectable for tests.

Percentiles are streaming via a DDSketch-style log-bucketed histogram
(:class:`Histogram`): bounded relative error (default 1%), O(log range)
memory, no sample retention — fit for a long-lived server.
"""

from __future__ import annotations

import dataclasses
import json
import math
import time


# --------------------------------------------------------------- histogram


class Histogram:
    """Streaming histogram with bounded RELATIVE quantile error.

    DDSketch-style log-spaced buckets: a value ``v > 0`` lands in bucket
    ``ceil(log_gamma(v))`` with ``gamma = (1 + rel_err) / (1 - rel_err)``,
    so any reported quantile is within ``rel_err`` (relatively) of the
    true one. Non-positive values collapse into one exact zero bucket.
    Count/sum/min/max are exact.
    """

    def __init__(self, rel_err: float = 0.01):
        if not 0.0 < rel_err < 1.0:
            raise ValueError(f"rel_err must be in (0, 1), got {rel_err}")
        self.rel_err = rel_err
        self._gamma = (1.0 + rel_err) / (1.0 - rel_err)
        self._lg = math.log(self._gamma)
        self.count = 0
        self.sum = 0.0
        self.min: float | None = None
        self.max: float | None = None
        self._zero = 0  # values <= 0 (exact bucket)
        self._buckets: dict = {}  # key -> count, value ~ gamma**key

    def record(self, value: float) -> None:
        v = float(value)
        self.count += 1
        self.sum += v
        self.min = v if self.min is None else min(self.min, v)
        self.max = v if self.max is None else max(self.max, v)
        if v <= 0.0:
            self._zero += 1
            return
        key = math.ceil(math.log(v) / self._lg)
        self._buckets[key] = self._buckets.get(key, 0) + 1

    @property
    def mean(self) -> float | None:
        return self.sum / self.count if self.count else None

    def percentile(self, q: float) -> float | None:
        """The q-quantile (``q`` in [0, 1]) within the sketch's relative
        error, clamped to the exact observed [min, max]."""
        if self.count == 0:
            return None
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"q must be in [0, 1], got {q}")
        if q == 0.0:
            return self.min  # exact extremes, not bucket midpoints
        if q == 1.0:
            return self.max
        rank = q * (self.count - 1)
        if rank < self._zero:
            # all values in the zero bucket are <= 0; min is exact
            return min(self.min, 0.0)
        cum = self._zero
        for key in sorted(self._buckets):
            cum += self._buckets[key]
            if cum > rank:
                # bucket midpoint: 2 * gamma^key / (gamma + 1) is the
                # value whose relative distance to both bucket edges
                # is exactly rel_err
                v = 2.0 * self._gamma ** key / (self._gamma + 1.0)
                return max(self.min, min(self.max, v))
        return self.max

    def summary(self) -> dict:
        """{count, sum, mean, min, max, p50, p95, p99} (empty → count 0)."""
        if self.count == 0:
            return {"count": 0}
        return {"count": self.count, "sum": self.sum, "mean": self.mean,
                "min": self.min, "max": self.max,
                "p50": self.percentile(0.50), "p95": self.percentile(0.95),
                "p99": self.percentile(0.99)}


class MetricsRegistry:
    """Named counters (monotonic), gauges (last value), and histograms
    (streaming percentiles). ``flat()`` renders everything as one
    ``{name: number}`` dict — histograms expand to dotted sub-keys."""

    def __init__(self):
        self.counters: dict = {}
        self.gauges: dict = {}
        self.histograms: dict = {}

    def count(self, name: str, n: float = 1) -> None:
        self.counters[name] = self.counters.get(name, 0) + n

    def gauge(self, name: str, value: float) -> None:
        self.gauges[name] = value

    def observe(self, name: str, value: float) -> None:
        h = self.histograms.get(name)
        if h is None:
            h = self.histograms[name] = Histogram()
        h.record(value)

    def flat(self) -> dict:
        out: dict = {}
        out.update(self.counters)
        out.update(self.gauges)
        for name, h in self.histograms.items():
            for k, v in h.summary().items():
                out[f"{name}.{k}"] = v
        return out


# ------------------------------------------------------------------- spans


@dataclasses.dataclass
class Span:
    """One duration on one track. ``end`` is None while the span is open;
    ``attrs`` carries reasons / tick ids / token counts."""

    name: str
    track: str
    start: float
    end: float | None = None
    rid: int | None = None
    attrs: dict = dataclasses.field(default_factory=dict)

    @property
    def duration(self) -> float | None:
        return None if self.end is None else self.end - self.start


@dataclasses.dataclass
class TickRecord:
    """One scheduler tick's timeline entry."""

    tick: int
    start: float
    wall_s: float
    mode: str  # "packed" | "chunked" | "wave"
    tokens: int  # live tokens the tick's jitted calls carried
    pad_tokens: int | None  # buffer pad rows (packed mode; None otherwise)
    new_compiles: int  # jitted call shapes first seen this tick
    shape_hits: int  # dispatches that reused an already-seen shape
    pages_in_use: int
    pages_shared: int
    swap_bytes: int
    queue_depth: int
    active_slots: int
    prefilling_slots: int


# ------------------------------------------------------------------ tracer


class Tracer:
    """Collects spans, instant events, tick records, and metrics from the
    serving layer. One instance per server/scheduler; share one across
    backends to get a single merged trace.

    Request-lifecycle helpers (``request_submitted`` ... ``request_
    finished``) encapsulate the span bookkeeping so the scheduler's
    instrumentation stays one guarded line per site; the generic
    ``span_begin`` / ``span_end`` / ``add_span`` / ``event`` API is
    available for everything else.
    """

    def __init__(self, clock=time.perf_counter):
        self.clock = clock
        self.t0 = clock()
        self.spans: list = []  # closed AND open spans, begin order
        self.events: list = []  # (name, t, track, rid, attrs) instants
        self.ticks: list = []
        self.metrics = MetricsRegistry()
        self.ttft_ticks: dict = {}  # rid -> ticks submit → first token
        self._open: dict = {}  # key -> Span
        self._submit_t: dict = {}  # rid -> submit time
        self._first_t: dict = {}  # rid -> first-token time
        self._tick_open: tuple | None = None  # (tick, t_start, mode)
        self._tick_compiles = 0
        self._tick_hits = 0
        self.current_tick: int | None = None

    def now(self) -> float:
        return self.clock()

    # -------------------------------------------------------- generic API

    def span_begin(self, key, name: str, track: str, rid: int | None = None,
                   **attrs) -> Span:
        """Open a span under ``key`` (any hashable); re-opening a live key
        closes the old span first (never silently drops one)."""
        if key in self._open:
            self.span_end(key)
        if self.current_tick is not None:
            attrs.setdefault("tick", self.current_tick)
        sp = Span(name, track, self.now(), rid=rid, attrs=attrs)
        self._open[key] = sp
        self.spans.append(sp)
        return sp

    def span_end(self, key, **attrs) -> Span | None:
        """Close the span opened under ``key`` (no-op for unknown keys —
        lifecycle paths may legitimately close a span twice, e.g. abort
        racing evict)."""
        sp = self._open.pop(key, None)
        if sp is None:
            return None
        sp.end = self.now()
        if self.current_tick is not None:
            attrs.setdefault("end_tick", self.current_tick)
        sp.attrs.update(attrs)
        return sp

    def add_span(self, name: str, start: float, end: float, track: str,
                 rid: int | None = None, **attrs) -> Span:
        """Record an already-timed duration (caller holds t0/t1)."""
        if self.current_tick is not None:
            attrs.setdefault("tick", self.current_tick)
        sp = Span(name, track, start, end, rid=rid, attrs=attrs)
        self.spans.append(sp)
        return sp

    def event(self, name: str, track: str = "ticks", rid: int | None = None,
              t: float | None = None, **attrs) -> None:
        """Record an instant event (Chrome ``ph: "i"``)."""
        if self.current_tick is not None:
            attrs.setdefault("tick", self.current_tick)
        self.events.append((name, self.now() if t is None else t, track,
                            rid, attrs))

    # -------------------------------------------------- request lifecycle

    def request_submitted(self, rid: int) -> None:
        self._submit_t[rid] = self.now()
        self.metrics.count("requests.submitted")
        self.span_begin(("queued", rid), "queued", "queue", rid=rid)

    def request_admitted(self, rid: int, slot: int,
                         resumed: bool = False) -> None:
        self.span_end(("queued", rid), slot=slot, resumed=resumed)
        self.metrics.count("requests.admitted")
        if resumed:
            self.metrics.count("requests.resumed")

    def request_requeued(self, rid: int, reason: str) -> None:
        """Back to the queue (preemption): a fresh ``queued`` span opens
        with the reason attached."""
        self.span_begin(("queued", rid), "queued", "queue", rid=rid,
                        requeued=True, reason=reason)

    def first_token(self, rid: int, track: str,
                    ttft_ticks: int | None = None) -> None:
        t = self.now()
        self._first_t.setdefault(rid, t)
        if ttft_ticks is not None:
            self.ttft_ticks.setdefault(rid, int(ttft_ticks))
        self.event("first_token", track=track, rid=rid, t=t)
        sub = self._submit_t.get(rid)
        if sub is not None:
            self.metrics.observe("ttft_s", t - sub)

    def decode_begin(self, rid: int, track: str) -> None:
        """Open the request's decode-residency span — idempotent, so the
        per-tick decode paths can call it unconditionally."""
        if ("decode", rid) not in self._open:
            self.span_begin(("decode", rid), "decode", track, rid=rid)

    def request_finished(self, rid: int, track: str, reason: str,
                         n_tokens: int) -> None:
        t = self.now()
        self.span_end(("queued", rid), outcome=reason)  # aborted-in-queue
        self.span_end(("decode", rid), outcome=reason)
        self.event("finish", track=track, rid=rid, t=t, reason=reason,
                   tokens=n_tokens)
        self.metrics.count("requests.finished")
        self.metrics.count(f"requests.finish_reason.{reason}")
        sub = self._submit_t.pop(rid, None)
        first = self._first_t.pop(rid, None)
        if sub is not None:
            self.metrics.observe("e2e_s", t - sub)
        if first is not None and n_tokens > 1:
            self.metrics.observe("tpot_s", (t - first) / (n_tokens - 1))

    # ---------------------------------------------------------- tick API

    def tick_begin(self, tick: int, mode: str) -> None:
        self._tick_open = (int(tick), self.now(), mode)
        self.current_tick = int(tick)
        self._tick_compiles = 0
        self._tick_hits = 0

    def shape_dispatch(self, new: bool) -> None:
        """One jitted dispatch this tick; ``new`` = first time this call
        shape was seen (an XLA compile)."""
        if new:
            self._tick_compiles += 1
            self.metrics.count("compile.shapes")
            if self._tick_open is not None:
                self.event("compile", track="ticks",
                           tick=self._tick_open[0])
        else:
            self._tick_hits += 1
        self.metrics.count("compile.dispatches")

    def tick_end(self, *, tokens: int = 0, pad_tokens: int | None = None,
                 pages_in_use: int = 0, pages_shared: int = 0,
                 swap_bytes: int = 0, queue_depth: int = 0,
                 active_slots: int = 0, prefilling_slots: int = 0) -> None:
        if self._tick_open is None:
            return
        tick, t_start, mode = self._tick_open
        self._tick_open = None
        self.current_tick = None
        wall = self.now() - t_start
        rec = TickRecord(tick, t_start, wall, mode, int(tokens),
                         None if pad_tokens is None else int(pad_tokens),
                         self._tick_compiles, self._tick_hits,
                         int(pages_in_use), int(pages_shared),
                         int(swap_bytes), int(queue_depth),
                         int(active_slots), int(prefilling_slots))
        self.ticks.append(rec)
        m = self.metrics
        m.observe("tick.wall_s", wall)
        m.count("tick.count")
        m.count("tick.tokens", rec.tokens)
        if rec.pad_tokens is not None:
            m.count("tick.pad_tokens", rec.pad_tokens)
        m.gauge("pool.pages_in_use", rec.pages_in_use)
        m.gauge("pool.pages_shared", rec.pages_shared)
        m.gauge("pool.swap_bytes", rec.swap_bytes)
        m.gauge("queue.depth", rec.queue_depth)
        m.observe("queue.depth_per_tick", rec.queue_depth)
        m.observe("pool.pages_in_use_per_tick", rec.pages_in_use)

    # ----------------------------------------------------------- exporters

    def metrics_dict(self) -> dict:
        """The flat metrics dict (counters + gauges + histogram
        summaries) — ``LLMServer.metrics()`` and benchmark artifacts."""
        return self.metrics.flat()

    def _us(self, t: float) -> float:
        return (t - self.t0) * 1e6

    def export_chrome_trace(self, path: str | None = None) -> dict:
        """Chrome trace-event JSON (Perfetto-loadable). Tracks become
        threads of one process: tid 0 is the ``ticks`` track, tid 1 the
        ``queue`` track, ``slot<i>`` tracks follow in slot order, then
        any remaining tracks in first-seen order. Spans still open at
        export time are emitted closed at the export instant with
        ``"open": true``. The flat metrics dict rides along under the
        top-level ``repro_metrics`` key. Returns the trace dict;
        ``path`` additionally writes it as JSON."""
        order = {"ticks": 0, "queue": 1}

        def tid(track: str) -> int:
            if track not in order:
                if track.startswith("slot"):
                    try:  # keep slot tracks contiguous from tid 2
                        order[track] = 2 + int(track[4:])
                    except ValueError:
                        order[track] = 1000 + len(order)
                else:
                    order[track] = 1000 + len(order)
            return order[track]

        now = self.now()
        events: list = []
        for sp in self.spans:
            end = now if sp.end is None else sp.end
            args = dict(sp.attrs)
            if sp.rid is not None:
                args["rid"] = sp.rid
            if sp.end is None:
                args["open"] = True
            events.append({
                "name": sp.name, "ph": "X", "cat": "span", "pid": 0,
                "tid": tid(sp.track), "ts": self._us(sp.start),
                "dur": max(0.0, self._us(end) - self._us(sp.start)),
                "args": args})
        for name, t, track, rid, attrs in self.events:
            args = dict(attrs)
            if rid is not None:
                args["rid"] = rid
            events.append({"name": name, "ph": "i", "cat": "instant",
                           "pid": 0, "tid": tid(track),
                           "ts": self._us(t), "s": "t", "args": args})
        for rec in self.ticks:
            args = dataclasses.asdict(rec)
            del args["start"], args["wall_s"]
            events.append({
                "name": f"tick[{rec.mode}]", "ph": "X", "cat": "tick",
                "pid": 0, "tid": tid("ticks"), "ts": self._us(rec.start),
                "dur": rec.wall_s * 1e6, "args": args})
        meta = [{"name": "process_name", "ph": "M", "pid": 0,
                 "args": {"name": "repro.serving"}}]
        meta += [{"name": "thread_name", "ph": "M", "pid": 0, "tid": t,
                  "args": {"name": track}} for track, t in order.items()]
        meta += [{"name": "thread_sort_index", "ph": "M", "pid": 0,
                  "tid": t, "args": {"sort_index": t}}
                 for track, t in order.items()]
        trace = {"traceEvents": meta + events, "displayTimeUnit": "ms",
                 "repro_metrics": self.metrics_dict()}
        if path is not None:
            with open(path, "w") as f:
                json.dump(trace, f)
        return trace
