"""One page-transport layer for every mover of paged-KV bytes.

The serving stack grew three independent mechanisms that ship a request's
KV state between memory domains, each with its own ad-hoc accounting:

  * HOST SWAP — the scheduler's preempt/resume path
    (``kv_pool.export_slot`` / ``restore_slot``): device pages → host
    snapshot → device pages, bit-identical round trip.
  * TAB-Q UPLINK — ``SplitEngine``'s edge→cloud activation payload
    (TS + TAB-Q compressed hidden states; with ``paged_cloud_kv`` the
    cloud side lands in a shared page pool).
  * PAGE STREAM (new) — the disaggregated prefill→decode replica handoff
    (DistServe/Splitwise-style): a ``PrefillWorker`` runs admission +
    chunked prefill on its own pool and ships each finished request's
    int8+scale pages layer-by-layer into a ``DecodeWorker``'s pool.

:class:`PageTransport` unifies their observability: every concrete mover
records each transfer as one telemetry span on the ``"transport"`` track
(PR 7 ``Tracer`` — ``t0``/``t1``/``bytes``/``rid`` attributes, so
transfer/compute overlap is visible in the Chrome trace) plus a
per-transfer bytes histogram and running totals, and mirrors
``bytes_moved``/``transfers`` on itself for tracer-less use. The VALUES
moved are never touched — transport is accounting + copying, so every
bit-identity guarantee of the underlying mechanism survives it.

:class:`DisaggregatedScheduler` composes the workers into a drop-in
``Scheduler`` facade (the ``deployment="disaggregated"`` knob of
``serving.api.LLMServer``): one prefill replica, one decode replica, one
:class:`PageStreamTransport` between them. Because the handoff rides the
proven swap-export/restore machinery, a request's greedy stream is
bit-identical to the single-scheduler (and ``Engine.generate``) stream —
the first token is emitted by the prefill replica, every later token by
the decode replica, with contiguous event indices.
"""

from __future__ import annotations

import dataclasses

from repro.serving.kv_pool import PagedKVPool


class PageTransport:
    """Base mover: telemetry spans + bytes accounting for one transport
    kind. Subclasses set ``kind`` and call :meth:`_record` once per
    transfer; with ``telemetry=None`` every instrumented path is a strict
    no-op and only the local counters update."""

    kind = "transport"

    def __init__(self, telemetry=None):
        self.telemetry = telemetry
        self.bytes_moved = 0  # total payload bytes across transfers
        self.transfers = 0

    def _record(self, name: str, t0: float, t1: float, nbytes: float,
                rid: int | None = None, track: str = "transport",
                **attrs) -> None:
        """Account one transfer: a span (on the ``"transport"`` track
        unless the mover claims a legacy lane) plus the per-kind bytes
        histogram and running totals."""
        self.bytes_moved += int(nbytes)
        self.transfers += 1
        tel = self.telemetry
        if tel is None:
            return
        tel.add_span(name, t0, t1, track=track, rid=rid,
                     bytes=int(nbytes), transport=self.kind, **attrs)
        tel.metrics.count(f"transport.{self.kind}.transfers")
        tel.metrics.count(f"transport.{self.kind}.total_bytes", int(nbytes))
        tel.metrics.observe(f"transport.{self.kind}.bytes", float(nbytes))

    def _now(self) -> float:
        return self.telemetry.now() if self.telemetry is not None else 0.0


class HostSwapTransport(PageTransport):
    """The preempt/resume mover: device pages ⇄ host snapshot on ONE pool.
    Wraps ``kv_pool.export_slot``/``restore_slot`` with the unified
    accounting; span names stay ``"swap_out"``/``"swap_resume"`` on the
    per-slot tracks (the PR 7 lifecycle shapes)."""

    kind = "host_swap"

    def swap_out(self, pool: PagedKVPool, slot: int, n_tokens: int,
                 rid: int | None = None) -> dict:
        t0 = self._now()
        snapshot = pool.export_slot(slot, n_tokens=n_tokens)
        self._record("swap_out", t0, self._now(),
                     pool.snapshot_bytes(snapshot), rid=rid,
                     track=f"slot{slot}")
        return snapshot

    def swap_in(self, pool: PagedKVPool, snapshot: dict,
                reserve_tokens: int | None = None,
                rid: int | None = None) -> int:
        nbytes = pool.snapshot_bytes(snapshot)
        t0 = self._now()
        slot = pool.restore_slot(snapshot, reserve_tokens=reserve_tokens)
        self._record("swap_resume", t0, self._now(), nbytes, rid=rid,
                     track=f"slot{slot}")
        return slot


class TabqUplinkTransport(PageTransport):
    """The split-computing edge→cloud mover: TS+TAB-Q activation payloads
    (``SplitEngine``). The engine computes the payload itself (compression
    is model code, not transport); this class owns the WIRE accounting —
    it emits the legacy ``"uplink"`` event on the ``"split:uplink"`` track
    (the shape ``tests/test_telemetry.py`` pins) plus the unified
    transport span/histogram, with bits rounded up to whole bytes."""

    kind = "tabq_uplink"

    def uplink(self, bits: float, rid: int | None = None, **attrs) -> None:
        t = self._now()
        if self.telemetry is not None:
            self.telemetry.event("uplink", track="split:uplink", rid=rid,
                                 t=t, bits=bits, **attrs)
        self._record("uplink", t, t, -(-bits // 8), rid=rid, **attrs)


class PageStreamTransport(PageTransport):
    """The NEW mover: stream one request's written int8+scale pages from a
    prefill replica's pool into a decode replica's pool, LAYER BY LAYER
    (one span per pattern position, so the Chrome trace shows each
    layer's shipment and a pipelined implementation could overlap layer N's
    wire time with layer N+1's prefill). The payload is the swap-snapshot
    encoding — quantized codes, scales and position tags exactly as the
    pool stores them — so the decode replica's restore is bit-identical by
    the same argument as swap resume. Snapshot byte ownership moves
    src → dst (``discard_snapshot``/``adopt_snapshot``)."""

    kind = "page_stream"

    def send(self, src_pool: PagedKVPool, dst_pool: PagedKVPool,
             snapshot: dict, rid: int | None = None) -> dict:
        if src_pool.page_size != dst_pool.page_size:
            raise ValueError(
                f"page stream needs matching page sizes: prefill pool has "
                f"{src_pool.page_size}, decode pool {dst_pool.page_size}")
        shipped = []
        for layer, leaves in enumerate(snapshot["data"]):
            t0 = self._now()
            # the copy IS the wire: the receiver owns distinct buffers,
            # never views into the sender's snapshot
            moved = tuple(leaf.copy() for leaf in leaves)
            self._record("page_stream", t0, self._now(),
                         sum(leaf.nbytes for leaf in moved), rid=rid,
                         layer=layer, tokens=snapshot["length"])
            shipped.append(moved)
        out = {"length": snapshot["length"], "data": tuple(shipped)}
        src_pool.discard_snapshot(snapshot)
        dst_pool.adopt_snapshot(out)
        return out


class PrefillWorker:
    """The prefill replica: a full :class:`~repro.serving.scheduler.
    Scheduler` that admits, prefills and emits each request's FIRST token,
    then hands the request off. ``harvest()`` extracts every slot that has
    finished its prompt (>= 1 generated token, not already finished) —
    the extracted ``Request`` carries its generated tokens and the page
    snapshot the transport ships."""

    def __init__(self, scheduler):
        self.scheduler = scheduler

    def tick(self) -> None:
        if self.scheduler.pending:
            self.scheduler.step()

    def harvest(self) -> list:
        sched = self.scheduler
        ready = [st.req.rid for st in sched.slots
                 if st is not None and not st.prefilling and st.generated
                 and not st.done]
        return [sched.extract(rid) for rid in ready]


class DecodeWorker:
    """The decode replica: a full scheduler that never ``submit``s — it
    only ``inject``s transported requests, restores their pages through
    the ordinary swap-resume admission path, and decodes them to
    completion."""

    def __init__(self, scheduler):
        self.scheduler = scheduler

    def accept(self, req) -> None:
        self.scheduler.inject(req)

    def tick(self) -> None:
        if self.scheduler.pending:
            self.scheduler.step()


class DisaggregatedScheduler:
    """DistServe/Splitwise-style disaggregated serving behind the ONE
    scheduler facade ``serving.api.PagedBackend`` drives: a
    :class:`PrefillWorker` and a :class:`DecodeWorker`, each a full
    ``Scheduler`` over its OWN page pool, joined by a
    :class:`PageStreamTransport`.

    Each :meth:`step` runs one prefill-replica tick, harvests every
    request that finished its prompt (its first token is already emitted
    by the prefill replica — TTFT is a prefill-side quantity, the whole
    point of disaggregation), streams its pages across, injects it into
    the decode replica, and runs one decode-replica tick. Keyword
    arguments pass to BOTH schedulers; ``prefill_kwargs=`` /
    ``decode_kwargs=`` dicts override per side (e.g. a small prefill pool
    and a large decode pool). ``speculate_k`` applies to the DECODE
    replica only — the prefill replica never decodes past token 0, so
    drafting there is dead weight. ``page_size`` must match across the
    two pools (the stream ships raw pages).

    Greedy streams are bit-identical to a single-scheduler run and to the
    per-request ``Engine.generate`` oracle: the handoff is the proven
    swap export/restore round trip, and both replicas run the same jitted
    tick functions (``tests/test_sharded_serving.py`` pins it on the
    differential fuzz schedules)."""

    def __init__(self, cfg, params, opts=None, *, telemetry=None,
                 transport: PageStreamTransport | None = None,
                 prefill_kwargs: dict | None = None,
                 decode_kwargs: dict | None = None, **scheduler_kwargs):
        from repro.models.transformer import RuntimeOpts
        from repro.serving.scheduler import Scheduler

        opts = RuntimeOpts() if opts is None else opts
        self.telemetry = telemetry
        self.transport = transport if transport is not None \
            else PageStreamTransport(telemetry=telemetry)
        pk = dict(scheduler_kwargs)
        pk["speculate_k"] = 0  # prefill replica never decodes past token 0
        pk.update(prefill_kwargs or {})
        dk = dict(scheduler_kwargs)
        dk.update(decode_kwargs or {})
        self.prefill = Scheduler(cfg, params, opts, telemetry=telemetry,
                                 **pk)
        self.decode = Scheduler(cfg, params, opts, telemetry=None, **dk)
        if self.prefill.pool.page_size != self.decode.pool.page_size:
            raise ValueError("prefill and decode pools must share page_size")
        self.workers = (PrefillWorker(self.prefill),
                        DecodeWorker(self.decode))

    # ------------------------------------------------- scheduler facade

    def submit(self, prompt, max_new_tokens=None, eos_id=None, *,
               prefix_key=None, prefix_len=None, priority=None,
               sampling=None) -> int:
        """Requests enter through the PREFILL replica (rids are therefore
        globally unique: the decode replica only ever ``inject``s)."""
        return self.prefill.submit(prompt, max_new_tokens, eos_id,
                                   prefix_key=prefix_key,
                                   prefix_len=prefix_len, priority=priority,
                                   sampling=sampling)

    @property
    def pending(self) -> bool:
        return self.prefill.pending or self.decode.pending

    def step(self) -> bool:
        """One disaggregated tick: prefill tick → harvest → page stream →
        inject → decode tick. Returns whether work remains."""
        pre, dec = self.workers
        pre.tick()
        for req in pre.harvest():
            req.snapshot = self.transport.send(
                self.prefill.pool, self.decode.pool, req.snapshot,
                rid=req.rid)
            dec.accept(req)
        dec.tick()
        return self.pending

    def run(self) -> dict:
        while self.step():
            pass
        self.release_prefixes()
        return self.results

    def abort(self, rid: int) -> bool:
        return self.prefill.abort(rid) or self.decode.abort(rid)

    def drain_events(self) -> list:
        """Prefill-replica events first (each request's token 0), then
        decode-replica events — per-request index order is preserved
        because a request's handoff happens strictly after its first
        token and before its second."""
        return self.prefill.drain_events() + self.decode.drain_events()

    def drain_finished(self) -> list:
        return self.prefill.drain_finished() + self.decode.drain_finished()

    @property
    def results(self) -> dict:
        return {**self.prefill.results, **self.decode.results}

    @property
    def finish_reasons(self) -> dict:
        return {**self.prefill.finish_reasons, **self.decode.finish_reasons}

    def _release_dicts(self) -> tuple:
        """The REAL retained dicts (``results``/``finish_reasons`` above
        are merged copies — popping those would silently retain)."""
        return (self.prefill.results, self.prefill.finish_reasons,
                self.decode.results, self.decode.finish_reasons)

    def release_prefixes(self) -> None:
        self.prefill.release_prefixes()
        self.decode.release_prefixes()

    @property
    def stats(self):
        """Merged view over both replicas' ``SchedulerStats``: counters
        sum, peaks take the max, dict fields merge (prefill first, so
        TTFT — a prefill-replica quantity — wins on collision)."""
        merged = {}
        for f in dataclasses.fields(self.prefill.stats):
            a = getattr(self.prefill.stats, f.name)
            b = getattr(self.decode.stats, f.name)
            if isinstance(a, dict):
                merged[f.name] = {**b, **a}
            elif f.name.startswith("peak_"):
                merged[f.name] = max(a, b)
            else:
                merged[f.name] = a + b
        return type(self.prefill.stats)(**merged)
