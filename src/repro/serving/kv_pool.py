"""Paged KV-cache pool: one shared block pool, per-request block tables.

The paper's serving constraint is Eq. (2) — the KV cache is the term that
grows with every generated token — and the dense per-request cache the seed
engine allocates wastes exactly the memory the optimizer is trying to
budget: every request holds ``cache_len`` slots regardless of its actual
length, and a batch must be bucketed to equal prompt lengths to share the
allocation. This module replaces that with the vLLM-style design: a single
fixed-size pool of ``page_size``-token pages (int8 codes + f32 scales per
page, ``kv_pos = -1`` marking empty slots), an allocator with free-list
reuse, and per-request block tables ``(R, max_blocks) int32`` that the
paged decode-attention kernel walks via its scalar-prefetch index map.

Layout per pattern position (leading ``num_blocks`` axis consumed by the
transformer's block scan, exactly like the dense caches):

  k / v          (nb, P, K, page, hd) int8
  k/v_scale      (nb, P, K, page)     f32
  pos            (nb, P, page)        int32   (-1 = empty)
  block_table    (nb, R, max_blocks)  int32   (host-owned, installed per call)

Page 0 is RESERVED as the trash page: block-table entries of inactive rows
and pad-token writes point at it, its positions stay -1, and the kernel's
validity mask keeps it out of every softmax. The allocator therefore hands
out pages [1, P).

Lifecycle (driven by ``serving.scheduler``):
  admit  — reserve ceil(prompt/page) pages + a slot row for a request
  append — extend a live request's page list when its length crosses a
           page boundary (raises ``PoolExhaustedError`` when the pool is
           full — the scheduler's backpressure signal)
  free   — return a finished request's pages to the free list (LIFO reuse)
           and scrub their stored positions to -1 on device, so a future
           request reusing the page can never attend stale tokens

Occupancy is accounted two ways: ``page_bytes_in_use`` (page-granular, what
the device actually holds, internal fragmentation included) and
``eq2_bytes`` (the paper's analytical B_kv via ``core.opsc.kv_cache_bytes``
at the pool's int8 activation width) — the gap between them IS the paging
overhead the benchmark reports.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, AttnSpec
from repro.models.layers import PagedKVCache

TRASH_PAGE = 0
DEFAULT_PAGE_SIZE = 16


class PoolExhaustedError(RuntimeError):
    """Raised when an admit/append needs more pages than the pool has free."""


def uniform_page_count(seq_len: int, page_size: int) -> int:
    """Pages needed to hold ``seq_len`` tokens in UNIFORM ``page_size`` pages
    (``kernels.decode_attention.padded_cache_len(s, page_size, uniform=True)``
    is the same rounding in token units)."""
    return max(1, -(-seq_len // page_size))


class PagedKVPool:
    """Fixed-size paged KV pool + host-side block allocator (see module doc).

    ``cfg`` must be an attention-only pattern without sliding windows (ring
    writes inside fixed pages are a follow-on); ``num_blocks`` overrides
    ``cfg.num_blocks`` so a split engine can pool just its cloud segment.
    """

    def __init__(self, cfg: ArchConfig, *, num_pages: int,
                 page_size: int = DEFAULT_PAGE_SIZE, max_requests: int,
                 max_seq_len: int | None = None, num_blocks: int | None = None):
        if page_size <= 0:
            raise ValueError(f"page_size must be positive, got {page_size}")
        if num_pages < 2:
            raise ValueError("num_pages must be >= 2 (page 0 is reserved)")
        self.specs = []
        for ls in cfg.pattern:
            m = ls.mixer
            if not isinstance(m, AttnSpec):
                raise NotImplementedError(
                    "PagedKVPool covers attention-only patterns; SSM/hybrid "
                    f"states are fixed-size (no paging needed), got {m.kind}")
            if m.sliding_window is not None:
                raise NotImplementedError(
                    "sliding-window layers ring-write inside their window; "
                    "paged ring-append is not supported yet")
            self.specs.append(m)
        if len({(m.num_kv_heads, m.head_dim) for m in self.specs}) != 1:
            raise NotImplementedError(
                "pattern positions must share (num_kv_heads, head_dim)")

        self.cfg = cfg
        self.nb = cfg.num_blocks if num_blocks is None else num_blocks
        self.num_pages = num_pages
        self.page_size = page_size
        self.max_requests = max_requests
        max_seq_len = (num_pages - 1) * page_size if max_seq_len is None \
            else max_seq_len
        self.max_blocks = uniform_page_count(max_seq_len, page_size)
        self.num_layers = self.nb * len(cfg.pattern)

        kh, hd = self.specs[0].num_kv_heads, self.specs[0].head_dim
        self.kv_heads, self.head_dim = kh, hd
        nb, p, ps = self.nb, num_pages, page_size
        self._caches = tuple(
            PagedKVCache(
                k=jnp.zeros((nb, p, kh, ps, hd), jnp.int8),
                v=jnp.zeros((nb, p, kh, ps, hd), jnp.int8),
                k_scale=jnp.zeros((nb, p, kh, ps), jnp.float32),
                v_scale=jnp.zeros((nb, p, kh, ps), jnp.float32),
                pos=jnp.full((nb, p, ps), -1, jnp.int32),
                block_table=jnp.zeros((nb, max_requests, self.max_blocks),
                                      jnp.int32),
            )
            for _ in cfg.pattern)

        # host allocator state: LIFO free list (most-recently-freed page is
        # reused first — keeps the hot pages hot), trash page 0 excluded
        self._free = list(range(num_pages - 1, 0, -1))
        self.block_tables = np.zeros((max_requests, self.max_blocks), np.int32)
        self.lengths = np.zeros((max_requests,), np.int64)
        self.active = np.zeros((max_requests,), bool)

    # ------------------------------------------------------------ allocator

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def pages_in_use(self) -> int:
        return (self.num_pages - 1) - len(self._free)

    def pages_for(self, n_tokens: int) -> int:
        return uniform_page_count(n_tokens, self.page_size)

    def can_admit(self, prompt_len: int) -> bool:
        return (not self.active.all()
                and self.pages_for(prompt_len) <= len(self._free)
                and self.pages_for(prompt_len) <= self.max_blocks)

    def admit(self, prompt_len: int, reserve_tokens: int | None = None) -> int:
        """Reserve a slot row + the prompt's pages; returns the slot index.
        Capacity is checked BEFORE any state changes, so a failed admit
        leaks nothing.

        ``reserve_tokens`` reserves pages for MORE than the prompt up front
        (typically prompt + max_new_tokens — the scheduler's worst-case
        admission control): a request admitted this way can never hit an
        exhausted pool mid-decode, because concurrent lazy growers can
        otherwise deadlock each other one page short of finishing."""
        if prompt_len < 1:
            raise ValueError("cannot admit an empty prompt")
        free_slots = np.flatnonzero(~self.active)
        if free_slots.size == 0:
            raise PoolExhaustedError(
                f"no free request slots (all {self.max_requests} active)")
        need = self.pages_for(max(prompt_len, reserve_tokens or 0))
        if need > self.max_blocks:
            raise PoolExhaustedError(
                f"prompt needs {need} pages > max_blocks {self.max_blocks}")
        if need > len(self._free):
            raise PoolExhaustedError(
                f"KV pool exhausted: prompt needs {need} page(s), "
                f"{len(self._free)} free of {self.num_pages - 1}")
        slot = int(free_slots[0])
        self.active[slot] = True
        self.lengths[slot] = 0
        self._grow(slot, need)
        return slot

    def commit_prefill(self, slot: int, n_tokens: int) -> None:
        """Record that the prompt's ``n_tokens`` were written by a prefill —
        pages were already reserved by ``admit``, this only sets the length
        (callers must not poke ``lengths`` directly; the decode path's
        ``append`` arithmetic builds on it)."""
        assert self.active[slot], f"slot {slot} is not active"
        assert self.lengths[slot] == 0, f"slot {slot} already prefilled"
        self._grow(slot, self.pages_for(n_tokens))  # no-op unless under-admitted
        self.lengths[slot] = n_tokens

    def append(self, slot: int, n_tokens: int = 1) -> None:
        """Account ``n_tokens`` about to be written to ``slot``, allocating a
        new page when the length crosses a page boundary."""
        assert self.active[slot], f"slot {slot} is not active"
        new_len = int(self.lengths[slot]) + n_tokens
        self._grow(slot, self.pages_for(new_len))
        self.lengths[slot] = new_len

    def _grow(self, slot: int, want_pages: int) -> None:
        have = int(np.count_nonzero(self.block_tables[slot]))
        if want_pages > self.max_blocks:
            raise PoolExhaustedError(
                f"request needs {want_pages} pages > max_blocks "
                f"{self.max_blocks} (max_seq_len too small)")
        need = want_pages - have
        if need > len(self._free):
            raise PoolExhaustedError(
                f"KV pool exhausted: slot {slot} needs {need} more "
                f"page(s), {len(self._free)} free of {self.num_pages - 1}")
        for b in range(have, want_pages):
            self.block_tables[slot, b] = self._free.pop()

    def free(self, slot: int) -> None:
        """Return a finished request's pages (LIFO) and scrub their stored
        positions on device so a reusing request can never attend stale
        tokens (the paged analogue of a fresh dense-cache init)."""
        assert self.active[slot], f"slot {slot} is not active"
        pages = [int(p) for p in self.block_tables[slot] if p != TRASH_PAGE]
        if pages:
            idx = jnp.asarray(pages, jnp.int32)
            self._caches = tuple(
                dataclasses.replace(c, pos=c.pos.at[:, idx].set(-1))
                for c in self._caches)
            self._free.extend(reversed(pages))
        self.block_tables[slot] = TRASH_PAGE
        self.lengths[slot] = 0
        self.active[slot] = False

    # ------------------------------------------------------- device plumbing

    def device_caches(self, rows=None) -> tuple:
        """The pool pytree with the CURRENT block tables installed —
        ``rows`` selects a sub-batch (e.g. the freshly admitted requests for
        a ragged prefill); default is every slot row."""
        bt = self.block_tables if rows is None else self.block_tables[rows]
        bt = jnp.broadcast_to(jnp.asarray(bt, jnp.int32)[None],
                              (self.nb,) + bt.shape)
        return tuple(dataclasses.replace(c, block_table=bt)
                     for c in self._caches)

    def update_from(self, new_caches: tuple) -> None:
        """Adopt the pool arrays a jitted prefill/decode step returned (the
        block tables riding in the pytree are per-call views; the host copy
        stays authoritative)."""
        for c in new_caches:
            if c.k.shape[-2] != self.page_size:
                raise ValueError(
                    f"non-uniform page size: pool pages are {self.page_size} "
                    f"tokens, got {c.k.shape[-2]}; pages must be uniform — "
                    f"round lengths with padded_cache_len(s, "
                    f"{self.page_size}, uniform=True) before paging")
        self._caches = tuple(
            dataclasses.replace(c, block_table=old.block_table)
            for c, old in zip(new_caches, self._caches))

    def gather_dense(self, slot: int) -> tuple:
        """Reassemble ``slot``'s cache densely from its pages (tests/debug):
        returns (k_codes, k_scale, v_codes, v_scale, pos) with leading nb."""
        from repro.kernels.ref import gather_pages_ref

        bt = jnp.asarray(self.block_tables[slot][None], jnp.int32)  # (1, mb)
        outs = []
        for c in self._caches:
            leaves = []
            for leaf in (c.k, c.v, c.k_scale, c.v_scale, c.pos):
                g = jnp.stack([gather_pages_ref(leaf[i], bt)[0]
                               for i in range(self.nb)])
                leaves.append(g)
            outs.append(tuple(leaves))
        return tuple(outs)

    # ----------------------------------------------------------- accounting

    def page_bytes(self) -> int:
        """Device bytes of ONE page across every covered layer."""
        kh, hd, ps = self.kv_heads, self.head_dim, self.page_size
        per_layer = 2 * kh * ps * hd * 1 + 2 * kh * ps * 4 + ps * 4
        return per_layer * self.num_layers

    def page_bytes_in_use(self) -> int:
        """Page-granular occupancy: what the allocated pages actually hold
        (internal fragmentation AND worst-case reservation included)."""
        return self.pages_in_use * self.page_bytes()

    def page_bytes_written(self) -> int:
        """Page-granular bytes of pages that hold at least one token —
        what a page-level KV shipment actually has to move (reserved-but-
        empty pages excluded, unlike :meth:`page_bytes_in_use`)."""
        return self.page_bytes() * sum(
            self.pages_for(int(self.lengths[slot]))
            for slot in np.flatnonzero(self.active) if self.lengths[slot] > 0)

    def eq2_bytes(self, qa_bits: int = 8) -> int:
        """The paper's analytical B_kv (Eq. 2 via ``core.opsc.
        kv_cache_bytes``) summed over resident requests at the pool's int8
        activation width — the quantity the OPSC optimizer constrains.
        ``page_bytes_in_use() - eq2_bytes()``-ish gap = paging overhead."""
        from repro.core.opsc import kv_cache_bytes

        total = 0
        for slot in np.flatnonzero(self.active):
            w = int(self.lengths[slot])
            if w > 0:
                total += kv_cache_bytes(w, self.num_layers, self.num_layers,
                                        self.kv_heads * self.head_dim,
                                        qa_bits, qa_bits)
        return total

    def occupancy(self) -> float:
        """Fraction of allocatable pages currently in use."""
        return self.pages_in_use / max(1, self.num_pages - 1)
