"""Paged KV-cache pool: one shared block pool, per-request block tables,
refcounted copy-on-write pages for multi-tenant prefix sharing.

The paper's serving constraint is Eq. (2) — the KV cache is the term that
grows with every generated token — and the dense per-request cache the seed
engine allocates wastes exactly the memory the optimizer is trying to
budget: every request holds ``cache_len`` slots regardless of its actual
length, and a batch must be bucketed to equal prompt lengths to share the
allocation. This module replaces that with the vLLM-style design: a single
fixed-size pool of ``page_size``-token pages (int8 codes + f32 scales per
page, ``kv_pos = -1`` marking empty slots), an allocator with free-list
reuse, and per-request block tables ``(R, max_blocks) int32`` that the
paged decode-attention kernel walks via its scalar-prefetch index map.

Layout per pattern position (leading ``num_blocks`` axis consumed by the
transformer's block scan, exactly like the dense caches):

  k / v          (nb, P, K, page, hd) int8
  k/v_scale      (nb, P, K, page)     f32
  pos            (nb, P, page)        int32   (-1 = empty)
  block_table    (nb, R, max_blocks)  int32   (host-owned, installed per call)

Page 0 is RESERVED as the trash page: block-table entries of inactive rows
and pad-token writes point at it, its positions stay -1, and the kernel's
validity mask keeps it out of every softmax. The allocator therefore hands
out pages [1, P).

Ownership model (the refcount state machine):

  Every non-trash page carries a host-side refcount. A reference is held by
  (a) each active slot whose block table names the page, and (b) each live
  :class:`SharedPrefix` handle that pins it. Pages move through exactly
  three states::

      free ──admit/append──▶ owned (refcount 1)
      owned ──share_prefix / admit(prefix=…)──▶ shared (refcount ≥ 2)
      shared ──decref──▶ owned ──decref──▶ free (positions scrubbed)

  Writes are only legal into pages the writer owns EXCLUSIVELY (refcount 1
  through its own table entry). ``reserve_write`` enforces this with
  copy-on-write: when the next token would land in a shared page, the page
  is copied on device to a fresh page (stored positions ≥ the writer's
  length scrubbed to -1, so another tenant's tokens can never leak into
  the copy), the writer's table entry is repointed, and the shared page is
  decref'd. Freeing is always a decref; only a page reaching refcount 0 is
  scrubbed and returned to the free list — so a double free is an assert,
  never silent reuse.

Lifecycle (driven by ``serving.scheduler``):
  admit        — reserve a slot row + pages for the prompt (and optionally a
                 worst-case ``reserve_tokens``); with ``prefix=`` the slot
                 attaches to a shared prefix's pages instead of allocating
  share_prefix — pin a slot's leading pages as a :class:`SharedPrefix` that
                 outlives the slot (system prompts, beams)
  append       — extend a live request's page list when its length crosses a
                 page boundary, CoW-copying a shared boundary page first
                 (raises ``PoolExhaustedError`` when the pool is full — the
                 scheduler's backpressure/preemption signal)
  free         — decref a finished request's pages; pages reaching zero are
                 scrubbed (-1 positions) on device and returned LIFO

Occupancy is accounted two ways: ``page_bytes_in_use`` (page-granular, what
the device actually holds — internal fragmentation included, shared pages
counted ONCE) and ``eq2_bytes`` (the paper's analytical B_kv via
``core.opsc.kv_cache_bytes`` summed PER REQUEST at the pool's int8
activation width). The gap between them is the paging overhead minus the
sharing win: with prefix sharing, ``eq2_bytes`` double-counts the shared
tokens that the pool physically holds once (``core.opsc.
kv_cache_bytes_shared`` is the sharing-aware analytical model).
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, AttnSpec
from repro.models.layers import PagedKVCache

TRASH_PAGE = 0
DEFAULT_PAGE_SIZE = 16


class PoolExhaustedError(RuntimeError):
    """Raised when an admit/append needs more pages than the pool has free."""


def uniform_page_count(seq_len: int, page_size: int) -> int:
    """Pages needed to hold ``seq_len`` tokens in UNIFORM ``page_size`` pages
    (``kernels.decode_attention.padded_cache_len(s, page_size, uniform=True)``
    is the same rounding in token units)."""
    return max(1, -(-seq_len // page_size))


@dataclasses.dataclass
class SharedPrefix:
    """Handle to a pinned run of pool pages holding a shared prompt prefix.

    ``pages`` are physical page ids in position order covering the first
    ``n_tokens`` TOKENS of some prefilled request; the handle OWNS one
    refcount reference per page, so the prefix outlives the request that
    wrote it. Slots attach with ``PagedKVPool.admit(..., prefix=handle)``
    (each attachment adds one more reference per page) and the registry that
    created the handle releases it with ``PagedKVPool.release_prefix`` —
    until then the pages can never be scrubbed or reused.

    The creator must guarantee the covered tokens are (or will be, before
    any fork attends them) written: ``share_prefix`` checks page coverage,
    not device contents."""

    pages: tuple
    n_tokens: int
    released: bool = False

    @property
    def num_pages(self) -> int:
        return len(self.pages)


class PagedKVPool:
    """Fixed-size paged KV pool + host-side refcounting block allocator
    (see module doc for the ownership model).

    ``cfg`` must be an attention-only pattern without sliding windows (ring
    writes inside fixed pages are a follow-on); ``num_blocks`` overrides
    ``cfg.num_blocks`` so a split engine can pool just its cloud segment.

    ``mesh=`` (a ``("kv", "model")`` mesh from ``repro.launch.mesh.
    make_serving_mesh``) turns on sharded mode: every pool leaf's PAGE axis
    is laid out over the mesh's ``kv`` axis via ``NamedSharding`` (the page
    count is rounded up to divide evenly), block tables stay replicated,
    and the host-side allocator / refcount / CoW / truncate logic is
    byte-for-byte the single-device logic — sharding only changes WHERE
    pages live, never which request owns them.

    Units note (applies to every method): ``*_tokens``/``*_len`` arguments
    count TOKENS, ``pages_*``/``*_pages`` count fixed-size PAGES, and
    ``*_bytes`` are device bytes across every covered layer."""

    def __init__(self, cfg: ArchConfig, *, num_pages: int,
                 page_size: int = DEFAULT_PAGE_SIZE, max_requests: int,
                 max_seq_len: int | None = None, num_blocks: int | None = None,
                 mesh=None):
        if page_size <= 0:
            raise ValueError(f"page_size must be positive, got {page_size}")
        if num_pages < 2:
            raise ValueError("num_pages must be >= 2 (page 0 is reserved)")
        if mesh is not None:
            # sharded mode: the PAGE axis (axis 1 of every leaf) is split
            # over the mesh's "kv" axis; round the page count up so it
            # divides evenly (extra pages just enlarge the free list)
            kv_size = mesh.shape["kv"]
            num_pages = -(-num_pages // kv_size) * kv_size
        self.specs = []
        for ls in cfg.pattern:
            m = ls.mixer
            if not isinstance(m, AttnSpec):
                raise NotImplementedError(
                    "PagedKVPool covers attention-only patterns; SSM/hybrid "
                    f"states are fixed-size (no paging needed), got {m.kind}")
            if m.sliding_window is not None:
                raise NotImplementedError(
                    "sliding-window layers ring-write inside their window; "
                    "paged ring-append is not supported yet")
            self.specs.append(m)
        if len({(m.num_kv_heads, m.head_dim) for m in self.specs}) != 1:
            raise NotImplementedError(
                "pattern positions must share (num_kv_heads, head_dim)")

        self.cfg = cfg
        self.nb = cfg.num_blocks if num_blocks is None else num_blocks
        self.num_pages = num_pages
        self.page_size = page_size
        self.max_requests = max_requests
        max_seq_len = (num_pages - 1) * page_size if max_seq_len is None \
            else max_seq_len
        self.max_blocks = uniform_page_count(max_seq_len, page_size)
        self.num_layers = self.nb * len(cfg.pattern)

        kh, hd = self.specs[0].num_kv_heads, self.specs[0].head_dim
        self.kv_heads, self.head_dim = kh, hd
        nb, p, ps = self.nb, num_pages, page_size
        self._caches = tuple(
            PagedKVCache(
                k=jnp.zeros((nb, p, kh, ps, hd), jnp.int8),
                v=jnp.zeros((nb, p, kh, ps, hd), jnp.int8),
                k_scale=jnp.zeros((nb, p, kh, ps), jnp.float32),
                v_scale=jnp.zeros((nb, p, kh, ps), jnp.float32),
                pos=jnp.full((nb, p, ps), -1, jnp.int32),
                block_table=jnp.zeros((nb, max_requests, self.max_blocks),
                                      jnp.int32),
            )
            for _ in cfg.pattern)

        # sharded mode: pin each leaf's placement — pages split over the
        # "kv" mesh axis, block tables replicated. The allocator / refcount
        # / CoW / truncate logic below is untouched: host-driven `.at`
        # updates may produce unplaced results, so :meth:`device_caches`
        # re-applies these shardings before every jitted step (a no-op when
        # the array is already placed correctly).
        self.mesh = mesh
        self._page_sharding = self._repl_sharding = None
        if mesh is not None:
            from jax.sharding import NamedSharding, PartitionSpec as P

            self._page_sharding = NamedSharding(mesh, P(None, "kv"))
            self._repl_sharding = NamedSharding(mesh, P())
            self._caches = tuple(self._place(c) for c in self._caches)

        # host allocator state: LIFO free list (most-recently-freed page is
        # reused first — keeps the hot pages hot), trash page 0 excluded,
        # and per-page refcounts (0 = free, 1 = exclusively owned,
        # >= 2 = shared / copy-on-write)
        self._free = list(range(num_pages - 1, 0, -1))
        self.refcount = np.zeros((num_pages,), np.int32)
        self.block_tables = np.zeros((max_requests, self.max_blocks), np.int32)
        self.lengths = np.zeros((max_requests,), np.int64)
        self.active = np.zeros((max_requests,), bool)
        # host BYTES held by live export_slot snapshots (the scheduler's
        # swap-resume preemption): export adds, restore/discard subtracts
        self.swap_bytes = 0

    # ------------------------------------------------------------ allocator

    @property
    def free_pages(self) -> int:
        """Count of PAGES currently on the free list."""
        return len(self._free)

    @property
    def pages_in_use(self) -> int:
        """Count of allocated PAGES — each shared page counts ONCE (physical
        residency, not the sum of logical references)."""
        return (self.num_pages - 1) - len(self._free)

    @property
    def pages_shared(self) -> int:
        """Count of PAGES currently referenced by more than one owner."""
        return int(np.sum(self.refcount > 1))

    def pages_for(self, n_tokens: int) -> int:
        """PAGES needed to hold ``n_tokens`` TOKENS (≥ 1)."""
        return uniform_page_count(n_tokens, self.page_size)

    def _alloc(self) -> int:
        """Pop one page off the free list with refcount 1 (caller has
        already checked capacity)."""
        page = self._free.pop()
        assert self.refcount[page] == 0, f"free list held live page {page}"
        self.refcount[page] = 1
        return page

    def _decref(self, pages) -> None:
        """Drop one reference per page; pages reaching zero are scrubbed on
        device (stored positions → -1, so a reusing request can never attend
        stale tokens) and returned to the free list LIFO."""
        dead = []
        for p in pages:
            p = int(p)
            assert self.refcount[p] > 0, f"double free of page {p}"
            self.refcount[p] -= 1
            if self.refcount[p] == 0:
                dead.append(p)
        if dead:
            idx = jnp.asarray(dead, jnp.int32)
            self._caches = tuple(
                dataclasses.replace(c, pos=c.pos.at[:, idx].set(-1))
                for c in self._caches)
            self._free.extend(reversed(dead))

    def _copy_page(self, src: int, dst: int, keep_below: int) -> None:
        """Copy-on-write device copy of page ``src`` → ``dst`` across every
        layer, keeping only stored positions < ``keep_below`` TOKENS (the
        forker's own history; another tenant's tokens past the shared prefix
        are scrubbed to -1 in the copy so they can never leak into the
        forker's attention)."""
        def cp(c):
            pos_src = c.pos[:, src]
            return dataclasses.replace(
                c,
                k=c.k.at[:, dst].set(c.k[:, src]),
                v=c.v.at[:, dst].set(c.v[:, src]),
                k_scale=c.k_scale.at[:, dst].set(c.k_scale[:, src]),
                v_scale=c.v_scale.at[:, dst].set(c.v_scale[:, src]),
                pos=c.pos.at[:, dst].set(
                    jnp.where(pos_src < keep_below, pos_src, -1)))

        self._caches = tuple(cp(c) for c in self._caches)

    def _write_need(self, length: int, have: int, boundary_shared: bool,
                    n_tokens: int):
        """THE growth formula, shared by :meth:`reserve_write` (actual
        writes) and :meth:`_fork_cost` (pre-attach admission check) so the
        two can never drift apart — admit's leak-free guarantee rests on
        the pre-check and the later reserve computing identical needs.
        Returns (cow_pages, new_pages, want_pages) for writing ``n_tokens``
        TOKENS past ``length`` given ``have`` allocated pages whose
        boundary page is (``boundary_shared``) refcount-shared."""
        if n_tokens <= 0:
            return 0, 0, have
        want = self.pages_for(length + n_tokens)
        boundary = length // self.page_size
        cow = 1 if (boundary < have and boundary_shared) else 0
        return cow, max(0, want - have), want

    def _fork_cost(self, prefix: SharedPrefix, target_tokens: int):
        """(pages needed from the free list NOW, eventual table pages) for
        admitting a request of ``target_tokens`` TOKENS onto ``prefix`` —
        includes the CoW copy of a partially-filled boundary page (the
        boundary is always shared at fork time: the handle plus the new
        slot both reference it)."""
        cow, new, want = self._write_need(
            prefix.n_tokens, prefix.num_pages, True,
            target_tokens - prefix.n_tokens)
        return cow + new, max(want, prefix.num_pages)

    def can_admit(self, n_tokens: int, prefix: SharedPrefix | None = None
                  ) -> bool:
        """Whether :meth:`admit` for ``n_tokens`` TOKENS (the admission
        target: prompt, or prompt + worst-case generation) would succeed."""
        if self.active.all():
            return False
        if prefix is not None:
            if prefix.released or n_tokens < prefix.n_tokens:
                return False
            need, want = self._fork_cost(prefix, n_tokens)
        else:
            need = want = self.pages_for(n_tokens)
        return need <= len(self._free) and want <= self.max_blocks

    def admit(self, prompt_len: int, reserve_tokens: int | None = None,
              prefix: SharedPrefix | None = None) -> int:
        """Reserve a slot row + pages; returns the slot index. Capacity is
        checked BEFORE any state changes, so a failed admit leaks nothing.

        ``prompt_len`` / ``reserve_tokens`` count TOKENS. ``reserve_tokens``
        reserves pages for MORE than the prompt up front (typically
        prompt + max_new_tokens — worst-case admission control): a request
        admitted this way can never hit an exhausted pool mid-decode. A
        lazily-grown request (no reserve) relies on the caller to handle
        ``PoolExhaustedError`` from :meth:`append` — e.g. the scheduler's
        preemption path.

        ``prefix`` attaches the slot to a :class:`SharedPrefix`: the slot's
        leading block-table entries alias the prefix's pages (one refcount
        reference each), its length starts at ``prefix.n_tokens``, and only
        the suffix pages (plus, for a non-page-aligned prefix, one CoW copy
        of the boundary page) are newly allocated — the physical-memory win
        of prefix sharing. The slot owns its references until :meth:`free`.
        """
        if prompt_len < 1:
            raise ValueError("cannot admit an empty prompt")
        free_slots = np.flatnonzero(~self.active)
        if free_slots.size == 0:
            raise PoolExhaustedError(
                f"no free request slots (all {self.max_requests} active)")
        target = max(prompt_len, reserve_tokens or 0)
        if prefix is not None:
            if prefix.released:
                raise ValueError("cannot admit onto a released SharedPrefix")
            if prompt_len < prefix.n_tokens:
                raise ValueError(
                    f"prompt ({prompt_len} tokens) shorter than its shared "
                    f"prefix ({prefix.n_tokens} tokens)")
            need, want = self._fork_cost(prefix, target)
            if want > self.max_blocks:
                raise PoolExhaustedError(
                    f"request needs {want} pages > max_blocks "
                    f"{self.max_blocks}")
            if need > len(self._free):
                raise PoolExhaustedError(
                    f"KV pool exhausted: fork needs {need} page(s) beyond "
                    f"the {prefix.num_pages} shared, {len(self._free)} free "
                    f"of {self.num_pages - 1}")
            slot = int(free_slots[0])
            self.active[slot] = True
            for b, p in enumerate(prefix.pages):
                self.block_tables[slot, b] = p
                self.refcount[p] += 1
            self.lengths[slot] = prefix.n_tokens
            # CoW the boundary page + allocate the suffix pages (cannot
            # raise: need was checked against the same formula above)
            self.reserve_write(slot, target - prefix.n_tokens)
            return slot
        need = self.pages_for(target)
        if need > self.max_blocks:
            raise PoolExhaustedError(
                f"prompt needs {need} pages > max_blocks {self.max_blocks}")
        if need > len(self._free):
            raise PoolExhaustedError(
                f"KV pool exhausted: prompt needs {need} page(s), "
                f"{len(self._free)} free of {self.num_pages - 1}")
        slot = int(free_slots[0])
        self.active[slot] = True
        self.lengths[slot] = 0
        self.reserve_write(slot, target)
        return slot

    def share_prefix(self, slot: int, n_tokens: int) -> SharedPrefix:
        """Pin ``slot``'s pages covering its first ``n_tokens`` TOKENS as a
        :class:`SharedPrefix` (one new refcount reference per page, owned by
        the returned handle). The pages survive ``free(slot)`` until
        :meth:`release_prefix` drops the handle's references.

        The caller guarantees those tokens are written (scheduler: share
        after ``commit_prefill``) or will be written before any fork attends
        them (split engine: rows prefill in the same device call)."""
        assert self.active[slot], f"slot {slot} is not active"
        if n_tokens < 1:
            raise ValueError("a shared prefix must cover at least one token")
        npages = self.pages_for(n_tokens)
        pages = [int(p) for p in self.block_tables[slot][:npages]]
        if TRASH_PAGE in pages:
            raise ValueError(
                f"slot {slot} has only "
                f"{int(np.count_nonzero(self.block_tables[slot]))} pages "
                f"allocated; cannot share a {n_tokens}-token prefix")
        for p in pages:
            self.refcount[p] += 1
        return SharedPrefix(tuple(pages), int(n_tokens))

    def release_prefix(self, prefix: SharedPrefix) -> None:
        """Drop the handle's page references; pages reaching refcount 0 are
        scrubbed and returned to the free list. Idempotent."""
        if prefix.released:
            return
        prefix.released = True
        self._decref(prefix.pages)

    def reserve_write(self, slot: int, n_tokens: int) -> None:
        """Make the next ``n_tokens`` TOKEN positions of ``slot`` writable
        WITHOUT changing its length: CoW-copy a shared boundary page, then
        allocate pages out to ``pages_for(length + n_tokens)``. All capacity
        checks happen before any state changes (a failed reserve leaks
        nothing — the scheduler's preempt-and-retry path depends on this).

        Callers never invoke this directly in the normal lifecycle —
        :meth:`admit` and :meth:`append` drive it — but the split engine's
        pool and tests may use it to stage capacity explicitly."""
        assert self.active[slot], f"slot {slot} is not active"
        if n_tokens <= 0:
            return
        length = int(self.lengths[slot])
        have = int(np.count_nonzero(self.block_tables[slot]))
        boundary = length // self.page_size
        boundary_shared = (
            boundary < have
            and self.refcount[self.block_tables[slot, boundary]] > 1)
        cow, new_pages, want = self._write_need(length, have,
                                                boundary_shared, n_tokens)
        if want > self.max_blocks:
            raise PoolExhaustedError(
                f"request needs {want} pages > max_blocks "
                f"{self.max_blocks} (max_seq_len too small)")
        if cow + new_pages > len(self._free):
            raise PoolExhaustedError(
                f"KV pool exhausted: slot {slot} needs {cow + new_pages} "
                f"more page(s), {len(self._free)} free of "
                f"{self.num_pages - 1}")
        if cow:
            old = int(self.block_tables[slot, boundary])
            new = self._alloc()
            self._copy_page(old, new, keep_below=length)
            self.block_tables[slot, boundary] = new
            self._decref([old])
        for b in range(have, want):
            self.block_tables[slot, b] = self._alloc()

    def commit_prefill(self, slot: int, n_tokens: int) -> None:
        """Record that the request's first ``n_tokens`` TOKENS were written
        by a prefill — pages were already reserved by ``admit`` (including
        any shared-prefix pages, which count toward ``n_tokens``), this only
        sets the length (callers must not poke ``lengths`` directly; the
        decode path's ``append`` arithmetic builds on it)."""
        assert self.active[slot], f"slot {slot} is not active"
        length = int(self.lengths[slot])
        assert length <= n_tokens, \
            f"slot {slot} already holds {length} > {n_tokens} tokens"
        if self.pages_for(n_tokens) > int(
                np.count_nonzero(self.block_tables[slot])):
            # legacy under-admitted growth: the device writes past the
            # reserved pages were routed to the trash page (lost), but the
            # accounting stays consistent
            self.reserve_write(slot, n_tokens - length)
        self.lengths[slot] = n_tokens

    def append(self, slot: int, n_tokens: int = 1) -> None:
        """Account ``n_tokens`` TOKENS about to be written to ``slot``:
        CoW-copies a shared boundary page and allocates a new page when the
        length crosses a page boundary. Raises ``PoolExhaustedError`` (with
        no state change) when the pool is full — the backpressure signal
        the scheduler's preemption path consumes."""
        assert self.active[slot], f"slot {slot} is not active"
        self.reserve_write(slot, n_tokens)
        self.lengths[slot] = int(self.lengths[slot]) + n_tokens

    def truncate(self, slot: int, new_len: int) -> None:
        """Roll ``slot`` back to ``new_len`` TOKENS — the speculative-decode
        rejection primitive: a verify round appends the draft burst
        optimistically, then truncates away the rejected tail.

        Stored positions >= ``new_len`` are scrubbed to -1 on device in the
        pages covering them, so the next step can never attend a rejected
        token (the varlen/paged history masks drop pos -1, and the swap
        exporter would otherwise snapshot the stale entries). The pages
        themselves STAY allocated: they sit inside the slot's reservation
        and the very next append rewrites the same page slots, so freeing
        and re-allocating them would only churn the free list and re-raise
        mid-tick ``PoolExhaustedError`` risk.

        CoW safety: a page is only scrubbed if this slot owns it
        EXCLUSIVELY. Shared pages hold immutable prefix tokens — drafts are
        only ever written past the prefix into exclusively-owned (possibly
        CoW-copied) pages — so a rollback reaching into a refcount > 1 page
        is a caller bug and raises ``ValueError`` with no state change
        (pinned by the property walk in ``tests/test_kv_pool.py``)."""
        assert self.active[slot], f"slot {slot} is not active"
        length = int(self.lengths[slot])
        if not 0 < new_len <= length:
            raise ValueError(f"truncate to {new_len} outside (0, {length}]")
        if new_len == length:
            return
        first = new_len // self.page_size  # boundary page: may keep a head
        pages = [int(p) for p in self.block_tables[slot][first:self.pages_for(length)]
                 if p != TRASH_PAGE]
        shared = [p for p in pages if self.refcount[p] > 1]
        if shared:
            raise ValueError(
                f"truncate({slot}, {new_len}) would scrub shared page(s) "
                f"{shared} (refcount > 1): CoW-shared prefixes are immutable")
        if pages:
            idx = jnp.asarray(pages, jnp.int32)
            self._caches = tuple(
                dataclasses.replace(c, pos=c.pos.at[:, idx].set(
                    jnp.where(c.pos[:, idx] >= new_len, -1, c.pos[:, idx])))
                for c in self._caches)
        self.lengths[slot] = new_len

    def free(self, slot: int) -> None:
        """Return a finished request's page REFERENCES. Pages the slot owned
        exclusively are scrubbed on device (stored positions → -1) and
        returned to the free list LIFO; pages still shared (a live
        :class:`SharedPrefix` or another slot) survive untouched."""
        assert self.active[slot], f"slot {slot} is not active"
        pages = [int(p) for p in self.block_tables[slot] if p != TRASH_PAGE]
        self._decref(pages)
        self.block_tables[slot] = TRASH_PAGE
        self.lengths[slot] = 0
        self.active[slot] = False

    # ---------------------------------------------------- preemption swap

    def export_slot(self, slot: int, n_tokens: int | None = None) -> dict:
        """Host snapshot of ``slot``'s WRITTEN pages (the first
        ``pages_for(n_tokens)`` table entries) for evict-to-queue
        preemption: ``{"length": tokens, "data": per-pattern-position
        (k, v, k_scale, v_scale, pos) numpy arrays with a leading
        page-run axis}``. Read-only — the slot stays live until the caller
        frees it. :meth:`restore_slot` puts the snapshot back
        bit-identically (the restored request decodes exactly as if never
        preempted).

        ``n_tokens`` (TOKENS, default the slot's accounted length) lets a
        caller exclude positions it has APPENDED but not yet written — the
        scheduler's speculative same-tick append: snapshotting the
        accounted length there would bake a never-written hole into the
        restore."""
        assert self.active[slot], f"slot {slot} is not active"
        n = int(self.lengths[slot]) if n_tokens is None else int(n_tokens)
        assert 1 <= n <= int(self.lengths[slot]), \
            f"cannot export {n} of slot {slot}'s {int(self.lengths[slot])}"
        pages = [int(p)
                 for p in self.block_tables[slot][:self.pages_for(n)]]
        assert TRASH_PAGE not in pages, f"slot {slot} under-allocated"
        idx = jnp.asarray(pages, jnp.int32)
        data = tuple(
            tuple(np.asarray(leaf[:, idx])
                  for leaf in (c.k, c.v, c.k_scale, c.v_scale, c.pos))
            for c in self._caches)
        snapshot = {"length": n, "data": data}
        self.swap_bytes += self.snapshot_bytes(snapshot)
        return snapshot

    @staticmethod
    def snapshot_bytes(snapshot: dict) -> int:
        """Host BYTES one :meth:`export_slot` snapshot holds."""
        return sum(a.nbytes for leaves in snapshot["data"] for a in leaves)

    def discard_snapshot(self, snapshot: dict) -> None:
        """Drop an :meth:`export_slot` snapshot that will never be
        restored (the preempted request was aborted) — releases its
        ``swap_bytes`` accounting."""
        self.swap_bytes -= self.snapshot_bytes(snapshot)
        assert self.swap_bytes >= 0, "snapshot discarded twice"

    def adopt_snapshot(self, snapshot: dict) -> None:
        """Take accounting ownership of a snapshot EXPORTED FROM ANOTHER
        pool (the disaggregated prefill→decode page stream,
        ``serving.page_transport.PageStreamTransport``): charges this
        pool's ``swap_bytes`` so the eventual :meth:`restore_slot`
        decrement balances. The exporting pool must release its own side
        with :meth:`discard_snapshot` — exactly one pool owns a snapshot's
        bytes at any time."""
        self.swap_bytes += self.snapshot_bytes(snapshot)

    def restore_slot(self, snapshot: dict,
                     reserve_tokens: int | None = None) -> int:
        """Re-admit a preempted request from an :meth:`export_slot`
        snapshot: allocates fresh pages (plus any ``reserve_tokens``
        headroom, in TOKENS) and writes the saved page contents back, so
        the stored int8 codes/scales/positions — and therefore every
        subsequent decoded token — are bit-identical to the un-preempted
        run. Returns the new slot index; raises ``PoolExhaustedError``
        (leaking nothing) when the pool cannot hold it yet."""
        n = int(snapshot["length"])
        slot = self.admit(n, reserve_tokens=reserve_tokens)
        pages = [int(p)
                 for p in self.block_tables[slot][:self.pages_for(n)]]
        idx = jnp.asarray(pages, jnp.int32)
        new = []
        for c, (k, v, ks, vs, pos) in zip(self._caches, snapshot["data"]):
            new.append(dataclasses.replace(
                c,
                k=c.k.at[:, idx].set(jnp.asarray(k)),
                v=c.v.at[:, idx].set(jnp.asarray(v)),
                k_scale=c.k_scale.at[:, idx].set(jnp.asarray(ks)),
                v_scale=c.v_scale.at[:, idx].set(jnp.asarray(vs)),
                pos=c.pos.at[:, idx].set(jnp.asarray(pos))))
        self._caches = tuple(new)
        self.lengths[slot] = n
        # the snapshot is consumed: its host bytes are no longer held
        # (the admit above already succeeded — nothing leaks on failure)
        self.swap_bytes -= self.snapshot_bytes(snapshot)
        assert self.swap_bytes >= 0, "snapshot restored twice"
        return slot

    # ------------------------------------------------------- device plumbing

    def _place(self, c: PagedKVCache) -> PagedKVCache:
        """Re-apply the mesh shardings to one pattern position's leaves
        (sharded mode only): page-axis leaves onto ``P(None, "kv")``, the
        block table replicated. ``jax.device_put`` is a no-op when the
        array already sits where it should, so calling this after every
        host-driven ``.at`` mutation costs nothing in steady state."""
        import jax

        return dataclasses.replace(
            c,
            k=jax.device_put(c.k, self._page_sharding),
            v=jax.device_put(c.v, self._page_sharding),
            k_scale=jax.device_put(c.k_scale, self._page_sharding),
            v_scale=jax.device_put(c.v_scale, self._page_sharding),
            pos=jax.device_put(c.pos, self._page_sharding),
            block_table=jax.device_put(c.block_table, self._repl_sharding))

    def device_caches(self, rows=None) -> tuple:
        """The pool pytree with the CURRENT block tables installed —
        ``rows`` selects a sub-batch (e.g. the freshly admitted requests for
        a ragged prefill); default is every slot row. In sharded mode every
        leaf is (re)placed onto the mesh first, so the jitted step always
        sees page-sharded pool leaves + replicated tables."""
        bt = self.block_tables if rows is None else self.block_tables[rows]
        bt = jnp.broadcast_to(jnp.asarray(bt, jnp.int32)[None],
                              (self.nb,) + bt.shape)
        caches = tuple(dataclasses.replace(c, block_table=bt)
                       for c in self._caches)
        if self.mesh is not None:
            caches = tuple(self._place(c) for c in caches)
        return caches

    def update_from(self, new_caches: tuple) -> None:
        """Adopt the pool arrays a jitted prefill/decode step returned (the
        block tables riding in the pytree are per-call views; the host copy
        stays authoritative)."""
        for c in new_caches:
            if c.k.shape[-2] != self.page_size:
                raise ValueError(
                    f"non-uniform page size: pool pages are {self.page_size} "
                    f"tokens, got {c.k.shape[-2]}; pages must be uniform — "
                    f"round lengths with padded_cache_len(s, "
                    f"{self.page_size}, uniform=True) before paging")
        self._caches = tuple(
            dataclasses.replace(c, block_table=old.block_table)
            for c, old in zip(new_caches, self._caches))

    def gather_dense(self, slot: int) -> tuple:
        """Reassemble ``slot``'s cache densely from its pages (tests/debug):
        returns (k_codes, k_scale, v_codes, v_scale, pos) with leading nb."""
        from repro.kernels.ref import gather_pages_ref

        bt = jnp.asarray(self.block_tables[slot][None], jnp.int32)  # (1, mb)
        outs = []
        for c in self._caches:
            leaves = []
            for leaf in (c.k, c.v, c.k_scale, c.v_scale, c.pos):
                g = jnp.stack([gather_pages_ref(leaf[i], bt)[0]
                               for i in range(self.nb)])
                leaves.append(g)
            outs.append(tuple(leaves))
        return tuple(outs)

    # ----------------------------------------------------------- accounting

    def page_bytes(self) -> int:
        """Device BYTES of ONE page across every covered layer."""
        kh, hd, ps = self.kv_heads, self.head_dim, self.page_size
        per_layer = 2 * kh * ps * hd * 1 + 2 * kh * ps * 4 + ps * 4
        return per_layer * self.num_layers

    def page_bytes_in_use(self) -> int:
        """Page-granular occupancy in BYTES: what the allocated pages
        actually hold (internal fragmentation AND worst-case reservation
        included; shared pages counted ONCE — physical residency)."""
        return self.pages_in_use * self.page_bytes()

    def page_bytes_written(self) -> int:
        """Page-granular BYTES of DISTINCT pages that hold at least one
        token — what a page-level KV shipment actually has to move
        (reserved-but-empty pages excluded, and pages shared between
        requests shipped ONCE, unlike a per-request dense transfer)."""
        written: set = set()
        for slot in np.flatnonzero(self.active):
            n = int(self.lengths[slot])
            if n > 0:
                written.update(
                    int(p) for p in self.block_tables[slot][:self.pages_for(n)]
                    if p != TRASH_PAGE)
        return self.page_bytes() * len(written)

    def eq2_bytes(self, qa_bits: int = 8) -> int:
        """The paper's analytical B_kv in BYTES (Eq. 2 via ``core.opsc.
        kv_cache_bytes``) summed over resident requests at the pool's int8
        activation width — the quantity the OPSC optimizer constrains.
        This is the LOGICAL (per-request) total: shared prefix tokens are
        counted once per sharing request, so under prefix sharing
        ``eq2_bytes() > page_bytes_in_use()`` measures the sharing win
        (``core.opsc.kv_cache_bytes_shared`` is the dedup-aware model)."""
        from repro.core.opsc import kv_cache_bytes

        total = 0
        for slot in np.flatnonzero(self.active):
            w = int(self.lengths[slot])
            if w > 0:
                total += kv_cache_bytes(w, self.num_layers, self.num_layers,
                                        self.kv_heads * self.head_dim,
                                        qa_bits, qa_bits)
        return total

    def occupancy(self) -> float:
        """Fraction of allocatable pages currently in use (shared pages
        counted once)."""
        return self.pages_in_use / max(1, self.num_pages - 1)

    def gauges(self) -> dict:
        """One consistent occupancy sample — what the telemetry tracer
        records per scheduler tick: pages in use / shared / free (page
        counts), host swap bytes, occupancy fraction, and the physical
        page bytes resident on device."""
        return {"pages_in_use": self.pages_in_use,
                "pages_shared": self.pages_shared,
                "pages_free": self.free_pages,
                "swap_bytes": self.swap_bytes,
                "occupancy": self.occupancy(),
                "page_bytes_in_use": self.page_bytes_in_use()}
