"""internlm2-20b [dense] — GQA [arXiv:2403.17297]. 48L, d_model 6144,
48H (GQA kv=8, head_dim 128), d_ff 16384, vocab 92544."""

from repro.configs.base import ArchConfig, AttnSpec, LayerSpec, MLPSpec, register

_attn = AttnSpec(num_heads=48, num_kv_heads=8, head_dim=128)
_mlp = MLPSpec(d_ff=16384, activation="silu", gated=True)

CONFIG = register(ArchConfig(
    name="internlm2-20b",
    arch_type="dense",
    d_model=6144,
    vocab_size=92544,
    pattern=(LayerSpec(_attn, _mlp),),
    num_blocks=48,
    rope_theta=1e6,
    tie_embeddings=False,
    source="arXiv:2403.17297 (InternLM2)",
))
