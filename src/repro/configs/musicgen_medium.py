"""musicgen-medium [audio] — decoder-only over EnCodec tokens
[arXiv:2306.05284]. 48L, d_model 1536, 24H (kv=24, head_dim 64), d_ff 6144
(non-gated GELU), vocab 2048 × 4 codebooks, sinusoidal positions.

The EnCodec tokenizer/conv frontend is a STUB per assignment: inputs are the
4 parallel codebook token streams (B, S, 4)."""

from repro.configs.base import ArchConfig, AttnSpec, LayerSpec, MLPSpec, register

_attn = AttnSpec(num_heads=24, num_kv_heads=24, head_dim=64)
_mlp = MLPSpec(d_ff=6144, activation="gelu", gated=False)

CONFIG = register(ArchConfig(
    name="musicgen-medium",
    arch_type="audio",
    d_model=1536,
    vocab_size=2048,
    pattern=(LayerSpec(_attn, _mlp),),
    num_blocks=48,
    rope="sinusoidal",
    embed="musicgen",
    num_codebooks=4,
    tie_embeddings=False,
    source="arXiv:2306.05284 (MusicGen)",
))
