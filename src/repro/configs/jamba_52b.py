"""jamba-v0.1-52b [hybrid] — Mamba+attention 1:7 interleave with MoE
[arXiv:2403.19887]. 32L, d_model 4096, 32H (GQA kv=8, head_dim 128),
d_ff 14336, MoE 16 experts top-2 on every other layer.

Pattern (period 8, matching the paper's 'Jamba block'): attention at
position 3 of 8 (1:7), MoE at odd positions (every other layer)."""

from repro.configs.base import (ArchConfig, AttnSpec, LayerSpec, MLPSpec,
                                MoESpec, SSMSpec, register)

_attn = AttnSpec(num_heads=32, num_kv_heads=8, head_dim=128)
_ssm = SSMSpec(d_inner=8192, d_state=16, head_dim=64, conv_width=4, chunk=256)
_mlp = MLPSpec(d_ff=14336, activation="silu", gated=True)
_moe = MoESpec(num_experts=16, top_k=2, d_ff=14336, renormalize=True,
               shard="expert")

_pattern = tuple(
    LayerSpec(_attn if i == 3 else _ssm, _moe if i % 2 == 1 else _mlp)
    for i in range(8)
)

CONFIG = register(ArchConfig(
    name="jamba-v0.1-52b",
    arch_type="hybrid",
    d_model=4096,
    vocab_size=65536,
    pattern=_pattern,
    num_blocks=4,  # 32 layers
    rope="none",  # Jamba uses no positional encoding (Mamba provides order)
    tie_embeddings=False,
    source="arXiv:2403.19887 (Jamba)",
    supports_long_context=True,  # only 4 attention layers carry 500k KV
))
