"""Architecture configuration system.

An ``ArchConfig`` describes a decoder stack as a repeated *pattern* of
``LayerSpec``s (mixer + ffn); the full depth is ``len(pattern) × num_blocks``.
Homogeneous stacks have a 1-layer pattern; gemma2's local/global alternation
is a 2-layer pattern; jamba's 1:7 attention:mamba interleave with alternating
MoE is an 8-layer pattern. Parameters for each pattern position are stacked
across blocks and scanned (compile time stays flat in depth).

Every assigned config cites its source in ``source``.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple, Union


@dataclasses.dataclass(frozen=True)
class AttnSpec:
    num_heads: int
    num_kv_heads: int
    head_dim: int
    sliding_window: Optional[int] = None
    attn_softcap: Optional[float] = None
    qk_norm: bool = False
    kind: str = "attn"


@dataclasses.dataclass(frozen=True)
class SSMSpec:
    d_inner: int
    d_state: int = 128
    head_dim: int = 64  # P
    conv_width: int = 4
    chunk: int = 128
    kind: str = "ssm"

    @property
    def n_heads(self) -> int:
        return self.d_inner // self.head_dim


@dataclasses.dataclass(frozen=True)
class MLPSpec:
    d_ff: int
    activation: str = "silu"
    gated: bool = True
    kind: str = "mlp"


@dataclasses.dataclass(frozen=True)
class MoESpec:
    num_experts: int
    top_k: int
    d_ff: int  # per-expert
    num_shared: int = 0  # shared-expert multiplier (shared ffn = num_shared·d_ff)
    renormalize: bool = True
    shard: str = "expert"  # 'expert' | 'ffn' — mesh mapping of expert weights
    kind: str = "moe"


MixerSpec = Union[AttnSpec, SSMSpec]
FFNSpec = Union[MLPSpec, MoESpec]


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    mixer: MixerSpec
    ffn: Optional[FFNSpec]  # None → mixer-only layer (mamba2)


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    arch_type: str  # dense | moe | ssm | hybrid | vlm | audio
    d_model: int
    vocab_size: int
    pattern: Tuple[LayerSpec, ...]
    num_blocks: int
    rope: str = "rope"  # rope | mrope | sinusoidal | none
    rope_theta: float = 10000.0
    mrope_sections: Tuple[int, ...] = ()
    embed: str = "token"  # token | musicgen | vlm
    num_codebooks: int = 1
    num_patches: int = 0  # VLM stub frontend: patch count in the sequence
    d_vision: int = 0  # VLM stub frontend: pre-projector patch width
    tie_embeddings: bool = True
    final_softcap: Optional[float] = None
    embed_scale: bool = False  # gemma: multiply embedding by sqrt(d_model)
    norm_eps: float = 1e-6
    source: str = ""
    # long_500k support: True only for sub-quadratic stacks (see DESIGN.md)
    supports_long_context: bool = False

    @property
    def num_layers(self) -> int:
        return len(self.pattern) * self.num_blocks

    # ---------------------------------------------------------------- sizes

    def mixer_params(self, m: MixerSpec) -> int:
        d = self.d_model
        if isinstance(m, AttnSpec):
            n = d * m.num_heads * m.head_dim * 2  # wq, wo
            n += d * m.num_kv_heads * m.head_dim * 2  # wk, wv
            if m.qk_norm:
                n += 2 * m.head_dim
            return n
        di, ns, h = m.d_inner, m.d_state, m.n_heads
        n = d * (2 * di + 2 * ns + h)  # w_z, w_x, w_B, w_C, w_dt
        n += m.conv_width * (di + 2 * ns) + (di + 2 * ns)  # conv
        n += 3 * h + di  # dt_bias, A_log, D, norm
        n += di * d  # w_out
        return n

    def ffn_params(self, f: Optional[FFNSpec], active: bool = False) -> int:
        if f is None:
            return 0
        d = self.d_model
        if isinstance(f, MLPSpec):
            return d * f.d_ff * (3 if f.gated else 2)
        e = f.top_k if active else f.num_experts
        n = d * f.num_experts  # router (always resident)
        n += e * 3 * d * f.d_ff  # gate/up/down per (active) expert
        if f.num_shared:
            n += 3 * d * f.num_shared * f.d_ff
        return n

    def layer_param_counts(self, active: bool = False) -> list:
        """Per-layer parameter counts, length num_layers (2 norms included)."""
        per_pattern = [
            self.mixer_params(ls.mixer) + self.ffn_params(ls.ffn, active) + 2 * self.d_model
            for ls in self.pattern
        ]
        return per_pattern * self.num_blocks

    def embed_params(self) -> int:
        n = self.num_codebooks * self.vocab_size * self.d_model
        if self.embed == "vlm":
            n += self.d_vision * self.d_model  # projector
        return n

    def head_params(self) -> int:
        if self.tie_embeddings and self.embed == "token":
            return 0
        return self.d_model * self.vocab_size * self.num_codebooks

    def total_params(self, active: bool = False) -> int:
        return (sum(self.layer_param_counts(active)) + self.embed_params()
                + self.head_params() + self.d_model)  # + final norm

    # ----------------------------------------------------------- reductions

    def tiny(self) -> "ArchConfig":
        """Reduced same-family variant for CPU smoke tests:
        ≤ 2 layers, d_model ≤ 512, ≤ 4 experts."""
        d = 128

        def shrink_mixer(m: MixerSpec) -> MixerSpec:
            if isinstance(m, AttnSpec):
                return dataclasses.replace(
                    m, num_heads=4, num_kv_heads=min(m.num_kv_heads, 2) or 1,
                    head_dim=32,
                    sliding_window=16 if m.sliding_window else None)
            return dataclasses.replace(m, d_inner=256, d_state=16, head_dim=32,
                                       chunk=8)

        def shrink_ffn(f: Optional[FFNSpec]) -> Optional[FFNSpec]:
            if f is None:
                return None
            if isinstance(f, MLPSpec):
                return dataclasses.replace(f, d_ff=256)
            return dataclasses.replace(f, num_experts=4, top_k=min(f.top_k, 2),
                                       d_ff=64, num_shared=min(f.num_shared, 1))

        # keep pattern diversity but cap total depth at 2 layers
        pat = self.pattern
        if len(pat) > 2:  # pick one of each distinct (mixer-kind, ffn-kind)
            seen, keep = set(), []
            for ls in pat:
                sig = (ls.mixer.kind, None if ls.ffn is None else ls.ffn.kind)
                if sig not in seen:
                    seen.add(sig)
                    keep.append(ls)
            pat = tuple(keep[:2])
        pat = tuple(LayerSpec(shrink_mixer(ls.mixer), shrink_ffn(ls.ffn)) for ls in pat)
        nb = 1 if len(pat) == 2 else 2
        sections = (4, 6, 6) if self.rope == "mrope" else ()
        return dataclasses.replace(
            self, name=self.name + "-tiny", d_model=d, vocab_size=256,
            pattern=pat, num_blocks=nb, mrope_sections=sections,
            num_patches=min(self.num_patches, 8) if self.embed == "vlm" else 0,
            d_vision=64 if self.embed == "vlm" else 0)


# ---------------------------------------------------------------- registry

_REGISTRY: dict = {}


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ArchConfig:
    import repro.configs  # noqa: F401 — populate registry

    if name.endswith("-tiny"):
        return get_config(name[: -len("-tiny")]).tiny()
    return _REGISTRY[name]


def list_configs() -> list:
    import repro.configs  # noqa: F401

    return sorted(_REGISTRY)
