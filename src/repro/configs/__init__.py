"""Architecture registry: importing this package registers every config."""

from repro.configs import (gemma2_2b, granite_34b, h2o_danube3_4b,  # noqa: F401
                           internlm2_20b, jamba_52b, llama2, mamba2_780m,
                           musicgen_medium, qwen2_moe_a27b, qwen2_vl_2b,
                           qwen3_moe_235b)
from repro.configs.base import (ArchConfig, AttnSpec, LayerSpec, MLPSpec,  # noqa: F401
                                MoESpec, SSMSpec, get_config, list_configs)

ASSIGNED = [
    "gemma2-2b",
    "qwen2-vl-2b",
    "qwen3-moe-235b-a22b",
    "qwen2-moe-a2.7b",
    "h2o-danube-3-4b",
    "granite-34b",
    "mamba2-780m",
    "musicgen-medium",
    "jamba-v0.1-52b",
    "internlm2-20b",
]
