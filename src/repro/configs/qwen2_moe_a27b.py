"""qwen2-moe-a2.7b [moe] — 4 shared + 60 routed experts top-4
[hf:Qwen/Qwen1.5-MoE-A2.7B]. 24L, d_model 2048, 16H (kv=16, head_dim 128),
per-expert d_ff 1408, vocab 151936.

60 experts do not divide the 16-way model axis → expert weights shard on the
per-expert ffn dim instead (``shard='ffn'``); see DESIGN.md §5."""

from repro.configs.base import (ArchConfig, AttnSpec, LayerSpec, MoESpec,
                                register)

_attn = AttnSpec(num_heads=16, num_kv_heads=16, head_dim=128)
_moe = MoESpec(num_experts=60, top_k=4, d_ff=1408, num_shared=4,
               renormalize=False, shard="ffn")

CONFIG = register(ArchConfig(
    name="qwen2-moe-a2.7b",
    arch_type="moe",
    d_model=2048,
    vocab_size=151936,
    pattern=(LayerSpec(_attn, _moe),),
    num_blocks=24,
    tie_embeddings=True,
    source="hf:Qwen/Qwen1.5-MoE-A2.7B",
))
