"""mamba2-780m [ssm] — SSD state-space duality, attention-free
[arXiv:2405.21060]. 48L, d_model 1536, d_inner 3072 (48 heads × P=64),
ssm_state 128, vocab 50280. Mamba blocks have no separate FFN (ffn=None)."""

from repro.configs.base import ArchConfig, LayerSpec, SSMSpec, register

_ssm = SSMSpec(d_inner=3072, d_state=128, head_dim=64, conv_width=4, chunk=256)

CONFIG = register(ArchConfig(
    name="mamba2-780m",
    arch_type="ssm",
    d_model=1536,
    vocab_size=50280,
    pattern=(LayerSpec(_ssm, None),),
    num_blocks=48,
    rope="none",
    tie_embeddings=True,
    source="arXiv:2405.21060 (Mamba-2)",
    supports_long_context=True,  # O(1) recurrent state
))
