"""gemma2-2b [dense] — local+global alternating attention, logit softcaps
[arXiv:2408.00118]. 26L, d_model 2304, 8H (GQA kv=4, head_dim 256),
d_ff 9216 (gated GELU), vocab 256000."""

from repro.configs.base import ArchConfig, AttnSpec, LayerSpec, MLPSpec, register

_local = AttnSpec(num_heads=8, num_kv_heads=4, head_dim=256,
                  sliding_window=4096, attn_softcap=50.0)
_global = AttnSpec(num_heads=8, num_kv_heads=4, head_dim=256,
                   attn_softcap=50.0)
_mlp = MLPSpec(d_ff=9216, activation="gelu", gated=True)

CONFIG = register(ArchConfig(
    name="gemma2-2b",
    arch_type="dense",
    d_model=2304,
    vocab_size=256000,
    pattern=(LayerSpec(_local, _mlp), LayerSpec(_global, _mlp)),
    num_blocks=13,  # 26 layers
    tie_embeddings=True,
    final_softcap=30.0,
    embed_scale=True,
    source="arXiv:2408.00118 (Gemma 2)",
    # long_500k: local layers keep a 4096-window ring cache; the 13 global
    # layers carry the full 500k cache (sub-quadratic in the windowed half —
    # see DESIGN.md §Arch-applicability)
    supports_long_context=True,
))
