"""h2o-danube-3-4b [dense] — llama+mistral mix with sliding-window attention
[arXiv:2401.16818]. 24L, d_model 3840, 32H (GQA kv=8, head_dim 120),
d_ff 10240, vocab 32000."""

from repro.configs.base import ArchConfig, AttnSpec, LayerSpec, MLPSpec, register

_attn = AttnSpec(num_heads=32, num_kv_heads=8, head_dim=120, sliding_window=4096)
_mlp = MLPSpec(d_ff=10240, activation="silu", gated=True)

CONFIG = register(ArchConfig(
    name="h2o-danube-3-4b",
    arch_type="dense",
    d_model=3840,
    vocab_size=32000,
    pattern=(LayerSpec(_attn, _mlp),),
    num_blocks=24,
    tie_embeddings=False,
    source="arXiv:2401.16818 (H2O-Danube)",
    supports_long_context=True,  # native SWA → windowed ring cache at 500k
))
