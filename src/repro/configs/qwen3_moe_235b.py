"""qwen3-moe-235b-a22b [moe] — 128 experts top-8 [hf:Qwen/Qwen3-30B-A3B
family scaled per assignment]. 94L, d_model 4096, 64H (GQA kv=4,
head_dim 128, QK-norm), per-expert d_ff 1536, vocab 151936."""

from repro.configs.base import (ArchConfig, AttnSpec, LayerSpec, MoESpec,
                                register)

_attn = AttnSpec(num_heads=64, num_kv_heads=4, head_dim=128, qk_norm=True)
_moe = MoESpec(num_experts=128, top_k=8, d_ff=1536, num_shared=0,
               renormalize=True, shard="expert")  # 128 / 16 mesh shards

CONFIG = register(ArchConfig(
    name="qwen3-moe-235b-a22b",
    arch_type="moe",
    d_model=4096,
    vocab_size=151936,
    pattern=(LayerSpec(_attn, _moe),),
    num_blocks=94,
    rope_theta=1e6,
    tie_embeddings=False,
    source="hf:Qwen/Qwen3-30B-A3B (scaled to 235B-A22B per assignment)",
))
