"""granite-34b [dense] — llama-arch code model with MQA [arXiv:2405.04324].
88L, d_model 6144, 48H (MQA kv=1, head_dim 128), d_ff 24576, vocab 49152."""

from repro.configs.base import ArchConfig, AttnSpec, LayerSpec, MLPSpec, register

_attn = AttnSpec(num_heads=48, num_kv_heads=1, head_dim=128)
_mlp = MLPSpec(d_ff=24576, activation="gelu", gated=False)

CONFIG = register(ArchConfig(
    name="granite-34b",
    arch_type="dense",
    d_model=6144,
    vocab_size=49152,
    pattern=(LayerSpec(_attn, _mlp),),
    num_blocks=88,
    tie_embeddings=True,
    source="arXiv:2405.04324 (Granite Code 34B)",
))
