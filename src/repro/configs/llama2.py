"""Llama-2 7B/13B [arXiv:2307.09288] — the paper's own evaluation models
(§3.1: 32 and 40 decoder layers; split point ℓ ranges over the full stack)."""

from repro.configs.base import ArchConfig, AttnSpec, LayerSpec, MLPSpec, register

CONFIG_7B = register(ArchConfig(
    name="llama2-7b",
    arch_type="dense",
    d_model=4096,
    vocab_size=32000,
    pattern=(LayerSpec(AttnSpec(num_heads=32, num_kv_heads=32, head_dim=128),
                       MLPSpec(d_ff=11008)),),
    num_blocks=32,
    tie_embeddings=False,
    source="arXiv:2307.09288 (Llama 2, paper's §3.1 7B-hf)",
))

CONFIG_13B = register(ArchConfig(
    name="llama2-13b",
    arch_type="dense",
    d_model=5120,
    vocab_size=32000,
    pattern=(LayerSpec(AttnSpec(num_heads=40, num_kv_heads=40, head_dim=128),
                       MLPSpec(d_ff=13824)),),
    num_blocks=40,
    tie_embeddings=False,
    source="arXiv:2307.09288 (Llama 2, paper's §3.1 13B-hf)",
))
