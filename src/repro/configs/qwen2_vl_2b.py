"""qwen2-vl-2b [vlm] — M-RoPE, dynamic resolution [arXiv:2409.12191].
28L, d_model 1536, 12H (GQA kv=2, head_dim 128), d_ff 8960, vocab 151936.
Vision frontend (ViT) is a STUB per assignment: ``input_specs`` provides
pre-projector patch embeddings; the config carries only the projector."""

from repro.configs.base import ArchConfig, AttnSpec, LayerSpec, MLPSpec, register

_attn = AttnSpec(num_heads=12, num_kv_heads=2, head_dim=128)
_mlp = MLPSpec(d_ff=8960, activation="silu", gated=True)

CONFIG = register(ArchConfig(
    name="qwen2-vl-2b",
    arch_type="vlm",
    d_model=1536,
    vocab_size=151936,
    pattern=(LayerSpec(_attn, _mlp),),
    num_blocks=28,
    rope="mrope",
    mrope_sections=(16, 24, 24),  # temporal/height/width bands of head_dim/2
    rope_theta=1e6,
    embed="vlm",
    num_patches=1024,  # stub frontend: patches occupy the sequence head
    d_vision=1280,
    tie_embeddings=True,
    source="arXiv:2409.12191 (Qwen2-VL)",
))
