"""Model assembly: pattern-stacked decoder with ``lax.scan`` over blocks.

Parameters for each pattern position are stacked across ``num_blocks`` (the
leading axis), so compile time and HLO size stay flat in depth — essential
for the 88/94-layer assigned configs. Three entry points:

  forward_train(params, cfg, tokens, ...)          → (logits, aux_loss)
  prefill(params, cfg, tokens, ...)                → (last_logits, caches)
  decode_step(params, cfg, token, caches, pos, ...)→ (logits, new_caches)

Caches are a tuple over pattern positions: ``KVCache`` for attention layers,
``(conv_state, ssm_state)`` for Mamba layers — each leaf carrying a leading
``num_blocks`` axis consumed by the scan.
"""

from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, AttnSpec, LayerSpec, MLPSpec, MoESpec, SSMSpec
from repro.models import layers as L
from repro.models.moe import init_moe_params, moe_layer
from repro.models.ssm import init_ssm_params, ssm_layer


@dataclasses.dataclass(frozen=True)
class RuntimeOpts:
    """Per-call knobs (all static under jit)."""

    q_chunk: int = 1024
    kv_chunk: int = 1024
    remat: bool = True
    quantized_kv: bool = False
    cache_dtype: str = "bfloat16"
    moe_capacity_factor: float = 1.25  # ≤ 0 → dropless routing
    # uniform per-layer activation fake-quant (baseline quantizers in
    # benchmarks apply Q_a at EVERY layer; the paper's method only at the
    # split — None disables)
    act_bits: int | None = None
    # pin the residual-stream layout between blocks, e.g. (('pod','data'),
    # None, None) — stops GSPMD sharding oscillation across the block scan
    # under remat (§Perf hillclimb 2); None disables
    act_sharding: tuple | None = None
    # grouped MoE dispatch: set to the data-shard count so the dispatch
    # scatter partitions shard-locally (§Perf hillclimb 2); 1 = global
    moe_groups: int = 1
    # SSD recurrent-state STORAGE dtype (compute stays f32): bf16 halves the
    # hybrid/SSM decode cache footprint (jamba fit fix, EXPERIMENTS §Dry-run)
    ssm_state_dtype: str = "float32"
    # route shared-prefix / chunked prefill attention through the Pallas
    # page-walk kernel (kernels.paged_prefill_attention); False falls back
    # to gathering the pool dense per layer — the pre-kernel baseline the
    # chunked_prefill benchmark measures against
    paged_prefill_kernel: bool = True
    # split the paged kernels' kv-head axis over a named mesh axis: each
    # shard walks the pages with its own head group and an exact tiled
    # all_gather reassembles the outputs (no psum — reduction order, and
    # therefore greedy argmaxes, stay bit-identical to single-device).
    # Only meaningful inside shard_map; set by sharded_step_fns, never by
    # callers directly. head_shards must divide num_kv_heads.
    head_axis: str | None = None
    head_shards: int = 1


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def _init_layer(key, cfg: ArchConfig, ls: LayerSpec, dtype):
    km, kf = jax.random.split(key)
    p = {"ln1": jnp.ones((cfg.d_model,), dtype)}
    if isinstance(ls.mixer, AttnSpec):
        p["mixer"] = L.init_attention_params(
            km, cfg.d_model, ls.mixer.num_heads, ls.mixer.num_kv_heads,
            ls.mixer.head_dim, dtype, ls.mixer.qk_norm)
    else:
        p["mixer"] = init_ssm_params(km, cfg.d_model, ls.mixer, dtype)
    if ls.ffn is not None:
        p["ln2"] = jnp.ones((cfg.d_model,), dtype)
        if isinstance(ls.ffn, MoESpec):
            p["ffn"] = init_moe_params(kf, cfg.d_model, ls.ffn, dtype)
        else:
            p["ffn"] = init_mlp(kf, cfg, ls.ffn, dtype)
    return p


def init_mlp(key, cfg, spec: MLPSpec, dtype):
    return L.init_mlp_params(key, cfg.d_model, spec.d_ff, spec.gated, dtype)


def init_params(cfg: ArchConfig, key, dtype=jnp.float32):
    keys = jax.random.split(key, 4 + len(cfg.pattern))
    d, v = cfg.d_model, cfg.vocab_size
    params: dict = {}
    if cfg.embed == "musicgen":
        params["embed"] = (jax.random.normal(keys[0], (cfg.num_codebooks, v, d))
                           * 0.02).astype(dtype)
    else:
        params["embed"] = (jax.random.normal(keys[0], (v, d)) * 0.02).astype(dtype)
    if cfg.embed == "vlm":
        params["w_proj"] = (jax.random.normal(keys[1], (cfg.d_vision, d))
                            * (1.0 / math.sqrt(cfg.d_vision))).astype(dtype)
    params["final_norm"] = jnp.ones((d,), dtype)
    if not (cfg.tie_embeddings and cfg.embed == "token"):
        params["lm_head"] = (jax.random.normal(keys[2], (d, v * cfg.num_codebooks))
                             * 0.02).astype(dtype)

    # stacked per pattern position
    blocks = {}
    for i, ls in enumerate(cfg.pattern):
        bkeys = jax.random.split(keys[4 + i], cfg.num_blocks)
        blocks[f"p{i}"] = jax.vmap(lambda k: _init_layer(k, cfg, ls, dtype))(bkeys)
    params["blocks"] = blocks
    return params


def abstract_params(cfg: ArchConfig, dtype=jnp.bfloat16):
    """ShapeDtypeStruct pytree of the parameters — no allocation (dry-run)."""
    return jax.eval_shape(lambda k: init_params(cfg, k, dtype),
                          jax.ShapeDtypeStruct((2,), jnp.uint32))


# ---------------------------------------------------------------------------
# Positions & rope
# ---------------------------------------------------------------------------


def make_positions(cfg: ArchConfig, b: int, s: int, offset=0):
    """Sequence-order positions (B, S) for causal masking and caches."""
    return jnp.broadcast_to(offset + jnp.arange(s, dtype=jnp.int32)[None], (b, s))


def make_mrope_positions(cfg: ArchConfig, positions: jax.Array):
    """Qwen2-VL M-RoPE ids (3, B, S) from sequence positions.

    Patches (first ``num_patches`` slots, a √P×√P grid): t = 0, (h, w) grid.
    Text: all three axes = seq_pos - P + √P (continuing past the grid).
    The mapping depends only on the *absolute* position, so prefill and
    decode agree by construction."""
    p = cfg.num_patches
    grid = max(int(math.isqrt(max(p, 1))), 1)
    is_patch = positions < p
    text = positions - p + grid
    pos_t = jnp.where(is_patch, 0, text)
    pos_h = jnp.where(is_patch, (positions // grid) % grid, text)
    pos_w = jnp.where(is_patch, positions % grid, text)
    return jnp.stack([pos_t, pos_h, pos_w])


def rope_tables(cfg: ArchConfig, positions: jax.Array):
    """(cos, sin) for the pattern's attention head_dim, or None."""
    attn_specs = [ls.mixer for ls in cfg.pattern if isinstance(ls.mixer, AttnSpec)]
    if not attn_specs or cfg.rope in ("none", "sinusoidal"):
        return None
    hd = attn_specs[0].head_dim
    if cfg.rope == "mrope":
        thw = make_mrope_positions(cfg, positions)
        return L.mrope_tables(thw, hd, cfg.mrope_sections, cfg.rope_theta)
    return L.rope_table(positions, hd, cfg.rope_theta)


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------


def embed_inputs(cfg: ArchConfig, params, tokens, patches=None, positions=None):
    if cfg.embed == "musicgen":
        # tokens (B, S, K): sum the per-codebook embeddings
        x = sum(jnp.take(params["embed"][k], tokens[..., k], axis=0)
                for k in range(cfg.num_codebooks))
    else:
        x = jnp.take(params["embed"], tokens, axis=0)
    if cfg.embed == "vlm" and patches is not None:
        proj = (patches.astype(x.dtype) @ params["w_proj"])  # (B, P, D)
        x = jnp.concatenate([proj, x[:, cfg.num_patches:]], axis=1)
    if cfg.embed_scale:
        x = x * math.sqrt(cfg.d_model)
    if cfg.rope == "sinusoidal" and positions is not None:
        x = x + L.sinusoidal_embedding(positions, cfg.d_model).astype(x.dtype)
    return x


def apply_head(cfg: ArchConfig, params, x):
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    if "lm_head" in params:
        w = params["lm_head"]
    else:
        w = params["embed"].T  # tied
    logits = (x @ w).astype(jnp.float32)
    if cfg.final_softcap is not None:
        logits = cfg.final_softcap * jnp.tanh(logits / cfg.final_softcap)
    if cfg.num_codebooks > 1:
        logits = logits.reshape(*logits.shape[:-1], cfg.num_codebooks, cfg.vocab_size)
    return logits


# ---------------------------------------------------------------------------
# Layers
# ---------------------------------------------------------------------------


def _apply_layer(cfg, ls: LayerSpec, p, x, *, rope_cs, q_positions, cache, pos,
                 opts: RuntimeOpts, decode: bool, attend_cache: bool = False,
                 token_slots=None, quant_fresh=None):
    h = L.rms_norm(x, p["ln1"], cfg.norm_eps)
    aux = jnp.zeros((), jnp.float32)
    if isinstance(ls.mixer, AttnSpec):
        out, new_cache = L.attention_layer(
            p["mixer"], h, ls.mixer, rope_cs=rope_cs, cache=cache, pos=pos,
            q_positions=q_positions, q_chunk=opts.q_chunk, kv_chunk=opts.kv_chunk,
            decode=decode, attend_cache=attend_cache,
            prefill_kernel=opts.paged_prefill_kernel, token_slots=token_slots,
            quant_fresh=quant_fresh, head_axis=opts.head_axis,
            head_shards=opts.head_shards)
    else:
        conv_state, ssm_state = cache if cache is not None else (None, None)
        out, new_cache = ssm_layer(p["mixer"], h, ls.mixer,
                                   conv_state=conv_state, ssm_state=ssm_state,
                                   decode=decode)
        if cache is not None:  # preserve the configured storage dtypes
            new_cache = jax.tree_util.tree_map(
                lambda new, old: new.astype(old.dtype), new_cache, cache)
    x = x + out
    if ls.ffn is not None:
        h = L.rms_norm(x, p["ln2"], cfg.norm_eps)
        if isinstance(ls.ffn, MoESpec):
            out, aux = moe_layer(p["ffn"], h, ls.ffn,
                                 opts.moe_capacity_factor, opts.moe_groups)
        else:
            out = L.mlp_layer(p["ffn"], h, ls.ffn.activation)
        x = x + out
    if opts.act_bits is not None:  # uniform activation quantization baseline
        from repro.core.quant import aiq, aiq_dequant

        b_, s_, d_ = x.shape
        codes, sc, z = aiq(x.reshape(b_ * s_, d_).astype(jnp.float32),
                           opts.act_bits, axis=-1)
        x = aiq_dequant(codes, sc, z).reshape(b_, s_, d_).astype(x.dtype)
    return x, new_cache, aux


def _apply_blocks_train(cfg, blocks, x, *, rope_cs, q_positions, opts: RuntimeOpts):
    def constrain(x):
        if opts.act_sharding is None:
            return x
        from jax.sharding import PartitionSpec

        return jax.lax.with_sharding_constraint(
            x, PartitionSpec(*opts.act_sharding))

    def body(carry, p_slice):
        x, aux = carry
        for i, ls in enumerate(cfg.pattern):
            x, _, a = _apply_layer(cfg, ls, p_slice[f"p{i}"], x, rope_cs=rope_cs,
                                   q_positions=q_positions, cache=None, pos=None,
                                   opts=opts, decode=False)
            x = constrain(x)
            aux = aux + a
        return (x, aux), None

    if opts.remat:
        body = jax.checkpoint(body)
    (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), blocks)
    return x, aux


def _apply_blocks_cached(cfg, blocks, x, caches, *, rope_cs, q_positions, pos,
                         opts: RuntimeOpts, decode: bool,
                         attend_cache: bool = False, token_slots=None,
                         quant_fresh=None):
    """Caches ride in the scan CARRY (sliced per block by index, written back
    with dynamic_update_slice) rather than as xs→ys: carries can be buffer-
    aliased/donated, so a serve step updates the multi-GB cache in place —
    xs/ys would keep two full copies live (observed +16 GB temp on jamba)."""

    def body(carry, xs):
        x, caches = carry
        p_slice, i = xs
        new_caches = []
        for pi, ls in enumerate(cfg.pattern):
            cache_i = jax.tree_util.tree_map(
                lambda a: jax.lax.dynamic_index_in_dim(a, i, 0, keepdims=False),
                caches[pi])
            x, nc, _ = _apply_layer(cfg, ls, p_slice[f"p{pi}"], x,
                                    rope_cs=rope_cs, q_positions=q_positions,
                                    cache=cache_i, pos=pos, opts=opts,
                                    decode=decode, attend_cache=attend_cache,
                                    token_slots=token_slots,
                                    quant_fresh=quant_fresh)
            new_caches.append(jax.tree_util.tree_map(
                lambda full, sl: jax.lax.dynamic_update_slice_in_dim(
                    full, sl[None].astype(full.dtype), i, axis=0),
                caches[pi], nc))
        return (x, tuple(new_caches)), None

    nb = jax.tree_util.tree_leaves(blocks)[0].shape[0]
    (x, new_caches), _ = jax.lax.scan(
        body, (x, caches), (blocks, jnp.arange(nb)))
    return x, new_caches


# ---------------------------------------------------------------------------
# Caches
# ---------------------------------------------------------------------------


def init_caches(cfg: ArchConfig, batch: int, cache_len: int, opts: RuntimeOpts):
    """Tuple over pattern positions, each leaf stacked (num_blocks, ...)."""
    nb = cfg.num_blocks
    dtype = jnp.dtype(opts.cache_dtype)
    caches = []
    for ls in cfg.pattern:
        m = ls.mixer
        if isinstance(m, AttnSpec):
            size = min(cache_len, m.sliding_window) if m.sliding_window else cache_len
            if opts.quantized_kv:
                # kv-head-major kernel layout: int8 codes + per-(token, head)
                # scales, streamed as-is by kernels.decode_attention; the
                # slot axis is block-aligned so the kernel never re-pads the
                # cache per step (pad slots keep pos = -1 → masked; ring
                # writes stay modulo the logical window, see cache_update)
                from repro.kernels.decode_attention import padded_cache_len

                psize = padded_cache_len(size)
                qshape = (nb, batch, m.num_kv_heads, psize, m.head_dim)
                c = L.KVCache(jnp.zeros(qshape, jnp.int8),
                              jnp.zeros(qshape, jnp.int8),
                              jnp.zeros(qshape[:-1], jnp.float32),
                              jnp.zeros(qshape[:-1], jnp.float32),
                              jnp.full((nb, batch, psize), -1, jnp.int32))
            else:
                shape = (nb, batch, size, m.num_kv_heads, m.head_dim)
                c = L.KVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype),
                              None, None, jnp.full((nb, batch, size), -1, jnp.int32))
        else:
            conv_ch = m.d_inner + 2 * m.d_state
            c = (jnp.zeros((nb, batch, m.conv_width - 1, conv_ch), dtype),
                 jnp.zeros((nb, batch, m.n_heads, m.d_inner // m.n_heads, m.d_state),
                           jnp.dtype(opts.ssm_state_dtype)))
        caches.append(c)
    return tuple(caches)


# ---------------------------------------------------------------------------
# Entry points
# ---------------------------------------------------------------------------


def forward_train(params, cfg: ArchConfig, tokens, patches=None,
                  opts: RuntimeOpts = RuntimeOpts()):
    b, s = tokens.shape[:2]
    positions = make_positions(cfg, b, s)
    x = embed_inputs(cfg, params, tokens, patches, positions)
    rope_cs = rope_tables(cfg, positions)
    x, aux = _apply_blocks_train(cfg, params["blocks"], x, rope_cs=rope_cs,
                                 q_positions=positions, opts=opts)
    return apply_head(cfg, params, x), aux


def prefill(params, cfg: ArchConfig, tokens, patches=None, cache_len=None,
            opts: RuntimeOpts = RuntimeOpts()):
    """Process the prompt, returning last-position logits + filled caches."""
    b, s = tokens.shape[:2]
    cache_len = cache_len or s
    positions = make_positions(cfg, b, s)
    x = embed_inputs(cfg, params, tokens, patches, positions)
    rope_cs = rope_tables(cfg, positions)
    caches = init_caches(cfg, b, cache_len, opts)
    x, caches = _apply_blocks_cached(cfg, params["blocks"], x, caches,
                                     rope_cs=rope_cs, q_positions=positions,
                                     pos=jnp.int32(0), opts=opts, decode=False)
    logits = apply_head(cfg, params, x[:, -1:])
    return logits[:, 0], caches


def decode_step(params, cfg: ArchConfig, tokens, caches, pos,
                opts: RuntimeOpts = RuntimeOpts()):
    """One autoregressive step. ``tokens`` (B, 1) (or (B, 1, K) musicgen);
    ``pos`` scalar int32 — the absolute position being generated."""
    b = tokens.shape[0]
    positions = jnp.broadcast_to(jnp.asarray(pos, jnp.int32)[None, None], (b, 1))
    x = embed_inputs(cfg, params, tokens, None, positions)
    rope_cs = rope_tables(cfg, positions)
    x, caches = _apply_blocks_cached(cfg, params["blocks"], x, caches,
                                     rope_cs=rope_cs, q_positions=positions,
                                     pos=jnp.asarray(pos, jnp.int32), opts=opts,
                                     decode=True)
    logits = apply_head(cfg, params, x)
    return logits[:, 0], caches


# --------------------------------------------------------- paged (ragged)


def paged_prefill(params, cfg: ArchConfig, tokens, caches, positions,
                  opts: RuntimeOpts = RuntimeOpts()):
    """Ragged prefill over the paged KV pool.

    ``tokens`` (R, S) RIGHT-ALIGNED: each row's prompt occupies the trailing
    slots, left pads carry ``positions = -1``. ``positions`` (R, S) are the
    per-row absolute positions (0..len-1 in the tail). Right alignment means
    the LAST column is every row's final prompt token, so one slice yields
    the next-token logits for the whole ragged batch; pad queries/keys are
    masked by the negative positions, and pad cache writes land on the
    pool's trash page. ``caches`` is the pool pytree from
    ``serving.kv_pool.PagedKVPool.device_caches`` (block tables installed
    for exactly these R rows). Returns (last_logits (R, V), caches)."""
    positions = jnp.asarray(positions, jnp.int32)
    x = embed_inputs(cfg, params, tokens, None, jnp.maximum(positions, 0))
    rope_cs = rope_tables(cfg, positions)
    x, caches = _apply_blocks_cached(cfg, params["blocks"], x, caches,
                                     rope_cs=rope_cs, q_positions=positions,
                                     pos=jnp.int32(0), opts=opts, decode=False)
    logits = apply_head(cfg, params, x[:, -1:])
    return logits[:, 0], caches


def paged_prefill_shared(params, cfg: ArchConfig, tokens, caches, positions,
                         opts: RuntimeOpts = RuntimeOpts()):
    """Ragged prefill THROUGH the paged pool — the shared-prefix entry point.

    Same calling convention as :func:`paged_prefill` (right-aligned
    ``tokens``/``positions`` (R, S), ``-1`` pads, last column = each row's
    final prompt token), but rows may start at a position > 0: a row forked
    from a shared prefix passes only its SUFFIX tokens with absolute
    positions ``[prefix_len, prompt_len)``, and its attention additionally
    reads the prefix tokens already stored in its block-table pages
    (``models.layers.paged_prefill_attention`` — history masked to stored
    positions below the row's first in-call position, so the suffix
    attends exactly prefix + itself). Rows starting at position 0 behave
    like the plain ragged prefill. Returns (last_logits (R, V), caches)."""
    positions = jnp.asarray(positions, jnp.int32)
    x = embed_inputs(cfg, params, tokens, None, jnp.maximum(positions, 0))
    rope_cs = rope_tables(cfg, positions)
    x, caches = _apply_blocks_cached(cfg, params["blocks"], x, caches,
                                     rope_cs=rope_cs, q_positions=positions,
                                     pos=jnp.int32(0), opts=opts, decode=False,
                                     attend_cache=True)
    logits = apply_head(cfg, params, x[:, -1:])
    return logits[:, 0], caches


def paged_decode_step(params, cfg: ArchConfig, tokens, caches, pos,
                      opts: RuntimeOpts = RuntimeOpts()):
    """One RAGGED autoregressive step over the paged pool: ``pos`` is (R,)
    int32 — each request decodes at its own absolute position (-1 marks an
    inactive slot, whose write is routed to the trash page and whose
    attention masks every key). This is the step the continuous-batching
    scheduler jits once for the full slot count and reuses as requests come
    and go."""
    positions = jnp.asarray(pos, jnp.int32)[:, None]  # (R, 1)
    x = embed_inputs(cfg, params, tokens, None, jnp.maximum(positions, 0))
    rope_cs = rope_tables(cfg, positions)
    x, caches = _apply_blocks_cached(cfg, params["blocks"], x, caches,
                                     rope_cs=rope_cs, q_positions=positions,
                                     pos=jnp.int32(0), opts=opts, decode=True)
    logits = apply_head(cfg, params, x)
    return logits[:, 0], caches


def packed_step(params, cfg: ArchConfig, tokens, caches, positions, slots,
                logit_rows, opts: RuntimeOpts = RuntimeOpts(),
                quant_fresh=None):
    """ONE token-packed step over the paged pool: the whole tick — every
    decoding slot's next token AND up-to-budget prefill-chunk tokens — as a
    single flat batch.

    ``tokens``/``positions``/``slots`` are (1, T): a fixed ``token_budget``
    buffer laid out slot-major (each active slot owns one contiguous run —
    a length-1 run for a decode token, a longer one for a prefill chunk),
    tail-padded with ``positions = slots = -1`` rows whose cache writes
    land on the trash page and whose attention emits exact zeros.
    ``logit_rows`` (R,) names the buffer row holding each slot's LAST token
    (any row for absent slots — their logits are garbage the scheduler
    never samples), so logits keep the ``(R, V)`` shape the per-slot
    sampling operand lanes expect.

    ``quant_fresh`` (1, T) bool marks rows whose FRESH self-keys must be
    attended through the int8 quantize→dequantize round trip instead of at
    full precision — the scheduler sets it on its decode rows, whose one
    fresh key IS their own token: a sequential decode step would read that
    key back from the pool's codes, so attending it at f32 here is the one
    value-level divergence packed mode had from ``paged_decode_step`` (and
    from ``Engine.generate``). With the mask on, packed greedy streams are
    bit-identical to the per-request oracle; prefill rows keep full-
    precision fresh keys exactly like the chunked prefill path. Returns
    (logits (R, V), caches)."""
    positions = jnp.asarray(positions, jnp.int32)
    slots = jnp.asarray(slots, jnp.int32)
    x = embed_inputs(cfg, params, tokens, None, jnp.maximum(positions, 0))
    rope_cs = rope_tables(cfg, positions)
    x, caches = _apply_blocks_cached(cfg, params["blocks"], x, caches,
                                     rope_cs=rope_cs, q_positions=positions,
                                     pos=jnp.int32(0), opts=opts, decode=False,
                                     token_slots=slots,
                                     quant_fresh=quant_fresh)
    xl = jnp.take(x[0], jnp.asarray(logit_rows, jnp.int32), axis=0)  # (R, D)
    logits = apply_head(cfg, params, xl[None])
    return logits[0], caches


def paged_verify_step(params, cfg: ArchConfig, tokens, caches, positions,
                      opts: RuntimeOpts = RuntimeOpts()):
    """Multi-token verify THROUGH the paged pool — the (R, S) generalization
    of :func:`paged_decode_step`: each row carries its last committed token
    plus its draft burst and gets logits at EVERY in-call position back.

    ``tokens``/``positions`` (R, S) RIGHT-ALIGNED (-1 pads route to the
    trash page), with ``S = 1 + speculate_k``. The in-call tokens are
    WRITTEN to the pool first and attention then reads every key —
    history and the burst itself — back through the pool's quantized
    codes, exactly like ``S`` single-token decode steps would
    (quantization is per-token, so batching the writes leaves the codes
    bit-identical; prefill-style fresh-f32 in-call keys would diverge
    from the sequential path at quantization scale and flip argmaxes).
    Returns (logits (R, S, V), caches): column j of row r is the target
    distribution after consuming the row's in-call tokens <= j (left-pad
    columns are garbage)."""
    positions = jnp.asarray(positions, jnp.int32)
    x = embed_inputs(cfg, params, tokens, None, jnp.maximum(positions, 0))
    rope_cs = rope_tables(cfg, positions)
    x, caches = _apply_blocks_cached(cfg, params["blocks"], x, caches,
                                     rope_cs=rope_cs, q_positions=positions,
                                     pos=jnp.int32(0), opts=opts, decode=True)
    return apply_head(cfg, params, x), caches


# --------------------------------------------------------------- sharded


def sharded_step_fns(cfg: ArchConfig, opts: RuntimeOpts, mesh) -> dict:
    """``shard_map``-lowered drop-in versions of the five paged step
    functions over a ``("kv", "model")`` mesh (``repro.launch.mesh.
    make_serving_mesh``). Returns ``{"prefill", "prefill_shared", "decode",
    "packed", "verify"}`` — same signatures as the base entry points with
    ``cfg``/``opts`` closed over, so the scheduler's jitted tick lambdas
    swap them in unchanged (one jitted tick per mode is preserved).

    Execution model, chosen for exactness (the repo's bit-identity bar):

      * pool PAGE leaves arrive sharded ``P(None, "kv")`` (each device
        STORES 1/kv of the pool — the memory-constrained axis); the body
        starts with a tiled ``all_gather`` over "kv" so every device walks
        the full page set with the block tables, then slices its own page
        shard back out of the updated pool. Gather/slice are exact — page
        values are moved, never reduced.
      * attention kv-heads are split over "model" via
        ``RuntimeOpts.head_axis``/``head_shards`` (the layers slice their
        head group, walk the pages with it, and reassemble with an exact
        tiled ``all_gather`` — no psum, so no reduction-order drift).
      * everything dense (embeddings, MLPs, lm head) runs replicated;
        logits come out ``P()`` and per-slot sampling stays OUTSIDE the
        shard_map, inside the scheduler's same jit.

    Greedy token streams are therefore bit-identical to the single-device
    step functions (asserted by ``tests/test_sharded_serving.py`` on
    forced CPU device counts with the Pallas kernels in interpret mode)."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec

    page = PartitionSpec(None, "kv")
    repl = PartitionSpec()
    cache_spec = tuple(L.PagedKVCache(page, page, page, page, page, repl)
                       for _ in cfg.pattern)
    ksize, msize = mesh.shape["kv"], mesh.shape["model"]
    kh = cfg.pattern[0].mixer.num_kv_heads
    inner = opts
    if msize > 1:
        if kh % msize != 0:
            raise ValueError(
                f"mesh 'model' axis {msize} must divide num_kv_heads {kh} "
                f"(make_serving_mesh only builds such meshes)")
        inner = dataclasses.replace(opts, head_axis="model",
                                    head_shards=msize)

    def _gather(caches):
        g = lambda a: jax.lax.all_gather(a, "kv", axis=1, tiled=True)
        return tuple(L.PagedKVCache(g(c.k), g(c.v), g(c.k_scale),
                                    g(c.v_scale), g(c.pos), c.block_table)
                     for c in caches)

    def _scatter(caches):
        i = jax.lax.axis_index("kv")

        def s(a):
            local = a.shape[1] // ksize
            return jax.lax.dynamic_slice_in_dim(a, i * local, local, axis=1)

        return tuple(L.PagedKVCache(s(c.k), s(c.v), s(c.k_scale),
                                    s(c.v_scale), s(c.pos), c.block_table)
                     for c in caches)

    def _wrap(step, n_repl: int):
        """shard_map a step whose args are (params, *n_repl replicated
        operands, caches-last-moved-to-front)…"""

        def body(params, caches, *args):
            logits, out = step(params, _gather(caches), *args)
            return logits, _scatter(out)

        sm = shard_map(body, mesh=mesh,
                       in_specs=(repl, cache_spec) + (repl,) * n_repl,
                       out_specs=(repl, cache_spec), check_rep=False)

        def fn(params, caches, *args):
            return sm(params, caches, *args)

        return fn

    prefill = _wrap(
        lambda p, c, tokens, positions: paged_prefill(
            p, cfg, tokens, c, positions, inner), 2)
    prefill_shared = _wrap(
        lambda p, c, tokens, positions: paged_prefill_shared(
            p, cfg, tokens, c, positions, inner), 2)
    decode = _wrap(
        lambda p, c, tokens, pos: paged_decode_step(
            p, cfg, tokens, c, pos, inner), 2)
    packed = _wrap(
        lambda p, c, tokens, positions, slots, logit_rows, quant_fresh:
        packed_step(p, cfg, tokens, c, positions, slots, logit_rows, inner,
                    quant_fresh), 5)
    verify = _wrap(
        lambda p, c, tokens, positions: paged_verify_step(
            p, cfg, tokens, c, positions, inner), 2)

    return {
        "prefill": lambda params, tokens, caches, positions:
            prefill(params, caches, tokens, positions),
        "prefill_shared": lambda params, tokens, caches, positions:
            prefill_shared(params, caches, tokens, positions),
        "decode": lambda params, tokens, caches, pos:
            decode(params, caches, tokens, pos),
        "packed": lambda params, tokens, caches, positions, slots,
            logit_rows, quant_fresh:
            packed(params, caches, tokens, positions, slots,
                   jnp.asarray(logit_rows, jnp.int32),
                   (jnp.zeros(jnp.asarray(tokens).shape, bool)
                    if quant_fresh is None else quant_fresh)),
        "verify": lambda params, tokens, caches, positions:
            verify(params, caches, tokens, positions),
    }
