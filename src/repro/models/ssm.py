"""Mamba-2 SSD (state-space duality) layers [arXiv:2405.21060].

Chunked SSD for training/prefill (intra-chunk quadratic term + inter-chunk
recurrence via ``lax.scan``) and the O(1) single-step recurrence for decode.
The TPU adaptation keeps everything in einsum/scan form so XLA maps the
intra-chunk quadratic onto the MXU; chunk length is a tunable (§Perf).

Shapes: x (B, S, H, P); dt (B, S, H); A (H,); B/C (B, S, N) (group G = 1,
broadcast over heads); state (B, H, P, N).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.layers import rms_norm


def init_ssm_params(key, d_model: int, spec, dtype=jnp.float32):
    ks = jax.random.split(key, 8)
    di, n, h = spec.d_inner, spec.d_state, spec.n_heads
    s_in = 1.0 / math.sqrt(d_model)
    conv_ch = di + 2 * n
    return {
        "w_z": (jax.random.normal(ks[0], (d_model, di)) * s_in).astype(dtype),
        "w_x": (jax.random.normal(ks[1], (d_model, di)) * s_in).astype(dtype),
        "w_B": (jax.random.normal(ks[2], (d_model, n)) * s_in).astype(dtype),
        "w_C": (jax.random.normal(ks[3], (d_model, n)) * s_in).astype(dtype),
        "w_dt": (jax.random.normal(ks[4], (d_model, h)) * s_in).astype(dtype),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "A_log": jnp.zeros((h,), jnp.float32),  # A = -exp(A_log) = -1 init
        "D": jnp.ones((h,), jnp.float32),
        "conv_w": (jax.random.normal(ks[5], (spec.conv_width, conv_ch))
                   * (1.0 / math.sqrt(spec.conv_width))).astype(dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "norm": jnp.ones((di,), dtype),
        "w_out": (jax.random.normal(ks[6], (di, d_model)) * (1.0 / math.sqrt(di))).astype(dtype),
    }


def _depthwise_conv(xbc: jax.Array, w: jax.Array, b: jax.Array,
                    conv_state: jax.Array | None):
    """Causal depthwise conv over seq. xbc (B, S, C); w (W, C).
    ``conv_state`` (B, W-1, C) holds the previous inputs for decode."""
    width = w.shape[0]
    if conv_state is None:
        pad = jnp.zeros((xbc.shape[0], width - 1, xbc.shape[-1]), xbc.dtype)
    else:
        pad = conv_state.astype(xbc.dtype)
    full = jnp.concatenate([pad, xbc], axis=1)  # (B, S+W-1, C)
    out = sum(full[:, i : i + xbc.shape[1]] * w[i] for i in range(width)) + b
    new_state = full[:, -(width - 1) :]
    return jax.nn.silu(out), new_state


def ssd_chunked(x, dt, a, b_mat, c_mat, chunk: int, initial_state=None):
    """Chunked SSD.  Returns (y (B,S,H,P), final_state (B,H,P,N))."""
    bsz, s, h, p = x.shape
    n = b_mat.shape[-1]
    q = min(chunk, s)
    nc = -(-s // q)
    pad = nc * q - s
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b_mat = jnp.pad(b_mat, ((0, 0), (0, pad), (0, 0)))
        c_mat = jnp.pad(c_mat, ((0, 0), (0, pad), (0, 0)))

    xc = x.reshape(bsz, nc, q, h, p).astype(jnp.float32)
    dtc = dt.reshape(bsz, nc, q, h).astype(jnp.float32)
    bc = b_mat.reshape(bsz, nc, q, n).astype(jnp.float32)
    cc = c_mat.reshape(bsz, nc, q, n).astype(jnp.float32)

    da = dtc * a  # (B, nc, q, H) — per-step log decay (A < 0)
    cs = jnp.cumsum(da, axis=2)  # inclusive cumsum within chunk

    # ---- intra-chunk (quadratic, MXU-friendly) --------------------------
    # scores[i,j] = (C_i · B_j) · exp(cs_i - cs_j) · dt_j   for i ≥ j
    cb = jnp.einsum("bzin,bzjn->bzij", cc, bc)  # (B, nc, q, q)
    decay = cs[:, :, :, None, :] - cs[:, :, None, :, :]  # (B,nc,i,j,H)
    mask = jnp.tril(jnp.ones((q, q), bool))
    l_mat = jnp.where(mask[None, None, :, :, None], jnp.exp(decay), 0.0)
    scores = cb[..., None] * l_mat * dtc[:, :, None, :, :]  # (B,nc,i,j,H)
    y_intra = jnp.einsum("bzijh,bzjhp->bzihp", scores, xc)

    # ---- chunk summaries and inter-chunk recurrence ---------------------
    # S_z = Σ_j exp(cs_last - cs_j) · dt_j · B_j ⊗ x_j      (B,nc,H,P,N)
    last = cs[:, :, -1:, :]  # (B,nc,1,H)
    w_j = jnp.exp(last - cs) * dtc  # (B,nc,q,H)
    s_chunk = jnp.einsum("bzjh,bzjn,bzjhp->bzhpn", w_j, bc, xc)
    chunk_decay = jnp.exp(last[:, :, 0, :])  # (B,nc,H) total decay of a chunk

    def step(state, inp):
        s_z, dec = inp  # (B,H,P,N), (B,H)
        new = state * dec[:, :, None, None] + s_z
        return new, state  # emit the state *entering* the chunk

    if initial_state is None:
        initial_state = jnp.zeros((bsz, h, p, n), jnp.float32)
    else:
        initial_state = initial_state.astype(jnp.float32)  # bf16 storage OK
    final_state, states_in = jax.lax.scan(
        step, initial_state,
        (jnp.moveaxis(s_chunk, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    states_in = jnp.moveaxis(states_in, 0, 1)  # (B, nc, H, P, N)

    # y_inter_i = exp(cs_i) · C_i · S_in
    y_inter = jnp.einsum("bzih,bzin,bzhpn->bzihp", jnp.exp(cs), cc, states_in)

    y = (y_intra + y_inter).reshape(bsz, nc * q, h, p)[:, :s]
    return y, final_state


def ssd_decode_step(x, dt, a, b_vec, c_vec, state):
    """Single-token recurrence: state' = exp(dt·A)·state + dt·(B ⊗ x).

    x (B,H,P); dt (B,H); b_vec/c_vec (B,N); state (B,H,P,N)."""
    dec = jnp.exp(dt * a)  # (B,H)
    upd = jnp.einsum("bh,bn,bhp->bhpn", dt, b_vec, x)
    new_state = state * dec[:, :, None, None] + upd
    y = jnp.einsum("bn,bhpn->bhp", c_vec, new_state)
    return y, new_state


def ssm_layer(params, x: jax.Array, spec, *, conv_state=None, ssm_state=None,
              decode: bool = False):
    """Full Mamba-2 block. x (B, S, D) → (out, (new_conv_state, new_ssm_state))."""
    bsz, s, d = x.shape
    di, n, h = spec.d_inner, spec.d_state, spec.n_heads
    p = di // h
    z = x @ params["w_z"]
    xbc = jnp.concatenate([x @ params["w_x"], x @ params["w_B"], x @ params["w_C"]], -1)
    xbc, new_conv = _depthwise_conv(xbc, params["conv_w"], params["conv_b"], conv_state)
    xs, bs, cs = jnp.split(xbc, [di, di + n], axis=-1)
    dt = jax.nn.softplus((x @ params["w_dt"]).astype(jnp.float32) + params["dt_bias"])
    a = -jnp.exp(params["A_log"])  # (H,)
    xh = xs.reshape(bsz, s, h, p)

    if decode:
        assert s == 1
        y, new_state = ssd_decode_step(
            xh[:, 0].astype(jnp.float32), dt[:, 0], a,
            bs[:, 0].astype(jnp.float32), cs[:, 0].astype(jnp.float32),
            ssm_state.astype(jnp.float32) if ssm_state is not None
            else jnp.zeros((bsz, h, p, n), jnp.float32))
        y = y[:, None]  # (B,1,H,P)
    else:
        y, new_state = ssd_chunked(xh, dt, a, bs, cs, spec.chunk, ssm_state)

    y = y + params["D"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(bsz, s, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), params["norm"])
    return y @ params["w_out"], (new_conv, new_state)
