"""Mixture-of-Experts layer: top-k router + capacity-based dispatch.

Dispatch uses the rank-in-expert scatter formulation (no (T, E, capacity)
one-hot dispatch tensor is ever materialized — only a (T·k, E) int32 cumsum),
so compiled FLOPs reflect *active* parameters: expert matmuls are
(E, capacity, D) × (E, D, F) with capacity ≈ T·k/E·cf.  This is what makes
the roofline MODEL_FLOPS/HLO_FLOPs ratio honest for the MoE architectures.

Sharding: expert dim over the 'model' mesh axis when divisible (qwen3: 128
experts / 16), else the per-expert ffn dim (qwen2-moe: 60 experts → ffn
sharding); see configs (``moe_shard``).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.layers import init_mlp_params, mlp_layer


def init_moe_params(key, d_model: int, spec, dtype=jnp.float32):
    ks = jax.random.split(key, 5)
    e, f = spec.num_experts, spec.d_ff
    s_in = 1.0 / math.sqrt(d_model)
    s_out = 1.0 / math.sqrt(f)
    p = {
        "w_router": (jax.random.normal(ks[0], (d_model, e)) * s_in).astype(jnp.float32),
        "w_gate": (jax.random.normal(ks[1], (e, d_model, f)) * s_in).astype(dtype),
        "w_up": (jax.random.normal(ks[2], (e, d_model, f)) * s_in).astype(dtype),
        "w_down": (jax.random.normal(ks[3], (e, f, d_model)) * s_out).astype(dtype),
    }
    if spec.num_shared:
        p["shared"] = init_mlp_params(ks[4], d_model, spec.num_shared * f,
                                      gated=True, dtype=dtype)
    return p


def moe_layer(params, x: jax.Array, spec, capacity_factor: float = 1.25,
              groups: int = 1):
    """x (B, S, D) → (out (B, S, D), aux_loss scalar).

    ``groups`` > 1 runs the rank-scatter dispatch independently per token
    group (vmapped). With groups = the data-shard count, the scatter carries
    a leading batch dim that GSPMD partitions over the data axes with ZERO
    cross-shard traffic — the global formulation instead gets partitioned as
    replicate-updates + all-reduce of the full (E, cap, D) buffer (~10 GB of
    AR per layer-microbatch on qwen3-235b; §Perf hillclimb 2). Capacity and
    token dropping become group-local, the standard EP behaviour.
    """
    b, s, d = x.shape
    t = b * s
    e, k = spec.num_experts, spec.top_k
    groups = max(1, min(groups, t))
    if t % groups:
        groups = 1
    tg = t // groups
    cap = tg if capacity_factor <= 0 else max(1, int(tg * k / e * capacity_factor))

    def dispatch(xt, w_gate, w_up, w_down):
        # xt (tg, D) — one token group
        logits = xt.astype(jnp.float32) @ params["w_router"]
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, sel = jax.lax.top_k(probs, k)
        if spec.renormalize:
            gate_vals = gate_vals / jnp.maximum(
                jnp.sum(gate_vals, -1, keepdims=True), 1e-9)
        sel_flat = sel.reshape(-1)
        oh = jax.nn.one_hot(sel_flat, e, dtype=jnp.int32)
        ranks = (jnp.cumsum(oh, axis=0) * oh).sum(-1) - 1
        keep = ranks < cap
        pos = jnp.where(keep, ranks, 0)
        x_rep = jnp.repeat(xt, k, axis=0)
        buf = jnp.zeros((e, cap, d), x.dtype)
        buf = buf.at[sel_flat, pos].add(jnp.where(keep[:, None], x_rep, 0.0))

        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, w_gate)) * jnp.einsum(
            "ecd,edf->ecf", buf, w_up)
        out_buf = jnp.einsum("ecf,efd->ecd", h, w_down)

        y = out_buf[sel_flat, pos]
        y = jnp.where(keep[:, None], y, 0.0)
        y = y * gate_vals.reshape(-1)[:, None].astype(y.dtype)
        y = y.reshape(tg, k, d).sum(axis=1)
        # load-balance auxiliary loss terms (Switch-style)
        f_e = jnp.mean(jax.nn.one_hot(sel, e, dtype=jnp.float32).sum(1), axis=0)
        p_e = jnp.mean(probs, axis=0)
        return y, f_e, p_e

    if groups == 1:
        y, f_e, p_e = dispatch(x.reshape(t, d), params["w_gate"],
                               params["w_up"], params["w_down"])
    else:
        y, f_e, p_e = jax.vmap(dispatch, in_axes=(0, None, None, None))(
            x.reshape(groups, tg, d), params["w_gate"], params["w_up"],
            params["w_down"])
        y = y.reshape(t, d)
        f_e, p_e = jnp.mean(f_e, 0), jnp.mean(p_e, 0)

    if "shared" in params:
        y = y + mlp_layer(params["shared"], x.reshape(t, d), "silu")
    aux = e * jnp.sum(f_e * p_e) / k
    return y.reshape(b, s, d), aux


def moe_layer_ep(params, x: jax.Array, spec, data_axes: tuple,
                 capacity_factor: float = 1.25, fsdp: bool = True):
    """Expert-parallel MoE under partial-manual ``shard_map`` (§Perf
    hillclimb 2).

    The rank-scatter dispatch in :func:`moe_layer` is *global*: under GSPMD a
    scatter whose updates are data-sharded and whose operand is
    expert-sharded gets partitioned as replicate-updates + all-reduce the
    full (E, cap, D) buffer — ~10 GB of AR per layer-microbatch on
    qwen3-235b. Here the dispatch runs manually *inside each data shard*
    (local tokens → local (E, cap_loc, D) buffer, zero collectives); only
    the expert matmuls remain under GSPMD, which handles the 'model'-axis
    TP/EP sharding of the weights. Per-shard capacity (cap/dsize) makes
    token dropping shard-local — the standard EP formulation.
    """
    b, s, d = x.shape
    e, k = spec.num_experts, spec.top_k

    def local(x_loc, w_router, w_gate, w_up, w_down, shared):
        bl = x_loc.shape[0]
        t_loc = bl * s
        cap_loc = (t_loc if capacity_factor <= 0
                   else max(1, int(t_loc * k / e * capacity_factor)))
        xt = x_loc.reshape(bl * s, d)
        if fsdp:  # weights arrive data-sharded on D (ZeRO) → gather at use
            w_gate_f = jax.lax.all_gather(w_gate, data_axes, axis=1, tiled=True)
            w_up_f = jax.lax.all_gather(w_up, data_axes, axis=1, tiled=True)
            w_down_f = jax.lax.all_gather(w_down, data_axes, axis=2, tiled=True)
        else:
            w_gate_f, w_up_f, w_down_f = w_gate, w_up, w_down

        logits = xt.astype(jnp.float32) @ w_router
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, sel = jax.lax.top_k(probs, k)
        if spec.renormalize:
            gate_vals = gate_vals / jnp.maximum(
                jnp.sum(gate_vals, -1, keepdims=True), 1e-9)
        sel_flat = sel.reshape(-1)
        oh = jax.nn.one_hot(sel_flat, e, dtype=jnp.int32)
        ranks = (jnp.cumsum(oh, axis=0) * oh).sum(-1) - 1
        keep = ranks < cap_loc
        pos = jnp.where(keep, ranks, 0)
        x_rep = jnp.repeat(xt, k, axis=0)
        buf = jnp.zeros((e, cap_loc, d), x.dtype)
        buf = buf.at[sel_flat, pos].add(jnp.where(keep[:, None], x_rep, 0.0))

        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, w_gate_f)) * jnp.einsum(
            "ecd,edf->ecf", buf, w_up_f)
        out_buf = jnp.einsum("ecf,efd->ecd", h, w_down_f)
        y = out_buf[sel_flat, pos]
        y = jnp.where(keep[:, None], y, 0.0)
        y = y * gate_vals.reshape(-1)[:, None].astype(y.dtype)
        y = y.reshape(bl * s, k, d).sum(axis=1)
        if shared is not None:
            sh = shared
            if fsdp:
                sh = dict(shared)
                sh["w_gate"] = jax.lax.all_gather(shared["w_gate"], data_axes,
                                                  axis=0, tiled=True)
                sh["w_up"] = jax.lax.all_gather(shared["w_up"], data_axes,
                                                axis=0, tiled=True)
                sh["w_down"] = jax.lax.all_gather(shared["w_down"], data_axes,
                                                  axis=0, tiled=True)
            y = y + mlp_layer(sh, xt, "silu")
        f_e = jnp.mean(jax.nn.one_hot(sel, e, dtype=jnp.float32).sum(1), axis=0)
        p_e = jnp.mean(probs, axis=0)
        aux = e * jnp.sum(f_e * p_e) / k
        aux = jax.lax.pmean(aux, data_axes)
        return y.reshape(bl, s, d), aux

    from jax.sharding import PartitionSpec as P

    dax = data_axes if len(data_axes) > 1 else data_axes[0]
    w_spec2 = P(None, dax, None) if fsdp else P(None, None, None)
    w_spec_down = P(None, None, dax) if fsdp else P(None, None, None)
    shared = params.get("shared")
    shared_spec = None
    if shared is not None:
        shared_spec = {kk: (P(dax, None) if fsdp else P(None, None))
                       for kk in shared}
    out = jax.shard_map(
        local,
        in_specs=(P(dax, None, None), P(None, None), w_spec2, w_spec2,
                  w_spec_down, shared_spec),
        out_specs=(P(dax, None, None), P()),
        axis_names=set(data_axes),
        check_vma=False,
    )(x, params["w_router"], params["w_gate"], params["w_up"],
      params["w_down"], shared)
    return out
