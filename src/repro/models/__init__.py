"""Model zoo: composable decoder layers, MoE, SSM, assembly."""
