"""Model building blocks: norms, rotary embeddings (RoPE / M-RoPE /
sinusoidal), GQA attention with flash-style double-chunked online softmax
(pure JAX for training/prefill — attention stays XLA-fusable and
differentiable), SwiGLU/GELU MLPs, and KV caches.

Caches come in three layouts:
  * fp (bf16/f32): token-major (B, S, K, hd) — read by chunked_attention;
  * int8-quantized: kv-head-major (B, K, S, hd) codes + per-(token, head)
    scales (B, K, S) — the exact layout streamed by the Pallas
    ``kernels.decode_attention`` kernel, which decode-time attention routes
    to (see :func:`quantized_decode_attention`);
  * paged (``PagedKVCache``): the int8 layout cut into fixed pages of a
    shared pool addressed by per-request block tables — ragged batches from
    ``serving.kv_pool``, streamed by ``kernels.paged_decode_attention``
    at decode (see :func:`paged_decode_attention_layer`) and by
    ``kernels.paged_prefill_attention`` for shared-prefix / chunked
    prefill (see :func:`paged_prefill_attention`).

Shapes: activations (B, S, D); q/k/v (B, S, H|K, hd).
"""

from __future__ import annotations

import dataclasses
import math
from functools import partial

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * w).astype(dt)


# ---------------------------------------------------------------------------
# Position encodings
# ---------------------------------------------------------------------------


def rope_table(positions: jax.Array, dim: int, theta: float = 10000.0):
    """positions (..., S) → (cos, sin) of shape (..., S, dim//2)."""
    freqs = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x (B, S, H, hd); cos/sin (B, S, hd//2) or (S, hd//2)."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    x1, x2 = jnp.split(x, 2, axis=-1)
    if cos.ndim == 2:
        cos, sin = cos[None, :, None, :], sin[None, :, None, :]
    else:
        cos, sin = cos[:, :, None, :], sin[:, :, None, :]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1).astype(dt)


def mrope_tables(positions_thw: jax.Array, dim: int, sections: tuple,
                 theta: float = 10000.0):
    """Qwen2-VL M-RoPE: ``positions_thw`` (3, B, S) temporal/height/width ids;
    ``sections`` splits dim//2 into per-axis bands (e.g. (16, 24, 24))."""
    assert sum(sections) == dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    cos_parts, sin_parts = [], []
    start = 0
    for axis, sec in enumerate(sections):
        f = freqs[start : start + sec]
        ang = positions_thw[axis].astype(jnp.float32)[..., None] * f  # (B,S,sec)
        cos_parts.append(jnp.cos(ang))
        sin_parts.append(jnp.sin(ang))
        start += sec
    return jnp.concatenate(cos_parts, -1), jnp.concatenate(sin_parts, -1)


def sinusoidal_embedding(positions: jax.Array, dim: int) -> jax.Array:
    """Classic transformer sinusoidal absolute embedding (MusicGen)."""
    half = dim // 2
    freqs = jnp.exp(-math.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# KV caches
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class KVCache:
    """Per-layer-stack KV cache (a pytree). ``k``/``v`` are either fp tensors
    in token-major (B, S, K, hd) layout, or int8 code tensors in kv-head-major
    (B, K, S, hd) layout with per-(token, head) scales (B, K, S) — realizing
    the paper's Q^a activation-bit control on the cache (Eq. 2) in the exact
    layout the Pallas decode-attention kernel streams.

    ``pos`` holds the absolute position stored in each slot (ring buffers for
    sliding-window layers overwrite slots; attention masks by position, so
    slot order is irrelevant)."""

    k: jax.Array
    v: jax.Array
    k_scale: jax.Array | None  # (B, K, S) when quantized
    v_scale: jax.Array | None
    pos: jax.Array  # (B, S) int32; -1 = empty

    @property
    def quantized(self) -> bool:
        return self.k_scale is not None


jax.tree_util.register_pytree_node(
    KVCache,
    lambda c: ((c.k, c.v, c.k_scale, c.v_scale, c.pos), None),
    lambda _, ch: KVCache(*ch),
)


@dataclasses.dataclass
class PagedKVCache:
    """Per-layer view of the shared paged KV pool (a pytree) — int8 codes +
    f32 scales in PAGE-major layout, addressed through per-request block
    tables instead of a dense per-request sequence axis. Allocation lives in
    ``serving.kv_pool``; this type is what flows through the block scan and
    what ``attention_layer`` routes on.

      k / v        (P, K, page, hd) int8    k/v_scale (P, K, page) f32
      pos          (P, page) int32          (-1 = empty slot)
      block_table  (R, max_blocks) int32    (page ids; 0 = reserved trash
                                             page for pads/inactive rows)

    Unlike the dense ``KVCache`` there is no batch axis on the pool leaves:
    requests of ragged lengths share the pool, and a request's cache is the
    gather of its block-table pages."""

    k: jax.Array
    v: jax.Array
    k_scale: jax.Array
    v_scale: jax.Array
    pos: jax.Array
    block_table: jax.Array

    @property
    def quantized(self) -> bool:
        return True

    @property
    def page_size(self) -> int:
        return self.k.shape[-2]


jax.tree_util.register_pytree_node(
    PagedKVCache,
    lambda c: ((c.k, c.v, c.k_scale, c.v_scale, c.pos, c.block_table), None),
    lambda _, ch: PagedKVCache(*ch),
)


def init_cache(batch: int, size: int, kv_heads: int, head_dim: int,
               dtype=jnp.bfloat16, quantized: bool = False) -> KVCache:
    if quantized:  # kv-head-major kernel layout
        shape = (batch, kv_heads, size, head_dim)
        return KVCache(
            k=jnp.zeros(shape, jnp.int8), v=jnp.zeros(shape, jnp.int8),
            k_scale=jnp.zeros((batch, kv_heads, size), jnp.float32),
            v_scale=jnp.zeros((batch, kv_heads, size), jnp.float32),
            pos=jnp.full((batch, size), -1, jnp.int32),
        )
    shape = (batch, size, kv_heads, head_dim)
    return KVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype), None, None,
                   jnp.full((batch, size), -1, jnp.int32))


def _quantize_kv(x: jax.Array):
    """Symmetric int8 per-(token, head): the Eq. 2 Q_a control realized."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    codes = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return codes, scale


def cache_update(cache: KVCache, k_new: jax.Array, v_new: jax.Array,
                 pos: jax.Array, window: int | None = None) -> KVCache:
    """Write ``k_new``/``v_new`` (B, S_new, K, hd) at absolute position ``pos``
    (scalar int32). Ring-buffered when ``window`` is set. Quantized caches are
    written in the kernel's kv-head-major layout, the slot axis being 2
    instead of 1."""
    seq_axis = 2 if cache.quantized else 1
    size = cache.k.shape[seq_axis]
    if window is not None:
        # quantized caches may be block-padded past the window; the ring must
        # wrap within it so stale positions can't outlive the window (pad
        # slots are never written and keep pos = -1 → masked)
        size = min(window, size)
    s_new = k_new.shape[1]
    if window is not None and s_new >= size:
        # writing ≥ a full ring: only the last ``size`` tokens survive; slice
        # them out so scatter indices stay unique (a permutation of the ring)
        keep = slice(s_new - size, None)
        k_new, v_new = k_new[:, keep], v_new[:, keep]
        pos = pos + (s_new - size)
        s_new = size
    if window is not None:
        slots = (pos + jnp.arange(s_new)) % size  # ring buffer

        def write(buf, val, axis=1):
            idx = (slice(None),) * axis + (slots,)
            return buf.at[idx].set(val.astype(buf.dtype))

        def write_pos(buf):
            return buf.at[:, slots].set(pos + jnp.arange(s_new))

    else:

        def write(buf, val, axis=1):  # contiguous → dynamic_update_slice
            idx = (0,) * axis + (pos,) + (0,) * (buf.ndim - axis - 1)
            return jax.lax.dynamic_update_slice(buf, val.astype(buf.dtype), idx)

        def write_pos(buf):
            upd = (pos + jnp.arange(s_new)[None, :]) * jnp.ones(
                (buf.shape[0], 1), jnp.int32)
            return jax.lax.dynamic_update_slice(buf, upd, (0, pos))

    if cache.quantized:
        kc, ks = _quantize_kv(k_new)  # (B, S_new, K, hd), (B, S_new, K, 1)
        vc, vs = _quantize_kv(v_new)
        to_hm = lambda c: jnp.swapaxes(c, 1, 2)  # token- → kv-head-major
        return KVCache(write(cache.k, to_hm(kc), seq_axis),
                       write(cache.v, to_hm(vc), seq_axis),
                       write(cache.k_scale, to_hm(ks[..., 0]), seq_axis),
                       write(cache.v_scale, to_hm(vs[..., 0]), seq_axis),
                       write_pos(cache.pos))
    return KVCache(write(cache.k, k_new), write(cache.v, v_new), None, None,
                   write_pos(cache.pos))


def paged_cache_update(cache: PagedKVCache, k_new: jax.Array, v_new: jax.Array,
                       positions: jax.Array,
                       slots: jax.Array | None = None) -> PagedKVCache:
    """Scatter ``k_new``/``v_new`` (R, S_new, K, hd) into the shared pool.

    ``positions`` (R, S_new) carries each token's ABSOLUTE position; negative
    entries are ragged-prefill pads (or inactive decode slots) and are routed
    to the reserved trash page 0 with ``pos = -1``, so they can never be
    attended — as is a position past the block table's reach or one whose
    table entry is still unallocated (a caller that skipped the host-side
    ``PagedKVPool.append`` would otherwise corrupt a live page or leak a
    real position onto the shared trash page). Valid tokens land at
    page ``block_table[r, p // page]``, slot ``p % page`` — distinct
    positions of a request hit distinct (page, slot) pairs, so every valid
    scatter index is unique. Quantization is the same per-(token, head) int8
    transform as the dense cache (bit-identical codes — the dense↔paged
    parity tests rely on this).

    ``slots`` (R, S_new) switches to SEGMENT-AWARE scatter for the
    token-packed varlen path: each token's block-table row is its own slot
    id rather than its batch row (the packed call's batch dim is 1 while
    its tokens span many requests). Tokens with slot -1 are pads."""
    page = cache.page_size
    r, s_new = positions.shape
    nbt = cache.block_table.shape[1]
    valid = (positions >= 0) & (positions < nbt * page)
    page_idx = jnp.where(valid, positions // page, 0)
    if slots is None:
        pages = jnp.take_along_axis(cache.block_table, page_idx, axis=1)
    else:
        valid = valid & (slots >= 0)
        pages = cache.block_table[jnp.maximum(slots, 0), page_idx]
    pages = jnp.where(valid, pages, 0)
    # a position whose block-table entry is still 0 (page not yet allocated)
    # must not store a real pos on the shared trash page — every request's
    # unused table entries point there, so it would leak across requests
    valid = valid & (pages != 0)
    slots = jnp.where(valid, positions % page, 0)
    pr, sl = pages.reshape(-1), slots.reshape(-1)

    kc, ks = _quantize_kv(k_new)  # (R, S_new, K, hd), (R, S_new, K, 1)
    vc, vs = _quantize_kv(v_new)

    def put(buf, val):  # buf (P, K, page[, hd]); val (R, S_new, K[, hd])
        flat = val.reshape((r * s_new,) + val.shape[2:])
        return buf.at[pr, :, sl].set(flat.astype(buf.dtype))

    new_pos = cache.pos.at[pr, sl].set(
        jnp.where(valid, positions, -1).reshape(-1))
    return PagedKVCache(put(cache.k, kc), put(cache.v, vc),
                        put(cache.k_scale, ks[..., 0]),
                        put(cache.v_scale, vs[..., 0]),
                        new_pos, cache.block_table)


# ---------------------------------------------------------------------------
# Flash-style attention (pure JAX, double-chunked online softmax)
# ---------------------------------------------------------------------------

NEG_INF = -1e30


def _soft_cap(scores: jax.Array, cap: float | None) -> jax.Array:
    if cap is None:
        return scores
    return cap * jnp.tanh(scores / cap)


@partial(jax.jit, static_argnames=("causal", "window", "softcap", "q_chunk", "kv_chunk"))
def chunked_attention(
    q: jax.Array,  # (B, Sq, H, hd)
    k: jax.Array,  # (B, Skv, K, hd)
    v: jax.Array,  # (B, Skv, K, hd)
    q_pos: jax.Array,  # (B, Sq) absolute positions of queries
    kv_pos: jax.Array,  # (B, Skv) absolute positions of keys (-1 = invalid)
    *,
    causal: bool = True,
    window: int | None = None,
    softcap: float | None = None,
    q_chunk: int = 1024,
    kv_chunk: int = 1024,
) -> jax.Array:
    """Memory-bounded attention: outer scan over query chunks, inner scan over
    KV chunks with online softmax. Never materializes an (Sq, Skv) score
    tensor — required for the 32k/500k shapes. Supports GQA (grouped heads),
    sliding windows and logit soft-capping. (Quantized-cache decode routes to
    :func:`quantized_decode_attention` instead.)"""
    b, sq, h, hd = q.shape
    _, skv, kh, _ = k.shape
    g = h // kh
    qc = min(q_chunk, sq)
    kc = min(kv_chunk, skv)
    nq = -(-sq // qc)
    nk = -(-skv // kc)
    pad_q = nq * qc - sq
    pad_k = nk * kc - skv

    scale = 1.0 / math.sqrt(hd)
    qf = (q * scale).astype(jnp.float32)
    if pad_q:
        qf = jnp.pad(qf, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, ((0, 0), (0, pad_q)), constant_values=-(10 ** 9))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, ((0, 0), (0, pad_k)), constant_values=-1)

    if nq == 1 and nk == 1:
        # single-block fast path (decode): no scan, no reshape/dynamic-slice
        # — keeps a seq- or head-sharded KV cache shardable under GSPMD
        # (the scan path's dynamic-slice forces involuntary remat/all-gather)
        q1 = qf.reshape(b, qc, kh, g, hd)
        kf = k.astype(jnp.float32)
        vf = v.astype(jnp.float32)
        s = jnp.einsum("bqkgd,bckd->bkgqc", q1, kf,
                       preferred_element_type=jnp.float32)
        s = _soft_cap(s, softcap)
        mask = kv_pos[:, None, None, None, :] >= 0
        if causal:
            mask &= kv_pos[:, None, None, None, :] <= q_pos[:, None, None, :, None]
        if window is not None:
            mask &= kv_pos[:, None, None, None, :] > (
                q_pos[:, None, None, :, None] - window)
        s = jnp.where(mask, s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum("bkgqc,bckd->bkgqd", p, vf,
                         preferred_element_type=jnp.float32)
        out = out.transpose(0, 3, 1, 2, 4).reshape(b, qc, h, hd)[:, :sq]
        return out.astype(q.dtype)

    # (B, nq, qc, K, G, hd) view of queries
    qf = qf.reshape(b, nq, qc, kh, g, hd)
    qp = q_pos.reshape(b, nq, qc)
    kr = k.reshape(b, nk, kc, kh, hd)
    vr = v.reshape(b, nk, kc, kh, hd)
    kp = kv_pos.reshape(b, nk, kc)

    def q_step(_, qi):
        q_blk = qf[:, qi]  # (B, qc, K, G, hd)
        qp_blk = qp[:, qi]  # (B, qc)

        def kv_step(carry, ki):
            m, l, acc = carry
            k_blk = kr[:, ki]
            v_blk = vr[:, ki]
            kp_blk = kp[:, ki]  # (B, kc)
            s = jnp.einsum("bqkgd,bckd->bkgqc", q_blk,
                           k_blk.astype(jnp.float32),
                           preferred_element_type=jnp.float32)
            s = _soft_cap(s, softcap)
            mask = kp_blk[:, None, None, None, :] >= 0
            if causal:
                mask &= kp_blk[:, None, None, None, :] <= qp_blk[:, None, None, :, None]
            if window is not None:
                mask &= kp_blk[:, None, None, None, :] > (qp_blk[:, None, None, :, None] - window)
            s = jnp.where(mask, s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bkgqc,bckd->bkgqd", p, v_blk.astype(jnp.float32),
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, kh, g, qc), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, kh, g, qc), jnp.float32)
        a0 = jnp.zeros((b, kh, g, qc, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), jnp.arange(nk))
        out = acc / jnp.maximum(l, 1e-30)[..., None]  # (B, K, G, qc, hd)
        return None, out.transpose(0, 3, 1, 2, 4)  # (B, qc, K, G, hd)

    if nq == 1:
        _, out = q_step(None, 0)
        out = out[:, None]
    else:
        _, out = jax.lax.scan(q_step, None, jnp.arange(nq))
        out = jnp.moveaxis(out, 0, 1)  # (B, nq, qc, K, G, hd)
    out = out.reshape(b, nq * qc, h, hd)[:, :sq]
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# Quantized-cache decode attention (Pallas fast path + fallback)
# ---------------------------------------------------------------------------


def quantized_decode_attention(q, cache: KVCache, spec, q_positions, pos, *,
                               q_chunk=1024, kv_chunk=1024):
    """Decode-time attention over the kv-head-major int8 cache.

    Kernel-eligible layers — single-token query, no logit softcap — stream
    the int8 codes straight through the Pallas ``decode_attention`` kernel
    (``interpret=True`` off-TPU gives bit-identical CPU parity), never
    materializing a dequantized fp copy of the cache. Sliding-window layers
    are eligible too: their ring buffer only ever holds in-window positions,
    so the kernel's position mask is sufficient. Softcapped layers (gemma2)
    dequantize to the token-major layout and take chunked_attention.
    """
    b, s, h, hd = q.shape
    kh = cache.k.shape[1]
    if s == 1 and spec.attn_softcap is None:
        from repro.kernels.ops import decode_attention

        qh = q[:, 0].reshape(b, kh, h // kh, hd)
        out = decode_attention(qh, cache.k, cache.k_scale, cache.v,
                               cache.v_scale, cache.pos,
                               jnp.asarray(pos, jnp.int32))
        return out.reshape(b, 1, h, hd).astype(q.dtype)
    k = jnp.swapaxes(cache.k.astype(jnp.float32) * cache.k_scale[..., None], 1, 2)
    v = jnp.swapaxes(cache.v.astype(jnp.float32) * cache.v_scale[..., None], 1, 2)
    return chunked_attention(q, k, v, q_positions, cache.pos, causal=True,
                             window=spec.sliding_window,
                             softcap=spec.attn_softcap,
                             q_chunk=q_chunk, kv_chunk=kv_chunk)


def _head_shard(head_axis, head_shards: int, kh: int):
    """This shard's kv-head slice ``(offset, kh_local)`` under the
    ``RuntimeOpts.head_axis`` split, or None to run the full head set.
    Only meaningful inside a ``shard_map`` that binds ``head_axis``; the
    split must divide the kv-head count evenly (``sharded_step_fns``
    guarantees it)."""
    if head_axis is None or head_shards <= 1 or kh % head_shards:
        return None
    kh_loc = kh // head_shards
    return jax.lax.axis_index(head_axis) * kh_loc, kh_loc


def _slice_cache_heads(cache: PagedKVCache, off, kh_loc: int) -> PagedKVCache:
    """Slice the pool leaves' kv-head axis (axis 1 of the per-block
    (P, K, page[, hd]) leaves) down to one shard's head group."""
    sl = lambda a: jax.lax.dynamic_slice_in_dim(a, off, kh_loc, axis=1)
    return PagedKVCache(sl(cache.k), sl(cache.v), sl(cache.k_scale),
                        sl(cache.v_scale), cache.pos, cache.block_table)


def _gather_dense_kv(cache: PagedKVCache):
    """Gather a paged cache dense via its block table and dequantize:
    (k, v) (R, S_pool, K, hd) f32 token-major + kv_pos (R, S_pool)."""
    from repro.kernels.ref import gather_pages_ref

    kd = gather_pages_ref(cache.k, cache.block_table)  # (R, K, Sp, hd)
    vd = gather_pages_ref(cache.v, cache.block_table)
    ks = gather_pages_ref(cache.k_scale, cache.block_table)
    vs = gather_pages_ref(cache.v_scale, cache.block_table)
    kv_pos = gather_pages_ref(cache.pos, cache.block_table)  # (R, Sp)
    k = jnp.swapaxes(kd.astype(jnp.float32) * ks[..., None], 1, 2)
    v = jnp.swapaxes(vd.astype(jnp.float32) * vs[..., None], 1, 2)
    return k, v, kv_pos


def paged_prefill_attention(q, cache: PagedKVCache, k_fresh, v_fresh, spec,
                            q_positions, *, q_chunk=1024, kv_chunk=1024,
                            use_kernel: bool = True, head_axis=None,
                            head_shards: int = 1):
    """Prefill attention THROUGH the paged pool — the shared-prefix /
    chunked-prefill entry.

    A plain prefill attends only the call's fresh k/v; a request forked from
    a shared prefix — or a chunked prefill's continuation chunk — additionally
    owns block-table pages holding tokens written BEFORE this call (the
    prefix / the earlier chunks). Each row attends the union of

      * its pool history, masked to stored positions < the row's FIRST
        in-call position (so tokens this very call scatters into the pool
        are not double-counted, and a row prefilling from position 0 sees
        no history at all), dequantized from int8 — exactly what its decode
        steps will read; and
      * the call's fresh keys/values at full precision, masked causally by
        ``q_positions`` like the plain ragged prefill.

    ``cache`` must be the post-update pool (this call's tokens already
    scattered), so rows created in the SAME call can serve as each other's
    prefix — the split engine prefills the prefix owner and its forks in
    one batched call.

    The default path walks the history pages in place with the Pallas
    ``kernels.paged_prefill_attention`` flash kernel (int8 dequantized
    in-register through the block-table index map — no dense f32 copy of
    the pool in HBM). Softcapped / windowed layers, and callers passing
    ``use_kernel=False`` (``RuntimeOpts.paged_prefill_kernel``), fall back
    to gathering the pool dense into ``chunked_attention`` — correct, not
    fast."""
    if use_kernel and spec.attn_softcap is None and spec.sliding_window is None:
        from repro.kernels.ops import paged_prefill_attention as _kernel

        b, s, h, hd = q.shape
        kh = cache.k.shape[1]
        qk = q.reshape(b, s, kh, h // kh, hd).transpose(0, 2, 1, 3, 4)
        kf, vf = jnp.swapaxes(k_fresh, 1, 2), jnp.swapaxes(v_fresh, 1, 2)
        shard = _head_shard(head_axis, head_shards, kh)
        if shard is not None:  # this shard walks the pages with its heads
            off, kh_loc = shard
            dyn = lambda a: jax.lax.dynamic_slice_in_dim(a, off, kh_loc, 1)
            qk, kf, vf = dyn(qk), dyn(kf), dyn(vf)
            cache = _slice_cache_heads(cache, off, kh_loc)
        out = _kernel(qk, cache.k, cache.k_scale, cache.v, cache.v_scale,
                      cache.pos, cache.block_table,
                      jnp.asarray(q_positions, jnp.int32), kf, vf)
        if shard is not None:  # exact tiled reassembly — no reduction
            out = jax.lax.all_gather(out, head_axis, axis=1, tiled=True)
        return out.transpose(0, 2, 1, 3, 4).reshape(b, s, h, hd).astype(q.dtype)
    from repro.kernels.paged_prefill_attention import first_call_position

    k_hist, v_hist, hist_pos = _gather_dense_kv(cache)
    start = first_call_position(q_positions)  # (R,) per-row history bound
    hist_pos = jnp.where(hist_pos < start[:, None], hist_pos, -1)
    k = jnp.concatenate([k_hist, k_fresh.astype(jnp.float32)], axis=1)
    v = jnp.concatenate([v_hist, v_fresh.astype(jnp.float32)], axis=1)
    kv_pos = jnp.concatenate([hist_pos, q_positions], axis=1)
    return chunked_attention(q, k, v, q_positions, kv_pos, causal=True,
                             window=spec.sliding_window,
                             softcap=spec.attn_softcap,
                             q_chunk=q_chunk, kv_chunk=kv_chunk)


def paged_decode_attention_layer(q, cache: PagedKVCache, spec, q_positions, *,
                                 q_chunk=1024, kv_chunk=1024, head_axis=None,
                                 head_shards: int = 1):
    """Decode-time attention through the PAGED pool.

    Kernel-eligible layers — single-token query, no logit softcap — walk
    their block-table pages with the Pallas ``paged_decode_attention`` kernel
    (scalar-prefetch gather, per-request causal bounds for ragged batches).
    Softcapped layers gather their pages dense via the block table and
    dequantize into ``chunked_attention`` — correct, not fast.

    ``q_positions`` (R, S): the per-request absolute query positions; the
    last column is each row's causal bound (-1 marks an inactive decode
    slot, which masks every key and yields a finite all-zero output)."""
    b, s, h, hd = q.shape
    kh = cache.k.shape[1]
    q_pos = q_positions[:, -1].astype(jnp.int32)
    if s == 1 and spec.attn_softcap is None:
        from repro.kernels.ops import paged_decode_attention

        qh = q[:, 0].reshape(b, kh, h // kh, hd)
        shard = _head_shard(head_axis, head_shards, kh)
        if shard is not None:  # this shard walks the pages with its heads
            off, kh_loc = shard
            qh = jax.lax.dynamic_slice_in_dim(qh, off, kh_loc, 1)
            cache = _slice_cache_heads(cache, off, kh_loc)
        out = paged_decode_attention(qh, cache.k, cache.k_scale, cache.v,
                                     cache.v_scale, cache.pos,
                                     cache.block_table, q_pos)
        if shard is not None:  # exact tiled reassembly — no reduction
            out = jax.lax.all_gather(out, head_axis, axis=1, tiled=True)
        return out.reshape(b, 1, h, hd).astype(q.dtype)
    k, v, kv_pos = _gather_dense_kv(cache)
    return chunked_attention(q, k, v, q_positions, kv_pos, causal=True,
                             window=spec.sliding_window,
                             softcap=spec.attn_softcap,
                             q_chunk=q_chunk, kv_chunk=kv_chunk)


def varlen_attention_layer(q, cache: PagedKVCache, k_fresh, v_fresh, spec,
                           q_positions, token_slots, *,
                           use_kernel: bool = True, head_axis=None,
                           head_shards: int = 1):
    """Token-packed VARLEN attention through the pool — the packed tick's
    entry. ONE flat batch (batch dim 1) whose tokens span many requests:
    q (1, T, H, hd), per-token ``q_positions``/``token_slots`` (1, T), the
    call's fresh k/v (1, T, K, hd). Each token attends its own slot's pool
    history (stored positions below the slot's first in-call position) plus
    the causally-ordered fresh keys of its own segment; pad rows (slot -1)
    emit exact zeros. ``cache`` must be the post-update pool, exactly like
    :func:`paged_prefill_attention`.

    The default path is the Pallas ``kernels.varlen_attention`` page walk;
    softcapped / windowed layers have no varlen route (the packed scheduler
    refuses such models up front), and ``use_kernel=False`` falls back to
    the dense ``kernels.ref`` oracle — correct, not fast."""
    if spec.attn_softcap is not None or spec.sliding_window is not None:
        raise NotImplementedError(
            "the token-packed varlen path requires kernel-eligible "
            "attention (no softcap, no sliding window)")
    b, t, h, hd = q.shape
    kh = cache.k.shape[1]
    qk = q.reshape(t, kh, h // kh, hd).transpose(1, 0, 2, 3)  # (K, T, G, hd)
    kf = jnp.swapaxes(k_fresh.reshape(t, kh, hd), 0, 1)  # (K, T, hd)
    vf = jnp.swapaxes(v_fresh.reshape(t, kh, hd), 0, 1)
    qp = jnp.asarray(q_positions, jnp.int32).reshape(-1)
    sl = jnp.asarray(token_slots, jnp.int32).reshape(-1)
    shard = _head_shard(head_axis, head_shards, kh)
    if shard is not None:  # this shard walks the pages with its heads
        off, kh_loc = shard
        dyn = lambda a: jax.lax.dynamic_slice_in_dim(a, off, kh_loc, 0)
        qk, kf, vf = dyn(qk), dyn(kf), dyn(vf)
        cache = _slice_cache_heads(cache, off, kh_loc)
    if use_kernel:
        from repro.kernels.ops import varlen_attention as _kernel

        out = _kernel(qk, cache.k, cache.k_scale, cache.v, cache.v_scale,
                      cache.pos, cache.block_table, qp, sl, kf, vf)
    else:
        from repro.kernels.ref import varlen_attention_ref
        from repro.kernels.varlen_attention import segment_start

        start = segment_start(qp, sl, cache.block_table.shape[0])
        out = varlen_attention_ref(qk, cache.k, cache.k_scale, cache.v,
                                   cache.v_scale, cache.pos,
                                   cache.block_table, qp, sl, start, kf, vf)
    if shard is not None:  # exact tiled reassembly — no reduction
        out = jax.lax.all_gather(out, head_axis, axis=0, tiled=True)
    return out.transpose(1, 0, 2, 3).reshape(b, t, h, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# Attention layer (projections + rope + cache plumbing)
# ---------------------------------------------------------------------------


def init_attention_params(key, d_model: int, num_heads: int, num_kv_heads: int,
                          head_dim: int, dtype=jnp.float32, qk_norm: bool = False):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s_in = 1.0 / math.sqrt(d_model)
    s_out = 1.0 / math.sqrt(num_heads * head_dim)
    p = {
        "wq": (jax.random.normal(k1, (d_model, num_heads * head_dim)) * s_in).astype(dtype),
        "wk": (jax.random.normal(k2, (d_model, num_kv_heads * head_dim)) * s_in).astype(dtype),
        "wv": (jax.random.normal(k3, (d_model, num_kv_heads * head_dim)) * s_in).astype(dtype),
        "wo": (jax.random.normal(k4, (num_heads * head_dim, d_model)) * s_out).astype(dtype),
    }
    if qk_norm:
        p["q_norm"] = jnp.ones((head_dim,), dtype)
        p["k_norm"] = jnp.ones((head_dim,), dtype)
    return p


def attention_layer(params, x: jax.Array, spec, *, rope_cs, cache: KVCache | None,
                    pos, q_positions, q_chunk=1024, kv_chunk=1024,
                    decode: bool = False, attend_cache: bool = False,
                    prefill_kernel: bool = True, token_slots=None,
                    quant_fresh=None, head_axis=None, head_shards: int = 1):
    """One attention layer.

    ``rope_cs``: (cos, sin) tables for the query positions, or None.
    ``cache``/``pos``: cache plumbing (None for pure training). During
    prefill the cache is *written* but attention runs over the fresh k/v
    (a window-sized ring cache cannot serve early queries their own window;
    chunked multi-segment prefill is not used by this framework). Only
    ``decode=True`` attends through the cache — except ``attend_cache=True``
    on a paged cache, which prefills THROUGH the pool (shared-prefix
    suffix prefill: history pages + fresh k/v, see
    :func:`paged_prefill_attention`), and ``token_slots`` on a paged cache,
    which routes the token-packed VARLEN path (per-token block-table rows
    for a flat mixed prefill/decode batch, see
    :func:`varlen_attention_layer`). Returns (output, new_cache).

    ``quant_fresh`` (B, S) bool (varlen route only): rows whose fresh k/v
    are attended through the int8 quantize→dequantize round trip — the
    exact values ``paged_cache_update`` stores, so a packed decode token
    attends its OWN key identically to a sequential decode step reading it
    back from the pool. The cache write always uses the original f32 k/v
    (re-quantizing a dequantized tensor is not code-stable).
    ``head_axis``/``head_shards``: see ``RuntimeOpts`` — split the paged
    kernels' kv-head axis across a shard_map mesh axis."""
    b, s, d = x.shape
    h, kh, hd = spec.num_heads, spec.num_kv_heads, spec.head_dim
    q = (x @ params["wq"]).reshape(b, s, h, hd)
    k = (x @ params["wk"]).reshape(b, s, kh, hd)
    v = (x @ params["wv"]).reshape(b, s, kh, hd)
    if "q_norm" in params:
        q = rms_norm(q, params["q_norm"])
        k = rms_norm(k, params["k_norm"])
    if rope_cs is not None:
        cos, sin = rope_cs
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)

    new_cache = None
    if cache is not None:
        if isinstance(cache, PagedKVCache):
            # paged pool: positions are per-token (ragged prefill pads < 0)
            new_cache = paged_cache_update(cache, k, v, q_positions,
                                           slots=token_slots)
        else:
            new_cache = cache_update(cache, k, v, pos, spec.sliding_window)
    if token_slots is not None and isinstance(new_cache, PagedKVCache):
        k_att, v_att = k, v
        if quant_fresh is not None:
            # int8 round trip for the masked rows: bit-identical to what
            # paged_cache_update just stored for them, so attending these
            # "fresh" keys equals reading them back from the pool
            kc, ks = _quantize_kv(k)
            vc, vs = _quantize_kv(v)
            m = quant_fresh[..., None, None]  # (B, S, 1, 1)
            k_att = jnp.where(m, kc.astype(jnp.float32) * ks, k).astype(k.dtype)
            v_att = jnp.where(m, vc.astype(jnp.float32) * vs, v).astype(v.dtype)
        out = varlen_attention_layer(q, new_cache, k_att, v_att, spec,
                                     q_positions, token_slots,
                                     use_kernel=prefill_kernel,
                                     head_axis=head_axis,
                                     head_shards=head_shards)
    elif cache is not None and decode:
        if isinstance(new_cache, PagedKVCache):
            out = paged_decode_attention_layer(
                q, new_cache, spec, q_positions,
                q_chunk=q_chunk, kv_chunk=kv_chunk,
                head_axis=head_axis, head_shards=head_shards)
        elif new_cache.quantized:
            out = quantized_decode_attention(
                q, new_cache, spec, q_positions, pos,
                q_chunk=q_chunk, kv_chunk=kv_chunk)
        else:
            out = chunked_attention(
                q, new_cache.k, new_cache.v, q_positions, new_cache.pos,
                causal=True, window=spec.sliding_window,
                softcap=spec.attn_softcap, q_chunk=q_chunk, kv_chunk=kv_chunk)
    elif attend_cache and isinstance(new_cache, PagedKVCache):
        out = paged_prefill_attention(q, new_cache, k, v, spec, q_positions,
                                      q_chunk=q_chunk, kv_chunk=kv_chunk,
                                      use_kernel=prefill_kernel,
                                      head_axis=head_axis,
                                      head_shards=head_shards)
    else:
        out = chunked_attention(
            q, k, v, q_positions, q_positions,
            causal=True, window=spec.sliding_window, softcap=spec.attn_softcap,
            q_chunk=q_chunk, kv_chunk=kv_chunk)
    out = out.reshape(b, s, h * hd) @ params["wo"]
    return out, new_cache


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def init_mlp_params(key, d_model: int, d_ff: int, gated: bool = True, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    s_in = 1.0 / math.sqrt(d_model)
    s_out = 1.0 / math.sqrt(d_ff)
    p = {"w_up": (jax.random.normal(ks[0], (d_model, d_ff)) * s_in).astype(dtype),
         "w_down": (jax.random.normal(ks[1], (d_ff, d_model)) * s_out).astype(dtype)}
    if gated:
        p["w_gate"] = (jax.random.normal(ks[2], (d_model, d_ff)) * s_in).astype(dtype)
    return p


def mlp_layer(params, x: jax.Array, activation: str = "silu") -> jax.Array:
    act = {"silu": jax.nn.silu, "gelu": jax.nn.gelu}[activation]
    up = x @ params["w_up"]
    if "w_gate" in params:
        up = act(x @ params["w_gate"]) * up
    else:
        up = act(up)
    return up @ params["w_down"]
