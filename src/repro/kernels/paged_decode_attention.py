"""Pallas TPU kernel: decode attention over a PAGED int8 KV-cache pool.

The dense ``kernels.decode_attention`` walk is already page-shaped: each grid
step loads one sequence block, masks by stored position, and folds it into
online-softmax state. This kernel keeps that walk unchanged and only swaps
the addressing — the minor grid axis no longer strides a per-request dense
cache but *gathers* the request's pages from a shared pool through a
block-table index map (``pltpu.PrefetchScalarGridSpec``: the block table and
per-request query positions are scalar-prefetched so the DMA addresses are
known before the body runs).

Pool layout (one pool per layer; `serving.kv_pool` owns allocation):

  k_codes  (P, K, page, hd) int8     k_scale (P, K, page) f32
  v_codes  (P, K, page, hd) int8     v_scale (P, K, page) f32
  pool_pos (P, page)        int32    (-1 = empty/pad slot)

Per-request operands:

  q            (R, K, G, hd)        one query token per active slot
  block_table  (R, max_blocks) int32  page ids; unused entries point at the
                                      reserved trash page 0 (all pos = -1,
                                      masked like any empty slot)
  q_pos        (R,) int32           per-request absolute position (ragged
                                      batches decode at unequal positions)

Grid: one program per (request, kv_head); the minor axis walks the request's
``max_blocks`` block-table entries. A fully-invalid page (trash or padding)
contributes garbage that the first valid page's correction factor
``exp(m_prev - m_new) = exp(-inf)`` scrubs to zero — and a row whose table
is ALL trash (a free decode slot) is caught by the epilogue's ``seen`` guard
and emits exact zeros, never NaN (the oracle in ``ref.py`` does not model
this free-slot case; parity holds on rows with ≥ 1 valid key).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
TRASH_PAGE = 0  # page id reserved by the pool for masked/pad gathers


def _kernel(nb: int, scale: float, bt_ref, qp_ref, q_ref, kc_ref, ks_ref,
            vc_ref, vs_ref, pos_ref, o_ref, m_ref, l_ref, acc_ref):
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    r = pl.program_id(0)
    q = q_ref[0, 0].astype(jnp.float32) * scale  # (G, hd)
    k = kc_ref[0, 0].astype(jnp.float32) * ks_ref[0, 0][:, None]  # (page, hd)
    v = vc_ref[0, 0].astype(jnp.float32) * vs_ref[0, 0][:, None]
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # (G, page)

    kv_pos = pos_ref[0]  # (page,)
    valid = (kv_pos >= 0) & (kv_pos <= qp_ref[r])
    s = jnp.where(valid[None, :], s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(si == nb - 1)
    def _finish():
        # a row whose every page was masked (free decode slot: all-trash
        # block table, q_pos = -1) never raises m above its init — emit
        # exact zeros instead of the exp(0)-uniform average of trash values
        seen = m_ref[...] > NEG_INF * 0.5
        o_ref[0, 0] = jnp.where(
            seen, acc_ref[...] / jnp.maximum(l_ref[...], 1e-30), 0.0)


def paged_decode_attention(q, k_codes, k_scale, v_codes, v_scale, pool_pos,
                           block_table, q_pos, interpret: bool = False):
    """See module docstring. Returns (R, K, G, hd) f32."""
    r, kh, g, hd = q.shape
    p, _, page, _ = k_codes.shape
    nb = block_table.shape[1]
    assert block_table.shape[0] == r and q_pos.shape == (r,)
    assert pool_pos.shape == (p, page)
    scale = 1.0 / (hd ** 0.5)
    kern = functools.partial(_kernel, nb, scale)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # block_table, q_pos
        grid=(r, kh, nb),
        in_specs=[
            pl.BlockSpec((1, 1, g, hd), lambda i, j, si, bt, qp: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, page, hd),
                         lambda i, j, si, bt, qp: (bt[i, si], j, 0, 0)),
            pl.BlockSpec((1, 1, page),
                         lambda i, j, si, bt, qp: (bt[i, si], j, 0)),
            pl.BlockSpec((1, 1, page, hd),
                         lambda i, j, si, bt, qp: (bt[i, si], j, 0, 0)),
            pl.BlockSpec((1, 1, page),
                         lambda i, j, si, bt, qp: (bt[i, si], j, 0)),
            pl.BlockSpec((1, page), lambda i, j, si, bt, qp: (bt[i, si], 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, hd),
                               lambda i, j, si, bt, qp: (i, j, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, hd), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((r, kh, g, hd), jnp.float32),
        interpret=interpret,
    )(block_table, q_pos, q, k_codes, k_scale, v_codes, v_scale, pool_pos)
