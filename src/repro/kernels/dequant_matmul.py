"""Pallas TPU kernel: fused int8-weight × activation matmul (OPSC front
segment / Atom-lite inference path).

TPU mapping: classic (M/BM, N/BN, K/BK) grid with a VMEM f32 accumulator
scratch. The int8 weight tile is upcast in-register and fed to the MXU
(``preferred_element_type=f32``); the per-output-channel scale multiplies
once on the final K step — so the dequantized weights NEVER materialize in
HBM, which is the entire point of weight-only quantization on TPU (HBM
traffic is the decode bottleneck; int8 halves it vs bf16).

Block defaults (128, 128, 512) keep the working set ≈ (BM·BK·2 + BK·BN +
BM·BN·4) ≈ 0.6 MB ≪ 16 MB VMEM and all matmul dims MXU-aligned (128).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _dequant_matmul_kernel(nk: int, x_ref, w_ref, s_ref, o_ref, acc_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)  # int8 tile upcast in-register
    acc_ref[...] += jnp.dot(x, w, preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _finish():
        o_ref[...] = acc_ref[...] * s_ref[...]  # per-out-channel scale


def dequant_matmul(x: jax.Array, w_codes: jax.Array, w_scale: jax.Array,
                   block_m: int = 128, block_n: int = 128, block_k: int = 512,
                   interpret: bool = False) -> jax.Array:
    """x (M, K) bf16/f32 × codes (K, N) int8, scale (N,) f32 → (M, N) f32."""
    m, k = x.shape
    k2, n = w_codes.shape
    assert k == k2 and w_scale.shape == (n,)
    bm, bn, bk = min(block_m, m), min(block_n, n), min(block_k, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (m, n, k, bm, bn, bk)
    nk = k // bk
    kern = functools.partial(_dequant_matmul_kernel, nk)
    return pl.pallas_call(
        kern,
        grid=(m // bm, n // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, w_codes, w_scale[None, :])
