"""Pallas TPU kernel: token-packed VARLEN attention over a paged int8 KV pool.

The chunked-prefill tick pads every prefill wave to a ``(max_slots, chunk)``
rectangle and runs decode as a SECOND compiled step — at high occupancy most
of that rectangle is pad and every decoding request pays two dispatches per
tick. This kernel serves ONE flat token batch instead: ragged prefill chunks
and single decode tokens from different requests coexist in the same call
(a decode token is just a length-1 segment), so the scheduler's whole tick
is one fixed-shape dispatch whose pad is only the flat buffer's tail.

Queries arrive as ``(K, T, G, hd)`` — a flat token axis of ``T =
token_budget`` rows, each carrying its request's slot id (= block-table
row) and absolute position. Two key groups fold into one online softmax per
row, exactly like the rectangular prefill kernel
(``kernels.paged_prefill_attention``), but masked per TOKEN rather than per
grid row:

  * POOL HISTORY — the minor grid axis walks EVERY slot's block-table pages
    (step ``si`` serves slot ``si // nb``, page ``block_table[slot,
    si % nb]``); a page's keys are valid only for query rows whose slot id
    matches the step's slot AND whose stored positions lie below that
    slot's first in-call position ``start[slot]`` (tokens this very call
    scatters into the pool are excluded — they are attended as fresh keys
    instead). ``start[slot]`` is each row's causal history bound: every
    row of the slot sits at a position ``>= start``, so the per-row causal
    check is implied by the per-slot one.
  * FRESH KEYS — the call's own k/v ``(K, T, hd)`` at full precision,
    walked as the final minor step with a block-diagonal causal mask:
    key column ``c`` is valid for query row ``r`` iff both carry the same
    slot id and ``q_pos[c] <= q_pos[r]``.

Operand layout (pool exactly as ``serving.kv_pool`` holds it):

  q            (K, T, G, hd)         flat token batch, kv-head-major
  k/v_codes    (P, K, page, hd) int8  k/v_scale (P, K, page) f32
  pool_pos     (P, page) int32        (-1 = empty slot)
  block_table  (R, nb) int32          (unused entries → trash page 0)
  q_pos        (T,) int32             per-token absolute positions (-1 pad)
  tok_slot     (T,) int32             per-token slot ids (-1 pad)
  start        (R,) int32             per-slot first in-call position
                                      (2^30 for slots absent from the call)
  k/v_fresh    (K, T, hd)             this call's keys/values, full precision
  out          (K, T, G, hd) f32

Grid: one program per (kv_head, minor step); total page visits are
``R * nb`` — identical to the decode kernel's ``(R, K, nb)`` grid, so the
packed tick never walks more pages than the two-step tick it replaces. A
fully-masked step contributes garbage that the next valid step's correction
factor ``exp(m_prev - m_new) = exp(-inf) = 0`` scrubs exactly; a pad row
(slot id -1, position -1) matches no key anywhere and the epilogue's
``seen`` guard emits exact zeros for it, never NaN.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.paged_prefill_attention import _fold

NEG_INF = -1e30
TRASH_PAGE = 0  # page id reserved by the pool for masked/pad gathers


def segment_start(q_pos, tok_slot, num_slots: int):
    """``start`` (R,) from flat per-token operands: each slot's FIRST
    in-call position (2^30 for slots with no tokens in the call, which
    every mask neutralizes). The single source the kernel route, the
    dense fallback, and the oracle all derive the history bound from —
    they can never disagree on it."""
    q_pos = jnp.asarray(q_pos, jnp.int32).reshape(-1)
    sl = jnp.asarray(tok_slot, jnp.int32).reshape(-1)
    big = jnp.int32(2 ** 30)
    vals = jnp.where((sl >= 0) & (q_pos >= 0), q_pos, big)
    return jnp.full((num_slots,), big, jnp.int32).at[
        jnp.maximum(sl, 0)].min(vals)


def _kernel(nsteps: int, nb: int, t: int, scale: float, bt_ref, start_ref,
            q_ref, qp_ref, sl_ref, kc_ref, ks_ref, vc_ref, vs_ref, pos_ref,
            fk_ref, fv_ref, o_ref, m_ref, l_ref, acc_ref):
    si = pl.program_id(1)

    @pl.when(si == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32) * scale  # (T, G, hd)
    g, hd = q.shape[1], q.shape[2]
    q2 = q.reshape(t * g, hd)
    qp = qp_ref[0]  # (T,) per-token positions
    sl = sl_ref[0]  # (T,) per-token slot ids

    @pl.when(si < nsteps)
    def _pool_page():
        slot = si // nb  # the slot this walk step serves
        start = start_ref[slot]
        k = kc_ref[0, 0].astype(jnp.float32) * ks_ref[0, 0][:, None]
        v = vc_ref[0, 0].astype(jnp.float32) * vs_ref[0, 0][:, None]
        kv_pos = pos_ref[0]  # (page,)
        # history only, and only for this step's slot: positions below the
        # slot's first in-call position (this call's own tokens live in the
        # pool too — post-update — and are attended as fresh keys instead;
        # every row of the slot sits at >= start, so per-row causality is
        # implied). Pad rows carry slot -1 and match nothing.
        valid = ((sl[:, None] == slot) & (kv_pos[None, :] >= 0)
                 & (kv_pos[None, :] < start))
        _fold(q2, k, v, valid, g, m_ref, l_ref, acc_ref)

    @pl.when(si == nsteps)
    def _fresh_and_finish():
        k = fk_ref[0].astype(jnp.float32)  # (T, hd) full precision
        v = fv_ref[0].astype(jnp.float32)
        # block-diagonal causal mask over the flat batch: same slot,
        # causally ordered; pad columns (position -1) match no row and pad
        # rows (position -1) accept no column
        valid = ((sl[None, :] == sl[:, None]) & (sl[None, :] >= 0)
                 & (qp[None, :] >= 0) & (qp[None, :] <= qp[:, None]))
        _fold(q2, k, v, valid, g, m_ref, l_ref, acc_ref)
        # a row whose every key was masked (a pad row in the fixed-budget
        # buffer) never raises m above its init — emit exact zeros, not
        # the exp(0)-uniform average of garbage values
        seen = m_ref[...] > NEG_INF * 0.5
        out = jnp.where(seen, acc_ref[...] / jnp.maximum(l_ref[...], 1e-30),
                        0.0)
        o_ref[0] = out.reshape(t, g, hd)


def varlen_attention(q, k_codes, k_scale, v_codes, v_scale, pool_pos,
                     block_table, q_pos, tok_slot, start, k_fresh, v_fresh,
                     interpret: bool = False):
    """See module docstring. Returns (K, T, G, hd) f32."""
    kh, t, g, hd = q.shape
    p, _, page, _ = k_codes.shape
    r, nb = block_table.shape
    assert q_pos.shape == (t,) and tok_slot.shape == (t,)
    assert start.shape == (r,) and pool_pos.shape == (p, page)
    assert k_fresh.shape == (kh, t, hd) and v_fresh.shape == k_fresh.shape
    nsteps = r * nb
    scale = 1.0 / (hd ** 0.5)
    kern = functools.partial(_kernel, nsteps, nb, t, scale)
    # the minor axis walks every slot's nb pages then one fresh step; pool
    # specs pin their index during the fresh step (same block as the last
    # page — the unchanged index elides the DMA) and the fresh specs pin
    # theirs during the pool walk
    last = nsteps - 1

    def page_of(si):
        su = jnp.minimum(si, last)
        return (su // nb, su % nb)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # block_table, start
        grid=(kh, nsteps + 1),
        in_specs=[
            pl.BlockSpec((1, t, g, hd), lambda j, si, bt, st: (j, 0, 0, 0)),
            pl.BlockSpec((1, t), lambda j, si, bt, st: (0, 0)),
            pl.BlockSpec((1, t), lambda j, si, bt, st: (0, 0)),
            pl.BlockSpec((1, 1, page, hd),
                         lambda j, si, bt, st: (bt[page_of(si)], j, 0, 0)),
            pl.BlockSpec((1, 1, page),
                         lambda j, si, bt, st: (bt[page_of(si)], j, 0)),
            pl.BlockSpec((1, 1, page, hd),
                         lambda j, si, bt, st: (bt[page_of(si)], j, 0, 0)),
            pl.BlockSpec((1, 1, page),
                         lambda j, si, bt, st: (bt[page_of(si)], j, 0)),
            pl.BlockSpec((1, page),
                         lambda j, si, bt, st: (bt[page_of(si)], 0)),
            pl.BlockSpec((1, t, hd), lambda j, si, bt, st: (j, 0, 0)),
            pl.BlockSpec((1, t, hd), lambda j, si, bt, st: (j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, t, g, hd),
                               lambda j, si, bt, st: (j, 0, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((t * g, 1), jnp.float32),
            pltpu.VMEM((t * g, 1), jnp.float32),
            pltpu.VMEM((t * g, hd), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((kh, t, g, hd), jnp.float32),
        interpret=interpret,
    )(block_table, start, q, q_pos[None], tok_slot[None], k_codes, k_scale,
      v_codes, v_scale, pool_pos, k_fresh, v_fresh)
