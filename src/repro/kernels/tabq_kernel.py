"""Pallas TPU kernel: per-token asymmetric magnitude quantization (the TAB-Q
inner loop, Eq. 5-6).

TPU mapping: the token dim is tiled across the grid; each program quantizes a
(BT, D) tile held in VMEM — one pass computes the per-token min/max on the
VPU, the second rounds and clips. D is the lane dim (keep it a multiple of
128 for full-lane utilization; BT=8 sublanes by default). Scales/zeros land
in SMEM-friendly (BT, 1) refs.

This is the hot op on the serving path: every stage-boundary payload and
every int-quantized KV-cache write runs it (fused here instead of the
XLA gather/scatter chain the pure-jnp version lowers to).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128


def _tabq_kernel(bits: int, x_ref, codes_ref, scale_ref, zero_ref, sign_ref):
    x = x_ref[...].astype(jnp.float32)
    sign = jnp.sign(x)
    mag = jnp.abs(x)
    qmax = float(2 ** (bits - 1) - 1)
    t_min = jnp.min(mag, axis=-1, keepdims=True)
    t_max = jnp.max(mag, axis=-1, keepdims=True)
    s = jnp.maximum((t_max - t_min) / max(qmax, 1.0), 1e-8)
    z = jnp.ceil(t_min / s)
    codes = jnp.round(mag / s + z)
    c_lo = jnp.round(t_min / s + z)
    codes = jnp.clip(codes, c_lo, c_lo + qmax)
    # rebase per token so codes span [0, qmax] ≤ 127 — an int8 carrier for
    # every bits ≤ 8; the zero point absorbs the shift, so the dequant form
    # (codes - zero)·scale·sign is unchanged
    codes_ref[...] = (codes - c_lo).astype(jnp.int8)
    scale_ref[...] = s
    zero_ref[...] = z - c_lo
    sign_ref[...] = sign.astype(jnp.int8)


def tabq_quantize(x: jax.Array, bits: int = 8, block_t: int = 8,
                  interpret: bool = False):
    """x (T, D) → (codes (T, D) i8, scale (T,1) f32, zero (T,1) f32,
    sign (T, D) i8). Codes are rebased per token to [0, 2^(bits-1)-1] so an
    int8 carrier always fits for bits ≤ 8 (the int32 carrier quadrupled the
    payload/cache bandwidth this kernel exists to save). T must divide by
    block_t; D should be lane-aligned."""
    assert bits <= 8, "int8 code carrier requires bits <= 8"
    t, d = x.shape
    assert t % block_t == 0, (t, block_t)
    grid = (t // block_t,)
    kern = functools.partial(_tabq_kernel, bits)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[pl.BlockSpec((block_t, d), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((block_t, d), lambda i: (i, 0)),
            pl.BlockSpec((block_t, 1), lambda i: (i, 0)),
            pl.BlockSpec((block_t, 1), lambda i: (i, 0)),
            pl.BlockSpec((block_t, d), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((t, d), jnp.int8),
            jax.ShapeDtypeStruct((t, 1), jnp.float32),
            jax.ShapeDtypeStruct((t, 1), jnp.float32),
            jax.ShapeDtypeStruct((t, d), jnp.int8),
        ],
        interpret=interpret,
    )(x)
