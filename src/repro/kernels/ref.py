"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def tabq_quantize_ref(x: jax.Array, bits: int):
    """Per-token asymmetric magnitude quantization (TAB-Q inner op, Eq. 5-6).

    x (T, D) → (codes (T, D) int8 rebased to [0, 2^(bits-1)-1], scale (T,1),
    zero (T,1), sign (T, D) int8). Matches repro.core.quant.aiq on |x| with
    per-token reduction, then shifts codes/zero by the per-token code floor
    so dequant (codes - zero)·scale·sign is unchanged."""
    assert bits <= 8, "int8 code carrier requires bits <= 8"  # match kernel
    sign = jnp.sign(x).astype(jnp.int8)
    mag = jnp.abs(x.astype(jnp.float32))
    qmax = float(2 ** (bits - 1) - 1)
    t_min = jnp.min(mag, axis=-1, keepdims=True)
    t_max = jnp.max(mag, axis=-1, keepdims=True)
    s = jnp.maximum((t_max - t_min) / max(qmax, 1.0), 1e-8)
    z = jnp.ceil(t_min / s)
    codes = jnp.round(mag / s + z)
    c_lo = jnp.round(t_min / s + z)
    codes = jnp.clip(codes, c_lo, c_lo + qmax)
    return (codes - c_lo).astype(jnp.int8), s, z - c_lo, sign


def tabq_dequantize_ref(codes, s, z, sign):
    return (codes.astype(jnp.float32) - z) * s * sign


def dequant_matmul_ref(x: jax.Array, w_codes: jax.Array, w_scale: jax.Array):
    """x (M, K) × int8 codes (K, N) with per-output-channel scale (N,) →
    f32 (M, N): out = (x @ codes) · scale."""
    acc = jnp.dot(x.astype(jnp.float32), w_codes.astype(jnp.float32),
                  preferred_element_type=jnp.float32)
    return acc * w_scale[None, :]


def ts_mask_ref(x: jax.Array, tau: float):
    """Threshold split (Eq. 4): (below, mask uint8, count int32)."""
    mask = (jnp.abs(x) >= tau)
    below = jnp.where(mask, 0.0, x.astype(jnp.float32))
    return below, mask.astype(jnp.uint8), jnp.sum(mask, dtype=jnp.int32)


def decode_attention_ref(q, k_codes, k_scale, v_codes, v_scale, kv_pos, q_pos):
    """Dense oracle for the int8-KV decode-attention kernel.

    q (B,K,G,hd); codes (B,K,S,hd) int8 with scales (B,K,S); kv_pos (B,S);
    q_pos scalar or (B,) per-request → (B,K,G,hd) f32."""
    hd = q.shape[-1]
    k = k_codes.astype(jnp.float32) * k_scale[..., None]
    v = v_codes.astype(jnp.float32) * v_scale[..., None]
    s = jnp.einsum("bkgd,bksd->bkgs", q.astype(jnp.float32), k) / (hd ** 0.5)
    q_pos = jnp.broadcast_to(jnp.asarray(q_pos), (q.shape[0],))
    valid = (kv_pos >= 0) & (kv_pos <= q_pos[:, None])
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bkgs,bksd->bkgd", p, v)


def gather_pages_ref(pool_leaf, block_table):
    """Gather a request's pages from a shared pool into dense per-request
    layout — the paged↔dense bridge both oracles and tests rely on.

    pool_leaf (P, K, page, ...) or (P, page); block_table (R, nb) →
    dense (R, K, nb·page, ...) or (R, nb·page) in block-table order."""
    g = pool_leaf[block_table]  # (R, nb, K, page, ...) or (R, nb, page)
    if pool_leaf.ndim == 2:
        return g.reshape(g.shape[0], -1)
    g = jnp.moveaxis(g, 2, 1)  # (R, K, nb, page, ...)
    return g.reshape(g.shape[0], g.shape[1], -1, *g.shape[4:])


def paged_decode_attention_ref(q, k_codes, k_scale, v_codes, v_scale,
                               pool_pos, block_table, q_pos):
    """Paged oracle: gather each request's pages dense (via the block table),
    then run :func:`decode_attention_ref` with per-request causal bounds.

    q (R,K,G,hd); pool codes (P,K,page,hd) int8 with scales (P,K,page);
    pool_pos (P,page); block_table (R,nb) int32; q_pos (R,) → (R,K,G,hd)."""
    kc = gather_pages_ref(k_codes, block_table)
    ks = gather_pages_ref(k_scale, block_table)
    vc = gather_pages_ref(v_codes, block_table)
    vs = gather_pages_ref(v_scale, block_table)
    kv_pos = gather_pages_ref(pool_pos, block_table)
    return decode_attention_ref(q, kc, ks, vc, vs, kv_pos, q_pos)


def paged_prefill_attention_ref(q, k_codes, k_scale, v_codes, v_scale,
                                pool_pos, block_table, q_pos, start,
                                k_fresh, v_fresh):
    """Dense oracle for the paged PREFILL page-walk kernel.

    q (R,K,S,G,hd); pool codes (P,K,page,hd) int8 with scales (P,K,page);
    pool_pos (P,page); block_table (R,nb); q_pos (R,S) per-token positions
    (-1 pads); start (R,) each row's first in-call position; fresh k/v
    (R,K,S,hd) full precision → (R,K,S,G,hd) f32.

    Each query row attends the union of (a) its gathered pool pages,
    dequantized, masked to stored positions < start (the shared-prefix /
    earlier-chunk history — this call's own pool writes are excluded), and
    (b) the call's fresh keys, causally masked by q_pos. Rows with no valid
    key emit exact zeros."""
    hd = q.shape[-1]
    kd = gather_pages_ref(k_codes, block_table)  # (R, K, Sp, hd)
    vd = gather_pages_ref(v_codes, block_table)
    ks = gather_pages_ref(k_scale, block_table)
    vs = gather_pages_ref(v_scale, block_table)
    hist_pos = gather_pages_ref(pool_pos, block_table)  # (R, Sp)
    k_hist = kd.astype(jnp.float32) * ks[..., None]
    v_hist = vd.astype(jnp.float32) * vs[..., None]
    k_all = jnp.concatenate([k_hist, k_fresh.astype(jnp.float32)], axis=2)
    v_all = jnp.concatenate([v_hist, v_fresh.astype(jnp.float32)], axis=2)
    ok_hist = (hist_pos >= 0) & (hist_pos < start[:, None])
    kv_pos = jnp.concatenate(
        [jnp.where(ok_hist, hist_pos, -1), q_pos], axis=1)  # (R, Sp+S)
    s = jnp.einsum("rksgd,rked->rksge", q.astype(jnp.float32) / (hd ** 0.5),
                   k_all, preferred_element_type=jnp.float32)
    valid = ((kv_pos[:, None, :] >= 0)
             & (kv_pos[:, None, :] <= q_pos[:, :, None]))  # (R, S, Skv)
    s = jnp.where(valid[:, None, :, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("rksge,rked->rksgd", p, v_all)
    any_valid = jnp.any(valid, axis=-1)  # (R, S)
    return jnp.where(any_valid[:, None, :, None, None], out, 0.0)


def varlen_attention_ref(q, k_codes, k_scale, v_codes, v_scale, pool_pos,
                         block_table, q_pos, tok_slot, start,
                         k_fresh, v_fresh):
    """Dense oracle for the token-packed VARLEN kernel.

    q (K,T,G,hd) — one flat token batch; q_pos/tok_slot (T,) per-token
    positions and slot ids (-1 pads); start (R,) per-slot first in-call
    position; pool + block_table as the pool holds them; fresh k/v (K,T,hd)
    → (K,T,G,hd) f32.

    Each token row gathers ITS slot's pages dense, masked to stored
    positions < start[slot] (this call's own pool writes excluded), and
    attends the call's fresh keys under a block-diagonal causal mask (same
    slot, q_pos[col] <= q_pos[row]). Pad rows emit exact zeros."""
    kh, t, g, hd = q.shape
    slu = jnp.maximum(tok_slot, 0)
    kd = gather_pages_ref(k_codes, block_table)  # (R, K, Sp, hd)
    vd = gather_pages_ref(v_codes, block_table)
    ks = gather_pages_ref(k_scale, block_table)
    vs = gather_pages_ref(v_scale, block_table)
    k_hist = (kd.astype(jnp.float32) * ks[..., None])[slu]  # (T, K, Sp, hd)
    v_hist = (vd.astype(jnp.float32) * vs[..., None])[slu]
    hist_pos = gather_pages_ref(pool_pos, block_table)[slu]  # (T, Sp)
    ok_hist = ((tok_slot[:, None] >= 0) & (hist_pos >= 0)
               & (hist_pos < start[slu][:, None]))  # (T, Sp)
    ok_fresh = ((tok_slot[None, :] == tok_slot[:, None])
                & (tok_slot[None, :] >= 0) & (q_pos[None, :] >= 0)
                & (q_pos[None, :] <= q_pos[:, None]))  # (T, T)
    k_fr = jnp.broadcast_to(jnp.swapaxes(k_fresh, 0, 1)[None],
                            (t, t, kh, hd)).swapaxes(1, 2)  # (T, K, T, hd)
    v_fr = jnp.broadcast_to(jnp.swapaxes(v_fresh, 0, 1)[None],
                            (t, t, kh, hd)).swapaxes(1, 2)
    k_all = jnp.concatenate([k_hist, k_fr.astype(jnp.float32)], axis=2)
    v_all = jnp.concatenate([v_hist, v_fr.astype(jnp.float32)], axis=2)
    valid = jnp.concatenate([ok_hist, ok_fresh], axis=1)  # (T, Sp+T)
    s = jnp.einsum("ktgd,tked->ktge", q.astype(jnp.float32) / (hd ** 0.5),
                   k_all, preferred_element_type=jnp.float32)
    s = jnp.where(valid[None, :, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("ktge,tked->ktgd", p, v_all)
    any_valid = jnp.any(valid, axis=-1)  # (T,)
    return jnp.where(any_valid[None, :, None, None], out, 0.0)
