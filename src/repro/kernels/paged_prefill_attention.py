"""Pallas TPU kernel: ragged PREFILL attention over a paged int8 KV pool.

The decode-side page walk (``kernels.paged_decode_attention``) serves one
query token per request; prefill is the TTFT-critical phase and needs the
same walk for a whole BLOCK of query tokens per request — a forked request
prefilling its suffix against a shared prefix, or a chunked prefill whose
later chunks attend the chunks already scattered into the pool. Before this
kernel, that path gathered the ENTIRE pool dense and dequantized it to f32
per layer (``models.layers._gather_dense_kv`` — "correct, not fast"); here
the pool is walked page by page through the same scalar-prefetched
block-table index map as decode, int8 codes + per-(token, head) scales
consumed in-register, and the dense f32 copy never materializes in HBM.

Two key groups fold into ONE online softmax, so the attended set (and its
precision) is exactly the dense-gather path's:

  * POOL HISTORY — the request's block-table pages, masked per query row to
    stored positions BELOW the row's first in-call position (``start``):
    tokens this very call scatters into the pool are excluded (they are
    attended as fresh keys instead, not double-counted), and a row starting
    at position 0 sees no history at all;
  * FRESH KEYS — the call's own k/v at full precision, causally masked by
    the per-token positions (left pads carry position -1 → masked), walked
    as the minor axis' final step.

Operand layout (pool exactly as ``serving.kv_pool`` holds it):

  q            (R, K, S, G, hd)      queries, kv-head-major
  k/v_codes    (P, K, page, hd) int8  k/v_scale (P, K, page) f32
  pool_pos     (P, page) int32        (-1 = empty slot)
  block_table  (R, nb) int32          (unused entries → trash page 0)
  q_pos        (R, S) int32           per-token absolute positions (-1 pad)
  start        (R,) int32             first in-call position (2^30 if none)
  k/v_fresh    (R, K, S, hd)          this call's keys/values, full precision
  out          (R, K, S, G, hd) f32

Grid: one program per (request, kv_head, query block); the minor axis walks
``nb`` block-table pages then the single fresh block. A fully-masked page
contributes garbage that the next valid step's correction factor
``exp(m_prev - m_new) = exp(-inf) = 0`` scrubs exactly; a query row whose
every key is masked (a pad column, or an inactive row in a fixed-shape
scheduler tick) is caught by the epilogue's ``seen`` guard and emits exact
zeros, never NaN.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
TRASH_PAGE = 0  # page id reserved by the pool for masked/pad gathers


def first_call_position(q_pos):
    """``start`` (R,) from per-token positions ``q_pos`` (R, S): each row's
    FIRST in-call position (2^30 for fully-padded rows, which every mask
    neutralizes). The single source both the kernel route and the
    dense-gather fallback derive their history bound from — they can never
    disagree on it."""
    q_pos = jnp.asarray(q_pos, jnp.int32)
    return jnp.min(jnp.where(q_pos >= 0, q_pos, jnp.int32(2 ** 30)), axis=1)


def _fold(q2, k, v, valid, g, m_ref, l_ref, acc_ref):
    """One online-softmax step: fold keys ``k``/values ``v`` (L, hd) with
    per-(q-row, key) mask ``valid`` (QB, L) into the (QB·G, ·) scratch."""
    s = jnp.dot(q2, k.T, preferred_element_type=jnp.float32)  # (QB*G, L)
    qb = valid.shape[0]
    vm = jnp.broadcast_to(valid[:, None, :], (qb, g, valid.shape[1]))
    s = jnp.where(vm.reshape(s.shape), s, NEG_INF)
    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new


def _kernel(nb: int, qb: int, scale: float, bt_ref, start_ref, q_ref, qp_ref,
            kc_ref, ks_ref, vc_ref, vs_ref, pos_ref, fk_ref, fv_ref,
            o_ref, m_ref, l_ref, acc_ref):
    si = pl.program_id(3)

    @pl.when(si == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    r = pl.program_id(0)
    qi = pl.program_id(2)
    q = q_ref[0, 0].astype(jnp.float32) * scale  # (QB, G, hd)
    g, hd = q.shape[1], q.shape[2]
    q2 = q.reshape(qb * g, hd)
    # per-row query positions of THIS q block (the operand carries the full
    # row so the fresh step below can mask every in-call key against them)
    qp = qp_ref[0, pl.ds(qi * qb, qb)]  # (QB,)
    start = start_ref[r]

    @pl.when(si < nb)
    def _pool_page():
        k = kc_ref[0, 0].astype(jnp.float32) * ks_ref[0, 0][:, None]
        v = vc_ref[0, 0].astype(jnp.float32) * vs_ref[0, 0][:, None]
        kv_pos = pos_ref[0]  # (page,)
        # history only: stored positions below the row's first in-call
        # position (this call's own tokens live in the pool too — post-
        # update — and are attended as fresh keys instead)
        valid = ((kv_pos[None, :] >= 0) & (kv_pos[None, :] < start)
                 & (kv_pos[None, :] <= qp[:, None]))
        _fold(q2, k, v, valid, g, m_ref, l_ref, acc_ref)

    @pl.when(si == nb)
    def _fresh_and_finish():
        k = fk_ref[0, 0].astype(jnp.float32)  # (S, hd) full precision
        v = fv_ref[0, 0].astype(jnp.float32)
        kv_pos = qp_ref[0]  # (S,) — fresh keys sit at the call's positions
        valid = ((kv_pos[None, :] >= 0)
                 & (kv_pos[None, :] <= qp[:, None]))  # causal in-call
        _fold(q2, k, v, valid, g, m_ref, l_ref, acc_ref)
        # a row whose every key was masked (pad column / inactive row)
        # never raises m above its init — emit exact zeros, not the
        # exp(0)-uniform average of garbage values
        seen = m_ref[...] > NEG_INF * 0.5
        out = jnp.where(seen, acc_ref[...] / jnp.maximum(l_ref[...], 1e-30),
                        0.0)
        o_ref[0, 0] = out.reshape(qb, g, hd)


def paged_prefill_attention(q, k_codes, k_scale, v_codes, v_scale, pool_pos,
                            block_table, q_pos, start, k_fresh, v_fresh,
                            q_block: int = 128, interpret: bool = False):
    """See module docstring. Returns (R, K, S, G, hd) f32.

    ``S`` need not divide ``q_block``: the query axis is padded on call and
    pad columns (position -1) emit zeros. ``q_block`` is clamped to S."""
    r, kh, s, g, hd = q.shape
    p, _, page, _ = k_codes.shape
    nb = block_table.shape[1]
    assert block_table.shape[0] == r and q_pos.shape == (r, s)
    assert start.shape == (r,) and pool_pos.shape == (p, page)
    assert k_fresh.shape == (r, kh, s, hd) and v_fresh.shape == k_fresh.shape
    qb = min(q_block, s)
    pad = (-s) % qb
    if pad:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, ((0, 0), (0, pad)), constant_values=-1)
        k_fresh = jnp.pad(k_fresh, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v_fresh = jnp.pad(v_fresh, ((0, 0), (0, 0), (0, pad), (0, 0)))
    sp = s + pad
    nq = sp // qb
    scale = 1.0 / (hd ** 0.5)
    kern = functools.partial(_kernel, nb, qb, scale)
    # the minor axis walks nb pool pages then one fresh step; pool specs pin
    # their index during the fresh step (same block as the last page — the
    # unchanged index elides the DMA) and the fresh specs pin theirs during
    # the pool walk
    last = nb - 1
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # block_table, start
        grid=(r, kh, nq, nb + 1),
        in_specs=[
            pl.BlockSpec((1, 1, qb, g, hd),
                         lambda i, j, qi, si, bt, st: (i, j, qi, 0, 0)),
            pl.BlockSpec((1, sp), lambda i, j, qi, si, bt, st: (i, 0)),
            pl.BlockSpec((1, 1, page, hd),
                         lambda i, j, qi, si, bt, st:
                         (bt[i, jnp.minimum(si, last)], j, 0, 0)),
            pl.BlockSpec((1, 1, page),
                         lambda i, j, qi, si, bt, st:
                         (bt[i, jnp.minimum(si, last)], j, 0)),
            pl.BlockSpec((1, 1, page, hd),
                         lambda i, j, qi, si, bt, st:
                         (bt[i, jnp.minimum(si, last)], j, 0, 0)),
            pl.BlockSpec((1, 1, page),
                         lambda i, j, qi, si, bt, st:
                         (bt[i, jnp.minimum(si, last)], j, 0)),
            pl.BlockSpec((1, page),
                         lambda i, j, qi, si, bt, st:
                         (bt[i, jnp.minimum(si, last)], 0)),
            pl.BlockSpec((1, 1, sp, hd),
                         lambda i, j, qi, si, bt, st: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, sp, hd),
                         lambda i, j, qi, si, bt, st: (i, j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, qb, g, hd),
                               lambda i, j, qi, si, bt, st: (i, j, qi, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((qb * g, 1), jnp.float32),
            pltpu.VMEM((qb * g, 1), jnp.float32),
            pltpu.VMEM((qb * g, hd), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((r, kh, sp, g, hd), jnp.float32),
        interpret=interpret,
    )(block_table, start, q, q_pos, k_codes, k_scale, v_codes, v_scale,
      pool_pos, k_fresh, v_fresh)
    return out[:, :, :s]
