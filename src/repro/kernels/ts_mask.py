"""Pallas TPU kernel: threshold split (TS, Eq. 4) — mask + below-tensor +
per-tile outlier counts in one VMEM pass.

TPU adaptation of the paper's CSR extraction (see DESIGN.md §2): the kernel
emits (below, mask, per-tile counts); the host/XLA side turns counts into
offsets and compacts the few outliers (≈0.0005 % above τ=100) — the dense
O(N) scan is what belongs on the TPU, the O(nnz) tail doesn't."""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ts_kernel(tau_ref, x_ref, below_ref, mask_ref, count_ref):
    x = x_ref[...].astype(jnp.float32)
    tau = tau_ref[0, 0]
    mask = jnp.abs(x) >= tau
    below_ref[...] = jnp.where(mask, 0.0, x)
    mask_ref[...] = mask.astype(jnp.uint8)
    count_ref[0, 0] = jnp.sum(mask.astype(jnp.int32))


def ts_mask(x: jax.Array, tau: float, block_t: int = 8,
            interpret: bool = False):
    """x (T, D) → (below f32 (T, D), mask u8 (T, D), counts i32 (T//bt, 1))."""
    t, d = x.shape
    assert t % block_t == 0
    grid = (t // block_t,)
    tau_arr = jnp.full((1, 1), tau, jnp.float32)
    return pl.pallas_call(
        _ts_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((block_t, d), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_t, d), lambda i: (i, 0)),
            pl.BlockSpec((block_t, d), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((t, d), jnp.float32),
            jax.ShapeDtypeStruct((t, d), jnp.uint8),
            jax.ShapeDtypeStruct((t // block_t, 1), jnp.int32),
        ],
        interpret=interpret,
    )(tau_arr, x)
