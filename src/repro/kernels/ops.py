"""Jit'd public wrappers over the Pallas kernels.

``interpret`` defaults to True off-TPU (this container is CPU-only; the
kernel bodies execute in Python for correctness validation) and False on
real TPU backends.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.dequant_matmul import dequant_matmul as _dequant_matmul
from repro.kernels.tabq_kernel import tabq_quantize as _tabq_quantize
from repro.kernels.ts_mask import ts_mask as _ts_mask


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("bits", "block_t", "interpret"))
def tabq_quantize(x, bits: int = 8, block_t: int = 8, interpret: bool | None = None):
    interpret = _default_interpret() if interpret is None else interpret
    return _tabq_quantize(x, bits, block_t, interpret)


def tabq_dequantize(codes, scale, zero, sign):
    return ref.tabq_dequantize_ref(codes, scale, zero, sign)


@partial(jax.jit, static_argnames=("block_m", "block_n", "block_k", "interpret"))
def dequant_matmul(x, w_codes, w_scale, block_m: int = 128, block_n: int = 128,
                   block_k: int = 512, interpret: bool | None = None):
    interpret = _default_interpret() if interpret is None else interpret
    return _dequant_matmul(x, w_codes, w_scale, block_m, block_n, block_k,
                           interpret)


@partial(jax.jit, static_argnames=("tau", "block_t", "interpret"))
def ts_mask(x, tau: float, block_t: int = 8, interpret: bool | None = None):
    interpret = _default_interpret() if interpret is None else interpret
    return _ts_mask(x, tau, block_t, interpret)


@partial(jax.jit, static_argnames=("block_s", "interpret"))
def decode_attention(q, k_codes, k_scale, v_codes, v_scale, kv_pos, q_pos,
                     block_s: int | None = None, interpret: bool | None = None):
    from repro.kernels.decode_attention import BLOCK_S
    from repro.kernels.decode_attention import decode_attention as _da

    block_s = BLOCK_S if block_s is None else block_s

    interpret = _default_interpret() if interpret is None else interpret
    return _da(q, k_codes, k_scale, v_codes, v_scale, kv_pos, q_pos,
               block_s, interpret)


@partial(jax.jit, static_argnames=("interpret",))
def paged_decode_attention(q, k_codes, k_scale, v_codes, v_scale, pool_pos,
                           block_table, q_pos, interpret: bool | None = None):
    from repro.kernels.paged_decode_attention import \
        paged_decode_attention as _pda

    interpret = _default_interpret() if interpret is None else interpret
    return _pda(q, k_codes, k_scale, v_codes, v_scale, pool_pos, block_table,
                q_pos, interpret)


@partial(jax.jit, static_argnames=("q_block", "interpret"))
def paged_prefill_attention(q, k_codes, k_scale, v_codes, v_scale, pool_pos,
                            block_table, q_pos, k_fresh, v_fresh,
                            q_block: int = 128, interpret: bool | None = None):
    """Ragged prefill page walk: q (R,K,S,G,hd) against the pool's history
    pages (masked below each row's first in-call position) plus the call's
    fresh k/v (R,K,S,hd) at full precision. ``start`` is derived from
    ``q_pos`` here so kernel and callers can never disagree on it."""
    from repro.kernels.paged_prefill_attention import (
        first_call_position, paged_prefill_attention as _ppa)

    interpret = _default_interpret() if interpret is None else interpret
    q_pos = jnp.asarray(q_pos, jnp.int32)
    start = first_call_position(q_pos)
    return _ppa(q, k_codes, k_scale, v_codes, v_scale, pool_pos, block_table,
                q_pos, start, k_fresh, v_fresh, q_block, interpret)


@partial(jax.jit, static_argnames=("interpret",))
def varlen_attention(q, k_codes, k_scale, v_codes, v_scale, pool_pos,
                     block_table, q_pos, tok_slot, k_fresh, v_fresh,
                     interpret: bool | None = None):
    """Token-packed varlen page walk: ONE flat batch q (K,T,G,hd) whose rows
    carry their own slot id and position, so ragged prefill chunks and
    single decode tokens coexist in one call. Attends each row's pool
    history (stored positions below its slot's first in-call position) plus
    the call's fresh k/v (K,T,hd) under a block-diagonal causal mask.
    ``start`` is derived from (q_pos, tok_slot) here so kernel and callers
    can never disagree on it."""
    from repro.kernels.varlen_attention import (
        segment_start, varlen_attention as _va)

    interpret = _default_interpret() if interpret is None else interpret
    q_pos = jnp.asarray(q_pos, jnp.int32)
    tok_slot = jnp.asarray(tok_slot, jnp.int32)
    start = segment_start(q_pos, tok_slot, block_table.shape[0])
    return _va(q, k_codes, k_scale, v_codes, v_scale, pool_pos, block_table,
               q_pos, tok_slot, start, k_fresh, v_fresh, interpret)
