"""Pallas TPU kernels for the paper's quantization hot spots — WIRED into
the serving path, not a side gallery:

  decode_attention — int8-KV decode attention. The §Roofline irreducible
      term: streams the kv-head-major quantized cache (codes (B, K, S, hd)
      int8 + per-(token, head) scales) through VMEM once, dequantizing
      in-register with online softmax. ``models.layers`` routes every
      decode-time attention over a quantized cache here (see
      ``quantized_decode_attention``); ``RuntimeOpts.quantized_kv=True``
      makes both serving engines take this path inside their fused loops.
  paged_decode_attention — the same online-softmax block walk re-addressed
      through per-request BLOCK TABLES (``pltpu.PrefetchScalarGridSpec``):
      each (request, kv-head) program gathers its pages from the shared
      ``serving.kv_pool`` pool, with per-request causal bounds for ragged
      continuous batching. ``models.layers.paged_decode_attention_layer``
      routes every decode over a ``PagedKVCache`` here.
  paged_prefill_attention — the PREFILL page walk (the TTFT path): a
      flash-style (request, kv-head, q-block) grid folds the request's
      block-table pages (int8 history dequantized in-register, masked per
      query row below its first in-call position) and the call's fresh
      full-precision keys into one online softmax — the dense f32 gather
      of the pool never materializes. ``models.layers.
      paged_prefill_attention`` routes shared-prefix and chunked-prefill
      attention here (dense-gather fallback for softcapped layers or
      ``RuntimeOpts.paged_prefill_kernel=False``).
  tabq_kernel — per-token TAB-Q magnitude quantization (Eq. 5-6), int8
      code carrier (codes rebased per token to [0, Q_max]).
  dequant_matmul — int8-weight × fp-activation matmul with per-channel
      dequant fused into the epilogue (OPSC front segments).
  ts_mask — threshold splitting (Eq. 4) for the stage-boundary payload.

``ops.py`` exposes jit'd wrappers that default to ``interpret=True`` off-TPU
(CPU correctness / parity testing); ``ref.py`` holds the pure-jnp oracles
the tests allclose against.
"""
