"""Pallas TPU kernel: decode attention over an int8-quantized KV cache.

The §Roofline analysis shows decode is memory-bound with the cache read as
the irreducible term — so the kernel's job is to stream the int8 cache
through VMEM ONCE, dequantizing in-register (the pure-XLA path on CPU
materializes an f32 copy of the cache; on TPU the fusion is also not
guaranteed across the scale-multiply + masked-softmax chain).

Layout: one program per (batch, kv-head); the grid's minor axis walks the
sequence in BS-sized blocks carrying online-softmax state (m, l, acc) in
VMEM scratch. GQA handled by processing all G = H/K query heads of the
kv-head together — the (G, BS) score tile feeds the MXU with hd as the
contraction dim.

  q        (B, K, G, hd)   bf16/f32
  k_codes  (B, K, S, hd)   int8      k_scale (B, K, S)   f32
  v_codes  (B, K, S, hd)   int8      v_scale (B, K, S)   f32
  kv_pos   (B, S)          int32     (-1 = empty slot)
  q_pos    scalar int32    (current absolute position, causal bound)
  out      (B, K, G, hd)   f32
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
BLOCK_S = 512  # default sequence-block size of the grid's minor axis


def padded_cache_len(s: int, block_s: int = BLOCK_S, uniform: bool = False) -> int:
    """Round a cache length up to a whole number of kernel blocks.

    With ``uniform=False`` (default — the dense-cache contract), a length
    ``s <= block_s`` is returned UNPADDED: the dense kernel clamps its block
    size to ``min(block_s, s)``, so a single short block needs no padding.
    Callers that allocate caches at this size (pad slots carry
    ``kv_pos = -1``) keep the per-step path copy-free; other lengths still
    work via the pad-on-call fallback below.

    With ``uniform=True``, every length — including ``s <= block_s`` — is
    rounded up to whole ``block_s``-sized blocks. This is the PAGED-POOL
    contract: ``serving.kv_pool`` pages must all be exactly one block long
    (the block-table index map addresses the pool in fixed page strides), so
    the short-block exemption above would produce a non-uniform final page.
    The pool rejects non-multiple lengths with a clear error and points here.
    """
    if not uniform and s <= block_s:
        return s  # a single (possibly short) block — no padding needed
    return -(-s // block_s) * block_s


def _kernel(ns: int, scale: float, q_ref, kc_ref, ks_ref, vc_ref, vs_ref,
            pos_ref, qpos_ref, o_ref, m_ref, l_ref, acc_ref):
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale  # (G, hd)
    k = kc_ref[0, 0].astype(jnp.float32) * ks_ref[0, 0][:, None]  # (BS, hd)
    v = vc_ref[0, 0].astype(jnp.float32) * vs_ref[0, 0][:, None]
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # (G, BS)

    kv_pos = pos_ref[0]  # (BS,)
    valid = (kv_pos >= 0) & (kv_pos <= qpos_ref[0, 0])
    s = jnp.where(valid[None, :], s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(si == ns - 1)
    def _finish():
        o_ref[0, 0] = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)


def decode_attention(q, k_codes, k_scale, v_codes, v_scale, kv_pos, q_pos,
                     block_s: int = BLOCK_S, interpret: bool = False):
    """See module docstring. Returns (B, K, G, hd) f32.

    ``cache_len`` need not divide ``block_s``: the trailing block is padded
    and the pad slots carry ``kv_pos = -1``, which the in-kernel validity
    mask already treats as empty."""
    b, kh, g, hd = q.shape
    s = k_codes.shape[2]
    bs = min(block_s, s)
    pad = (-s) % bs
    if pad:
        k_codes = jnp.pad(k_codes, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v_codes = jnp.pad(v_codes, ((0, 0), (0, 0), (0, pad), (0, 0)))
        k_scale = jnp.pad(k_scale, ((0, 0), (0, 0), (0, pad)))
        v_scale = jnp.pad(v_scale, ((0, 0), (0, 0), (0, pad)))
        kv_pos = jnp.pad(kv_pos, ((0, 0), (0, pad)), constant_values=-1)
        s += pad
    ns = s // bs
    scale = 1.0 / (hd ** 0.5)
    kern = functools.partial(_kernel, ns, scale)
    qpos_arr = jnp.full((1, 1), q_pos, jnp.int32)
    return pl.pallas_call(
        kern,
        grid=(b, kh, ns),
        in_specs=[
            pl.BlockSpec((1, 1, g, hd), lambda i, j, si: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, bs, hd), lambda i, j, si: (i, j, si, 0)),
            pl.BlockSpec((1, 1, bs), lambda i, j, si: (i, j, si)),
            pl.BlockSpec((1, 1, bs, hd), lambda i, j, si: (i, j, si, 0)),
            pl.BlockSpec((1, 1, bs), lambda i, j, si: (i, j, si)),
            pl.BlockSpec((1, bs), lambda i, j, si: (i, si)),
            pl.BlockSpec((1, 1), lambda i, j, si: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, hd), lambda i, j, si: (i, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, kh, g, hd), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k_codes, k_scale, v_codes, v_scale, kv_pos, qpos_arr)
