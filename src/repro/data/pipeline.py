"""Synthetic data pipeline (offline container — no external corpora).

Two corpora:

* **Zipf–Markov LM** — a deterministic sparse Markov chain over the
  vocabulary with Zipf-distributed stationary token frequencies. A model
  must learn the transition structure; perplexity is meaningful and
  quantization-induced degradation is measurable (vehicle for the paper's
  Table 4 perplexity analog).
* **Induction-copy task** — sequences of the form ``[prefix][SEP][prefix]``;
  next-token accuracy on the second half requires attention to function
  (vehicle for the accuracy claims: Tables 2/3/5 analogs — TS/TAB-Q
  distortion directly disrupts the induction heads).

Batches are dicts {tokens, labels (shifted), loss_mask}. The iterator
prefetches on a background thread (a real input pipeline, miniaturized).
"""

from __future__ import annotations

import dataclasses
import queue
import threading

import numpy as np


@dataclasses.dataclass
class ZipfMarkov:
    """Deterministic sparse Markov chain with Zipf marginals."""

    vocab_size: int
    branching: int = 8  # successors per state
    alpha: float = 1.2  # Zipf exponent
    seed: int = 0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        v, b = self.vocab_size, self.branching
        self.successors = rng.integers(0, v, size=(v, b))
        w = rng.zipf(self.alpha, size=(v, b)).astype(np.float64)
        self.probs = w / w.sum(axis=1, keepdims=True)

    def sample(self, rng: np.random.Generator, batch: int, seq: int) -> np.ndarray:
        out = np.empty((batch, seq), np.int64)
        state = rng.integers(0, self.vocab_size, size=batch)
        for t in range(seq):
            out[:, t] = state
            choice = np.array([rng.choice(self.branching, p=self.probs[s]) for s in state])
            state = self.successors[state, choice]
        return out

    def entropy_rate_bits(self) -> float:
        """Per-token entropy of the chain (uniform stationary approx) —
        lower bound on achievable loss, used to sanity-check training."""
        h = -np.sum(self.probs * np.log2(np.maximum(self.probs, 1e-12)), axis=1)
        return float(np.mean(h))


def induction_batch(rng: np.random.Generator, batch: int, seq: int, vocab: int,
                    sep_token: int | None = None):
    """[prefix][SEP][prefix] sequences. Returns (tokens, loss_mask) where the
    mask selects the copied half (where accuracy is measurable)."""
    sep = sep_token if sep_token is not None else vocab - 1
    half = (seq - 1) // 2
    prefix = rng.integers(0, vocab - 1, size=(batch, half))
    tokens = np.concatenate(
        [prefix, np.full((batch, 1), sep), prefix], axis=1)[:, :seq]
    mask = np.zeros((batch, seq), np.float32)
    mask[:, half + 1:] = 1.0  # predictable (copied) region
    return tokens.astype(np.int64), mask


def make_batch(tokens: np.ndarray, loss_mask: np.ndarray | None = None) -> dict:
    """Next-token LM batch: labels are tokens shifted left."""
    labels = np.concatenate([tokens[:, 1:], tokens[:, :1] * 0], axis=1)
    if loss_mask is None:
        loss_mask = np.ones_like(labels, np.float32)
        loss_mask[:, -1] = 0.0
    else:
        loss_mask = loss_mask[:, 1:]
        loss_mask = np.concatenate([loss_mask, np.zeros_like(loss_mask[:, :1])], 1)
    return {"tokens": tokens, "labels": labels, "loss_mask": loss_mask}


class DataLoader:
    """Background-thread prefetching iterator over a batch factory."""

    def __init__(self, batch_fn, num_batches: int, prefetch: int = 4):
        self.batch_fn = batch_fn
        self.num_batches = num_batches
        self.q: queue.Queue = queue.Queue(maxsize=prefetch)
        self.thread = threading.Thread(target=self._worker, daemon=True)
        self.thread.start()

    def _worker(self):
        for i in range(self.num_batches):
            self.q.put(self.batch_fn(i))
        self.q.put(None)

    def __iter__(self):
        while True:
            item = self.q.get()
            if item is None:
                return
            yield item


def lm_loader(corpus: ZipfMarkov, batch: int, seq: int, num_batches: int,
              seed: int = 1) -> DataLoader:
    def fn(i):
        rng = np.random.default_rng(seed + i)
        return make_batch(corpus.sample(rng, batch, seq))

    return DataLoader(fn, num_batches)


def induction_loader(vocab: int, batch: int, seq: int, num_batches: int,
                     seed: int = 1) -> DataLoader:
    def fn(i):
        rng = np.random.default_rng(seed + i)
        tokens, mask = induction_batch(rng, batch, seq, vocab)
        return make_batch(tokens, mask)

    return DataLoader(fn, num_batches)
