"""Synthetic data pipeline."""
