import os

os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Split-computing pipeline dry-run (multi-pod): lower the 2-stage pod
pipeline decode step and measure how TS/TAB-Q-style payload compression
moves the inter-pod collective traffic — the paper's central quantity,
measured in compiled HLO rather than simulated.

  PYTHONPATH=src python -m repro.launch.split_dryrun --arch internlm2-20b \
      [--shape decode_32k] [--bits 16 8 4] [--n-micro 4]
"""

import argparse
import json
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.launch import sharding as shd
from repro.launch.hlo_cost import analyze
from repro.launch.mesh import make_production_mesh
from repro.launch.shapes import SHAPES, default_opts
from repro.launch.split_pipeline import (init_pipeline_caches,
                                          pipeline_decode_sharded)
from repro.models.transformer import abstract_params

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "split_dryrun")


def run_one(arch: str, shape_name: str, payload_bits: int, n_micro: int) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    prefill = shape.kind == "prefill"
    assert cfg.num_blocks % 2 == 0, f"{arch}: odd block count, pipeline n/a"
    mesh = make_production_mesh(multi_pod=True)
    jax.set_mesh(mesh)
    opts = default_opts(cfg, shape)

    params = abstract_params(cfg, jnp.bfloat16)
    pspecs = shd.param_specs(cfg, mesh, fsdp=False)
    # blocks: stage dim over 'pod' (dim 0) + the usual model sharding
    def pod_spec(spec):
        return P("pod", *tuple(spec)[1:]) if len(spec) >= 1 else spec

    blocks = shd.to_shaped(
        params["blocks"],
        jax.tree_util.tree_map(pod_spec, pspecs["blocks"],
                               is_leaf=lambda x: isinstance(x, P)),
        mesh)
    other = {k: shd.to_shaped(v, pspecs[k], mesh)
             for k, v in params.items() if k != "blocks"}

    b = shape.global_batch
    bs = b // n_micro
    s_tok = shape.seq_len if prefill else 1
    tokens = jax.ShapeDtypeStruct(
        (b, s_tok, cfg.num_codebooks) if cfg.embed == "musicgen" else (b, s_tok),
        jnp.int32, sharding=NamedSharding(mesh, P()))
    caches = jax.eval_shape(
        lambda: init_pipeline_caches(cfg, bs, n_micro, shape.seq_len, opts))
    cspecs = shd.cache_specs(cfg, mesh, bs, shape.seq_len, opts.quantized_kv)
    # microbatch-major layout: (nb→'pod', micro=None, bs=None, seq..., ...);
    # pods are stages, so drop any data-axes the policy put on the batch dim
    def pipe_spec(spec):
        clean = tuple(tuple(a for a in (ax if isinstance(ax, tuple) else (ax,))
                            if a != "pod") or None if ax is not None else None
                      for ax in tuple(spec))
        clean = tuple(c[0] if isinstance(c, tuple) and len(c) == 1 else c
                      for c in clean)
        return P("pod", None, None, *clean[2:])

    cspecs = jax.tree_util.tree_map(pipe_spec, cspecs,
                                    is_leaf=lambda x: isinstance(x, P))
    caches = shd.to_shaped(caches, cspecs, mesh)
    pos = jax.ShapeDtypeStruct((), jnp.int32, sharding=NamedSharding(mesh, P()))

    fn = pipeline_decode_sharded(cfg, opts, mesh, n_micro, payload_bits,
                                 prefill=prefill)
    t0 = time.time()
    with mesh:
        compiled = jax.jit(fn).lower(blocks, other, tokens, caches, pos).compile()
    hc = analyze(compiled.as_text())
    # isolate the boundary-payload permutes by shape: the payload is the only
    # (bs, seq, D[/2]) int8/uint8/bf16 tensor crossing the pod link
    bs = b // n_micro
    d_payload = cfg.d_model // 2 if payload_bits == 4 else cfg.d_model
    import re as _re
    payload_permute = 0.0
    for line in compiled.as_text().splitlines():
        if "collective-permute" not in line or "-done" in line:
            continue
        m = _re.search(r"(bf16|s8|u8|f32)\[([\d,]+)\]", line.strip())
        if not m:
            continue
        dims = [int(x_) for x_ in m.group(2).split(",")]
        # per-device payload: (bs, seq-shard, D[/2]) — seq may be partitioned
        if len(dims) == 3 and dims[0] == bs and dims[2] == d_payload:
            bytes_per = {"bf16": 2, "s8": 1, "u8": 1, "f32": 4}[m.group(1)]
            n = 1
            for x_ in dims:
                n *= x_
            payload_permute += n * bytes_per * (n_micro + 1)
    mem = compiled.memory_analysis()
    rec = {
        "arch": arch, "shape": shape_name, "payload_bits": payload_bits,
        "n_micro": n_micro, "compile_s": round(time.time() - t0, 1),
        "collective_bytes_by_kind": hc.collective_bytes_by_kind,
        "collective_bytes": hc.collective_bytes,
        "permute_bytes": hc.collective_bytes_by_kind.get("collective-permute", 0.0),
        "payload_permute_bytes": payload_permute,
        "flops": hc.flops,
        "memory_bytes": hc.memory_bytes,
        "arg_bytes_per_device": getattr(mem, "argument_size_in_bytes", None),
        "temp_bytes_per_device": getattr(mem, "temp_size_in_bytes", None),
    }
    os.makedirs(OUT_DIR, exist_ok=True)
    with open(os.path.join(
            OUT_DIR, f"{arch}__{shape_name}__bits{payload_bits}.json"), "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-20b")
    ap.add_argument("--shape", default="decode_32k")
    ap.add_argument("--bits", type=int, nargs="+", default=[16, 8, 4])
    ap.add_argument("--n-micro", type=int, default=4)
    args = ap.parse_args()
    base_permute = None
    for bits in args.bits:
        rec = run_one(args.arch, args.shape, bits, args.n_micro)
        if base_permute is None:
            base_permute = rec["permute_bytes"] or 1.0
        print(f"[split-dryrun] {args.arch} {args.shape} bits={bits}: "
              f"payload_permute={rec['payload_permute_bytes'] / 1e6:.2f} MB "
              f"all_permute={rec['permute_bytes'] / 1e6:.2f} MB/dev "
              f"total_coll={rec['collective_bytes'] / 1e6:.2f} MB "
              f"compile={rec['compile_s']}s", flush=True)


if __name__ == "__main__":
    main()
