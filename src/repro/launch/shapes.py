"""Assigned input shapes and per-(arch × shape) lowering targets.

  train_4k        seq 4,096    global_batch 256  → train_step (grad-accum scan)
  prefill_32k     seq 32,768   global_batch 32   → chunked prefill
  decode_32k      seq 32,768   global_batch 128  → serve_step (1 token, full KV)
  long_500k       seq 524,288  global_batch 1    → serve_step, context-parallel
  paged_decode_32k seq 32,768  global_batch 128  → paged_decode_step (ragged
                                                   pool, block-table kernel)

``input_specs(cfg, shape, mesh)`` returns (fn, args) where args are
ShapeDtypeStructs with NamedShardings attached — weak-type-correct,
shardable, zero allocation.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.launch import sharding as shd
from repro.launch.mesh import data_axes, data_size
from repro.models.transformer import RuntimeOpts, init_caches, prefill
from repro.serving.engine import serve_step_fn
from repro.training.optimizer import AdamWConfig, adamw_init
from repro.training.train_loop import TrainConfig, make_train_step


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
    # ragged continuous-batching decode: one paged_decode_step over a shared
    # kv_pool at decode_32k scale — kernel pages of BLOCK_S, pool page axis
    # sharded over the data axes, block tables replicated
    "paged_decode_32k": ShapeSpec("paged_decode_32k", 32768, 128,
                                  "paged_decode"),
}

MICRO_GLOBAL = 32  # tokensets per grad-accum microbatch (train_4k)


def supports(cfg: ArchConfig, shape: ShapeSpec) -> bool:
    """long_500k only for sub-quadratic stacks (DESIGN.md §Arch-applicability);
    the paged pool covers attention-only patterns without sliding windows
    (see serving.kv_pool)."""
    if shape.name == "long_500k":
        return cfg.supports_long_context
    if shape.kind == "paged_decode":
        from repro.configs.base import AttnSpec

        return all(isinstance(ls.mixer, AttnSpec)
                   and ls.mixer.sliding_window is None for ls in cfg.pattern)
    return True


def _token_struct(cfg: ArchConfig, b: int, s: int, mesh, b_axes, lead=()):
    shape = lead + ((b, s, cfg.num_codebooks) if cfg.embed == "musicgen" else (b, s))
    spec = [None] * len(shape)
    spec[len(lead)] = b_axes
    return jax.ShapeDtypeStruct(shape, jnp.int32,
                                sharding=NamedSharding(mesh, P(*spec)))


def _patch_struct(cfg: ArchConfig, b: int, mesh, b_axes, lead=()):
    if cfg.embed != "vlm":
        return None
    shape = lead + (b, cfg.num_patches, cfg.d_vision)
    spec = [None] * len(shape)
    spec[len(lead)] = b_axes
    return jax.ShapeDtypeStruct(shape, jnp.bfloat16,
                                sharding=NamedSharding(mesh, P(*spec)))


def default_opts(cfg: ArchConfig, shape: ShapeSpec, **overrides) -> RuntimeOpts:
    base = dict(q_chunk=1024, kv_chunk=1024, remat=True,
                # paper's Q^a on the cache: kv-head-major int8 codes +
                # per-(token, head) f32 scales (the Pallas decode-attention
                # layout — init_caches/cache_specs carry the dtypes/shapes;
                # the paged pool is int8 by construction)
                quantized_kv=shape.kind in ("decode", "paged_decode"),
                moe_capacity_factor=1.25)
    if shape.kind in ("decode", "paged_decode"):
        # single KV block: no scan over a sharded cache dim (DESIGN.md §5);
        # bf16 SSD-state storage (f32 compute) — jamba fit fix
        base.update(kv_chunk=shape.seq_len, q_chunk=1, remat=False,
                    ssm_state_dtype="bfloat16")
    base.update(overrides)
    return RuntimeOpts(**base)


# ------------------------------------------------------------------ train


def train_target(cfg: ArchConfig, shape: ShapeSpec, mesh, opts: RuntimeOpts,
                 param_dtype=jnp.bfloat16):
    import dataclasses

    from repro.models.transformer import abstract_params

    dax = data_axes(mesh)
    if opts.act_sharding is None:
        # pin the residual stream to (batch=data, seq=None, d=None) across
        # the block scan (§Perf hillclimb 2)
        opts = dataclasses.replace(opts, act_sharding=(dax, None, None))
    if opts.moe_groups == 1:
        # shard-local expert dispatch (§Perf hillclimb 2): kills the global
        # dispatch scatter's full-buffer all-reduce
        opts = dataclasses.replace(opts, moe_groups=data_size(mesh))
    accum = max(1, shape.global_batch // MICRO_GLOBAL)
    micro = shape.global_batch // accum
    tc = TrainConfig(optimizer=AdamWConfig(), accum_steps=accum,
                     batch_pre_split=True)

    params = abstract_params(cfg, param_dtype)
    pspecs = shd.param_specs(cfg, mesh, fsdp=True)
    params = shd.to_shaped(params, pspecs, mesh)
    opt = jax.eval_shape(adamw_init, params)
    ospecs = shd.opt_state_specs(pspecs)
    opt = shd.to_shaped(opt, ospecs, mesh)

    lead = (accum,) if accum > 1 else ()
    b = micro if accum > 1 else shape.global_batch
    batch = {
        "tokens": _token_struct(cfg, b, shape.seq_len, mesh, dax, lead),
        "labels": _token_struct(cfg, b, shape.seq_len, mesh, dax, lead),
        "loss_mask": jax.ShapeDtypeStruct(
            lead + (b, shape.seq_len), jnp.float32,
            sharding=NamedSharding(mesh, P(*([None] * len(lead)), dax, None))),
    }
    if cfg.embed == "vlm":
        batch["patches"] = _patch_struct(cfg, b, mesh, dax, lead)
    if cfg.embed == "musicgen":
        # labels carry the codebook axis too
        batch["labels"] = _token_struct(cfg, b, shape.seq_len, mesh, dax, lead)

    fn = make_train_step(cfg, tc, opts)
    return fn, (params, opt, batch)


# ---------------------------------------------------------------- prefill


def make_prefill_chunked(cfg: ArchConfig, opts: RuntimeOpts, n_chunks: int):
    def fn(params, tokens, patches=None):
        if n_chunks == 1:
            return prefill(params, cfg, tokens, patches, None, opts)
        b = tokens.shape[0]
        bs = b // n_chunks
        toks = tokens.reshape(n_chunks, bs, *tokens.shape[1:])
        pat = (patches.reshape(n_chunks, bs, *patches.shape[1:])
               if patches is not None else None)

        def body(_, xs):
            tk = xs[0]
            pt = xs[1] if len(xs) > 1 else None
            logits, caches = prefill(params, cfg, tk, pt, None, opts)
            return None, (logits, caches)

        xs = (toks,) if pat is None else (toks, pat)
        _, (logits, caches) = jax.lax.scan(body, None, xs)

        def merge(a):  # (chunks, nb, bs, ...) → (nb, chunks·bs, ...)
            a = jnp.moveaxis(a, 0, 1)
            return a.reshape(a.shape[0], n_chunks * a.shape[2], *a.shape[3:])

        caches = jax.tree_util.tree_map(merge, caches)
        logits = logits.reshape(b, *logits.shape[2:])
        return logits, caches

    return fn


def prefill_target(cfg: ArchConfig, shape: ShapeSpec, mesh, opts: RuntimeOpts,
                   param_dtype=jnp.bfloat16):
    from repro.models.transformer import abstract_params

    dax = data_axes(mesh)
    dsz = data_size(mesh)
    fsdp = cfg.total_params() * 2 / mesh.shape["model"] > 8e9
    params = shd.to_shaped(abstract_params(cfg, param_dtype),
                           shd.param_specs(cfg, mesh, fsdp=fsdp), mesh)
    # largest chunk count keeping per-chunk batch divisible by the data axes
    n_chunks = 1
    for c in (8, 4, 2):
        if shape.global_batch % c == 0 and (shape.global_batch // c) % dsz == 0:
            n_chunks = c
            break
    tokens = _token_struct(cfg, shape.global_batch, shape.seq_len, mesh, dax)
    patches = _patch_struct(cfg, shape.global_batch, mesh, dax)
    inner = make_prefill_chunked(cfg, opts, n_chunks)
    # constrain the returned caches to the decode cache layout (seq over
    # 'model'): GSPMD otherwise leaves them model-replicated (~13 GB/dev on
    # internlm2) — §Perf fleet note
    cspecs = shd.cache_specs(cfg, mesh, shape.global_batch, shape.seq_len,
                             opts.quantized_kv)

    def fn(params, tokens, patches=None):
        logits, caches = (inner(params, tokens, patches)
                          if patches is not None else inner(params, tokens))
        from jax.sharding import NamedSharding

        caches = jax.tree_util.tree_map(
            lambda c, sp: jax.lax.with_sharding_constraint(
                c, NamedSharding(mesh, sp)),
            caches, cspecs)
        return logits, caches

    args = (params, tokens) + ((patches,) if patches is not None else ())
    return fn, args


# ----------------------------------------------------------------- decode


def decode_target(cfg: ArchConfig, shape: ShapeSpec, mesh, opts: RuntimeOpts,
                  param_dtype=jnp.bfloat16):
    from repro.models.transformer import abstract_params

    dax = data_axes(mesh)
    fsdp = cfg.total_params() * 2 / mesh.shape["model"] > 8e9
    params = shd.to_shaped(abstract_params(cfg, param_dtype),
                           shd.param_specs(cfg, mesh, fsdp=fsdp), mesh)
    b = shape.global_batch
    b_axes = dax if b % data_size(mesh) == 0 else None
    tokens = _token_struct(cfg, b, 1, mesh, b_axes)
    caches = jax.eval_shape(
        partial(init_caches, cfg, b, shape.seq_len, opts))
    cspecs = shd.cache_specs(cfg, mesh, b, shape.seq_len, opts.quantized_kv)
    caches = shd.to_shaped(caches, cspecs, mesh)
    pos = jax.ShapeDtypeStruct((), jnp.int32,
                               sharding=NamedSharding(mesh, P()))
    inner = serve_step_fn(cfg, opts)

    def fn(params, tokens, caches, pos):
        toks, new_caches = inner(params, tokens, caches, pos)
        # pin output caches to the input layout → donation can alias them
        new_caches = jax.tree_util.tree_map(
            lambda c, sp: jax.lax.with_sharding_constraint(
                c, NamedSharding(mesh, sp)),
            new_caches, cspecs)
        return toks, new_caches

    return fn, (params, tokens, caches, pos)


def paged_decode_target(cfg: ArchConfig, shape: ShapeSpec, mesh,
                        opts: RuntimeOpts, param_dtype=jnp.bfloat16):
    """One ragged ``paged_decode_step`` over a worst-case-sized kv_pool:
    pool page axis sharded over the data axes (pages are independent; the
    block-table gather crosses shards only at page granularity), block
    tables and per-request positions replicated."""
    from repro.kernels.decode_attention import BLOCK_S
    from repro.models import layers as L
    from repro.models.transformer import abstract_params, paged_decode_step

    dax = data_axes(mesh)
    fsdp = cfg.total_params() * 2 / mesh.shape["model"] > 8e9
    params = shd.to_shaped(abstract_params(cfg, param_dtype),
                           shd.param_specs(cfg, mesh, fsdp=fsdp), mesh)
    b = shape.global_batch
    page = min(BLOCK_S, shape.seq_len)
    maxb = -(-shape.seq_len // page)
    # worst-case reservation + trash page, rounded so the sharded page axis
    # divides the data-axis size
    dsz = data_size(mesh)
    num_pages = -(-(b * maxb + 1) // dsz) * dsz
    nb = cfg.num_blocks
    m = cfg.pattern[0].mixer
    kh, hd = m.num_kv_heads, m.head_dim

    def leaf(shp, dtype, spec):
        return jax.ShapeDtypeStruct(shp, dtype,
                                    sharding=NamedSharding(mesh, P(*spec)))

    caches = tuple(
        L.PagedKVCache(
            k=leaf((nb, num_pages, kh, page, hd), jnp.int8,
                   (None, dax, None, None, None)),
            v=leaf((nb, num_pages, kh, page, hd), jnp.int8,
                   (None, dax, None, None, None)),
            k_scale=leaf((nb, num_pages, kh, page), jnp.float32,
                         (None, dax, None, None)),
            v_scale=leaf((nb, num_pages, kh, page), jnp.float32,
                         (None, dax, None, None)),
            pos=leaf((nb, num_pages, page), jnp.int32, (None, dax, None)),
            block_table=leaf((nb, b, maxb), jnp.int32, (None, None, None)),
        )
        for _ in cfg.pattern)
    b_axes = dax if b % data_size(mesh) == 0 else None
    tokens = _token_struct(cfg, b, 1, mesh, b_axes)
    pos = leaf((b,), jnp.int32, (None,))

    def fn(params, tokens, caches, pos):
        logits, new_caches = paged_decode_step(params, cfg, tokens, caches,
                                               pos, opts)
        return jnp.argmax(logits, axis=-1), new_caches

    return fn, (params, tokens, caches, pos)


def get_target(cfg: ArchConfig, shape_name: str, mesh, **opt_overrides):
    shape = SHAPES[shape_name]
    opts = default_opts(cfg, shape, **opt_overrides)
    if shape.kind == "train":
        return train_target(cfg, shape, mesh, opts)
    if shape.kind == "prefill":
        return prefill_target(cfg, shape, mesh, opts)
    if shape.kind == "paged_decode":
        return paged_decode_target(cfg, shape, mesh, opts)
    return decode_target(cfg, shape, mesh, opts)
