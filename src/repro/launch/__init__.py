"""Launch: meshes, dry-run, roofline, drivers."""
