import os

os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

# (the two lines above MUST run before any jax import — jax locks the device
# count on first backend initialization)

"""Multi-pod dry-run: lower + compile every (architecture × input shape) on
the production meshes, prove memory fits, and extract the roofline terms.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma2-2b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all                 # 16×16
  PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod     # 2×16×16

Results land in experiments/dryrun/<arch>__<shape>__<mesh>.json.
"""

import argparse
import json
import time
import traceback

import jax

from repro.configs import ASSIGNED, get_config
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import build_roofline
from repro.launch.shapes import SHAPES, get_target, supports

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


def _mem_dict(compiled):
    try:
        m = compiled.memory_analysis()
    except Exception:
        return {}
    if m is None:
        return {}
    keys = ("argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "alias_size_in_bytes",
            "generated_code_size_in_bytes")
    out = {}
    for k in keys:
        v = getattr(m, k, None)
        if v is not None:
            out[k] = int(v)
    return out


def run_one(arch: str, shape_name: str, multi_pod: bool, out_dir: str = OUT_DIR,
            save_hlo: bool = False, **opt_overrides) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh_name = "pod512" if multi_pod else "pod256"
    tag = f"{arch}__{shape_name}__{mesh_name}"
    if not supports(cfg, shape):
        rec = {"tag": tag, "status": "skipped",
               "reason": "full-attention arch at 500k decode (DESIGN.md)"}
        _save(out_dir, tag, rec)
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    try:
        fn, args = get_target(cfg, shape_name, mesh, **opt_overrides)
        jax.set_mesh(mesh)  # context mesh (shard_map) + pjit mesh
        # donation mirrors production: train donates (params, opt_state);
        # decode donates the KV/SSM caches — without it memory_analysis
        # double-counts the scan's cache ys as temp
        donate = {"train": (0, 1), "decode": (2,)}.get(shape.kind, ())
        with mesh:
            lowered = jax.jit(fn, donate_argnums=donate).lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
        mem = _mem_dict(compiled)
        print(mem or "(memory_analysis unavailable on CPU backend)")
        cost = compiled.cost_analysis()
        if isinstance(cost, list):
            cost = cost[0]
        print({k: cost[k] for k in ("flops", "bytes accessed") if k in cost})
        rl = build_roofline(cfg, shape, compiled, mesh)
        from repro.launch.hlo_cost import analyze as hlo_analyze

        hc = hlo_analyze(compiled.as_text())
        coll_bytes = hc.collective_bytes_by_kind
        rec = {
            "tag": tag, "status": "ok", "arch": arch, "shape": shape_name,
            "mesh": mesh_name, "n_devices": int(mesh.devices.size),
            "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
            "memory_analysis": mem,
            "cost_analysis": {k: float(cost[k]) for k in
                              ("flops", "bytes accessed") if k in cost},
            "roofline": rl.as_dict(),
            "collectives": {"bytes": coll_bytes,
                            "unknown_trip_loops": hc.unknown_trip_loops},
            "opt_overrides": {k: str(v) for k, v in opt_overrides.items()},
        }
        if save_hlo:
            with open(os.path.join(out_dir, tag + ".hlo.txt"), "w") as f:
                f.write(compiled.as_text())
    except Exception as e:  # a failure here is a bug in the system
        rec = {"tag": tag, "status": "error", "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-2000:],
               "elapsed_s": round(time.time() - t0, 1)}
    _save(out_dir, tag, rec)
    return rec


def _save(out_dir: str, tag: str, rec: dict):
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, tag + ".json"), "w") as f:
        json.dump(rec, f, indent=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--out", default=OUT_DIR)
    args = ap.parse_args()

    archs = [args.arch] if args.arch else ASSIGNED
    shapes = [args.shape] if args.shape else list(SHAPES)
    if not (args.all or args.arch):
        ap.error("pass --arch or --all")

    results = []
    for a in archs:
        for s in shapes:
            t0 = time.time()
            rec = run_one(a, s, args.multi_pod, args.out, args.save_hlo)
            status = rec["status"]
            extra = ""
            if status == "ok":
                r = rec["roofline"]
                extra = (f" dominant={r['dominant']}"
                         f" c={r['compute_s']:.3e} m={r['memory_s']:.3e}"
                         f" x={r['collective_s']:.3e}")
            elif status == "error":
                extra = " " + rec["error"][:120]
            print(f"[{time.time() - t0:7.1f}s] {rec['tag']}: {status}{extra}",
                  flush=True)
            results.append(rec)
    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skipped" for r in results)
    n_err = len(results) - n_ok - n_skip
    print(f"\n== dry-run summary: {n_ok} ok, {n_skip} skipped, {n_err} errors ==")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
