"""Distributed training launcher.

On real hardware this wires the same ``make_train_step`` through pjit with
the FSDP×TP shardings from repro.launch.sharding; in this CPU container use
``REPRO_FORCE_DEVICES=N`` to simulate an N-device host mesh (must be set
before jax initializes, hence the env hook at module top).

  REPRO_FORCE_DEVICES=8 PYTHONPATH=src python -m repro.launch.train \
      --arch gemma2-2b --tiny --steps 20 --mesh 2x4
"""

import os

if os.environ.get("REPRO_FORCE_DEVICES"):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                               f" --xla_force_host_platform_device_count="
                               f"{os.environ['REPRO_FORCE_DEVICES']}").strip()

import argparse
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.data.pipeline import ZipfMarkov, lm_loader
from repro.launch import sharding as shd
from repro.models.transformer import RuntimeOpts
from repro.training.checkpoint import save_checkpoint
from repro.training.optimizer import AdamWConfig, adamw_init
from repro.training.train_loop import TrainConfig, init_train_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--mesh", default=None, help="e.g. 2x4 (data x model)")
    ap.add_argument("--checkpoint", default=None)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.tiny:
        cfg = cfg.tiny()
    if args.mesh:
        dims = tuple(int(x) for x in args.mesh.split("x"))
        axes = ("data", "model") if len(dims) == 2 else ("pod", "data", "model")
        mesh = jax.make_mesh(dims, axes)
    else:
        mesh = jax.make_mesh((1, 1), ("data", "model"))
    print(f"[train] arch={cfg.name} params={cfg.total_params():,} "
          f"mesh={dict(zip(mesh.axis_names, mesh.devices.shape))}")

    opts = RuntimeOpts(q_chunk=min(1024, args.seq), kv_chunk=min(1024, args.seq),
                       remat=True)
    tc = TrainConfig(AdamWConfig(lr=args.lr, warmup_steps=10,
                                 total_steps=args.steps),
                     accum_steps=args.accum)
    params, opt_state = init_train_state(cfg, jax.random.PRNGKey(0))

    pspecs = shd.param_specs(cfg, mesh, fsdp=True)
    with mesh:
        params = jax.device_put(params, shd.shardings_of(pspecs, mesh))
        opt_state = jax.device_put(
            opt_state, shd.shardings_of(shd.opt_state_specs(pspecs), mesh))
        step_fn = jax.jit(make_train_step(cfg, tc, opts),
                          donate_argnums=(0, 1))
        corpus = ZipfMarkov(cfg.vocab_size, branching=8, seed=0)
        loader = lm_loader(corpus, args.batch, args.seq, args.steps)
        dax = shd.data_axes(mesh) if args.batch % shd.len_prod(
            mesh, shd.data_axes(mesh)) == 0 else None
        bshard = NamedSharding(mesh, P(dax))
        t0 = time.time()
        for i, batch in enumerate(loader):
            batch = {k: jax.device_put(jnp.asarray(v), bshard)
                     for k, v in batch.items()}
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            if i % 10 == 0:
                print(f"[train] step {i:4d} loss {float(metrics['loss']):.4f} "
                      f"({(time.time() - t0) / (i + 1):.2f}s/step)")
    if args.checkpoint:
        save_checkpoint(args.checkpoint, params, step=args.steps)
        print(f"[train] saved checkpoint → {args.checkpoint}")


if __name__ == "__main__":
    main()
