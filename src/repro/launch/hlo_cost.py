"""Trip-count-aware HLO cost analysis.

XLA:CPU's ``compiled.cost_analysis()`` counts each ``while`` body ONCE —
useless for scan-heavy programs (an 88-block scan × 8 grad-accum steps
under-counts ~700×). This module parses the post-SPMD HLO text, builds the
computation call graph, extracts static trip counts from loop conditions
(``constant(N)`` + LT compare — the lax.scan pattern), and weights:

  * dot FLOPs            — 2 · |result| · |contracted dims|,
  * collective bytes     — result bytes of all-gather / all-reduce /
                           reduce-scatter / all-to-all / collective-permute,
  * materialized bytes   — 2 × Σ result bytes of top-level (non-fusion-
                           internal) ops — a standard read+write HBM-traffic
                           estimate (fusion internals never hit HBM).

Validated against the analytic 6·N·D model in tests (ratios land in the
expected remat/recompute band instead of 10–300× off).
"""

from __future__ import annotations

import dataclasses
import re

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e5m2": 1, "f8e4m3fn": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "s4": 0.5,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1, "u4": 0.5,
    "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(
    r"(f64|f32|f16|bf16|f8e5m2|f8e4m3fn|s64|s32|s16|s8|s4|u64|u32|u16|u8|u4|"
    r"pred|c64|c128)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?(%[\w.\-]+)\s*=\s*(.*)$")
_OP_RE = re.compile(r"^(\(?[^(]*?\)?)\s+([\w\-]+)\((.*)$")


def _shape_dims(shape_text: str):
    """All (dtype, dims) found in a type string (tuples give several)."""
    out = []
    for dt, dims in _SHAPE_RE.findall(shape_text):
        d = [int(x) for x in dims.split(",")] if dims else []
        out.append((dt, d))
    return out


def _shape_bytes(shape_text: str) -> float:
    total = 0.0
    for dt, dims in _shape_dims(shape_text):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class _Op:
    name: str
    kind: str
    result_text: str
    line: str


@dataclasses.dataclass
class _Computation:
    name: str
    ops: list
    symbols: dict  # %name -> result type text


def parse_computations(hlo: str) -> dict:
    comps: dict = {}
    cur = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        stripped = line.strip()
        header = re.match(r"^(?:ENTRY\s+)?(%[\w.\-]+)\s*\(.*\)\s*->.*{", stripped)
        if header and not line.startswith(" "):
            cur = _Computation(header.group(1), [], {})
            comps[cur.name] = cur
            if stripped.startswith("ENTRY") or raw.startswith("ENTRY"):
                comps["__entry__"] = cur
            continue
        if raw.startswith("ENTRY"):
            h2 = re.match(r"^ENTRY\s+(%[\w.\-]+)", raw)
            if h2:
                cur = _Computation(h2.group(1), [], {})
                comps[cur.name] = cur
                comps["__entry__"] = cur
            continue
        if cur is None:
            continue
        if stripped == "}":
            cur = None
            continue
        m = _DEF_RE.match(stripped)
        if not m:
            continue
        name, rhs = m.groups()
        om = _OP_RE.match(rhs)
        if not om:
            continue
        result_text, kind, _ = om.groups()
        cur.symbols[name] = result_text
        cur.ops.append(_Op(name, kind, result_text, stripped))
    return comps


def _trip_count(cond: _Computation) -> int | None:
    const = None
    for op in cond.ops:
        c = re.search(r"constant\((\d+)\)", op.line)
        if c and op.kind == "constant":
            const = int(c.group(1))
    for op in cond.ops:
        if op.kind == "compare" and "direction=LT" in op.line and const is not None:
            return const
    return const


def _called(line: str) -> dict:
    """Computation references on an op line: {role: comp_name}."""
    out = {}
    for role in ("condition", "body", "calls", "to_apply"):
        m = re.search(role + r"=(%[\w.\-]+)", line)
        if m:
            out[role] = m.group(1)
    return out


def _dot_flops(op: _Op, symbols: dict) -> float:
    operands = re.findall(r"dot\((%[\w.\-]+),\s*(%[\w.\-]+)\)", op.line)
    if not operands:
        return 0.0
    lhs_name = operands[0][0]
    lhs_text = symbols.get(lhs_name, "")
    lhs_shapes = _shape_dims(lhs_text)
    res_shapes = _shape_dims(op.result_text)
    if not lhs_shapes or not res_shapes:
        return 0.0
    lhs_dims = lhs_shapes[0][1]
    res_n = 1
    for d in res_shapes[0][1]:
        res_n *= d
    cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.line)
    contract = 1
    if cm and cm.group(1):
        for idx in cm.group(1).split(","):
            i = int(idx)
            if i < len(lhs_dims):
                contract *= lhs_dims[i]
    return 2.0 * res_n * contract


_SKIP_MEMORY = {"parameter", "constant", "get-tuple-element", "tuple",
                "bitcast", "copy-start", "copy-done", "after-all"}


@dataclasses.dataclass
class HloCost:
    flops: float
    collective_bytes: float
    memory_bytes: float
    collective_bytes_by_kind: dict
    unknown_trip_loops: int


def analyze(hlo: str) -> HloCost:
    comps = parse_computations(hlo)
    entry = comps.get("__entry__")
    if entry is None:
        return HloCost(0, 0, 0, {}, 0)

    # multipliers via DFS over the call graph
    mult: dict = {}
    fusion_internal: set = set()
    unknown = [0]

    def visit(comp_name: str, m: float, in_fusion: bool):
        comp = comps.get(comp_name)
        if comp is None:
            return
        mult[comp_name] = mult.get(comp_name, 0.0) + m
        if in_fusion:
            fusion_internal.add(comp_name)
        for op in comp.ops:
            refs = _called(op.line)
            if op.kind == "while":
                cond = comps.get(refs.get("condition", ""))
                tc = _trip_count(cond) if cond else None
                if tc is None:
                    tc = 1
                    unknown[0] += 1
                visit(refs.get("body", ""), m * tc, in_fusion)
                visit(refs.get("condition", ""), m * tc, True)  # cond ~ free
            elif op.kind == "fusion":
                visit(refs.get("calls", ""), m, True)
            elif "to_apply" in refs:
                visit(refs["to_apply"], m, in_fusion or op.kind in
                      ("reduce", "sort", "scatter", "select-and-scatter",
                       "reduce-window"))

    visit(entry.name, 1.0, False)

    flops = 0.0
    coll = {k: 0.0 for k in _COLLECTIVES}
    mem = 0.0
    for name, comp in comps.items():
        if name == "__entry__":
            continue
        m = mult.get(name, 0.0)
        if m == 0.0:
            continue
        internal = name in fusion_internal
        for op in comp.ops:
            if op.kind == "dot":
                flops += m * _dot_flops(op, comp.symbols)
            base = op.kind
            for suf in ("-start", "-done"):
                if base.endswith(suf):
                    base = base[: -len(suf)]
            if base in _COLLECTIVES and not op.kind.endswith("-done"):
                coll[base] += m * _shape_bytes(op.result_text)
            if not internal and op.kind not in _SKIP_MEMORY:
                mem += m * _shape_bytes(op.result_text)
    return HloCost(flops, sum(coll.values()), 2.0 * mem, coll, unknown[0])
