"""Serving launcher: batched requests through the engine, optionally in
split-computing mode (the paper's deployment).

  PYTHONPATH=src python -m repro.launch.serve --arch gemma2-2b --tiny \
      --batch 4 --new 16 [--split --split-layer 1 --qw-front 8]
"""

import os

if os.environ.get("REPRO_FORCE_DEVICES"):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                               f" --xla_force_host_platform_device_count="
                               f"{os.environ['REPRO_FORCE_DEVICES']}").strip()

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core.channel import ChannelConfig
from repro.core.opsc import OPSCConfig
from repro.models.transformer import RuntimeOpts, init_params
from repro.serving.engine import Engine
from repro.serving.split_engine import SplitEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new", type=int, default=16)
    ap.add_argument("--quantized-kv", action="store_true")
    ap.add_argument("--split", action="store_true")
    ap.add_argument("--split-layer", type=int, default=1)
    ap.add_argument("--qw-front", type=int, default=8)
    ap.add_argument("--deadline-ms", type=float, default=None)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.tiny:
        cfg = cfg.tiny()
    opts = RuntimeOpts(q_chunk=64, kv_chunk=64, remat=False,
                       quantized_kv=args.quantized_kv,
                       moe_capacity_factor=0.0)
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    if cfg.embed == "musicgen":
        prompts = rng.integers(0, cfg.vocab_size,
                               (args.batch, args.prompt_len, cfg.num_codebooks))
    else:
        prompts = rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len))
    prompts = prompts.astype(np.int32)
    cache_len = args.prompt_len + args.new

    if args.split:
        # snap the split to a pattern boundary (OPSC splits between blocks)
        plen = len(cfg.pattern)
        ell = max(plen, args.split_layer - args.split_layer % plen)
        if ell != args.split_layer:
            print(f"[serve/split] split_layer {args.split_layer} → {ell} "
                  f"(pattern boundary)")
        opsc = OPSCConfig(split_layer=ell, qw_front=args.qw_front)
        eng = SplitEngine(cfg, params, opsc, channel=ChannelConfig(),
                          deadline_s=(args.deadline_ms or 0) / 1e3 or None,
                          opts=opts, cache_len=cache_len)
        t0 = time.time()
        tokens, stats = eng.generate(prompts, args.new)
        dt = time.time() - t0
        print(f"[serve/split] {tokens.shape[0]}×{args.new} tokens in {dt:.2f}s; "
              f"uplink {stats.uplink_bits_measured / 8e3:.1f} KB measured "
              f"({stats.uplink_bits_eq3 / 8e3:.1f} KB Eq.3), "
              f"early_exits={stats.early_exits}")
    else:
        eng = Engine(cfg, params, opts, cache_len=cache_len)
        t0 = time.time()
        res = eng.generate(prompts, args.new)
        dt = time.time() - t0
        tps = args.batch * args.new / dt
        print(f"[serve] {res.tokens.shape} in {dt:.2f}s = {tps:.1f} tok/s "
              f"(kv={'int8' if args.quantized_kv else 'bf16'})")


if __name__ == "__main__":
    main()
