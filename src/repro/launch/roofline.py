"""Roofline analysis from compiled artifacts (no real hardware).

Three terms per (arch × shape × mesh), from the SPMD-partitioned per-device
module:

  compute    = HLO_FLOPs_per_device / PEAK_FLOPS_BF16
  memory     = HLO_bytes_per_device / HBM_BW
  collective = collective_bytes_per_device / ICI_BW

FLOPs/bytes come from ``compiled.cost_analysis()``; collective bytes are NOT
there — we parse the post-SPMD HLO (``compiled.as_text()``) and sum operand
sizes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute. MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE); the
ratio MODEL_FLOPS / (HLO_FLOPs × chips) exposes remat/redundancy waste.
"""

from __future__ import annotations

import dataclasses
import re

from repro.configs.base import ArchConfig
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e5m2": 1, "f8e4m3fn": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "s4": 0.5,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1, "u4": 0.5,
    "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(f64|f32|f16|bf16|f8e5m2|f8e4m3fn|s64|s32|s16|s8|s4|"
                       r"u64|u32|u16|u8|u4|pred|c64|c128)\[([\d,]*)\]")


def _shape_bytes(text: str) -> float:
    """Sum bytes of every typed shape literal in ``text`` (handles tuples)."""
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_kind: dict
    count_by_kind: dict

    @property
    def total_bytes(self) -> float:
        return sum(self.bytes_by_kind.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Sum *result* operand sizes of collective ops in post-SPMD HLO.

    Lines look like ``%all-reduce.5 = bf16[2,512]{1,0} all-reduce(...)``;
    ``-start``/``-done`` async pairs are counted once (on -start; bare ops
    counted directly)."""
    bytes_by = {k: 0.0 for k in _COLLECTIVES}
    count_by = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        ls = line.strip()
        m = re.match(r"%?[\w.\-]+\s*=\s*(.+?)\s+([\w\-]+)\(", ls)
        if not m:
            continue
        shape_part, op = m.groups()
        base = op
        for suffix in ("-start", "-done"):
            if base.endswith(suffix):
                base = base[: -len(suffix)]
        if base not in _COLLECTIVES:
            continue
        if op.endswith("-done"):
            continue  # counted at -start
        bytes_by[base] += _shape_bytes(shape_part)
        count_by[base] += 1
    return CollectiveStats(bytes_by, count_by)


@dataclasses.dataclass
class Roofline:
    flops_per_device: float
    hbm_bytes_per_device: float
    collective_bytes_per_device: float
    n_devices: int
    model_flops: float  # 6·N_active·D_tokens (global)

    @property
    def compute_s(self) -> float:
        return self.flops_per_device / PEAK_FLOPS_BF16

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes_per_device / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.collective_bytes_per_device / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        hlo_global = self.flops_per_device * self.n_devices
        return self.model_flops / hlo_global if hlo_global else 0.0

    def as_dict(self) -> dict:
        return {
            "flops_per_device": self.flops_per_device,
            "hbm_bytes_per_device": self.hbm_bytes_per_device,
            "collective_bytes_per_device": self.collective_bytes_per_device,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "model_flops": self.model_flops,
            "useful_flops_ratio": self.useful_flops_ratio,
        }


def model_flops(cfg: ArchConfig, shape_kind: str, tokens: int) -> float:
    """6·N·D with N = active params (MoE counts top-k + shared only).
    Train = fwd+bwd (the full 6·N·D); prefill = 2·N·D; decode = 2·N·D per
    generated token (D = batch here)."""
    n = cfg.total_params(active=True)
    if shape_kind == "train":
        return 6.0 * n * tokens
    return 2.0 * n * tokens


def build_roofline(cfg: ArchConfig, shape, compiled, mesh) -> Roofline:
    """Trip-count-aware terms from the post-SPMD HLO (see repro.launch
    .hlo_cost — XLA:CPU cost_analysis counts scan bodies once, which
    under-counts deep-stack programs by orders of magnitude)."""
    from repro.launch.hlo_cost import analyze

    hc = analyze(compiled.as_text())
    n_dev = mesh.devices.size
    if shape.kind in ("train", "prefill"):
        tokens = shape.global_batch * shape.seq_len
    else:
        tokens = shape.global_batch  # one step
    return Roofline(hc.flops, hc.memory_bytes, hc.collective_bytes, n_dev,
                    model_flops(cfg, shape.kind, tokens))
