"""Production meshes.

Defined as FUNCTIONS (not module constants) so importing this module never
touches jax device state. The dry-run entry point sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import; everything else (tests, benches) sees the real single CPU device.

Target: TPU v5e, 256 chips/pod. Single-pod mesh (16, 16) = ('data',
'model'); multi-pod (2, 16, 16) = ('pod', 'data', 'model') — the 'pod' axis
joins data parallelism by default and becomes the edge/cloud *stage* axis in
split-computing mode (see repro.launch.split_dryrun).
"""

from __future__ import annotations

import jax

PEAK_FLOPS_BF16 = 197e12  # per chip, TPU v5e
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_serving_mesh(num_kv_heads: int, devices=None):
    """The serving deployment's ``("kv", "model")`` mesh over ``devices``
    (default: every visible device).

    The ``kv`` axis shards the paged pool's page axis; the ``model`` axis
    splits attention kv-head groups. ``model`` takes the largest divisor of
    ``gcd(len(devices), num_kv_heads)`` that still leaves >= 2 devices for
    the page axis (head splits only pay off once pages are already spread),
    so 1 device -> (1, 1), 2 -> (2, 1), 4 with an even kv-head count ->
    (2, 2). Built from an explicit device array (not ``jax.make_mesh``) so
    sub-meshes over ``jax.devices()[:n]`` work inside one forced-N-device
    test process."""
    import math

    import numpy as np
    from jax.sharding import Mesh

    devices = list(jax.devices()) if devices is None else list(devices)
    d = len(devices)
    model = 1
    for m in range(math.gcd(d, num_kv_heads), 0, -1):
        if math.gcd(d, num_kv_heads) % m == 0 and d % m == 0 and d // m >= 2:
            model = m
            break
    kv = d // model
    return Mesh(np.asarray(devices).reshape(kv, model), ("kv", "model"))


def data_axes(mesh) -> tuple:
    """Axes carrying data parallelism (the 'pod' axis joins by default)."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def data_size(mesh) -> int:
    return int(jax.numpy.prod(jax.numpy.asarray(
        [mesh.shape[a] for a in data_axes(mesh)])))


def model_size(mesh) -> int:
    return mesh.shape["model"]
