"""Production meshes.

Defined as FUNCTIONS (not module constants) so importing this module never
touches jax device state. The dry-run entry point sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import; everything else (tests, benches) sees the real single CPU device.

Target: TPU v5e, 256 chips/pod. Single-pod mesh (16, 16) = ('data',
'model'); multi-pod (2, 16, 16) = ('pod', 'data', 'model') — the 'pod' axis
joins data parallelism by default and becomes the edge/cloud *stage* axis in
split-computing mode (see repro.launch.split_dryrun).
"""

from __future__ import annotations

import jax

PEAK_FLOPS_BF16 = 197e12  # per chip, TPU v5e
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def data_axes(mesh) -> tuple:
    """Axes carrying data parallelism (the 'pod' axis joins by default)."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def data_size(mesh) -> int:
    return int(jax.numpy.prod(jax.numpy.asarray(
        [mesh.shape[a] for a in data_axes(mesh)])))


def model_size(mesh) -> int:
    return mesh.shape["model"]
