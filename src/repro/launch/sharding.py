"""Sharding policies: map parameter/activation/cache pytrees onto the mesh.

Rules (see DESIGN.md §5):

* Parameters (train, FSDP×TP): last dim over 'model', second-to-last over the
  data axes — when divisible. MoE expert tensors (nb, E, D, F) shard E over
  'model' when the spec says ``shard='expert'`` and E divides; otherwise the
  per-expert ffn dim. Embedding/lm_head shard vocab over 'model' so logits
  come out vocab-sharded (the CE all-reduce is cheap; un-sharded 256k-vocab
  logits are not).
* Parameters (serve): same mapping with FSDP off when the TP-sharded weights
  fit HBM (all archs but qwen3-moe-235b), on otherwise.
* Batches: leading (batch) dim over the data axes.
* KV caches: batch over data when divisible (else seq over data — the
  long_500k batch=1 context-parallel case); kv-heads over 'model' when
  divisible, else head_dim.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.launch.mesh import data_axes, model_size


def _data_div(mesh, n: int) -> bool:
    from repro.launch.mesh import data_size

    return n % data_size(mesh) == 0


def _path_str(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
                    for p in path)


def param_specs(cfg: ArchConfig, mesh, fsdp: bool):
    """PartitionSpec pytree matching ``init_params(cfg, ...)``."""
    from repro.models.transformer import abstract_params

    msize = model_size(mesh)
    dax = data_axes(mesh)
    moe_shard = {}
    for i, ls in enumerate(cfg.pattern):
        if ls.ffn is not None and ls.ffn.kind == "moe":
            moe_shard[f"p{i}"] = ls.ffn.shard

    def spec_for(path, leaf):
        name = _path_str(path)
        shape = leaf.shape
        nd = len(shape)
        spec = [None] * nd
        is_block = name.startswith("blocks/")

        if nd == 4 and is_block:  # MoE expert weights (nb, E, D, F)
            pos = name.split("/")[1]
            if moe_shard.get(pos) == "expert" and shape[1] % msize == 0:
                spec[1] = "model"
                if fsdp and shape[2] % len_prod(mesh, dax) == 0:
                    spec[2] = dax
            else:  # ffn sharding
                if shape[3] % msize == 0:
                    spec[3] = "model"
                if fsdp and shape[2] % len_prod(mesh, dax) == 0:
                    spec[2] = dax
            return P(*spec)

        if name == "embed" or name.startswith("embed"):
            # (V, D) or (K, V, D): vocab over model, D over data (fsdp)
            if shape[-2] % msize == 0:
                spec[-2] = "model"
            if fsdp and shape[-1] % len_prod(mesh, dax) == 0:
                spec[-1] = dax
            return P(*spec)

        if nd >= 2:
            if shape[-1] % msize == 0:
                spec[-1] = "model"
            if fsdp and shape[-2] % len_prod(mesh, dax) == 0:
                spec[-2] = dax
            return P(*spec)
        return P()  # 1-D / scalars replicated

    tmpl = abstract_params(cfg)
    return jax.tree_util.tree_map_with_path(spec_for, tmpl)


def len_prod(mesh, axes) -> int:
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def opt_state_specs(param_spec_tree):
    """AdamW state (mu, nu, count) mirrors the parameter sharding."""
    from repro.training.optimizer import AdamWState

    return AdamWState(param_spec_tree, param_spec_tree, P())


def batch_specs(mesh, batch: int):
    dax = data_axes(mesh)
    b_ax = dax if _data_div(mesh, batch) else None
    return b_ax


def cache_specs(cfg: ArchConfig, mesh, batch: int, seq: int, quantized: bool = False):
    """PartitionSpec pytree matching ``init_caches``."""
    msize = model_size(mesh)
    dax = data_axes(mesh)
    batch_ok = batch % len_prod(mesh, dax) == 0
    b_ax = dax if batch_ok else None

    def kv_spec(kv_heads, head_dim, size):
        # Preferred: context parallelism — seq over 'model' (plus the data
        # axes when batch=1). Decode attention then contracts over hd and
        # psums only the tiny (b, h, 1, hd) output; sharding kv-heads or hd
        # instead forces score-side collectives over the whole cache.
        # Returns (kv tensor spec, scale spec, seq axis); the quantized cache
        # is kv-head-major (nb, B, K, S, hd) + scales (nb, B, K, S), the fp
        # cache token-major (nb, B, S, K, hd).
        s_axes = []
        if not batch_ok:
            s_axes.extend(dax)  # long_500k batch=1
        s_axes.append("model")
        s_ax = None
        if size % len_prod(mesh, tuple(s_axes)) == 0:
            s_ax = tuple(s_axes) if len(s_axes) > 1 else s_axes[0]
        elif not batch_ok and size % len_prod(mesh, dax) == 0:
            s_ax = dax
        if quantized:
            if s_ax is not None:
                return (P(None, b_ax, None, s_ax, None),
                        P(None, b_ax, None, s_ax), s_ax)
            if kv_heads % msize == 0:
                return (P(None, b_ax, "model", None, None),
                        P(None, b_ax, "model", None), None)
            return (P(None, b_ax, None, None, None),
                    P(None, b_ax, None, None), None)
        if s_ax is not None:
            return P(None, b_ax, s_ax, None, None), None, s_ax
        if kv_heads % msize == 0:
            return P(None, b_ax, None, "model", None), None, None
        return P(None, b_ax, None, None, None), None, None

    specs = []
    for ls in cfg.pattern:
        m = ls.mixer
        if m.kind == "attn":
            size = min(seq, m.sliding_window) if m.sliding_window else seq
            if quantized:  # init_caches block-aligns the quantized slot axis
                from repro.kernels.decode_attention import padded_cache_len

                size = padded_cache_len(size)
            kv, sc, pos_sax = kv_spec(m.num_kv_heads, m.head_dim, size)
            from repro.models.layers import KVCache

            specs.append(KVCache(kv, kv, sc, sc, P(None, b_ax, pos_sax)))
        else:
            conv_ch = m.d_inner + 2 * m.d_state
            conv = P(None, b_ax, None, "model" if conv_ch % msize == 0 else None)
            h_ax = "model" if m.n_heads % msize == 0 else None
            state = P(None, b_ax, h_ax, None, None)
            specs.append((conv, state))
    return tuple(specs)


def to_shaped(tree, spec_tree, mesh):
    """Attach NamedShardings to a ShapeDtypeStruct pytree."""

    def attach(leaf, spec):
        return jax.ShapeDtypeStruct(leaf.shape, leaf.dtype,
                                    sharding=NamedSharding(mesh, spec))

    return jax.tree_util.tree_map(attach, tree, spec_tree,
                                  is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def shardings_of(spec_tree, mesh):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))
