"""Split-computing over the pod axis — the paper's edge/cloud split mapped
onto a 2-pod TPU system (DESIGN.md §2).

``make_pipeline_decode_step`` builds a 2-stage pipelined decode step under
``jax.shard_map`` manual over the 'pod' axis only ('data'/'model' stay under
GSPMD): pod 0 ("edge") owns the front half of the stacked blocks, pod 1
("cloud") the back half. The decode batch is split into ``n_micro``
microbatches that flow through the two stages GPipe-style (n_micro + 1
iterations, one bubble). The stage-boundary activation is compressed before
the inter-pod ``ppermute``:

  payload_bits = 16 → bf16 (baseline)
  payload_bits = 8  → per-token int8 (fixed-bit TAB-Q: codes + f32 scale)
  payload_bits = 4  → per-token int4, two codes packed per byte

Adaptive per-token bit-widths (Algorithm 1 proper) would make message sizes
data-dependent — unsupported on ICI — so the TPU-native adaptation is
fixed-bit TAB-Q with per-token scales; the *choice* of bit-width moves to
the launcher (the paper's Eq. 8/12 decision layer). Inter-pod bytes drop
~2×/4×, measured directly in the dry-run's collective-permute traffic
(EXPERIMENTS.md §Perf).

Cache note (§Perf pair-3 iter 4): caches are **microbatch-major** —
(num_blocks, n_micro+1, bs, seq, ...) — so per-iteration slicing is a
dynamic-index on an UNSHARDED dim; slicing row ranges of a flat batch dim
instead forces GSPMD to rematerialize the (seq-sharded) cache every
iteration (~258 GB/dev of resharding permutes measured). The last micro
slot is trash for the bubble iterations (memory overhead 1/n_micro).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models.transformer import (RuntimeOpts, _apply_blocks_cached,
                                      apply_head, embed_inputs, init_caches,
                                      make_positions, rope_tables)


def init_pipeline_caches(cfg: ArchConfig, bs: int, n_micro: int,
                         cache_len: int, opts: RuntimeOpts):
    """Microbatch-major caches: (num_blocks, n_micro+1, bs, ...) — slot
    n_micro is the bubble trash slot."""
    base = init_caches(cfg, bs, cache_len, opts)
    return jax.tree_util.tree_map(
        lambda a: jnp.repeat(a[:, None], n_micro + 1, axis=1), base)


def _quant_payload(h: jax.Array, bits: int):
    """h (bs, 1, D) → (codes, scale). Fixed-bit TAB-Q (per-token scale)."""
    if bits >= 16:
        return h.astype(jnp.bfloat16), jnp.zeros((*h.shape[:-1], 1), jnp.float32)
    hf = h.astype(jnp.float32)
    qmax = float(2 ** (bits - 1) - 1)
    amax = jnp.max(jnp.abs(hf), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / qmax
    codes = jnp.clip(jnp.round(hf / scale), -qmax, qmax)
    if bits == 8:
        return codes.astype(jnp.int8), scale
    # int4: pack two codes per uint8 byte
    c = codes.astype(jnp.int32) & 0xF
    lo, hi = c[..., 0::2], c[..., 1::2]
    return (lo | (hi << 4)).astype(jnp.uint8), scale


def _dequant_payload(codes: jax.Array, scale: jax.Array, bits: int, d: int,
                     dtype) -> jax.Array:
    if bits >= 16:
        return codes.astype(dtype)
    if bits == 8:
        return (codes.astype(jnp.float32) * scale).astype(dtype)
    p = codes.astype(jnp.int32)
    lo, hi = p & 0xF, (p >> 4) & 0xF
    vals = jnp.stack([lo, hi], axis=-1).reshape(*codes.shape[:-1], d)
    vals = jnp.where(vals >= 8, vals - 16, vals)
    return (vals.astype(jnp.float32) * scale).astype(dtype)


def make_pipeline_decode_step(cfg: ArchConfig, opts: RuntimeOpts, n_micro: int,
                              payload_bits: int = 16, prefill: bool = False):
    """Returns fn(blocks, other_params, tokens, caches, pos) → (tokens_out,
    caches). Call under ``jax.shard_map(..., axis_names={'pod'})`` via
    :func:`pipeline_decode_sharded`. Caches must carry B + B/n_micro batch
    rows (trash slot); blocks/caches leading dim = num_blocks (sharded over
    'pod' by the wrapper). ``prefill=True`` processes full prompts (tokens
    (B, S)), where the stage boundary is B/n_micro × S × D per microbatch —
    the regime where payload compression moves real inter-pod bytes."""
    assert cfg.num_blocks % 2 == 0, "pipeline needs an even block count"

    def fn(blocks, other_params, tokens, caches, pos):
        stage = jax.lax.axis_index("pod")
        b = tokens.shape[0]
        seq = tokens.shape[1] if prefill else 1
        bs = b // n_micro
        d = cfg.d_model
        payload_d = d // 2 if payload_bits == 4 else d
        payload_dtype = (jnp.bfloat16 if payload_bits >= 16
                         else jnp.int8 if payload_bits == 8 else jnp.uint8)

        if prefill:
            positions = jnp.broadcast_to(
                jnp.arange(seq, dtype=jnp.int32)[None], (bs, seq))
        else:
            positions = jnp.broadcast_to(
                jnp.asarray(pos, jnp.int32)[None, None], (bs, 1))
        rope_cs = rope_tables(cfg, positions)
        n_vocab_out = cfg.vocab_size * cfg.num_codebooks

        def iter_body(carry, i):
            codes_in, scale_in, caches, out = carry
            valid0 = i < n_micro
            valid1 = i >= 1
            tok_off = jnp.where(valid0, i * bs, 0)
            tok = jax.lax.dynamic_slice_in_dim(tokens, tok_off, bs, 0)
            dec = not prefill
            # micro slot this stage touches (slot n_micro = bubble trash)
            slot = jnp.where(stage == 0,
                             jnp.where(valid0, i, n_micro),
                             jnp.where(valid1, i - 1, n_micro))

            x_edge = embed_inputs(cfg, other_params, tok, None, positions)
            x_cloud = _dequant_payload(codes_in, scale_in, payload_bits, d,
                                       x_edge.dtype)
            x = jnp.where(stage == 0, x_edge, x_cloud)

            cache_slice = jax.tree_util.tree_map(
                lambda a: jax.lax.dynamic_index_in_dim(a, slot, axis=1,
                                                       keepdims=False),
                caches)
            x, new_slice = _apply_blocks_cached(
                cfg, blocks, x, cache_slice, rope_cs=rope_cs,
                q_positions=positions, pos=jnp.asarray(pos, jnp.int32),
                opts=opts, decode=dec)
            caches = jax.tree_util.tree_map(
                lambda full, sl: jax.lax.dynamic_update_slice_in_dim(
                    full, sl[:, None].astype(full.dtype), slot, axis=1),
                caches, new_slice)

            # compress + ship the boundary activation across the pod link
            codes, scale = _quant_payload(x, payload_bits)
            codes = jax.lax.ppermute(codes, "pod", [(0, 1), (1, 0)])
            scale = jax.lax.ppermute(scale, "pod", [(0, 1), (1, 0)])

            # cloud head for microbatch i-1 (stage 0's write lands in trash)
            logits = apply_head(cfg, other_params, x[:, -1:])[:, 0]
            logits = logits.reshape(bs, n_vocab_out)
            out_slot = jnp.where(stage == 1,
                                 jnp.where(valid1, i - 1, n_micro), n_micro)
            out = jax.lax.dynamic_update_slice(
                out, logits[None].astype(out.dtype), (out_slot, 0, 0))
            return (codes, scale, caches, out), None

        codes0 = jnp.zeros((bs, seq, payload_d), payload_dtype)
        scale0 = jnp.zeros((bs, seq, 1), jnp.float32)
        out0 = jnp.zeros((n_micro + 1, bs, n_vocab_out), jnp.float32)
        (_, _, caches, out), _ = jax.lax.scan(
            iter_body, (codes0, scale0, caches, out0),
            jnp.arange(n_micro + 1))
        logits = out[:n_micro].reshape(b, n_vocab_out)
        # only the cloud stage holds real logits → replicate via masked psum
        logits = jax.lax.psum(jnp.where(stage == 1, logits, 0.0), "pod")
        if cfg.num_codebooks > 1:
            logits = logits.reshape(b, cfg.num_codebooks, cfg.vocab_size)
        return jnp.argmax(logits, axis=-1)[:, None], caches

    return fn


def pipeline_decode_sharded(cfg: ArchConfig, opts: RuntimeOpts, mesh,
                            n_micro: int, payload_bits: int = 16,
                            prefill: bool = False):
    """shard_map wrapper: blocks/caches sharded over 'pod' (stage dim 0);
    everything else replicated across pods ('data'/'model' stay auto)."""
    fn = make_pipeline_decode_step(cfg, opts, n_micro, payload_bits, prefill)

    def blocks_spec(tree):
        return jax.tree_util.tree_map(lambda _: P("pod"), tree)

    def wrapped(blocks, other_params, tokens, caches, pos):
        return shard_map(
            fn,
            mesh=mesh,
            in_specs=(blocks_spec(blocks), jax.tree_util.tree_map(
                lambda _: P(), other_params), P(), blocks_spec(caches), P()),
            out_specs=(P(), blocks_spec(caches)),
            # manual over 'pod' only; any other mesh axes stay under GSPMD
            auto=frozenset(mesh.axis_names) - {"pod"},
            check_rep=False,
        )(blocks, other_params, tokens, caches, pos)

    return wrapped
