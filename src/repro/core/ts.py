"""Threshold Splitting (paper §2.3.1, Eq. 4) and Eq. (7) recovery.

TS partitions the split-layer activation T into
  T_above = T ⊙ M   (|T| ≥ τ — tiny, accuracy-critical, kept exact)
  T_below = T ⊙ (1-M)
The paper CSR-codes T_above on GPU. TPUs have no efficient dynamic-sparsity
format, so the *carrier* here is a fixed-capacity (values, indices, count)
triple (dense, shardable, jit-able) while the *byte accounting* still uses
the CSR formula so the paper's Fig. 6/7 numbers reproduce. Capacity defaults
to numel/1024 — the paper measures ~0.0005 % of entries above τ=100 and a few
percent above τ=1; capacity is a config knob and overflow falls back to
keeping the largest-|.| entries (exactly the right ones to keep).
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp


@dataclasses.dataclass
class SparseAbove:
    """Fixed-capacity sparse carrier for T_above (a pytree)."""

    values: jax.Array  # (capacity,)
    indices: jax.Array  # (capacity,) flat int32 indices; invalid slots = -1
    count: jax.Array  # () int32 — true nnz (may exceed capacity; clipped)
    shape: tuple  # original dense shape (static)

    def csr_bytes(self, rows: int | None = None, value_bytes: int = 4) -> jax.Array:
        """Paper's CSR accounting: nnz*(value + colidx) + (rows+1)*rowptr."""
        rows = rows if rows is not None else (self.shape[0] if len(self.shape) > 1 else 1)
        nnz = jnp.minimum(self.count, self.values.shape[0])
        return nnz * (value_bytes + 4) + (rows + 1) * 4


jax.tree_util.register_pytree_node(
    SparseAbove,
    lambda s: ((s.values, s.indices, s.count), s.shape),
    lambda shape, ch: SparseAbove(ch[0], ch[1], ch[2], shape),
)


def split_dense(t: jax.Array, tau: float):
    """Eq. (4) in dense form: (T_above, T_below, M)."""
    m = (jnp.abs(t) >= tau).astype(t.dtype)
    return t * m, t * (1.0 - m), m


@partial(jax.jit, static_argnames=("capacity",))
def ts_encode(t: jax.Array, tau: float, capacity: int):
    """Threshold-split ``t``: returns (t_below, SparseAbove).

    Keeps the ``capacity`` largest-magnitude entries that exceed τ (top-k is
    jit-able and deterministic; if nnz > capacity the kept set is exactly the
    most accuracy-critical subset per the paper's Fig. 4 argument).
    """
    flat = t.reshape(-1)
    mag = jnp.abs(flat)
    count = jnp.sum(mag >= tau).astype(jnp.int32)
    top_vals_mag, top_idx = jax.lax.top_k(mag, capacity)
    valid = top_vals_mag >= tau
    idx = jnp.where(valid, top_idx, -1)
    vals = jnp.where(valid, flat[top_idx], 0.0)
    # zero the extracted slots (top_idx entries are unique; invalid slots
    # degrade to a no-op multiply at index 0)
    safe_idx = jnp.where(valid, top_idx, 0)
    below = flat.at[safe_idx].multiply(jnp.where(valid, 0.0, 1.0))
    return below.reshape(t.shape), SparseAbove(vals, idx, count, tuple(t.shape))


@jax.jit
def ts_decode(above: SparseAbove) -> jax.Array:
    """Densify T_above (used by Eq. 7 on the 'cloud' side)."""
    import math

    flat = jnp.zeros(math.prod(above.shape), above.values.dtype)
    safe_idx = jnp.where(above.indices >= 0, above.indices, 0)
    contrib = jnp.where(above.indices >= 0, above.values, 0.0)
    flat = flat.at[safe_idx].add(contrib)
    return flat.reshape(above.shape)


def reconstruct(below_dequant: jax.Array, above: SparseAbove) -> jax.Array:
    """Eq. (7): T̃ = dequant(T̂_below) + T_above  (above slots overwrite)."""
    dense_above = ts_decode(above)
    mask = dense_above != 0.0
    return jnp.where(mask, dense_above, below_dequant)
