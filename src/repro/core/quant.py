"""Integer quantization primitives (paper §2.3.2, Eq. 5-6).

Implements the paper's asymmetric integer quantization (AIQ) exactly as
written — note the paper's convention ``Q_max = 2^(Q-1) - 1`` (one bit is
reserved for the sign in the TAB-Q pipeline, so AIQ quantizes magnitudes) —
plus the symmetric per-channel / group-wise weight quantizers used by OPSC
(§2.1) and the Atom-lite baseline (outlier channels in int8, rest int4).
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

_EPS = 1e-8


def qmax_for_bits(bits) -> jax.Array:
    """Paper Eq. (6): Q_max = 2^(Q-1) - 1."""
    return (2 ** (jnp.asarray(bits, jnp.int32) - 1) - 1).astype(jnp.float32)


# ---------------------------------------------------------------------------
# Asymmetric integer quantization — Eq. (5)-(6)
# ---------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("axis",))
def aiq(t: jax.Array, bits: jax.Array, axis: int | None = None):
    """Asymmetric integer quantization of ``t`` at ``bits`` bits.

    Eq. (5)-(6):  s = (T_max - T_min) / Q_max,  z = ceil(T_min / s),
                  T_hat = round(T / s + z)   (so dequant = (T_hat - z) * s).

    ``axis``: reduction axis for min/max (``None`` = whole tensor, ``-1`` =
    per-token when ``t`` is (tokens, features)).  ``bits`` may be a scalar or
    broadcastable per-token array (used by TAB-Q).

    Returns (codes f32-valued integers, scale, zero).
    """
    if axis is None:
        t_min = jnp.min(t)
        t_max = jnp.max(t)
    else:
        t_min = jnp.min(t, axis=axis, keepdims=True)
        t_max = jnp.max(t, axis=axis, keepdims=True)
    qmax = qmax_for_bits(bits)
    s = (t_max - t_min) / jnp.maximum(qmax, 1.0)
    s = jnp.where(jnp.abs(s) < _EPS, _EPS, s)
    z = jnp.ceil(t_min / s)
    codes = jnp.round(t / s + z)
    # valid code range: the paper's z sits *inside* the rounding, so codes
    # span [round(t_min/s + z), +Q_max] (2^(Q-1) distinct values)
    c_lo = jnp.round(t_min / s + z)
    codes = jnp.clip(codes, c_lo, c_lo + qmax)
    return codes, s, z


def aiq_dequant(codes: jax.Array, s: jax.Array, z: jax.Array) -> jax.Array:
    """Eq. (7) dense part: (T_hat - z) * s."""
    return (codes - z) * s


# ---------------------------------------------------------------------------
# Symmetric per-channel weight quantization (OPSC front/back segments)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class QuantizedTensor:
    """An int-quantized tensor + metadata. A pytree."""

    codes: jax.Array  # int8 carrier (int4 values also live in int8)
    scale: jax.Array  # f32, broadcastable against codes
    bits: int  # static
    shape: tuple  # original shape (static)

    def dequantize(self, dtype=jnp.float32) -> jax.Array:
        return (self.codes.astype(jnp.float32) * self.scale).astype(dtype)

    @property
    def nbytes(self) -> int:
        import numpy as np

        return int(np.prod(self.shape)) * self.bits // 8 + self.scale.size * 4


jax.tree_util.register_pytree_node(
    QuantizedTensor,
    lambda qt: ((qt.codes, qt.scale), (qt.bits, qt.shape)),
    lambda aux, ch: QuantizedTensor(ch[0], ch[1], aux[0], aux[1]),
)


def quantize_sym(w: jax.Array, bits: int, axis: int | None = -1) -> QuantizedTensor:
    """Symmetric per-channel quantization: codes in [-(2^(b-1)-1), 2^(b-1)-1]."""
    qmax = float(2 ** (bits - 1) - 1)
    if axis is None:
        amax = jnp.max(jnp.abs(w))
    else:
        amax = jnp.max(jnp.abs(w), axis=axis, keepdims=True)
    scale = jnp.maximum(amax, _EPS) / qmax
    carrier = jnp.int8 if bits <= 8 else jnp.int32
    codes = jnp.clip(jnp.round(w / scale), -qmax, qmax).astype(carrier)
    return QuantizedTensor(codes, scale.astype(jnp.float32), bits, tuple(w.shape))


def quantize_groupwise(w: jax.Array, bits: int, group: int = 128) -> QuantizedTensor:
    """Group-wise symmetric quantization along dim 0 (in-features).

    Atom-style: each ``group`` consecutive input channels share a scale.
    ``w``: (in, out).  Pads the in-dim if not divisible.
    """
    din, dout = w.shape
    pad = (-din) % group
    wp = jnp.pad(w, ((0, pad), (0, 0)))
    g = wp.reshape(-1, group, dout)
    qmax = float(2 ** (bits - 1) - 1)
    amax = jnp.max(jnp.abs(g), axis=1, keepdims=True)
    scale = jnp.maximum(amax, _EPS) / qmax
    codes = jnp.clip(jnp.round(g / scale), -qmax, qmax)
    codes = codes.reshape(din + pad, dout)[:din].astype(jnp.int8)
    scale = jnp.repeat(scale, group, axis=1).reshape(din + pad, dout)[:din]
    # store one scale per (group, out) — keep broadcast form compact:
    scale_c = scale[::group][: (din + group - 1) // group]
    return QuantizedTensor(codes, scale_c.repeat(group, 0)[:din], bits, (din, dout))


# ---------------------------------------------------------------------------
# int4 packing (two nibbles per int8 byte) — storage for OPSC front weights
# ---------------------------------------------------------------------------


def pack_int4(codes: jax.Array) -> jax.Array:
    """Pack signed int4 values (range [-7,7]) pair-wise into int8."""
    flat = codes.reshape(-1)
    if flat.shape[0] % 2:
        flat = jnp.pad(flat, (0, 1))
    lo = (flat[0::2].astype(jnp.int32) & 0xF)
    hi = (flat[1::2].astype(jnp.int32) & 0xF) << 4
    return (lo | hi).astype(jnp.uint8)


def unpack_int4(packed: jax.Array, n: int) -> jax.Array:
    """Inverse of :func:`pack_int4`; returns int8 values, length ``n``."""
    p = packed.astype(jnp.int32)
    lo = p & 0xF
    hi = (p >> 4) & 0xF
    vals = jnp.stack([lo, hi], axis=-1).reshape(-1)[:n]
    # sign-extend 4-bit two's complement
    vals = jnp.where(vals >= 8, vals - 16, vals)
    return vals.astype(jnp.int8)


# ---------------------------------------------------------------------------
# Baseline quantizers for Table 3 comparison (lite re-implementations)
# ---------------------------------------------------------------------------


def smoothquant_lite(w: jax.Array, act_absmax: jax.Array, bits_w: int, alpha: float = 0.5):
    """SmoothQuant: migrate activation outliers into weights via per-channel
    smoothing s_j = absmax_act_j^alpha / absmax_w_j^(1-alpha), then per-tensor
    int quantization.  Returns (QuantizedTensor of W*s, smoothing vector)."""
    w_absmax = jnp.maximum(jnp.max(jnp.abs(w), axis=1), _EPS)
    s = jnp.maximum(act_absmax, _EPS) ** alpha / w_absmax ** (1.0 - alpha)
    s = jnp.maximum(s, _EPS)
    qt = quantize_sym(w * s[:, None], bits_w, axis=None)  # per-tensor (E1 is static)
    return qt, s


def omniquant_lite(w: jax.Array, bits: int, clip_grid=(1.0, 0.9, 0.8, 0.7, 0.6)):
    """OmniQuant-lite: grid-search a clipping ratio minimizing MSE, per-channel."""
    best = None
    for c in clip_grid:
        qmax = float(2 ** (bits - 1) - 1)
        amax = jnp.max(jnp.abs(w), axis=-1, keepdims=True) * c
        scale = jnp.maximum(amax, _EPS) / qmax
        codes = jnp.clip(jnp.round(w / scale), -qmax, qmax)
        err = jnp.mean((codes * scale - w) ** 2, axis=-1, keepdims=True)
        if best is None:
            best = (err, codes, scale)
        else:
            berr, bcodes, bscale = best
            take = err < berr
            best = (
                jnp.where(take, err, berr),
                jnp.where(take, codes, bcodes),
                jnp.where(take, scale, bscale),
            )
    _, codes, scale = best
    return QuantizedTensor(codes.astype(jnp.int8), scale, bits, tuple(w.shape))


def atom_lite(w: jax.Array, bits_low: int = 4, outlier_frac: float = 1 / 128, group: int = 128):
    """Atom-lite: keep the highest-|.|-norm input channels in int8, quantize the
    rest group-wise at ``bits_low``.  Returns (low QuantizedTensor with outlier
    channels zeroed, outlier QuantizedTensor int8, outlier channel mask)."""
    din = w.shape[0]
    n_out = max(1, int(din * outlier_frac))
    norms = jnp.sum(jnp.abs(w), axis=1)
    thresh = jnp.sort(norms)[-n_out]
    mask = norms >= thresh  # (din,) outlier channels
    w_low = jnp.where(mask[:, None], 0.0, w)
    w_out = jnp.where(mask[:, None], w, 0.0)
    q_low = quantize_groupwise(w_low, bits_low, group)
    q_out = quantize_sym(w_out, 8, axis=-1)
    return q_low, q_out, mask


def dequant_atom(q_low: QuantizedTensor, q_out: QuantizedTensor, mask: jax.Array):
    return jnp.where(mask[:, None], q_out.dequantize(), q_low.dequantize())
