"""Core algorithms from the paper: OPSC, TS, TAB-Q, channel model, unified
split optimization, early exit, and the stage-boundary payload codec."""

from repro.core.channel import (ChannelConfig, LatencyModel, g, optimal_rate,
                                outage_probability, worst_case_latency)
from repro.core.early_exit import (EarlyExitController, EarlyExitDecision,
                                   default_payload_bits_fn)
from repro.core.opsc import (OPSCConfig, edge_weight_memory_bytes,
                             kv_cache_bytes, payload_bytes,
                             quantize_front_params, ssm_state_bytes,
                             weight_memory_bytes)
from repro.core.payload import Payload, decode, encode, encode_decode_ste
from repro.core.quant import (QuantizedTensor, aiq, aiq_dequant, atom_lite,
                              omniquant_lite, pack_int4, quantize_groupwise,
                              quantize_sym, smoothquant_lite, unpack_int4)
from repro.core.sampling import (SamplingParams, broadcast_params,
                                 device_operands, sample_tokens,
                                 sampling_operands, truncate_at_stop)
from repro.core.split_optimizer import (SplitSearchSpace, SplitSolution,
                                        optimize_split, psi)
from repro.core.tabq import TabQResult, tabq, tabq_fixed
from repro.core.ts import SparseAbove, reconstruct, split_dense, ts_decode, ts_encode

__all__ = [n for n in dir() if not n.startswith("_")]
