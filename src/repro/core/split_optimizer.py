"""Unified split/quantization optimization (paper §2.4.1, Eq. 8).

Enumerates (ℓ_w, Q^w, Q^a) over discrete candidate sets, keeps configurations
satisfying the accuracy bound (8b) and the memory bound (8c), and returns the
one maximizing total activation precision Ψ(Q^a) = Σ_k Q_{a,k}.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Callable, Sequence

from repro.core.opsc import (OPSCConfig, activation_bits_per_layer,
                             edge_weight_memory_bytes, kv_cache_bytes)


@dataclasses.dataclass
class SplitSearchSpace:
    split_layers: Sequence[int]
    qw_bits: Sequence[int] = (4, 8, 16)
    qa_bits: Sequence[int] = (2, 4, 8, 16)


@dataclasses.dataclass
class SplitSolution:
    config: OPSCConfig
    psi: int  # Ψ(Q^a)
    memory_bytes: int
    accuracy: float


def psi(num_layers: int, ell: int, qa_front: int, qa_back: int) -> int:
    """Ψ(Q^a) = Σ_k Q_{a,k}."""
    return sum(activation_bits_per_layer(num_layers, ell, qa_front, qa_back))


def optimize_split(
    *,
    num_layers: int,
    layer_param_counts: Sequence[int],
    embed_params: int,
    kv_heads_dim: int,
    max_tokens: int,  # W̄ — fixed per §2.4.1 ("the edge must fit the full length")
    memory_budget_bytes: int,  # M
    accuracy_fn: Callable[[OPSCConfig], float],  # A(ℓ, Q^w, Q^a)
    base_accuracy: float,  # A_base
    accuracy_drop: float,  # A_Δ
    space: SplitSearchSpace | None = None,
) -> SplitSolution | None:
    """Solve Eq. (8) by enumeration (the paper's prescribed approach).

    ``accuracy_fn`` evaluates a candidate configuration (on the validation
    vehicle); callers may memoize it — the loop visits each (ℓ, Q^w, Q^a)
    once, cheapest-to-check constraints first (memory before accuracy)."""
    space = space or SplitSearchSpace(split_layers=range(1, num_layers))
    best: SplitSolution | None = None
    for ell, qw1, qw2, qa1, qa2 in itertools.product(
        space.split_layers, space.qw_bits, space.qw_bits, space.qa_bits, space.qa_bits
    ):
        cfg = OPSCConfig(split_layer=ell, qw_front=qw1, qw_back=qw2,
                         qa_front=qa1, qa_back=qa2)
        # (8c): edge weights + KV cache at the maximum sequence length W̄
        mem = edge_weight_memory_bytes(layer_param_counts, ell, qw1, embed_params)
        mem += kv_cache_bytes(max_tokens, ell, num_layers, kv_heads_dim, qa1, qa2)
        if mem > memory_budget_bytes:
            continue
        cand_psi = psi(num_layers, ell, qa1, qa2)
        if best is not None and cand_psi <= best.psi:
            continue  # cannot improve Ψ — skip the (expensive) accuracy check
        acc = accuracy_fn(cfg)
        if acc < base_accuracy - accuracy_drop:  # (8b)
            continue
        best = SplitSolution(cfg, cand_psi, mem, acc)
    return best
