"""Stage-boundary payload codec: TS + TAB-Q (paper §2.3, Fig. 3 pipeline).

``encode`` and ``decode`` are jit-able and differentiable-free (used at
inference); ``encode_ste`` provides a straight-through variant so the codec
can sit inside a training graph (QAT-style ablations).

Payload accounting matches the paper: T_above is CSR-accounted, T_below is
per-token adaptive bits (+ per-token scale/zero/bitwidth sideband), and an
optional analytical rANS bound (Shannon entropy of the code stream) reports
what the paper's DietGPU stage would add — see DESIGN.md §2 for why the
entropy coder itself is not executed on TPU.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.core.tabq import TabQResult, tabq, tabq_fixed
from repro.core.ts import SparseAbove, reconstruct, ts_encode


@dataclasses.dataclass
class Payload:
    """What crosses the split boundary (a pytree)."""

    below: TabQResult
    above: SparseAbove

    def payload_bits(self) -> jax.Array:
        return self.below.payload_bits() + self.above.csr_bytes() * 8


jax.tree_util.register_pytree_node(
    Payload,
    lambda p: ((p.below, p.above), None),
    lambda _, ch: Payload(*ch),
)


@partial(jax.jit, static_argnames=("max_bits", "capacity", "fixed_bits"))
def encode(t: jax.Array, *, tau: float = 5.0, delta: float = 0.2, max_bits: int = 8,
           capacity: int | None = None, fixed_bits: int | None = None) -> Payload:
    """TS then TAB-Q.  ``t``: (tokens, D).  ``fixed_bits`` bypasses the
    adaptive search (Algorithm 2's budget-dictated fallback)."""
    tokens, d = t.shape
    capacity = capacity if capacity is not None else max(16, (tokens * d) // 1024)
    below, above = ts_encode(t, tau, capacity)
    if fixed_bits is not None:
        q = tabq_fixed(below, fixed_bits)
    else:
        q = tabq(below, max_bits=max_bits, delta=delta)
    return Payload(q, above)


@jax.jit
def decode(p: Payload) -> jax.Array:
    """Eq. (7): dequantize T_below, reinstate T_above."""
    below = p.below.dequantize()
    return reconstruct(below, p.above)


def encode_decode_ste(t: jax.Array, **kw) -> jax.Array:
    """Straight-through encode→decode (gradient = identity)."""
    out = decode(encode(jax.lax.stop_gradient(t), **kw))
    return t + jax.lax.stop_gradient(out - t)


def entropy_bound_bits(q: TabQResult, n_bins: int = 256) -> jax.Array:
    """Shannon bound for an rANS pass over the magnitude codes (analytical
    stand-in for the paper's DietGPU stage)."""
    # codes ride an int8 carrier (rebased to [0, Q_max]); widen before the
    # clip so the n_bins-1 bound can't wrap the narrow dtype
    codes = jnp.clip(q.codes.reshape(-1).astype(jnp.int32), 0, n_bins - 1)
    hist = jnp.zeros(n_bins).at[codes].add(1.0)
    p = hist / jnp.maximum(jnp.sum(hist), 1.0)
    h = -jnp.sum(jnp.where(p > 0, p * jnp.log2(jnp.maximum(p, 1e-12)), 0.0))
    return h * codes.shape[0] + q.bits.shape[0] * (64 + 8)
