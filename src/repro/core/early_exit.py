"""Early-exit controller under delay constraints (paper Algorithm 2).

Host-side control loop (see DESIGN.md §2 — XLA programs cannot branch on
wall-clock latency, so decisions are made between jitted steps and select
among pre-compiled step variants). Faithful to Algorithm 2's escalation
ladder for each generated token:

  1. try shipping at the memory-optimal precision Q̄^a;
  2. if L_t > D → apply TAB-Q payload compression;
  3. still over → drop the KV cache from the payload (I_kv ← 0) and ship the
     compressed hidden state only;
  4. still over → reduce the token count (generate fewer tokens) — early exit.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

from repro.core.channel import LatencyModel, worst_case_latency
from repro.core.opsc import OPSCConfig, payload_bytes


@dataclasses.dataclass
class EarlyExitDecision:
    w: int  # tokens actually generated
    i_kv: int  # final KV-transmission switch
    compressed: bool  # whether TAB-Q compression was engaged
    latency_s: float  # modeled worst-case total latency
    exited_early: bool


@dataclasses.dataclass
class EarlyExitController:
    """Algorithm 2. ``payload_bits_fn(w, i_kv, compressed)`` returns the
    modeled payload size in bits (TS+TAB-Q accounting when compressed)."""

    opsc: OPSCConfig
    latency: LatencyModel
    deadline_s: float  # D
    num_layers: int
    payload_bits_fn: Callable[[int, int, bool], float]

    def _lat(self, w: int, i_kv: int, compressed: bool) -> float:
        bits = self.payload_bits_fn(w, i_kv, compressed)
        return self.latency.total_latency(w, self.opsc.split_layer, bits)

    def decide(self, w_max: int) -> EarlyExitDecision:
        """Run Algorithm 2 for a target of ``w_max`` tokens."""
        i_kv = self.opsc.i_kv
        # line 9-10: uncompressed at the chosen precision
        lat = self._lat(w_max, i_kv, compressed=False)
        if lat <= self.deadline_s:
            return EarlyExitDecision(w_max, i_kv, False, lat, False)
        # line 11-14: engage TAB-Q compression
        lat = self._lat(w_max, i_kv, compressed=True)
        if lat <= self.deadline_s:
            return EarlyExitDecision(w_max, i_kv, True, lat, False)
        # line 16-18: drop the KV cache from the payload
        i_kv = 0
        lat = self._lat(w_max, i_kv, compressed=True)
        if lat <= self.deadline_s:
            return EarlyExitDecision(w_max, i_kv, True, lat, False)
        # line 19-24: reduce token count until the deadline holds
        w = w_max
        while w > 1 and lat > self.deadline_s:
            w -= 1
            lat = self._lat(w, i_kv, compressed=True)
        return EarlyExitDecision(w, i_kv, True, lat, True)


def solve_depth_objective(latency: LatencyModel, payload_bits_fn,
                          deadline_s: float, w_max: int, num_layers: int,
                          i_kv: int = 1, compressed: bool = True):
    """Paper Eq. (12): maximize the inference depth w·ℓ subject to
    L_t(w, ℓ) ≤ D — solved by enumeration over the (w, ℓ) grid (both sets are
    small and discrete; the paper prescribes direct search).

    ``payload_bits_fn(w, ell, i_kv, compressed)`` → payload bits at (w, ℓ).
    Returns (w*, ℓ*, latency_s) or None if even (1, 1) violates D."""
    best = None
    for ell in range(1, num_layers + 1):
        # L_t is monotone in w at fixed ℓ → binary search the largest w
        def lat_at(w):
            bits = payload_bits_fn(w, ell, i_kv, compressed)
            return (latency.compute_per_token_s * ell
                    + worst_case_latency(bits, latency.rate, latency.channel))

        lo, hi = 0, w_max
        while lo < hi:
            mid = (lo + hi + 1) // 2
            if lat_at(mid) <= deadline_s:
                lo = mid
            else:
                hi = mid - 1
        if lo >= 1 and (best is None or lo * ell > best[0] * best[1]):
            best = (lo, ell, lat_at(lo))
    return best


def default_payload_bits_fn(opsc: OPSCConfig, num_layers: int, kv_heads_dim: int,
                            hidden_dim: int, compression_ratio: float = 4.0):
    """Analytical payload model: Eq. (3) bytes, divided by the measured
    TS+TAB-Q compression ratio when compression is engaged."""

    def fn(w: int, i_kv: int, compressed: bool) -> float:
        raw = payload_bytes(w, opsc.split_layer, num_layers, kv_heads_dim,
                            hidden_dim, opsc.qa_front, opsc.qa_back, i_kv) * 8.0
        return raw / compression_ratio if compressed else raw

    return fn
