"""ε-outage wireless channel model (paper §2.4.2, Eq. 9-10, 13).

Pure analytical math — hardware-independent, kept verbatim from the paper.
On the TPU mapping this models the scarce cross-boundary link (see DESIGN.md
§2); in the edge-cloud serving simulation it models the real uplink.

  P_o(R)          = 1 - exp(-(2^{R/W} - 1)/γ)                  (Eq. 10)
  L_ε(D_tx; R)    = D_tx / R · ⌈ln ε / ln P_o(R)⌉              (Eq. 9)
  g(R)            = ln(1/P_o(R)) / R,  R* = argmin g(R)        (Eq. 13)

Units: R in bits/s, W in Hz, D_tx in bits, latency in seconds.
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np


@dataclasses.dataclass(frozen=True)
class ChannelConfig:
    bandwidth_hz: float = 10e6  # W  (paper: 10 MHz)
    snr: float = 10.0  # γ  (paper: 10)
    epsilon: float = 1e-3  # ε  (paper: 0.001)
    r_min: float = 1e5  # feasible rate interval [R_, R̄] (bits/s)
    r_max: float = 200e6


def outage_probability(rate: float, cfg: ChannelConfig) -> float:
    """Eq. (10)."""
    snr_needed = 2.0 ** (rate / cfg.bandwidth_hz) - 1.0
    return 1.0 - math.exp(-snr_needed / cfg.snr)


def worst_case_latency(d_tx_bits: float, rate: float, cfg: ChannelConfig) -> float:
    """Eq. (9): worst-case latency to deliver ``d_tx_bits`` at outage ε.

    The ceil term is the number of (re)transmissions needed so the residual
    failure probability drops below ε."""
    p_o = outage_probability(rate, cfg)
    p_o = min(max(p_o, 1e-300), 1.0 - 1e-12)
    n_tx = math.ceil(math.log(cfg.epsilon) / math.log(p_o))
    return d_tx_bits / rate * max(n_tx, 1)


def g(rate: float, cfg: ChannelConfig) -> float:
    """Eq. (13) objective: ln(1/P_o(R)) / R — maximize to minimize latency.

    (Minimizing worst-case latency D/R·ln ε/ln P_o = D·ln(1/ε) / (R·ln(1/P_o))
    ⇔ maximizing R·ln(1/P_o(R)); the paper states it as minimizing
    g(R) = ln(1/P_o(R))/R with the reciprocal objective — we follow the
    latency-minimizing direction and expose both.)"""
    p_o = outage_probability(rate, cfg)
    p_o = min(max(p_o, 1e-300), 1.0 - 1e-12)
    return math.log(1.0 / p_o) / rate


def optimal_rate(cfg: ChannelConfig, n_grid: int = 4096) -> float:
    """Eq. (13): 1-D grid search for R* minimizing worst-case latency."""
    rates = np.geomspace(cfg.r_min, cfg.r_max, n_grid)
    lat = np.array([worst_case_latency(1.0, r, cfg) for r in rates])
    return float(rates[int(np.argmin(lat))])


@dataclasses.dataclass(frozen=True)
class LatencyModel:
    """Eq. (11): L_t = L_c(w) + L_ε(B_io, R) — total per-token edge latency."""

    channel: ChannelConfig
    rate: float  # R* from optimal_rate
    compute_per_token_s: float  # profiled local per-layer-per-token seconds

    def total_latency(self, w: int, ell: int, payload_bits: float) -> float:
        l_c = self.compute_per_token_s * ell  # local compute up to layer ℓ
        return l_c + worst_case_latency(payload_bits, self.rate, self.channel)
