"""Shared on-device batched sampler: per-request temperature / top-k /
top-p with per-request PRNG lanes, in ONE compiled shape.

This is the sampling half of the request-level serving API
(``repro.serving.api``): every backend — the fused ``Engine`` scan, the
paged ``Scheduler`` decode tick, and the ``SplitEngine`` cloud loop —
samples through :func:`sample_tokens`, so a request's token stream is a
function of (its logits, its seed, its generation index) ONLY:

  * every per-request knob is a TRACED per-row operand (``temperature``/
    ``top_p`` f32, ``top_k`` int32, a (2,) uint32 PRNG key per row), so a
    batch mixing greedy, temperature and nucleus requests shares one
    compiled shape — no per-request recompiles, no host round-trip;
  * randomness is keyed per ROW and folded with the row's own generation
    index (``fold_in(key_r, t_r)``), never with a batch-wide step counter —
    a request sampled in slot 3 of a ragged batch draws exactly the bits it
    would draw alone, which is what makes the paged scheduler reproduce the
    fused engine token-for-token under the same per-request seeds;
  * the GREEDY LANE IS EXACT: rows with ``temperature <= 0`` or
    ``top_k == 1`` take a plain ``argmax`` selected by ``jnp.where`` — the
    same integers the pre-sampler host ``np.argmax`` produced, bit for bit
    (the greedy-equivalence regression in ``tests/test_serving_api.py``).

:class:`SamplingParams` (the request-level dataclass the serving API
passes around) lives here rather than in ``serving.api`` so the scheduler
can depend on it without importing the API layer that wraps it.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

# finite mask value: -inf arithmetic breeds NaNs under jnp.where once two
# masked lanes are subtracted; anything below any real logit works
NEG_INF = -1e30

_LATENCY_HINTS = ("interactive", "balanced", "batch")


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request generation parameters — the one knob object of the
    serving API (``repro.serving.api``).

    Defaults are GREEDY and must reproduce the pre-API engines bit for bit
    on every backend (the regression ``tests/test_serving_api.py`` pins it).

    ``temperature <= 0`` or ``top_k == 1`` selects the exact argmax lane;
    ``top_k = 0`` disables the top-k filter, ``top_p = 1.0`` disables the
    nucleus filter. ``stop_token_ids`` and ``eos_id`` together form
    :meth:`stop_set`: generation finishes (reason ``"stop"``) the moment a
    sampled token lands in it, and the output is truncated at that token
    inclusive. ``priority`` orders preemption victims in the paged
    scheduler's lazy mode (lower evicts first); ``prefix_key`` /
    ``prefix_len`` declare a shared prompt prefix exactly like
    ``Scheduler.submit``. ``latency_hint`` feeds the scheduler's adaptive
    prefill chunking (``prefill_chunk="auto"``): ``"interactive"`` pulls
    chunk sizes down while this request decodes (tail latency),
    ``"batch"`` tolerates big chunks (throughput). ``speculate_k`` asks
    the backend to draft up to k tokens per step and verify them in one
    batched model call (:func:`speculative_verify`); 0 disables. Backends
    without a draft source (the fused scan) ignore it. ``logit_bias``
    maps token ids to additive biases applied to the logits BEFORE
    temperature/top-k/top-p — it reshapes the greedy argmax too (ban a
    token with a large negative bias, force one with a large positive
    bias), while reported logprobs stay raw-distribution. Applied by the
    fused and paged backends; the split engine ignores it."""

    max_tokens: int = 16
    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    seed: int = 0
    stop_token_ids: tuple = ()
    eos_id: int | None = None
    priority: int = 0
    prefix_key: object = None
    prefix_len: int | None = None
    latency_hint: str = "balanced"
    speculate_k: int = 0
    logit_bias: object = None

    def __post_init__(self):
        if self.max_tokens < 1:
            raise ValueError(f"max_tokens must be >= 1, got {self.max_tokens}")
        if self.top_k < 0:
            raise ValueError(f"top_k must be >= 0 (0 disables), got {self.top_k}")
        if self.speculate_k < 0:
            raise ValueError(f"speculate_k must be >= 0 (0 disables), "
                             f"got {self.speculate_k}")
        if not 0.0 < self.top_p <= 1.0:
            raise ValueError(f"top_p must be in (0, 1], got {self.top_p}")
        if self.latency_hint not in _LATENCY_HINTS:
            raise ValueError(f"latency_hint must be one of {_LATENCY_HINTS}, "
                             f"got {self.latency_hint!r}")
        # frozen dataclass: normalize via object.__setattr__, and cache the
        # derived stop set once — done() checks it per slot per tick
        object.__setattr__(self, "stop_token_ids",
                           tuple(int(t) for t in self.stop_token_ids))
        s = frozenset(self.stop_token_ids)
        if self.eos_id is not None:
            s |= {int(self.eos_id)}
        object.__setattr__(self, "_stop_set", s)
        # logit_bias (a dict or (token, bias) pairs) normalizes to a SORTED
        # tuple of (int, float) pairs — hashable (frozen dataclass) and
        # order-independent (two dicts with the same entries compare equal)
        lb = self.logit_bias
        if lb:
            items = lb.items() if hasattr(lb, "items") else lb
            lb = tuple(sorted((int(t), float(b)) for t, b in items))
            for tid, _ in lb:
                if tid < 0:
                    raise ValueError(
                        f"logit_bias token ids must be >= 0, got {tid}")
        else:
            lb = ()
        object.__setattr__(self, "logit_bias", lb)

    @property
    def greedy(self) -> bool:
        """Whether this request takes the exact-argmax lane."""
        return self.temperature <= 0.0 or self.top_k == 1

    @property
    def stop_set(self) -> frozenset:
        """Tokens that finish the request (``eos_id`` included)."""
        return self._stop_set


def sampling_operands(params_list) -> dict:
    """Stack a list of :class:`SamplingParams` into the per-row device
    operands :func:`sample_tokens` consumes: ``keys`` (R, 2) uint32 (one
    ``PRNGKey(seed)`` per row), ``temperature``/``top_p`` (R,) f32,
    ``top_k`` (R,) int32. Host-side numpy — callers move them to device
    inside their own jit boundaries."""
    return {
        "keys": np.stack([np.asarray(jax.random.PRNGKey(p.seed), np.uint32)
                          for p in params_list]),
        "temperature": np.asarray([p.temperature for p in params_list],
                                  np.float32),
        "top_k": np.asarray([p.top_k for p in params_list], np.int32),
        "top_p": np.asarray([p.top_p for p in params_list], np.float32),
    }


def broadcast_params(sampling, batch: int) -> list:
    """Normalize a per-batch ``sampling`` argument — one
    :class:`SamplingParams` (applied to every row) or a sequence of
    ``batch`` — into a validated list. The one place the broadcast rule
    lives for every backend."""
    lst = [sampling] * batch if isinstance(sampling, SamplingParams) \
        else list(sampling)
    if len(lst) != batch:
        raise ValueError(f"need one SamplingParams per row: got {len(lst)} "
                         f"for batch {batch}")
    return lst


def device_operands(params_list) -> tuple:
    """:func:`sampling_operands` as device arrays, in
    :func:`sample_tokens` argument order: (keys, temperature, top_k,
    top_p)."""
    o = sampling_operands(params_list)
    return (jnp.asarray(o["keys"]), jnp.asarray(o["temperature"]),
            jnp.asarray(o["top_k"]), jnp.asarray(o["top_p"]))


def bias_rows(params_list, vocab_size: int) -> np.ndarray:
    """Dense (R, V) f32 logit-bias operand: row r scatters
    ``params_list[r].logit_bias`` into a zero vocab row. A DENSE row per
    request (rather than a ragged id list) is what keeps the sampler at one
    compiled shape — an all-zero row is the exact identity (``x + 0.0``),
    so requests without a bias are untouched bit for bit. Host-side numpy;
    callers move it to device inside their own jit boundaries."""
    rows = np.zeros((len(params_list), vocab_size), np.float32)
    for i, p in enumerate(params_list):
        for tid, b in p.logit_bias:
            if tid >= vocab_size:
                raise ValueError(f"logit_bias token id {tid} out of range "
                                 f"for vocab size {vocab_size}")
            rows[i, tid] = b
    return rows


def truncate_at_stop(tokens, params: SamplingParams) -> tuple:
    """Truncate ``tokens`` at the first stop-set token (INCLUSIVE) →
    ``(tokens as a python int list, finish_reason)`` with reason ``"stop"``
    when a stop token fired, ``"length"`` otherwise. The one output-shaping
    rule shared by every backend (``serving.api`` replay truncation and
    the paged scheduler's eviction) — change it here, not per backend."""
    toks = [int(tok) for tok in tokens]
    stop = params.stop_set
    if stop:
        for j, tok in enumerate(toks):
            if tok in stop:
                return toks[: j + 1], "stop"
    return toks, "length"


def filtered_logits(logits, temperature, top_k, top_p):
    """Temperature-scale ``logits`` (R, V) and mask everything outside the
    intersection of the per-row top-k and nucleus sets to ``NEG_INF`` (ties
    at either cutoff are kept — at least the argmax token always survives).
    This IS the non-greedy sampling distribution: ``categorical`` over the
    returned array renormalizes implicitly. Factored out of
    :func:`sample_tokens` so :func:`speculative_verify` accepts/rejects
    drafts against the EXACT distribution the non-speculative path samples
    from — any drift here would break the rejection-sampling equivalence.
    Returns (R, V) f32."""
    logits = logits.astype(jnp.float32)
    v = logits.shape[-1]
    safe_t = jnp.where(temperature > 0.0, temperature, 1.0)
    z = logits / safe_t[:, None]
    sz = jnp.flip(jnp.sort(z, axis=-1), axis=-1)  # per-row descending
    # top-k cutoff: k-th largest scaled logit (k=0 disables → keep all)
    k = jnp.where(top_k <= 0, v, jnp.minimum(top_k, v)).astype(jnp.int32)
    kth = jnp.take_along_axis(sz, (k - 1)[:, None], axis=-1)[:, 0]
    # nucleus cutoff: in sorted order keep rows whose EXCLUSIVE
    # cumulative probability is < top_p (the smallest set whose mass
    # reaches top_p; the top-1 token is always kept)
    probs = jax.nn.softmax(sz, axis=-1)
    cum = jnp.cumsum(probs, axis=-1) - probs
    keep = cum < top_p[:, None]
    keep = keep.at[:, 0].set(True)
    n_keep = jnp.sum(keep, axis=-1).astype(jnp.int32)
    pth = jnp.take_along_axis(sz, (n_keep - 1)[:, None], axis=-1)[:, 0]

    cutoff = jnp.maximum(kth, pth)
    return jnp.where(z >= cutoff[:, None], z, NEG_INF)


def sample_tokens(logits, keys, t, temperature, top_k, top_p, bias=None):
    """Sample one token per row, all rows in one compiled shape.

    ``logits`` (R, V) — any float dtype, promoted to f32; ``keys`` (R, 2)
    uint32 per-request PRNG keys; ``t`` (R,) int32 per-row generation index
    (folded into the row's key, so the draw depends on the row's own stream
    position, not on batch composition or a global step counter);
    ``temperature``/``top_p`` (R,) f32; ``top_k`` (R,) int32, 0 = disabled;
    ``bias`` optional (R, V) f32 per-request logit bias
    (:func:`bias_rows`), added BEFORE the greedy argmax and the
    temperature/top-k/top-p filters — an all-zero row is the bitwise
    identity.

    Rows with ``temperature <= 0`` or ``top_k == 1`` return the exact
    ``argmax`` (greedy lane). The rest are filtered to the intersection of
    the top-k and nucleus sets (:func:`filtered_logits`) and sampled from
    the renormalized distribution at their temperature. When EVERY row is
    greedy — the default workload — a ``lax.cond`` skips the
    sort/softmax/categorical arithmetic at runtime entirely (same compiled
    shape, argmax-only cost). Returns (R,) int32."""
    logits = logits.astype(jnp.float32)
    if bias is not None:
        logits = logits + bias
    greedy_tok = jnp.argmax(logits, axis=-1)
    use_greedy = (temperature <= 0.0) | (top_k == 1)

    def non_greedy(_):
        masked = filtered_logits(logits, temperature, top_k, top_p)
        step_keys = jax.vmap(jax.random.fold_in)(
            jnp.asarray(keys, jnp.uint32), jnp.maximum(jnp.asarray(t), 0))
        return jax.vmap(jax.random.categorical)(step_keys, masked)

    sampled = jax.lax.cond(jnp.all(use_greedy),
                           lambda _: greedy_tok, non_greedy, None)
    return jnp.where(use_greedy, greedy_tok, sampled).astype(jnp.int32)


def token_logprobs(logits, tokens):
    """Log-probability of each row's chosen token under the row's RAW
    softmax distribution — untempered and unfiltered, so the value means
    the same thing for greedy and sampled rows and across backends (it is
    the model's confidence in the emitted token, not the probability it
    was drawn with after temperature/top-k/top-p reshaping). ``logits``
    (..., V) any float dtype, ``tokens`` (...) int → (...) f32."""
    lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    return jnp.take_along_axis(lp, tokens[..., None].astype(jnp.int32),
                               axis=-1)[..., 0]


def sample_tokens_with_logprobs(logits, keys, t, temperature, top_k, top_p,
                                bias=None):
    """:func:`sample_tokens` plus each drawn token's :func:`token_logprobs`
    value, in one jittable call — the serving backends fuse this with the
    model step so neither logits nor logprobs round-trip the host
    separately. ``bias`` reshapes the draw only: logprobs stay RAW (the
    unbiased distribution), so the value still reads as the model's own
    confidence in the emitted token. Returns ((R,) int32 tokens, (R,) f32
    logprobs)."""
    toks = sample_tokens(logits, keys, t, temperature, top_k, top_p, bias)
    return toks, token_logprobs(logits, toks)


# PRNG stream tags for the speculative accept / residual draws. The token
# draw at generation index t uses fold_in(key, t) (sample_tokens); the
# accept and residual draws fold a second, distinct constant on top so the
# three streams never collide — re-using the token stream for acceptance
# would correlate "was the draft accepted" with "which token would have
# been drawn", silently biasing the output distribution.
_ACCEPT_TAG = 0x5EC0_0001
_RESIDUAL_TAG = 0x5EC0_0002


def speculative_verify(draft, draft_len, logits, keys, t0,
                       temperature, top_k, top_p, bias=None):
    """Draft-verify acceptance for speculative decoding, all rows in one
    compiled shape — the sampler half of the split-boundary speculation
    loop (``SplitEngine.generate(speculate_k=)`` and the paged scheduler's
    verify ticks).

    ``draft`` (R, K) int32 — each row's proposed tokens (garbage beyond
    ``draft_len``); ``draft_len`` (R,) int32 in [0, K]; ``logits``
    (R, K+1, V) — the VERIFY model's logits, where ``logits[:, j]`` is the
    target distribution for generation index ``t0 + j`` given the prefix
    plus drafts < j (one multi-token model call produces all K+1 rows);
    ``keys``/``temperature``/``top_k``/``top_p`` as in
    :func:`sample_tokens`; ``t0`` (R,) int32 — the generation index of the
    first token emitted by this round.

    GREEDY rows (``temperature <= 0`` or ``top_k == 1``) take exact-match
    acceptance: draft position j is accepted iff it equals
    ``argmax(logits[:, j])``, and every emitted token IS that argmax — so
    the emitted stream is bit-identical to non-speculative greedy decoding
    regardless of what the drafter proposed (a bad draft only costs
    acceptance length, never correctness).

    NON-GREEDY rows take standard rejection sampling against the point-mass
    draft proposal: position j accepts draft d with probability p_j(d)
    under the filtered+tempered target (:func:`filtered_logits` — the
    EXACT distribution :func:`sample_tokens` draws from); the first
    rejected position samples the residual p_j(y)/(1 - p_j(d)) over y ≠ d;
    and when ALL drafts are accepted the bonus token at position
    ``draft_len`` is drawn with ``fold_in(key, t0 + draft_len)`` — the
    very bits :func:`sample_tokens` would use at that generation index, so
    a round with ``draft_len == 0`` degenerates bit-identically to the
    non-speculative draw. Either way each emitted token is marginally
    distributed as the target sampler (the rejection-sampling identity;
    pinned statistically by ``tests/test_speculative_sampling.py``).

    Returns ``(out (R, K+1) int32, n_out (R,) int32, logprobs (R, K+1)
    f32)``: row r emits ``out[r, :n_out[r]]`` (1 <= n_out <= draft_len+1 —
    the accepted prefix, then the correction/bonus token); ``logprobs`` are
    :func:`token_logprobs` under the raw VERIFY logits (never the draft
    model's), valid wherever ``out`` is.

    ``bias`` optional (R, V) f32 per-request logit bias, broadcast over the
    K+1 verify positions and applied before the greedy argmax and the
    filtered target distribution — the exact logits
    :func:`sample_tokens` would bias at each position, so speculative and
    non-speculative biased decoding stay equivalent. Logprobs stay raw
    (unbiased)."""
    raw = jnp.asarray(logits).astype(jnp.float32)
    logits = raw if bias is None else raw + bias[:, None, :]
    r, k1, v = logits.shape
    kd = k1 - 1
    draft = jnp.asarray(draft, jnp.int32)
    draft_len = jnp.asarray(draft_len, jnp.int32)
    keys = jnp.asarray(keys, jnp.uint32)
    t0 = jnp.maximum(jnp.asarray(t0, jnp.int32), 0)

    tgt = jnp.argmax(logits, axis=-1).astype(jnp.int32)  # (R, K+1)
    jpos = jnp.arange(kd, dtype=jnp.int32)
    in_draft = jpos[None, :] < draft_len[:, None]  # (R, K)
    use_greedy = (temperature <= 0.0) | (top_k == 1)

    def leading(accept):
        """Length of the accepted prefix: #leading True in (R, K)."""
        return jnp.sum(jnp.cumprod(accept.astype(jnp.int32), axis=-1),
                       axis=-1).astype(jnp.int32)

    g_m = leading((draft == tgt[:, :kd]) & in_draft)

    def non_greedy(_):
        flat = filtered_logits(
            logits.reshape(r * k1, v),
            jnp.repeat(temperature, k1), jnp.repeat(top_k, k1),
            jnp.repeat(top_p, k1))
        masked = flat.reshape(r, k1, v)
        # per-(row, position) keys: fold_in(key_r, t0_r + j) — the exact
        # sample_tokens stream at each position's generation index
        tj = t0[:, None] + jnp.arange(k1, dtype=jnp.int32)[None, :]
        pos_keys = jax.vmap(
            lambda key, ts: jax.vmap(lambda tt: jax.random.fold_in(key, tt))(ts)
        )(keys, tj)  # (R, K+1, 2)
        fresh = jax.vmap(jax.vmap(jax.random.categorical))(pos_keys, masked)

        if kd == 0:
            return fresh.astype(jnp.int32), jnp.ones((r,), jnp.int32)

        tag = jax.vmap(jax.vmap(jax.random.fold_in, in_axes=(0, None)),
                       in_axes=(0, None))
        # accept draft_j with probability p_j(draft_j) under the filtered
        # sampling distribution (point-mass proposal: q_j = δ_draft)
        probs = jax.nn.softmax(masked[:, :kd], axis=-1)
        p_draft = jnp.take_along_axis(
            probs, draft[..., None], axis=-1)[..., 0]  # (R, K)
        u = jax.vmap(jax.vmap(lambda k: jax.random.uniform(k, ())))(
            tag(pos_keys[:, :kd], _ACCEPT_TAG))
        m = leading((u < p_draft) & in_draft)  # (R,)
        # residual at the first rejection: p_j(y) / (1 - p_j(d)) over y ≠ d
        # (categorical renormalizes the masked logits implicitly)
        d_hot = jax.nn.one_hot(draft, v, dtype=jnp.bool_)
        resid = jax.vmap(jax.vmap(jax.random.categorical))(
            tag(pos_keys[:, :kd], _RESIDUAL_TAG),
            jnp.where(d_hot, NEG_INF, masked[:, :kd]))

        jj = jnp.arange(k1, dtype=jnp.int32)[None, :]
        draft_pad = jnp.pad(draft, ((0, 0), (0, 1)))
        resid_pad = jnp.pad(resid, ((0, 0), (0, 1)))
        rejected = (jj == m[:, None]) & (m < draft_len)[:, None]
        out = jnp.where(jj < m[:, None], draft_pad,
                        jnp.where(rejected, resid_pad, fresh))
        return out.astype(jnp.int32), (m + 1).astype(jnp.int32)

    ng_out, ng_n = jax.lax.cond(
        jnp.all(use_greedy), lambda _: (tgt, g_m + 1), non_greedy, None)
    out = jnp.where(use_greedy[:, None], tgt, ng_out).astype(jnp.int32)
    n_out = jnp.where(use_greedy, g_m + 1, ng_n).astype(jnp.int32)
    return out, n_out, token_logprobs(raw, out)
