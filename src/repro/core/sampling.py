"""Shared on-device batched sampler: per-request temperature / top-k /
top-p with per-request PRNG lanes, in ONE compiled shape.

This is the sampling half of the request-level serving API
(``repro.serving.api``): every backend — the fused ``Engine`` scan, the
paged ``Scheduler`` decode tick, and the ``SplitEngine`` cloud loop —
samples through :func:`sample_tokens`, so a request's token stream is a
function of (its logits, its seed, its generation index) ONLY:

  * every per-request knob is a TRACED per-row operand (``temperature``/
    ``top_p`` f32, ``top_k`` int32, a (2,) uint32 PRNG key per row), so a
    batch mixing greedy, temperature and nucleus requests shares one
    compiled shape — no per-request recompiles, no host round-trip;
  * randomness is keyed per ROW and folded with the row's own generation
    index (``fold_in(key_r, t_r)``), never with a batch-wide step counter —
    a request sampled in slot 3 of a ragged batch draws exactly the bits it
    would draw alone, which is what makes the paged scheduler reproduce the
    fused engine token-for-token under the same per-request seeds;
  * the GREEDY LANE IS EXACT: rows with ``temperature <= 0`` or
    ``top_k == 1`` take a plain ``argmax`` selected by ``jnp.where`` — the
    same integers the pre-sampler host ``np.argmax`` produced, bit for bit
    (the greedy-equivalence regression in ``tests/test_serving_api.py``).

:class:`SamplingParams` (the request-level dataclass the serving API
passes around) lives here rather than in ``serving.api`` so the scheduler
can depend on it without importing the API layer that wraps it.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

# finite mask value: -inf arithmetic breeds NaNs under jnp.where once two
# masked lanes are subtracted; anything below any real logit works
NEG_INF = -1e30

_LATENCY_HINTS = ("interactive", "balanced", "batch")


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request generation parameters — the one knob object of the
    serving API (``repro.serving.api``).

    Defaults are GREEDY and must reproduce the pre-API engines bit for bit
    on every backend (the regression ``tests/test_serving_api.py`` pins it).

    ``temperature <= 0`` or ``top_k == 1`` selects the exact argmax lane;
    ``top_k = 0`` disables the top-k filter, ``top_p = 1.0`` disables the
    nucleus filter. ``stop_token_ids`` and ``eos_id`` together form
    :meth:`stop_set`: generation finishes (reason ``"stop"``) the moment a
    sampled token lands in it, and the output is truncated at that token
    inclusive. ``priority`` orders preemption victims in the paged
    scheduler's lazy mode (lower evicts first); ``prefix_key`` /
    ``prefix_len`` declare a shared prompt prefix exactly like
    ``Scheduler.submit``. ``latency_hint`` feeds the scheduler's adaptive
    prefill chunking (``prefill_chunk="auto"``): ``"interactive"`` pulls
    chunk sizes down while this request decodes (tail latency),
    ``"batch"`` tolerates big chunks (throughput)."""

    max_tokens: int = 16
    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    seed: int = 0
    stop_token_ids: tuple = ()
    eos_id: int | None = None
    priority: int = 0
    prefix_key: object = None
    prefix_len: int | None = None
    latency_hint: str = "balanced"

    def __post_init__(self):
        if self.max_tokens < 1:
            raise ValueError(f"max_tokens must be >= 1, got {self.max_tokens}")
        if self.top_k < 0:
            raise ValueError(f"top_k must be >= 0 (0 disables), got {self.top_k}")
        if not 0.0 < self.top_p <= 1.0:
            raise ValueError(f"top_p must be in (0, 1], got {self.top_p}")
        if self.latency_hint not in _LATENCY_HINTS:
            raise ValueError(f"latency_hint must be one of {_LATENCY_HINTS}, "
                             f"got {self.latency_hint!r}")
        # frozen dataclass: normalize via object.__setattr__, and cache the
        # derived stop set once — done() checks it per slot per tick
        object.__setattr__(self, "stop_token_ids",
                           tuple(int(t) for t in self.stop_token_ids))
        s = frozenset(self.stop_token_ids)
        if self.eos_id is not None:
            s |= {int(self.eos_id)}
        object.__setattr__(self, "_stop_set", s)

    @property
    def greedy(self) -> bool:
        """Whether this request takes the exact-argmax lane."""
        return self.temperature <= 0.0 or self.top_k == 1

    @property
    def stop_set(self) -> frozenset:
        """Tokens that finish the request (``eos_id`` included)."""
        return self._stop_set


def sampling_operands(params_list) -> dict:
    """Stack a list of :class:`SamplingParams` into the per-row device
    operands :func:`sample_tokens` consumes: ``keys`` (R, 2) uint32 (one
    ``PRNGKey(seed)`` per row), ``temperature``/``top_p`` (R,) f32,
    ``top_k`` (R,) int32. Host-side numpy — callers move them to device
    inside their own jit boundaries."""
    return {
        "keys": np.stack([np.asarray(jax.random.PRNGKey(p.seed), np.uint32)
                          for p in params_list]),
        "temperature": np.asarray([p.temperature for p in params_list],
                                  np.float32),
        "top_k": np.asarray([p.top_k for p in params_list], np.int32),
        "top_p": np.asarray([p.top_p for p in params_list], np.float32),
    }


def broadcast_params(sampling, batch: int) -> list:
    """Normalize a per-batch ``sampling`` argument — one
    :class:`SamplingParams` (applied to every row) or a sequence of
    ``batch`` — into a validated list. The one place the broadcast rule
    lives for every backend."""
    lst = [sampling] * batch if isinstance(sampling, SamplingParams) \
        else list(sampling)
    if len(lst) != batch:
        raise ValueError(f"need one SamplingParams per row: got {len(lst)} "
                         f"for batch {batch}")
    return lst


def device_operands(params_list) -> tuple:
    """:func:`sampling_operands` as device arrays, in
    :func:`sample_tokens` argument order: (keys, temperature, top_k,
    top_p)."""
    o = sampling_operands(params_list)
    return (jnp.asarray(o["keys"]), jnp.asarray(o["temperature"]),
            jnp.asarray(o["top_k"]), jnp.asarray(o["top_p"]))


def truncate_at_stop(tokens, params: SamplingParams) -> tuple:
    """Truncate ``tokens`` at the first stop-set token (INCLUSIVE) →
    ``(tokens as a python int list, finish_reason)`` with reason ``"stop"``
    when a stop token fired, ``"length"`` otherwise. The one output-shaping
    rule shared by every backend (``serving.api`` replay truncation and
    the paged scheduler's eviction) — change it here, not per backend."""
    toks = [int(tok) for tok in tokens]
    stop = params.stop_set
    if stop:
        for j, tok in enumerate(toks):
            if tok in stop:
                return toks[: j + 1], "stop"
    return toks, "length"


def sample_tokens(logits, keys, t, temperature, top_k, top_p):
    """Sample one token per row, all rows in one compiled shape.

    ``logits`` (R, V) — any float dtype, promoted to f32; ``keys`` (R, 2)
    uint32 per-request PRNG keys; ``t`` (R,) int32 per-row generation index
    (folded into the row's key, so the draw depends on the row's own stream
    position, not on batch composition or a global step counter);
    ``temperature``/``top_p`` (R,) f32; ``top_k`` (R,) int32, 0 = disabled.

    Rows with ``temperature <= 0`` or ``top_k == 1`` return the exact
    ``argmax`` (greedy lane). The rest are filtered to the intersection of
    the top-k and nucleus sets (ties at either cutoff are kept — at least
    the argmax token always survives) and sampled from the renormalized
    distribution at their temperature. When EVERY row is greedy — the
    default workload — a ``lax.cond`` skips the sort/softmax/categorical
    arithmetic at runtime entirely (same compiled shape, argmax-only
    cost). Returns (R,) int32."""
    logits = logits.astype(jnp.float32)
    r, v = logits.shape
    greedy_tok = jnp.argmax(logits, axis=-1)
    use_greedy = (temperature <= 0.0) | (top_k == 1)

    def non_greedy(_):
        safe_t = jnp.where(temperature > 0.0, temperature, 1.0)
        z = logits / safe_t[:, None]
        sz = jnp.flip(jnp.sort(z, axis=-1), axis=-1)  # per-row descending
        # top-k cutoff: k-th largest scaled logit (k=0 disables → keep all)
        k = jnp.where(top_k <= 0, v, jnp.minimum(top_k, v)).astype(jnp.int32)
        kth = jnp.take_along_axis(sz, (k - 1)[:, None], axis=-1)[:, 0]
        # nucleus cutoff: in sorted order keep rows whose EXCLUSIVE
        # cumulative probability is < top_p (the smallest set whose mass
        # reaches top_p; the top-1 token is always kept)
        probs = jax.nn.softmax(sz, axis=-1)
        cum = jnp.cumsum(probs, axis=-1) - probs
        keep = cum < top_p[:, None]
        keep = keep.at[:, 0].set(True)
        n_keep = jnp.sum(keep, axis=-1).astype(jnp.int32)
        pth = jnp.take_along_axis(sz, (n_keep - 1)[:, None], axis=-1)[:, 0]

        cutoff = jnp.maximum(kth, pth)
        masked = jnp.where(z >= cutoff[:, None], z, NEG_INF)
        step_keys = jax.vmap(jax.random.fold_in)(
            jnp.asarray(keys, jnp.uint32), jnp.maximum(jnp.asarray(t), 0))
        return jax.vmap(jax.random.categorical)(step_keys, masked)

    sampled = jax.lax.cond(jnp.all(use_greedy),
                           lambda _: greedy_tok, non_greedy, None)
    return jnp.where(use_greedy, greedy_tok, sampled).astype(jnp.int32)


def token_logprobs(logits, tokens):
    """Log-probability of each row's chosen token under the row's RAW
    softmax distribution — untempered and unfiltered, so the value means
    the same thing for greedy and sampled rows and across backends (it is
    the model's confidence in the emitted token, not the probability it
    was drawn with after temperature/top-k/top-p reshaping). ``logits``
    (..., V) any float dtype, ``tokens`` (...) int → (...) f32."""
    lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    return jnp.take_along_axis(lp, tokens[..., None].astype(jnp.int32),
                               axis=-1)[..., 0]


def sample_tokens_with_logprobs(logits, keys, t, temperature, top_k, top_p):
    """:func:`sample_tokens` plus each drawn token's :func:`token_logprobs`
    value, in one jittable call — the serving backends fuse this with the
    model step so neither logits nor logprobs round-trip the host
    separately. Returns ((R,) int32 tokens, (R,) f32 logprobs)."""
    toks = sample_tokens(logits, keys, t, temperature, top_k, top_p)
    return toks, token_logprobs(logits, toks)
