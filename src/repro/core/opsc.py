"""OPSC — One-Point Split Compression (paper §2.1-2.2, Eq. 1-3).

Analytical memory/payload models parameterized by an architecture config,
plus the weight-quantization transform that realizes OPSC on a parameter
pytree (front blocks at Q_w1 bits, back blocks at Q_w2 bits).

Conventions (match the paper's Table 1):
  w       — current token index / sequence length generated so far
  ℓ (ell) — split layer: layers 1..ℓ on the edge, ℓ+1..L on the cloud
  Q^w     — {Q_w1 front, Q_w2 back} weight bits
  Q^a     — {Q_a1 front, Q_a2 back} activation (KV-cache / payload) bits
  I_kv    — 1: transmit KV cache, 0: transmit only the hidden state
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class OPSCConfig:
    split_layer: int  # ℓ_w
    qw_front: int = 4  # Q_w1
    qw_back: int = 16  # Q_w2 (cloud side typically keeps high precision)
    qa_front: int = 4  # Q_a1
    qa_back: int = 16  # Q_a2
    i_kv: int = 1
    tau: float = 5.0  # TS threshold (paper default)
    delta: float = 0.2  # TAB-Q distortion tolerance (paper default)
    max_act_bits: int = 8  # Q̄_a


# ---------------------------------------------------------------------------
# Eq. (1): weight memory of the two segments
# ---------------------------------------------------------------------------


def weight_memory_bytes(layer_param_counts, ell: int, qw_front: int, qw_back: int) -> int:
    """M(ℓ_w, Q^w) = Σ_{i≤ℓ} B_w(i;Q_w1) + Σ_{j>ℓ} B_w(j;Q_w2)  [bytes].

    ``layer_param_counts``: per-layer parameter counts, len L (embeddings /
    head counted by the caller at the precision of the segment they sit in).
    """
    front = sum(layer_param_counts[:ell]) * qw_front
    back = sum(layer_param_counts[ell:]) * qw_back
    return (front + back) // 8


def edge_weight_memory_bytes(layer_param_counts, ell: int, qw_front: int,
                             embed_params: int = 0) -> int:
    """Bytes the *edge device* must hold: front segment + embedding table."""
    return (sum(layer_param_counts[:ell]) + embed_params) * qw_front // 8


# ---------------------------------------------------------------------------
# Eq. (2): KV-cache memory as the sequence grows
# ---------------------------------------------------------------------------


def activation_bits_per_layer(num_layers: int, ell: int, qa_front: int, qa_back: int):
    """Q_{a,k} per the paper: Q_a1 for k < ℓ_w, Q_a2 for k ≥ ℓ_w."""
    return [qa_front if k < ell else qa_back for k in range(num_layers)]


def kv_cache_bytes(w: int, ell: int, num_layers: int, heads_dim: int,
                   qa_front: int, qa_back: int) -> int:
    """B_kv(w, ℓ; Q^a), Eq. (2)  [bytes].

    heads_dim = H·D (for GQA this is kv_heads · head_dim — the actual cached
    width; the paper's dense-MHA formula is the special case kv_heads = H).

      2·Σ_{k≤ℓ} T_w·Q_{a,k}  +  2·Σ_{k>ℓ} T_{w-1}·Q_{a,k}  +  H·D·Q_{a,ℓ}
    with T_w = w·H·D.
    """
    qa = activation_bits_per_layer(num_layers, ell, qa_front, qa_back)
    t_w = w * heads_dim
    t_wm1 = (w - 1) * heads_dim
    bits = 2 * sum(t_w * qa[k] for k in range(ell))
    bits += 2 * sum(t_wm1 * qa[k] for k in range(ell, num_layers))
    bits += heads_dim * qa[min(ell, num_layers - 1)]
    return bits // 8


def kv_cache_bytes_shared(w_prefix: int, request_ws, ell: int,
                          num_layers: int, heads_dim: int,
                          qa_front: int, qa_back: int) -> int:
    """Eq. (2) under PREFIX SHARING  [bytes].

    ``request_ws`` are the TOTAL lengths w_r (prefix + suffix + generated)
    of the requests sharing a ``w_prefix``-token materialized prompt
    prefix. The prefix's cache is resident ONCE; each request adds only its
    marginal suffix bytes::

        B_kv_shared = B_kv(w_prefix) + Σ_r [ B_kv(w_r) - B_kv(w_prefix) ]

    (B_kv affine in w makes the marginal exactly the suffix tokens' bytes.)
    This is the analytical counterpart of ``serving.kv_pool``'s refcounted
    pages — what the per-request Eq. (2) sum over-counts under sharing is
    ``(N-1) · B_kv(w_prefix)``, the multi-tenant memory win."""
    base = kv_cache_bytes(w_prefix, ell, num_layers, heads_dim,
                          qa_front, qa_back) if w_prefix > 0 else 0
    total = base
    for w in request_ws:
        if w < w_prefix:
            raise ValueError(f"request length {w} < shared prefix {w_prefix}")
        total += kv_cache_bytes(w, ell, num_layers, heads_dim,
                                qa_front, qa_back) - base
    return total


def ssm_state_bytes(num_ssm_layers: int, state_elems: int, qa_bits: int) -> int:
    """Degenerate Eq. (2) for SSM/hybrid layers: the 'cache' is a fixed-size
    recurrent state (constant in w) — see DESIGN.md §Arch-applicability."""
    return num_ssm_layers * state_elems * qa_bits // 8


# ---------------------------------------------------------------------------
# Eq. (3): intermediate payload crossing the split
# ---------------------------------------------------------------------------


def payload_bytes(w: int, ell: int, num_layers: int, heads_dim: int, hidden_dim: int,
                  qa_front: int, qa_back: int, i_kv: int) -> int:
    """B_io(w, ℓ, I_kv; Q^a), Eq. (3)  [bytes].

    I_kv = 1 → ship the KV cache (B_kv);  I_kv = 0 → ship only the split-layer
    hidden state T_w at Q_{a,ℓ} bits (hidden width = d_model)."""
    if i_kv:
        return kv_cache_bytes(w, ell, num_layers, heads_dim, qa_front, qa_back)
    qa = activation_bits_per_layer(num_layers, ell, qa_front, qa_back)
    return w * hidden_dim * qa[min(ell, num_layers - 1)] // 8


# ---------------------------------------------------------------------------
# OPSC applied to a parameter pytree (front blocks quantized)
# ---------------------------------------------------------------------------


def quantize_front_params(params, split_layer: int, qw_front: int, num_blocks: int,
                          pattern_len: int = 1):
    """Quantize the *front* (edge) segment of a stacked-blocks param pytree.

    Parameters under ``params['blocks']`` are stacked along dim 0 with
    ``num_blocks`` entries (each covering ``pattern_len`` layers).  Front
    blocks [0, split_layer/pattern_len) are symmetrically quantized at
    ``qw_front`` bits and immediately dequantized back — fake-quant semantics,
    which is what accuracy evaluation needs; the int carriers for deployment
    come from :func:`repro.core.quant.quantize_sym` directly.
    """
    import jax.numpy as jnp

    from repro.core.quant import quantize_sym

    front_blocks = min(num_blocks, max(0, split_layer // max(pattern_len, 1)))
    if front_blocks == 0:
        return params

    def fake_quant_leading(x):
        if not hasattr(x, "ndim") or x.ndim < 2 or x.shape[0] != num_blocks:
            return x
        front = x[:front_blocks]
        fq = quantize_sym(front.reshape(front.shape[0], -1), qw_front, axis=-1)
        deq = fq.dequantize(front.dtype).reshape(front.shape)
        return jnp.concatenate([deq, x[front_blocks:]], axis=0)

    import jax

    out = dict(params)
    out["blocks"] = jax.tree_util.tree_map(fake_quant_leading, params["blocks"])
    return out
